//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! (HLO text) and executes them on the XLA CPU client from the Rust hot
//! path. Python never runs at request time.
//!
//! - [`pjrt::Engine`] — PJRT client + compile cache;
//! - [`manifest::Manifest`] — artifact shapes (artifacts/manifest.json);
//! - [`scorer::PjrtScorer`] — batched split-criterion scoring (L1 kernel);
//! - [`predictor::PjrtPredictor`] — batched forest inference over a
//!   tensorized forest (L2 graph).
//!
//! Every runtime component has a native-Rust fallback with identical
//! semantics; parity tests in each module pin them together.

pub mod manifest;
pub mod pjrt;
pub mod predictor;
pub mod scorer;
pub mod tensorize;

pub use manifest::Manifest;
pub use pjrt::Engine;
pub use predictor::PjrtPredictor;
pub use scorer::PjrtScorer;
