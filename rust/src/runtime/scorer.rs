//! Batched split-criterion scoring through the AOT-compiled L1 Pallas
//! kernel, with the native `forest::criterion` implementation as both
//! fallback and parity oracle.

use crate::forest::params::SplitCriterion;
use crate::runtime::manifest::Manifest;
use crate::runtime::pjrt::{Engine, Input, LoadedExe};

/// One candidate's counts (matching the kernel's four input vectors).
#[derive(Clone, Copy, Debug)]
pub struct Counts {
    pub n: u32,
    pub n_pos: u32,
    pub n_left: u32,
    pub n_left_pos: u32,
}

/// PJRT-backed scorer for one criterion.
pub struct PjrtScorer {
    exe: LoadedExe,
    batch: usize,
    criterion: SplitCriterion,
}

impl PjrtScorer {
    pub fn new(
        engine: &Engine,
        manifest: &Manifest,
        criterion: SplitCriterion,
    ) -> anyhow::Result<Self> {
        let art = match criterion {
            SplitCriterion::Gini => &manifest.score_gini,
            SplitCriterion::Entropy => &manifest.score_entropy,
        };
        Ok(PjrtScorer {
            exe: engine.load_hlo_text(&art.file)?,
            batch: art.batch,
            criterion,
        })
    }

    pub fn criterion(&self) -> SplitCriterion {
        self.criterion
    }

    /// Kernel batch size (callers may exceed it; chunking is internal).
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Score candidates via the compiled kernel. Input length is arbitrary;
    /// batches are padded with benign counts and truncated on return.
    pub fn score(&self, counts: &[Counts]) -> anyhow::Result<Vec<f32>> {
        let mut out = Vec::with_capacity(counts.len());
        for chunk in counts.chunks(self.batch) {
            let mut n = vec![1.0f32; self.batch];
            let mut np = vec![0.0f32; self.batch];
            let mut nl = vec![0.0f32; self.batch];
            let mut nlp = vec![0.0f32; self.batch];
            for (i, c) in chunk.iter().enumerate() {
                n[i] = c.n as f32;
                np[i] = c.n_pos as f32;
                nl[i] = c.n_left as f32;
                nlp[i] = c.n_left_pos as f32;
            }
            let dims = vec![self.batch as i64];
            let scores = self.exe.run_f32(&[
                Input::F32(n, dims.clone()),
                Input::F32(np, dims.clone()),
                Input::F32(nl, dims.clone()),
                Input::F32(nlp, dims),
            ])?;
            out.extend_from_slice(&scores[..chunk.len()]);
        }
        Ok(out)
    }
}

/// Native fallback with identical semantics (f64 internally, like the
/// forest's own scorer, cast to f32 on return).
pub fn score_native(criterion: SplitCriterion, counts: &[Counts]) -> Vec<f32> {
    counts
        .iter()
        .map(|c| {
            crate::forest::criterion::split_score(criterion, c.n, c.n_pos, c.n_left, c.n_left_pos)
                as f32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::locate_artifacts;
    use crate::util::rng::Rng;

    fn random_counts(rng: &mut Rng, total: usize) -> Vec<Counts> {
        (0..total)
            .map(|_| {
                let n = 1 + rng.index(1000) as u32;
                let n_pos = rng.index(n as usize + 1) as u32;
                let n_left = rng.index(n as usize + 1) as u32;
                let lo = n_pos.saturating_sub(n - n_left);
                let hi = n_left.min(n_pos);
                let n_left_pos = if hi > lo {
                    lo + rng.index((hi - lo) as usize + 1) as u32
                } else {
                    lo
                };
                Counts {
                    n,
                    n_pos,
                    n_left,
                    n_left_pos,
                }
            })
            .collect()
    }

    #[test]
    fn pjrt_matches_native_for_both_criteria() {
        let Some(dir) = locate_artifacts() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let manifest = Manifest::load(&dir).unwrap();
        let Ok(engine) = Engine::global() else {
            eprintln!("skipping: PJRT backend unavailable");
            return;
        };
        let mut rng = Rng::new(4);
        for criterion in [SplitCriterion::Gini, SplitCriterion::Entropy] {
            let scorer = PjrtScorer::new(engine, &manifest, criterion).unwrap();
            // irregular length forces chunking + padding
            let counts = random_counts(&mut rng, scorer.batch() + 333);
            let got = scorer.score(&counts).unwrap();
            let want = score_native(criterion, &counts);
            assert_eq!(got.len(), counts.len());
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (g - w).abs() < 1e-5,
                    "{criterion:?} candidate {i}: pjrt {g} vs native {w} ({:?})",
                    counts[i]
                );
            }
        }
    }

    #[test]
    fn native_scorer_edge_cases() {
        let cases = [
            Counts { n: 4, n_pos: 2, n_left: 2, n_left_pos: 2 }, // perfect
            Counts { n: 5, n_pos: 2, n_left: 0, n_left_pos: 0 }, // empty side
            Counts { n: 8, n_pos: 4, n_left: 4, n_left_pos: 2 }, // useless
        ];
        let g = score_native(SplitCriterion::Gini, &cases);
        assert!(g[0].abs() < 1e-7);
        assert!(g.iter().all(|v| v.is_finite()));
        assert!((g[2] - 0.5).abs() < 1e-6);
        let e = score_native(SplitCriterion::Entropy, &cases);
        assert!(e[0].abs() < 1e-7);
        assert!((e[2] - 1.0).abs() < 1e-6);
    }
}
