//! Tensorize a DaRE forest for the L2 predict graph: flatten each tree into
//! fixed-size node arrays. Leaves self-loop; padded trees are value-0 single
//! leaves (they add 0 to the sum the graph returns).
//!
//! Since the arena refactor (DESIGN.md §7) this reads the per-tree SoA hot
//! plane directly instead of traversing boxed nodes. A freshly trained tree
//! is already stored in BFS order with root at slot 0 and children in
//! contiguous pairs — exactly this artifact's layout — so tensorizing it is
//! a linear copy with only the leaf self-loop fix-up. Trees that have been
//! churned by deletions (free-list reuse breaks BFS order) fall back to a
//! BFS remap over the same flat arrays — still no pointer chasing.

use crate::forest::arena::{ArenaTree, NIL};
use crate::forest::forest::DareForest;
use crate::runtime::manifest::PredictArtifact;

/// Flat forest arrays matching the predict artifact's (T, M) layout.
#[derive(Clone, Debug)]
pub struct TensorForest {
    pub attr: Vec<i32>,    // T*M
    pub thresh: Vec<f32>,  // T*M
    pub left: Vec<i32>,    // T*M
    pub right: Vec<i32>,   // T*M
    pub value: Vec<f32>,   // T*M
    pub n_real_trees: usize,
    pub trees: usize,
    pub nodes: usize,
}

/// Errors when the forest exceeds the artifact's static shape.
pub fn tensorize(forest: &DareForest, art: &PredictArtifact) -> anyhow::Result<TensorForest> {
    let t_real = forest.n_trees();
    anyhow::ensure!(
        t_real <= art.trees,
        "forest has {t_real} trees, artifact supports {}",
        art.trees
    );
    anyhow::ensure!(
        forest.data().n_features() <= art.features,
        "dataset has {} features, artifact supports {}",
        forest.data().n_features(),
        art.features
    );
    let (t, m) = (art.trees, art.nodes);
    let mut tf = TensorForest {
        attr: vec![0; t * m],
        thresh: vec![0.0; t * m],
        left: vec![0; t * m],
        right: vec![0; t * m],
        value: vec![0.0; t * m],
        n_real_trees: t_real,
        trees: t,
        nodes: m,
    };
    // initialize all slots as self-looping value-0 leaves
    for ti in 0..t {
        for ni in 0..m {
            tf.left[ti * m + ni] = ni as i32;
            tf.right[ti * m + ni] = ni as i32;
        }
    }
    for (ti, tree) in forest.trees().iter().enumerate() {
        flatten_tree(&tree.arena, ti, m, &mut tf)?;
        let max_d = tree.shape().max_depth;
        anyhow::ensure!(
            max_d <= art.depth,
            "tree depth {max_d} exceeds artifact unroll bound {}",
            art.depth
        );
    }
    Ok(tf)
}

/// Flatten one arena tree into slots `[ti*m .. ti*m+m)`. Returns nodes used.
fn flatten_tree(arena: &ArenaTree, ti: usize, m: usize, tf: &mut TensorForest) -> anyhow::Result<usize> {
    let base = ti * m;
    let hot = arena.hot();
    if arena.is_bfs_compact() {
        // Fresh build: the hot plane IS the artifact layout — linear copy,
        // converting the leaf encoding (left == NIL) to self-loops.
        let used = arena.len();
        anyhow::ensure!(used <= m, "tree has {used} nodes, artifact supports {m} slots");
        for i in 0..used {
            let l = hot.left[i];
            if l == NIL {
                tf.value[base + i] = hot.value[i];
                tf.left[base + i] = i as i32;
                tf.right[base + i] = i as i32;
            } else {
                tf.attr[base + i] = hot.attr[i] as i32;
                tf.thresh[base + i] = hot.thresh[i];
                tf.left[base + i] = l as i32;
                tf.right[base + i] = hot.right[i] as i32;
            }
        }
        return Ok(used);
    }
    // Churned arena: BFS remap of node ids onto dense slots, reading only
    // the flat hot-plane arrays.
    let mut queue: std::collections::VecDeque<(u32, usize)> = Default::default();
    let mut next_free = 1usize;
    queue.push_back((arena.root(), 0));
    while let Some((nid, slot)) = queue.pop_front() {
        let ni = nid as usize;
        let l = hot.left[ni];
        if l == NIL {
            tf.value[base + slot] = hot.value[ni];
            tf.left[base + slot] = slot as i32;
            tf.right[base + slot] = slot as i32;
        } else {
            anyhow::ensure!(next_free + 1 < m, "tree exceeds {m} node slots");
            tf.attr[base + slot] = hot.attr[ni] as i32;
            tf.thresh[base + slot] = hot.thresh[ni];
            tf.left[base + slot] = next_free as i32;
            tf.right[base + slot] = (next_free + 1) as i32;
            queue.push_back((l, next_free));
            queue.push_back((hot.right[ni], next_free + 1));
            next_free += 2;
        }
    }
    Ok(next_free)
}

/// Re-flatten one tree into an existing tensor snapshot, resetting its slot
/// range to padded self-looping zero leaves first. This is the per-shard
/// refresh path (DESIGN.md §8): after mutations, the predictor re-tensorizes
/// only the trees of shards whose epoch moved instead of the whole forest.
/// `depth_bound` is the artifact's unroll bound (`PredictArtifact::depth`).
pub fn retensorize_tree(
    tf: &mut TensorForest,
    arena: &ArenaTree,
    ti: usize,
    depth_bound: usize,
) -> anyhow::Result<()> {
    anyhow::ensure!(
        ti < tf.n_real_trees,
        "tree index {ti} out of range ({} real trees)",
        tf.n_real_trees
    );
    let m = tf.nodes;
    let base = ti * m;
    for ni in 0..m {
        tf.attr[base + ni] = 0;
        tf.thresh[base + ni] = 0.0;
        tf.left[base + ni] = ni as i32;
        tf.right[base + ni] = ni as i32;
        tf.value[base + ni] = 0.0;
    }
    flatten_tree(arena, ti, m, tf)?;
    let max_d = arena.shape().max_depth;
    anyhow::ensure!(
        max_d <= depth_bound,
        "tree depth {max_d} exceeds artifact unroll bound {depth_bound}"
    );
    Ok(())
}

/// Pure-Rust traversal of the tensorized arrays — the parity oracle for the
/// PJRT predictor and a fallback when artifacts are unavailable.
pub fn predict_tensorized(tf: &TensorForest, row: &[f32]) -> f32 {
    let m = tf.nodes;
    let mut sum = 0.0f32;
    for ti in 0..tf.trees {
        let base = ti * m;
        let mut idx = 0usize;
        loop {
            let l = tf.left[base + idx] as usize;
            let r = tf.right[base + idx] as usize;
            if l == idx && r == idx {
                break;
            }
            let a = tf.attr[base + idx] as usize;
            let v = tf.thresh[base + idx];
            idx = if row.get(a).copied().unwrap_or(0.0) <= v {
                l
            } else {
                r
            };
        }
        sum += tf.value[base + idx];
    }
    sum / tf.n_real_trees as f32
}

/// Batched native traversal: all rows advance through one tree before the
/// next tree is touched, so the tree's upper slots stay cached — the
/// tensorized twin of the arena's level-synchronous block descent.
pub fn predict_tensorized_rows(tf: &TensorForest, rows: &[Vec<f32>]) -> Vec<f32> {
    let m = tf.nodes;
    let mut sums = vec![0.0f32; rows.len()];
    for ti in 0..tf.trees {
        let base = ti * m;
        for (row, s) in rows.iter().zip(sums.iter_mut()) {
            let mut idx = 0usize;
            loop {
                let l = tf.left[base + idx] as usize;
                let r = tf.right[base + idx] as usize;
                if l == idx && r == idx {
                    break;
                }
                let a = tf.attr[base + idx] as usize;
                let v = tf.thresh[base + idx];
                idx = if row.get(a).copied().unwrap_or(0.0) <= v {
                    l
                } else {
                    r
                };
            }
            *s += tf.value[base + idx];
        }
    }
    let nt = tf.n_real_trees as f32;
    for s in sums.iter_mut() {
        *s /= nt;
    }
    sums
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};
    use crate::forest::params::Params;
    use crate::runtime::manifest::PredictArtifact;

    fn art() -> PredictArtifact {
        PredictArtifact {
            file: "unused".into(),
            batch: 8,
            features: 16,
            trees: 8,
            nodes: 512,
            depth: 24,
        }
    }

    fn forest(n_trees: usize) -> DareForest {
        let d = generate(
            &SynthSpec {
                n: 300,
                informative: 3,
                redundant: 1,
                noise: 2,
                flip: 0.05,
                ..Default::default()
            },
            3,
        );
        DareForest::fit(
            d,
            &Params {
                n_trees,
                max_depth: 6,
                k: 5,
                d_rmax: 1,
                ..Default::default()
            },
            9,
        )
    }

    #[test]
    fn tensorized_matches_native_predictions() {
        let f = forest(4);
        let tf = tensorize(&f, &art()).unwrap();
        assert_eq!(tf.n_real_trees, 4);
        for id in f.data().live_ids().iter().take(100) {
            let row = f.data().row(*id);
            let native = f.predict_proba(&row);
            let tens = predict_tensorized(&tf, &row);
            assert!(
                (native - tens).abs() < 1e-6,
                "id {id}: native {native} vs tensorized {tens}"
            );
        }
    }

    #[test]
    fn churned_forest_takes_bfs_remap_path_and_still_matches() {
        let mut f = forest(4);
        // deep churn: drain most of the data so leaf collapses and argmax
        // moves are certain to have freed arena slots in every tree
        for id in f.live_ids().into_iter().take(250) {
            f.delete_seq(id).unwrap();
        }
        assert!(
            f.trees().iter().any(|t| !t.arena.is_bfs_compact()),
            "deletions should leave at least one non-compact arena"
        );
        let tf = tensorize(&f, &art()).unwrap();
        for id in f.data().live_ids().iter().take(100) {
            let row = f.data().row(*id);
            assert!((f.predict_proba(&row) - predict_tensorized(&tf, &row)).abs() < 1e-6);
        }
    }

    #[test]
    fn batched_tensorized_matches_per_row() {
        let f = forest(3);
        let tf = tensorize(&f, &art()).unwrap();
        let rows: Vec<Vec<f32>> = (0..50u32).map(|i| f.data().row(i)).collect();
        let batched = predict_tensorized_rows(&tf, &rows);
        for (row, b) in rows.iter().zip(&batched) {
            assert_eq!(*b, predict_tensorized(&tf, row));
        }
    }

    #[test]
    fn retensorize_tree_matches_full_tensorize() {
        let mut f = forest(4);
        let a = art();
        let mut tf = tensorize(&f, &a).unwrap();
        // churn only some trees, then refresh exactly those slots in place
        for id in f.live_ids().into_iter().take(120) {
            f.delete_seq(id).unwrap();
        }
        for (ti, tree) in f.trees().iter().enumerate() {
            retensorize_tree(&mut tf, &tree.arena, ti, a.depth).unwrap();
        }
        let full = tensorize(&f, &a).unwrap();
        assert_eq!(tf.attr, full.attr);
        assert_eq!(tf.thresh, full.thresh);
        assert_eq!(tf.left, full.left);
        assert_eq!(tf.right, full.right);
        assert_eq!(tf.value, full.value);
        // out-of-range tree index is rejected
        assert!(retensorize_tree(&mut tf, &f.trees()[0].arena, 4, a.depth).is_err());
        // an impossible depth bound is rejected
        assert!(retensorize_tree(&mut tf, &f.trees()[0].arena, 0, 0).is_err());
    }

    #[test]
    fn rejects_too_many_trees() {
        let f = forest(9);
        assert!(tensorize(&f, &art()).is_err());
    }

    #[test]
    fn rejects_too_many_features() {
        let d = generate(
            &SynthSpec {
                n: 100,
                informative: 10,
                redundant: 5,
                noise: 5,
                ..Default::default()
            },
            1,
        );
        let f = DareForest::fit(
            d,
            &Params {
                n_trees: 2,
                max_depth: 3,
                k: 5,
                ..Default::default()
            },
            1,
        );
        assert!(tensorize(&f, &art()).is_err()); // 20 > 16 features
    }

    #[test]
    fn padded_tree_slots_are_zero_leaves() {
        let f = forest(2);
        let tf = tensorize(&f, &art()).unwrap();
        // slots for trees 2..8 must be self-looping zero leaves
        let m = tf.nodes;
        for ti in 2..8 {
            assert_eq!(tf.value[ti * m], 0.0);
            assert_eq!(tf.left[ti * m], 0);
            assert_eq!(tf.right[ti * m], 0);
        }
    }
}
