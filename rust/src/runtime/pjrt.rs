//! PJRT engine: wraps the `xla` crate's CPU client, loads HLO-text
//! artifacts, compiles them once, and executes with f32/i32 literals.
//!
//! Pattern follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `client.compile` → `execute`.
//!
//! **Build gating:** the `xla` crate is not on crates.io and only exists in
//! images that ship the XLA toolchain. The real backend compiles under
//! `--cfg pjrt_xla` (set `RUSTFLAGS="--cfg pjrt_xla"` and add the `xla`
//! path dependency); otherwise an API-identical stub is built whose
//! [`Engine::new`] fails, so every caller takes its native fallback exactly
//! as it would when artifacts are missing. The native paths have identical
//! semantics (see `runtime::mod`), so no functionality is lost — only the
//! batched-inference speedup.

#[cfg(pjrt_xla)]
mod backend {
    use std::path::Path;
    use std::sync::Mutex;

    /// The backend's literal type (re-exported so callers never name `xla::`
    /// directly and keep compiling against the stub).
    pub type Literal = xla::Literal;

    // The `xla` crate's client/executable types hold raw pointers and are not
    // marked Send/Sync, but the underlying PJRT C API objects are thread-safe
    // (the PJRT contract requires it; the TFRT CPU client serializes
    // internally). We wrap them and assert Send + Sync, and additionally
    // serialize all compile/execute calls behind Mutexes for belt-and-braces
    // safety.
    struct SendClient(xla::PjRtClient);
    unsafe impl Send for SendClient {}
    struct SendExe(xla::PjRtLoadedExecutable);
    unsafe impl Send for SendExe {}

    /// A compiled executable plus its expected argument count.
    pub struct LoadedExe {
        exe: Mutex<SendExe>,
    }

    /// One input tensor for execution.
    pub enum Input {
        F32(Vec<f32>, Vec<i64>),
        I32(Vec<i32>, Vec<i64>),
    }

    impl Input {
        fn to_literal(&self) -> anyhow::Result<Literal> {
            match self {
                Input::F32(data, dims) => Ok(xla::Literal::vec1(data).reshape(dims)?),
                Input::I32(data, dims) => Ok(xla::Literal::vec1(data).reshape(dims)?),
            }
        }
    }

    impl LoadedExe {
        /// Execute and return the first (tuple-unwrapped) output as f32s.
        pub fn run_f32(&self, inputs: &[Input]) -> anyhow::Result<Vec<f32>> {
            let literals: Vec<Literal> = inputs
                .iter()
                .map(|i| i.to_literal())
                .collect::<anyhow::Result<_>>()?;
            let refs: Vec<&Literal> = literals.iter().collect();
            self.run_f32_literals(&refs)
        }

        /// Execute with pre-built literals (hot path: callers cache the large
        /// constant inputs — e.g. the tensorized forest — across calls).
        pub fn run_f32_literals(&self, inputs: &[&Literal]) -> anyhow::Result<Vec<f32>> {
            let exe = self.exe.lock().unwrap();
            let result = exe.0.execute::<&Literal>(inputs)?[0][0].to_literal_sync()?;
            // aot.py lowers with return_tuple=True → 1-tuple output
            let out = result.to_tuple1()?;
            Ok(out.to_vec::<f32>()?)
        }
    }

    /// Build a literal from an [`Input`] (exposed for callers that cache).
    pub fn build_literal(input: &Input) -> anyhow::Result<Literal> {
        input.to_literal()
    }

    /// PJRT CPU engine. Creating a client is expensive (TFRT thread pools),
    /// so share one per process via [`Engine::global`].
    pub struct Engine {
        client: Mutex<SendClient>,
    }

    impl Engine {
        pub fn new() -> anyhow::Result<Engine> {
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e}"))?;
            Ok(Engine {
                client: Mutex::new(SendClient(client)),
            })
        }

        /// Process-wide shared engine (PJRT clients are heavy; one is
        /// enough).
        pub fn global() -> anyhow::Result<&'static Engine> {
            use std::sync::OnceLock;
            static ENGINE: OnceLock<Option<Engine>> = OnceLock::new();
            ENGINE
                .get_or_init(|| Engine::new().ok())
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("failed to create PJRT CPU client"))
        }

        /// Load an HLO-text artifact and compile it.
        pub fn load_hlo_text(&self, path: &Path) -> anyhow::Result<LoadedExe> {
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let client = self.client.lock().unwrap();
            let exe = client
                .0
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compile {}: {e}", path.display()))?;
            Ok(LoadedExe {
                exe: Mutex::new(SendExe(exe)),
            })
        }

        pub fn platform(&self) -> String {
            self.client.lock().unwrap().0.platform_name()
        }
    }
}

#[cfg(not(pjrt_xla))]
mod backend {
    //! API-identical stub: every entry point fails with a clear message, so
    //! callers fall back to the native implementations (the same graceful
    //! path they take when AOT artifacts are missing).

    use std::path::Path;

    const UNAVAILABLE: &str =
        "PJRT backend not compiled in (build with RUSTFLAGS=\"--cfg pjrt_xla\" and the xla crate)";

    /// Opaque placeholder for the backend literal type.
    pub struct Literal {
        _private: (),
    }

    /// A compiled executable (never constructible in the stub).
    pub struct LoadedExe {
        _private: (),
    }

    /// One input tensor for execution.
    pub enum Input {
        F32(Vec<f32>, Vec<i64>),
        I32(Vec<i32>, Vec<i64>),
    }

    impl LoadedExe {
        pub fn run_f32(&self, _inputs: &[Input]) -> anyhow::Result<Vec<f32>> {
            Err(anyhow::anyhow!(UNAVAILABLE))
        }

        pub fn run_f32_literals(&self, _inputs: &[&Literal]) -> anyhow::Result<Vec<f32>> {
            Err(anyhow::anyhow!(UNAVAILABLE))
        }
    }

    /// Build a literal from an [`Input`] (exposed for callers that cache).
    pub fn build_literal(_input: &Input) -> anyhow::Result<Literal> {
        Err(anyhow::anyhow!(UNAVAILABLE))
    }

    /// PJRT CPU engine stub: construction always fails.
    pub struct Engine {
        _private: (),
    }

    impl Engine {
        pub fn new() -> anyhow::Result<Engine> {
            Err(anyhow::anyhow!(UNAVAILABLE))
        }

        pub fn global() -> anyhow::Result<&'static Engine> {
            Err(anyhow::anyhow!(UNAVAILABLE))
        }

        pub fn load_hlo_text(&self, _path: &Path) -> anyhow::Result<LoadedExe> {
            Err(anyhow::anyhow!(UNAVAILABLE))
        }

        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }
    }
}

pub use backend::{build_literal, Engine, Input, Literal, LoadedExe};

#[cfg(all(test, pjrt_xla))]
mod tests {
    use super::*;
    use crate::runtime::manifest::locate_artifacts;
    use std::path::Path;

    #[test]
    fn engine_loads_and_runs_score_artifact() {
        let Some(dir) = locate_artifacts() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = crate::runtime::Manifest::load(&dir).unwrap();
        let engine = Engine::global().unwrap();
        assert_eq!(engine.platform(), "cpu");
        let exe = engine.load_hlo_text(&m.score_gini.file).unwrap();
        let b = m.score_gini.batch;
        let dims = vec![b as i64];
        let out = exe
            .run_f32(&[
                Input::F32(vec![10.0; b], dims.clone()),
                Input::F32(vec![4.0; b], dims.clone()),
                Input::F32(vec![6.0; b], dims.clone()),
                Input::F32(vec![1.0; b], dims.clone()),
            ])
            .unwrap();
        assert_eq!(out.len(), b);
        // matches rust/src/forest/criterion.rs gini_known_value
        let expect = 0.6 * (10.0 / 36.0) + 0.4 * (6.0 / 16.0);
        assert!((out[0] as f64 - expect).abs() < 1e-6, "{}", out[0]);
        assert!(out.iter().all(|v| (v - out[0]).abs() < 1e-7));
    }

    #[test]
    fn load_missing_file_errors() {
        let engine = match Engine::global() {
            Ok(e) => e,
            Err(_) => return,
        };
        assert!(engine
            .load_hlo_text(Path::new("/nonexistent/file.hlo.txt"))
            .is_err());
    }
}

#[cfg(all(test, not(pjrt_xla)))]
mod stub_tests {
    use super::*;

    #[test]
    fn stub_engine_reports_unavailable() {
        let e = match Engine::global() {
            Err(e) => e,
            Ok(_) => panic!("stub engine should not construct"),
        };
        assert!(e.to_string().contains("PJRT backend not compiled in"));
        assert!(Engine::new().is_err());
        let lit = build_literal(&Input::F32(vec![1.0], vec![1]));
        assert!(lit.is_err());
    }
}
