//! Artifact manifest (artifacts/manifest.json): shapes and file names the
//! AOT step baked into the HLO modules, so Rust never hard-codes them.

use crate::util::json::{parse, Value};
use std::path::{Path, PathBuf};

#[derive(Clone, Debug)]
pub struct ScoreArtifact {
    pub file: PathBuf,
    pub batch: usize,
    pub block: usize,
}

#[derive(Clone, Debug)]
pub struct PredictArtifact {
    pub file: PathBuf,
    pub batch: usize,
    pub features: usize,
    pub trees: usize,
    pub nodes: usize,
    pub depth: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub score_gini: ScoreArtifact,
    pub score_entropy: ScoreArtifact,
    pub predict: PredictArtifact,
    /// Optional small-tree-count variant — XLA-CPU gather cost scales with
    /// the padded tree dimension, so ≤32-tree forests use this one (§Perf).
    pub predict_small: Option<PredictArtifact>,
}

impl Manifest {
    /// Default artifact directory: `$DARE_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("DARE_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Load `manifest.json` from a directory.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| anyhow::anyhow!("missing artifacts (run `make artifacts`): {e}"))?;
        let v = parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        anyhow::ensure!(
            v.get("format").and_then(|x| x.as_str()) == Some("dare-artifacts-v1"),
            "unknown artifact manifest format"
        );
        let arts = v
            .get("artifacts")
            .ok_or_else(|| anyhow::anyhow!("manifest missing 'artifacts'"))?;

        let score = |key: &str| -> anyhow::Result<ScoreArtifact> {
            let a = arts
                .get(key)
                .ok_or_else(|| anyhow::anyhow!("manifest missing '{key}'"))?;
            Ok(ScoreArtifact {
                file: dir.join(
                    a.get("file")
                        .and_then(Value::as_str)
                        .ok_or_else(|| anyhow::anyhow!("{key}.file missing"))?,
                ),
                batch: a
                    .get("batch")
                    .and_then(Value::as_usize)
                    .ok_or_else(|| anyhow::anyhow!("{key}.batch missing"))?,
                block: a.get("block").and_then(Value::as_usize).unwrap_or(0),
            })
        };
        let predict_art = |key: &str| -> anyhow::Result<PredictArtifact> {
            let p = arts
                .get(key)
                .ok_or_else(|| anyhow::anyhow!("manifest missing '{key}'"))?;
            let pu = |k: &str| -> anyhow::Result<usize> {
                p.get(k)
                    .and_then(Value::as_usize)
                    .ok_or_else(|| anyhow::anyhow!("{key}.{k} missing"))
            };
            Ok(PredictArtifact {
                file: dir.join(
                    p.get("file")
                        .and_then(Value::as_str)
                        .ok_or_else(|| anyhow::anyhow!("{key}.file missing"))?,
                ),
                batch: pu("batch")?,
                features: pu("features")?,
                trees: pu("trees")?,
                nodes: pu("nodes")?,
                depth: pu("depth")?,
            })
        };
        Ok(Manifest {
            dir: dir.to_path_buf(),
            score_gini: score("split_scores_gini")?,
            score_entropy: score("split_scores_entropy")?,
            predict: predict_art("forest_predict")?,
            predict_small: predict_art("forest_predict_small").ok(),
        })
    }

    /// Smallest predict artifact that fits a forest with `n_trees`.
    pub fn predict_for(&self, n_trees: usize) -> &PredictArtifact {
        match &self.predict_small {
            Some(s) if n_trees <= s.trees => s,
            _ => &self.predict,
        }
    }
}

/// Locate the artifacts dir for tests/examples: walks up from cwd looking
/// for `artifacts/manifest.json`. Returns None when artifacts are not built.
pub fn locate_artifacts() -> Option<PathBuf> {
    let mut dir = std::env::current_dir().ok()?;
    loop {
        let cand = dir.join("artifacts/manifest.json");
        if cand.exists() {
            return Some(dir.join("artifacts"));
        }
        if !dir.pop() {
            return None;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_built_manifest_when_present() {
        let Some(dir) = locate_artifacts() else {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert!(m.score_gini.batch >= m.score_gini.block);
        assert!(m.predict.trees > 0);
        assert!(m.predict.depth >= 20);
        assert!(m.score_gini.file.exists());
        assert!(m.score_entropy.file.exists());
        assert!(m.predict.file.exists());
    }

    #[test]
    fn missing_dir_errors() {
        assert!(Manifest::load(Path::new("/nonexistent/xyz")).is_err());
    }
}
