//! Batched forest inference through the AOT-compiled L2 graph, with a
//! native tensorized fallback. Used by the evaluation harness (test-set
//! metrics) and the coordinator's Predict path.

use crate::forest::forest::DareForest;
use crate::runtime::manifest::Manifest;
use crate::runtime::pjrt::{Engine, Input, Literal, LoadedExe};
use crate::runtime::tensorize::{tensorize, TensorForest};

/// PJRT-backed batch predictor over a tensorized forest snapshot.
///
/// The five forest arrays (~10 MB at the default artifact shape) are built
/// into PJRT literals once per snapshot/refresh and reused across predict
/// calls — only the feature batch is uploaded per call (§Perf: this took
/// the 256-row batch from ~49 ms to single-digit ms).
pub struct PjrtPredictor {
    exe: LoadedExe,
    tf: TensorForest,
    forest_literals: Vec<SendLiteral>,
    batch: usize,
    features: usize,
    /// Artifact unroll bound, re-checked on per-tree refreshes.
    depth: usize,
}

/// The backend `Literal` wraps a raw pointer and is not marked Send;
/// literals are plain host buffers owned by this predictor and only touched
/// under the caller's synchronization (the service keeps the predictor in a
/// Mutex).
struct SendLiteral(Literal);
unsafe impl Send for SendLiteral {}

impl PjrtPredictor {
    /// Tensorize `forest` against the predict artifact and compile it.
    /// Fails when the forest exceeds the artifact's static shape — callers
    /// fall back to native prediction.
    pub fn new(engine: &Engine, manifest: &Manifest, forest: &DareForest) -> anyhow::Result<Self> {
        let art = manifest.predict_for(forest.n_trees());
        let tf = tensorize(forest, art)?;
        let forest_literals = Self::build_forest_literals(&tf)?;
        Ok(PjrtPredictor {
            exe: engine.load_hlo_text(&art.file)?,
            tf,
            forest_literals,
            batch: art.batch,
            features: art.features,
            depth: art.depth,
        })
    }

    fn build_forest_literals(tf: &TensorForest) -> anyhow::Result<Vec<SendLiteral>> {
        let (t, m) = (tf.trees, tf.nodes);
        let tm = vec![t as i64, m as i64];
        [
            Input::I32(tf.attr.clone(), tm.clone()),
            Input::F32(tf.thresh.clone(), tm.clone()),
            Input::I32(tf.left.clone(), tm.clone()),
            Input::I32(tf.right.clone(), tm.clone()),
            Input::F32(tf.value.clone(), tm),
        ]
        .iter()
        .map(|i| crate::runtime::pjrt::build_literal(i).map(SendLiteral))
        .collect()
    }

    /// Refresh the forest snapshot (after deletions) without recompiling.
    /// The variant (small/large) is fixed at construction.
    pub fn refresh(&mut self, manifest: &Manifest, forest: &DareForest) -> anyhow::Result<()> {
        let art = if manifest
            .predict_small
            .as_ref()
            .map(|s| s.trees == self.tf.trees)
            .unwrap_or(false)
        {
            manifest.predict_small.as_ref().unwrap()
        } else {
            &manifest.predict
        };
        self.tf = tensorize(forest, art)?;
        self.forest_literals = Self::build_forest_literals(&self.tf)?;
        Ok(())
    }

    /// Partial refresh (DESIGN.md §8): re-tensorize only `trees` — the tree
    /// subset of one mutated shard, with global indices
    /// `first..first + trees.len()` — in place. Call
    /// [`PjrtPredictor::rebuild_literals`] once after refreshing every dirty
    /// shard. On error the caller should discard the predictor (the forest
    /// outgrew the artifact shape) and fall back to native prediction.
    pub fn refresh_trees(
        &mut self,
        first: usize,
        trees: &[crate::forest::tree::DareTree],
    ) -> anyhow::Result<()> {
        for (k, t) in trees.iter().enumerate() {
            crate::runtime::tensorize::retensorize_tree(&mut self.tf, &t.arena, first + k, self.depth)?;
        }
        Ok(())
    }

    /// Upload the current tensor snapshot as fresh PJRT literals (one call
    /// per refresh round, however many shards were dirty).
    pub fn rebuild_literals(&mut self) -> anyhow::Result<()> {
        self.forest_literals = Self::build_forest_literals(&self.tf)?;
        Ok(())
    }

    /// Positive-class probabilities for row-major feature rows.
    pub fn predict(&self, rows: &[Vec<f32>]) -> anyhow::Result<Vec<f32>> {
        let mut out = Vec::with_capacity(rows.len());
        for chunk in rows.chunks(self.batch) {
            // pad features to the artifact width and the batch to its height
            let mut x = vec![0.0f32; self.batch * self.features];
            for (i, row) in chunk.iter().enumerate() {
                anyhow::ensure!(
                    row.len() <= self.features,
                    "row has {} features, artifact supports {}",
                    row.len(),
                    self.features
                );
                x[i * self.features..i * self.features + row.len()].copy_from_slice(row);
            }
            let x_lit = crate::runtime::pjrt::build_literal(&Input::F32(
                x,
                vec![self.batch as i64, self.features as i64],
            ))?;
            let mut inputs: Vec<&Literal> = Vec::with_capacity(6);
            inputs.push(&x_lit);
            inputs.extend(self.forest_literals.iter().map(|l| &l.0));
            let sums = self.exe.run_f32_literals(&inputs)?;
            for s in &sums[..chunk.len()] {
                out.push(s / self.tf.n_real_trees as f32);
            }
        }
        Ok(out)
    }

    /// Native traversal of the same tensorized snapshot (parity oracle),
    /// batched tree-at-a-time like the arena's block descent.
    pub fn predict_native(&self, rows: &[Vec<f32>]) -> Vec<f32> {
        crate::runtime::tensorize::predict_tensorized_rows(&self.tf, rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};
    use crate::forest::params::Params;
    use crate::runtime::manifest::locate_artifacts;

    fn forest() -> DareForest {
        let d = generate(
            &SynthSpec {
                n: 400,
                informative: 4,
                redundant: 1,
                noise: 3,
                flip: 0.05,
                ..Default::default()
            },
            11,
        );
        DareForest::fit(
            d,
            &Params {
                n_trees: 8,
                max_depth: 7,
                k: 5,
                d_rmax: 2,
                ..Default::default()
            },
            13,
        )
    }

    #[test]
    fn pjrt_predictions_match_native_forest() {
        let Some(dir) = locate_artifacts() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let manifest = Manifest::load(&dir).unwrap();
        let Ok(engine) = Engine::global() else {
            eprintln!("skipping: PJRT backend unavailable");
            return;
        };
        let f = forest();
        let predictor = PjrtPredictor::new(engine, &manifest, &f).unwrap();
        // irregular row count forces chunk padding
        let rows: Vec<Vec<f32>> = f
            .data()
            .live_ids()
            .iter()
            .take(manifest.predict.batch + 17)
            .map(|&i| f.data().row(i))
            .collect();
        let got = predictor.predict(&rows).unwrap();
        assert_eq!(got.len(), rows.len());
        for (i, row) in rows.iter().enumerate() {
            let native = f.predict_proba(row);
            assert!(
                (got[i] - native).abs() < 1e-5,
                "row {i}: pjrt {} vs native {}",
                got[i],
                native
            );
        }
        // native tensorized path agrees too
        let nat = predictor.predict_native(&rows);
        for (a, b) in got.iter().zip(&nat) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn refresh_tracks_deletions() {
        let Some(dir) = locate_artifacts() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let manifest = Manifest::load(&dir).unwrap();
        let Ok(engine) = Engine::global() else {
            eprintln!("skipping: PJRT backend unavailable");
            return;
        };
        let mut f = forest();
        let mut predictor = PjrtPredictor::new(engine, &manifest, &f).unwrap();
        let probe: Vec<Vec<f32>> = (0..8).map(|i| f.data().row(i)).collect();
        let before = predictor.predict(&probe).unwrap();
        for id in f.live_ids().into_iter().take(60) {
            f.delete_seq(id).unwrap();
        }
        predictor.refresh(&manifest, &f).unwrap();
        let after = predictor.predict(&probe).unwrap();
        // parity with the updated native forest
        for (i, row) in probe.iter().enumerate() {
            assert!((after[i] - f.predict_proba(row)).abs() < 1e-5);
        }
        // deletions should have moved at least one probe prediction
        assert!(
            before
                .iter()
                .zip(&after)
                .any(|(a, b)| (a - b).abs() > 1e-7),
            "predictions unchanged after 60 deletions"
        );
    }
}
