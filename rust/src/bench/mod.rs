//! Hand-rolled benchmark harness (no criterion in the offline image).
//!
//! `cargo bench` targets are built with `harness = false` and drive this
//! module: warmup, timed iterations until a target duration or iteration
//! cap, and mean/std/p50/p95 reporting in a criterion-like format. Suites
//! can also dump JSON for the experiment index.

use crate::util::stats::{mean, percentile, std_dev};
use crate::util::timer::fmt_secs;
use std::time::Instant;

/// Config for one benchmark.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    /// Stop once this much measurement time has accumulated.
    pub target_seconds: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 1000,
            target_seconds: 2.0,
        }
    }
}

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn throughput_per_sec(&self) -> f64 {
        if self.mean_s > 0.0 {
            1.0 / self.mean_s
        } else {
            f64::INFINITY
        }
    }

    /// Mean nanoseconds per iteration — the unit the cross-PR perf
    /// trajectory (BENCH_*.json at the repo root) is tracked in.
    pub fn ns_per_iter(&self) -> f64 {
        self.mean_s * 1e9
    }
    pub fn render(&self) -> String {
        format!(
            "{:<44} time: [{} {} {}]  p95: {}  ({} iters)",
            self.name,
            fmt_secs(self.min_s),
            fmt_secs(self.mean_s),
            fmt_secs(self.p50_s),
            fmt_secs(self.p95_s),
            self.iters
        )
    }
}

/// Run one benchmark.
pub fn bench<F: FnMut()>(name: &str, cfg: BenchConfig, mut f: F) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut samples: Vec<f64> = Vec::new();
    let started = Instant::now();
    while samples.len() < cfg.min_iters
        || (samples.len() < cfg.max_iters
            && started.elapsed().as_secs_f64() < cfg.target_seconds)
    {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let (min_s, _) = crate::util::stats::min_max(&samples);
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean_s: mean(&samples),
        std_s: std_dev(&samples),
        p50_s: percentile(&samples, 50.0),
        p95_s: percentile(&samples, 95.0),
        min_s,
    }
}

/// A collection of results with uniform reporting.
#[derive(Default)]
pub struct Suite {
    pub title: String,
    pub results: Vec<BenchResult>,
}

impl Suite {
    pub fn new(title: &str) -> Suite {
        println!("\n=== bench suite: {title} ===");
        Suite {
            title: title.to_string(),
            results: Vec::new(),
        }
    }

    pub fn run<F: FnMut()>(&mut self, name: &str, cfg: BenchConfig, f: F) -> &BenchResult {
        let r = bench(name, cfg, f);
        println!("{}", r.render());
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// The suite as a JSON value: suite name plus per-case stats, with
    /// `ns_per_iter` as the headline number for cross-PR tracking.
    pub fn to_json(&self) -> crate::util::json::Value {
        use crate::util::json::Value;
        let mut arr = Vec::new();
        for r in &self.results {
            let mut o = Value::obj();
            o.set("name", r.name.as_str())
                .set("ns_per_iter", r.ns_per_iter())
                .set("iters", r.iters)
                .set("mean_s", r.mean_s)
                .set("std_s", r.std_s)
                .set("p50_s", r.p50_s)
                .set("p95_s", r.p95_s)
                .set("min_s", r.min_s);
            arr.push(o);
        }
        let mut top = Value::obj();
        top.set("suite", self.title.as_str())
            .set("results", Value::Arr(arr));
        top
    }

    /// Write results to results/bench_<title>.json.
    pub fn save_json(&self) -> anyhow::Result<std::path::PathBuf> {
        std::fs::create_dir_all("results")?;
        let path = std::path::PathBuf::from(format!(
            "results/bench_{}.json",
            self.title.replace([' ', '/'], "_")
        ));
        std::fs::write(&path, self.to_json().to_pretty())?;
        Ok(path)
    }

    /// Write the machine-readable dump to an explicit path — used by the
    /// bench binaries to refresh the `BENCH_<suite>.json` perf-trajectory
    /// files at the repo root.
    pub fn save_json_to<P: AsRef<std::path::Path>>(&self, path: P) -> anyhow::Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_json().to_pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let mut count = 0usize;
        let r = bench(
            "noop",
            BenchConfig {
                warmup_iters: 1,
                min_iters: 5,
                max_iters: 8,
                target_seconds: 0.01,
            },
            || {
                count += 1;
            },
        );
        assert!(r.iters >= 5 && r.iters <= 8);
        assert_eq!(count, r.iters + 1); // + warmup
        assert!(r.mean_s >= 0.0);
        assert!(r.p95_s >= r.p50_s);
        assert!(r.render().contains("noop"));
    }

    #[test]
    fn suite_saves_json() {
        let mut s = Suite::new("unit test");
        s.run(
            "sleepless",
            BenchConfig {
                warmup_iters: 0,
                min_iters: 3,
                max_iters: 3,
                target_seconds: 0.001,
            },
            || {},
        );
        let path = s.save_json().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("sleepless"));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn suite_saves_json_to_explicit_path() {
        let mut s = Suite::new("explicit path");
        s.run(
            "case",
            BenchConfig {
                warmup_iters: 0,
                min_iters: 2,
                max_iters: 2,
                target_seconds: 0.001,
            },
            || {},
        );
        let path = std::env::temp_dir().join("dare_bench_explicit.json");
        s.save_json_to(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("ns_per_iter"));
        assert!(text.contains("explicit path"));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn ns_per_iter_scales_mean() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_s: 0.5e-6,
            std_s: 0.0,
            p50_s: 0.0,
            p95_s: 0.0,
            min_s: 0.0,
        };
        assert!((r.ns_per_iter() - 500.0).abs() < 1e-9);
    }

    #[test]
    fn throughput_inverse_of_mean() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_s: 0.25,
            std_s: 0.0,
            p50_s: 0.25,
            p95_s: 0.25,
            min_s: 0.25,
        };
        assert_eq!(r.throughput_per_sec(), 4.0);
    }
}
