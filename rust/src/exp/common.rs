//! Shared plumbing for the experiment reproductions (`dare reproduce ...`):
//! scaled dataset preparation, config, and JSON result output.

use crate::data::dataset::Dataset;
use crate::data::registry::{corpus, DatasetInfo, PaperParams};
use crate::data::split::train_test;
use crate::forest::params::{Params, SplitCriterion};
use crate::util::json::Value;
use std::path::PathBuf;

/// Experiment configuration (defaults target a few-minute CI-scale run;
/// `--scale 1 --repeats 5 --deletions 0` reproduces the paper's protocol).
#[derive(Clone, Debug)]
pub struct ExpConfig {
    /// Divide each dataset's paper-size n by this (min 800 rows).
    pub scale_div: usize,
    /// Repeats per cell (paper: 5).
    pub repeats: usize,
    /// Deletion cap per speedup run (0 = unlimited, paper protocol).
    pub max_deletions: usize,
    /// Candidate pool for the worst-of adversary (paper: 1000).
    pub worst_of: usize,
    /// Dataset name filter (empty = all 14).
    pub datasets: Vec<String>,
    /// Split criterion.
    pub criterion: SplitCriterion,
    /// Worker threads for training.
    pub threads: usize,
    /// Cap on trees/depth for quick smoke runs (0 = paper values).
    pub max_trees: usize,
    pub seed: u64,
    pub out_dir: PathBuf,
}

impl Default for ExpConfig {
    fn default() -> Self {
        ExpConfig {
            scale_div: 500,
            repeats: 1,
            max_deletions: 150,
            worst_of: 100,
            datasets: Vec::new(),
            criterion: SplitCriterion::Gini,
            threads: crate::util::threadpool::default_threads(),
            max_trees: 0,
            seed: 1,
            out_dir: PathBuf::from("results"),
        }
    }
}

impl ExpConfig {
    /// Datasets selected by the filter, in Table-1 order.
    pub fn selected(&self) -> Vec<DatasetInfo> {
        corpus()
            .into_iter()
            .filter(|d| {
                self.datasets.is_empty()
                    || self
                        .datasets
                        .iter()
                        .any(|n| n.eq_ignore_ascii_case(d.name))
            })
            .collect()
    }

    /// Paper params for a dataset under the configured criterion, with the
    /// optional tree cap applied.
    pub fn paper_params(&self, info: &DatasetInfo) -> PaperParams {
        let mut pp = match self.criterion {
            SplitCriterion::Gini => info.gini,
            SplitCriterion::Entropy => info.entropy,
        };
        if self.max_trees > 0 {
            pp.n_trees = pp.n_trees.min(self.max_trees);
        }
        pp
    }

    /// Instantiate Params from PaperParams with this config's threading.
    pub fn params(&self, pp: &PaperParams, d_rmax: usize) -> Params {
        Params {
            criterion: self.criterion,
            n_threads: self.threads,
            ..Params::from_paper(pp, d_rmax)
        }
    }

    /// Generate + split one dataset at the configured scale (paper: 80/20).
    pub fn prepare(&self, info: &DatasetInfo, repeat: u64) -> (Dataset, Dataset) {
        let full = info.generate(
            self.scale_div,
            crate::util::rng::mix_seed(&[self.seed, repeat]),
        );
        train_test(&full, 0.8, crate::util::rng::mix_seed(&[self.seed, repeat, 0x59]))
    }

    /// Write a result JSON under out_dir.
    pub fn save(&self, name: &str, value: &Value) -> anyhow::Result<PathBuf> {
        std::fs::create_dir_all(&self.out_dir)?;
        let path = self.out_dir.join(format!("{name}.json"));
        std::fs::write(&path, value.to_pretty())?;
        Ok(path)
    }

    /// Load a previously saved result (for aggregation steps like Table 2).
    pub fn load(&self, name: &str) -> Option<Value> {
        let path = self.out_dir.join(format!("{name}.json"));
        let text = std::fs::read_to_string(path).ok()?;
        crate::util::json::parse(&text).ok()
    }

    pub fn criterion_tag(&self) -> &'static str {
        match self.criterion {
            SplitCriterion::Gini => "gini",
            SplitCriterion::Entropy => "entropy",
        }
    }
}

/// The paper's four R-DaRE error tolerances (percent).
pub const TOLERANCES: [f64; 4] = [0.1, 0.25, 0.5, 1.0];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selection_filters() {
        let mut cfg = ExpConfig::default();
        assert_eq!(cfg.selected().len(), 14);
        cfg.datasets = vec!["surgical".into(), "higgs".into()];
        let sel = cfg.selected();
        assert_eq!(sel.len(), 2);
        assert_eq!(sel[0].name, "surgical");
    }

    #[test]
    fn params_respect_caps_and_criterion() {
        let cfg = ExpConfig {
            max_trees: 10,
            criterion: SplitCriterion::Entropy,
            ..Default::default()
        };
        let info = crate::data::registry::find("vaccine").unwrap();
        let pp = cfg.paper_params(&info);
        assert_eq!(pp.n_trees, 10); // capped from 250 (entropy table)
        let p = cfg.params(&pp, 2);
        assert_eq!(p.d_rmax, 2);
        assert_eq!(p.criterion, SplitCriterion::Entropy);
    }

    #[test]
    fn prepare_shapes() {
        let cfg = ExpConfig {
            scale_div: 1000,
            ..Default::default()
        };
        let info = crate::data::registry::find("surgical").unwrap();
        let (tr, te) = cfg.prepare(&info, 0);
        assert_eq!(tr.n_features(), info.p);
        assert!(tr.n_total() >= 600);
        assert!(te.n_total() >= 100);
    }

    #[test]
    fn save_load_roundtrip() {
        let cfg = ExpConfig {
            out_dir: std::env::temp_dir().join("dare_exp_test"),
            ..Default::default()
        };
        let mut v = Value::obj();
        v.set("x", 1u64);
        cfg.save("unit", &v).unwrap();
        let back = cfg.load("unit").unwrap();
        assert_eq!(back.get("x").unwrap().as_u64(), Some(1));
        std::fs::remove_dir_all(&cfg.out_dir).ok();
    }
}
