//! Table 3: memory usage breakdown — training data, G-DaRE structure /
//! decision stats / leaf stats, a lean standard-RF model at the same T and
//! d_max, and the (data + DaRE)/(data + RF) overhead ratio.

use crate::eval::memory::{measure, MemoryRow};
use crate::exp::common::ExpConfig;
use crate::util::json::Value;
use crate::util::table::Table;

pub struct Table3Result {
    pub rows: Vec<(String, MemoryRow)>,
}

pub fn run(cfg: &ExpConfig) -> anyhow::Result<Table3Result> {
    let mut rows = Vec::new();
    for info in cfg.selected() {
        let pp = cfg.paper_params(&info);
        let params = cfg.params(&pp, 0); // G-DaRE
        let (train, _) = cfg.prepare(&info, 0);
        let row = measure(&train, &params, cfg.seed);
        eprintln!(
            "table3 [{}] data={}KB dare={}KB rf={}KB overhead={:.1}x",
            info.name,
            row.data_bytes / 1024,
            row.dare_total / 1024,
            row.sklearn_like / 1024,
            row.overhead_ratio
        );
        rows.push((info.name.to_string(), row));
    }
    let r = Table3Result { rows };
    cfg.save(&format!("table3_{}", cfg.criterion_tag()), &to_json(&r))?;
    Ok(r)
}

fn to_json(r: &Table3Result) -> Value {
    let mut arr = Vec::new();
    for (name, row) in &r.rows {
        let mut o = Value::obj();
        o.set("dataset", name.as_str())
            .set("data_bytes", row.data_bytes)
            .set("structure", row.structure)
            .set("decision_stats", row.decision_stats)
            .set("leaf_stats", row.leaf_stats)
            .set("dare_total", row.dare_total)
            .set("sklearn_like", row.sklearn_like)
            .set("overhead_ratio", row.overhead_ratio)
            .set("mean_decision_nodes", row.mean_decision_nodes);
        arr.push(o);
    }
    let mut top = Value::obj();
    top.set("experiment", "table3").set("rows", Value::Arr(arr));
    top
}

fn mb(bytes: usize) -> String {
    format!("{:.2}", bytes as f64 / 1e6)
}

pub fn render(r: &Table3Result) -> String {
    let mut t = Table::new(
        "Table 3 — memory usage (MB)",
        &[
            "dataset",
            "data",
            "structure",
            "decision stats",
            "leaf stats",
            "total",
            "lean RF",
            "overhead",
        ],
    );
    for (name, row) in &r.rows {
        t.row(vec![
            name.clone(),
            mb(row.data_bytes),
            mb(row.structure),
            mb(row.decision_stats),
            mb(row.leaf_stats),
            mb(row.dare_total),
            mb(row.sklearn_like),
            format!("{:.1}x", row.overhead_ratio),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_two_datasets() {
        let cfg = ExpConfig {
            scale_div: 20_000,
            datasets: vec!["ctr".into(), "credit_card".into()],
            max_trees: 3,
            out_dir: std::env::temp_dir().join("dare_table3_test"),
            ..Default::default()
        };
        let r = run(&cfg).unwrap();
        assert_eq!(r.rows.len(), 2);
        for (_, row) in &r.rows {
            assert!(row.overhead_ratio >= 1.0);
            assert!(row.dare_total > row.sklearn_like);
        }
        let text = render(&r);
        assert!(text.contains("overhead"));
        std::fs::remove_dir_all(&cfg.out_dir).ok();
    }
}
