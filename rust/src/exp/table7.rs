//! Table 7: G-DaRE training time per dataset (mean ± std over repeats).

use crate::exp::common::ExpConfig;
use crate::forest::forest::DareForest;
use crate::util::json::Value;
use crate::util::stats::{mean, std_dev};
use crate::util::table::Table;
use crate::util::timer::time;

#[derive(Clone, Debug)]
pub struct Table7Row {
    pub dataset: String,
    pub n_train: usize,
    pub seconds: Vec<f64>,
}

pub struct Table7Result {
    pub rows: Vec<Table7Row>,
}

pub fn run(cfg: &ExpConfig) -> anyhow::Result<Table7Result> {
    let mut rows = Vec::new();
    for info in cfg.selected() {
        let pp = cfg.paper_params(&info);
        let params = cfg.params(&pp, 0);
        let mut seconds = Vec::new();
        let mut n_train = 0;
        for rep in 0..cfg.repeats.max(1) {
            let (train, _) = cfg.prepare(&info, rep as u64);
            n_train = train.n_total();
            let (_, secs) = time(|| {
                DareForest::fit(
                    train,
                    &params,
                    crate::util::rng::mix_seed(&[cfg.seed, rep as u64]),
                )
            });
            seconds.push(secs);
        }
        eprintln!(
            "table7 [{}] n={} -> {:.2}s ± {:.2}",
            info.name,
            n_train,
            mean(&seconds),
            std_dev(&seconds)
        );
        rows.push(Table7Row {
            dataset: info.name.to_string(),
            n_train,
            seconds,
        });
    }
    let result = Table7Result { rows };
    let mut arr = Vec::new();
    for r in &result.rows {
        let mut o = Value::obj();
        o.set("dataset", r.dataset.as_str())
            .set("n_train", r.n_train)
            .set("seconds", r.seconds.clone());
        arr.push(o);
    }
    let mut top = Value::obj();
    top.set("experiment", "table7").set("rows", Value::Arr(arr));
    cfg.save(&format!("table7_{}", cfg.criterion_tag()), &top)?;
    Ok(result)
}

pub fn render(r: &Table7Result) -> String {
    let mut t = Table::new(
        "Table 7 — G-DaRE training time (seconds)",
        &["dataset", "n_train", "mean", "s.d."],
    );
    for row in &r.rows {
        t.row(vec![
            row.dataset.clone(),
            row.n_train.to_string(),
            format!("{:.2}", mean(&row.seconds)),
            format!("{:.2}", std_dev(&row.seconds)),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table7_times_two_datasets() {
        let cfg = ExpConfig {
            scale_div: 20_000,
            repeats: 2,
            datasets: vec!["ctr".into(), "higgs".into()],
            max_trees: 3,
            out_dir: std::env::temp_dir().join("dare_table7_test"),
            ..Default::default()
        };
        let r = run(&cfg).unwrap();
        assert_eq!(r.rows.len(), 2);
        assert!(r.rows.iter().all(|row| row.seconds.iter().all(|&s| s > 0.0)));
        assert!(render(&r).contains("higgs"));
        std::fs::remove_dir_all(&cfg.out_dir).ok();
    }
}
