//! Table 2 (Gini) / Table 9 (entropy): min / max / geometric-mean speedup
//! over all datasets, per model and adversary — aggregated from the Fig. 1
//! grid (reusing results/fig1_<criterion>.json when present).

use crate::exp::common::ExpConfig;
use crate::exp::fig1::{self, Fig1Result};
use crate::util::stats::{geo_mean, mean};
use crate::util::table::{speedup as fmt, Table};

#[derive(Clone, Debug)]
pub struct SummaryRow {
    pub adversary: String,
    pub model: String,
    pub min: f64,
    pub max: f64,
    pub gmean: f64,
}

pub fn summarize(r: &Fig1Result) -> Vec<SummaryRow> {
    let mut rows = Vec::new();
    let mut keys: Vec<(String, String)> = Vec::new();
    for c in &r.cells {
        let key = (c.adversary.clone(), c.model.clone());
        if !keys.contains(&key) {
            keys.push(key);
        }
    }
    for (adv, model) in keys {
        let per_dataset: Vec<f64> = r
            .cells
            .iter()
            .filter(|c| c.adversary == adv && c.model == model)
            .map(|c| mean(&c.speedups))
            .collect();
        if per_dataset.is_empty() {
            continue;
        }
        let (min, max) = crate::util::stats::min_max(&per_dataset);
        rows.push(SummaryRow {
            adversary: adv,
            model,
            min,
            max,
            gmean: geo_mean(&per_dataset),
        });
    }
    rows
}

pub fn run(cfg: &ExpConfig) -> anyhow::Result<Vec<SummaryRow>> {
    let name = format!("fig1_{}", cfg.criterion_tag());
    let fig1_result = match cfg.load(&name).and_then(|v| fig1::from_json(&v)) {
        Some(r) => {
            eprintln!("table2: reusing {}/{}.json", cfg.out_dir.display(), name);
            r
        }
        None => fig1::run(cfg)?,
    };
    let rows = summarize(&fig1_result);

    // save
    let mut arr = Vec::new();
    for r in &rows {
        let mut o = crate::util::json::Value::obj();
        o.set("adversary", r.adversary.as_str())
            .set("model", r.model.as_str())
            .set("min", r.min)
            .set("max", r.max)
            .set("gmean", r.gmean);
        arr.push(o);
    }
    let mut top = crate::util::json::Value::obj();
    top.set("experiment", "table2")
        .set("rows", crate::util::json::Value::Arr(arr));
    let out_name = match cfg.criterion_tag() {
        "entropy" => "table9",
        _ => "table2",
    };
    cfg.save(out_name, &top)?;
    Ok(rows)
}

pub fn render(rows: &[SummaryRow], criterion: &str) -> String {
    let title = if criterion == "entropy" {
        "Table 9 — deletion-efficiency summary (entropy)"
    } else {
        "Table 2 — deletion-efficiency summary (Gini)"
    };
    let mut out = String::new();
    for adv_prefix in ["random", "worst_of"] {
        let mut t = Table::new(
            &format!("{title} — {adv_prefix} adversary"),
            &["model", "min", "max", "g-mean"],
        );
        for r in rows.iter().filter(|r| r.adversary.starts_with(adv_prefix)) {
            t.row(vec![
                r.model.clone(),
                fmt(r.min),
                fmt(r.max),
                fmt(r.gmean),
            ]);
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exp::fig1::Cell;

    #[test]
    fn summarize_grid() {
        let r = Fig1Result {
            cells: vec![
                Cell {
                    dataset: "a".into(),
                    model: "G-DaRE".into(),
                    adversary: "random".into(),
                    speedups: vec![10.0, 20.0],
                    err_increase_pct: vec![],
                    n_deleted: vec![],
                },
                Cell {
                    dataset: "b".into(),
                    model: "G-DaRE".into(),
                    adversary: "random".into(),
                    speedups: vec![1000.0],
                    err_increase_pct: vec![],
                    n_deleted: vec![],
                },
            ],
        };
        let rows = summarize(&r);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].min, 15.0);
        assert_eq!(rows[0].max, 1000.0);
        assert!((rows[0].gmean - (15.0f64 * 1000.0).sqrt()).abs() < 1e-9);
        let text = render(&rows, "gini");
        assert!(text.contains("Table 2"));
        assert!(text.contains("G-DaRE"));
    }
}
