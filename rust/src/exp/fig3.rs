//! Figure 3 (+ Appendix Fig. 5): effect of k (thresholds per attribute) on
//! predictive performance and deletion efficiency, d_rmax fixed at 0.

use crate::eval::adversary::Adversary;
use crate::eval::speedup::{measure, SpeedupConfig};
use crate::exp::common::ExpConfig;
use crate::util::json::Value;
use crate::util::stats::{mean, std_dev, std_err};
use crate::util::table::Table;

#[derive(Clone, Debug)]
pub struct KPoint {
    pub k: usize,
    pub speedups: Vec<f64>,
    pub metric: Vec<f64>,
}

pub struct Fig3Result {
    pub dataset: String,
    pub points: Vec<KPoint>,
}

/// Sweep the paper's k grid {1, 5, 10, 25, 50, 100} (Appendix B.4).
pub fn run(cfg: &ExpConfig, dataset: &str, ks: &[usize]) -> anyhow::Result<Fig3Result> {
    let info = crate::data::registry::find(dataset)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset '{dataset}'"))?;
    let pp = cfg.paper_params(&info);
    let mut points = Vec::new();
    for &k in ks {
        let mut p = cfg.params(&pp, 0);
        p.k = k;
        let mut speedups = Vec::new();
        let mut metric = Vec::new();
        for rep in 0..cfg.repeats {
            let (train, test) = cfg.prepare(&info, rep as u64);
            let r = measure(
                &train,
                &test,
                &p,
                &SpeedupConfig {
                    adversary: Adversary::Random,
                    max_deletions: cfg.max_deletions,
                    metric: info.metric,
                    seed: crate::util::rng::mix_seed(&[cfg.seed, rep as u64, k as u64]),
                },
            );
            speedups.push(r.speedup);
            metric.push(r.metric_before);
        }
        eprintln!(
            "fig3 [{}] k={} -> {:.0}x, {}={:.4}",
            info.name,
            k,
            mean(&speedups),
            info.metric.name(),
            mean(&metric)
        );
        points.push(KPoint {
            k,
            speedups,
            metric,
        });
    }
    let r = Fig3Result {
        dataset: info.name.to_string(),
        points,
    };
    let mut arr = Vec::new();
    for p in &r.points {
        let mut o = Value::obj();
        o.set("k", p.k)
            .set("speedups", p.speedups.clone())
            .set("metric", p.metric.clone());
        arr.push(o);
    }
    let mut top = Value::obj();
    top.set("experiment", "fig3")
        .set("dataset", r.dataset.as_str())
        .set("points", Value::Arr(arr));
    cfg.save(&format!("fig3_{}_{}", info.name, cfg.criterion_tag()), &top)?;
    Ok(r)
}

pub fn render(r: &Fig3Result) -> String {
    let mut t = Table::new(
        &format!(
            "Figure 3 [{}] — k sweep (random adversary, d_rmax=0)",
            r.dataset
        ),
        &["k", "test metric (±se)", "speedup (±std)"],
    );
    for p in &r.points {
        t.row(vec![
            p.k.to_string(),
            format!("{:.4} ± {:.4}", mean(&p.metric), std_err(&p.metric)),
            format!("{:.0} ± {:.0}", mean(&p.speedups), std_dev(&p.speedups)),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_tiny_sweep() {
        let cfg = ExpConfig {
            scale_div: 20_000,
            repeats: 1,
            max_deletions: 6,
            max_trees: 2,
            out_dir: std::env::temp_dir().join("dare_fig3_test"),
            ..Default::default()
        };
        let r = run(&cfg, "twitter", &[1, 10]).unwrap();
        assert_eq!(r.points.len(), 2);
        assert_eq!(r.points[0].k, 1);
        let text = render(&r);
        assert!(text.contains("twitter"));
        std::fs::remove_dir_all(&cfg.out_dir).ok();
    }
}
