//! Scenario harness: scripted unlearning workloads replayed against the
//! full coordinator stack, with per-op latency histograms and oracle
//! cross-checks (DESIGN.md §14).
//!
//! A [`Scenario`] is `(kind, scale, seed)`. [`Scenario::compile`] expands it
//! into a [`CompiledScenario`]: a concrete, fully-resolved op stream
//! (every delete target, added row, probe batch and tenant route pinned)
//! plus one *differential oracle* per tenant — a plain eager [`DareForest`]
//! that the compiler drove through the identical logical ops. Compilation
//! is a pure function of the spec: no clocks, no ambient randomness, only
//! the seeded [`Rng`] stream — so the op stream is byte-stable across
//! processes and machines (the determinism contract, DESIGN.md §14).
//!
//! [`replay`] then drives the op stream through the real serving path —
//! [`UnlearningService::handle`] over the versioned wire codec, through the
//! registry, deletion batcher, sharded store, the ambient
//! `DARE_LAZY_POLICY`, and Occ(q) ownership — timing every request into
//! per-tenant, per-op-type [`Histogram`]s. [`cross_check`] closes the loop:
//!
//! 1. **Differential oracle** (every scenario): each tenant's final flushed
//!    snapshot must serialize byte-identical to its compile-time oracle,
//!    and a fixed probe batch must predict f32-identical.
//! 2. **Scratch-retrain oracle** ([`Check::ScratchRetrain`], attached where
//!    the paper's exactness theorem applies — delete-only histories and
//!    fully-purged add histories, compiled in the exhaustive regime): every
//!    final tree must equal a from-scratch train on its owned surviving
//!    ids.
//! 3. **Telemetry coherence** (every scenario): per-op counts, error
//!    counts, histogram counts and mutation counters reported by the
//!    service must reconcile exactly with the ops the driver issued.
//!
//! The four canonical scenarios ship as [`Scenario::canonical`]:
//! worst-case adversarial churn (paper §5, reusing
//! [`Adversary::WorstOf`]), poison-then-purge (flipped-label injection,
//! batched purge, bit-exact accuracy recovery), sliding-window continual
//! learning under distribution drift, and a zipf-routed multi-tenant mix
//! with one Occ(q)-subsampled tenant. `benches/scenarios.rs` replays them
//! at `DARE_SCENARIO_SCALE` and emits `BENCH_scenarios.json`. A fifth
//! kind, [`ScenarioKind::Burst`] (synchronized multi-tenant arrival
//! spikes), pairs with [`replay_scheduled`] to drive the identical op
//! stream through the DESIGN.md §15 time-budgeted scheduler — the
//! scheduled-vs-direct snapshot comparison is how the scheduler's
//! byte-exactness claim is enforced end to end.

use crate::coordinator::api::{encode_request, Op, Request, WIRE_VERSION};
use crate::coordinator::scheduler::{RunReport, Scheduler, SchedulerConfig, Submitted};
use crate::coordinator::{ServiceConfig, UnlearningService};
use crate::data::dataset::InstanceId;
use crate::data::split::train_test;
use crate::data::synth::{generate, SynthSpec};
use crate::eval::adversary::Adversary;
use crate::forest::serialize::forest_to_json;
use crate::forest::train::{train, TrainCtx, ROOT_PATH};
use crate::forest::{owned_live_ids, DareForest, LazyPolicy, MaxFeatures, Params};
use crate::metrics::accuracy;
use crate::util::histogram::Histogram;
use crate::util::json::Value;
use crate::util::rng::{mix_seed, Rng};
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Workload scale knob: corpus sizes and op counts derive from this.
/// CI's scenarios job pins `DARE_SCENARIO_SCALE=2000`; the default keeps
/// local test runs fast. Clamped below at 64 so every script stays
/// well-formed.
pub fn scenario_scale() -> usize {
    std::env::var("DARE_SCENARIO_SCALE")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .unwrap_or(400)
        .max(64)
}

// ---------------------------------------------------------------------------
// Spec
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScenarioKind {
    /// Worst-case deletion churn: `worst_of_<c>` adversarial targets
    /// (paper §5) against a single tenant, delete-only, exhaustive regime.
    AdversarialChurn,
    /// Flipped-label injection followed by a batched purge of exactly the
    /// injected ids; accuracy on a held-out split must recover bit-exactly.
    PoisonPurge,
    /// Sliding-window continual learning: add a drifting batch, retire the
    /// oldest, keep the window size fixed.
    SlidingWindow,
    /// Zipf-routed traffic across four tenants (one Occ(q)-subsampled),
    /// predict-heavy with interleaved mutations.
    MultiTenantZipf,
    /// Randomized spec for the op-fuzz replay leg: 1–2 small tenants, a
    /// random mix over the whole op vocabulary.
    Fuzz,
    /// Synchronized multi-tenant arrival spikes: every round, all tenants
    /// burst interleaved predict-heavy traffic at once (the workload the
    /// DESIGN.md §15 scheduler packs into budget cycles); quiet tails of
    /// cost reads and compaction separate the rounds.
    Burst,
}

/// A scenario spec — the unit the harness compiles and replays.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Scenario {
    pub kind: ScenarioKind,
    pub scale: usize,
    pub seed: u64,
}

impl Scenario {
    pub fn name(&self) -> &'static str {
        match self.kind {
            ScenarioKind::AdversarialChurn => "adversarial_churn",
            ScenarioKind::PoisonPurge => "poison_purge",
            ScenarioKind::SlidingWindow => "sliding_window",
            ScenarioKind::MultiTenantZipf => "multi_tenant_zipf",
            ScenarioKind::Fuzz => "fuzz",
            ScenarioKind::Burst => "burst",
        }
    }

    /// The four canonical scenarios at `scale`, with their pinned seeds.
    pub fn canonical(scale: usize) -> Vec<Scenario> {
        [
            ScenarioKind::AdversarialChurn,
            ScenarioKind::PoisonPurge,
            ScenarioKind::SlidingWindow,
            ScenarioKind::MultiTenantZipf,
        ]
        .iter()
        .enumerate()
        .map(|(i, &kind)| Scenario {
            kind,
            scale,
            seed: 0xD0_5CE0 + i as u64,
        })
        .collect()
    }

    /// Expand the spec into a concrete op stream + per-tenant oracles.
    pub fn compile(&self) -> CompiledScenario {
        let mut c = Compiler::new(mix_seed(&[self.seed, 0x5CEA]));
        match self.kind {
            ScenarioKind::AdversarialChurn => compile_adversarial_churn(&mut c, self.scale),
            ScenarioKind::PoisonPurge => compile_poison_purge(&mut c, self.scale, self.seed),
            ScenarioKind::SlidingWindow => compile_sliding_window(&mut c, self.scale, self.seed),
            ScenarioKind::MultiTenantZipf => compile_multi_tenant_zipf(&mut c, self.scale),
            ScenarioKind::Fuzz => compile_fuzz(&mut c, self.scale),
            ScenarioKind::Burst => compile_burst(&mut c, self.scale),
        }
        c.finish(self.name(), self.seed)
    }
}

// ---------------------------------------------------------------------------
// Compiled form
// ---------------------------------------------------------------------------

/// One fully-resolved op against one tenant. Pure data — `PartialEq` is
/// what the determinism tests compare.
#[derive(Clone, Debug, PartialEq)]
pub enum ScenarioOp {
    Predict { tenant: usize, rows: Vec<Vec<f32>> },
    Delete { tenant: usize, ids: Vec<InstanceId> },
    Add { tenant: usize, row: Vec<f32>, label: u8 },
    DeleteCost { tenant: usize, id: InstanceId },
    Flush { tenant: usize },
    Compact { tenant: usize, budget: usize },
    Stats { tenant: usize },
}

impl ScenarioOp {
    pub fn tenant(&self) -> usize {
        match *self {
            ScenarioOp::Predict { tenant, .. }
            | ScenarioOp::Delete { tenant, .. }
            | ScenarioOp::Add { tenant, .. }
            | ScenarioOp::DeleteCost { tenant, .. }
            | ScenarioOp::Flush { tenant }
            | ScenarioOp::Compact { tenant, .. }
            | ScenarioOp::Stats { tenant } => tenant,
        }
    }

    /// Histogram key; also the wire op name for the four timed data-plane
    /// ops, so telemetry coherence can compare counts key-for-key.
    pub fn op_type(&self) -> &'static str {
        match self {
            ScenarioOp::Predict { .. } => "predict",
            ScenarioOp::Delete { .. } => "delete",
            ScenarioOp::Add { .. } => "add",
            ScenarioOp::DeleteCost { .. } => "delete_cost",
            ScenarioOp::Flush { .. } => "flush",
            ScenarioOp::Compact { .. } => "compact",
            ScenarioOp::Stats { .. } => "stats",
        }
    }

    fn to_wire(&self) -> Op {
        match self {
            ScenarioOp::Predict { rows, .. } => Op::Predict { rows: rows.clone() },
            ScenarioOp::Delete { ids, .. } => Op::Delete { ids: ids.clone() },
            ScenarioOp::Add { row, label, .. } => Op::Add {
                row: row.clone(),
                label: *label,
            },
            ScenarioOp::DeleteCost { id, .. } => Op::DeleteCost { id: *id },
            ScenarioOp::Flush { .. } => Op::Flush,
            ScenarioOp::Compact { budget, .. } => Op::Compact { budget: *budget },
            ScenarioOp::Stats { .. } => Op::Stats,
        }
    }
}

/// One tenant: its pre-script trained forest (what the service boots
/// from), its post-script differential oracle, and a fixed probe batch.
pub struct Tenant {
    pub name: String,
    pub initial: DareForest,
    pub oracle: DareForest,
    pub probes: Vec<Vec<f32>>,
}

/// Scenario-specific assertions attached at compile time and executed by
/// [`cross_check`] (the oracle cross-check rule, DESIGN.md §14).
pub enum Check {
    /// Every final tree must equal a from-scratch train on its owned
    /// surviving ids. Sound only for exhaustive-regime scripts whose
    /// history is delete-only or whose every added id was purged — the §6
    /// add path is oracle-exact, not scratch-exact (see op_fuzz leg 2).
    ScratchRetrain { tenant: usize },
    /// Held-out accuracy after the purge must equal the pre-poison
    /// baseline bit-for-bit (purging every injected id in the exhaustive
    /// regime restores the forest structurally, so this is exact, not
    /// approximate). `poisoned_acc` is carried for reporting.
    AccuracyRecovery {
        tenant: usize,
        test_rows: Vec<Vec<f32>>,
        test_labels: Vec<u8>,
        baseline_acc: f64,
        poisoned_acc: f64,
    },
}

pub struct CompiledScenario {
    pub name: String,
    pub seed: u64,
    pub tenants: Vec<Tenant>,
    pub ops: Vec<ScenarioOp>,
    pub checks: Vec<Check>,
}

// ---------------------------------------------------------------------------
// Compiler
// ---------------------------------------------------------------------------

/// Compile-time state: the seeded stream plus every tenant's evolving
/// eager oracle. Builders append ops AND apply their logical effect to the
/// oracle in the same breath, so the two cannot drift.
struct Compiler {
    rng: Rng,
    tenants: Vec<Tenant>,
    ops: Vec<ScenarioOp>,
    checks: Vec<Check>,
}

impl Compiler {
    fn new(seed: u64) -> Compiler {
        Compiler {
            rng: Rng::new(seed),
            tenants: Vec::new(),
            ops: Vec::new(),
            checks: Vec::new(),
        }
    }

    /// Train a tenant and register it. The oracle is pinned to the eager
    /// policy regardless of the ambient `DARE_LAZY_POLICY`: flush-order
    /// invariance (DESIGN.md §9) makes the service's flushed snapshot
    /// byte-identical to the eager evolution under every policy, which is
    /// exactly what makes one compile-time oracle serve the whole matrix.
    fn tenant(
        &mut self,
        name: &str,
        data: crate::data::dataset::Dataset,
        params: &Params,
        forest_seed: u64,
    ) -> usize {
        let mut oracle = DareForest::fit(data, params, forest_seed);
        oracle.set_lazy_policy(LazyPolicy::Eager);
        let p = oracle.data().n_features();
        let mut probes: Vec<Vec<f32>> = (0..6)
            .map(|_| (0..p).map(|_| self.rng.range_f32(-4.0, 4.0)).collect())
            .collect();
        // A couple of real corpus rows so probes hit populated leaves.
        for id in oracle.live_ids().iter().take(2) {
            probes.push(oracle.data().row(*id));
        }
        self.tenants.push(Tenant {
            name: name.to_string(),
            initial: oracle.clone(),
            oracle,
            probes,
        });
        self.tenants.len() - 1
    }

    fn predict(&mut self, tenant: usize, rows: Vec<Vec<f32>>) {
        self.ops.push(ScenarioOp::Predict { tenant, rows });
    }

    fn predict_probe(&mut self, tenant: usize) {
        let rows = self.tenants[tenant].probes.clone();
        self.predict(tenant, rows);
    }

    fn delete(&mut self, tenant: usize, ids: Vec<InstanceId>) {
        self.tenants[tenant].oracle.delete_batch(&ids);
        self.ops.push(ScenarioOp::Delete { tenant, ids });
    }

    fn add(&mut self, tenant: usize, row: Vec<f32>, label: u8) -> InstanceId {
        let id = self.tenants[tenant].oracle.add(&row, label);
        self.ops.push(ScenarioOp::Add { tenant, row, label });
        id
    }

    fn delete_cost(&mut self, tenant: usize, id: InstanceId) {
        self.ops.push(ScenarioOp::DeleteCost { tenant, id });
    }

    fn flush(&mut self, tenant: usize) {
        self.ops.push(ScenarioOp::Flush { tenant });
    }

    fn compact(&mut self, tenant: usize, budget: usize) {
        self.ops.push(ScenarioOp::Compact { tenant, budget });
    }

    fn stats(&mut self, tenant: usize) {
        self.ops.push(ScenarioOp::Stats { tenant });
    }

    /// Every script ends with a flush + stats per tenant: the final state
    /// the cross-check sees is the fully-drained one, and the last stats
    /// op exercises the histogram export surface.
    fn finish(mut self, name: &str, seed: u64) -> CompiledScenario {
        for t in 0..self.tenants.len() {
            self.flush(t);
            self.stats(t);
        }
        CompiledScenario {
            name: name.to_string(),
            seed,
            tenants: self.tenants,
            ops: self.ops,
            checks: self.checks,
        }
    }
}

/// Exhaustive-regime params (k ≥ all candidates, all attributes, no random
/// layer): the regime where the paper's deletion theorem is a structural
/// identity, making the scratch-retrain oracle applicable.
fn exhaustive_params(n_trees: usize) -> Params {
    Params {
        n_trees,
        max_depth: 6,
        k: 10_000,
        d_rmax: 0,
        max_features: MaxFeatures::All,
        ..Default::default()
    }
}

/// Compact synthetic spec (p = 10) so CI-scale corpora stay cheap.
fn spec(n: usize) -> SynthSpec {
    SynthSpec {
        n,
        informative: 4,
        redundant: 2,
        noise: 4,
        flip: 0.05,
        ..Default::default()
    }
}

fn random_row(rng: &mut Rng, p: usize) -> Vec<f32> {
    (0..p).map(|_| rng.range_f32(-4.0, 4.0)).collect()
}

/// Zipf-distributed index in `0..n` with exponent `s` (rank 0 hottest).
fn zipf(rng: &mut Rng, n: usize, s: f64) -> usize {
    let total: f64 = (1..=n).map(|k| (k as f64).powf(-s)).sum();
    let mut u = rng.f64() * total;
    for k in 0..n {
        let w = ((k + 1) as f64).powf(-s);
        if u < w {
            return k;
        }
        u -= w;
    }
    n - 1
}

// ---------------------------------------------------------------------------
// Canonical scenario builders
// ---------------------------------------------------------------------------

/// Paper §5 worst-case churn: delete 10% of the corpus in worst-of-16
/// order, re-ranked against the evolving forest, with probe predicts and
/// cost/stats reads interleaved. Delete-only + exhaustive regime ⇒ the
/// scratch-retrain oracle applies after every deletion, so it is attached.
fn compile_adversarial_churn(c: &mut Compiler, scale: usize) {
    let n = scale;
    let fseed = c.rng.next_u64();
    let data = generate(&spec(n), c.rng.next_u64());
    let t = c.tenant("churn", data, &exhaustive_params(4), fseed);
    let adversary = Adversary::WorstOf(16);
    let deletions = (n / 10).max(16);
    for step in 0..deletions {
        let id = {
            let Compiler { rng, tenants, .. } = c;
            adversary.next_target(&tenants[t].oracle, rng)
        };
        let Some(id) = id else { break };
        c.delete(t, vec![id]);
        if step % 8 == 4 {
            c.predict_probe(t);
        }
        if step % 25 == 12 {
            if let Some(&probe) = c.tenants[t].oracle.live_ids().first() {
                c.delete_cost(t, probe);
            }
            c.stats(t);
        }
    }
    c.checks.push(Check::ScratchRetrain { tenant: t });
}

/// Random-Relabeling-style poisoning response: train clean, measure
/// held-out accuracy, inject 20% flipped-label rows, purge exactly those
/// ids in batched deletes, and require the held-out accuracy to land back
/// on the baseline bit-for-bit. Exhaustive regime: purging every injected
/// id restores the forest structurally (adds are self-inverse under their
/// own deletion — DESIGN.md §14), so both the scratch-retrain and the
/// exact-recovery checks attach.
fn compile_poison_purge(c: &mut Compiler, scale: usize, seed: u64) {
    let n = scale;
    let full = generate(&spec(n + n / 4), mix_seed(&[seed, 0xF00D]));
    let (train_d, test_d) = train_test(&full, 0.8, mix_seed(&[seed, 0x5917]));
    let test_rows: Vec<Vec<f32>> =
        (0..test_d.n_total() as InstanceId).map(|i| test_d.row(i)).collect();
    let test_labels: Vec<u8> = test_d.labels().to_vec();
    let fseed = c.rng.next_u64();
    let t = c.tenant("poison", train_d, &exhaustive_params(4), fseed);
    let baseline_acc = accuracy(
        &c.tenants[t].oracle.predict_proba_rows(&test_rows),
        &test_labels,
    );

    // Inject: plausible rows with deliberately flipped labels.
    let n_poison = (n / 5).max(8);
    let poison_src = generate(&spec(n_poison), mix_seed(&[seed, 0xBAD]));
    let mut poison_ids = Vec::with_capacity(n_poison);
    for i in 0..poison_src.n_total() as InstanceId {
        let row = poison_src.row(i);
        let flipped = 1 - poison_src.y(i);
        poison_ids.push(c.add(t, row, flipped));
        if i % 16 == 7 {
            c.predict_probe(t);
        }
    }
    c.stats(t);
    let poisoned_acc = accuracy(
        &c.tenants[t].oracle.predict_proba_rows(&test_rows),
        &test_labels,
    );

    // Purge: batched wire deletes over exactly the injected ids.
    for chunk in poison_ids.chunks(16) {
        c.delete(t, chunk.to_vec());
    }
    c.predict_probe(t);
    c.checks.push(Check::ScratchRetrain { tenant: t });
    c.checks.push(Check::AccuracyRecovery {
        tenant: t,
        test_rows,
        test_labels,
        baseline_acc,
        poisoned_acc,
    });
}

/// Continual learning under drift: a fixed-size window slides over a
/// stream whose class separation and positive rate drift per step — each
/// step adds a fresh batch row-by-row, retires the oldest batch in one
/// wire delete, and reads predictions/costs. Adds make scratch-retrain
/// inapplicable; the differential oracle + telemetry coherence carry the
/// correctness load here.
fn compile_sliding_window(c: &mut Compiler, scale: usize, seed: u64) {
    let window = (scale / 2).max(48);
    let fseed = c.rng.next_u64();
    let data = generate(&spec(window), mix_seed(&[seed, 0x71DE]));
    let params = Params {
        n_trees: 6,
        max_depth: 6,
        k: 8,
        d_rmax: 1,
        ..Default::default()
    };
    let t = c.tenant("window", data, &params, fseed);
    let mut fifo: Vec<InstanceId> = c.tenants[t].oracle.live_ids();
    let steps = 6;
    let batch = (window / 8).max(4);
    for step in 0..steps {
        // Drifting source: separation tightens, positives thin out.
        let drift = SynthSpec {
            class_sep: 1.0 + 0.15 * step as f64,
            pos_fraction: (0.5 - 0.04 * step as f64).max(0.2),
            ..spec(batch)
        };
        let fresh = generate(&drift, mix_seed(&[seed, 0xD21F, step as u64]));
        for i in 0..fresh.n_total() as InstanceId {
            let id = c.add(t, fresh.row(i), fresh.y(i));
            fifo.push(id);
        }
        let old: Vec<InstanceId> = fifo.drain(..batch.min(fifo.len())).collect();
        c.delete(t, old);
        c.predict_probe(t);
        if step % 2 == 1 {
            if let Some(&oldest) = fifo.first() {
                c.delete_cost(t, oldest);
            }
            c.compact(t, 4);
            c.stats(t);
        }
    }
}

/// Zipf-routed multi-tenant mix: four tenants of descending size — one
/// Occ(q)-subsampled (DESIGN.md §13) — served by one registry, with
/// traffic routed by a zipf(1.2) draw per op and a predict-heavy mix.
fn compile_multi_tenant_zipf(c: &mut Compiler, scale: usize) {
    let sizes = [scale / 2, scale / 3, scale / 4, scale / 6];
    let mut tenants = Vec::new();
    for (i, &n) in sizes.iter().enumerate() {
        let mut params = Params {
            n_trees: 3 + i % 2,
            max_depth: 5,
            k: 4 + i,
            d_rmax: 1,
            ..Default::default()
        };
        if i == 2 {
            params = params.with_subsample(0.35);
        }
        let fseed = c.rng.next_u64();
        let data = generate(&spec(n.max(48)), c.rng.next_u64());
        tenants.push(c.tenant(&format!("t{i}"), data, &params, fseed));
    }
    let ops = (scale / 2).max(64);
    for k in 0..ops {
        let t = tenants[zipf(&mut c.rng, tenants.len(), 1.2)];
        let p = c.tenants[t].oracle.data().n_features();
        match c.rng.index(10) {
            0..=4 => {
                let rows: Vec<Vec<f32>> =
                    (0..1 + c.rng.index(6)).map(|_| random_row(&mut c.rng, p)).collect();
                c.predict(t, rows);
            }
            5..=6 => {
                let live = c.tenants[t].oracle.live_ids();
                if live.len() > 24 {
                    let m = 1 + c.rng.index(3);
                    let ids: Vec<InstanceId> = (0..m)
                        .map(|_| live[c.rng.index(live.len())])
                        .collect();
                    c.delete(t, ids);
                }
            }
            7 => {
                let row = random_row(&mut c.rng, p);
                let label = (c.rng.index(2)) as u8;
                c.add(t, row, label);
            }
            8 => {
                let live = c.tenants[t].oracle.live_ids();
                if !live.is_empty() {
                    let id = live[c.rng.index(live.len())];
                    c.delete_cost(t, id);
                }
            }
            _ => c.stats(t),
        }
        if k % 40 == 21 {
            c.flush(t);
        }
    }
}

/// Randomized spec for the op-fuzz replay leg: everything small, every op
/// kind reachable, targets resolved against the oracle so dead-id deletes
/// (skip-path) occur but cost reads stay live.
fn compile_fuzz(c: &mut Compiler, scale: usize) {
    let n_tenants = 1 + c.rng.index(2);
    let mut tenants = Vec::new();
    for i in 0..n_tenants {
        let n = 48 + c.rng.index(scale.min(120));
        let max_depth = 4 + c.rng.index(2);
        let mut params = Params {
            n_trees: 2 + c.rng.index(2),
            max_depth,
            k: 2 + c.rng.index(5),
            d_rmax: c.rng.index(2).min(max_depth),
            ..Default::default()
        };
        if c.rng.bernoulli(0.3) {
            params = params.with_subsample(0.3 + 0.4 * c.rng.f64());
        }
        let fseed = c.rng.next_u64();
        let data = generate(&spec(n), c.rng.next_u64());
        tenants.push(c.tenant(&format!("fuzz{i}"), data, &params, fseed));
    }
    let adversary = if c.rng.bernoulli(0.5) {
        Adversary::WorstOf(8)
    } else {
        Adversary::Random
    };
    for _ in 0..30 + c.rng.index(20) {
        let t = tenants[c.rng.index(tenants.len())];
        let p = c.tenants[t].oracle.data().n_features();
        match c.rng.index(12) {
            0..=2 if c.tenants[t].oracle.n_alive() > 16 => {
                let id = {
                    let Compiler { rng, tenants, .. } = c;
                    adversary.next_target(&tenants[t].oracle, rng)
                };
                if let Some(id) = id {
                    c.delete(t, vec![id]);
                }
            }
            3 => {
                // Dead/out-of-band ids exercise the accept/skip path.
                let id = c.rng.next_below(1 << 20) as InstanceId;
                c.delete(t, vec![id]);
            }
            4..=5 => {
                let row = random_row(&mut c.rng, p);
                let label = c.rng.index(2) as u8;
                c.add(t, row, label);
            }
            6..=8 => {
                let rows: Vec<Vec<f32>> =
                    (0..1 + c.rng.index(5)).map(|_| random_row(&mut c.rng, p)).collect();
                c.predict(t, rows);
            }
            9 => {
                let live = c.tenants[t].oracle.live_ids();
                if !live.is_empty() {
                    let id = live[c.rng.index(live.len())];
                    c.delete_cost(t, id);
                }
            }
            10 => {
                if c.rng.bernoulli(0.5) {
                    c.flush(t);
                } else {
                    c.compact(t, 1 + c.rng.index(4));
                }
            }
            _ => c.stats(t),
        }
    }
    // Every fuzz script ends with a probe predict per tenant: guarantees
    // the differential probe check has a final data point (and that the
    // report always carries a `predict` histogram entry, which the
    // BENCH_scenarios.json schema pin relies on).
    for &t in &tenants {
        c.predict_probe(t);
    }
}

/// Synchronized multi-tenant arrival spikes. Three tenants; each round,
/// every tenant's burst of predict-heavy traffic (with scattered deletes
/// and adds) arrives interleaved — the adversarial shape for a
/// time-budgeted scheduler, since no tenant's queue is ever empty during
/// a spike and naive FIFO service would let one tenant starve the rest.
/// Quiet tails of cost reads separate the rounds, and every other round
/// ends with a wire compact per tenant (a foreground Compact-class
/// ticket when replayed through the scheduler).
fn compile_burst(c: &mut Compiler, scale: usize) {
    let n = (scale / 3).max(48);
    let mut tenants = Vec::new();
    for i in 0..3 {
        let params = Params {
            n_trees: 3,
            max_depth: 5,
            k: 4 + i,
            d_rmax: 1,
            ..Default::default()
        };
        let fseed = c.rng.next_u64();
        let data = generate(&spec(n), c.rng.next_u64());
        tenants.push(c.tenant(&format!("burst{i}"), data, &params, fseed));
    }
    let rounds = 5;
    let spike = (scale / 8).max(18);
    for round in 0..rounds {
        // The spike: requests from all tenants arrive interleaved, as a
        // synchronized burst would at a shared front door.
        for j in 0..spike {
            let t = tenants[j % tenants.len()];
            let p = c.tenants[t].oracle.data().n_features();
            match c.rng.index(8) {
                0 => {
                    let live = c.tenants[t].oracle.live_ids();
                    if live.len() > 24 {
                        let id = live[c.rng.index(live.len())];
                        c.delete(t, vec![id]);
                    }
                }
                1 => {
                    let row = random_row(&mut c.rng, p);
                    let label = c.rng.index(2) as u8;
                    c.add(t, row, label);
                }
                _ => {
                    let rows: Vec<Vec<f32>> = (0..1 + c.rng.index(4))
                        .map(|_| random_row(&mut c.rng, p))
                        .collect();
                    c.predict(t, rows);
                }
            }
        }
        // Quiet tail: one cost read per tenant, compaction every other
        // round so deferred retrain backlogs never pile across rounds.
        for &t in &tenants {
            let live = c.tenants[t].oracle.live_ids();
            if !live.is_empty() {
                c.delete_cost(t, live[c.rng.index(live.len())]);
            }
        }
        if round % 2 == 1 {
            for &t in &tenants {
                c.compact(t, 4);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Replay
// ---------------------------------------------------------------------------

/// Service configuration for scenario replay: native predict only, a short
/// batch window (single-threaded replay ⇒ one request per batch), and the
/// background compactor parked so the only state transitions are the
/// scripted ops (byte-determinism across replays). The lazy policy comes
/// from the ambient `DARE_LAZY_POLICY`, which is how the CI matrix runs
/// the same scripts through both deferral modes.
pub fn replay_config() -> ServiceConfig {
    ServiceConfig {
        batch_window: Duration::from_millis(1),
        use_pjrt: false,
        n_shards: 2,
        lazy: LazyPolicy::from_env(),
        compact_interval: Duration::from_secs(3600),
        ..Default::default()
    }
}

/// Everything a replay produced: the live service (for cross-checking),
/// per-op-type latency histograms (merged across tenants, plus the
/// per-tenant split), and the issued-op ledger telemetry is reconciled
/// against.
pub struct Replayed {
    pub svc: Arc<UnlearningService>,
    /// Per-op-type latency, merged across tenants via `Histogram::merge`.
    pub per_op: BTreeMap<String, Histogram>,
    /// (tenant index, op type) → latency histogram.
    pub per_tenant_op: BTreeMap<(usize, String), Histogram>,
    /// (tenant index, op type) → ops issued.
    pub issued: BTreeMap<(usize, String), u64>,
    /// Per tenant: total rows sent through predict ops.
    pub predict_rows: Vec<u64>,
    /// Per tenant: total ids the service reported deleted.
    pub deleted_ids: Vec<u64>,
    /// Wall-clock seconds for the whole op stream.
    pub wall_s: f64,
}

impl Replayed {
    /// Op counts derived from the merged histograms — the latency-free
    /// projection the determinism tests compare.
    pub fn op_counts(&self) -> BTreeMap<String, u64> {
        self.per_op.iter().map(|(k, h)| (k.clone(), h.count())).collect()
    }

    /// Final flushed snapshot bytes per tenant (compile order).
    pub fn final_snapshots(&self, c: &CompiledScenario) -> Vec<String> {
        c.tenants
            .iter()
            .map(|t| {
                let model = self.svc.registry().get(&t.name).expect("tenant registered");
                forest_to_json(&model.sharded().snapshot())
            })
            .collect()
    }
}

/// Drive the compiled op stream through `UnlearningService::handle`,
/// timing every wire round-trip. Panics on any non-`ok` response — a
/// scenario script is valid by construction, so an error is a harness or
/// service bug, never data.
pub fn replay(c: &CompiledScenario) -> Replayed {
    let svc = UnlearningService::with_models(
        c.tenants.iter().map(|t| (t.name.clone(), t.initial.clone())).collect(),
        replay_config(),
    );
    let mut per_tenant_op: BTreeMap<(usize, String), Histogram> = BTreeMap::new();
    let mut issued: BTreeMap<(usize, String), u64> = BTreeMap::new();
    let mut predict_rows = vec![0u64; c.tenants.len()];
    let mut deleted_ids = vec![0u64; c.tenants.len()];
    let t_start = Instant::now();
    for op in &c.ops {
        let tenant = op.tenant();
        let wire = encode_request(&Request {
            v: WIRE_VERSION,
            model: c.tenants[tenant].name.clone(),
            op: op.to_wire(),
        });
        let t0 = Instant::now();
        let resp = svc.handle(&wire);
        let dt = t0.elapsed().as_secs_f64();
        assert_eq!(
            resp.get("ok").and_then(|v| v.as_bool()),
            Some(true),
            "scenario '{}': op {:?} failed: {}",
            c.name,
            op,
            resp.to_string()
        );
        let key = (tenant, op.op_type().to_string());
        per_tenant_op.entry(key.clone()).or_insert_with(Histogram::new).record(dt);
        *issued.entry(key).or_insert(0) += 1;
        match op {
            ScenarioOp::Predict { rows, .. } => predict_rows[tenant] += rows.len() as u64,
            ScenarioOp::Delete { .. } => {
                deleted_ids[tenant] +=
                    resp.get("deleted").and_then(|v| v.as_u64()).unwrap_or(0)
            }
            _ => {}
        }
    }
    let wall_s = t_start.elapsed().as_secs_f64();
    let mut per_op: BTreeMap<String, Histogram> = BTreeMap::new();
    for ((_, op), h) in &per_tenant_op {
        per_op.entry(op.clone()).or_insert_with(Histogram::new).merge(h);
    }
    Replayed {
        svc,
        per_op,
        per_tenant_op,
        issued,
        predict_rows,
        deleted_ids,
        wall_s,
    }
}

/// A scheduled replay: the same [`Replayed`] surface (so [`cross_check`]
/// and snapshot comparisons apply unchanged), plus the scheduler-side
/// evidence — one [`RunReport`] per `run_for` cycle and the
/// submit→response sojourn histogram over the queued ops.
pub struct ScheduledReplay {
    pub replayed: Replayed,
    /// One report per `run_for(budget)` cycle, in execution order.
    pub cycles: Vec<RunReport>,
    /// Submit→response latency for queued ops (queue wait + execution).
    pub sojourn: Histogram,
}

/// Drive the compiled op stream through a [`Scheduler`] attached to the
/// replay service: ops are `submit`ted in stream order (per-tenant FIFO by
/// construction), queued work is drained with `run_for(budget)` cycles
/// whenever the backlog crosses a spike-sized bound, and every reply is
/// collected and held to the same `ok` bar as [`replay`]. Because the
/// scheduler executes through `UnlearningService::handle`, the telemetry
/// ledger fills exactly as in a direct replay and [`cross_check`] applies
/// verbatim — the ISSUE's byte-exactness claim is checked by comparing
/// `final_snapshots` of the two replays.
///
/// Admission control is disabled (`queue_depth: 0` semantics via a depth
/// larger than the stream): a synchronous driver that panics on refusal
/// would make spike sizing a correctness knob, which it is not.
pub fn replay_scheduled(c: &CompiledScenario, budget: Duration) -> ScheduledReplay {
    let svc = UnlearningService::with_models(
        c.tenants.iter().map(|t| (t.name.clone(), t.initial.clone())).collect(),
        replay_config(),
    );
    let cfg = SchedulerConfig {
        budget,
        queue_depth: c.ops.len() + 1,
        ..SchedulerConfig::default()
    };
    let sched = Scheduler::attach(&svc, cfg);
    let mut per_tenant_op: BTreeMap<(usize, String), Histogram> = BTreeMap::new();
    let mut issued: BTreeMap<(usize, String), u64> = BTreeMap::new();
    let mut predict_rows = vec![0u64; c.tenants.len()];
    let mut deleted_ids = vec![0u64; c.tenants.len()];
    let mut cycles: Vec<RunReport> = Vec::new();
    let mut sojourn = Histogram::new();
    // Queued replies: (op index, submit instant, receiver).
    let mut pending: Vec<(usize, Instant, std::sync::mpsc::Receiver<Value>)> = Vec::new();
    let mut responses: Vec<Option<Value>> = (0..c.ops.len()).map(|_| None).collect();
    let t_start = Instant::now();
    for (k, op) in c.ops.iter().enumerate() {
        let tenant = op.tenant();
        let wire = encode_request(&Request {
            v: WIRE_VERSION,
            model: c.tenants[tenant].name.clone(),
            op: op.to_wire(),
        });
        let t0 = Instant::now();
        match sched.submit(&wire).expect("replay queue depth exceeds the stream") {
            Submitted::Immediate(v) => {
                let dt = t0.elapsed().as_secs_f64();
                let key = (tenant, op.op_type().to_string());
                per_tenant_op.entry(key).or_insert_with(Histogram::new).record(dt);
                responses[k] = Some(v);
            }
            Submitted::Queued(rx) => pending.push((k, t0, rx)),
        }
        *issued.entry((tenant, op.op_type().to_string())).or_insert(0) += 1;
        // Drain in budget-sized cycles once a spike's worth has queued —
        // the queue stays deep enough that EDF/DRR choices are real.
        while sched.queued_total() >= 64 {
            cycles.push(sched.run_for(budget));
        }
    }
    while sched.queued_total() > 0 {
        cycles.push(sched.run_for(budget));
    }
    for (k, t0, rx) in pending {
        let v = rx.recv().expect("scheduler dropped a reply");
        let dt = t0.elapsed().as_secs_f64();
        sojourn.record(dt);
        let tenant = c.ops[k].tenant();
        let key = (tenant, c.ops[k].op_type().to_string());
        per_tenant_op.entry(key).or_insert_with(Histogram::new).record(dt);
        responses[k] = Some(v);
    }
    let wall_s = t_start.elapsed().as_secs_f64();
    for (k, op) in c.ops.iter().enumerate() {
        let tenant = op.tenant();
        let resp = responses[k].as_ref().expect("every op produced a response");
        assert_eq!(
            resp.get("ok").and_then(|v| v.as_bool()),
            Some(true),
            "scenario '{}' (scheduled): op {:?} failed: {}",
            c.name,
            op,
            resp.to_string()
        );
        match op {
            ScenarioOp::Predict { rows, .. } => predict_rows[tenant] += rows.len() as u64,
            ScenarioOp::Delete { .. } => {
                deleted_ids[tenant] +=
                    resp.get("deleted").and_then(|v| v.as_u64()).unwrap_or(0)
            }
            _ => {}
        }
    }
    let mut per_op: BTreeMap<String, Histogram> = BTreeMap::new();
    for ((_, op), h) in &per_tenant_op {
        per_op.entry(op.clone()).or_insert_with(Histogram::new).merge(h);
    }
    ScheduledReplay {
        replayed: Replayed {
            svc,
            per_op,
            per_tenant_op,
            issued,
            predict_rows,
            deleted_ids,
            wall_s,
        },
        cycles,
        sojourn,
    }
}

// ---------------------------------------------------------------------------
// Cross-check
// ---------------------------------------------------------------------------

/// The harness's correctness surface (DESIGN.md §14): differential-oracle
/// byte equality + probe-prediction bit equality + telemetry coherence for
/// every tenant, then the scenario-specific [`Check`]s.
pub fn cross_check(c: &CompiledScenario, r: &Replayed) {
    for (i, tenant) in c.tenants.iter().enumerate() {
        let model = r.svc.registry().get(&tenant.name).expect("tenant registered");

        // 1. Differential oracle: final flushed state, byte for byte.
        let snap = model.sharded().snapshot();
        assert_eq!(
            forest_to_json(&snap),
            forest_to_json(&tenant.oracle),
            "scenario '{}': tenant '{}' final snapshot diverged from its \
             differential oracle",
            c.name,
            tenant.name
        );
        assert_eq!(
            model.sharded().predict_proba_rows(&tenant.probes),
            tenant.oracle.predict_proba_rows(&tenant.probes),
            "scenario '{}': tenant '{}' probe predictions diverged",
            c.name,
            tenant.name
        );

        // 2. Telemetry coherence: the service's ledger must reconcile with
        // the ops the driver issued — counts, errors, histogram mass, and
        // the mutation counters.
        let tel = model.telemetry();
        for op in ["predict", "delete", "add", "delete_cost"] {
            let want = r.issued.get(&(i, op.to_string())).copied().unwrap_or(0);
            assert_eq!(
                tel.op_count(op),
                want,
                "scenario '{}': tenant '{}' telemetry count for '{op}' diverged",
                c.name,
                tenant.name
            );
            assert_eq!(tel.op_errors(op), 0, "scenario '{}': '{op}' errored", c.name);
            let hist_count = tel.op_histogram(op).map(|h| h.count()).unwrap_or(0);
            assert_eq!(
                hist_count, want,
                "scenario '{}': '{op}' histogram mass != op count",
                c.name
            );
        }
        assert_eq!(
            tel.counter("predict_rows"),
            r.predict_rows[i],
            "scenario '{}': predict_rows counter diverged",
            c.name
        );
        assert_eq!(
            tel.counter("deleted_ids"),
            r.deleted_ids[i],
            "scenario '{}': deleted_ids counter diverged",
            c.name
        );

        // Stats surface: the flushed store reports a clean backlog and the
        // payload agrees with the oracle on the corpus.
        assert_eq!(model.sharded().pending_retrains(), 0);
        let stats = model.stats();
        assert_eq!(
            stats.get("n_alive").and_then(|v| v.as_u64()),
            Some(tenant.oracle.n_alive() as u64),
            "scenario '{}': stats n_alive diverged",
            c.name
        );
        assert_eq!(stats.get("dirty_subtrees").and_then(|v| v.as_u64()), Some(0));
    }

    for check in &c.checks {
        match check {
            Check::ScratchRetrain { tenant } => {
                let t = &c.tenants[*tenant];
                let model = r.svc.registry().get(&t.name).unwrap();
                let f = model.sharded().snapshot();
                for (k, tree) in f.trees().iter().enumerate() {
                    let ctx = TrainCtx {
                        data: f.data(),
                        params: f.params(),
                        tree_seed: tree.tree_seed,
                    };
                    let scratch = train(
                        &ctx,
                        owned_live_ids(f.data(), tree.tree_seed, f.params().q),
                        0,
                        ROOT_PATH,
                    );
                    assert!(
                        tree.matches_root(&scratch),
                        "scenario '{}': tenant '{}' tree {k} != from-scratch \
                         retrain on the surviving corpus",
                        c.name,
                        t.name
                    );
                }
            }
            Check::AccuracyRecovery {
                tenant,
                test_rows,
                test_labels,
                baseline_acc,
                poisoned_acc: _,
            } => {
                let t = &c.tenants[*tenant];
                let model = r.svc.registry().get(&t.name).unwrap();
                let recovered =
                    accuracy(&model.sharded().predict_proba_rows(test_rows), test_labels);
                assert!(
                    (recovered - baseline_acc).abs() < 1e-12,
                    "scenario '{}': purge must restore held-out accuracy \
                     exactly (baseline {baseline_acc}, recovered {recovered})",
                    c.name
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Reporting
// ---------------------------------------------------------------------------

/// One scenario's entry in `BENCH_scenarios.json`.
pub fn scenario_json(c: &CompiledScenario, r: &Replayed) -> Value {
    let mut ops = Value::obj();
    let mut total = 0u64;
    for (op, h) in &r.per_op {
        total += h.count();
        ops.set(op.as_str(), h.to_json());
    }
    let mut extra = Value::obj();
    for check in &c.checks {
        if let Check::AccuracyRecovery {
            baseline_acc,
            poisoned_acc,
            ..
        } = check
        {
            extra
                .set("baseline_acc", *baseline_acc)
                .set("poisoned_acc", *poisoned_acc);
        }
    }
    let mut o = Value::obj();
    o.set("name", c.name.as_str())
        .set("seed", c.seed.to_string())
        .set("tenants", c.tenants.len())
        .set("ops_total", total)
        .set("wall_s", r.wall_s)
        .set("ops", ops);
    if !matches!(extra, Value::Obj(ref m) if m.is_empty()) {
        o.set("recovery", extra);
    }
    o
}

/// The full `BENCH_scenarios.json` document (schema pinned by
/// `tests/scenarios.rs::bench_schema_is_pinned`).
pub fn report_json(scale: usize, entries: Vec<Value>) -> Value {
    let mut o = Value::obj();
    o.set("suite", "scenarios")
        .set("scale", scale)
        .set("lazy_policy", LazyPolicy::from_env().to_string())
        .set("scenarios", Value::Arr(entries));
    o
}

/// Write the report where every other BENCH file lands (repo root when run
/// via `cargo bench`).
pub fn save_report<P: AsRef<std::path::Path>>(path: P, report: &Value) -> anyhow::Result<()> {
    std::fs::write(path.as_ref(), report.to_pretty())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(kind: ScenarioKind, seed: u64) -> Scenario {
        Scenario {
            kind,
            scale: 80,
            seed,
        }
    }

    #[test]
    fn compilation_is_deterministic_for_every_kind() {
        for kind in [
            ScenarioKind::AdversarialChurn,
            ScenarioKind::PoisonPurge,
            ScenarioKind::SlidingWindow,
            ScenarioKind::MultiTenantZipf,
            ScenarioKind::Fuzz,
            ScenarioKind::Burst,
        ] {
            let a = tiny(kind, 7).compile();
            let b = tiny(kind, 7).compile();
            assert_eq!(a.ops, b.ops, "{kind:?}: op stream must be seed-deterministic");
            assert_eq!(
                forest_to_json(&a.tenants[0].oracle),
                forest_to_json(&b.tenants[0].oracle),
                "{kind:?}: oracle state must be seed-deterministic"
            );
            let c = tiny(kind, 8).compile();
            assert_ne!(a.ops, c.ops, "{kind:?}: different seeds must diverge");
        }
    }

    #[test]
    fn scripts_cover_their_advertised_shapes() {
        let churn = tiny(ScenarioKind::AdversarialChurn, 3).compile();
        assert!(churn.ops.iter().all(|o| !matches!(o, ScenarioOp::Add { .. })));
        assert!(matches!(churn.checks.as_slice(), [Check::ScratchRetrain { .. }]));

        let purge = tiny(ScenarioKind::PoisonPurge, 3).compile();
        let adds = purge.ops.iter().filter(|o| matches!(o, ScenarioOp::Add { .. })).count();
        let deleted: usize = purge
            .ops
            .iter()
            .filter_map(|o| match o {
                ScenarioOp::Delete { ids, .. } => Some(ids.len()),
                _ => None,
            })
            .sum();
        assert!(adds > 0 && deleted == adds, "purge must delete exactly the injected ids");

        let zipf_sc = tiny(ScenarioKind::MultiTenantZipf, 3).compile();
        assert_eq!(zipf_sc.tenants.len(), 4);
        assert!(
            zipf_sc.tenants.iter().any(|t| t.oracle.params().subsampled()),
            "one zipf tenant must run Occ(q)"
        );
    }

    #[test]
    fn zipf_routing_is_head_heavy() {
        let mut rng = Rng::new(9);
        let mut counts = [0usize; 4];
        for _ in 0..4000 {
            counts[zipf(&mut rng, 4, 1.2)] += 1;
        }
        assert!(counts[0] > counts[1] && counts[1] > counts[3], "{counts:?}");
    }

    #[test]
    fn fuzz_scenario_replays_and_cross_checks_at_tiny_scale() {
        let c = tiny(ScenarioKind::Fuzz, 11).compile();
        let r = replay(&c);
        cross_check(&c, &r);
        assert!(r.per_op.values().map(|h| h.count()).sum::<u64>() == c.ops.len() as u64);
    }

    #[test]
    fn burst_scheduled_replay_is_byte_identical_to_direct() {
        let c = tiny(ScenarioKind::Burst, 17).compile();
        let direct = replay(&c);
        cross_check(&c, &direct);
        let sched = replay_scheduled(&c, Duration::from_millis(5));
        // The scheduled service passes the identical correctness surface:
        // differential oracle, probe bits, telemetry coherence.
        cross_check(&c, &sched.replayed);
        assert_eq!(
            direct.final_snapshots(&c),
            sched.replayed.final_snapshots(&c),
            "scheduled execution must be byte-identical to direct handle()"
        );
        assert_eq!(direct.op_counts(), sched.replayed.op_counts());
        // Every reply accounted for: sojourn mass == queued ops == total
        // minus the bypass (stats) ops that returned Immediate.
        let stats_ops =
            c.ops.iter().filter(|o| matches!(o, ScenarioOp::Stats { .. })).count() as u64;
        assert_eq!(sched.sojourn.count(), c.ops.len() as u64 - stats_ops);
        // Budget packing held in every cycle that dispatched work: the
        // overrun is bounded by the last ticket's measured cost (plus
        // bookkeeping slop — this is a real clock, so the assertion is
        // arithmetic-robust rather than wall-clock-tight; the exact bound
        // lives in the virtual-clock unit suite).
        assert!(!sched.cycles.is_empty());
        for r in &sched.cycles {
            if r.executed > 0 {
                assert!(
                    r.spent_s <= r.budget_s + r.last_cost_s + 0.05,
                    "cycle overran its budget: spent {} budget {} last {}",
                    r.spent_s,
                    r.budget_s,
                    r.last_cost_s
                );
            }
        }
    }

    #[test]
    fn per_tenant_histograms_merge_into_the_rollup() {
        let c = tiny(ScenarioKind::MultiTenantZipf, 5).compile();
        let r = replay(&c);
        cross_check(&c, &r);
        for (op, rollup) in &r.per_op {
            let split: u64 = r
                .per_tenant_op
                .iter()
                .filter(|((_, o), _)| o == op)
                .map(|(_, h)| h.count())
                .sum();
            assert_eq!(rollup.count(), split, "merge must preserve '{op}' mass");
        }
    }

    #[test]
    fn scenario_json_carries_the_histogram_entries() {
        let c = tiny(ScenarioKind::Fuzz, 13).compile();
        let r = replay(&c);
        let entry = scenario_json(&c, &r);
        assert_eq!(entry.get("name").unwrap().as_str(), Some("fuzz"));
        let ops = entry.get("ops").unwrap();
        let pred = ops.get("predict").expect("fuzz scripts always predict");
        for key in ["count", "p50_s", "p95_s", "p99_s", "max_s"] {
            assert!(pred.get(key).is_some(), "missing '{key}'");
        }
        let report = report_json(80, vec![entry]);
        assert_eq!(report.get("suite").unwrap().as_str(), Some("scenarios"));
        assert!(report.get("lazy_policy").is_some());
    }
}
