//! Table 5: predictive performance of G-DaRE RF against Random Trees,
//! Extra Trees, and a standard RF with and without bootstrapping,
//! averaged over repeats.

use crate::baselines::simple::{BaselineForest, BaselineKind, BaselineParams};
use crate::exp::common::ExpConfig;
use crate::forest::forest::DareForest;
use crate::util::json::Value;
use crate::util::stats::{mean, std_err};
use crate::util::table::Table;

#[derive(Clone, Debug)]
pub struct Table5Row {
    pub dataset: String,
    pub metric: &'static str,
    /// model name → per-repeat scores
    pub scores: Vec<(String, Vec<f64>)>,
}

pub struct Table5Result {
    pub rows: Vec<Table5Row>,
}

pub fn run(cfg: &ExpConfig) -> anyhow::Result<Table5Result> {
    let mut rows = Vec::new();
    for info in cfg.selected() {
        let pp = cfg.paper_params(&info);
        let models: Vec<String> = vec![
            "RandomTrees".into(),
            "ExtraTrees".into(),
            "StandardRF".into(),
            "StandardRF(bootstrap)".into(),
            "G-DaRE".into(),
        ];
        let mut scores: Vec<(String, Vec<f64>)> =
            models.iter().map(|m| (m.clone(), Vec::new())).collect();

        for rep in 0..cfg.repeats {
            let (train, test) = cfg.prepare(&info, rep as u64);
            let (_, test_ys, _) = test.to_row_major();
            let seed = crate::util::rng::mix_seed(&[cfg.seed, rep as u64, 0x7AB5]);

            for (mi, model) in models.iter().enumerate() {
                let probs: Vec<f32> = match model.as_str() {
                    "G-DaRE" => {
                        let params = cfg.params(&pp, 0);
                        let f = DareForest::fit(train.clone(), &params, seed);
                        f.predict_proba_dataset(&test)
                    }
                    name => {
                        let kind = match name {
                            "RandomTrees" => BaselineKind::RandomTrees,
                            "ExtraTrees" => BaselineKind::ExtraTrees,
                            _ => BaselineKind::Standard,
                        };
                        let bp = BaselineParams {
                            kind,
                            n_trees: pp.n_trees,
                            max_depth: pp.max_depth,
                            criterion: cfg.criterion,
                            bootstrap: name.contains("bootstrap"),
                            n_threads: cfg.threads,
                            ..Default::default()
                        };
                        let f = BaselineForest::fit(&train, &bp, seed);
                        f.predict_proba_dataset(&test)
                    }
                };
                scores[mi].1.push(info.metric.score(&probs, &test_ys));
            }
        }
        eprintln!(
            "table5 [{}] {}: {}",
            info.name,
            info.metric.name(),
            scores
                .iter()
                .map(|(m, s)| format!("{m}={:.4}", mean(s)))
                .collect::<Vec<_>>()
                .join(" ")
        );
        rows.push(Table5Row {
            dataset: info.name.to_string(),
            metric: info.metric.name(),
            scores,
        });
    }
    let r = Table5Result { rows };
    cfg.save(&format!("table5_{}", cfg.criterion_tag()), &to_json(&r))?;
    Ok(r)
}

fn to_json(r: &Table5Result) -> Value {
    let mut arr = Vec::new();
    for row in &r.rows {
        let mut o = Value::obj();
        o.set("dataset", row.dataset.as_str())
            .set("metric", row.metric);
        let mut models = Value::obj();
        for (m, s) in &row.scores {
            models.set(m, s.clone());
        }
        o.set("models", models);
        arr.push(o);
    }
    let mut top = Value::obj();
    top.set("experiment", "table5").set("rows", Value::Arr(arr));
    top
}

pub fn render(r: &Table5Result) -> String {
    let headers: Vec<&str> = vec![
        "dataset",
        "metric",
        "RandomTrees",
        "ExtraTrees",
        "StandardRF",
        "StdRF(boot)",
        "G-DaRE",
    ];
    let mut t = Table::new("Table 5 — predictive performance (mean ± se)", &headers);
    for row in &r.rows {
        let mut cells = vec![row.dataset.clone(), row.metric.to_string()];
        for (_, s) in &row.scores {
            cells.push(format!("{:.3}±{:.3}", mean(s), std_err(s)));
        }
        t.row(cells);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_one_dataset() {
        let cfg = ExpConfig {
            scale_div: 20_000,
            repeats: 2,
            datasets: vec!["twitter".into()],
            max_trees: 3,
            out_dir: std::env::temp_dir().join("dare_table5_test"),
            ..Default::default()
        };
        let r = run(&cfg).unwrap();
        assert_eq!(r.rows.len(), 1);
        assert_eq!(r.rows[0].scores.len(), 5);
        assert!(r.rows[0].scores.iter().all(|(_, s)| s.len() == 2));
        // all models beat random guessing on AUC
        for (m, s) in &r.rows[0].scores {
            assert!(mean(s) > 0.5, "{m}: {}", mean(s));
        }
        let text = render(&r);
        assert!(text.contains("G-DaRE"));
        std::fs::remove_dir_all(&cfg.out_dir).ok();
    }
}
