//! Figure 2 (+ Appendix Fig. 4): effect of d_rmax on deletion efficiency
//! (left), predictive performance (middle), and the retrain-cost-by-depth
//! histogram (right), for one dataset under both adversaries.

use crate::eval::adversary::Adversary;
use crate::eval::speedup::{measure, SpeedupConfig};
use crate::exp::common::ExpConfig;
use crate::util::json::Value;
use crate::util::stats::{mean, std_dev, std_err};
use crate::util::table::Table;

#[derive(Clone, Debug)]
pub struct DrmaxPoint {
    pub d_rmax: usize,
    pub adversary: String,
    pub speedups: Vec<f64>,
    pub metric: Vec<f64>,
    pub cost_by_depth: Vec<u64>,
}

pub struct Fig2Result {
    pub dataset: String,
    pub points: Vec<DrmaxPoint>,
}

/// Sweep d_rmax from 0 to d_max (sampled levels when d_max is large).
pub fn run(cfg: &ExpConfig, dataset: &str) -> anyhow::Result<Fig2Result> {
    let info = crate::data::registry::find(dataset)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset '{dataset}'"))?;
    let pp = cfg.paper_params(&info);
    // sample levels: all up to 6, then every other
    let levels: Vec<usize> = (0..=pp.max_depth)
        .filter(|&d| d <= 6 || d % 2 == 0)
        .collect();

    let mut points = Vec::new();
    for adv in [Adversary::Random, Adversary::WorstOf(cfg.worst_of)] {
        for &d_rmax in &levels {
            let params = cfg.params(&pp, d_rmax);
            let mut speedups = Vec::new();
            let mut metric = Vec::new();
            let mut hist = vec![0u64; pp.max_depth + 1];
            for rep in 0..cfg.repeats {
                let (train, test) = cfg.prepare(&info, rep as u64);
                let r = measure(
                    &train,
                    &test,
                    &params,
                    &SpeedupConfig {
                        adversary: adv,
                        max_deletions: cfg.max_deletions,
                        metric: info.metric,
                        seed: crate::util::rng::mix_seed(&[cfg.seed, rep as u64, d_rmax as u64]),
                    },
                );
                speedups.push(r.speedup);
                metric.push(r.metric_before);
                for (d, c) in r.cost_by_depth.iter().enumerate() {
                    hist[d] += c;
                }
            }
            eprintln!(
                "fig2 [{}] d_rmax={} {} -> {:.0}x, {}={:.4}",
                info.name,
                d_rmax,
                adv.name(),
                mean(&speedups),
                info.metric.name(),
                mean(&metric)
            );
            points.push(DrmaxPoint {
                d_rmax,
                adversary: adv.name(),
                speedups,
                metric,
                cost_by_depth: hist,
            });
        }
    }
    let r = Fig2Result {
        dataset: info.name.to_string(),
        points,
    };
    cfg.save(&format!("fig2_{}_{}", info.name, cfg.criterion_tag()), &to_json(&r))?;
    Ok(r)
}

fn to_json(r: &Fig2Result) -> Value {
    let mut arr = Vec::new();
    for p in &r.points {
        let mut o = Value::obj();
        o.set("d_rmax", p.d_rmax)
            .set("adversary", p.adversary.as_str())
            .set("speedups", p.speedups.clone())
            .set("metric", p.metric.clone())
            .set(
                "cost_by_depth",
                p.cost_by_depth.iter().map(|&c| c as f64).collect::<Vec<f64>>(),
            );
        arr.push(o);
    }
    let mut top = Value::obj();
    top.set("experiment", "fig2")
        .set("dataset", r.dataset.as_str())
        .set("points", Value::Arr(arr));
    top
}

pub fn render(r: &Fig2Result) -> String {
    let mut out = String::new();
    for adv_prefix in ["random", "worst_of"] {
        let mut t = Table::new(
            &format!(
                "Figure 2 [{}] — d_rmax sweep ({adv_prefix} adversary)",
                r.dataset
            ),
            &[
                "d_rmax",
                "speedup (±std)",
                "test metric (±se)",
                "retrained instances (by depth, head)",
            ],
        );
        for p in r.points.iter().filter(|p| p.adversary.starts_with(adv_prefix)) {
            let head: Vec<String> = p
                .cost_by_depth
                .iter()
                .take(8)
                .map(|c| c.to_string())
                .collect();
            t.row(vec![
                p.d_rmax.to_string(),
                format!("{:.0} ± {:.0}", mean(&p.speedups), std_dev(&p.speedups)),
                format!("{:.4} ± {:.4}", mean(&p.metric), std_err(&p.metric)),
                head.join(","),
            ]);
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_tiny_sweep() {
        let cfg = ExpConfig {
            scale_div: 20_000,
            repeats: 1,
            max_deletions: 6,
            worst_of: 6,
            max_trees: 2,
            out_dir: std::env::temp_dir().join("dare_fig2_test"),
            ..Default::default()
        };
        let r = run(&cfg, "ctr").unwrap();
        assert_eq!(r.dataset, "ctr");
        // ctr: d_max = 10 → levels 0..6 + 8,10 = 9 levels × 2 adversaries
        assert_eq!(r.points.len(), 18);
        // speedup should (weakly) increase with d_rmax at the extremes
        let text = render(&r);
        assert!(text.contains("d_rmax"));
        std::fs::remove_dir_all(&cfg.out_dir).ok();
    }
}
