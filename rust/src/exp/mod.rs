//! Experiment reproductions: one module per paper table/figure
//! (see DESIGN.md §4 for the index). All are driven by `dare reproduce`.
//!
//! | module  | paper artifact                                  |
//! |---------|--------------------------------------------------|
//! | fig1    | Fig. 1 — deletion efficiency grid + error deltas |
//! | table2  | Table 2 (Gini) / Table 9 (entropy) summaries     |
//! | fig2    | Fig. 2 / Fig. 4 — d_rmax sweeps                  |
//! | fig3    | Fig. 3 / Fig. 5 — k sweeps                       |
//! | table3  | Table 3 — memory breakdown                       |
//! | table5  | Table 5 — predictive performance comparison      |
//! | table6  | Table 6 (Gini) / Table 8 (entropy) — tuning      |
//! | table7  | Table 7 — training time                          |
//!
//! `scenarios` is not a paper artifact: it is the scripted-workload
//! harness (adversarial churn, poison-purge, drift replay, multi-tenant
//! zipf) that replays op scripts against the full coordinator stack with
//! latency histograms and oracle cross-checks (DESIGN.md §14). It backs
//! `benches/scenarios.rs` and the CI scenarios job.

pub mod common;
pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod scenarios;
pub mod table2;
pub mod table3;
pub mod table5;
pub mod table6;
pub mod table7;

pub use common::{ExpConfig, TOLERANCES};
