//! Figure 1 + Table 2 (and Table 9 with entropy): deletion efficiency of
//! G-DaRE and R-DaRE (four tolerances) under the random and worst-of-c
//! adversaries, plus the R-DaRE test-error increase relative to G-DaRE
//! (Fig. 1 bottom).

use crate::eval::adversary::Adversary;
use crate::eval::speedup::{measure, SpeedupConfig};
use crate::exp::common::{ExpConfig, TOLERANCES};
use crate::util::json::Value;
use crate::util::stats::{mean, std_dev};
use crate::util::table::{speedup as fmt_speedup, Table};

/// One (dataset, model, adversary) cell.
#[derive(Clone, Debug)]
pub struct Cell {
    pub dataset: String,
    pub model: String,
    pub adversary: String,
    pub speedups: Vec<f64>,
    pub err_increase_pct: Vec<f64>, // vs G-DaRE, same repeat
    pub n_deleted: Vec<f64>,
}

/// Full Figure-1 result grid.
pub struct Fig1Result {
    pub cells: Vec<Cell>,
}

pub fn run(cfg: &ExpConfig) -> anyhow::Result<Fig1Result> {
    let mut cells: Vec<Cell> = Vec::new();
    let adversaries = [Adversary::Random, Adversary::WorstOf(cfg.worst_of)];

    for info in cfg.selected() {
        let pp = cfg.paper_params(&info);
        // model list: G-DaRE + R-DaRE per tolerance (dedupe d_rmax=0 repeats)
        let mut models: Vec<(String, usize)> = vec![("G-DaRE".to_string(), 0)];
        for (i, tol) in TOLERANCES.iter().enumerate() {
            models.push((format!("R-DaRE({tol}%)"), pp.drmax[i]));
        }

        for adv in adversaries {
            // per-repeat G-DaRE metric to compute error increases
            let mut gdare_metric: Vec<f64> = Vec::new();
            for (model_name, d_rmax) in &models {
                let params = cfg.params(&pp, *d_rmax);
                let mut speedups = Vec::new();
                let mut errs = Vec::new();
                let mut dels = Vec::new();
                for rep in 0..cfg.repeats {
                    let (train, test) = cfg.prepare(&info, rep as u64);
                    let scfg = SpeedupConfig {
                        adversary: adv,
                        max_deletions: cfg.max_deletions,
                        metric: info.metric,
                        seed: crate::util::rng::mix_seed(&[cfg.seed, rep as u64, *d_rmax as u64]),
                    };
                    let r = measure(&train, &test, &params, &scfg);
                    speedups.push(r.speedup);
                    dels.push(r.n_deleted as f64);
                    if *d_rmax == 0 && model_name == "G-DaRE" {
                        gdare_metric.push(r.metric_before);
                        errs.push(0.0);
                    } else {
                        let base = gdare_metric.get(rep).copied().unwrap_or(r.metric_before);
                        // error increase = (base score − this score) in percent
                        errs.push((base - r.metric_before) * 100.0);
                    }
                }
                eprintln!(
                    "fig1 [{}] {} {} -> {:.0}x (mean of {} reps)",
                    info.name,
                    model_name,
                    adv.name(),
                    mean(&speedups),
                    cfg.repeats
                );
                cells.push(Cell {
                    dataset: info.name.to_string(),
                    model: model_name.clone(),
                    adversary: adv.name(),
                    speedups,
                    err_increase_pct: errs,
                    n_deleted: dels,
                });
            }
        }
    }

    let result = Fig1Result { cells };
    let json = to_json(&result);
    cfg.save(&format!("fig1_{}", cfg.criterion_tag()), &json)?;
    Ok(result)
}

pub fn to_json(r: &Fig1Result) -> Value {
    let mut arr = Vec::new();
    for c in &r.cells {
        let mut o = Value::obj();
        o.set("dataset", c.dataset.as_str())
            .set("model", c.model.as_str())
            .set("adversary", c.adversary.as_str())
            .set("speedups", c.speedups.clone())
            .set("err_increase_pct", c.err_increase_pct.clone())
            .set("n_deleted", c.n_deleted.clone());
        arr.push(o);
    }
    let mut top = Value::obj();
    top.set("experiment", "fig1").set("cells", Value::Arr(arr));
    top
}

pub fn from_json(v: &Value) -> Option<Fig1Result> {
    let cells = v.get("cells")?.as_arr()?;
    let mut out = Vec::new();
    for c in cells {
        let nums = |k: &str| -> Vec<f64> {
            c.get(k)
                .and_then(Value::as_arr)
                .map(|a| a.iter().filter_map(Value::as_f64).collect())
                .unwrap_or_default()
        };
        out.push(Cell {
            dataset: c.get("dataset")?.as_str()?.to_string(),
            model: c.get("model")?.as_str()?.to_string(),
            adversary: c.get("adversary")?.as_str()?.to_string(),
            speedups: nums("speedups"),
            err_increase_pct: nums("err_increase_pct"),
            n_deleted: nums("n_deleted"),
        });
    }
    Some(Fig1Result { cells: out })
}

/// Render the Figure-1 grid as text tables (top/middle/bottom panels).
pub fn render(r: &Fig1Result) -> String {
    let mut out = String::new();
    for adv in ["random", "worst_of"] {
        let mut t = Table::new(
            &format!("Figure 1 — deletions per naive-retrain time ({adv} adversary)"),
            &["dataset", "model", "speedup (mean±std)", "deleted"],
        );
        for c in r.cells.iter().filter(|c| c.adversary.starts_with(adv)) {
            t.row(vec![
                c.dataset.clone(),
                c.model.clone(),
                format!(
                    "{} ± {:.0}",
                    fmt_speedup(mean(&c.speedups)),
                    std_dev(&c.speedups)
                ),
                format!("{:.0}", mean(&c.n_deleted)),
            ]);
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    let mut t = Table::new(
        "Figure 1 (bottom) — R-DaRE test-error increase vs G-DaRE (%)",
        &["dataset", "model", "err increase (mean)"],
    );
    for c in r
        .cells
        .iter()
        .filter(|c| c.adversary == "random" && c.model != "G-DaRE")
    {
        t.row(vec![
            c.dataset.clone(),
            c.model.clone(),
            format!("{:+.3}", mean(&c.err_increase_pct)),
        ]);
    }
    out.push_str(&t.render());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ExpConfig {
        ExpConfig {
            scale_div: 20_000,
            repeats: 1,
            max_deletions: 8,
            worst_of: 8,
            datasets: vec!["ctr".into()],
            max_trees: 3,
            out_dir: std::env::temp_dir().join("dare_fig1_test"),
            ..Default::default()
        }
    }

    #[test]
    fn fig1_tiny_end_to_end() {
        let cfg = tiny_cfg();
        let r = run(&cfg).unwrap();
        // 5 models × 2 adversaries × 1 dataset
        assert_eq!(r.cells.len(), 10);
        assert!(r.cells.iter().all(|c| !c.speedups.is_empty()));
        let text = render(&r);
        assert!(text.contains("ctr"));
        assert!(text.contains("G-DaRE"));
        // json roundtrip
        let v = to_json(&r);
        let back = from_json(&v).unwrap();
        assert_eq!(back.cells.len(), 10);
        // result file written
        assert!(cfg.load("fig1_gini").is_some());
        std::fs::remove_dir_all(&cfg.out_dir).ok();
    }
}
