//! Predictive-performance metrics: accuracy, AUC (Hanley & McNeil 1982), and
//! average precision (Zhu 2004) — the three metrics the paper selects among
//! based on label imbalance (§4, Table 1).

/// Which metric a dataset is scored with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    Accuracy,
    Auc,
    AveragePrecision,
}

impl Metric {
    pub fn name(&self) -> &'static str {
        match self {
            Metric::Accuracy => "acc",
            Metric::Auc => "auc",
            Metric::AveragePrecision => "ap",
        }
    }

    /// Score predicted positive-class probabilities against labels.
    pub fn score(&self, probs: &[f32], labels: &[u8]) -> f64 {
        match self {
            Metric::Accuracy => accuracy(probs, labels),
            Metric::Auc => auc(probs, labels),
            Metric::AveragePrecision => average_precision(probs, labels),
        }
    }
}

impl std::str::FromStr for Metric {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "acc" | "accuracy" => Ok(Metric::Accuracy),
            "auc" => Ok(Metric::Auc),
            "ap" | "average_precision" => Ok(Metric::AveragePrecision),
            _ => Err(format!("unknown metric '{s}'")),
        }
    }
}

/// Fraction of correct predictions at the 0.5 threshold.
pub fn accuracy(probs: &[f32], labels: &[u8]) -> f64 {
    assert_eq!(probs.len(), labels.len());
    if probs.is_empty() {
        return 0.0;
    }
    let correct = probs
        .iter()
        .zip(labels)
        .filter(|(&p, &y)| (p >= 0.5) as u8 == y)
        .count();
    correct as f64 / probs.len() as f64
}

/// Area under the ROC curve via the rank-sum (Mann–Whitney) formulation,
/// with midrank handling for tied scores. Returns 0.5 when a class is absent.
pub fn auc(probs: &[f32], labels: &[u8]) -> f64 {
    assert_eq!(probs.len(), labels.len());
    let n_pos = labels.iter().filter(|&&y| y == 1).count();
    let n_neg = labels.len() - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return 0.5;
    }
    // sort indices by score ascending
    let mut idx: Vec<usize> = (0..probs.len()).collect();
    idx.sort_by(|&a, &b| probs[a].partial_cmp(&probs[b]).unwrap());
    // midranks over ties
    let mut rank_sum_pos = 0.0f64;
    let mut i = 0;
    while i < idx.len() {
        let mut j = i;
        while j + 1 < idx.len() && probs[idx[j + 1]] == probs[idx[i]] {
            j += 1;
        }
        // ranks i+1 ..= j+1 share midrank
        let midrank = (i + 1 + j + 1) as f64 / 2.0;
        for &t in &idx[i..=j] {
            if labels[t] == 1 {
                rank_sum_pos += midrank;
            }
        }
        i = j + 1;
    }
    let u = rank_sum_pos - (n_pos * (n_pos + 1)) as f64 / 2.0;
    u / (n_pos as f64 * n_neg as f64)
}

/// Average precision: AP = Σ_k (R_k − R_{k−1}) · P_k over the ranking, i.e.
/// precision averaged at each positive hit. Ties are broken pessimistically
/// (stable order). Returns 0.0 when there are no positives.
pub fn average_precision(probs: &[f32], labels: &[u8]) -> f64 {
    assert_eq!(probs.len(), labels.len());
    let n_pos = labels.iter().filter(|&&y| y == 1).count();
    if n_pos == 0 {
        return 0.0;
    }
    let mut idx: Vec<usize> = (0..probs.len()).collect();
    // descending score
    idx.sort_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap());
    let mut tp = 0usize;
    let mut ap = 0.0f64;
    for (rank, &i) in idx.iter().enumerate() {
        if labels[i] == 1 {
            tp += 1;
            let precision = tp as f64 / (rank + 1) as f64;
            ap += precision / n_pos as f64;
        }
    }
    ap
}

/// Binary log loss (used by the end-to-end example's loss curve).
pub fn log_loss(probs: &[f32], labels: &[u8]) -> f64 {
    assert_eq!(probs.len(), labels.len());
    if probs.is_empty() {
        return 0.0;
    }
    let eps = 1e-7f64;
    let mut s = 0.0;
    for (&p, &y) in probs.iter().zip(labels) {
        let p = (p as f64).clamp(eps, 1.0 - eps);
        s -= if y == 1 { p.ln() } else { (1.0 - p).ln() };
    }
    s / probs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_basic() {
        assert_eq!(accuracy(&[0.9, 0.1, 0.6, 0.4], &[1, 0, 1, 1]), 0.75);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }

    #[test]
    fn auc_perfect_and_inverted() {
        let y = [0u8, 0, 1, 1];
        assert_eq!(auc(&[0.1, 0.2, 0.8, 0.9], &y), 1.0);
        assert_eq!(auc(&[0.9, 0.8, 0.2, 0.1], &y), 0.0);
        assert_eq!(auc(&[0.5, 0.5, 0.5, 0.5], &y), 0.5);
    }

    #[test]
    fn auc_ties_midrank() {
        // scores: pos {0.5, 0.8}, neg {0.5, 0.2}
        // pairs: (0.5,0.5)=0.5, (0.5,0.2)=1, (0.8,0.5)=1, (0.8,0.2)=1 → 3.5/4
        let v = auc(&[0.5, 0.8, 0.5, 0.2], &[1, 1, 0, 0]);
        assert!((v - 0.875).abs() < 1e-12, "{v}");
    }

    #[test]
    fn auc_degenerate_single_class() {
        assert_eq!(auc(&[0.3, 0.7], &[1, 1]), 0.5);
        assert_eq!(auc(&[0.3, 0.7], &[0, 0]), 0.5);
    }

    #[test]
    fn ap_perfect_ranking() {
        let v = average_precision(&[0.9, 0.8, 0.3, 0.2], &[1, 1, 0, 0]);
        assert!((v - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ap_known_value() {
        // ranking: pos@1, neg@2, pos@3 → AP = (1/1 + 2/3) / 2 = 5/6
        let v = average_precision(&[0.9, 0.5, 0.4], &[1, 0, 1]);
        assert!((v - 5.0 / 6.0).abs() < 1e-12, "{v}");
    }

    #[test]
    fn ap_no_positives() {
        assert_eq!(average_precision(&[0.5], &[0]), 0.0);
    }

    #[test]
    fn log_loss_sane() {
        assert!(log_loss(&[0.99, 0.01], &[1, 0]) < 0.05);
        assert!(log_loss(&[0.01, 0.99], &[1, 0]) > 2.0);
    }

    #[test]
    fn metric_dispatch_and_parse() {
        assert_eq!("auc".parse::<Metric>().unwrap(), Metric::Auc);
        assert_eq!("ACC".parse::<Metric>().unwrap(), Metric::Accuracy);
        assert!("bogus".parse::<Metric>().is_err());
        let m = Metric::Auc;
        assert_eq!(m.score(&[0.1, 0.9], &[0, 1]), 1.0);
        assert_eq!(m.name(), "auc");
    }

    #[test]
    fn auc_is_threshold_invariant_monotone() {
        // monotone transform of scores leaves AUC unchanged
        let y = [0u8, 1, 0, 1, 1, 0, 0, 1];
        let s1: Vec<f32> = vec![0.1, 0.4, 0.35, 0.8, 0.7, 0.2, 0.5, 0.9];
        let s2: Vec<f32> = s1.iter().map(|v| v * v).collect();
        assert!((auc(&s1, &y) - auc(&s2, &y)).abs() < 1e-12);
    }
}
