//! Space-overhead accounting (paper §4.4, Table 3): break G-DaRE memory into
//! structure / decision statistics / leaf statistics, compare against a lean
//! standard-RF model with the same T and d_max, and compute the paper's
//! overhead ratio (data + DaRE) / (data + lean RF).
//!
//! Since the arena refactor (DESIGN.md §7) the structure column reflects the
//! SoA hot plane's actual footprint (five 4-byte elements per slot, free
//! slots included) rather than boxed-node pointers, so the overhead ratio is
//! measured on what the process really allocates.

use crate::baselines::simple::{BaselineForest, BaselineParams};
use crate::data::dataset::Dataset;
use crate::forest::forest::DareForest;
use crate::forest::params::Params;

/// One Table-3 row, in bytes.
#[derive(Clone, Debug)]
pub struct MemoryRow {
    pub data_bytes: usize,
    pub structure: usize,
    pub decision_stats: usize,
    pub leaf_stats: usize,
    pub dare_total: usize,
    pub sklearn_like: usize,
    /// (data + DaRE) / (data + lean RF)
    pub overhead_ratio: f64,
    pub mean_decision_nodes: f64,
}

/// Measure the space breakdown of a trained DaRE forest versus a lean RF
/// trained with the same T / d_max on the same data.
pub fn measure(train: &Dataset, params: &Params, seed: u64) -> MemoryRow {
    let forest = DareForest::fit(train.clone(), params, seed);
    let m = forest.memory();
    let lean_params = BaselineParams {
        n_trees: params.n_trees,
        max_depth: params.max_depth,
        criterion: params.criterion,
        max_features: params.max_features,
        n_threads: params.n_threads,
        ..Default::default()
    };
    let lean = BaselineForest::fit(train, &lean_params, seed);
    let data_bytes = train.memory_bytes();
    let dare_total = m.total();
    let sklearn_like = lean.memory_bytes();
    MemoryRow {
        data_bytes,
        structure: m.structure,
        decision_stats: m.decision_stats,
        leaf_stats: m.leaf_stats,
        dare_total,
        sklearn_like,
        overhead_ratio: (data_bytes + dare_total) as f64 / (data_bytes + sklearn_like) as f64,
        mean_decision_nodes: forest.mean_decision_nodes(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};

    #[test]
    fn breakdown_reflects_paper_shape() {
        let d = generate(
            &SynthSpec {
                n: 800,
                informative: 4,
                redundant: 2,
                noise: 6,
                flip: 0.05,
                ..Default::default()
            },
            3,
        );
        let params = Params {
            n_trees: 10,
            max_depth: 8,
            k: 10,
            ..Default::default()
        };
        let row = measure(&d, &params, 1);
        assert_eq!(
            row.dare_total,
            row.structure + row.decision_stats + row.leaf_stats
        );
        // Table 3: decision stats dominate the DaRE overhead...
        assert!(row.decision_stats > row.structure);
        // ...and the DaRE model is much larger than the lean model...
        assert!(row.dare_total > 3 * row.sklearn_like);
        // ...but the *relative* overhead (counting data) is single/double-digit
        assert!(row.overhead_ratio > 1.0 && row.overhead_ratio < 200.0);
        assert!(row.mean_decision_nodes > 1.0);
    }

    #[test]
    fn more_k_means_more_decision_stats() {
        let d = generate(
            &SynthSpec {
                n: 600,
                ..Default::default()
            },
            4,
        );
        let small = measure(
            &d,
            &Params {
                n_trees: 5,
                max_depth: 6,
                k: 5,
                ..Default::default()
            },
            1,
        );
        let big = measure(
            &d,
            &Params {
                n_trees: 5,
                max_depth: 6,
                k: 50,
                ..Default::default()
            },
            1,
        );
        assert!(big.decision_stats > small.decision_stats);
    }
}
