//! Stratified k-fold cross-validation of DaRE parameter settings — the
//! scoring primitive behind the paper's tuning protocol (§4).

use crate::data::dataset::Dataset;
use crate::data::split::stratified_kfold;
use crate::forest::forest::DareForest;
use crate::forest::params::Params;
use crate::metrics::Metric;

/// Mean validation score of `params` across `k` stratified folds.
pub fn cv_score(data: &Dataset, params: &Params, metric: Metric, k: usize, seed: u64) -> f64 {
    let folds = stratified_kfold(data, k, seed);
    let mut scores = Vec::with_capacity(k);
    for (fi, (train_ids, valid_ids)) in folds.iter().enumerate() {
        let train = data.subset(train_ids);
        let valid = data.subset(valid_ids);
        let forest = DareForest::fit(
            train,
            params,
            crate::util::rng::mix_seed(&[seed, fi as u64, 0xCF]),
        );
        let probs = forest.predict_proba_dataset(&valid);
        let (_, ys, _) = valid.to_row_major();
        scores.push(metric.score(&probs, &ys));
    }
    crate::util::stats::mean(&scores)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};

    fn data() -> Dataset {
        generate(
            &SynthSpec {
                n: 500,
                informative: 4,
                redundant: 0,
                noise: 2,
                flip: 0.05,
                ..Default::default()
            },
            9,
        )
    }

    #[test]
    fn cv_scores_sane_and_deterministic() {
        let d = data();
        let p = Params {
            n_trees: 5,
            max_depth: 5,
            k: 5,
            ..Default::default()
        };
        let a = cv_score(&d, &p, Metric::Accuracy, 3, 1);
        let b = cv_score(&d, &p, Metric::Accuracy, 3, 1);
        assert_eq!(a, b);
        assert!(a > 0.7, "cv accuracy {a}");
        assert!(a <= 1.0);
    }

    #[test]
    fn deeper_trees_not_worse_on_learnable_data() {
        let d = data();
        let shallow = Params {
            n_trees: 5,
            max_depth: 1,
            k: 5,
            ..Default::default()
        };
        let deep = Params {
            n_trees: 5,
            max_depth: 8,
            k: 5,
            ..Default::default()
        };
        let s = cv_score(&d, &shallow, Metric::Accuracy, 3, 2);
        let dscore = cv_score(&d, &deep, Metric::Accuracy, 3, 2);
        assert!(dscore >= s - 0.02, "deep {dscore} vs shallow {s}");
    }
}
