//! Evaluation harness: deletion adversaries, cross-validation, the paper's
//! hyperparameter tuning protocol, speedup measurement, and space-overhead
//! accounting.

pub mod adversary;
pub mod cv;
pub mod memory;
pub mod speedup;
pub mod tuner;

pub use adversary::Adversary;
pub use speedup::{measure as measure_speedup, SpeedupConfig, SpeedupResult};
pub use tuner::{tune, Grid, TuneResult};
