//! The paper's two-stage hyperparameter tuning protocol (§4, Appendix B.2):
//!
//! 1. Grid-search T (trees), d_max (depth) and k (thresholds/attribute) for
//!    the greedy model (d_rmax = 0) by 5-fold CV — paper grids:
//!    T ∈ {10,25,50,100,250}, d_max ∈ {1,3,5,10,20}, k ∈ {5,10,25,50}.
//! 2. Holding those fixed, increment d_rmax from 0 until the CV score drops
//!    more than each error tolerance below the greedy model's score,
//!    recording the largest admissible d_rmax per tolerance
//!    (paper tolerances: 0.1%, 0.25%, 0.5%, 1.0%).

use crate::data::dataset::Dataset;
use crate::eval::cv::cv_score;
use crate::forest::params::{Params, SplitCriterion};
use crate::metrics::Metric;

/// Search space for stage 1.
#[derive(Clone, Debug)]
pub struct Grid {
    pub n_trees: Vec<usize>,
    pub max_depth: Vec<usize>,
    pub k: Vec<usize>,
}

impl Grid {
    /// The paper's full grid (Appendix B.2).
    pub fn paper() -> Self {
        Grid {
            n_trees: vec![10, 25, 50, 100, 250],
            max_depth: vec![1, 3, 5, 10, 20],
            k: vec![5, 10, 25, 50],
        }
    }

    /// A reduced grid for CI-scale runs.
    pub fn small() -> Self {
        Grid {
            n_trees: vec![5, 10, 25],
            max_depth: vec![3, 5, 8],
            k: vec![5, 10, 25],
        }
    }
}

/// Tuning output: the greedy optimum and d_rmax per tolerance (Table 6/8).
#[derive(Clone, Debug)]
pub struct TuneResult {
    pub gdare: Params,
    pub gdare_cv: f64,
    /// (tolerance, d_rmax, cv score at that d_rmax)
    pub drmax_per_tol: Vec<(f64, usize, f64)>,
}

/// Run the full protocol.
pub fn tune(
    data: &Dataset,
    metric: Metric,
    criterion: SplitCriterion,
    grid: &Grid,
    tolerances: &[f64],
    folds: usize,
    threads: usize,
    seed: u64,
) -> TuneResult {
    // stage 1: grid-search the greedy model
    let mut best: Option<(Params, f64)> = None;
    for &t in &grid.n_trees {
        for &d in &grid.max_depth {
            for &k in &grid.k {
                let params = Params {
                    n_trees: t,
                    max_depth: d,
                    k,
                    d_rmax: 0,
                    criterion,
                    n_threads: threads,
                    ..Default::default()
                };
                let score = cv_score(data, &params, metric, folds, seed);
                match &best {
                    Some((_, bs)) if score <= *bs => {}
                    _ => best = Some((params, score)),
                }
            }
        }
    }
    let (gdare, gdare_cv) = best.expect("non-empty grid");

    // stage 2: push d_rmax up per tolerance
    let mut drmax_per_tol = Vec::with_capacity(tolerances.len());
    let mut scores_by_drmax: Vec<Option<f64>> = vec![None; gdare.max_depth + 1];
    scores_by_drmax[0] = Some(gdare_cv);
    for &tol in tolerances {
        let budget = tol / 100.0; // tolerances given in percent
        let mut chosen = 0usize;
        let mut chosen_score = gdare_cv;
        for d_rmax in 1..=gdare.max_depth {
            let score = match scores_by_drmax[d_rmax] {
                Some(s) => s,
                None => {
                    let p = Params {
                        d_rmax,
                        ..gdare.clone()
                    };
                    let s = cv_score(data, &p, metric, folds, seed);
                    scores_by_drmax[d_rmax] = Some(s);
                    s
                }
            };
            if gdare_cv - score > budget {
                break;
            }
            chosen = d_rmax;
            chosen_score = score;
        }
        drmax_per_tol.push((tol, chosen, chosen_score));
    }

    TuneResult {
        gdare,
        gdare_cv,
        drmax_per_tol,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};

    fn data() -> Dataset {
        generate(
            &SynthSpec {
                n: 400,
                informative: 4,
                redundant: 0,
                noise: 2,
                flip: 0.05,
                ..Default::default()
            },
            13,
        )
    }

    #[test]
    fn tune_small_grid_end_to_end() {
        let d = data();
        let grid = Grid {
            n_trees: vec![5],
            max_depth: vec![3, 6],
            k: vec![5],
        };
        let r = tune(
            &d,
            Metric::Accuracy,
            SplitCriterion::Gini,
            &grid,
            &[0.5, 5.0],
            3,
            1,
            1,
        );
        assert!(r.gdare_cv > 0.7);
        assert_eq!(r.gdare.d_rmax, 0);
        assert!(grid.max_depth.contains(&r.gdare.max_depth));
        assert_eq!(r.drmax_per_tol.len(), 2);
        // looser tolerance admits at least as much randomness
        assert!(r.drmax_per_tol[1].1 >= r.drmax_per_tol[0].1);
        for (_, drmax, _) in &r.drmax_per_tol {
            assert!(*drmax <= r.gdare.max_depth);
        }
    }

    #[test]
    fn grids_exist() {
        let p = Grid::paper();
        assert_eq!(p.n_trees.len() * p.max_depth.len() * p.k.len(), 100);
        let s = Grid::small();
        assert!(s.n_trees.len() * s.max_depth.len() * s.k.len() <= 27);
    }
}
