//! Deletion-order adversaries (paper §4.1): *Random* picks uniformly among
//! live instances; *Worst-of-c* samples c candidates and deletes the one
//! whose dry-run retrain cost (instances assigned to retrained nodes, summed
//! over trees) is largest — the paper uses c = 1000.

use crate::data::dataset::InstanceId;
use crate::forest::forest::DareForest;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Adversary {
    Random,
    WorstOf(usize),
}

impl Adversary {
    pub fn name(&self) -> String {
        match self {
            Adversary::Random => "random".to_string(),
            Adversary::WorstOf(c) => format!("worst_of_{c}"),
        }
    }

    /// Choose the next instance to delete. Returns None when no live
    /// instances remain.
    pub fn next_target(&self, forest: &DareForest, rng: &mut Rng) -> Option<InstanceId> {
        let live = forest.live_ids();
        if live.is_empty() {
            return None;
        }
        match self {
            Adversary::Random => Some(live[rng.index(live.len())]),
            Adversary::WorstOf(c) => {
                let c = (*c).max(1).min(live.len());
                let picks = rng.sample_indices(live.len(), c);
                let mut best: Option<(InstanceId, u64)> = None;
                for idx in picks {
                    let id = live[idx];
                    let cost = forest.delete_cost(id);
                    match best {
                        Some((_, bc)) if cost <= bc => {}
                        _ => best = Some((id, cost)),
                    }
                }
                best.map(|(id, _)| id)
            }
        }
    }

    /// Resolve a full deletion schedule of up to `count` targets against a
    /// clone of `base`, deleting as it goes (worst-of-c re-ranks against the
    /// *current* forest, exactly like a live adversary). A pure function of
    /// `(base, self, rng stream)` — same seed, same order — which is what
    /// lets the scenario harness compile adversarial scripts into a
    /// deterministic op stream (DESIGN.md §14).
    pub fn schedule(&self, base: &DareForest, count: usize, rng: &mut Rng) -> Vec<InstanceId> {
        let mut f = base.clone();
        let mut out = Vec::with_capacity(count);
        for _ in 0..count {
            let Some(id) = self.next_target(&f, rng) else {
                break;
            };
            f.delete_seq(id).expect("adversary picked a live id");
            out.push(id);
        }
        out
    }
}

impl std::str::FromStr for Adversary {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let l = s.to_ascii_lowercase();
        if l == "random" {
            return Ok(Adversary::Random);
        }
        if let Some(rest) = l.strip_prefix("worst_of_").or(l.strip_prefix("worst")) {
            let c = rest.trim_start_matches('_').parse::<usize>().unwrap_or(1000);
            return Ok(Adversary::WorstOf(c));
        }
        Err(format!("unknown adversary '{s}' (random|worst_of_<c>)"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};
    use crate::forest::params::Params;

    fn forest(n: usize) -> DareForest {
        let d = generate(
            &SynthSpec {
                n,
                informative: 3,
                redundant: 0,
                noise: 2,
                flip: 0.1,
                ..Default::default()
            },
            3,
        );
        DareForest::fit(
            d,
            &Params {
                n_trees: 3,
                max_depth: 5,
                k: 5,
                ..Default::default()
            },
            7,
        )
    }

    #[test]
    fn random_returns_live_ids() {
        let f = forest(100);
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            let id = Adversary::Random.next_target(&f, &mut rng).unwrap();
            assert!(f.data().is_alive(id));
        }
    }

    #[test]
    fn worst_of_prefers_expensive_deletions() {
        let f = forest(200);
        let mut rng = Rng::new(2);
        // Average dry-run cost of worst-of-32 picks should dominate random's.
        let mut worst_sum = 0u64;
        let mut rand_sum = 0u64;
        for _ in 0..15 {
            let wid = Adversary::WorstOf(32).next_target(&f, &mut rng).unwrap();
            worst_sum += f.delete_cost(wid);
            let rid = Adversary::Random.next_target(&f, &mut rng).unwrap();
            rand_sum += f.delete_cost(rid);
        }
        assert!(
            worst_sum >= rand_sum,
            "worst-of adversary should find costlier deletions ({worst_sum} vs {rand_sum})"
        );
    }

    #[test]
    fn same_seed_gives_identical_worst_of_schedules() {
        // Determinism contract (DESIGN.md §14): the deletion order is a pure
        // function of (forest, adversary, seed) — replaying the seed grid
        // must reproduce the schedule element-for-element, and a different
        // seed stream must be free to diverge.
        let f = forest(150);
        for seed in [1u64, 2, 3, 5, 8] {
            let a = Adversary::WorstOf(16).schedule(&f, 12, &mut Rng::new(seed));
            let b = Adversary::WorstOf(16).schedule(&f, 12, &mut Rng::new(seed));
            assert_eq!(a, b, "seed {seed}: schedule must be deterministic");
            assert_eq!(a.len(), 12);
            // Schedules never repeat a target (each pick is deleted).
            let mut sorted = a.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), a.len(), "seed {seed}: duplicate target");
            let r = Adversary::Random.schedule(&f, 12, &mut Rng::new(seed));
            assert_eq!(r, Adversary::Random.schedule(&f, 12, &mut Rng::new(seed)));
        }
    }

    #[test]
    fn worst_of_schedule_cost_dominates_random_across_seed_grid() {
        // Ranking: along the *evolving* forest (each pick deleted before the
        // next), the worst-of-c order's summed dry-run cost must dominate
        // the random adversary's on every seed of the pinned grid.
        let base = forest(200);
        let cost_of = |order: &[InstanceId]| -> u64 {
            let mut f = base.clone();
            let mut total = 0u64;
            for &id in order {
                total += f.delete_cost(id);
                f.delete_seq(id).unwrap();
            }
            total
        };
        let mut grid_worst = 0u64;
        let mut grid_rand = 0u64;
        for seed in [1u64, 2, 3, 5, 8] {
            let worst = Adversary::WorstOf(32).schedule(&base, 10, &mut Rng::new(seed));
            let rand = Adversary::Random.schedule(&base, 10, &mut Rng::new(seed ^ 0x9E37));
            let (wc, rc) = (cost_of(&worst), cost_of(&rand));
            assert!(
                wc >= rc,
                "seed {seed}: worst-of-32 sum {wc} fell below random {rc}"
            );
            grid_worst += wc;
            grid_rand += rc;
        }
        assert!(
            grid_worst > grid_rand,
            "worst-of must strictly dominate over the whole grid ({grid_worst} vs {grid_rand})"
        );
    }

    #[test]
    fn exhausted_forest_returns_none() {
        let mut f = forest(20);
        let ids = f.live_ids();
        for id in ids {
            f.delete_seq(id).unwrap();
        }
        let mut rng = Rng::new(3);
        assert!(Adversary::Random.next_target(&f, &mut rng).is_none());
        assert!(Adversary::WorstOf(10).next_target(&f, &mut rng).is_none());
    }

    #[test]
    fn parsing() {
        assert_eq!("random".parse::<Adversary>().unwrap(), Adversary::Random);
        assert_eq!(
            "worst_of_1000".parse::<Adversary>().unwrap(),
            Adversary::WorstOf(1000)
        );
        assert_eq!(
            "worst_of_50".parse::<Adversary>().unwrap(),
            Adversary::WorstOf(50)
        );
        assert!("x".parse::<Adversary>().is_err());
    }
}
