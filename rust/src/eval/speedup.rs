//! Deletion-efficiency measurement (paper §4.1): "the number of instances a
//! DaRE model deletes in the time it takes the naive retraining approach to
//! delete one instance". We time one naive retrain (fit from scratch on
//! n−1 instances), then stream deletions chosen by the adversary and count
//! how many fit in that budget. A deletion cap keeps CI-scale runs bounded;
//! when the cap is hit first, the count is extrapolated from the mean
//! per-deletion latency (reported separately).

use crate::data::dataset::Dataset;
use crate::eval::adversary::Adversary;
use crate::forest::forest::DareForest;
use crate::forest::params::Params;
use crate::metrics::Metric;
use crate::util::rng::Rng;
use crate::util::timer::time;

/// Result of one deletion-efficiency run.
#[derive(Clone, Debug)]
pub struct SpeedupResult {
    /// Wall time of one naive scratch retrain (seconds).
    pub naive_seconds: f64,
    /// Deletions actually executed.
    pub n_deleted: usize,
    /// Total wall time of those deletions.
    pub delete_seconds: f64,
    /// Deletions-per-naive-retrain (the paper's speedup; extrapolated when
    /// the cap ended the run before the budget was spent).
    pub speedup: f64,
    /// True when `speedup` was extrapolated from mean latency.
    pub extrapolated: bool,
    /// Mean seconds per deletion.
    pub mean_delete_seconds: f64,
    /// Test metric before any deletion.
    pub metric_before: f64,
    /// Test metric after the deletion stream.
    pub metric_after: f64,
    /// Retrained instances per tree-depth (Fig. 2 right).
    pub cost_by_depth: Vec<u64>,
    /// Total retrain events across the stream.
    pub retrain_events: usize,
}

/// Configuration for a speedup run.
#[derive(Clone, Debug)]
pub struct SpeedupConfig {
    pub adversary: Adversary,
    /// Hard cap on deletions (0 = only the time budget stops the run).
    pub max_deletions: usize,
    /// Evaluate the test metric before/after.
    pub metric: Metric,
    pub seed: u64,
}

impl Default for SpeedupConfig {
    fn default() -> Self {
        SpeedupConfig {
            adversary: Adversary::Random,
            max_deletions: 1000,
            metric: Metric::Accuracy,
            seed: 0,
        }
    }
}

/// Measure deletion efficiency of `params` on a train/test pair.
pub fn measure(
    train: &Dataset,
    test: &Dataset,
    params: &Params,
    cfg: &SpeedupConfig,
) -> SpeedupResult {
    let mut rng = Rng::new(crate::util::rng::mix_seed(&[cfg.seed, 0x5EED]));

    // --- naive retrain budget: fit from scratch on n-1 instances ----------
    // Single-threaded, matching the paper's protocol ("No parallelization is
    // used when building the independent decision trees", Appendix B) — the
    // deletion stream below is also single-threaded (delete_seq).
    let naive_params = Params {
        n_threads: 1,
        ..params.clone()
    };
    let mut reduced = train.clone();
    let some_id = reduced.live_ids()[0];
    reduced.mark_removed(some_id);
    let reduced = reduced.compacted();
    let (_, naive_seconds) = time(|| DareForest::fit(reduced, &naive_params, cfg.seed ^ 0xAA));

    // --- the model under test --------------------------------------------
    let mut forest = DareForest::fit(train.clone(), params, cfg.seed);
    let probs = forest.predict_proba_dataset(test);
    let (_, test_ys, _) = test.to_row_major();
    let metric_before = cfg.metric.score(&probs, &test_ys);

    // --- deletion stream ----------------------------------------------------
    let mut n_deleted = 0usize;
    let mut delete_seconds = 0.0f64;
    let mut cost_by_depth = vec![0u64; params.max_depth + 1];
    let mut retrain_events = 0usize;
    let cap = if cfg.max_deletions == 0 {
        usize::MAX
    } else {
        cfg.max_deletions
    };
    while delete_seconds < naive_seconds && n_deleted < cap && forest.n_alive() > 2 {
        // Adversary choice is *not* billed to deletion time (the paper
        // measures the unlearning operation itself).
        let Some(id) = cfg.adversary.next_target(&forest, &mut rng) else {
            break;
        };
        let (report, secs) = time(|| forest.delete_seq(id).expect("live id"));
        delete_seconds += secs;
        n_deleted += 1;
        retrain_events += report.retrain_events();
        for (d, c) in report.cost_by_depth(params.max_depth).iter().enumerate() {
            cost_by_depth[d] += c;
        }
    }

    let mean_delete_seconds = if n_deleted > 0 {
        delete_seconds / n_deleted as f64
    } else {
        f64::NAN
    };
    let extrapolated = delete_seconds < naive_seconds && n_deleted > 0;
    let speedup = if n_deleted == 0 {
        0.0
    } else if extrapolated {
        naive_seconds / mean_delete_seconds
    } else {
        n_deleted as f64
    };

    let probs = forest.predict_proba_dataset(test);
    let metric_after = cfg.metric.score(&probs, &test_ys);

    SpeedupResult {
        naive_seconds,
        n_deleted,
        delete_seconds,
        speedup,
        extrapolated,
        mean_delete_seconds,
        metric_before,
        metric_after,
        cost_by_depth,
        retrain_events,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::split::train_test;
    use crate::data::synth::{generate, SynthSpec};

    fn data() -> (Dataset, Dataset) {
        let all = generate(
            &SynthSpec {
                n: 700,
                informative: 4,
                redundant: 1,
                noise: 3,
                flip: 0.05,
                ..Default::default()
            },
            21,
        );
        train_test(&all, 0.8, 0)
    }

    #[test]
    fn speedup_reported_and_positive() {
        let (tr, te) = data();
        let params = Params {
            n_trees: 5,
            max_depth: 6,
            k: 5,
            ..Default::default()
        };
        let cfg = SpeedupConfig {
            max_deletions: 40,
            ..Default::default()
        };
        let r = measure(&tr, &te, &params, &cfg);
        assert!(r.naive_seconds > 0.0);
        assert!(r.n_deleted > 0);
        assert!(r.speedup > 1.0, "deletion should beat retraining: {}", r.speedup);
        assert!(r.metric_before > 0.6);
        assert!((r.metric_after - r.metric_before).abs() < 0.2);
        assert_eq!(r.cost_by_depth.len(), 7);
    }

    #[test]
    fn rdare_speedup_at_least_gdare() {
        let (tr, te) = data();
        let g = Params {
            n_trees: 5,
            max_depth: 6,
            k: 5,
            d_rmax: 0,
            ..Default::default()
        };
        let r = Params { d_rmax: 3, ..g.clone() };
        let cfg = SpeedupConfig {
            max_deletions: 60,
            ..Default::default()
        };
        let sg = measure(&tr, &te, &g, &cfg);
        let sr = measure(&tr, &te, &r, &cfg);
        // random upper layers should not make deletion *slower* (allow noise)
        assert!(
            sr.mean_delete_seconds < sg.mean_delete_seconds * 1.6,
            "R-DaRE {} vs G-DaRE {}",
            sr.mean_delete_seconds,
            sg.mean_delete_seconds
        );
    }

    #[test]
    fn worst_adversary_costs_more() {
        let (tr, te) = data();
        let params = Params {
            n_trees: 5,
            max_depth: 6,
            k: 5,
            ..Default::default()
        };
        let rnd = measure(
            &tr,
            &te,
            &params,
            &SpeedupConfig {
                adversary: Adversary::Random,
                max_deletions: 30,
                ..Default::default()
            },
        );
        let worst = measure(
            &tr,
            &te,
            &params,
            &SpeedupConfig {
                adversary: Adversary::WorstOf(64),
                max_deletions: 30,
                ..Default::default()
            },
        );
        // Both streams mutate independent forests, so at 30 deletions the
        // comparison is noisy; the precise monotonicity check lives in
        // eval::adversary::tests. Here we only guard against the adversary
        // being *broken* (dramatically cheaper than random).
        let rnd_cost: u64 = rnd.cost_by_depth.iter().sum();
        let worst_cost: u64 = worst.cost_by_depth.iter().sum();
        assert!(
            2 * worst_cost >= rnd_cost,
            "worst-of adversary should not be far cheaper than random ({worst_cost} vs {rnd_cost})"
        );
    }
}
