//! Train/test splits and cross-validation folds (the paper uses an 80/20
//! split and 5-fold CV for tuning).

use crate::data::dataset::{Dataset, InstanceId};
use crate::util::rng::Rng;

/// Random train/test split by fraction (paper: 80% train).
pub fn train_test(data: &Dataset, train_frac: f64, seed: u64) -> (Dataset, Dataset) {
    let mut ids = data.live_ids();
    let mut rng = Rng::new(crate::util::rng::mix_seed(&[seed, 0x7e57]));
    rng.shuffle(&mut ids);
    let n_train = ((ids.len() as f64) * train_frac).round() as usize;
    let n_train = n_train.clamp(1, ids.len().saturating_sub(1).max(1));
    let (tr, te) = ids.split_at(n_train.min(ids.len()));
    (data.subset(tr), data.subset(te))
}

/// Stratified K-fold indices: returns `k` (train_ids, valid_ids) pairs with
/// class balance preserved per fold, as scikit-learn's StratifiedKFold does
/// (the paper tunes with 5-fold CV on imbalanced data, so stratification
/// matters for the AP/AUC datasets).
pub fn stratified_kfold(
    data: &Dataset,
    k: usize,
    seed: u64,
) -> Vec<(Vec<InstanceId>, Vec<InstanceId>)> {
    assert!(k >= 2, "k-fold needs k >= 2");
    let mut rng = Rng::new(crate::util::rng::mix_seed(&[seed, 0xf01d]));
    let mut pos: Vec<InstanceId> = Vec::new();
    let mut neg: Vec<InstanceId> = Vec::new();
    for id in data.live_ids() {
        if data.y(id) == 1 {
            pos.push(id);
        } else {
            neg.push(id);
        }
    }
    rng.shuffle(&mut pos);
    rng.shuffle(&mut neg);

    // round-robin assignment to folds keeps per-fold class counts within 1
    let mut folds: Vec<Vec<InstanceId>> = vec![Vec::new(); k];
    for (i, &id) in pos.iter().enumerate() {
        folds[i % k].push(id);
    }
    for (i, &id) in neg.iter().enumerate() {
        folds[i % k].push(id);
    }

    (0..k)
        .map(|f| {
            let valid = folds[f].clone();
            let train: Vec<InstanceId> = (0..k)
                .filter(|&g| g != f)
                .flat_map(|g| folds[g].iter().copied())
                .collect();
            (train, valid)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};

    fn toy(n: usize, pos: f64) -> Dataset {
        generate(
            &SynthSpec {
                n,
                pos_fraction: pos,
                flip: 0.0,
                ..Default::default()
            },
            42,
        )
    }

    #[test]
    fn split_sizes() {
        let d = toy(1000, 0.3);
        let (tr, te) = train_test(&d, 0.8, 1);
        assert_eq!(tr.n_total(), 800);
        assert_eq!(te.n_total(), 200);
        assert_eq!(tr.n_features(), d.n_features());
    }

    #[test]
    fn split_deterministic_and_seed_sensitive() {
        let d = toy(500, 0.5);
        let (a, _) = train_test(&d, 0.8, 9);
        let (b, _) = train_test(&d, 0.8, 9);
        let (c, _) = train_test(&d, 0.8, 10);
        assert_eq!(a.col(0), b.col(0));
        assert_ne!(a.col(0), c.col(0));
    }

    #[test]
    fn kfold_partitions_everything() {
        let d = toy(503, 0.25);
        let folds = stratified_kfold(&d, 5, 3);
        assert_eq!(folds.len(), 5);
        let mut all_valid: Vec<u32> = folds.iter().flat_map(|(_, v)| v.clone()).collect();
        all_valid.sort_unstable();
        let mut expect = d.live_ids();
        expect.sort_unstable();
        assert_eq!(all_valid, expect, "valid folds partition the data");
        for (tr, va) in &folds {
            assert_eq!(tr.len() + va.len(), d.n_total());
            // no overlap
            for id in va {
                assert!(!tr.contains(id));
            }
        }
    }

    #[test]
    fn kfold_is_stratified() {
        let d = toy(1000, 0.1);
        let total_pos = d.n_pos_alive();
        for (_, valid) in stratified_kfold(&d, 5, 7) {
            let pos = valid.iter().filter(|&&i| d.y(i) == 1).count();
            let expected = total_pos as f64 / 5.0;
            assert!(
                (pos as f64 - expected).abs() <= 1.0,
                "fold pos {pos} vs expected {expected}"
            );
        }
    }
}
