//! Synthetic dataset generation.
//!
//! The paper evaluates on 13 public datasets plus one synthetic dataset. The
//! public data is not available in this offline image, so each dataset is
//! *simulated* by a generator that reproduces the characteristics DaRE's
//! behaviour actually depends on (DESIGN.md §2): instance count `n`, post-
//! one-hot attribute count `p`, positive-label rate, the numeric/one-hot/
//! binary attribute mix, and learnable (but noisy) class structure.
//!
//! The generator follows the scikit-learn `make_classification` recipe the
//! paper itself uses for its Synthetic dataset: class-conditional Gaussian
//! clusters at hypercube vertices for informative features, random linear
//! combinations for redundant features, pure noise features, plus categorical
//! latents (class-correlated multinomials) that are one-hot encoded, and a
//! label-flip rate.

use crate::data::dataset::Dataset;
use crate::util::rng::Rng;

/// Specification of a synthetic binary-classification dataset.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    /// Total instances to generate.
    pub n: usize,
    /// Informative numeric attributes (class-separating).
    pub informative: usize,
    /// Redundant numeric attributes (linear combos of informative).
    pub redundant: usize,
    /// Pure-noise numeric attributes.
    pub noise: usize,
    /// Cardinalities of categorical attributes; each is one-hot encoded into
    /// `card` binary columns (mirroring the paper's preprocessing).
    pub categorical: Vec<usize>,
    /// Target positive-label fraction (class prior).
    pub pos_fraction: f64,
    /// Fraction of labels flipped after generation (task difficulty).
    pub flip: f64,
    /// Gaussian clusters per class (hypercube vertices).
    pub clusters_per_class: usize,
    /// Class separation multiplier (distance between cluster centers).
    pub class_sep: f64,
}

impl Default for SynthSpec {
    fn default() -> Self {
        SynthSpec {
            n: 1000,
            informative: 5,
            redundant: 5,
            noise: 30,
            categorical: Vec::new(),
            pos_fraction: 0.5,
            flip: 0.05,
            clusters_per_class: 2,
            class_sep: 1.0,
        }
    }
}

impl SynthSpec {
    /// Post-one-hot attribute count.
    pub fn p_total(&self) -> usize {
        self.informative + self.redundant + self.noise + self.categorical.iter().sum::<usize>()
    }
}

/// Generate a dataset from a spec, deterministically from `seed`.
pub fn generate(spec: &SynthSpec, seed: u64) -> Dataset {
    let mut rng = Rng::new(crate::util::rng::mix_seed(&[seed, 0x5E17]));
    let n = spec.n;
    let ni = spec.informative.max(1);

    // --- labels from the class prior -------------------------------------
    // Compensate the prior for the label-flip noise applied later so the
    // *observed* positive rate matches the spec: obs = q(1-f) + (1-q)f.
    let f = spec.flip.min(0.49);
    let q = ((spec.pos_fraction - f) / (1.0 - 2.0 * f)).clamp(0.0, 1.0);
    let mut labels: Vec<u8> = (0..n).map(|_| rng.bernoulli(q) as u8).collect();
    // Guarantee both classes exist for non-degenerate training.
    if n >= 2 {
        if labels.iter().all(|&y| y == 1) {
            labels[0] = 0;
        }
        if labels.iter().all(|&y| y == 0) {
            labels[0] = 1;
        }
    }

    // --- cluster centers at hypercube vertices ---------------------------
    // 2 classes × clusters_per_class centers in R^informative.
    let n_clusters = 2 * spec.clusters_per_class.max(1);
    let mut centers = Vec::with_capacity(n_clusters);
    for c in 0..n_clusters {
        let mut v = Vec::with_capacity(ni);
        for j in 0..ni {
            // Vertex coordinate: deterministic pseudo-random ±1 pattern per
            // (cluster, dim), scaled by class_sep.
            let bit = (crate::util::rng::mix_seed(&[seed, c as u64, j as u64]) >> 17) & 1;
            v.push(if bit == 1 { spec.class_sep } else { -spec.class_sep });
        }
        centers.push(v);
    }

    // --- informative features --------------------------------------------
    // cluster assignment: label selects among its class's clusters.
    let mut cols: Vec<Vec<f32>> = Vec::with_capacity(spec.p_total());
    let mut info_cols: Vec<Vec<f32>> = vec![Vec::with_capacity(n); ni];
    for i in 0..n {
        let class = labels[i] as usize;
        let cluster = class * spec.clusters_per_class + rng.index(spec.clusters_per_class.max(1));
        for (j, col) in info_cols.iter_mut().enumerate() {
            col.push((centers[cluster][j] + rng.normal()) as f32);
        }
    }

    // --- redundant features: random linear combos of informative ----------
    let mut red_cols: Vec<Vec<f32>> = Vec::with_capacity(spec.redundant);
    for _ in 0..spec.redundant {
        let w: Vec<f64> = (0..ni).map(|_| rng.normal()).collect();
        let mut col = Vec::with_capacity(n);
        for i in 0..n {
            let v: f64 = (0..ni).map(|j| w[j] * info_cols[j][i] as f64).sum();
            col.push(v as f32);
        }
        red_cols.push(col);
    }

    // --- noise features -----------------------------------------------------
    let mut noise_cols: Vec<Vec<f32>> = Vec::with_capacity(spec.noise);
    for _ in 0..spec.noise {
        noise_cols.push((0..n).map(|_| rng.normal() as f32).collect());
    }

    // --- categorical features (one-hot) -----------------------------------
    // Each categorical attribute has class-correlated category probabilities:
    // category c gets weight ~ Dirichlet-ish noise, shifted by class so trees
    // can exploit it (mirrors real categorical signal like "job" in Bank Mktg).
    let mut cat_cols: Vec<Vec<f32>> = Vec::new();
    for (g, &card) in spec.categorical.iter().enumerate() {
        let card = card.max(2);
        // class-conditional category weights
        let mut w0: Vec<f64> = (0..card).map(|_| rng.f64() + 0.2).collect();
        let mut w1: Vec<f64> = w0
            .iter()
            .map(|&w| (w * (0.5 + rng.f64())).max(0.05))
            .collect();
        let s0: f64 = w0.iter().sum();
        let s1: f64 = w1.iter().sum();
        for w in w0.iter_mut() {
            *w /= s0;
        }
        for w in w1.iter_mut() {
            *w /= s1;
        }
        let base = cat_cols.len();
        for _ in 0..card {
            cat_cols.push(vec![0.0; n]);
        }
        for i in 0..n {
            let w = if labels[i] == 1 { &w1 } else { &w0 };
            let mut u = rng.f64();
            let mut c = card - 1;
            for (k, &wk) in w.iter().enumerate() {
                if u < wk {
                    c = k;
                    break;
                }
                u -= wk;
            }
            cat_cols[base + c][i] = 1.0;
        }
        let _ = g;
    }

    // --- label flips ---------------------------------------------------------
    if spec.flip > 0.0 {
        for y in labels.iter_mut() {
            if rng.bernoulli(spec.flip) {
                *y ^= 1;
            }
        }
    }

    cols.extend(info_cols);
    cols.extend(red_cols);
    cols.extend(noise_cols);
    cols.extend(cat_cols);
    Dataset::from_columns(cols, labels)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_matches_spec() {
        let spec = SynthSpec {
            n: 500,
            informative: 3,
            redundant: 2,
            noise: 4,
            categorical: vec![3, 5],
            pos_fraction: 0.3,
            flip: 0.0,
            ..Default::default()
        };
        let d = generate(&spec, 1);
        assert_eq!(d.n_total(), 500);
        assert_eq!(d.n_features(), spec.p_total());
        assert_eq!(spec.p_total(), 3 + 2 + 4 + 8);
    }

    #[test]
    fn pos_fraction_approximate() {
        let spec = SynthSpec {
            n: 20_000,
            pos_fraction: 0.2,
            flip: 0.0,
            ..Default::default()
        };
        let d = generate(&spec, 2);
        let f = d.pos_fraction();
        assert!((f - 0.2).abs() < 0.02, "pos fraction {f}");
    }

    #[test]
    fn deterministic_per_seed() {
        let spec = SynthSpec {
            n: 200,
            ..Default::default()
        };
        let a = generate(&spec, 7);
        let b = generate(&spec, 7);
        let c = generate(&spec, 8);
        assert_eq!(a.col(0), b.col(0));
        assert_ne!(a.col(0), c.col(0));
    }

    #[test]
    fn one_hot_columns_are_binary_and_exclusive() {
        let spec = SynthSpec {
            n: 300,
            informative: 2,
            redundant: 0,
            noise: 0,
            categorical: vec![4],
            flip: 0.0,
            ..Default::default()
        };
        let d = generate(&spec, 3);
        let base = 2;
        for i in 0..300u32 {
            let s: f32 = (0..4).map(|k| d.x(i, base + k)).sum();
            assert_eq!(s, 1.0, "one-hot exactly one set");
        }
    }

    #[test]
    fn informative_features_separate_classes() {
        // Sanity: the mean of informative feature 0 should differ by class.
        let spec = SynthSpec {
            n: 5_000,
            informative: 4,
            redundant: 0,
            noise: 0,
            flip: 0.0,
            class_sep: 2.0,
            clusters_per_class: 1,
            ..Default::default()
        };
        let d = generate(&spec, 4);
        let (mut m0, mut c0, mut m1, mut c1) = (0.0f64, 0, 0.0f64, 0);
        for i in 0..d.n_total() as u32 {
            if d.y(i) == 1 {
                m1 += d.x(i, 0) as f64;
                c1 += 1;
            } else {
                m0 += d.x(i, 0) as f64;
                c0 += 1;
            }
        }
        let gap = (m1 / c1 as f64 - m0 / c0 as f64).abs();
        assert!(gap > 0.5, "class means should separate, gap={gap}");
    }

    #[test]
    fn both_classes_present_even_extreme_prior() {
        let spec = SynthSpec {
            n: 50,
            pos_fraction: 0.0001,
            flip: 0.0,
            ..Default::default()
        };
        let d = generate(&spec, 5);
        assert!(d.n_pos_alive() >= 1);
        assert!(d.n_pos_alive() < d.n_alive());
    }
}
