//! CSV load/save so users can bring their own data (`dare train --csv ...`).
//! Format: header row optional; last column is the 0/1 label.

use crate::data::dataset::Dataset;
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// Load a dataset from CSV. If the first row fails numeric parsing it is
/// treated as a header and skipped. Last column = binary label.
pub fn load_csv(path: &Path) -> anyhow::Result<Dataset> {
    let file = std::fs::File::open(path)?;
    let reader = std::io::BufReader::new(file);
    let mut rows: Vec<Vec<f32>> = Vec::new();
    let mut labels: Vec<u8> = Vec::new();
    let mut arity: Option<usize> = None;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let fields: Vec<&str> = t.split(',').map(|f| f.trim()).collect();
        if fields.len() < 2 {
            anyhow::bail!("line {}: need at least one feature + label", lineno + 1);
        }
        let parsed: Result<Vec<f32>, _> = fields.iter().map(|f| f.parse::<f32>()).collect();
        match parsed {
            Err(_) if rows.is_empty() && labels.is_empty() => continue, // header
            Err(e) => anyhow::bail!("line {}: parse error: {e}", lineno + 1),
            Ok(vals) => {
                if let Some(a) = arity {
                    if vals.len() != a {
                        anyhow::bail!(
                            "line {}: expected {} columns, got {}",
                            lineno + 1,
                            a,
                            vals.len()
                        );
                    }
                } else {
                    arity = Some(vals.len());
                }
                let y = *vals.last().unwrap();
                if y != 0.0 && y != 1.0 {
                    anyhow::bail!("line {}: label must be 0 or 1, got {y}", lineno + 1);
                }
                labels.push(y as u8);
                rows.push(vals[..vals.len() - 1].to_vec());
            }
        }
    }
    if rows.is_empty() {
        anyhow::bail!("no data rows in {}", path.display());
    }
    Ok(Dataset::from_rows(&rows, labels))
}

/// Save the live subset of a dataset as CSV (features then label).
pub fn save_csv(data: &Dataset, path: &Path) -> anyhow::Result<()> {
    let file = std::fs::File::create(path)?;
    let mut w = BufWriter::new(file);
    let p = data.n_features();
    for j in 0..p {
        write!(w, "f{j},")?;
    }
    writeln!(w, "label")?;
    for id in data.live_ids() {
        for j in 0..p {
            write!(w, "{},", data.x(id, j))?;
        }
        writeln!(w, "{}", data.y(id))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let d = Dataset::from_rows(
            &[vec![1.5, 2.0], vec![-3.0, 0.25], vec![0.0, 9.0]],
            vec![1, 0, 1],
        );
        let tmp = std::env::temp_dir().join("dare_io_test.csv");
        save_csv(&d, &tmp).unwrap();
        let back = load_csv(&tmp).unwrap();
        assert_eq!(back.n_total(), 3);
        assert_eq!(back.n_features(), 2);
        assert_eq!(back.x(1, 0), -3.0);
        assert_eq!(back.y(2), 1);
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn headerless_and_comments() {
        let tmp = std::env::temp_dir().join("dare_io_test2.csv");
        std::fs::write(&tmp, "# comment\n1.0,2.0,0\n3.0,4.0,1\n\n").unwrap();
        let d = load_csv(&tmp).unwrap();
        assert_eq!(d.n_total(), 2);
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn rejects_bad_labels_and_ragged() {
        let tmp = std::env::temp_dir().join("dare_io_test3.csv");
        std::fs::write(&tmp, "1.0,2.0,5\n").unwrap();
        assert!(load_csv(&tmp).is_err());
        std::fs::write(&tmp, "1.0,2.0,1\n1.0,1\n").unwrap();
        assert!(load_csv(&tmp).is_err());
        std::fs::write(&tmp, "").unwrap();
        assert!(load_csv(&tmp).is_err());
        std::fs::remove_file(&tmp).ok();
    }
}
