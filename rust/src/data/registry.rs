//! The 14-dataset corpus of the paper's evaluation (Table 1 / Table 4),
//! realized as synthetic generators (see DESIGN.md §2 for the substitution
//! argument). Each entry reproduces the paper's instance count, post-one-hot
//! attribute count, positive-label rate, attribute mix, and carries the
//! hyperparameters the paper selected (Table 6: Gini, Table 8: entropy).

use crate::data::dataset::Dataset;
use crate::data::synth::{generate, SynthSpec};
use crate::metrics::Metric;

/// Hyperparameters chosen by the paper's tuning protocol for one dataset.
#[derive(Clone, Copy, Debug)]
pub struct PaperParams {
    /// Number of trees (T).
    pub n_trees: usize,
    /// Maximum depth (d_max).
    pub max_depth: usize,
    /// Thresholds per attribute at greedy nodes (k).
    pub k: usize,
    /// d_rmax at error tolerances 0.1%, 0.25%, 0.5%, 1.0%.
    pub drmax: [usize; 4],
}

/// One dataset of the corpus.
#[derive(Clone, Debug)]
pub struct DatasetInfo {
    pub name: &'static str,
    /// Paper's instance count (train+test).
    pub n_paper: usize,
    /// Paper's post-one-hot attribute count.
    pub p: usize,
    /// Paper's positive-label percentage.
    pub pos_pct: f64,
    /// Paper's chosen predictive-performance metric.
    pub metric: Metric,
    /// Paper's tuned hyperparameters with Gini (Table 6).
    pub gini: PaperParams,
    /// Paper's tuned hyperparameters with entropy (Table 8).
    pub entropy: PaperParams,
    /// Generator recipe (numeric + categorical composition).
    spec: SynthSpec,
}

impl DatasetInfo {
    /// Generate the dataset at `1/scale_div` of the paper's size (min 800
    /// instances so folds stay meaningful). `scale_div = 1` reproduces the
    /// paper's n exactly.
    pub fn generate(&self, scale_div: usize, seed: u64) -> Dataset {
        let mut spec = self.spec.clone();
        spec.n = (self.n_paper / scale_div.max(1)).max(800);
        let d = generate(&spec, crate::util::rng::mix_seed(&[seed, hash_name(self.name)]));
        debug_assert_eq!(d.n_features(), self.p, "{}: p mismatch", self.name);
        d
    }

    /// The generator spec (exposed for tests / docs).
    pub fn spec(&self) -> &SynthSpec {
        &self.spec
    }
}

fn hash_name(name: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[allow(clippy::too_many_arguments)]
fn spec(
    n: usize,
    informative: usize,
    redundant: usize,
    noise: usize,
    categorical: Vec<usize>,
    pos_fraction: f64,
    flip: f64,
    class_sep: f64,
) -> SynthSpec {
    SynthSpec {
        n,
        informative,
        redundant,
        noise,
        categorical,
        pos_fraction,
        flip,
        clusters_per_class: 2,
        class_sep,
    }
}

fn pp(n_trees: usize, max_depth: usize, k: usize, drmax: [usize; 4]) -> PaperParams {
    PaperParams {
        n_trees,
        max_depth,
        k,
        drmax,
    }
}

/// The full corpus, in the paper's Table 1 order.
pub fn corpus() -> Vec<DatasetInfo> {
    vec![
        DatasetInfo {
            name: "surgical",
            n_paper: 14_635,
            p: 90,
            pos_pct: 25.2,
            metric: Metric::Accuracy,
            gini: pp(100, 20, 25, [0, 1, 2, 4]),
            entropy: pp(100, 20, 50, [1, 1, 2, 4]),
            spec: spec(0, 5, 3, 16, vec![10, 10, 10, 12, 12, 12], 0.252, 0.08, 1.2),
        },
        DatasetInfo {
            name: "vaccine",
            n_paper: 26_707,
            p: 185,
            pos_pct: 46.4,
            metric: Metric::Accuracy,
            gini: pp(50, 20, 5, [5, 7, 11, 14]),
            entropy: pp(250, 20, 5, [6, 9, 11, 15]),
            spec: spec(0, 3, 1, 1, vec![5; 36], 0.464, 0.15, 0.9),
        },
        DatasetInfo {
            name: "adult",
            n_paper: 48_842,
            p: 107,
            pos_pct: 23.9,
            metric: Metric::Accuracy,
            gini: pp(50, 20, 5, [10, 13, 14, 16]),
            entropy: pp(50, 20, 5, [9, 12, 14, 15]),
            spec: spec(
                0,
                3,
                1,
                2,
                vec![7, 16, 7, 14, 6, 5, 2, 41, 3],
                0.239,
                0.10,
                1.0,
            ),
        },
        DatasetInfo {
            name: "bank_marketing",
            n_paper: 41_188,
            p: 63,
            pos_pct: 11.3,
            metric: Metric::Auc,
            gini: pp(100, 20, 25, [6, 9, 12, 14]),
            entropy: pp(100, 10, 10, [1, 1, 3, 4]),
            spec: spec(
                0,
                4,
                2,
                4,
                vec![12, 3, 4, 8, 3, 2, 3, 5, 10, 3],
                0.113,
                0.06,
                1.1,
            ),
        },
        DatasetInfo {
            name: "flight_delays",
            n_paper: 100_000,
            p: 648,
            pos_pct: 19.0,
            metric: Metric::Auc,
            gini: pp(250, 20, 25, [1, 3, 5, 10]),
            entropy: pp(250, 20, 50, [1, 3, 5, 10]),
            spec: spec(0, 2, 1, 1, vec![300, 300, 20, 12, 7, 5], 0.19, 0.10, 0.9),
        },
        DatasetInfo {
            name: "diabetes",
            n_paper: 101_766,
            p: 253,
            pos_pct: 46.1,
            metric: Metric::Accuracy,
            gini: pp(250, 20, 5, [7, 10, 12, 15]),
            entropy: pp(100, 20, 5, [4, 10, 11, 14]),
            spec: spec(0, 5, 3, 5, vec![10; 24], 0.461, 0.22, 0.7),
        },
        DatasetInfo {
            name: "no_show",
            n_paper: 110_527,
            p: 99,
            pos_pct: 20.2,
            metric: Metric::Auc,
            gini: pp(250, 20, 10, [1, 3, 6, 10]),
            entropy: pp(250, 20, 10, [1, 3, 6, 9]),
            spec: spec(0, 4, 2, 3, vec![80, 7, 3], 0.202, 0.14, 0.8),
        },
        DatasetInfo {
            name: "olympics",
            n_paper: 206_165,
            p: 1_004,
            pos_pct: 14.6,
            metric: Metric::Auc,
            gini: pp(250, 20, 5, [0, 1, 2, 3]),
            entropy: pp(250, 20, 5, [0, 1, 2, 4]),
            spec: spec(0, 2, 1, 1, vec![200, 230, 500, 50, 20], 0.146, 0.08, 1.0),
        },
        DatasetInfo {
            name: "census",
            n_paper: 299_285,
            p: 408,
            pos_pct: 6.2,
            metric: Metric::Auc,
            gini: pp(100, 20, 25, [6, 9, 12, 16]),
            entropy: pp(100, 20, 25, [5, 8, 11, 15]),
            spec: spec(0, 4, 2, 2, vec![50; 8], 0.062, 0.03, 1.2),
        },
        DatasetInfo {
            name: "credit_card",
            n_paper: 284_807,
            p: 29,
            pos_pct: 0.2,
            metric: Metric::AveragePrecision,
            gini: pp(250, 20, 5, [5, 8, 14, 17]),
            entropy: pp(250, 10, 25, [1, 2, 3, 4]),
            spec: spec(0, 6, 6, 17, vec![], 0.002, 0.0005, 2.0),
        },
        DatasetInfo {
            name: "ctr",
            n_paper: 1_000_000,
            p: 13,
            pos_pct: 2.9,
            metric: Metric::Auc,
            gini: pp(100, 10, 50, [2, 3, 4, 6]),
            entropy: pp(100, 10, 25, [2, 3, 4, 6]),
            spec: spec(0, 4, 3, 6, vec![], 0.029, 0.01, 1.0),
        },
        DatasetInfo {
            name: "twitter",
            n_paper: 1_000_000,
            p: 15,
            pos_pct: 17.0,
            metric: Metric::Auc,
            gini: pp(100, 20, 5, [2, 4, 7, 11]),
            entropy: pp(100, 20, 5, [3, 5, 8, 11]),
            spec: spec(0, 5, 3, 7, vec![], 0.17, 0.05, 1.3),
        },
        DatasetInfo {
            name: "synthetic",
            n_paper: 1_000_000,
            p: 40,
            pos_pct: 50.0,
            metric: Metric::Accuracy,
            gini: pp(50, 20, 10, [0, 2, 3, 5]),
            entropy: pp(50, 20, 10, [1, 2, 3, 6]),
            // Exactly the paper's recipe: 5 informative, 5 redundant, 30
            // useless, 2 clusters/class, 5% label flips.
            spec: spec(0, 5, 5, 30, vec![], 0.5, 0.05, 1.0),
        },
        DatasetInfo {
            name: "higgs",
            n_paper: 11_000_000,
            p: 28,
            pos_pct: 53.0,
            metric: Metric::Accuracy,
            gini: pp(50, 20, 10, [1, 3, 6, 9]),
            entropy: pp(50, 20, 10, [0, 2, 5, 8]),
            spec: spec(0, 8, 7, 13, vec![], 0.53, 0.18, 0.6),
        },
    ]
}

/// Look up a dataset by name (case-insensitive, hyphens/underscores folded).
pub fn find(name: &str) -> Option<DatasetInfo> {
    let norm: String = name
        .chars()
        .filter(|c| c.is_ascii_alphanumeric())
        .map(|c| c.to_ascii_lowercase())
        .collect();
    corpus().into_iter().find(|d| {
        d.name
            .chars()
            .filter(|c| c.is_ascii_alphanumeric())
            .collect::<String>()
            == norm
    })
}

/// The paper's metric-selection rule (§4): AP when positives < 1%, AUC in
/// [1%, 20%], accuracy otherwise. The registry stores the paper's explicit
/// per-dataset choice (No Show sits at 20.2% but uses AUC in Table 1).
pub fn metric_rule(pos_pct: f64) -> Metric {
    if pos_pct < 1.0 {
        Metric::AveragePrecision
    } else if pos_pct <= 20.0 {
        Metric::Auc
    } else {
        Metric::Accuracy
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_has_14_entries_matching_table1() {
        let c = corpus();
        assert_eq!(c.len(), 14);
        // every generator recipe matches the paper's p exactly
        for d in &c {
            assert_eq!(d.spec.p_total(), d.p, "{}", d.name);
        }
        // spot-check table 1 rows
        let higgs = find("higgs").unwrap();
        assert_eq!(higgs.n_paper, 11_000_000);
        assert_eq!(higgs.p, 28);
        let cc = find("credit_card").unwrap();
        assert_eq!(cc.metric, Metric::AveragePrecision);
    }

    #[test]
    fn generation_matches_spec_shape() {
        for d in corpus() {
            let ds = d.generate(1000, 0);
            assert_eq!(ds.n_features(), d.p, "{}", d.name);
            assert!(ds.n_total() >= 800);
            // positive rate within tolerance of the paper's rate (coarser
            // tolerance at small n for the rare-positive datasets)
            let got = ds.pos_fraction() * 100.0;
            let want = d.pos_pct;
            let tol = (want * 0.5).max(1.5);
            assert!(
                (got - want).abs() < tol,
                "{}: pos% {got:.2} vs paper {want:.2}",
                d.name
            );
        }
    }

    #[test]
    fn find_normalizes_names() {
        assert!(find("Bank-Marketing").is_some());
        assert!(find("BANK_MARKETING").is_some());
        assert!(find("nope").is_none());
    }

    #[test]
    fn metric_rule_matches_paper_bands() {
        assert_eq!(metric_rule(0.2), Metric::AveragePrecision);
        assert_eq!(metric_rule(11.3), Metric::Auc);
        assert_eq!(metric_rule(25.2), Metric::Accuracy);
        assert_eq!(metric_rule(53.0), Metric::Accuracy);
    }

    #[test]
    fn paper_params_spot_check_table6() {
        let bm = find("bank_marketing").unwrap();
        assert_eq!(bm.gini.n_trees, 100);
        assert_eq!(bm.gini.max_depth, 20);
        assert_eq!(bm.gini.k, 25);
        assert_eq!(bm.gini.drmax, [6, 9, 12, 14]);
        let ctr = find("ctr").unwrap();
        assert_eq!(ctr.gini.max_depth, 10);
        assert_eq!(ctr.gini.k, 50);
        // entropy table 8 spot check
        let surgical = find("surgical").unwrap();
        assert_eq!(surgical.entropy.k, 50);
    }
}
