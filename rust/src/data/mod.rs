//! Data substrate: storage, the paper's 14-dataset corpus (synthetic
//! generators), splits/folds, and CSV I/O.

pub mod dataset;
pub mod io;
pub mod registry;
pub mod split;
pub mod synth;

pub use dataset::{Dataset, InstanceId};
pub use registry::{corpus, find, DatasetInfo};
pub use synth::{generate, SynthSpec};
