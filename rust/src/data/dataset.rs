//! Dataset storage.
//!
//! Column-major `f32` feature storage plus `u8` binary labels, with a
//! liveness mask so deletions are O(1) "remove from database" operations
//! (Alg. 2 line 6/18). Trees reference instances by stable `u32` ids; ids are
//! never recycled while a dataset is alive, so leaf instance lists stay valid
//! across deletions and additions (§6 continual learning).

/// Stable instance identifier (index into the dataset's backing columns).
pub type InstanceId = u32;

#[derive(Clone, Debug)]
pub struct Dataset {
    /// Column-major features: `cols[j][i]` is attribute j of instance i.
    cols: Vec<Vec<f32>>,
    /// Binary labels (0/1).
    labels: Vec<u8>,
    /// Liveness mask: false once deleted.
    alive: Vec<bool>,
    n_alive: usize,
    n_pos_alive: usize,
}

impl Dataset {
    /// Build from column-major data. All columns must share a length.
    pub fn from_columns(cols: Vec<Vec<f32>>, labels: Vec<u8>) -> Self {
        let n = labels.len();
        for (j, c) in cols.iter().enumerate() {
            assert_eq!(c.len(), n, "column {j} length mismatch");
        }
        assert!(labels.iter().all(|&y| y <= 1), "labels must be binary");
        let n_pos = labels.iter().filter(|&&y| y == 1).count();
        Dataset {
            cols,
            alive: vec![true; n],
            n_alive: n,
            n_pos_alive: n_pos,
            labels,
        }
    }

    /// Build from row-major data (`rows[i][j]`).
    pub fn from_rows(rows: &[Vec<f32>], labels: Vec<u8>) -> Self {
        let n = rows.len();
        assert_eq!(n, labels.len());
        let p = rows.first().map(|r| r.len()).unwrap_or(0);
        let mut cols = vec![Vec::with_capacity(n); p];
        for row in rows {
            assert_eq!(row.len(), p, "ragged rows");
            for (j, &v) in row.iter().enumerate() {
                cols[j].push(v);
            }
        }
        Dataset::from_columns(cols, labels)
    }

    /// Empty dataset with `p` attributes.
    pub fn empty(p: usize) -> Self {
        Dataset {
            cols: vec![Vec::new(); p],
            labels: Vec::new(),
            alive: Vec::new(),
            n_alive: 0,
            n_pos_alive: 0,
        }
    }

    /// Number of attributes.
    #[inline]
    pub fn n_features(&self) -> usize {
        self.cols.len()
    }

    /// Total instances ever inserted (including deleted ones).
    #[inline]
    pub fn n_total(&self) -> usize {
        self.labels.len()
    }

    /// Currently-live instances.
    #[inline]
    pub fn n_alive(&self) -> usize {
        self.n_alive
    }

    /// Currently-live positive instances.
    #[inline]
    pub fn n_pos_alive(&self) -> usize {
        self.n_pos_alive
    }

    /// Feature value (caller must pass a valid id; deleted rows still readable
    /// — trees read values mid-deletion).
    #[inline]
    pub fn x(&self, i: InstanceId, j: usize) -> f32 {
        self.cols[j][i as usize]
    }

    /// Label of instance `i`.
    #[inline]
    pub fn y(&self, i: InstanceId) -> u8 {
        self.labels[i as usize]
    }

    #[inline]
    pub fn is_alive(&self, i: InstanceId) -> bool {
        self.alive[i as usize]
    }

    /// Entire column `j` (includes dead rows; filter by liveness if needed).
    #[inline]
    pub fn col(&self, j: usize) -> &[f32] {
        &self.cols[j]
    }

    /// All labels as a slice (`labels()[i]` is the label of instance `i`;
    /// includes dead rows). The training workspace's linear scans read
    /// through this directly instead of per-element `y(i)` calls.
    #[inline]
    pub fn labels(&self) -> &[u8] {
        &self.labels
    }

    /// Row-major copy of instance `i`.
    pub fn row(&self, i: InstanceId) -> Vec<f32> {
        (0..self.n_features()).map(|j| self.x(i, j)).collect()
    }

    /// Mark an instance deleted ("remove from database"). Returns false if it
    /// was already dead.
    pub fn mark_removed(&mut self, i: InstanceId) -> bool {
        let idx = i as usize;
        if !self.alive[idx] {
            return false;
        }
        self.alive[idx] = false;
        self.n_alive -= 1;
        if self.labels[idx] == 1 {
            self.n_pos_alive -= 1;
        }
        true
    }

    /// Append a new instance (continual learning §6); returns its id.
    pub fn push_row(&mut self, row: &[f32], label: u8) -> InstanceId {
        assert_eq!(row.len(), self.n_features(), "row arity mismatch");
        assert!(label <= 1);
        for (j, &v) in row.iter().enumerate() {
            self.cols[j].push(v);
        }
        self.labels.push(label);
        self.alive.push(true);
        self.n_alive += 1;
        if label == 1 {
            self.n_pos_alive += 1;
        }
        (self.labels.len() - 1) as InstanceId
    }

    /// Ids of all live instances, ascending.
    pub fn live_ids(&self) -> Vec<InstanceId> {
        (0..self.n_total() as u32)
            .filter(|&i| self.alive[i as usize])
            .collect()
    }

    /// Copy of the live subset as a fresh dataset (used by the naive-retrain
    /// baseline and scratch-equality tests).
    pub fn compacted(&self) -> Dataset {
        let ids = self.live_ids();
        let mut cols = vec![Vec::with_capacity(ids.len()); self.n_features()];
        let mut labels = Vec::with_capacity(ids.len());
        for &i in &ids {
            for (j, c) in cols.iter_mut().enumerate() {
                c.push(self.x(i, j));
            }
            labels.push(self.y(i));
        }
        Dataset::from_columns(cols, labels)
    }

    /// Subset by explicit ids (e.g. a train/test split or CV fold).
    pub fn subset(&self, ids: &[InstanceId]) -> Dataset {
        let mut cols = vec![Vec::with_capacity(ids.len()); self.n_features()];
        let mut labels = Vec::with_capacity(ids.len());
        for &i in ids {
            for (j, c) in cols.iter_mut().enumerate() {
                c.push(self.x(i, j));
            }
            labels.push(self.y(i));
        }
        Dataset::from_columns(cols, labels)
    }

    /// Fraction of live instances that are positive.
    pub fn pos_fraction(&self) -> f64 {
        if self.n_alive == 0 {
            0.0
        } else {
            self.n_pos_alive as f64 / self.n_alive as f64
        }
    }

    /// Bytes used by the raw data (features + labels + mask) — the "Data"
    /// column of the paper's Table 3.
    pub fn memory_bytes(&self) -> usize {
        self.cols.iter().map(|c| c.len() * 4).sum::<usize>()
            + self.labels.len()
            + self.alive.len()
    }

    /// Row-major feature matrix of live instances plus labels — feed for the
    /// PJRT batch predictor and the python parity tests.
    pub fn to_row_major(&self) -> (Vec<f32>, Vec<u8>, usize) {
        let ids = self.live_ids();
        let p = self.n_features();
        let mut flat = Vec::with_capacity(ids.len() * p);
        let mut ys = Vec::with_capacity(ids.len());
        for &i in &ids {
            for j in 0..p {
                flat.push(self.x(i, j));
            }
            ys.push(self.y(i));
        }
        (flat, ys, p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        Dataset::from_rows(
            &[
                vec![1.0, 10.0],
                vec![2.0, 20.0],
                vec![3.0, 30.0],
                vec![4.0, 40.0],
            ],
            vec![0, 1, 0, 1],
        )
    }

    #[test]
    fn construction_and_access() {
        let d = toy();
        assert_eq!(d.n_features(), 2);
        assert_eq!(d.n_total(), 4);
        assert_eq!(d.n_alive(), 4);
        assert_eq!(d.n_pos_alive(), 2);
        assert_eq!(d.x(2, 1), 30.0);
        assert_eq!(d.y(3), 1);
        assert_eq!(d.row(1), vec![2.0, 20.0]);
        assert_eq!(d.col(0), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn delete_updates_counts() {
        let mut d = toy();
        assert!(d.mark_removed(1));
        assert!(!d.mark_removed(1), "double delete is a no-op");
        assert_eq!(d.n_alive(), 3);
        assert_eq!(d.n_pos_alive(), 1);
        assert!(!d.is_alive(1));
        assert_eq!(d.live_ids(), vec![0, 2, 3]);
    }

    #[test]
    fn push_after_delete_gets_fresh_id() {
        let mut d = toy();
        d.mark_removed(0);
        let id = d.push_row(&[5.0, 50.0], 1);
        assert_eq!(id, 4);
        assert_eq!(d.n_alive(), 4);
        assert_eq!(d.x(id, 0), 5.0);
    }

    #[test]
    fn compacted_drops_dead_rows() {
        let mut d = toy();
        d.mark_removed(2);
        let c = d.compacted();
        assert_eq!(c.n_total(), 3);
        assert_eq!(c.n_alive(), 3);
        assert_eq!(c.col(0), &[1.0, 2.0, 4.0]);
        assert_eq!(c.pos_fraction(), 2.0 / 3.0);
    }

    #[test]
    fn subset_selects_ids() {
        let d = toy();
        let s = d.subset(&[3, 0]);
        assert_eq!(s.col(0), &[4.0, 1.0]);
        assert_eq!(s.y(0), 1);
    }

    #[test]
    fn row_major_export() {
        let mut d = toy();
        d.mark_removed(1);
        let (flat, ys, p) = d.to_row_major();
        assert_eq!(p, 2);
        assert_eq!(flat, vec![1.0, 10.0, 3.0, 30.0, 4.0, 40.0]);
        assert_eq!(ys, vec![0, 0, 1]);
    }

    #[test]
    #[should_panic(expected = "binary")]
    fn rejects_nonbinary_labels() {
        Dataset::from_rows(&[vec![1.0]], vec![2]);
    }

    #[test]
    fn memory_accounting_positive() {
        let d = toy();
        assert_eq!(d.memory_bytes(), 4 * 2 * 4 + 4 + 4);
    }
}
