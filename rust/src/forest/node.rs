//! Tree node representation (paper §A.6): leaf, random decision, and greedy
//! decision nodes, each with the cached statistics that make deletions cheap.
//!
//! Since the arena refactor (DESIGN.md §7) this boxed form is the
//! *construction and oracle* representation: the trainer still builds boxed
//! subtrees (which the arena grafts into its SoA planes), the reference
//! deletion path in `forest::delete` mutates them, and the exactness tests
//! compare arena trees against them. Live trees are stored in
//! [`crate::forest::arena::ArenaTree`].

use crate::data::dataset::InstanceId;
use crate::forest::stats::AttrStats;

/// A leaf: label counts plus the training-instance pointer list that lets
/// any ancestor gather its data for subtree retraining (§3.1).
#[derive(Clone, Debug)]
pub struct LeafNode {
    pub n: u32,
    pub n_pos: u32,
    pub ids: Vec<InstanceId>,
}

impl LeafNode {
    /// Leaf prediction: fraction of positives (0.5 when empty).
    #[inline]
    pub fn value(&self) -> f32 {
        if self.n == 0 {
            0.5
        } else {
            self.n_pos as f32 / self.n as f32
        }
    }
}

/// A random decision node (§3.3): uniformly sampled attribute + threshold;
/// stores only |D|, |D_{·,1}|, |D_l|, |D_r| and retrains iff a side empties.
#[derive(Clone, Debug)]
pub struct RandomNode {
    pub n: u32,
    pub n_pos: u32,
    pub attr: usize,
    pub v: f32,
    pub n_left: u32,
    pub n_right: u32,
    pub left: Box<Node>,
    pub right: Box<Node>,
}

/// A greedy decision node: p̃ sampled attributes × ≤k candidate thresholds
/// with cached statistics; the chosen split is (attrs[best_attr],
/// thresholds[best_thr]).
#[derive(Clone, Debug)]
pub struct GreedyNode {
    pub n: u32,
    pub n_pos: u32,
    pub attrs: Vec<AttrStats>,
    pub best_attr: usize,
    pub best_thr: usize,
    pub left: Box<Node>,
    pub right: Box<Node>,
}

impl GreedyNode {
    #[inline]
    pub fn split_attr(&self) -> usize {
        self.attrs[self.best_attr].attr
    }
    #[inline]
    pub fn split_v(&self) -> f32 {
        self.attrs[self.best_attr].thresholds[self.best_thr].v
    }
}

/// A DaRE tree node.
#[derive(Clone, Debug)]
pub enum Node {
    Leaf(LeafNode),
    Random(RandomNode),
    Greedy(GreedyNode),
}

impl Node {
    /// |D| at this node.
    #[inline]
    pub fn n(&self) -> u32 {
        match self {
            Node::Leaf(l) => l.n,
            Node::Random(r) => r.n,
            Node::Greedy(g) => g.n,
        }
    }

    /// |D_{·,1}| at this node.
    #[inline]
    pub fn n_pos(&self) -> u32 {
        match self {
            Node::Leaf(l) => l.n_pos,
            Node::Random(r) => r.n_pos,
            Node::Greedy(g) => g.n_pos,
        }
    }

    /// Split (attribute, threshold) for decision nodes.
    #[inline]
    pub fn split(&self) -> Option<(usize, f32)> {
        match self {
            Node::Leaf(_) => None,
            Node::Random(r) => Some((r.attr, r.v)),
            Node::Greedy(g) => Some((g.split_attr(), g.split_v())),
        }
    }

    /// Predict the positive-class probability for a feature row.
    pub fn predict(&self, row: &[f32]) -> f32 {
        let mut node = self;
        loop {
            match node {
                Node::Leaf(l) => return l.value(),
                Node::Random(r) => {
                    node = if row[r.attr] <= r.v { &r.left } else { &r.right };
                }
                Node::Greedy(g) => {
                    let (a, v) = (g.split_attr(), g.split_v());
                    node = if row[a] <= v { &g.left } else { &g.right };
                }
            }
        }
    }

    /// Gather the instance ids stored at the leaves of this subtree (§3.1),
    /// optionally excluding one id (the instance being deleted).
    pub fn collect_ids(&self, exclude: Option<InstanceId>, out: &mut Vec<InstanceId>) {
        match self {
            Node::Leaf(l) => {
                match exclude {
                    Some(ex) => out.extend(l.ids.iter().copied().filter(|&i| i != ex)),
                    None => out.extend_from_slice(&l.ids),
                };
            }
            Node::Random(r) => {
                r.left.collect_ids(exclude, out);
                r.right.collect_ids(exclude, out);
            }
            Node::Greedy(g) => {
                g.left.collect_ids(exclude, out);
                g.right.collect_ids(exclude, out);
            }
        }
    }

    /// Count of (decision nodes, random nodes, leaves, max depth).
    pub fn shape(&self) -> TreeShape {
        let mut s = TreeShape::default();
        self.shape_rec(0, &mut s);
        s
    }

    fn shape_rec(&self, depth: usize, s: &mut TreeShape) {
        s.max_depth = s.max_depth.max(depth);
        match self {
            Node::Leaf(_) => s.leaves += 1,
            Node::Random(r) => {
                s.random_nodes += 1;
                r.left.shape_rec(depth + 1, s);
                r.right.shape_rec(depth + 1, s);
            }
            Node::Greedy(g) => {
                s.greedy_nodes += 1;
                g.left.shape_rec(depth + 1, s);
                g.right.shape_rec(depth + 1, s);
            }
        }
    }

    /// Memory accounting for the paper's Table 3 breakdown, in bytes.
    pub fn memory(&self) -> NodeMemory {
        let mut m = NodeMemory::default();
        self.memory_rec(&mut m);
        m
    }

    fn memory_rec(&self, m: &mut NodeMemory) {
        use std::mem::size_of;
        match self {
            Node::Leaf(l) => {
                // structure: the leaf's prediction value
                m.structure += size_of::<f32>();
                // leaf stats: counts + instance pointer list
                m.leaf_stats += 2 * size_of::<u32>() + l.ids.capacity() * size_of::<InstanceId>();
            }
            Node::Random(r) => {
                // structure: attr + threshold + two child pointers
                m.structure += size_of::<usize>() + size_of::<f32>() + 2 * size_of::<usize>();
                // decision stats: n, n_pos, n_left, n_right
                m.decision_stats += 4 * size_of::<u32>();
                r.left.memory_rec(m);
                r.right.memory_rec(m);
            }
            Node::Greedy(g) => {
                m.structure += size_of::<usize>() + size_of::<f32>() + 2 * size_of::<usize>();
                // decision stats: n, n_pos + per-attribute threshold tables
                m.decision_stats += 2 * size_of::<u32>();
                for a in &g.attrs {
                    m.decision_stats += size_of::<usize>()
                        + a.thresholds.capacity()
                            * size_of::<crate::forest::stats::ThresholdStats>();
                }
                g.left.memory_rec(m);
                g.right.memory_rec(m);
            }
        }
    }
}

/// Structural summary of a tree.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TreeShape {
    pub greedy_nodes: usize,
    pub random_nodes: usize,
    pub leaves: usize,
    pub max_depth: usize,
}

impl TreeShape {
    pub fn decision_nodes(&self) -> usize {
        self.greedy_nodes + self.random_nodes
    }
}

/// Byte counts for the Table-3 memory breakdown.
#[derive(Clone, Copy, Debug, Default)]
pub struct NodeMemory {
    pub structure: usize,
    pub decision_stats: usize,
    pub leaf_stats: usize,
}

impl NodeMemory {
    pub fn total(&self) -> usize {
        self.structure + self.decision_stats + self.leaf_stats
    }
    pub fn add(&mut self, o: &NodeMemory) {
        self.structure += o.structure;
        self.decision_stats += o.decision_stats;
        self.leaf_stats += o.leaf_stats;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::forest::stats::ThresholdStats;

    fn leaf(n: u32, n_pos: u32, ids: Vec<u32>) -> Node {
        Node::Leaf(LeafNode { n, n_pos, ids })
    }

    fn toy_greedy() -> Node {
        let t = ThresholdStats {
            v: 1.5,
            v_low: 1.0,
            v_high: 2.0,
            n_left: 2,
            n_left_pos: 0,
            n_low: 2,
            n_low_pos: 0,
            n_high: 2,
            n_high_pos: 2,
        };
        Node::Greedy(GreedyNode {
            n: 4,
            n_pos: 2,
            attrs: vec![AttrStats {
                attr: 0,
                thresholds: vec![t],
            }],
            best_attr: 0,
            best_thr: 0,
            left: Box::new(leaf(2, 0, vec![0, 1])),
            right: Box::new(leaf(2, 2, vec![2, 3])),
        })
    }

    #[test]
    fn leaf_value() {
        assert_eq!(
            LeafNode {
                n: 4,
                n_pos: 1,
                ids: vec![]
            }
            .value(),
            0.25
        );
        assert_eq!(
            LeafNode {
                n: 0,
                n_pos: 0,
                ids: vec![]
            }
            .value(),
            0.5
        );
    }

    #[test]
    fn predict_routes() {
        let t = toy_greedy();
        assert_eq!(t.predict(&[1.0]), 0.0);
        assert_eq!(t.predict(&[2.0]), 1.0);
        assert_eq!(t.predict(&[1.5]), 0.0, "x <= v goes left");
    }

    #[test]
    fn collect_ids_excludes() {
        let t = toy_greedy();
        let mut ids = Vec::new();
        t.collect_ids(None, &mut ids);
        assert_eq!(ids, vec![0, 1, 2, 3]);
        ids.clear();
        t.collect_ids(Some(2), &mut ids);
        assert_eq!(ids, vec![0, 1, 3]);
    }

    #[test]
    fn shape_counts() {
        let t = toy_greedy();
        let s = t.shape();
        assert_eq!(s.greedy_nodes, 1);
        assert_eq!(s.random_nodes, 0);
        assert_eq!(s.leaves, 2);
        assert_eq!(s.max_depth, 1);
        assert_eq!(s.decision_nodes(), 1);
    }

    #[test]
    fn memory_nonzero_partition() {
        let t = toy_greedy();
        let m = t.memory();
        assert!(m.structure > 0);
        assert!(m.decision_stats > 0);
        assert!(m.leaf_stats > 0);
        assert_eq!(m.total(), m.structure + m.decision_stats + m.leaf_stats);
    }

    #[test]
    fn split_accessor() {
        let t = toy_greedy();
        assert_eq!(t.split(), Some((0, 1.5)));
        assert_eq!(leaf(1, 0, vec![9]).split(), None);
        assert_eq!(t.n(), 4);
        assert_eq!(t.n_pos(), 2);
    }
}
