//! Arena tree storage: a contiguous, id-indexed node store replacing the
//! heap-boxed `Node` tree as the *live* representation inside [`crate::forest::tree::DareTree`]
//! (DESIGN.md §7).
//!
//! Layout:
//! - **Hot plane** ([`HotPlane`]) — five parallel SoA arrays holding exactly
//!   what a prediction descent reads: split attribute, threshold, left/right
//!   child ids, and the leaf value. A descent never touches anything else,
//!   so the working set is cache-dense instead of one heap box per node.
//! - **Cold plane** — side tables indexed by the same node id: per-node
//!   `n`/`n_pos` counts and a [`Cold`] payload (leaf instance-id lists,
//!   random-node branch counts, greedy-node `AttrStats` threshold tables).
//!   Deletion walks read the hot plane for routing and the cold plane for
//!   the cached statistics that make DaRE deletions cheap.
//!
//! Node ids are slots in these arrays. Freed slots (from subtree retrains
//! and leaf collapses) go on a LIFO free list and are reused by later
//! grafts, so arena size tracks the *peak* tree size, not churn. All
//! allocation and free orders are deterministic functions of the operation
//! sequence — no hashing, no threading — which keeps delete-then-retrain
//! grafts reproducible (DESIGN.md §5 applies unchanged).
//!
//! The boxed [`Node`] representation remains the construction format and
//! exactness oracle: trees are built by the (workspace) trainer as `Node`s
//! and grafted in ([`ArenaTree::from_node`] / grafting on the update path),
//! and `tests/workspace_exactness.rs` plus the churn tests assert arena
//! trees stay `structural_eq` to the boxed implementation.

use crate::data::dataset::{Dataset, InstanceId};
use crate::forest::node::{GreedyNode, LeafNode, Node, NodeMemory, RandomNode, TreeShape};
use crate::forest::stats::{AttrStats, ThresholdStats};
use crate::forest::train::count_pos;
use std::collections::VecDeque;

/// Sentinel child id: a node whose `left` is `NIL` is a leaf; a slot whose
/// `left` *and* cold payload say `Free` is on the free list.
pub const NIL: u32 = u32::MAX;

/// Leaf prediction from counts — must match [`LeafNode::value`] bit-exactly
/// (the hot plane caches this so descents never divide).
#[inline]
pub(crate) fn leaf_value(n: u32, n_pos: u32) -> f32 {
    if n == 0 {
        0.5
    } else {
        n_pos as f32 / n as f32
    }
}

/// The SoA arrays a prediction descent reads. All five are indexed by node
/// id and always have the same length.
#[derive(Clone, Debug, Default)]
pub struct HotPlane {
    /// Split attribute (unused for leaves).
    pub attr: Vec<u32>,
    /// Split threshold (unused for leaves).
    pub thresh: Vec<f32>,
    /// Left child id, or [`NIL`] for leaves/free slots.
    pub left: Vec<u32>,
    /// Right child id, or [`NIL`] for leaves/free slots.
    pub right: Vec<u32>,
    /// Cached leaf prediction (0.0 for decision nodes).
    pub value: Vec<f32>,
}

/// Cold per-node payload: everything deletion needs beyond the hot plane.
#[derive(Clone, Debug)]
pub enum Cold {
    /// Slot is on the free list.
    Free,
    Leaf {
        ids: Vec<InstanceId>,
    },
    Random {
        n_left: u32,
        n_right: u32,
    },
    Greedy {
        attrs: Vec<AttrStats>,
        best_attr: usize,
        best_thr: usize,
    },
}

/// One DaRE tree in arena form.
#[derive(Clone, Debug)]
pub struct ArenaTree {
    pub(crate) root: u32,
    pub(crate) hot: HotPlane,
    /// |D| at each node.
    pub(crate) n: Vec<u32>,
    /// |D_{·,1}| at each node.
    pub(crate) n_pos: Vec<u32>,
    pub(crate) cold: Vec<Cold>,
    /// Freed slots, reused LIFO by later grafts.
    pub(crate) free: Vec<u32>,
    /// True while the layout is exactly the BFS order of a fresh
    /// [`ArenaTree::from_node`] build (root at slot 0, children allocated in
    /// contiguous pairs) — lets `runtime::tensorize` copy the hot plane
    /// linearly. Any graft or free clears it.
    pub(crate) bfs_compact: bool,
}

impl ArenaTree {
    fn empty() -> ArenaTree {
        ArenaTree {
            root: NIL,
            hot: HotPlane::default(),
            n: Vec::new(),
            n_pos: Vec::new(),
            cold: Vec::new(),
            free: Vec::new(),
            bfs_compact: false,
        }
    }

    /// Consume a boxed tree into a fresh arena in BFS order: the root lands
    /// in slot 0 and children occupy contiguous pairs — the exact layout the
    /// tensorized predict artifact uses.
    pub fn from_node(root: Node) -> ArenaTree {
        let mut t = ArenaTree::empty();
        let slot = t.alloc();
        t.root = slot;
        t.graft_at(slot, root);
        t.bfs_compact = true;
        t
    }

    /// Root node id.
    #[inline]
    pub fn root(&self) -> u32 {
        self.root
    }

    /// Total slots (live + free).
    #[inline]
    pub fn len(&self) -> usize {
        self.cold.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.cold.is_empty()
    }

    /// Slots currently on the free list.
    #[inline]
    pub fn free_len(&self) -> usize {
        self.free.len()
    }

    /// Live (reachable) node count.
    #[inline]
    pub fn live_len(&self) -> usize {
        self.len() - self.free_len()
    }

    /// Hot-plane accessor for the tensorizer.
    #[inline]
    pub fn hot(&self) -> &HotPlane {
        &self.hot
    }

    /// See [`ArenaTree::bfs_compact`].
    #[inline]
    pub fn is_bfs_compact(&self) -> bool {
        self.bfs_compact && self.root == 0 && self.free.is_empty()
    }

    #[inline]
    pub fn is_leaf(&self, nid: u32) -> bool {
        self.hot.left[nid as usize] == NIL
    }

    /// |D| at the root.
    #[inline]
    pub fn n_root(&self) -> u32 {
        self.n[self.root as usize]
    }

    // --- slot management ---------------------------------------------------

    /// Claim a slot: reuse the most recently freed one, else grow every
    /// plane by one. Deterministic given the operation sequence.
    pub(crate) fn alloc(&mut self) -> u32 {
        if let Some(s) = self.free.pop() {
            return s;
        }
        self.hot.attr.push(0);
        self.hot.thresh.push(0.0);
        self.hot.left.push(NIL);
        self.hot.right.push(NIL);
        self.hot.value.push(0.0);
        self.n.push(0);
        self.n_pos.push(0);
        self.cold.push(Cold::Free);
        (self.cold.len() - 1) as u32
    }

    /// Return `nid` and its whole subtree to the free list.
    pub(crate) fn free_subtree(&mut self, nid: u32) {
        let mut stack = vec![nid];
        while let Some(s) = stack.pop() {
            let si = s as usize;
            if self.hot.left[si] != NIL {
                stack.push(self.hot.left[si]);
                stack.push(self.hot.right[si]);
            }
            self.hot.left[si] = NIL;
            self.hot.right[si] = NIL;
            self.hot.value[si] = 0.0;
            self.n[si] = 0;
            self.n_pos[si] = 0;
            self.cold[si] = Cold::Free;
            self.free.push(s);
        }
        self.bfs_compact = false;
    }

    /// Free both child subtrees of a decision node (keeping `nid` itself).
    pub(crate) fn free_children(&mut self, nid: u32) {
        let ni = nid as usize;
        if self.hot.left[ni] == NIL {
            return;
        }
        let l = self.hot.left[ni];
        let r = self.hot.right[ni];
        self.free_subtree(l);
        self.free_subtree(r);
        self.hot.left[ni] = NIL;
        self.hot.right[ni] = NIL;
    }

    // --- slot writers ------------------------------------------------------

    pub(crate) fn write_leaf(&mut self, slot: u32, n: u32, n_pos: u32, ids: Vec<InstanceId>) {
        let si = slot as usize;
        self.hot.attr[si] = 0;
        self.hot.thresh[si] = 0.0;
        self.hot.left[si] = NIL;
        self.hot.right[si] = NIL;
        self.hot.value[si] = leaf_value(n, n_pos);
        self.n[si] = n;
        self.n_pos[si] = n_pos;
        self.cold[si] = Cold::Leaf { ids };
    }

    #[allow(clippy::too_many_arguments)]
    pub(crate) fn write_random(
        &mut self,
        slot: u32,
        n: u32,
        n_pos: u32,
        attr: usize,
        v: f32,
        n_left: u32,
        n_right: u32,
        left: u32,
        right: u32,
    ) {
        let si = slot as usize;
        self.hot.attr[si] = attr as u32;
        self.hot.thresh[si] = v;
        self.hot.left[si] = left;
        self.hot.right[si] = right;
        self.hot.value[si] = 0.0;
        self.n[si] = n;
        self.n_pos[si] = n_pos;
        self.cold[si] = Cold::Random { n_left, n_right };
    }

    #[allow(clippy::too_many_arguments)]
    pub(crate) fn write_greedy(
        &mut self,
        slot: u32,
        n: u32,
        n_pos: u32,
        attrs: Vec<AttrStats>,
        best_attr: usize,
        best_thr: usize,
        left: u32,
        right: u32,
    ) {
        let si = slot as usize;
        self.hot.attr[si] = attrs[best_attr].attr as u32;
        self.hot.thresh[si] = attrs[best_attr].thresholds[best_thr].v;
        self.hot.left[si] = left;
        self.hot.right[si] = right;
        self.hot.value[si] = 0.0;
        self.n[si] = n;
        self.n_pos[si] = n_pos;
        self.cold[si] = Cold::Greedy {
            attrs,
            best_attr,
            best_thr,
        };
    }

    /// Refresh a greedy node's hot split after its `best_attr`/`best_thr`
    /// moved (cold plane already updated).
    pub(crate) fn refresh_greedy_split(&mut self, nid: u32) {
        let ni = nid as usize;
        let Cold::Greedy {
            attrs,
            best_attr,
            best_thr,
        } = &self.cold[ni]
        else {
            unreachable!("refresh_greedy_split on non-greedy node");
        };
        self.hot.attr[ni] = attrs[*best_attr].attr as u32;
        self.hot.thresh[ni] = attrs[*best_attr].thresholds[*best_thr].v;
    }

    // --- grafting ----------------------------------------------------------

    /// Write `node`'s subtree into the arena with `slot` as its root,
    /// allocating descendant slots in BFS order (free-list first). The
    /// previous children of `slot`, if any, must already have been freed.
    pub(crate) fn graft_at(&mut self, slot: u32, node: Node) {
        let mut queue: VecDeque<(Node, u32)> = VecDeque::new();
        queue.push_back((node, slot));
        while let Some((n, s)) = queue.pop_front() {
            match n {
                Node::Leaf(l) => {
                    self.write_leaf(s, l.n, l.n_pos, l.ids);
                }
                Node::Random(r) => {
                    let ls = self.alloc();
                    let rs = self.alloc();
                    self.write_random(s, r.n, r.n_pos, r.attr, r.v, r.n_left, r.n_right, ls, rs);
                    queue.push_back((*r.left, ls));
                    queue.push_back((*r.right, rs));
                }
                Node::Greedy(g) => {
                    let ls = self.alloc();
                    let rs = self.alloc();
                    queue.push_back((*g.left, ls));
                    queue.push_back((*g.right, rs));
                    self.write_greedy(s, g.n, g.n_pos, g.attrs, g.best_attr, g.best_thr, ls, rs);
                }
            }
        }
        self.bfs_compact = false;
    }

    /// Allocate a fresh slot and graft `node` there; returns the slot.
    pub(crate) fn graft_new(&mut self, node: Node) -> u32 {
        let slot = self.alloc();
        self.graft_at(slot, node);
        slot
    }

    /// Replace the whole subtree at `nid` with `node`, keeping the id.
    pub(crate) fn replace_node(&mut self, nid: u32, node: Node) {
        self.free_children(nid);
        self.graft_at(nid, node);
    }

    /// Collapse the subtree at `nid` into a leaf over `ids` (deletion
    /// stopping criteria), keeping the id.
    pub(crate) fn collapse_to_leaf(&mut self, nid: u32, data: &Dataset, ids: Vec<InstanceId>) {
        self.free_children(nid);
        let n_pos = count_pos(data, &ids);
        self.write_leaf(nid, ids.len() as u32, n_pos, ids);
    }

    /// Replace both children of the decision node `nid` after its split
    /// moved to `(attr, v)` — the greedy argmax-changed retrain path.
    pub(crate) fn replace_children(&mut self, nid: u32, attr: usize, v: f32, left: Node, right: Node) {
        self.free_children(nid);
        let ls = self.graft_new(left);
        let rs = self.graft_new(right);
        let ni = nid as usize;
        self.hot.attr[ni] = attr as u32;
        self.hot.thresh[ni] = v;
        self.hot.left[ni] = ls;
        self.hot.right[ni] = rs;
    }

    // --- reads -------------------------------------------------------------

    /// Positive-class probability for one feature row: a pure hot-plane
    /// descent (two array reads + one compare per level).
    #[inline]
    pub fn predict(&self, row: &[f32]) -> f32 {
        let mut i = self.root as usize;
        loop {
            let l = self.hot.left[i];
            if l == NIL {
                return self.hot.value[i];
            }
            i = if row[self.hot.attr[i] as usize] <= self.hot.thresh[i] {
                l
            } else {
                self.hot.right[i]
            } as usize;
        }
    }

    /// Level-synchronous batched descent: advance every row of the block one
    /// level per sweep, so the tree's upper levels stay hot in cache across
    /// the whole block, then add each row's leaf value into `sums`.
    /// `cursors` is caller-provided scratch (cleared here, reused across
    /// trees). Accumulation order per row equals the per-row path's
    /// tree-ordered sum, so forest probabilities are bit-identical.
    pub fn predict_block_sum(&self, rows: &[Vec<f32>], cursors: &mut Vec<u32>, sums: &mut [f32]) {
        debug_assert_eq!(rows.len(), sums.len());
        cursors.clear();
        cursors.resize(rows.len(), self.root);
        loop {
            let mut moved = false;
            for (c, row) in cursors.iter_mut().zip(rows) {
                let i = *c as usize;
                let l = self.hot.left[i];
                if l == NIL {
                    continue;
                }
                *c = if row[self.hot.attr[i] as usize] <= self.hot.thresh[i] {
                    l
                } else {
                    self.hot.right[i]
                };
                moved = true;
            }
            if !moved {
                break;
            }
        }
        for (c, s) in cursors.iter().zip(sums.iter_mut()) {
            *s += self.hot.value[*c as usize];
        }
    }

    /// Gather the instance ids at the leaves of the subtree rooted at `nid`
    /// (left-to-right, matching [`Node::collect_ids`]), optionally excluding
    /// one id.
    pub fn collect_ids(&self, nid: u32, exclude: Option<InstanceId>, out: &mut Vec<InstanceId>) {
        let ni = nid as usize;
        if self.hot.left[ni] == NIL {
            let Cold::Leaf { ids } = &self.cold[ni] else {
                unreachable!("leaf-shaped slot without leaf payload");
            };
            match exclude {
                Some(ex) => out.extend(ids.iter().copied().filter(|&i| i != ex)),
                None => out.extend_from_slice(ids),
            }
            return;
        }
        self.collect_ids(self.hot.left[ni], exclude, out);
        self.collect_ids(self.hot.right[ni], exclude, out);
    }

    /// Structural summary (node-kind counts + max depth).
    pub fn shape(&self) -> TreeShape {
        let mut s = TreeShape::default();
        let mut stack = vec![(self.root, 0usize)];
        while let Some((nid, depth)) = stack.pop() {
            let ni = nid as usize;
            s.max_depth = s.max_depth.max(depth);
            match &self.cold[ni] {
                Cold::Leaf { .. } => s.leaves += 1,
                Cold::Random { .. } => {
                    s.random_nodes += 1;
                    stack.push((self.hot.left[ni], depth + 1));
                    stack.push((self.hot.right[ni], depth + 1));
                }
                Cold::Greedy { .. } => {
                    s.greedy_nodes += 1;
                    stack.push((self.hot.left[ni], depth + 1));
                    stack.push((self.hot.right[ni], depth + 1));
                }
                Cold::Free => unreachable!("free slot reachable from root"),
            }
        }
        s
    }

    /// Memory accounting (Table 3 categories) over the arena's actual
    /// layout: every slot (live or free) pays its five hot-plane elements
    /// (20 B) plus the two count-plane elements (8 B); cold payloads are
    /// attributed like the boxed accounting (leaf lists to `leaf_stats`,
    /// branch counts and threshold tables to `decision_stats`).
    pub fn memory(&self) -> NodeMemory {
        use std::mem::size_of;
        let hot_slot = 3 * size_of::<u32>() + 2 * size_of::<f32>();
        let count_slot = 2 * size_of::<u32>();
        let mut m = NodeMemory::default();
        for c in &self.cold {
            m.structure += hot_slot;
            match c {
                Cold::Free => m.structure += count_slot,
                Cold::Leaf { ids } => {
                    m.leaf_stats += count_slot + ids.capacity() * size_of::<InstanceId>();
                }
                Cold::Random { .. } => {
                    m.decision_stats += count_slot + 2 * size_of::<u32>();
                }
                Cold::Greedy { attrs, .. } => {
                    m.decision_stats += count_slot;
                    for a in attrs {
                        m.decision_stats += size_of::<usize>()
                            + a.thresholds.capacity() * size_of::<ThresholdStats>();
                    }
                }
            }
        }
        m
    }

    // --- boxed-view interop ------------------------------------------------

    /// Reconstruct the boxed view of the whole tree (oracle comparisons,
    /// serialization).
    pub fn to_node(&self) -> Node {
        self.to_node_at(self.root)
    }

    fn to_node_at(&self, nid: u32) -> Node {
        let ni = nid as usize;
        match &self.cold[ni] {
            Cold::Leaf { ids } => Node::Leaf(LeafNode {
                n: self.n[ni],
                n_pos: self.n_pos[ni],
                ids: ids.clone(),
            }),
            Cold::Random { n_left, n_right } => Node::Random(RandomNode {
                n: self.n[ni],
                n_pos: self.n_pos[ni],
                attr: self.hot.attr[ni] as usize,
                v: self.hot.thresh[ni],
                n_left: *n_left,
                n_right: *n_right,
                left: Box::new(self.to_node_at(self.hot.left[ni])),
                right: Box::new(self.to_node_at(self.hot.right[ni])),
            }),
            Cold::Greedy {
                attrs,
                best_attr,
                best_thr,
            } => Node::Greedy(GreedyNode {
                n: self.n[ni],
                n_pos: self.n_pos[ni],
                attrs: attrs.clone(),
                best_attr: *best_attr,
                best_thr: *best_thr,
                left: Box::new(self.to_node_at(self.hot.left[ni])),
                right: Box::new(self.to_node_at(self.hot.right[ni])),
            }),
            Cold::Free => unreachable!("to_node on a free slot"),
        }
    }

    /// Structural equality against a boxed tree (same semantics as
    /// [`crate::forest::tree::structural_eq`]: kinds, splits, counts, and
    /// order-insensitive leaf id sets).
    pub fn matches_node(&self, node: &Node) -> bool {
        let mut scratch = IdScratch::default();
        self.matches_node_at(self.root, node, &mut scratch)
    }

    fn matches_node_at(&self, nid: u32, node: &Node, s: &mut IdScratch) -> bool {
        let ni = nid as usize;
        match (&self.cold[ni], node) {
            (Cold::Leaf { ids }, Node::Leaf(l)) => {
                self.n[ni] == l.n && self.n_pos[ni] == l.n_pos && s.ids_eq(ids, &l.ids)
            }
            (Cold::Random { .. }, Node::Random(r)) => {
                self.hot.attr[ni] as usize == r.attr
                    && self.hot.thresh[ni] == r.v
                    && self.n[ni] == r.n
                    && self.n_pos[ni] == r.n_pos
                    && self.matches_node_at(self.hot.left[ni], &r.left, s)
                    && self.matches_node_at(self.hot.right[ni], &r.right, s)
            }
            (
                Cold::Greedy {
                    attrs,
                    best_attr,
                    best_thr,
                },
                Node::Greedy(g),
            ) => {
                attrs[*best_attr].attr == g.split_attr()
                    && attrs[*best_attr].thresholds[*best_thr].v == g.split_v()
                    && self.n[ni] == g.n
                    && self.n_pos[ni] == g.n_pos
                    && self.matches_node_at(self.hot.left[ni], &g.left, s)
                    && self.matches_node_at(self.hot.right[ni], &g.right, s)
            }
            _ => false,
        }
    }

    /// Structural equality between two arena trees (no reconstruction).
    pub fn structural_matches(&self, other: &ArenaTree) -> bool {
        let mut scratch = IdScratch::default();
        self.matches_arena_at(self.root, other, other.root, &mut scratch)
    }

    fn matches_arena_at(&self, nid: u32, o: &ArenaTree, oid: u32, s: &mut IdScratch) -> bool {
        let (ni, oi) = (nid as usize, oid as usize);
        if self.n[ni] != o.n[oi] || self.n_pos[ni] != o.n_pos[oi] {
            return false;
        }
        match (&self.cold[ni], &o.cold[oi]) {
            (Cold::Leaf { ids: a }, Cold::Leaf { ids: b }) => s.ids_eq(a, b),
            (Cold::Random { .. }, Cold::Random { .. })
            | (Cold::Greedy { .. }, Cold::Greedy { .. }) => {
                self.hot.attr[ni] == o.hot.attr[oi]
                    && self.hot.thresh[ni] == o.hot.thresh[oi]
                    && self.matches_arena_at(self.hot.left[ni], o, o.hot.left[oi], s)
                    && self.matches_arena_at(self.hot.right[ni], o, o.hot.right[oi], s)
            }
            _ => false,
        }
    }

    // --- consistency -------------------------------------------------------

    /// Deep structural audit: every slot is either reachable exactly once
    /// from the root or on the free list exactly once; hot and cold planes
    /// agree on every node kind and split; counts are consistent between
    /// parents and children; leaf values are fresh. Test-support (and cheap
    /// enough for debug assertions after churn).
    pub fn validate(&self) -> anyhow::Result<()> {
        let len = self.len();
        anyhow::ensure!(
            (self.root as usize) < len,
            "root {} out of bounds ({len} slots)",
            self.root
        );
        let mut seen = vec![false; len];
        for &f in &self.free {
            let fi = f as usize;
            anyhow::ensure!(fi < len, "free id {f} out of bounds");
            anyhow::ensure!(!seen[fi], "slot {f} on the free list twice");
            seen[fi] = true;
            anyhow::ensure!(
                matches!(self.cold[fi], Cold::Free),
                "free slot {f} holds a live payload"
            );
            anyhow::ensure!(
                self.hot.left[fi] == NIL && self.hot.right[fi] == NIL,
                "free slot {f} has children"
            );
        }
        let mut stack = vec![self.root];
        let mut live = 0usize;
        while let Some(nid) = stack.pop() {
            let ni = nid as usize;
            anyhow::ensure!(ni < len, "node id {nid} out of bounds");
            anyhow::ensure!(!seen[ni], "slot {nid} reached twice (cycle or free-list overlap)");
            seen[ni] = true;
            live += 1;
            match &self.cold[ni] {
                Cold::Free => anyhow::bail!("free slot {nid} reachable from root"),
                Cold::Leaf { ids } => {
                    anyhow::ensure!(self.hot.left[ni] == NIL, "leaf {nid} has a left child");
                    anyhow::ensure!(self.hot.right[ni] == NIL, "leaf {nid} has a right child");
                    anyhow::ensure!(
                        ids.len() == self.n[ni] as usize,
                        "leaf {nid}: |ids| {} != n {}",
                        ids.len(),
                        self.n[ni]
                    );
                    anyhow::ensure!(
                        self.hot.value[ni] == leaf_value(self.n[ni], self.n_pos[ni]),
                        "leaf {nid}: stale hot value"
                    );
                }
                Cold::Random { n_left, n_right } => {
                    let (l, r) = (self.hot.left[ni], self.hot.right[ni]);
                    anyhow::ensure!(l != NIL && r != NIL, "random node {nid} missing children");
                    anyhow::ensure!(
                        *n_left == self.n[l as usize] && *n_right == self.n[r as usize],
                        "random node {nid}: branch counts disagree with children"
                    );
                    anyhow::ensure!(
                        self.n[ni] == self.n[l as usize] + self.n[r as usize],
                        "random node {nid}: n != n_l + n_r"
                    );
                    anyhow::ensure!(
                        self.n_pos[ni] == self.n_pos[l as usize] + self.n_pos[r as usize],
                        "random node {nid}: n_pos disagrees with children"
                    );
                    stack.push(l);
                    stack.push(r);
                }
                Cold::Greedy {
                    attrs,
                    best_attr,
                    best_thr,
                } => {
                    let (l, r) = (self.hot.left[ni], self.hot.right[ni]);
                    anyhow::ensure!(l != NIL && r != NIL, "greedy node {nid} missing children");
                    anyhow::ensure!(
                        *best_attr < attrs.len() && *best_thr < attrs[*best_attr].thresholds.len(),
                        "greedy node {nid}: best split out of range"
                    );
                    anyhow::ensure!(
                        self.hot.attr[ni] as usize == attrs[*best_attr].attr
                            && self.hot.thresh[ni] == attrs[*best_attr].thresholds[*best_thr].v,
                        "greedy node {nid}: hot split diverged from cold plane"
                    );
                    anyhow::ensure!(
                        self.n[ni] == self.n[l as usize] + self.n[r as usize]
                            && self.n_pos[ni] == self.n_pos[l as usize] + self.n_pos[r as usize],
                        "greedy node {nid}: counts disagree with children"
                    );
                    stack.push(l);
                    stack.push(r);
                }
            }
        }
        anyhow::ensure!(
            live + self.free.len() == len,
            "leak: {live} live + {} free != {len} slots",
            self.free.len()
        );
        Ok(())
    }

    /// Data-aware extension of [`ArenaTree::validate`]: every leaf's
    /// `n_pos` must equal the positive-label count over its id list (so,
    /// with the parent-sum checks of `validate`, every node's `n`/`n_pos`
    /// equals the sum over the leaf id lists below it), and leaf ids must
    /// index real rows. Used by the churn property tests.
    pub fn validate_counts(&self, data: &Dataset) -> anyhow::Result<()> {
        self.validate()?;
        for (ni, c) in self.cold.iter().enumerate() {
            if let Cold::Leaf { ids } = c {
                for &id in ids {
                    anyhow::ensure!(
                        (id as usize) < data.n_total(),
                        "leaf {ni}: id {id} out of range"
                    );
                }
                let pos = count_pos(data, ids);
                anyhow::ensure!(
                    pos == self.n_pos[ni],
                    "leaf {ni}: n_pos {} != label sum {pos} over its id list",
                    self.n_pos[ni]
                );
            }
        }
        Ok(())
    }
}

/// Reusable sorted-id scratch for order-insensitive leaf comparisons: one
/// pair of buffers serves every leaf of a whole tree comparison instead of
/// two fresh allocations per leaf (tree.rs' `structural_eq` shares this).
#[derive(Default)]
pub(crate) struct IdScratch {
    a: Vec<InstanceId>,
    b: Vec<InstanceId>,
}

impl IdScratch {
    /// Multiset equality of two id lists via the reused buffers.
    pub(crate) fn ids_eq(&mut self, x: &[InstanceId], y: &[InstanceId]) -> bool {
        if x.len() != y.len() {
            return false;
        }
        self.a.clear();
        self.a.extend_from_slice(x);
        self.a.sort_unstable();
        self.b.clear();
        self.b.extend_from_slice(y);
        self.b.sort_unstable();
        self.a == self.b
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};
    use crate::forest::params::{MaxFeatures, Params};
    use crate::forest::train::{train, TrainCtx, ROOT_PATH};
    use crate::forest::tree::structural_eq;

    fn toy_data(n: usize, seed: u64) -> Dataset {
        generate(
            &SynthSpec {
                n,
                informative: 3,
                redundant: 1,
                noise: 2,
                flip: 0.05,
                ..Default::default()
            },
            seed,
        )
    }

    fn params(d_rmax: usize) -> Params {
        Params {
            n_trees: 1,
            max_depth: 8,
            k: 5,
            d_rmax,
            max_features: MaxFeatures::Sqrt,
            ..Default::default()
        }
    }

    fn boxed(data: &Dataset, p: &Params, tree_seed: u64) -> Node {
        let ctx = TrainCtx {
            data,
            params: p,
            tree_seed,
        };
        train(&ctx, data.live_ids(), 0, ROOT_PATH)
    }

    #[test]
    fn from_node_roundtrips_structurally() {
        let d = toy_data(300, 1);
        for d_rmax in [0usize, 2] {
            let p = params(d_rmax);
            let node = boxed(&d, &p, 7);
            let arena = ArenaTree::from_node(boxed(&d, &p, 7));
            assert!(arena.matches_node(&node));
            assert!(structural_eq(&arena.to_node(), &node));
            arena.validate().unwrap();
            assert!(arena.is_bfs_compact());
            assert_eq!(arena.free_len(), 0);
        }
    }

    #[test]
    fn bfs_layout_matches_tensorizer_contract() {
        // Fresh builds place the root at 0 and children in contiguous
        // ascending pairs — what the tensorizer's linear copy relies on.
        let d = toy_data(400, 2);
        let arena = ArenaTree::from_node(boxed(&d, &params(1), 3));
        assert_eq!(arena.root(), 0);
        let mut next_expected = 1u32;
        for i in 0..arena.len() {
            let l = arena.hot().left[i];
            if l == NIL {
                continue;
            }
            assert_eq!(l, next_expected, "left child of {i} out of BFS order");
            assert_eq!(arena.hot().right[i], next_expected + 1);
            next_expected += 2;
        }
        assert_eq!(next_expected as usize, arena.len());
    }

    #[test]
    fn predict_matches_boxed_descent() {
        let d = toy_data(500, 3);
        let node = boxed(&d, &params(2), 11);
        let arena = ArenaTree::from_node(boxed(&d, &params(2), 11));
        for id in d.live_ids().into_iter().take(120) {
            let row = d.row(id);
            assert_eq!(arena.predict(&row), node.predict(&row), "row {id}");
        }
    }

    #[test]
    fn block_descent_matches_per_row() {
        let d = toy_data(400, 4);
        let arena = ArenaTree::from_node(boxed(&d, &params(1), 5));
        let rows: Vec<Vec<f32>> = (0..97u32).map(|i| d.row(i)).collect();
        let mut sums = vec![0.0f32; rows.len()];
        let mut cursors = Vec::new();
        arena.predict_block_sum(&rows, &mut cursors, &mut sums);
        for (row, s) in rows.iter().zip(&sums) {
            assert_eq!(*s, arena.predict(row));
        }
        // accumulation: a second pass adds on top
        arena.predict_block_sum(&rows, &mut cursors, &mut sums);
        for (row, s) in rows.iter().zip(&sums) {
            assert_eq!(*s, 2.0 * arena.predict(row));
        }
    }

    #[test]
    fn shape_and_memory_track_boxed_tree() {
        let d = toy_data(350, 5);
        let node = boxed(&d, &params(2), 9);
        let arena = ArenaTree::from_node(boxed(&d, &params(2), 9));
        assert_eq!(arena.shape(), node.shape());
        let m = arena.memory();
        assert!(m.structure > 0 && m.decision_stats > 0 && m.leaf_stats > 0);
        assert_eq!(m.total(), m.structure + m.decision_stats + m.leaf_stats);
        assert_eq!(
            arena.live_len(),
            node.shape().leaves + node.shape().decision_nodes()
        );
    }

    #[test]
    fn collect_ids_matches_boxed_order() {
        let d = toy_data(250, 6);
        let node = boxed(&d, &params(1), 13);
        let arena = ArenaTree::from_node(boxed(&d, &params(1), 13));
        let mut a = Vec::new();
        let mut b = Vec::new();
        node.collect_ids(None, &mut a);
        arena.collect_ids(arena.root(), None, &mut b);
        assert_eq!(a, b);
        let ex = a[0];
        a.clear();
        b.clear();
        node.collect_ids(Some(ex), &mut a);
        arena.collect_ids(arena.root(), Some(ex), &mut b);
        assert_eq!(a, b);
        assert_eq!(a.len(), arena.n_root() as usize - 1);
    }

    #[test]
    fn free_and_regraft_reuses_slots() {
        let d = toy_data(300, 7);
        let mut arena = ArenaTree::from_node(boxed(&d, &params(0), 17));
        let before_len = arena.len();
        let root = arena.root();
        // Replace the whole tree in place with a rebuilt copy: every slot
        // the old children held must be recycled, not leaked.
        arena.replace_node(root, boxed(&d, &params(0), 17));
        arena.validate().unwrap();
        assert_eq!(arena.len(), before_len, "regraft must reuse freed slots");
        assert!(!arena.is_bfs_compact());
        assert!(arena.matches_node(&boxed(&d, &params(0), 17)));
    }

    #[test]
    fn structural_matches_between_arenas() {
        let d = toy_data(200, 8);
        let a = ArenaTree::from_node(boxed(&d, &params(1), 1));
        let b = ArenaTree::from_node(boxed(&d, &params(1), 1));
        let c = ArenaTree::from_node(boxed(&d, &params(1), 2));
        assert!(a.structural_matches(&b));
        assert!(!a.structural_matches(&c));
    }

    #[test]
    fn id_scratch_multiset_semantics() {
        let mut s = IdScratch::default();
        assert!(s.ids_eq(&[3, 1, 2], &[1, 2, 3]));
        assert!(!s.ids_eq(&[1, 2], &[1, 2, 3]));
        assert!(!s.ids_eq(&[1, 1, 2], &[1, 2, 2]));
        assert!(s.ids_eq(&[], &[]));
    }
}
