//! Split criteria (paper Eq. 2 and Eq. 3), computed from cached counts in
//! O(1) per (attribute, threshold) pair — the property that makes DaRE's
//! post-deletion rescoring cheap (Theorem 3.3).
//!
//! These scalar routines are the semantic reference for the L1 Pallas kernel
//! (`python/compile/kernels/split_scores.py`); `runtime::scorer` checks the
//! PJRT-executed kernel against them bit-for-bit at f32 granularity.

use crate::forest::params::SplitCriterion;

/// Weighted Gini index of a binary split (Eq. 2). Lower is better.
///
/// `n`/`n_pos`: instances and positives at the node;
/// `n_l`/`n_l_pos`: instances and positives in the left branch (x ≤ v).
#[inline]
pub fn gini(n: u32, n_pos: u32, n_l: u32, n_l_pos: u32) -> f64 {
    debug_assert!(n_l <= n && n_l_pos <= n_pos);
    let n_r = n - n_l;
    let n_r_pos = n_pos - n_l_pos;
    let side = |nb: u32, nb_pos: u32| -> f64 {
        if nb == 0 {
            return 0.0;
        }
        let p1 = nb_pos as f64 / nb as f64;
        let p0 = 1.0 - p1;
        (nb as f64 / n as f64) * (1.0 - p1 * p1 - p0 * p0)
    };
    side(n_l, n_l_pos) + side(n_r, n_r_pos)
}

/// Weighted entropy of a binary split (Eq. 3). Lower is better.
#[inline]
pub fn entropy(n: u32, n_pos: u32, n_l: u32, n_l_pos: u32) -> f64 {
    debug_assert!(n_l <= n && n_l_pos <= n_pos);
    let n_r = n - n_l;
    let n_r_pos = n_pos - n_l_pos;
    let h = |p: f64| -> f64 {
        if p <= 0.0 || p >= 1.0 {
            0.0
        } else {
            -p * p.log2() - (1.0 - p) * (1.0 - p).log2()
        }
    };
    let side = |nb: u32, nb_pos: u32| -> f64 {
        if nb == 0 {
            return 0.0;
        }
        (nb as f64 / n as f64) * h(nb_pos as f64 / nb as f64)
    };
    side(n_l, n_l_pos) + side(n_r, n_r_pos)
}

/// Dispatch on the configured criterion.
#[inline]
pub fn split_score(c: SplitCriterion, n: u32, n_pos: u32, n_l: u32, n_l_pos: u32) -> f64 {
    match c {
        SplitCriterion::Gini => gini(n, n_pos, n_l, n_l_pos),
        SplitCriterion::Entropy => entropy(n, n_pos, n_l, n_l_pos),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gini_pure_split_is_zero() {
        // 4 instances, 2 pos; left = both pos, right = both neg
        assert_eq!(gini(4, 2, 2, 2), 0.0);
    }

    #[test]
    fn gini_useless_split_max() {
        // 50/50 at node and in both branches → 0.5
        let g = gini(8, 4, 4, 2);
        assert!((g - 0.5).abs() < 1e-12);
    }

    #[test]
    fn gini_known_value() {
        // n=10, pos=4; left: 6 instances 1 pos; right: 4 instances 3 pos
        // left gini = 1 - (1/6)^2 - (5/6)^2 = 10/36; right = 1 - 9/16 - 1/16 = 6/16
        let expect = 0.6 * (10.0 / 36.0) + 0.4 * (6.0 / 16.0);
        assert!((gini(10, 4, 6, 1) - expect).abs() < 1e-12);
    }

    #[test]
    fn entropy_pure_split_is_zero() {
        assert_eq!(entropy(4, 2, 2, 2), 0.0);
    }

    #[test]
    fn entropy_useless_split_is_one() {
        assert!((entropy(8, 4, 4, 2) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn entropy_known_value() {
        // left: 2 of 4 pos → H=1, weight 0.5; right: 0 of 4 → H=0
        assert!((entropy(8, 2, 4, 2) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn empty_side_contributes_zero() {
        assert!(gini(5, 2, 0, 0).is_finite());
        assert!(entropy(5, 2, 0, 0).is_finite());
        assert!((gini(5, 2, 5, 2) - gini(5, 2, 0, 0)).abs() < 1e-12);
    }

    #[test]
    fn informative_beats_uninformative() {
        for c in [SplitCriterion::Gini, SplitCriterion::Entropy] {
            let good = split_score(c, 100, 50, 50, 45); // mostly separates
            let bad = split_score(c, 100, 50, 50, 25); // no separation
            assert!(good < bad, "{c:?}: {good} !< {bad}");
        }
    }

    #[test]
    fn symmetry_left_right() {
        // swapping branch contents leaves the weighted score unchanged
        for c in [SplitCriterion::Gini, SplitCriterion::Entropy] {
            let a = split_score(c, 10, 4, 6, 1);
            let b = split_score(c, 10, 4, 4, 3); // complementary branch
            assert!((a - b).abs() < 1e-12);
        }
    }
}
