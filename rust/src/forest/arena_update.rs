//! Deletion, addition and deletion-cost dry runs over [`ArenaTree`] storage —
//! the arena port of `forest::delete` (paper Alg. 2 / §6), preserving its
//! control flow, RNG stream consumption and retrain triggers exactly, so an
//! arena tree evolves bit-identically (`structural_eq`) to the boxed
//! implementation under any delete/add sequence. The boxed path stays in the
//! crate as the oracle; the equivalence is enforced by this module's tests
//! and `tests/arena_churn.rs`.
//!
//! Structure updates reuse the same primitives as the boxed path
//! (`ThresholdStats::remove`/`add`, `resample_invalid`, `select_best`,
//! `workspace::train_subtree`); subtree retrains are grafted into the arena
//! in deterministic BFS order with freed slots recycled LIFO, so node
//! allocation is a pure function of the operation sequence (DESIGN.md §7).
//!
//! Since the lazy pipeline (DESIGN.md §9) the walks are parameterized by a
//! [`RetrainSink`]: the *stats* half (count updates, threshold maintenance,
//! Lemma-A.1 resampling, argmax re-selection) runs inline, while the
//! *structural* half (every `train_subtree` rebuild) is routed through the
//! sink. [`EagerSink`] trains in place — the historical behavior, used by
//! the public [`delete`]/[`add`] wrappers — and `forest::lazy::LazySink`
//! records the rebuild as a pending subtree to be flushed later. The hooks
//! ([`RetrainSink::enter`], [`RetrainSink::before_collect`]) exist so the
//! lazy sink can materialize pending regions *before* the walk inspects or
//! gathers them, which keeps every observable the walk reads — structure,
//! gathered id order, RNG draws — identical to the eager path.
//!
//! Under Occ(q) subsampling (DESIGN.md §13) these walks are only ever
//! entered for instances the tree *owns*: the ownership gate lives one
//! layer up (`forest::forest::owns`, consulted by `DareForest` and the
//! sharded store before dispatching), so within this module a tree's
//! instance universe is its owned id set and nothing here changes — the
//! same property that lets a q<1 tree be differentially tested against a
//! from-scratch oracle trained on exactly its owned ids.

use crate::data::dataset::InstanceId;
use crate::forest::arena::{leaf_value, ArenaTree, Cold, NIL};
use crate::forest::criterion::split_score;
use crate::forest::delete::{delete_rng, DeleteReport, RetrainEvent};
use crate::forest::stats::{enumerate_valid, resample_invalid, sample_thresholds, AttrStats};
use crate::forest::train::{child_path, gather_pairs, partition, select_best, TrainCtx, ROOT_PATH};
use crate::forest::workspace::train_subtree;

/// How the delete/add walks execute subtree rebuilds (the `train_subtree`
/// halves of Alg. 2 / §6). Implementations must leave the arena in the
/// state the eager path would observe at every hook return — that is the
/// whole exactness contract of the lazy pipeline (DESIGN.md §9).
pub(crate) trait RetrainSink {
    /// Runs at the top of every node visit, before the node's kind is
    /// inspected. The lazy sink flushes a pending subtree here so the walk
    /// below always sees eager-accurate structure.
    fn enter(&mut self, t: &mut ArenaTree, ctx: &TrainCtx<'_>, nid: u32);

    /// Runs before the walk gathers a subtree's instance ids
    /// (`collect_ids`). The lazy sink materializes pending descendants so
    /// the gathered id *order* — which feeds `train_subtree` and leaf
    /// payloads, and therefore serialized bytes — matches the eager path.
    fn before_collect(&mut self, t: &mut ArenaTree, ctx: &TrainCtx<'_>, nid: u32);

    /// Replace the subtree at `nid` with a retrain over `ids` (seeded by
    /// `(ctx.tree_seed, path)`, so execution time cannot change the result).
    fn retrain_node(
        &mut self,
        t: &mut ArenaTree,
        ctx: &TrainCtx<'_>,
        nid: u32,
        ids: Vec<InstanceId>,
        depth: usize,
        path: u64,
    );

    /// Replace `nid`'s children after its split moved to `(attr, v)`:
    /// retrain the two children on the given partition (child paths derived
    /// from `path`/`depth` exactly as the eager code does).
    #[allow(clippy::too_many_arguments)]
    fn retrain_children(
        &mut self,
        t: &mut ArenaTree,
        ctx: &TrainCtx<'_>,
        nid: u32,
        attr: usize,
        v: f32,
        left_ids: Vec<InstanceId>,
        right_ids: Vec<InstanceId>,
        depth: usize,
        path: u64,
    );
}

/// The historical in-place executor: every rebuild trains immediately.
pub(crate) struct EagerSink;

impl RetrainSink for EagerSink {
    fn enter(&mut self, _t: &mut ArenaTree, _ctx: &TrainCtx<'_>, _nid: u32) {}
    fn before_collect(&mut self, _t: &mut ArenaTree, _ctx: &TrainCtx<'_>, _nid: u32) {}

    fn retrain_node(
        &mut self,
        t: &mut ArenaTree,
        ctx: &TrainCtx<'_>,
        nid: u32,
        ids: Vec<InstanceId>,
        depth: usize,
        path: u64,
    ) {
        let node = train_subtree(ctx, ids, depth, path);
        t.replace_node(nid, node);
    }

    fn retrain_children(
        &mut self,
        t: &mut ArenaTree,
        ctx: &TrainCtx<'_>,
        nid: u32,
        attr: usize,
        v: f32,
        left_ids: Vec<InstanceId>,
        right_ids: Vec<InstanceId>,
        depth: usize,
        path: u64,
    ) {
        let left = train_subtree(ctx, left_ids, depth + 1, child_path(path, depth, false));
        let right = train_subtree(ctx, right_ids, depth + 1, child_path(path, depth, true));
        t.replace_children(nid, attr, v, left, right);
    }
}

/// Delete instance `id` from the arena tree (paper Alg. 2). `ctx.data` must
/// still contain the instance; `epoch` is the tree's update counter feeding
/// the Lemma-A.1 resampling streams.
pub fn delete(
    t: &mut ArenaTree,
    ctx: &TrainCtx<'_>,
    id: InstanceId,
    epoch: u64,
    report: &mut DeleteReport,
) {
    delete_with(t, ctx, id, epoch, report, &mut EagerSink);
}

/// [`delete`] with an explicit executor (the lazy mark phase routes here).
pub(crate) fn delete_with<S: RetrainSink>(
    t: &mut ArenaTree,
    ctx: &TrainCtx<'_>,
    id: InstanceId,
    epoch: u64,
    report: &mut DeleteReport,
    sink: &mut S,
) {
    let root = t.root();
    delete_at(t, ctx, root, id, 0, ROOT_PATH, epoch, report, sink);
}

#[allow(clippy::too_many_arguments)]
fn delete_at<S: RetrainSink>(
    t: &mut ArenaTree,
    ctx: &TrainCtx<'_>,
    nid: u32,
    id: InstanceId,
    depth: usize,
    path: u64,
    epoch: u64,
    report: &mut DeleteReport,
    sink: &mut S,
) {
    sink.enter(t, ctx, nid);
    let y = ctx.data.y(id);
    let ni = nid as usize;

    // ---- leaf: Alg. 2 lines 3–6 -----------------------------------------
    if t.hot.left[ni] == NIL {
        {
            let Cold::Leaf { ids } = &mut t.cold[ni] else {
                unreachable!("leaf-shaped slot without leaf payload");
            };
            let pos = ids
                .iter()
                .position(|&i| i == id)
                .expect("deleting an instance absent from its leaf");
            ids.swap_remove(pos);
        }
        let n_now = t.n[ni] - 1;
        let pos_now = t.n_pos[ni] - y as u32;
        t.n[ni] = n_now;
        t.n_pos[ni] = pos_now;
        t.hot.value[ni] = leaf_value(n_now, pos_now);
        return;
    }

    // ---- decision node ----------------------------------------------------
    let n_new = t.n[ni] - 1;
    let pos_new = t.n_pos[ni] - y as u32;

    // Collapse to a leaf when scratch training would stop here now.
    if n_new < ctx.params.min_samples_split as u32 || pos_new == 0 || pos_new == n_new {
        sink.before_collect(t, ctx, nid);
        let mut ids = Vec::with_capacity(n_new as usize);
        t.collect_ids(nid, Some(id), &mut ids);
        report.retrain_events.push(RetrainEvent { depth, n: n_new });
        t.collapse_to_leaf(nid, ctx.data, ids);
        return;
    }

    if matches!(&t.cold[ni], Cold::Random { .. }) {
        delete_random_at(t, ctx, nid, id, n_new, pos_new, depth, path, epoch, report, sink);
    } else {
        delete_greedy_at(
            t, ctx, nid, id, y, n_new, pos_new, depth, path, epoch, report, sink,
        );
    }
}

#[allow(clippy::too_many_arguments)]
fn delete_random_at<S: RetrainSink>(
    t: &mut ArenaTree,
    ctx: &TrainCtx<'_>,
    nid: u32,
    id: InstanceId,
    n_new: u32,
    pos_new: u32,
    depth: usize,
    path: u64,
    epoch: u64,
    report: &mut DeleteReport,
    sink: &mut S,
) {
    let ni = nid as usize;
    // stage 1: update counts; decide whether the threshold fell out of range
    let xa = ctx.data.x(id, t.hot.attr[ni] as usize);
    let goes_left = xa <= t.hot.thresh[ni];
    let needs_retrain = {
        let Cold::Random { n_left, n_right } = &mut t.cold[ni] else {
            unreachable!("delete_random_at on non-random node");
        };
        if goes_left {
            *n_left -= 1;
        } else {
            *n_right -= 1;
        }
        *n_left == 0 || *n_right == 0
    };
    t.n[ni] = n_new;
    t.n_pos[ni] = pos_new;

    if needs_retrain {
        // Threshold no longer inside [a_min, a_max): retrain this node with
        // its path seed — identical to scratch training on the updated data
        // (Alg. 2 lines 10–17, derandomized; DESIGN.md §5).
        sink.before_collect(t, ctx, nid);
        let mut ids = Vec::with_capacity(n_new as usize);
        t.collect_ids(nid, Some(id), &mut ids);
        report.retrain_events.push(RetrainEvent { depth, n: n_new });
        sink.retrain_node(t, ctx, nid, ids, depth, path);
        return;
    }

    let next = if goes_left {
        t.hot.left[ni]
    } else {
        t.hot.right[ni]
    };
    delete_at(
        t,
        ctx,
        next,
        id,
        depth + 1,
        child_path(path, depth, !goes_left),
        epoch,
        report,
        sink,
    );
}

#[allow(clippy::too_many_arguments)]
fn delete_greedy_at<S: RetrainSink>(
    t: &mut ArenaTree,
    ctx: &TrainCtx<'_>,
    nid: u32,
    id: InstanceId,
    y: u8,
    n_new: u32,
    pos_new: u32,
    depth: usize,
    path: u64,
    epoch: u64,
    report: &mut DeleteReport,
    sink: &mut S,
) {
    let ni = nid as usize;
    // stage 1: update node + threshold statistics (Alg. 2 line 8): O(p̃·k)
    t.n[ni] = n_new;
    t.n_pos[ni] = pos_new;
    let (old_attr, old_v, any_invalid) = {
        let Cold::Greedy {
            attrs,
            best_attr,
            best_thr,
        } = &mut t.cold[ni]
        else {
            unreachable!("delete_greedy_at on non-greedy node");
        };
        let old_attr = attrs[*best_attr].attr;
        let old_v = attrs[*best_attr].thresholds[*best_thr].v;
        let mut any_invalid = false;
        for a in attrs.iter_mut() {
            let xa = ctx.data.x(id, a.attr);
            for th in a.thresholds.iter_mut() {
                th.remove(xa, y);
                any_invalid |= !th.is_valid();
            }
        }
        (old_attr, old_v, any_invalid)
    };

    // stage 2: resample invalidated thresholds / attributes (Lemma A.1);
    // requires gathering the node's data from its leaves (§3.1).
    let mut gathered: Option<Vec<InstanceId>> = None;
    if any_invalid {
        sink.before_collect(t, ctx, nid);
        let mut ids = Vec::with_capacity(n_new as usize);
        t.collect_ids(nid, Some(id), &mut ids);

        let made_leaf = {
            let mut rng = delete_rng(ctx.tree_seed, path, epoch);
            let Cold::Greedy { attrs, .. } = &mut t.cold[ni] else {
                unreachable!()
            };
            let mut dead_slots: Vec<usize> = Vec::new();
            for (slot, a) in attrs.iter_mut().enumerate() {
                if a.thresholds.iter().all(|th| th.is_valid()) {
                    continue;
                }
                let mut pairs = gather_pairs(ctx.data, &ids, a.attr);
                let candidates = enumerate_valid(&mut pairs);
                report.thresholds_resampled +=
                    resample_invalid(&mut a.thresholds, &candidates, ctx.params.k, &mut rng)
                        as u64;
                if a.thresholds.is_empty() {
                    dead_slots.push(slot);
                }
            }
            // Attributes with no remaining valid thresholds are replaced by
            // uniformly drawn valid attributes (§A.1).
            if !dead_slots.is_empty() {
                let in_use: Vec<usize> = attrs.iter().map(|a| a.attr).collect();
                let p = ctx.data.n_features();
                let mut pool: Vec<usize> = (0..p).filter(|a| !in_use.contains(a)).collect();
                rng.shuffle(&mut pool);
                let mut pool_iter = pool.into_iter();
                for slot in dead_slots {
                    for attr in pool_iter.by_ref() {
                        let mut pairs = gather_pairs(ctx.data, &ids, attr);
                        let candidates = enumerate_valid(&mut pairs);
                        if candidates.is_empty() {
                            continue;
                        }
                        attrs[slot] = AttrStats {
                            attr,
                            thresholds: sample_thresholds(candidates, ctx.params.k, &mut rng),
                        };
                        report.attrs_resampled += 1;
                        break;
                    }
                }
                attrs.retain(|a| !a.thresholds.is_empty());
            }
            attrs.is_empty()
        };

        if made_leaf {
            // No valid split exists anywhere anymore: leaf.
            report.retrain_events.push(RetrainEvent { depth, n: n_new });
            t.collapse_to_leaf(nid, ctx.data, ids);
            return;
        }
        gathered = Some(ids);
    }

    // stage 3: recompute scores from cached counts, select the optimum
    // (Alg. 2 lines 23–24).
    let (new_attr, new_v) = {
        let Cold::Greedy {
            attrs,
            best_attr,
            best_thr,
        } = &mut t.cold[ni]
        else {
            unreachable!()
        };
        let (ba, bt) = select_best(n_new, pos_new, attrs, ctx.params).expect("attrs non-empty");
        *best_attr = ba;
        *best_thr = bt;
        (attrs[ba].attr, attrs[ba].thresholds[bt].v)
    };

    if new_attr != old_attr || new_v != old_v {
        // Optimal split changed: retrain both children on the new partition
        // (Alg. 2 lines 25–27).
        let ids = match gathered {
            Some(ids) => ids,
            None => {
                sink.before_collect(t, ctx, nid);
                let mut v = Vec::with_capacity(n_new as usize);
                t.collect_ids(nid, Some(id), &mut v);
                v
            }
        };
        report.retrain_events.push(RetrainEvent { depth, n: n_new });
        let (left_ids, right_ids) = partition(ctx.data, &ids, new_attr, new_v);
        debug_assert!(!left_ids.is_empty() && !right_ids.is_empty());
        sink.retrain_children(
            t, ctx, nid, new_attr, new_v, left_ids, right_ids, depth, path,
        );
        return;
    }

    // stage 4: split unchanged — keep the hot plane aligned with the
    // (possibly re-indexed) cold split and continue down the branch.
    t.refresh_greedy_split(nid);
    let xa = ctx.data.x(id, new_attr);
    let goes_left = xa <= new_v;
    let next = if goes_left {
        t.hot.left[ni]
    } else {
        t.hot.right[ni]
    };
    delete_at(
        t,
        ctx,
        next,
        id,
        depth + 1,
        child_path(path, depth, !goes_left),
        epoch,
        report,
        sink,
    );
}

/// Non-mutating estimate of the retrain cost of deleting `id` — the arena
/// port of `forest::delete::delete_cost` (worst-of-1000 adversary signal).
pub fn delete_cost(t: &ArenaTree, ctx: &TrainCtx<'_>, id: InstanceId) -> u64 {
    cost_at(t, ctx, t.root(), id)
}

fn cost_at(t: &ArenaTree, ctx: &TrainCtx<'_>, nid: u32, id: InstanceId) -> u64 {
    let ni = nid as usize;
    if t.hot.left[ni] == NIL {
        return 0;
    }
    let y = ctx.data.y(id);
    let n_new = t.n[ni] - 1;
    let pos_new = t.n_pos[ni] - y as u32;
    if n_new < ctx.params.min_samples_split as u32 || pos_new == 0 || pos_new == n_new {
        return n_new as u64;
    }
    match &t.cold[ni] {
        Cold::Random { n_left, n_right } => {
            let xa = ctx.data.x(id, t.hot.attr[ni] as usize);
            let goes_left = xa <= t.hot.thresh[ni];
            let (nl, nr) = if goes_left {
                (*n_left - 1, *n_right)
            } else {
                (*n_left, *n_right - 1)
            };
            if nl == 0 || nr == 0 {
                return n_new as u64;
            }
            let next = if goes_left {
                t.hot.left[ni]
            } else {
                t.hot.right[ni]
            };
            cost_at(t, ctx, next, id)
        }
        Cold::Greedy {
            attrs,
            best_attr,
            best_thr,
        } => {
            let old_attr = attrs[*best_attr].attr;
            let old_v = attrs[*best_attr].thresholds[*best_thr].v;
            // Find the best split over decremented, still-valid thresholds.
            let mut best: Option<(usize, f32, f64)> = None;
            let mut chosen_invalid = false;
            for a in attrs {
                let xa = ctx.data.x(id, a.attr);
                for th in &a.thresholds {
                    let mut tt = *th;
                    tt.remove(xa, y);
                    let is_chosen = a.attr == old_attr && th.v == old_v;
                    if !tt.is_valid() {
                        if is_chosen {
                            chosen_invalid = true;
                        }
                        continue;
                    }
                    let s = split_score(
                        ctx.params.criterion,
                        n_new,
                        pos_new,
                        tt.n_left,
                        tt.n_left_pos,
                    );
                    match best {
                        Some((_, _, bs)) if s >= bs => {}
                        _ => best = Some((a.attr, th.v, s)),
                    }
                }
            }
            if chosen_invalid {
                return n_new as u64; // pessimistic: resampling may move the split
            }
            match best {
                Some((ba, bv, _)) if ba == old_attr && bv == old_v => {
                    let xa = ctx.data.x(id, old_attr);
                    let next = if xa <= old_v {
                        t.hot.left[ni]
                    } else {
                        t.hot.right[ni]
                    };
                    cost_at(t, ctx, next, id)
                }
                _ => n_new as u64,
            }
        }
        _ => unreachable!("decision-shaped slot without decision payload"),
    }
}

/// Add an instance (already inserted into the dataset) to the arena tree —
/// the §6 continual-learning extension, mirroring `forest::delete::add`.
pub fn add(
    t: &mut ArenaTree,
    ctx: &TrainCtx<'_>,
    id: InstanceId,
    epoch: u64,
    report: &mut DeleteReport,
) {
    add_with(t, ctx, id, epoch, report, &mut EagerSink);
}

/// [`add`] with an explicit executor (the lazy mark phase routes here).
pub(crate) fn add_with<S: RetrainSink>(
    t: &mut ArenaTree,
    ctx: &TrainCtx<'_>,
    id: InstanceId,
    epoch: u64,
    report: &mut DeleteReport,
    sink: &mut S,
) {
    let root = t.root();
    add_at(t, ctx, root, id, 0, ROOT_PATH, epoch, report, sink);
}

#[allow(clippy::too_many_arguments)]
fn add_at<S: RetrainSink>(
    t: &mut ArenaTree,
    ctx: &TrainCtx<'_>,
    nid: u32,
    id: InstanceId,
    depth: usize,
    path: u64,
    epoch: u64,
    report: &mut DeleteReport,
    sink: &mut S,
) {
    sink.enter(t, ctx, nid);
    let y = ctx.data.y(id);
    let ni = nid as usize;

    // ---- leaf ----------------------------------------------------------
    if t.hot.left[ni] == NIL {
        {
            let Cold::Leaf { ids } = &mut t.cold[ni] else {
                unreachable!("leaf-shaped slot without leaf payload");
            };
            ids.push(id);
        }
        let n_now = t.n[ni] + 1;
        let pos_now = t.n_pos[ni] + y as u32;
        t.n[ni] = n_now;
        t.n_pos[ni] = pos_now;
        t.hot.value[ni] = leaf_value(n_now, pos_now);
        // A leaf that scratch training would now split gets rebuilt (it may
        // have stopped on purity / size before this addition).
        let should_split = n_now >= ctx.params.min_samples_split as u32
            && pos_now > 0
            && pos_now < n_now
            && depth < ctx.params.max_depth;
        if should_split {
            let ids = {
                let Cold::Leaf { ids } = &mut t.cold[ni] else {
                    unreachable!()
                };
                std::mem::take(ids)
            };
            report.retrain_events.push(RetrainEvent {
                depth,
                n: ids.len() as u32,
            });
            sink.retrain_node(t, ctx, nid, ids, depth, path);
        }
        return;
    }

    if matches!(&t.cold[ni], Cold::Random { .. }) {
        let xa = ctx.data.x(id, t.hot.attr[ni] as usize);
        let goes_left = xa <= t.hot.thresh[ni];
        {
            let Cold::Random { n_left, n_right } = &mut t.cold[ni] else {
                unreachable!()
            };
            if goes_left {
                *n_left += 1;
            } else {
                *n_right += 1;
            }
        }
        t.n[ni] += 1;
        t.n_pos[ni] += y as u32;
        let next = if goes_left {
            t.hot.left[ni]
        } else {
            t.hot.right[ni]
        };
        add_at(
            t,
            ctx,
            next,
            id,
            depth + 1,
            child_path(path, depth, !goes_left),
            epoch,
            report,
            sink,
        );
        return;
    }

    // ---- greedy node ------------------------------------------------------
    // stage 1: update stats; detect thresholds whose adjacency the new value
    // breaks (x strictly between v_low and v_high).
    let n_now = t.n[ni] + 1;
    let pos_now = t.n_pos[ni] + y as u32;
    t.n[ni] = n_now;
    t.n_pos[ni] = pos_now;
    let (old_attr, old_v, any_broken) = {
        let Cold::Greedy {
            attrs,
            best_attr,
            best_thr,
        } = &mut t.cold[ni]
        else {
            unreachable!("add_at greedy on non-greedy node");
        };
        let old_attr = attrs[*best_attr].attr;
        let old_v = attrs[*best_attr].thresholds[*best_thr].v;
        let mut any_broken = false;
        for a in attrs.iter_mut() {
            let xa = ctx.data.x(id, a.attr);
            for th in a.thresholds.iter_mut() {
                if th.adjacency_broken(xa) {
                    any_broken = true;
                    th.n_low = 0; // force invalid so the resampler replaces it
                } else {
                    th.add(xa, y);
                }
            }
        }
        (old_attr, old_v, any_broken)
    };

    // stage 2: resample broken thresholds over the updated data.
    if any_broken {
        sink.before_collect(t, ctx, nid);
        let mut ids = Vec::new();
        t.collect_ids(nid, None, &mut ids);
        ids.push(id); // leaves below don't know the new instance yet

        let made_leafless = {
            let mut rng = delete_rng(ctx.tree_seed, path, 0xADD ^ epoch);
            let Cold::Greedy { attrs, .. } = &mut t.cold[ni] else {
                unreachable!()
            };
            for a in attrs.iter_mut() {
                if a.thresholds.iter().all(|th| th.is_valid()) {
                    continue;
                }
                let mut pairs = gather_pairs(ctx.data, &ids, a.attr);
                let candidates = enumerate_valid(&mut pairs);
                report.thresholds_resampled +=
                    resample_invalid(&mut a.thresholds, &candidates, ctx.params.k, &mut rng)
                        as u64;
            }
            attrs.retain(|a| !a.thresholds.is_empty());
            attrs.is_empty()
        };
        if made_leafless {
            report.retrain_events.push(RetrainEvent {
                depth,
                n: ids.len() as u32,
            });
            sink.retrain_node(t, ctx, nid, ids, depth, path);
            return;
        }
    }

    // stage 3: re-select optimum; retrain children if it moved.
    let (new_attr, new_v) = {
        let Cold::Greedy {
            attrs,
            best_attr,
            best_thr,
        } = &mut t.cold[ni]
        else {
            unreachable!()
        };
        let (ba, bt) = select_best(n_now, pos_now, attrs, ctx.params).expect("attrs");
        *best_attr = ba;
        *best_thr = bt;
        (attrs[ba].attr, attrs[ba].thresholds[bt].v)
    };

    if new_attr != old_attr || new_v != old_v {
        sink.before_collect(t, ctx, nid);
        let mut ids = Vec::new();
        t.collect_ids(nid, None, &mut ids);
        if !ids.contains(&id) {
            ids.push(id);
        }
        report.retrain_events.push(RetrainEvent {
            depth,
            n: ids.len() as u32,
        });
        let (left_ids, right_ids) = partition(ctx.data, &ids, new_attr, new_v);
        sink.retrain_children(
            t, ctx, nid, new_attr, new_v, left_ids, right_ids, depth, path,
        );
        return;
    }

    // stage 4: split unchanged — re-align the hot split and recurse.
    t.refresh_greedy_split(nid);
    let xa = ctx.data.x(id, new_attr);
    let goes_left = xa <= new_v;
    let next = if goes_left {
        t.hot.left[ni]
    } else {
        t.hot.right[ni]
    };
    add_at(
        t,
        ctx,
        next,
        id,
        depth + 1,
        child_path(path, depth, !goes_left),
        epoch,
        report,
        sink,
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;
    use crate::data::synth::{generate, SynthSpec};
    use crate::forest::delete as boxed;
    use crate::forest::params::{MaxFeatures, Params};
    use crate::forest::train::{train, TrainCtx, ROOT_PATH};
    use crate::util::rng::Rng;

    fn data(n: usize, seed: u64) -> Dataset {
        generate(
            &SynthSpec {
                n,
                informative: 3,
                redundant: 1,
                noise: 2,
                flip: 0.1,
                ..Default::default()
            },
            seed,
        )
    }

    fn params(d_rmax: usize, k: usize) -> Params {
        Params {
            max_depth: 8,
            k,
            d_rmax,
            max_features: MaxFeatures::Sqrt,
            ..Default::default()
        }
    }

    /// The oracle harness: drive the boxed implementation and the arena with
    /// the same operation/epoch sequence and assert `structural_eq` + arena
    /// consistency throughout.
    fn churn(d_rmax: usize, k: usize, data_seed: u64, tree_seed: u64, ops: usize) {
        let mut d = data(260, data_seed);
        let p = params(d_rmax, k);
        let ctx_seed = tree_seed;
        let mut boxed_root = {
            let ctx = TrainCtx {
                data: &d,
                params: &p,
                tree_seed: ctx_seed,
            };
            train(&ctx, d.live_ids(), 0, ROOT_PATH)
        };
        let mut arena = ArenaTree::from_node({
            let ctx = TrainCtx {
                data: &d,
                params: &p,
                tree_seed: ctx_seed,
            };
            train(&ctx, d.live_ids(), 0, ROOT_PATH)
        });
        let mut rng = Rng::new(data_seed ^ 0xC0FFEE);
        for epoch in 0..ops as u64 {
            let do_delete = d.n_alive() > 40 && rng.bernoulli(0.7);
            if do_delete {
                let live = d.live_ids();
                let id = live[rng.index(live.len())];
                let mut ra = DeleteReport::default();
                let mut rb = DeleteReport::default();
                {
                    let ctx = TrainCtx {
                        data: &d,
                        params: &p,
                        tree_seed: ctx_seed,
                    };
                    boxed::delete(&ctx, &mut boxed_root, id, 0, ROOT_PATH, epoch, &mut rb);
                    delete(&mut arena, &ctx, id, epoch, &mut ra);
                }
                assert_eq!(ra.cost(), rb.cost(), "epoch {epoch}: report cost diverged");
                assert_eq!(
                    ra.thresholds_resampled, rb.thresholds_resampled,
                    "epoch {epoch}: resample count diverged"
                );
                d.mark_removed(id);
            } else {
                let row: Vec<f32> = (0..d.n_features())
                    .map(|_| rng.range_f32(-3.0, 3.0))
                    .collect();
                let y = rng.bernoulli(0.5) as u8;
                let id = d.push_row(&row, y);
                let mut ra = DeleteReport::default();
                let mut rb = DeleteReport::default();
                {
                    let ctx = TrainCtx {
                        data: &d,
                        params: &p,
                        tree_seed: ctx_seed,
                    };
                    boxed::add(&ctx, &mut boxed_root, id, 0, ROOT_PATH, epoch, &mut rb);
                    add(&mut arena, &ctx, id, epoch, &mut ra);
                }
            }
            arena.validate().unwrap_or_else(|e| {
                panic!("arena inconsistent after epoch {epoch}: {e}")
            });
            assert!(
                arena.matches_node(&boxed_root),
                "arena diverged from boxed tree at epoch {epoch}"
            );
        }
        assert_eq!(arena.n_root() as usize, d.n_alive());
    }

    #[test]
    fn greedy_churn_matches_boxed() {
        churn(0, 5, 1, 3, 120);
    }

    #[test]
    fn random_layer_churn_matches_boxed() {
        churn(3, 5, 2, 4, 120);
    }

    #[test]
    fn exhaustive_k_churn_matches_boxed() {
        churn(0, 10_000, 3, 9, 60);
    }

    #[test]
    fn delete_cost_matches_boxed() {
        let d = data(220, 5);
        let p = params(2, 5);
        let ctx = TrainCtx {
            data: &d,
            params: &p,
            tree_seed: 13,
        };
        let root = train(&ctx, d.live_ids(), 0, ROOT_PATH);
        let arena = ArenaTree::from_node(train(&ctx, d.live_ids(), 0, ROOT_PATH));
        for id in d.live_ids().into_iter().take(80) {
            assert_eq!(
                delete_cost(&arena, &ctx, id),
                boxed::delete_cost(&ctx, &root, id, 0),
                "cost diverged for id {id}"
            );
        }
        // dry runs must not mutate the arena
        arena.validate().unwrap();
        assert!(arena.matches_node(&root));
    }

    #[test]
    fn delete_down_to_empty_leaf() {
        let mut d = data(60, 6);
        let p = params(1, 3);
        let ctx_seed = 5u64;
        let mut arena = ArenaTree::from_node({
            let ctx = TrainCtx {
                data: &d,
                params: &p,
                tree_seed: ctx_seed,
            };
            train(&ctx, d.live_ids(), 0, ROOT_PATH)
        });
        let ids = d.live_ids();
        for (epoch, id) in ids.into_iter().enumerate() {
            let ctx = TrainCtx {
                data: &d,
                params: &p,
                tree_seed: ctx_seed,
            };
            let mut report = DeleteReport::default();
            delete(&mut arena, &ctx, id, epoch as u64, &mut report);
            d.mark_removed(id);
            arena.validate().unwrap();
        }
        assert_eq!(arena.n_root(), 0);
        assert!(arena.is_leaf(arena.root()));
        assert_eq!(arena.predict(&[0.0; 6]), 0.5);
        // everything except the root slot must be back on the free list
        assert_eq!(arena.live_len(), 1);
    }
}
