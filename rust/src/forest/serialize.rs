//! Forest (de)serialization to JSON — model snapshots for the coordinator
//! and the `dare train --save` / `dare serve --load` CLI paths.
//!
//! The dataset is serialized alongside the trees: DaRE deletion requires the
//! training data (leaf instance pointers reference it), so a snapshot is only
//! self-contained with both.

use crate::data::dataset::Dataset;
use crate::forest::arena::{ArenaTree, Cold};
use crate::forest::forest::DareForest;
use crate::forest::node::{GreedyNode, LeafNode, Node, RandomNode};
use crate::forest::params::{MaxFeatures, Params, SplitCriterion};
use crate::forest::stats::{AttrStats, ThresholdStats};
use crate::forest::tree::DareTree;
use crate::util::json::{parse, Value};

/// Snapshot schema identifier; bumped only on incompatible layout changes
/// (the wire API's `load` op rejects snapshots with a different tag).
pub const SNAPSHOT_FORMAT: &str = "dare-forest-v1";

/// Schema tag for Occ(q)-subsampled forests (DESIGN.md §13). A q<1 snapshot
/// carries a `q` params key that a v1 reader would silently drop — and with
/// it the ownership gating that makes per-tree instance sets a strict subset
/// of the corpus — so subsampled snapshots get their own tag, while q=1.0
/// forests keep emitting byte-identical v1 snapshots.
pub const SNAPSHOT_FORMAT_V2: &str = "dare-forest-v2";

/// u64 values (seeds) exceed f64's exact-integer range; encode as strings.
fn set_u64(o: &mut Value, key: &str, v: u64) {
    o.set(key, v.to_string());
}

fn get_u64(v: &Value, key: &str) -> anyhow::Result<u64> {
    match v.get(key) {
        Some(Value::Str(s)) => s
            .parse::<u64>()
            .map_err(|e| anyhow::anyhow!("bad u64 field '{key}': {e}")),
        Some(Value::Num(n)) => Ok(*n as u64),
        _ => anyhow::bail!("u64 field '{key}' missing"),
    }
}

fn thr_to_json(t: &ThresholdStats) -> Value {
    let mut o = Value::obj();
    o.set("v", t.v)
        .set("vl", t.v_low)
        .set("vh", t.v_high)
        .set("nl", t.n_left)
        .set("nlp", t.n_left_pos)
        .set("clo", t.n_low)
        .set("clop", t.n_low_pos)
        .set("chi", t.n_high)
        .set("chip", t.n_high_pos);
    o
}

fn thr_from_json(v: &Value) -> anyhow::Result<ThresholdStats> {
    let g = |k: &str| -> anyhow::Result<f64> {
        v.get(k)
            .and_then(|x| x.as_f64())
            .ok_or_else(|| anyhow::anyhow!("threshold field '{k}' missing"))
    };
    Ok(ThresholdStats {
        v: g("v")? as f32,
        v_low: g("vl")? as f32,
        v_high: g("vh")? as f32,
        n_left: g("nl")? as u32,
        n_left_pos: g("nlp")? as u32,
        n_low: g("clo")? as u32,
        n_low_pos: g("clop")? as u32,
        n_high: g("chi")? as u32,
        n_high_pos: g("chip")? as u32,
    })
}

/// Emit one arena node (and its subtree) in the boxed-tree JSON schema,
/// walking the arena planes directly — no transient `Node` reconstruction,
/// so snapshotting never deep-clones the model.
fn arena_node_to_json(t: &ArenaTree, nid: u32) -> Value {
    let ni = nid as usize;
    let mut o = Value::obj();
    match &t.cold[ni] {
        Cold::Leaf { ids } => {
            o.set("t", "leaf")
                .set("n", t.n[ni])
                .set("np", t.n_pos[ni])
                .set("ids", ids.clone());
        }
        Cold::Random { n_left, n_right } => {
            o.set("t", "rand")
                .set("n", t.n[ni])
                .set("np", t.n_pos[ni])
                .set("a", t.hot.attr[ni] as usize)
                .set("v", t.hot.thresh[ni])
                .set("nl", *n_left)
                .set("nr", *n_right)
                .set("l", arena_node_to_json(t, t.hot.left[ni]))
                .set("r", arena_node_to_json(t, t.hot.right[ni]));
        }
        Cold::Greedy {
            attrs,
            best_attr,
            best_thr,
        } => {
            let attrs_json: Vec<Value> = attrs
                .iter()
                .map(|a| {
                    let mut ao = Value::obj();
                    ao.set("a", a.attr).set(
                        "thr",
                        Value::Arr(a.thresholds.iter().map(thr_to_json).collect()),
                    );
                    ao
                })
                .collect();
            o.set("t", "greedy")
                .set("n", t.n[ni])
                .set("np", t.n_pos[ni])
                .set("attrs", Value::Arr(attrs_json))
                .set("ba", *best_attr)
                .set("bt", *best_thr)
                .set("l", arena_node_to_json(t, t.hot.left[ni]))
                .set("r", arena_node_to_json(t, t.hot.right[ni]));
        }
        Cold::Free => unreachable!("serializing a free arena slot"),
    }
    o
}

/// `n_total` bounds leaf instance ids: a snapshot whose leaves point past
/// the serialized dataset would index out of bounds on the first retrain,
/// so it is rejected up front (the wire `load` op surfaces this as a
/// structured `bad_request`).
fn node_from_json(v: &Value, n_total: u32) -> anyhow::Result<Node> {
    let t = v
        .get("t")
        .and_then(|x| x.as_str())
        .ok_or_else(|| anyhow::anyhow!("node kind missing"))?;
    let num =
        |k: &str| -> anyhow::Result<u32> {
            v.get(k)
                .and_then(|x| x.as_u64())
                .map(|x| x as u32)
                .ok_or_else(|| anyhow::anyhow!("node field '{k}' missing"))
        };
    match t {
        "leaf" => {
            let ids = v
                .get("ids")
                .and_then(|x| x.as_arr())
                .ok_or_else(|| anyhow::anyhow!("leaf ids missing"))?
                .iter()
                .map(|x| {
                    let id = x
                        .as_u64()
                        .ok_or_else(|| anyhow::anyhow!("non-numeric leaf id"))?;
                    anyhow::ensure!(
                        id < n_total as u64,
                        "leaf id {id} out of range (dataset has {n_total} rows)"
                    );
                    Ok(id as u32)
                })
                .collect::<anyhow::Result<Vec<u32>>>()?;
            Ok(Node::Leaf(LeafNode {
                n: num("n")?,
                n_pos: num("np")?,
                ids,
            }))
        }
        "rand" => Ok(Node::Random(RandomNode {
            n: num("n")?,
            n_pos: num("np")?,
            attr: num("a")? as usize,
            v: v.get("v").and_then(|x| x.as_f64()).unwrap_or(0.0) as f32,
            n_left: num("nl")?,
            n_right: num("nr")?,
            left: Box::new(node_from_json(
                v.get("l").ok_or_else(|| anyhow::anyhow!("left missing"))?,
                n_total,
            )?),
            right: Box::new(node_from_json(
                v.get("r").ok_or_else(|| anyhow::anyhow!("right missing"))?,
                n_total,
            )?),
        })),
        "greedy" => {
            let attrs_json = v
                .get("attrs")
                .and_then(|x| x.as_arr())
                .ok_or_else(|| anyhow::anyhow!("attrs missing"))?;
            let mut attrs = Vec::with_capacity(attrs_json.len());
            for a in attrs_json {
                let attr = a
                    .get("a")
                    .and_then(|x| x.as_usize())
                    .ok_or_else(|| anyhow::anyhow!("attr id missing"))?;
                let thr = a
                    .get("thr")
                    .and_then(|x| x.as_arr())
                    .ok_or_else(|| anyhow::anyhow!("thresholds missing"))?
                    .iter()
                    .map(thr_from_json)
                    .collect::<anyhow::Result<Vec<_>>>()?;
                attrs.push(AttrStats {
                    attr,
                    thresholds: thr,
                });
            }
            Ok(Node::Greedy(GreedyNode {
                n: num("n")?,
                n_pos: num("np")?,
                attrs,
                best_attr: num("ba")? as usize,
                best_thr: num("bt")? as usize,
                left: Box::new(node_from_json(
                    v.get("l").ok_or_else(|| anyhow::anyhow!("left missing"))?,
                    n_total,
                )?),
                right: Box::new(node_from_json(
                    v.get("r").ok_or_else(|| anyhow::anyhow!("right missing"))?,
                    n_total,
                )?),
            }))
        }
        _ => anyhow::bail!("unknown node kind '{t}'"),
    }
}

fn params_to_json(p: &Params) -> Value {
    let mut o = Value::obj();
    o.set("n_trees", p.n_trees)
        .set("max_depth", p.max_depth)
        .set("k", p.k)
        .set("d_rmax", p.d_rmax)
        .set(
            "criterion",
            match p.criterion {
                SplitCriterion::Gini => "gini",
                SplitCriterion::Entropy => "entropy",
            },
        )
        .set(
            "max_features",
            match p.max_features {
                MaxFeatures::Sqrt => "sqrt".to_string(),
                MaxFeatures::All => "all".to_string(),
                MaxFeatures::Fixed(n) => n.to_string(),
            },
        )
        .set("min_samples_split", p.min_samples_split)
        .set("n_threads", p.n_threads);
    // Emitted only when subsampled: q=1.0 snapshots must stay byte-identical
    // to the pre-Occ(q) format (acceptance bar for DESIGN.md §13).
    if p.subsampled() {
        o.set("q", p.q);
    }
    o
}

fn params_from_json(v: &Value) -> anyhow::Result<Params> {
    let get = |k: &str| -> anyhow::Result<usize> {
        v.get(k)
            .and_then(|x| x.as_usize())
            .ok_or_else(|| anyhow::anyhow!("params field '{k}' missing"))
    };
    let mf = match v.get("max_features").and_then(|x| x.as_str()) {
        Some("sqrt") | None => MaxFeatures::Sqrt,
        Some("all") => MaxFeatures::All,
        Some(s) => MaxFeatures::Fixed(s.parse::<usize>().unwrap_or(1)),
    };
    Ok(Params {
        n_trees: get("n_trees")?,
        max_depth: get("max_depth")?,
        k: get("k")?,
        d_rmax: get("d_rmax")?,
        criterion: v
            .get("criterion")
            .and_then(|x| x.as_str())
            .unwrap_or("gini")
            .parse()
            .map_err(|e: String| anyhow::anyhow!(e))?,
        max_features: mf,
        min_samples_split: get("min_samples_split")?,
        n_threads: get("n_threads").unwrap_or(1),
        // Absent in every v1 snapshot (full ownership); `from_parts` then
        // revalidates the declared q against each tree's leaf id sets.
        q: v.get("q").and_then(|x| x.as_f64()).unwrap_or(1.0),
    })
}

fn dataset_to_json(d: &Dataset) -> Value {
    // Store the full backing arrays including dead rows so instance ids in
    // leaf lists stay valid; liveness is reconstructed from the alive list.
    let n = d.n_total();
    let p = d.n_features();
    let mut cols: Vec<Value> = Vec::with_capacity(p);
    for j in 0..p {
        cols.push(Value::Arr(
            d.col(j).iter().map(|&x| Value::Num(x as f64)).collect(),
        ));
    }
    let labels: Vec<Value> = (0..n as u32).map(|i| Value::Num(d.y(i) as f64)).collect();
    let alive: Vec<Value> = (0..n as u32)
        .map(|i| Value::Bool(d.is_alive(i)))
        .collect();
    let mut o = Value::obj();
    o.set("cols", Value::Arr(cols))
        .set("labels", Value::Arr(labels))
        .set("alive", Value::Arr(alive));
    o
}

fn dataset_from_json(v: &Value) -> anyhow::Result<Dataset> {
    let cols_json = v
        .get("cols")
        .and_then(|x| x.as_arr())
        .ok_or_else(|| anyhow::anyhow!("dataset cols missing"))?;
    anyhow::ensure!(!cols_json.is_empty(), "dataset has no feature columns");
    let cols: Vec<Vec<f32>> = cols_json
        .iter()
        .map(|c| {
            c.as_arr()
                .map(|a| a.iter().map(|x| x.as_f64().unwrap_or(0.0) as f32).collect())
                .ok_or_else(|| anyhow::anyhow!("bad column"))
        })
        .collect::<anyhow::Result<_>>()?;
    // `Dataset::from_columns` asserts rectangularity; validate here so a
    // hand-edited or truncated snapshot surfaces a structured error rather
    // than a panic inside the data layer.
    let n = cols[0].len();
    anyhow::ensure!(n > 0, "dataset has no rows");
    for (j, c) in cols.iter().enumerate() {
        anyhow::ensure!(
            c.len() == n,
            "ragged dataset: column {j} has {} rows, column 0 has {n}",
            c.len()
        );
    }
    let labels: Vec<u8> = v
        .get("labels")
        .and_then(|x| x.as_arr())
        .ok_or_else(|| anyhow::anyhow!("labels missing"))?
        .iter()
        .map(|x| match x.as_u64() {
            Some(l @ (0 | 1)) => Ok(l as u8),
            Some(l) => anyhow::bail!("label {l} out of range (binary labels only)"),
            None => anyhow::bail!("non-numeric label"),
        })
        .collect::<anyhow::Result<_>>()?;
    anyhow::ensure!(
        labels.len() == n,
        "label count {} != row count {n}",
        labels.len()
    );
    let mut d = Dataset::from_columns(cols, labels);
    if let Some(alive) = v.get("alive").and_then(|x| x.as_arr()) {
        anyhow::ensure!(
            alive.len() == n,
            "alive mask length {} != row count {n}",
            alive.len()
        );
        for (i, a) in alive.iter().enumerate() {
            if a.as_bool() == Some(false) {
                d.mark_removed(i as u32);
            }
        }
    }
    Ok(d)
}

/// Serialize a forest (model + params + database) to a JSON string.
///
/// Refuses a forest with pending deferred retrains (DESIGN.md §9): baking
/// a pending leaf into a snapshot would silently freeze a non-eager model
/// (the dirty set is not part of the schema), so callers must
/// `flush_all()` first — the sharded store's `snapshot()` does this
/// automatically.
pub fn forest_to_json(f: &DareForest) -> String {
    assert_eq!(
        f.dirty_subtrees(),
        0,
        "serializing a forest with pending deferred retrains — call flush_all() first"
    );
    let trees: Vec<Value> = f
        .trees()
        .iter()
        .map(|t| {
            let mut o = Value::obj();
            set_u64(&mut o, "seed", t.tree_seed);
            set_u64(&mut o, "epoch", t.epoch);
            // The snapshot format stays the boxed-tree JSON schema; the
            // emitter walks the arena in place (slot ids renumber on reload;
            // structure, stats and predictions are preserved — see tests).
            o.set("root", arena_node_to_json(&t.arena, t.arena.root()));
            o
        })
        .collect();
    let mut o = Value::obj();
    o.set(
        "format",
        if f.params().subsampled() {
            SNAPSHOT_FORMAT_V2
        } else {
            SNAPSHOT_FORMAT
        },
    );
    set_u64(&mut o, "seed", f.seed());
    o.set("params", params_to_json(f.params()))
        .set("trees", Value::Arr(trees))
        .set("data", dataset_to_json(f.data()));
    o.to_string()
}

/// Deserialize a forest from JSON produced by [`forest_to_json`].
pub fn forest_from_json(s: &str) -> anyhow::Result<DareForest> {
    let v = parse(s).map_err(|e| anyhow::anyhow!("{e}"))?;
    let format = v.get("format").and_then(|x| x.as_str());
    anyhow::ensure!(
        format == Some(SNAPSHOT_FORMAT) || format == Some(SNAPSHOT_FORMAT_V2),
        "unknown snapshot format (expected '{SNAPSHOT_FORMAT}' or '{SNAPSHOT_FORMAT_V2}')"
    );
    let params = params_from_json(v.get("params").ok_or_else(|| anyhow::anyhow!("params"))?)?;
    let seed = get_u64(&v, "seed")?;
    let data = dataset_from_json(v.get("data").ok_or_else(|| anyhow::anyhow!("data"))?)?;
    let trees_json = v
        .get("trees")
        .and_then(|x| x.as_arr())
        .ok_or_else(|| anyhow::anyhow!("trees missing"))?;
    let mut trees = Vec::with_capacity(trees_json.len());
    for t in trees_json {
        trees.push(DareTree::from_root(
            node_from_json(
                t.get("root").ok_or_else(|| anyhow::anyhow!("root"))?,
                data.n_total() as u32,
            )?,
            get_u64(t, "seed")?,
            get_u64(t, "epoch").unwrap_or(0),
        ));
    }
    DareForest::from_parts(params, seed, trees, data)
}

/// Save to a file, crash-safely: the snapshot is written to a temp file,
/// fsync'd, renamed over `path`, and the parent directory fsync'd — a crash
/// at any instant leaves either the old snapshot or the new one, never a
/// torn file (DESIGN.md §11).
pub fn save(f: &DareForest, path: &std::path::Path) -> anyhow::Result<()> {
    crate::util::fsio::atomic_write(path, forest_to_json(f).as_bytes())?;
    Ok(())
}

/// Load from a file.
pub fn load(path: &std::path::Path) -> anyhow::Result<DareForest> {
    let s = std::fs::read_to_string(path)?;
    forest_from_json(&s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};
    use crate::forest::tree::structural_eq;

    fn forest() -> DareForest {
        let data = generate(
            &SynthSpec {
                n: 150,
                informative: 3,
                redundant: 0,
                noise: 2,
                flip: 0.05,
                ..Default::default()
            },
            5,
        );
        let params = Params {
            n_trees: 3,
            max_depth: 5,
            k: 5,
            d_rmax: 1,
            ..Default::default()
        };
        DareForest::fit(data, &params, 77)
    }

    #[test]
    fn roundtrip_preserves_structure_and_predictions() {
        let f = forest();
        let json = forest_to_json(&f);
        let back = forest_from_json(&json).unwrap();
        assert_eq!(back.n_trees(), f.n_trees());
        assert_eq!(back.n_alive(), f.n_alive());
        for (a, b) in f.trees().iter().zip(back.trees()) {
            assert!(a.structural_matches(b));
            assert!(structural_eq(&a.root_node(), &b.root_node()));
            assert_eq!(a.tree_seed, b.tree_seed);
            b.arena.validate().unwrap();
        }
        let row = f.data().row(3);
        assert_eq!(f.predict_proba(&row), back.predict_proba(&row));
    }

    #[test]
    fn roundtrip_after_churn_preserves_structure_and_predictions() {
        // Deletions + additions leave the arenas non-BFS-compact with live
        // free lists; the snapshot must still round-trip to structurally
        // identical, fully-consistent trees with bit-equal predictions.
        let mut f = forest();
        let p = f.data().n_features();
        for id in [0u32, 7, 12, 33, 48] {
            f.delete(id).unwrap();
        }
        for i in 0..6 {
            f.add(&vec![0.25 * i as f32; p], (i % 2) as u8);
        }
        let back = forest_from_json(&forest_to_json(&f)).unwrap();
        assert_eq!(back.n_alive(), f.n_alive());
        for (a, b) in f.trees().iter().zip(back.trees()) {
            assert!(a.structural_matches(b));
            assert_eq!(a.epoch, b.epoch);
            b.arena.validate().unwrap();
        }
        let rows: Vec<Vec<f32>> = (0..60u32).map(|i| f.data().row(i)).collect();
        assert_eq!(f.predict_proba_rows(&rows), back.predict_proba_rows(&rows));
    }

    #[test]
    fn roundtrip_supports_further_deletions() {
        let mut f = forest();
        f.delete(0).unwrap();
        let json = forest_to_json(&f);
        let mut back = forest_from_json(&json).unwrap();
        // deleting the same id again fails (dead), a live one succeeds
        assert!(back.delete(0).is_err());
        back.delete(5).unwrap();
        assert_eq!(back.n_alive(), f.n_alive() - 1);
    }

    #[test]
    fn rejects_garbage() {
        assert!(forest_from_json("{}").is_err());
        assert!(forest_from_json("not json").is_err());
        assert!(forest_from_json(r#"{"format":"other"}"#).is_err());
    }

    /// A snapshot that parses as JSON but violates arity or value-range
    /// invariants must come back as a structured `Err`, never a panic —
    /// the wire `load` op forwards these messages as `bad_request`.
    #[test]
    fn rejects_malformed_snapshots_without_panicking() {
        let good = forest_to_json(&forest());
        let v = parse(&good).unwrap();

        // Ragged dataset: drop one entry from column 0.
        let mut ragged = v.clone();
        if let Some(Value::Arr(cols)) = ragged.get_mut("data").and_then(|d| d.get_mut("cols")) {
            if let Value::Arr(c0) = &mut cols[0] {
                c0.pop();
            }
        }
        let err = forest_from_json(&ragged.to_string()).unwrap_err().to_string();
        assert!(err.contains("ragged"), "got: {err}");

        // Non-binary label.
        let mut bad_label = v.clone();
        if let Some(Value::Arr(ls)) = bad_label.get_mut("data").and_then(|d| d.get_mut("labels")) {
            ls[0] = Value::Num(7.0);
        }
        let err = forest_from_json(&bad_label.to_string()).unwrap_err().to_string();
        assert!(err.contains("label"), "got: {err}");

        // Wrong-length alive mask.
        let mut bad_alive = v.clone();
        if let Some(Value::Arr(a)) = bad_alive.get_mut("data").and_then(|d| d.get_mut("alive")) {
            a.pop();
        }
        let err = forest_from_json(&bad_alive.to_string()).unwrap_err().to_string();
        assert!(err.contains("alive mask"), "got: {err}");

        // Leaf id pointing past the dataset.
        let huge = good.replacen("\"ids\":[", "\"ids\":[999999,", 1);
        let err = forest_from_json(&huge).unwrap_err().to_string();
        assert!(err.contains("out of range"), "got: {err}");

        // Params failing their own validation (zero trees).
        let zero_trees = good.replace("\"n_trees\":3", "\"n_trees\":0");
        assert!(forest_from_json(&zero_trees).is_err());
    }

    #[test]
    fn full_ownership_snapshots_keep_the_v1_format_byte_for_byte() {
        // q=1.0 must serialize exactly as before Occ(q) existed: v1 tag,
        // no "q" key anywhere in the params object.
        let json = forest_to_json(&forest());
        assert!(json.contains("\"format\":\"dare-forest-v1\""), "got: {json}");
        assert!(!json.contains("\"q\":"), "q key leaked into a v1 snapshot");
    }

    #[test]
    fn subsampled_roundtrip_preserves_ownership() {
        let data = generate(
            &SynthSpec {
                n: 150,
                informative: 3,
                redundant: 0,
                noise: 2,
                flip: 0.05,
                ..Default::default()
            },
            5,
        );
        let params = Params {
            n_trees: 4,
            max_depth: 5,
            k: 5,
            ..Default::default()
        }
        .with_subsample(0.4);
        let mut f = DareForest::fit(data, &params, 77);
        f.delete(3).unwrap();
        let p = f.data().n_features();
        f.add(&vec![0.5; p], 1);

        let json = forest_to_json(&f);
        assert!(json.contains("\"format\":\"dare-forest-v2\""), "got tag: {json}");
        assert!(json.contains("\"q\":0.4"), "q missing from params");
        // The loader runs `from_parts`' ownership validation: every tree's
        // leaf id set must equal {live} ∩ owns(tree_seed, ·, q).
        let back = forest_from_json(&json).unwrap();
        assert_eq!(back.params().q, 0.4);
        for (a, b) in f.trees().iter().zip(back.trees()) {
            assert!(a.structural_matches(b));
        }
        let rows: Vec<Vec<f32>> = (0..20u32).map(|i| f.data().row(i)).collect();
        assert_eq!(f.predict_proba_rows(&rows), back.predict_proba_rows(&rows));

        // Tampering with the declared q breaks the predicate check.
        let lying = json.replace("\"q\":0.4", "\"q\":0.9");
        assert!(forest_from_json(&lying).is_err(), "wrong q must be rejected");
    }

    #[test]
    fn file_roundtrip() {
        let f = forest();
        let tmp = std::env::temp_dir().join("dare_snapshot_test.json");
        save(&f, &tmp).unwrap();
        let back = load(&tmp).unwrap();
        assert_eq!(back.n_trees(), 3);
        std::fs::remove_file(&tmp).ok();
    }
}
