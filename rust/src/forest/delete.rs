//! Deletion (paper Alg. 2 / Alg. 3 DELETE), addition (§6 continual
//! learning), and the non-mutating deletion-cost dry run used by the
//! worst-of-1000 adversary (§4.1).
//!
//! Deletion walks the instance's root→leaf path, updating cached statistics
//! top-down. A subtree is retrained only when the updated statistics say the
//! structure must change:
//! - any node: collapses to a leaf when the updated data is pure or too small
//!   (matching the TRAIN stopping criteria — scratch equality);
//! - random node: a branch emptied ⇒ the node is retrained from its leaves'
//!   data with its *path-derived* seed, which replays exactly what scratch
//!   training on the updated data would build;
//! - greedy node: invalidated thresholds/attributes are resampled per
//!   Lemma A.1, scores are recomputed from the cached counts, and only a
//!   *changed argmax* forces retraining the two children on the new split.
//!
//! Since the arena refactor (DESIGN.md §7) this boxed implementation is the
//! *reference oracle*: live trees store their nodes in `forest::arena`, and
//! `forest::arena_update` ports this exact control flow onto arena ids. The
//! two are kept bit-identical by the churn equivalence tests.

use crate::data::dataset::InstanceId;
use crate::forest::criterion::split_score;
use crate::forest::node::Node;
use crate::forest::stats::{enumerate_valid, resample_invalid, sample_thresholds, AttrStats};
use crate::forest::train::{
    child_path, gather_pairs, make_leaf, partition, select_best, TrainCtx,
};
use crate::forest::workspace::train_subtree;
use crate::util::rng::{mix_seed, Rng};

/// One subtree-retrain event (for Fig. 2's cost-by-depth histogram).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetrainEvent {
    pub depth: usize,
    /// Instances assigned to the retrained node (after the update).
    pub n: u32,
}

/// What a single deletion/addition did to one tree.
#[derive(Clone, Debug, Default)]
pub struct DeleteReport {
    pub retrain_events: Vec<RetrainEvent>,
    pub thresholds_resampled: u64,
    pub attrs_resampled: u64,
}

impl DeleteReport {
    /// The paper's retrain-cost measure: total instances across retrained
    /// nodes.
    pub fn cost(&self) -> u64 {
        self.retrain_events.iter().map(|e| e.n as u64).sum()
    }
    pub fn merge(&mut self, o: &DeleteReport) {
        self.retrain_events.extend_from_slice(&o.retrain_events);
        self.thresholds_resampled += o.thresholds_resampled;
        self.attrs_resampled += o.attrs_resampled;
    }
}

/// Per-deletion RNG for Lemma A.1 resampling; `epoch` is a per-tree update
/// counter so successive deletions draw fresh randomness. Shared with the
/// arena port (`forest::arena_update`), which must consume the identical
/// stream to stay bit-exact with this reference implementation.
pub(crate) fn delete_rng(tree_seed: u64, path: u64, epoch: u64) -> Rng {
    Rng::new(mix_seed(&[tree_seed, path, 0xDE1E_7E00, epoch]))
}

/// Delete instance `id` from the subtree at `node` (paper Alg. 2).
/// `ctx.data` must still contain the instance (the forest marks it removed
/// from the database only after all trees are updated).
pub fn delete(
    ctx: &TrainCtx<'_>,
    node: &mut Node,
    id: InstanceId,
    depth: usize,
    path: u64,
    epoch: u64,
    report: &mut DeleteReport,
) {
    let y = ctx.data.y(id);

    // ---- leaf: Alg. 2 lines 3–6 -----------------------------------------
    if let Node::Leaf(l) = node {
        let pos = l
            .ids
            .iter()
            .position(|&i| i == id)
            .expect("deleting an instance absent from its leaf");
        l.ids.swap_remove(pos);
        l.n -= 1;
        l.n_pos -= y as u32;
        return;
    }

    // ---- decision node ----------------------------------------------------
    let n_new = node.n() - 1;
    let pos_new = node.n_pos() - y as u32;

    // Collapse to a leaf when scratch training would stop here now.
    if n_new < ctx.params.min_samples_split as u32 || pos_new == 0 || pos_new == n_new {
        let mut ids = Vec::with_capacity(n_new as usize);
        node.collect_ids(Some(id), &mut ids);
        report.retrain_events.push(RetrainEvent { depth, n: n_new });
        *node = make_leaf(ctx.data, ids);
        return;
    }

    if matches!(node, Node::Random(_)) {
        delete_random(ctx, node, id, y, n_new, pos_new, depth, path, epoch, report);
    } else {
        delete_greedy(ctx, node, id, y, n_new, pos_new, depth, path, epoch, report);
    }
}

#[allow(clippy::too_many_arguments)]
fn delete_random(
    ctx: &TrainCtx<'_>,
    node: &mut Node,
    id: InstanceId,
    _y: u8,
    n_new: u32,
    pos_new: u32,
    depth: usize,
    path: u64,
    epoch: u64,
    report: &mut DeleteReport,
) {
    // stage 1: update counts; decide whether the threshold fell out of range
    let (goes_left, needs_retrain) = {
        let Node::Random(r) = &mut *node else { unreachable!() };
        r.n = n_new;
        r.n_pos = pos_new;
        let xa = ctx.data.x(id, r.attr);
        let gl = xa <= r.v;
        if gl {
            r.n_left -= 1;
        } else {
            r.n_right -= 1;
        }
        (gl, r.n_left == 0 || r.n_right == 0)
    };

    if needs_retrain {
        // Threshold no longer inside [a_min, a_max): retrain this node with
        // its path seed — identical to scratch training on the updated data
        // (Alg. 2 lines 10–17, derandomized; DESIGN.md §5). Retraining goes
        // through the sort-free workspace (DESIGN.md §6).
        let mut ids = Vec::with_capacity(n_new as usize);
        node.collect_ids(Some(id), &mut ids);
        report.retrain_events.push(RetrainEvent { depth, n: n_new });
        *node = train_subtree(ctx, ids, depth, path);
        return;
    }

    let Node::Random(r) = node else { unreachable!() };
    let (next, right) = if goes_left {
        (&mut r.left, false)
    } else {
        (&mut r.right, true)
    };
    delete(
        ctx,
        next,
        id,
        depth + 1,
        child_path(path, depth, right),
        epoch,
        report,
    );
}

#[allow(clippy::too_many_arguments)]
fn delete_greedy(
    ctx: &TrainCtx<'_>,
    node: &mut Node,
    id: InstanceId,
    y: u8,
    n_new: u32,
    pos_new: u32,
    depth: usize,
    path: u64,
    epoch: u64,
    report: &mut DeleteReport,
) {
    // stage 1: update node + threshold statistics (Alg. 2 line 8): O(p̃·k)
    let (old_attr, old_v, any_invalid) = {
        let Node::Greedy(g) = &mut *node else { unreachable!() };
        g.n = n_new;
        g.n_pos = pos_new;
        let old_attr = g.split_attr();
        let old_v = g.split_v();
        let mut any_invalid = false;
        for a in g.attrs.iter_mut() {
            let xa = ctx.data.x(id, a.attr);
            for t in a.thresholds.iter_mut() {
                t.remove(xa, y);
                any_invalid |= !t.is_valid();
            }
        }
        (old_attr, old_v, any_invalid)
    };

    // stage 2: resample invalidated thresholds / attributes (Lemma A.1);
    // requires gathering the node's data from its leaves (§3.1).
    let mut gathered: Option<Vec<InstanceId>> = None;
    if any_invalid {
        let mut ids = Vec::with_capacity(n_new as usize);
        node.collect_ids(Some(id), &mut ids);

        let made_leaf = {
            let Node::Greedy(g) = &mut *node else { unreachable!() };
            let mut rng = delete_rng(ctx.tree_seed, path, epoch);
            let mut dead_slots: Vec<usize> = Vec::new();
            for (slot, a) in g.attrs.iter_mut().enumerate() {
                if a.thresholds.iter().all(|t| t.is_valid()) {
                    continue;
                }
                let mut pairs = gather_pairs(ctx.data, &ids, a.attr);
                let candidates = enumerate_valid(&mut pairs);
                report.thresholds_resampled +=
                    resample_invalid(&mut a.thresholds, &candidates, ctx.params.k, &mut rng)
                        as u64;
                if a.thresholds.is_empty() {
                    dead_slots.push(slot);
                }
            }
            // Attributes with no remaining valid thresholds are replaced by
            // uniformly drawn valid attributes (§A.1).
            if !dead_slots.is_empty() {
                let in_use: Vec<usize> = g.attrs.iter().map(|a| a.attr).collect();
                let p = ctx.data.n_features();
                let mut pool: Vec<usize> = (0..p).filter(|a| !in_use.contains(a)).collect();
                rng.shuffle(&mut pool);
                let mut pool_iter = pool.into_iter();
                for slot in dead_slots {
                    for attr in pool_iter.by_ref() {
                        let mut pairs = gather_pairs(ctx.data, &ids, attr);
                        let candidates = enumerate_valid(&mut pairs);
                        if candidates.is_empty() {
                            continue;
                        }
                        g.attrs[slot] = AttrStats {
                            attr,
                            thresholds: sample_thresholds(candidates, ctx.params.k, &mut rng),
                        };
                        report.attrs_resampled += 1;
                        break;
                    }
                }
                g.attrs.retain(|a| !a.thresholds.is_empty());
            }
            g.attrs.is_empty()
        };

        if made_leaf {
            // No valid split exists anywhere anymore: leaf.
            report.retrain_events.push(RetrainEvent { depth, n: n_new });
            *node = make_leaf(ctx.data, ids);
            return;
        }
        gathered = Some(ids);
    }

    // stage 3: recompute scores from cached counts, select the optimum
    // (Alg. 2 lines 23–24).
    let (new_attr, new_v) = {
        let Node::Greedy(g) = &mut *node else { unreachable!() };
        let (ba, bt) = select_best(n_new, pos_new, &g.attrs, ctx.params).expect("attrs non-empty");
        g.best_attr = ba;
        g.best_thr = bt;
        (g.split_attr(), g.split_v())
    };

    if new_attr != old_attr || new_v != old_v {
        // Optimal split changed: retrain both children on the new partition
        // (Alg. 2 lines 25–27).
        let ids = match gathered {
            Some(ids) => ids,
            None => {
                let mut v = Vec::with_capacity(n_new as usize);
                node.collect_ids(Some(id), &mut v);
                v
            }
        };
        report.retrain_events.push(RetrainEvent { depth, n: n_new });
        let (left_ids, right_ids) = partition(ctx.data, &ids, new_attr, new_v);
        debug_assert!(!left_ids.is_empty() && !right_ids.is_empty());
        let left = train_subtree(ctx, left_ids, depth + 1, child_path(path, depth, false));
        let right = train_subtree(ctx, right_ids, depth + 1, child_path(path, depth, true));
        let Node::Greedy(g) = node else { unreachable!() };
        g.left = Box::new(left);
        g.right = Box::new(right);
        return;
    }

    // stage 4: split unchanged — continue down the instance's branch.
    let Node::Greedy(g) = node else { unreachable!() };
    let xa = ctx.data.x(id, new_attr);
    let (next, right) = if xa <= new_v {
        (&mut g.left, false)
    } else {
        (&mut g.right, true)
    };
    delete(
        ctx,
        next,
        id,
        depth + 1,
        child_path(path, depth, right),
        epoch,
        report,
    );
}

/// Non-mutating estimate of the retrain cost of deleting `id` — the ranking
/// signal for the worst-of-1000 adversary. Mirrors `delete` but computes the
/// decremented statistics in temporaries; resampling outcomes are
/// approximated pessimistically (an invalidated *chosen* threshold counts as
/// a retrain).
pub fn delete_cost(ctx: &TrainCtx<'_>, node: &Node, id: InstanceId, depth: usize) -> u64 {
    let y = ctx.data.y(id);
    match node {
        Node::Leaf(_) => 0,
        Node::Random(r) => {
            let n_new = r.n - 1;
            let pos_new = r.n_pos - y as u32;
            if n_new < ctx.params.min_samples_split as u32 || pos_new == 0 || pos_new == n_new {
                return n_new as u64;
            }
            let xa = ctx.data.x(id, r.attr);
            let goes_left = xa <= r.v;
            let (nl, nr) = if goes_left {
                (r.n_left - 1, r.n_right)
            } else {
                (r.n_left, r.n_right - 1)
            };
            if nl == 0 || nr == 0 {
                return n_new as u64;
            }
            if goes_left {
                delete_cost(ctx, &r.left, id, depth + 1)
            } else {
                delete_cost(ctx, &r.right, id, depth + 1)
            }
        }
        Node::Greedy(g) => {
            let n_new = g.n - 1;
            let pos_new = g.n_pos - y as u32;
            if n_new < ctx.params.min_samples_split as u32 || pos_new == 0 || pos_new == n_new {
                return n_new as u64;
            }
            let old_attr = g.split_attr();
            let old_v = g.split_v();
            // Find the best split over decremented, still-valid thresholds.
            let mut best: Option<(usize, f32, f64)> = None;
            let mut chosen_invalid = false;
            for a in &g.attrs {
                let xa = ctx.data.x(id, a.attr);
                for t in &a.thresholds {
                    let mut tt = *t;
                    tt.remove(xa, y);
                    let is_chosen = a.attr == old_attr && t.v == old_v;
                    if !tt.is_valid() {
                        if is_chosen {
                            chosen_invalid = true;
                        }
                        continue;
                    }
                    let s = split_score(
                        ctx.params.criterion,
                        n_new,
                        pos_new,
                        tt.n_left,
                        tt.n_left_pos,
                    );
                    match best {
                        Some((_, _, bs)) if s >= bs => {}
                        _ => best = Some((a.attr, t.v, s)),
                    }
                }
            }
            if chosen_invalid {
                return n_new as u64; // pessimistic: resampling may move the split
            }
            match best {
                Some((ba, bv, _)) if ba == old_attr && bv == old_v => {
                    let xa = ctx.data.x(id, old_attr);
                    if xa <= old_v {
                        delete_cost(ctx, &g.left, id, depth + 1)
                    } else {
                        delete_cost(ctx, &g.right, id, depth + 1)
                    }
                }
                _ => n_new as u64,
            }
        }
    }
}

/// Add an instance (already inserted into the dataset) to the subtree —
/// the §6 continual-learning extension, mirroring `delete`.
pub fn add(
    ctx: &TrainCtx<'_>,
    node: &mut Node,
    id: InstanceId,
    depth: usize,
    path: u64,
    epoch: u64,
    report: &mut DeleteReport,
) {
    let y = ctx.data.y(id);

    // ---- leaf ----------------------------------------------------------
    if let Node::Leaf(l) = node {
        l.ids.push(id);
        l.n += 1;
        l.n_pos += y as u32;
        // A leaf that scratch training would now split gets rebuilt (it may
        // have stopped on purity / size before this addition).
        let should_split = l.n >= ctx.params.min_samples_split as u32
            && l.n_pos > 0
            && l.n_pos < l.n
            && depth < ctx.params.max_depth;
        if should_split {
            let ids = std::mem::take(&mut l.ids);
            report.retrain_events.push(RetrainEvent {
                depth,
                n: ids.len() as u32,
            });
            *node = train_subtree(ctx, ids, depth, path);
        }
        return;
    }

    if matches!(node, Node::Random(_)) {
        let Node::Random(r) = node else { unreachable!() };
        r.n += 1;
        r.n_pos += y as u32;
        let xa = ctx.data.x(id, r.attr);
        let goes_left = xa <= r.v;
        if goes_left {
            r.n_left += 1;
        } else {
            r.n_right += 1;
        }
        let (next, right) = if goes_left {
            (&mut r.left, false)
        } else {
            (&mut r.right, true)
        };
        add(
            ctx,
            next,
            id,
            depth + 1,
            child_path(path, depth, right),
            epoch,
            report,
        );
        return;
    }

    // ---- greedy node ------------------------------------------------------
    // stage 1: update stats; detect thresholds whose adjacency the new value
    // breaks (x strictly between v_low and v_high).
    let (old_attr, old_v, any_broken) = {
        let Node::Greedy(g) = &mut *node else { unreachable!() };
        g.n += 1;
        g.n_pos += y as u32;
        let old_attr = g.split_attr();
        let old_v = g.split_v();
        let mut any_broken = false;
        for a in g.attrs.iter_mut() {
            let xa = ctx.data.x(id, a.attr);
            for t in a.thresholds.iter_mut() {
                if t.adjacency_broken(xa) {
                    any_broken = true;
                    t.n_low = 0; // force invalid so the resampler replaces it
                } else {
                    t.add(xa, y);
                }
            }
        }
        (old_attr, old_v, any_broken)
    };

    // stage 2: resample broken thresholds over the updated data.
    if any_broken {
        let mut ids = Vec::new();
        node.collect_ids(None, &mut ids);
        ids.push(id); // leaves below don't know the new instance yet

        let made_leafless = {
            let Node::Greedy(g) = &mut *node else { unreachable!() };
            let mut rng = delete_rng(ctx.tree_seed, path, 0xADD ^ epoch);
            for a in g.attrs.iter_mut() {
                if a.thresholds.iter().all(|t| t.is_valid()) {
                    continue;
                }
                let mut pairs = gather_pairs(ctx.data, &ids, a.attr);
                let candidates = enumerate_valid(&mut pairs);
                report.thresholds_resampled +=
                    resample_invalid(&mut a.thresholds, &candidates, ctx.params.k, &mut rng)
                        as u64;
            }
            g.attrs.retain(|a| !a.thresholds.is_empty());
            g.attrs.is_empty()
        };
        if made_leafless {
            report.retrain_events.push(RetrainEvent {
                depth,
                n: ids.len() as u32,
            });
            *node = train_subtree(ctx, ids, depth, path);
            return;
        }
    }

    // stage 3: re-select optimum; retrain children if it moved.
    let (new_attr, new_v, n_now, pos_now) = {
        let Node::Greedy(g) = &mut *node else { unreachable!() };
        let (ba, bt) = select_best(g.n, g.n_pos, &g.attrs, ctx.params).expect("attrs");
        g.best_attr = ba;
        g.best_thr = bt;
        (g.split_attr(), g.split_v(), g.n, g.n_pos)
    };
    let _ = (n_now, pos_now);

    if new_attr != old_attr || new_v != old_v {
        let mut ids = Vec::new();
        node.collect_ids(None, &mut ids);
        if !ids.contains(&id) {
            ids.push(id);
        }
        report.retrain_events.push(RetrainEvent {
            depth,
            n: ids.len() as u32,
        });
        let (left_ids, right_ids) = partition(ctx.data, &ids, new_attr, new_v);
        let left = train_subtree(ctx, left_ids, depth + 1, child_path(path, depth, false));
        let right = train_subtree(ctx, right_ids, depth + 1, child_path(path, depth, true));
        let Node::Greedy(g) = node else { unreachable!() };
        g.left = Box::new(left);
        g.right = Box::new(right);
        return;
    }

    let Node::Greedy(g) = node else { unreachable!() };
    let xa = ctx.data.x(id, new_attr);
    let (next, right) = if xa <= new_v {
        (&mut g.left, false)
    } else {
        (&mut g.right, true)
    };
    add(
        ctx,
        next,
        id,
        depth + 1,
        child_path(path, depth, right),
        epoch,
        report,
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;
    use crate::data::synth::{generate, SynthSpec};
    use crate::forest::params::{MaxFeatures, Params};
    use crate::forest::train::{count_pos, train, ROOT_PATH};

    fn params(d_rmax: usize, k: usize) -> Params {
        Params {
            max_depth: 8,
            k,
            d_rmax,
            max_features: MaxFeatures::Sqrt,
            ..Default::default()
        }
    }

    fn data(n: usize, seed: u64) -> Dataset {
        generate(
            &SynthSpec {
                n,
                informative: 3,
                redundant: 1,
                noise: 2,
                flip: 0.1,
                ..Default::default()
            },
            seed,
        )
    }

    /// Verify every invariant that ties cached statistics to actual data.
    fn check_invariants(node: &Node, d: &Dataset) {
        match node {
            Node::Leaf(l) => {
                assert_eq!(l.n as usize, l.ids.len());
                assert_eq!(l.n_pos, count_pos(d, &l.ids));
            }
            Node::Random(r) => {
                assert_eq!(r.n, r.left.n() + r.right.n());
                assert_eq!(r.n_left, r.left.n());
                assert_eq!(r.n_right, r.right.n());
                assert!(r.n_left > 0 && r.n_right > 0);
                check_invariants(&r.left, d);
                check_invariants(&r.right, d);
            }
            Node::Greedy(g) => {
                assert_eq!(g.n, g.left.n() + g.right.n());
                assert_eq!(g.n_pos, g.left.n_pos() + g.right.n_pos());
                let mut ids = Vec::new();
                node.collect_ids(None, &mut ids);
                // cached threshold stats match a fresh recount
                for a in &g.attrs {
                    for t in &a.thresholds {
                        assert!(t.is_valid());
                        let mut n_left = 0;
                        let mut n_left_pos = 0;
                        for &i in &ids {
                            if d.x(i, a.attr) <= t.v {
                                n_left += 1;
                                n_left_pos += d.y(i) as u32;
                            }
                        }
                        assert_eq!(t.n_left, n_left, "stale n_left");
                        assert_eq!(t.n_left_pos, n_left_pos, "stale n_left_pos");
                    }
                }
                check_invariants(&g.left, d);
                check_invariants(&g.right, d);
            }
        }
    }

    #[test]
    fn delete_preserves_invariants_greedy() {
        let mut d = data(250, 1);
        let p = params(0, 5);
        let mut root = {
            let ctx = TrainCtx {
                data: &d,
                params: &p,
                tree_seed: 3,
            };
            train(&ctx, d.live_ids(), 0, ROOT_PATH)
        };
        let mut rng = Rng::new(10);
        for epoch in 0..120u64 {
            let live = d.live_ids();
            let id = live[rng.index(live.len())];
            let mut report = DeleteReport::default();
            {
                let ctx = TrainCtx {
                    data: &d,
                    params: &p,
                    tree_seed: 3,
                };
                delete(&ctx, &mut root, id, 0, ROOT_PATH, epoch, &mut report);
            }
            d.mark_removed(id);
            assert_eq!(root.n() as usize, d.n_alive());
            check_invariants(&root, &d);
        }
    }

    #[test]
    fn delete_preserves_invariants_random_layers() {
        let mut d = data(300, 2);
        let p = params(3, 5);
        let mut root = {
            let ctx = TrainCtx {
                data: &d,
                params: &p,
                tree_seed: 4,
            };
            train(&ctx, d.live_ids(), 0, ROOT_PATH)
        };
        let mut rng = Rng::new(11);
        for epoch in 0..150u64 {
            let live = d.live_ids();
            let id = live[rng.index(live.len())];
            let mut report = DeleteReport::default();
            {
                let ctx = TrainCtx {
                    data: &d,
                    params: &p,
                    tree_seed: 4,
                };
                delete(&ctx, &mut root, id, 0, ROOT_PATH, epoch, &mut report);
            }
            d.mark_removed(id);
            check_invariants(&root, &d);
        }
    }

    #[test]
    fn delete_down_to_nothing() {
        let mut d = data(60, 3);
        let p = params(1, 3);
        let mut root = {
            let ctx = TrainCtx {
                data: &d,
                params: &p,
                tree_seed: 5,
            };
            train(&ctx, d.live_ids(), 0, ROOT_PATH)
        };
        let ids = d.live_ids();
        for (epoch, id) in ids.into_iter().enumerate() {
            let mut report = DeleteReport::default();
            {
                let ctx = TrainCtx {
                    data: &d,
                    params: &p,
                    tree_seed: 5,
                };
                delete(&ctx, &mut root, id, 0, ROOT_PATH, epoch as u64, &mut report);
            }
            d.mark_removed(id);
            check_invariants(&root, &d);
        }
        assert_eq!(root.n(), 0);
        assert!(matches!(root, Node::Leaf(_)));
        assert_eq!(root.predict(&[0.0; 6]), 0.5);
    }

    /// The core exactness check: with exhaustive thresholds (k ≥ all valid)
    /// and all attributes considered, deletion must produce *structurally*
    /// the same tree as training from scratch on the updated data with the
    /// same path seeds (DESIGN.md §5).
    #[test]
    fn exactness_vs_scratch_retrain_exhaustive_k() {
        let mut d = data(120, 6);
        let p = Params {
            max_depth: 6,
            k: 10_000,
            d_rmax: 0,
            max_features: MaxFeatures::All,
            ..Default::default()
        };
        let mut root = {
            let ctx = TrainCtx {
                data: &d,
                params: &p,
                tree_seed: 9,
            };
            train(&ctx, d.live_ids(), 0, ROOT_PATH)
        };
        let mut rng = Rng::new(42);
        for epoch in 0..40u64 {
            let live = d.live_ids();
            let id = live[rng.index(live.len())];
            let mut report = DeleteReport::default();
            {
                let ctx = TrainCtx {
                    data: &d,
                    params: &p,
                    tree_seed: 9,
                };
                delete(&ctx, &mut root, id, 0, ROOT_PATH, epoch, &mut report);
            }
            d.mark_removed(id);
            let scratch = {
                let ctx = TrainCtx {
                    data: &d,
                    params: &p,
                    tree_seed: 9,
                };
                train(&ctx, d.live_ids(), 0, ROOT_PATH)
            };
            assert!(
                crate::forest::tree::structural_eq(&root, &scratch),
                "delete != scratch retrain after epoch {epoch}"
            );
        }
    }

    #[test]
    fn delete_cost_zero_when_structure_stable() {
        // Well-separated data: deleting one point deep in a cluster should
        // rarely force retraining near the root.
        let d = generate(
            &SynthSpec {
                n: 400,
                informative: 4,
                redundant: 0,
                noise: 0,
                flip: 0.0,
                class_sep: 3.0,
                ..Default::default()
            },
            7,
        );
        let p = params(0, 10);
        let ctx = TrainCtx {
            data: &d,
            params: &p,
            tree_seed: 12,
        };
        let root = train(&ctx, d.live_ids(), 0, ROOT_PATH);
        let costs: Vec<u64> = d
            .live_ids()
            .iter()
            .take(100)
            .map(|&id| delete_cost(&ctx, &root, id, 0))
            .collect();
        let zeros = costs.iter().filter(|&&c| c == 0).count();
        assert!(zeros > 50, "most dry-run deletions should be free: {zeros}/100");
    }

    #[test]
    fn dry_run_does_not_mutate() {
        let d = data(200, 8);
        let p = params(2, 5);
        let ctx = TrainCtx {
            data: &d,
            params: &p,
            tree_seed: 13,
        };
        let root = train(&ctx, d.live_ids(), 0, ROOT_PATH);
        let before = format!("{root:?}");
        for id in d.live_ids().iter().take(50) {
            let _ = delete_cost(&ctx, &root, *id, 0);
        }
        assert_eq!(before, format!("{root:?}"));
    }

    #[test]
    fn add_then_invariants_hold() {
        let mut d = data(150, 9);
        let p = params(1, 5);
        let mut root = {
            let ctx = TrainCtx {
                data: &d,
                params: &p,
                tree_seed: 21,
            };
            train(&ctx, d.live_ids(), 0, ROOT_PATH)
        };
        let mut rng = Rng::new(77);
        for epoch in 0..60u64 {
            let row: Vec<f32> = (0..d.n_features())
                .map(|_| rng.range_f32(-3.0, 3.0))
                .collect();
            let y = rng.bernoulli(0.5) as u8;
            let id = d.push_row(&row, y);
            let mut report = DeleteReport::default();
            {
                let ctx = TrainCtx {
                    data: &d,
                    params: &p,
                    tree_seed: 21,
                };
                add(&ctx, &mut root, id, 0, ROOT_PATH, epoch, &mut report);
            }
            assert_eq!(root.n() as usize, d.n_alive());
            check_invariants(&root, &d);
        }
    }

    #[test]
    fn add_then_delete_roundtrip_counts() {
        let mut d = data(100, 10);
        let p = params(0, 5);
        let mut root = {
            let ctx = TrainCtx {
                data: &d,
                params: &p,
                tree_seed: 31,
            };
            train(&ctx, d.live_ids(), 0, ROOT_PATH)
        };
        let row: Vec<f32> = vec![0.1; d.n_features()];
        let id = d.push_row(&row, 1);
        let mut report = DeleteReport::default();
        {
            let ctx = TrainCtx {
                data: &d,
                params: &p,
                tree_seed: 31,
            };
            add(&ctx, &mut root, id, 0, ROOT_PATH, 0, &mut report);
        }
        assert_eq!(root.n(), 101);
        {
            let ctx = TrainCtx {
                data: &d,
                params: &p,
                tree_seed: 31,
            };
            delete(&ctx, &mut root, id, 0, ROOT_PATH, 1, &mut report);
        }
        d.mark_removed(id);
        assert_eq!(root.n(), 100);
        check_invariants(&root, &d);
    }

    #[test]
    fn report_costs_accumulate() {
        let mut r = DeleteReport::default();
        r.retrain_events.push(RetrainEvent { depth: 1, n: 10 });
        let mut r2 = DeleteReport::default();
        r2.retrain_events.push(RetrainEvent { depth: 0, n: 5 });
        r2.thresholds_resampled = 2;
        r.merge(&r2);
        assert_eq!(r.cost(), 15);
        assert_eq!(r.retrain_events.len(), 2);
        assert_eq!(r.thresholds_resampled, 2);
    }
}
