//! Cached node statistics (paper §3.1–3.2, Appendix A.6).
//!
//! Greedy decision nodes store, per sampled attribute, up to `k` candidate
//! thresholds. Each threshold is the midpoint of two *adjacent* attribute
//! values `v_low < v_high` present in the node's data, and is **valid** iff
//! some instance at `v_low` and some instance at `v_high` carry opposite
//! labels (§3.2). Alongside the split counts (|D_l|, |D_l,1|) we cache the
//! per-boundary-value counts so invalidation is detected in O(1) per deletion
//! and scores recompute in O(1) without touching the data (Theorem 3.3).

use crate::data::dataset::InstanceId;
use crate::util::rng::Rng;
use std::collections::HashSet;

/// Statistics for one candidate threshold of one attribute (§A.6).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ThresholdStats {
    /// The threshold value v (midpoint of `v_low` and `v_high`).
    pub v: f32,
    /// Adjacent attribute value just below/at the boundary.
    pub v_low: f32,
    /// Adjacent attribute value just above the boundary.
    pub v_high: f32,
    /// |D_l| — instances with x ≤ v.
    pub n_left: u32,
    /// |D_{l,1}| — positives with x ≤ v.
    pub n_left_pos: u32,
    /// Instances with x == v_low.
    pub n_low: u32,
    /// Positives with x == v_low.
    pub n_low_pos: u32,
    /// Instances with x == v_high.
    pub n_high: u32,
    /// Positives with x == v_high.
    pub n_high_pos: u32,
}

impl ThresholdStats {
    /// Validity per §3.2: both boundary value-groups non-empty and at least
    /// one opposite-label pair across the boundary.
    #[inline]
    pub fn is_valid(&self) -> bool {
        if self.n_low == 0 || self.n_high == 0 {
            return false;
        }
        let low_neg = self.n_low - self.n_low_pos;
        let high_neg = self.n_high - self.n_high_pos;
        (self.n_low_pos > 0 && high_neg > 0) || (low_neg > 0 && self.n_high_pos > 0)
    }

    /// Update counts for the removal of an instance with attribute value `x`
    /// and label `y` (O(1); called on the deletion path).
    #[inline]
    pub fn remove(&mut self, x: f32, y: u8) {
        let yp = y as u32;
        if x <= self.v {
            self.n_left -= 1;
            self.n_left_pos -= yp;
        }
        if x == self.v_low {
            self.n_low -= 1;
            self.n_low_pos -= yp;
        } else if x == self.v_high {
            self.n_high -= 1;
            self.n_high_pos -= yp;
        }
    }

    /// Update counts for an added instance. NOTE: addition can also *break
    /// adjacency* (a new value strictly between `v_low` and `v_high`); the
    /// caller detects that via [`ThresholdStats::adjacency_broken`].
    #[inline]
    pub fn add(&mut self, x: f32, y: u8) {
        let yp = y as u32;
        if x <= self.v {
            self.n_left += 1;
            self.n_left_pos += yp;
        }
        if x == self.v_low {
            self.n_low += 1;
            self.n_low_pos += yp;
        } else if x == self.v_high {
            self.n_high += 1;
            self.n_high_pos += yp;
        }
    }

    /// True if inserting value `x` would break the (v_low, v_high) adjacency.
    #[inline]
    pub fn adjacency_broken(&self, x: f32) -> bool {
        x > self.v_low && x < self.v_high
    }
}

/// Per-attribute statistics at a greedy node: the attribute id and its
/// sampled candidate thresholds (≤ k, possibly fewer when the attribute has
/// few valid thresholds).
#[derive(Clone, Debug, Default)]
pub struct AttrStats {
    pub attr: usize,
    pub thresholds: Vec<ThresholdStats>,
}

/// One distinct attribute value with its label counts, as seen by the
/// streaming enumeration.
#[derive(Clone, Copy)]
struct Group {
    v: f32,
    n: u32,
    pos: u32,
}

/// Emit the boundary between two adjacent value-groups if it is valid
/// (§3.2). `cum_n`/`cum_pos` are the totals over all groups up to and
/// including `lo`.
#[inline]
fn push_boundary(lo: &Group, hi: &Group, cum_n: u32, cum_pos: u32, out: &mut Vec<ThresholdStats>) {
    let lo_neg = lo.n - lo.pos;
    let hi_neg = hi.n - hi.pos;
    let valid = (lo.pos > 0 && hi_neg > 0) || (lo_neg > 0 && hi.pos > 0);
    if valid {
        out.push(ThresholdStats {
            v: midpoint(lo.v, hi.v),
            v_low: lo.v,
            v_high: hi.v,
            n_left: cum_n,
            n_left_pos: cum_pos,
            n_low: lo.n,
            n_low_pos: lo.pos,
            n_high: hi.n,
            n_high_pos: hi.pos,
        });
    }
}

/// Streaming core shared by [`enumerate_valid`] and
/// [`enumerate_valid_presorted`]: consumes (value, label) pairs that must
/// arrive in value-sorted order and emits the fully-populated stats of every
/// valid boundary, in value order. One pass, no intermediate group vector —
/// only the last completed group and the group still accumulating are held.
///
/// NaN feature values are skipped outright: NaN never satisfies `x ≤ v`, so
/// a NaN instance belongs to no left count and can define no boundary — a
/// NaN-valued midpoint would otherwise produce a split with an empty left
/// partition.
fn enumerate_sorted(pairs: impl Iterator<Item = (f32, u8)>) -> Vec<ThresholdStats> {
    let mut out = Vec::new();
    let mut prev: Option<Group> = None; // last completed value-group
    let mut cur: Option<Group> = None; // group still accumulating
    let mut cum_n = 0u32; // totals over groups completed before `prev`
    let mut cum_pos = 0u32;
    for (v, y) in pairs {
        if v.is_nan() {
            continue;
        }
        match cur.as_mut() {
            Some(g) if g.v == v => {
                g.n += 1;
                g.pos += y as u32;
            }
            _ => {
                if let Some(done) = cur.take() {
                    if let Some(p) = prev.take() {
                        cum_n += p.n;
                        cum_pos += p.pos;
                        push_boundary(&p, &done, cum_n, cum_pos, &mut out);
                    }
                    prev = Some(done);
                }
                cur = Some(Group {
                    v,
                    n: 1,
                    pos: y as u32,
                });
            }
        }
    }
    if let (Some(p), Some(done)) = (prev, cur) {
        cum_n += p.n;
        cum_pos += p.pos;
        push_boundary(&p, &done, cum_n, cum_pos, &mut out);
    }
    out
}

/// Enumerate ALL valid thresholds of one attribute over `pairs`
/// (value, label) — O(m log m). Returns fully-populated stats, sorted by v.
pub fn enumerate_valid(pairs: &mut Vec<(f32, u8)>) -> Vec<ThresholdStats> {
    if pairs.len() < 2 {
        return Vec::new();
    }
    // total_cmp avoids the partial_cmp Option in the hot sort (§Perf); NaNs
    // sort to the run's ends (negative NaNs first, positive last) and are
    // then skipped by the streaming core, so they never form thresholds.
    pairs.sort_unstable_by(|a, b| a.0.total_cmp(&b.0));
    enumerate_sorted(pairs.iter().copied())
}

/// Enumerate valid thresholds over a run of instance ids that is already
/// sorted by the attribute — the sort-free workspace path (DESIGN.md §6).
/// `run` must be ordered by `col[id]` under `total_cmp`; the output is
/// bit-identical to [`enumerate_valid`] on the same instance multiset, in
/// O(m) with no sort and no intermediate allocation.
pub fn enumerate_valid_presorted(
    col: &[f32],
    labels: &[u8],
    run: &[InstanceId],
) -> Vec<ThresholdStats> {
    if run.len() < 2 {
        return Vec::new();
    }
    debug_assert!(
        run.windows(2).all(|w| {
            col[w[0] as usize].total_cmp(&col[w[1] as usize]) != std::cmp::Ordering::Greater
        }),
        "presorted run is not value-sorted"
    );
    enumerate_sorted(run.iter().map(|&i| (col[i as usize], labels[i as usize])))
}

/// Midpoint of two adjacent float values, guaranteed to satisfy
/// `lo <= mid < hi` so `x ≤ v` routes the `lo` group left and the `hi`
/// group right even when the values are adjacent floats.
#[inline]
pub fn midpoint(lo: f32, hi: f32) -> f32 {
    debug_assert!(lo < hi);
    let mid = lo + (hi - lo) * 0.5;
    if mid >= hi {
        lo
    } else {
        mid
    }
}

/// Sample up to `k` of the given candidates uniformly without replacement,
/// preserving the (random) sample order. Used at training time (Alg. 1 l.20).
pub fn sample_thresholds(candidates: Vec<ThresholdStats>, k: usize, rng: &mut Rng) -> Vec<ThresholdStats> {
    if candidates.len() <= k {
        return candidates;
    }
    rng.sample_indices(candidates.len(), k)
        .into_iter()
        .map(|i| candidates[i])
        .collect()
}

/// Bit-key of a threshold value for set membership: normalizes −0.0 to +0.0
/// so the key relation matches float `==` on the stored values.
#[inline]
fn threshold_key(v: f32) -> u32 {
    (v + 0.0).to_bits()
}

/// Resample invalidated thresholds after a deletion (Lemma A.1): keep the
/// still-valid stored thresholds, and replace the invalid ones by sampling
/// uniformly from the valid-and-unselected candidates. `candidates` must be
/// the full valid set for this attribute over the node's updated data.
///
/// Returns the number of thresholds replaced.
pub fn resample_invalid(
    stored: &mut Vec<ThresholdStats>,
    candidates: &[ThresholdStats],
    k: usize,
    rng: &mut Rng,
) -> usize {
    // keep valid stored thresholds
    let before = stored.len();
    stored.retain(|t| t.is_valid());
    let kept = stored.len();
    let dropped = before - kept;

    // pool = candidates not currently stored, tested against a bit-key set
    // of the stored threshold values — O(k + |candidates|) instead of the
    // former O(k·|candidates|) nested scan. Midpoints are recomputed
    // bit-identically from the same adjacent values, so bit-key membership
    // coincides with float `==` (−0.0 is normalized; NaN thresholds cannot
    // arise from midpoints of real data values).
    let stored_keys: HashSet<u32> = stored.iter().map(|s| threshold_key(s.v)).collect();
    let pool: Vec<&ThresholdStats> = candidates
        .iter()
        .filter(|c| !stored_keys.contains(&threshold_key(c.v)))
        .collect();
    let target = k.min(kept + pool.len());
    let need = target.saturating_sub(kept);
    if need > 0 {
        for i in rng.sample_indices(pool.len(), need) {
            stored.push(*pool[i]);
        }
    }
    dropped
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pairs(vals: &[(f32, u8)]) -> Vec<(f32, u8)> {
        vals.to_vec()
    }

    #[test]
    fn enumerate_simple() {
        // values 1(neg) 2(pos) 3(neg): both boundaries valid
        let mut p = pairs(&[(1.0, 0), (2.0, 1), (3.0, 0)]);
        let c = enumerate_valid(&mut p);
        assert_eq!(c.len(), 2);
        assert_eq!(c[0].v, 1.5);
        assert_eq!(c[0].n_left, 1);
        assert_eq!(c[0].n_left_pos, 0);
        assert_eq!(c[1].v, 2.5);
        assert_eq!(c[1].n_left, 2);
        assert_eq!(c[1].n_left_pos, 1);
    }

    #[test]
    fn same_label_boundary_invalid() {
        // 1(neg) 2(neg) 3(pos): only the 2/3 boundary is valid
        let mut p = pairs(&[(1.0, 0), (2.0, 0), (3.0, 1)]);
        let c = enumerate_valid(&mut p);
        assert_eq!(c.len(), 1);
        assert_eq!(c[0].v_low, 2.0);
        assert_eq!(c[0].v_high, 3.0);
    }

    #[test]
    fn mixed_labels_at_one_value_validates_boundary() {
        // value 1 has both labels; value 2 all neg → boundary valid
        // (pos@1 vs neg@2)
        let mut p = pairs(&[(1.0, 0), (1.0, 1), (2.0, 0)]);
        let c = enumerate_valid(&mut p);
        assert_eq!(c.len(), 1);
        assert_eq!(c[0].n_low, 2);
        assert_eq!(c[0].n_low_pos, 1);
    }

    #[test]
    fn constant_attribute_no_thresholds() {
        let mut p = pairs(&[(5.0, 0), (5.0, 1), (5.0, 0)]);
        assert!(enumerate_valid(&mut p).is_empty());
        let mut single = pairs(&[(1.0, 1)]);
        assert!(enumerate_valid(&mut single).is_empty());
    }

    #[test]
    fn pure_labels_no_thresholds() {
        let mut p = pairs(&[(1.0, 1), (2.0, 1), (3.0, 1)]);
        assert!(enumerate_valid(&mut p).is_empty());
    }

    #[test]
    fn remove_updates_and_invalidates() {
        let mut p = pairs(&[(1.0, 0), (2.0, 1), (3.0, 0)]);
        let c = enumerate_valid(&mut p);
        let mut t = c[0]; // boundary 1/2, v=1.5
        assert!(t.is_valid());
        // delete the only positive at value 2 → boundary 1/2 loses its
        // opposite-label pair (v_high group keeps... the 2.0 instance is the
        // only one at v_high) → invalid
        t.remove(2.0, 1);
        assert_eq!(t.n_high, 0);
        assert!(!t.is_valid());
    }

    #[test]
    fn remove_left_count_tracking() {
        let mut p = pairs(&[(1.0, 0), (2.0, 1), (3.0, 0), (1.0, 1)]);
        let c = enumerate_valid(&mut p);
        let mut t = *c.iter().find(|t| t.v == 1.5).unwrap();
        assert_eq!(t.n_left, 2);
        assert_eq!(t.n_left_pos, 1);
        t.remove(1.0, 1);
        assert_eq!(t.n_left, 1);
        assert_eq!(t.n_left_pos, 0);
        assert_eq!(t.n_low, 1);
        assert_eq!(t.n_low_pos, 0);
        // still valid: neg@1 vs pos@2
        assert!(t.is_valid());
    }

    #[test]
    fn add_and_adjacency() {
        let mut p = pairs(&[(1.0, 0), (3.0, 1)]);
        let c = enumerate_valid(&mut p);
        let mut t = c[0];
        assert!(!t.adjacency_broken(1.0));
        assert!(!t.adjacency_broken(3.0));
        assert!(t.adjacency_broken(2.0));
        t.add(1.0, 1);
        assert_eq!(t.n_low, 2);
        assert_eq!(t.n_low_pos, 1);
        assert_eq!(t.n_left, 2);
    }

    #[test]
    fn midpoint_routes_correctly() {
        // adjacent f32s: midpoint must stay strictly below hi
        let lo = 1.0f32;
        let hi = f32::from_bits(lo.to_bits() + 1);
        let m = midpoint(lo, hi);
        assert!(lo <= m && m < hi);
        assert!((midpoint(2.0, 4.0) - 3.0).abs() < 1e-7);
    }

    #[test]
    fn sampling_respects_k() {
        let mut rng = Rng::new(3);
        let mut p: Vec<(f32, u8)> = (0..40).map(|i| (i as f32, (i % 2) as u8)).collect();
        let c = enumerate_valid(&mut p);
        assert!(c.len() >= 30);
        let total = c.len();
        let s = sample_thresholds(c.clone(), 5, &mut rng);
        assert_eq!(s.len(), 5);
        let s2 = sample_thresholds(c, total + 10, &mut rng);
        assert_eq!(s2.len(), total);
    }

    #[test]
    fn resample_keeps_valid_replaces_invalid() {
        let mut rng = Rng::new(5);
        let mut p: Vec<(f32, u8)> = (0..20).map(|i| (i as f32, (i % 2) as u8)).collect();
        let full = enumerate_valid(&mut p);
        let mut stored = vec![full[0], full[1], full[2]];
        // invalidate stored[1] artificially
        stored[1].n_low = 0;
        let replaced = resample_invalid(&mut stored, &full, 3, &mut rng);
        assert_eq!(replaced, 1);
        assert_eq!(stored.len(), 3);
        assert!(stored.iter().all(|t| t.is_valid()));
        // originals kept
        assert!(stored.iter().any(|t| t.v == full[0].v));
        assert!(stored.iter().any(|t| t.v == full[2].v));
        // replacement is none of the kept ones
        let mut vs: Vec<u32> = stored.iter().map(|t| t.v.to_bits()).collect();
        vs.sort_unstable();
        vs.dedup();
        assert_eq!(vs.len(), 3, "no duplicate thresholds");
    }

    #[test]
    fn presorted_matches_gathered_enumeration() {
        // random-ish column with duplicates; labels alternate with runs
        let mut rng = Rng::new(8);
        let n = 200usize;
        let col: Vec<f32> = (0..n).map(|_| (rng.index(40) as f32) * 0.5 - 3.0).collect();
        let labels: Vec<u8> = (0..n).map(|_| rng.bernoulli(0.45) as u8).collect();
        // pick an arbitrary subset as the "node"
        let ids: Vec<InstanceId> = (0..n as u32).filter(|i| i % 3 != 1).collect();
        let mut run = ids.clone();
        run.sort_unstable_by(|&a, &b| col[a as usize].total_cmp(&col[b as usize]));
        let by_scan = enumerate_valid_presorted(&col, &labels, &run);
        let mut pairs: Vec<(f32, u8)> = ids
            .iter()
            .map(|&i| (col[i as usize], labels[i as usize]))
            .collect();
        let by_sort = enumerate_valid(&mut pairs);
        assert_eq!(by_scan.len(), by_sort.len());
        for (a, b) in by_scan.iter().zip(&by_sort) {
            assert_eq!(a, b, "presorted enumeration diverged");
        }
    }

    #[test]
    fn presorted_trivial_runs_empty() {
        let col = [1.0f32, 2.0];
        let labels = [0u8, 1];
        assert!(enumerate_valid_presorted(&col, &labels, &[]).is_empty());
        assert!(enumerate_valid_presorted(&col, &labels, &[1]).is_empty());
        let both = enumerate_valid_presorted(&col, &labels, &[0, 1]);
        assert_eq!(both.len(), 1);
        assert_eq!(both[0].v, 1.5);
    }

    #[test]
    fn nan_values_never_form_thresholds() {
        // NaNs sort to the ends under total_cmp; they must be excluded from
        // boundaries AND from left counts (x ≤ v is false for NaN, so the
        // partition would never route them left).
        let mut p = pairs(&[(f32::NAN, 1), (1.0, 0), (2.0, 1), (-f32::NAN, 0)]);
        let c = enumerate_valid(&mut p);
        assert_eq!(c.len(), 1);
        assert_eq!(c[0].v, 1.5);
        assert_eq!(c[0].n_left, 1);
        assert_eq!(c[0].n_high_pos, 1);
        // all-NaN column: no candidates at all
        let mut all_nan = pairs(&[(f32::NAN, 0), (f32::NAN, 1)]);
        assert!(enumerate_valid(&mut all_nan).is_empty());
    }

    #[test]
    fn threshold_key_normalizes_signed_zero() {
        assert_eq!(threshold_key(-0.0), threshold_key(0.0));
        assert_ne!(threshold_key(1.0), threshold_key(2.0));
    }

    #[test]
    fn resample_shrinks_when_candidates_exhausted() {
        let mut rng = Rng::new(6);
        let mut p = pairs(&[(1.0, 0), (2.0, 1)]);
        let full = enumerate_valid(&mut p); // exactly one candidate
        let mut stored = vec![full[0], full[0]];
        stored[1].n_high = 0; // invalid duplicate
        resample_invalid(&mut stored, &full, 2, &mut rng);
        assert_eq!(stored.len(), 1, "no unselected candidates to draw");
    }
}
