//! Deferred (lazy) unlearning — the DynFrs-style serving lever over DaRE's
//! eager deletion (DESIGN.md §9).
//!
//! Under churn, a deletion's cost is dominated by the subtree retrains it
//! triggers at the moment of the request. This module splits the
//! `arena_update` walks into **mark** and **flush** halves:
//!
//! - the *mark* half runs the complete eager control flow — count updates,
//!   threshold maintenance, Lemma-A.1 resampling (consuming the identical
//!   `delete_rng(tree_seed, path, epoch)` streams), argmax re-selection,
//!   leaf collapses — but where the eager path would call `train_subtree`,
//!   it instead collapses the region to a *pending leaf* holding the exact
//!   instance-id vector the retrain would receive, and records the node in
//!   a per-tree [`DirtySet`];
//! - the *flush* half executes a recorded retrain:
//!   `train_subtree(ctx, ids, depth, path)`. Retrains are seeded by
//!   `(tree_seed, node_path)` only — never by wall-clock order or a shared
//!   sequential stream — so a flush is a **pure function** of the pending
//!   payload and *flush order cannot change the result*.
//!
//! **Exactness invariant.** At every hook boundary the lazy tree's
//! observable state equals the eager tree's: a walk (mutation, prediction,
//! or cost query) that is about to *enter* a pending region flushes it
//! first ([`LazySink::enter`]), and a walk about to *gather* a subtree's
//! ids flushes the subtree's pending descendants first
//! ([`LazySink::before_collect`]) so the gathered order — which feeds
//! retrain seeds and leaf payloads, and therefore serialized bytes — is
//! identical. By induction every served prediction / `delete_cost` under
//! `on_read` is bit-identical to the eager path at the moment of the query,
//! and flushing everything yields a forest bit-identical (structure,
//! serialized bytes, predictions) to eager — `tests/lazy_equivalence.rs`
//! and the lazy leg of `tests/op_fuzz.rs` enforce both.
//!
//! Pending leaves are *valid* arena leaves (counts, payload, hot value all
//! consistent), so `ArenaTree::validate` passes mid-deferral and ancestors'
//! count invariants hold; only the [`DirtySet`] distinguishes them from
//! final leaves.
//!
//! **Occ(q) add-tagging (DESIGN.md §13).** Under subsampled ownership the
//! forest layer gates every mutation on `owns(tree_seed, id, q)` *before*
//! these hooks run: a non-owning tree never marks, never accrues dirty
//! entries for the op, and never spends budgeted drain on it. An *owned*
//! add under a lazy policy lands here as a pending subtree exactly like a
//! deferred delete (`mark_add`), so the DynFrs compounding — most trees
//! skip the op outright, owning trees defer it — needs no new machinery in
//! this module; the per-tree dirty sets only ever hold owned work.

use crate::data::dataset::{Dataset, InstanceId};
use crate::forest::arena::{ArenaTree, Cold, NIL};
use crate::forest::arena_update::RetrainSink;
use crate::forest::train::{child_path, TrainCtx};
use crate::forest::workspace::train_subtree;
use std::collections::BTreeMap;

/// When deferred retrains are executed, relative to the mutation that
/// triggered them. Threaded through `DareForest`, the sharded coordinator
/// store, and `ServiceConfig`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum LazyPolicy {
    /// Retrain at the moment of the mutation (the paper's semantics; the
    /// historical behavior and the default).
    #[default]
    Eager,
    /// Defer every retrain; flush only what a prediction / `delete_cost`
    /// query descends into (plus whatever the background compactor drains).
    OnRead,
    /// Like `OnRead`, but each mutation also flushes up to `k` pending
    /// subtrees per tree before returning — bounds the dirty backlog while
    /// keeping the request off the worst-case retrain path.
    Budgeted(usize),
}

impl LazyPolicy {
    /// Parse `"eager" | "on_read" | "budgeted:<k>"` (case-insensitive).
    pub fn parse(s: &str) -> Option<LazyPolicy> {
        let s = s.trim().to_ascii_lowercase();
        match s.as_str() {
            "eager" => Some(LazyPolicy::Eager),
            "on_read" | "onread" | "lazy" => Some(LazyPolicy::OnRead),
            _ => s
                .strip_prefix("budgeted:")
                .and_then(|k| k.parse::<usize>().ok())
                .map(LazyPolicy::Budgeted),
        }
    }

    /// Policy from the `DARE_LAZY_POLICY` environment variable, falling
    /// back to `Eager`. This is how the CI matrix leg runs the whole suite
    /// with `on_read` as the service default.
    pub fn from_env() -> LazyPolicy {
        std::env::var("DARE_LAZY_POLICY")
            .ok()
            .and_then(|s| LazyPolicy::parse(&s))
            .unwrap_or(LazyPolicy::Eager)
    }

    /// Is any deferral active?
    #[inline]
    pub fn is_lazy(&self) -> bool {
        !matches!(self, LazyPolicy::Eager)
    }
}

impl std::fmt::Display for LazyPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LazyPolicy::Eager => write!(f, "eager"),
            LazyPolicy::OnRead => write!(f, "on_read"),
            LazyPolicy::Budgeted(k) => write!(f, "budgeted:{k}"),
        }
    }
}

/// One deferred retrain: the subtree at the recorded arena node must be
/// rebuilt as `train_subtree(ctx, <pending leaf payload>, depth, path)`.
/// The id vector itself lives in the node's `Cold::Leaf` payload so
/// ancestors' `collect_ids` and the arena audit see a consistent tree.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PendingRetrain {
    pub depth: usize,
    pub path: u64,
}

/// Per-tree record of deferred retrains, keyed by arena node id. Ordered
/// (BTreeMap) so budgeted/compactor drains are deterministic functions of
/// the operation sequence.
#[derive(Clone, Debug, Default)]
pub struct DirtySet {
    pending: BTreeMap<u32, PendingRetrain>,
    /// Cumulative retrains deferred (telemetry: `deferred_retrains`).
    deferred: u64,
    /// Cumulative deferred retrains executed (telemetry: `flushed_retrains`).
    flushed: u64,
}

impl DirtySet {
    #[inline]
    pub fn len(&self) -> usize {
        self.pending.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    #[inline]
    pub fn contains(&self, nid: u32) -> bool {
        self.pending.contains_key(&nid)
    }

    #[inline]
    pub fn deferred_total(&self) -> u64 {
        self.deferred
    }

    #[inline]
    pub fn flushed_total(&self) -> u64 {
        self.flushed
    }

    /// Iterate the pending node ids (ascending).
    pub fn ids(&self) -> impl Iterator<Item = u32> + '_ {
        self.pending.keys().copied()
    }

    /// Record a deferred retrain: collapse the subtree at `nid` into a
    /// pending leaf over `ids` and remember `(depth, path)`. The freed
    /// descendants are guaranteed pending-free by the walk contract (every
    /// defer site gathers — and therefore flushes — the subtree first).
    fn defer(
        &mut self,
        t: &mut ArenaTree,
        data: &Dataset,
        nid: u32,
        ids: Vec<InstanceId>,
        depth: usize,
        path: u64,
    ) {
        // Reuse the eager collapse primitive so pending-leaf construction
        // can never drift from the leaves the bit-exactness tests compare.
        t.collapse_to_leaf(nid, data, ids);
        self.record(nid, depth, path);
    }

    /// Single point of dirty-set bookkeeping: every deferral — whole-node
    /// or fresh child slot — goes through here, so the backlog invariant
    /// (`len == deferred − flushed`) and the double-defer guard live once.
    fn record(&mut self, nid: u32, depth: usize, path: u64) {
        let prev = self.pending.insert(nid, PendingRetrain { depth, path });
        debug_assert!(prev.is_none(), "node {nid} deferred twice without a flush");
        self.deferred += 1;
    }

    /// Execute one deferred retrain (no-op when `nid` is not pending).
    /// Pure in the pending payload: `train_subtree` is seeded by
    /// `(ctx.tree_seed, path)`, so *when* this runs cannot change what it
    /// builds.
    pub fn flush(&mut self, t: &mut ArenaTree, ctx: &TrainCtx<'_>, nid: u32) {
        let Some(p) = self.pending.remove(&nid) else {
            return;
        };
        let ids = {
            let Cold::Leaf { ids } = &mut t.cold[nid as usize] else {
                unreachable!("pending node {nid} lost its leaf payload");
            };
            std::mem::take(ids)
        };
        let node = train_subtree(ctx, ids, p.depth, p.path);
        t.replace_node(nid, node);
        self.flushed += 1;
    }

    /// Flush every pending node inside the subtree rooted at `nid`
    /// (including `nid` itself). Freshly flushed regions are fully trained
    /// and never contain further pendings, so the walk skips into them.
    pub fn flush_subtree(&mut self, t: &mut ArenaTree, ctx: &TrainCtx<'_>, nid: u32) {
        if self.pending.is_empty() {
            return;
        }
        let mut stack = vec![nid];
        while let Some(s) = stack.pop() {
            if self.pending.contains_key(&s) {
                self.flush(t, ctx, s);
                continue;
            }
            let si = s as usize;
            if t.hot.left[si] != NIL {
                stack.push(t.hot.left[si]);
                stack.push(t.hot.right[si]);
            }
            if self.pending.is_empty() {
                return;
            }
        }
    }

    /// Flush every pending node in the tree (ascending node-id order; order
    /// is irrelevant to the result — see [`DirtySet::flush`]).
    pub fn flush_all(&mut self, t: &mut ArenaTree, ctx: &TrainCtx<'_>) -> usize {
        self.flush_budget(t, ctx, usize::MAX)
    }

    /// Flush up to `k` pending nodes; returns how many were executed.
    pub fn flush_budget(&mut self, t: &mut ArenaTree, ctx: &TrainCtx<'_>, k: usize) -> usize {
        let mut n = 0usize;
        while n < k {
            let Some((&nid, _)) = self.pending.iter().next() else {
                break;
            };
            self.flush(t, ctx, nid);
            n += 1;
        }
        n
    }

    /// Shared descent-with-flush: walk the hot plane from the root routed
    /// by `feature(attr)` (the same `x ≤ v` predicate as every descent in
    /// the crate), flushing each pending node before stepping through it.
    fn flush_along(
        &mut self,
        t: &mut ArenaTree,
        ctx: &TrainCtx<'_>,
        feature: impl Fn(usize) -> f32,
    ) {
        if self.pending.is_empty() {
            return;
        }
        let mut i = t.root();
        loop {
            if self.pending.contains_key(&i) {
                self.flush(t, ctx, i);
            }
            let ii = i as usize;
            let l = t.hot.left[ii];
            if l == NIL {
                return;
            }
            i = if feature(t.hot.attr[ii] as usize) <= t.hot.thresh[ii] {
                l
            } else {
                t.hot.right[ii]
            };
        }
    }

    /// Flush the pending nodes a descent of `row` passes through, so a
    /// subsequent hot-plane prediction of `row` is bit-identical to the
    /// eager path ("flush just that subtree before serving").
    pub fn flush_for_row(&mut self, t: &mut ArenaTree, ctx: &TrainCtx<'_>, row: &[f32]) {
        self.flush_along(t, ctx, |attr| row[attr]);
    }

    /// Like [`DirtySet::flush_for_row`], routed by a training instance's
    /// stored feature values (the `delete_cost` as-if-flushed fix).
    pub fn flush_for_instance(&mut self, t: &mut ArenaTree, ctx: &TrainCtx<'_>, id: InstanceId) {
        let data = ctx.data;
        self.flush_along(t, ctx, move |attr| data.x(id, attr));
    }

    /// Audit the dirty set against the arena: every entry must name an
    /// in-bounds, live (non-free), leaf-shaped slot. Nesting is impossible
    /// by construction (pending nodes have no children), and
    /// `ArenaTree::validate` guarantees every non-free slot is reachable
    /// exactly once — together: every dirty entry is a live, flushable id.
    pub fn validate(&self, t: &ArenaTree) -> anyhow::Result<()> {
        for &nid in self.pending.keys() {
            let ni = nid as usize;
            anyhow::ensure!(ni < t.len(), "dirty entry {nid} out of bounds");
            anyhow::ensure!(
                !matches!(t.cold[ni], Cold::Free),
                "dirty entry {nid} names a freed slot"
            );
            anyhow::ensure!(
                matches!(t.cold[ni], Cold::Leaf { .. }) && t.hot.left[ni] == NIL,
                "dirty entry {nid} is not a pending (leaf-shaped) node"
            );
        }
        Ok(())
    }
}

/// The deferring executor for `arena_update::{delete_with, add_with}`: the
/// mark half of the pipeline. See the module docs for the invariants.
pub(crate) struct LazySink<'d> {
    pub dirty: &'d mut DirtySet,
}

impl RetrainSink for LazySink<'_> {
    /// A walk about to inspect a pending node materializes it first, so the
    /// control flow below is driven by eager-accurate structure.
    fn enter(&mut self, t: &mut ArenaTree, ctx: &TrainCtx<'_>, nid: u32) {
        if self.dirty.contains(nid) {
            self.dirty.flush(t, ctx, nid);
        }
    }

    /// A walk about to gather a subtree's ids materializes its pending
    /// descendants first: the gathered *order* feeds retrain inputs and
    /// leaf payloads, so it must match the eager tree's leaf order.
    fn before_collect(&mut self, t: &mut ArenaTree, ctx: &TrainCtx<'_>, nid: u32) {
        self.dirty.flush_subtree(t, ctx, nid);
    }

    fn retrain_node(
        &mut self,
        t: &mut ArenaTree,
        ctx: &TrainCtx<'_>,
        nid: u32,
        ids: Vec<InstanceId>,
        depth: usize,
        path: u64,
    ) {
        self.dirty.defer(t, ctx.data, nid, ids, depth, path);
    }

    fn retrain_children(
        &mut self,
        t: &mut ArenaTree,
        ctx: &TrainCtx<'_>,
        nid: u32,
        attr: usize,
        v: f32,
        left_ids: Vec<InstanceId>,
        right_ids: Vec<InstanceId>,
        depth: usize,
        path: u64,
    ) {
        // The split itself moved eagerly (stage 3 already updated the cold
        // plane's argmax); only the two child rebuilds are deferred. Slot
        // allocation differs from the eager graft order, but nothing
        // observable depends on slot ids (serialization, equality and
        // predictions all walk child pointers).
        t.free_children(nid);
        let ls = t.alloc();
        t.collapse_to_leaf(ls, ctx.data, left_ids);
        let rs = t.alloc();
        t.collapse_to_leaf(rs, ctx.data, right_ids);
        let ni = nid as usize;
        t.hot.attr[ni] = attr as u32;
        t.hot.thresh[ni] = v;
        t.hot.left[ni] = ls;
        t.hot.right[ni] = rs;
        self.dirty.record(ls, depth + 1, child_path(path, depth, false));
        self.dirty.record(rs, depth + 1, child_path(path, depth, true));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parsing_and_display() {
        assert_eq!(LazyPolicy::parse("eager"), Some(LazyPolicy::Eager));
        assert_eq!(LazyPolicy::parse("on_read"), Some(LazyPolicy::OnRead));
        assert_eq!(LazyPolicy::parse("ON_READ"), Some(LazyPolicy::OnRead));
        assert_eq!(LazyPolicy::parse("budgeted:4"), Some(LazyPolicy::Budgeted(4)));
        assert_eq!(LazyPolicy::parse("nope"), None);
        assert_eq!(LazyPolicy::parse("budgeted:x"), None);
        assert_eq!(LazyPolicy::Budgeted(3).to_string(), "budgeted:3");
        assert_eq!(
            LazyPolicy::parse(&LazyPolicy::OnRead.to_string()),
            Some(LazyPolicy::OnRead)
        );
        assert!(!LazyPolicy::Eager.is_lazy());
        assert!(LazyPolicy::OnRead.is_lazy());
        assert!(LazyPolicy::Budgeted(0).is_lazy());
        assert_eq!(LazyPolicy::default(), LazyPolicy::Eager);
    }

    #[test]
    fn dirty_set_counters_start_clean() {
        let d = DirtySet::default();
        assert!(d.is_empty());
        assert_eq!(d.len(), 0);
        assert_eq!(d.deferred_total(), 0);
        assert_eq!(d.flushed_total(), 0);
        assert!(!d.contains(0));
    }
}
