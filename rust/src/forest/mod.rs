//! The DaRE forest core (paper §3): data-removal-enabled trees with cached
//! node statistics, random upper layers, threshold subsampling, and exact
//! deletion.

pub mod arena;
pub mod arena_update;
pub mod criterion;
pub mod delete;
pub mod forest;
pub mod lazy;
pub mod node;
pub mod params;
pub mod serialize;
pub mod stats;
pub mod train;
pub mod tree;
pub mod workspace;

pub use arena::{ArenaTree, HotPlane};
pub use delete::{DeleteReport, RetrainEvent};
pub use forest::{owned_live_ids, owns, DareForest, ForestDeleteReport};
pub use lazy::{DirtySet, LazyPolicy};
pub use node::{Node, NodeMemory, TreeShape};
pub use params::{MaxFeatures, Params, SplitCriterion};
pub use tree::{structural_eq, DareTree};
