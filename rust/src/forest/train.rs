//! DaRE tree training (paper Alg. 1 / Alg. 3 TRAIN).
//!
//! Training is recursive: random nodes in the top `d_rmax` layers, greedy
//! nodes below, leaves at the stopping criteria (pure node, max depth, or
//! too few instances). Every node's randomness comes from a stream seeded by
//! `(tree_seed, node_path)`, so retraining a subtree on the same data replays
//! the same choices — the property the exactness tests exploit (DESIGN.md §5).

use crate::data::dataset::{Dataset, InstanceId};
use crate::forest::node::{GreedyNode, LeafNode, Node, RandomNode};
use crate::forest::params::Params;
use crate::forest::stats::{enumerate_valid, sample_thresholds, AttrStats};
use crate::forest::criterion::split_score;
use crate::util::rng::{mix_seed, Rng};

/// Shared context threaded through the recursion.
pub struct TrainCtx<'a> {
    pub data: &'a Dataset,
    pub params: &'a Params,
    pub tree_seed: u64,
}

/// Path discriminator of the root node.
pub const ROOT_PATH: u64 = 0x600D_F00D;

/// Path discriminator of a child node.
#[inline]
pub fn child_path(path: u64, depth: usize, right: bool) -> u64 {
    mix_seed(&[path, depth as u64, right as u64 + 1])
}

/// RNG for the node at `path`.
#[inline]
pub fn node_rng(tree_seed: u64, path: u64) -> Rng {
    Rng::new(mix_seed(&[tree_seed, path]))
}

/// Gather (value, label) pairs of one attribute over the given instances.
/// Reads through the column slice directly (no per-element bounds hops).
pub fn gather_pairs(data: &Dataset, ids: &[InstanceId], attr: usize) -> Vec<(f32, u8)> {
    let col = data.col(attr);
    ids.iter()
        .map(|&i| (col[i as usize], data.y(i)))
        .collect()
}

/// Partition ids by `x_attr ≤ v` into (left, right).
pub fn partition(
    data: &Dataset,
    ids: &[InstanceId],
    attr: usize,
    v: f32,
) -> (Vec<InstanceId>, Vec<InstanceId>) {
    let mut left = Vec::with_capacity(ids.len());
    let mut right = Vec::with_capacity(ids.len());
    let col = data.col(attr);
    for &i in ids {
        if col[i as usize] <= v {
            left.push(i);
        } else {
            right.push(i);
        }
    }
    (left, right)
}

/// Select the best (attr_slot, thr_slot) over all cached stats; ties break to
/// the first-encountered pair (stored order is random, so the tie-break is
/// distributionally harmless). Returns None when no thresholds exist.
pub fn select_best(node_n: u32, node_pos: u32, attrs: &[AttrStats], params: &Params) -> Option<(usize, usize)> {
    let mut best: Option<(usize, usize, f64)> = None;
    for (ai, a) in attrs.iter().enumerate() {
        for (ti, t) in a.thresholds.iter().enumerate() {
            let s = split_score(params.criterion, node_n, node_pos, t.n_left, t.n_left_pos);
            match best {
                Some((_, _, bs)) if s >= bs => {}
                _ => best = Some((ai, ti, s)),
            }
        }
    }
    best.map(|(a, t, _)| (a, t))
}

/// Count positives among `ids`.
#[inline]
pub fn count_pos(data: &Dataset, ids: &[InstanceId]) -> u32 {
    ids.iter().map(|&i| data.y(i) as u32).sum()
}

/// Build a leaf from `ids`.
pub fn make_leaf(data: &Dataset, ids: Vec<InstanceId>) -> Node {
    let n_pos = count_pos(data, &ids);
    Node::Leaf(LeafNode {
        n: ids.len() as u32,
        n_pos,
        ids,
    })
}

/// Train a DaRE (sub)tree on `ids` rooted at `depth` with path id `path`
/// (paper Alg. 1). Used both for initial training and for the subtree
/// retraining triggered by deletions (Alg. 2).
pub fn train(ctx: &TrainCtx<'_>, ids: Vec<InstanceId>, depth: usize, path: u64) -> Node {
    let n = ids.len() as u32;
    let n_pos = count_pos(ctx.data, &ids);

    // stopping criteria: pure node, insufficient data, or max depth
    if n < ctx.params.min_samples_split as u32
        || n_pos == 0
        || n_pos == n
        || depth >= ctx.params.max_depth
    {
        return make_leaf(ctx.data, ids);
    }

    if depth < ctx.params.d_rmax {
        train_random(ctx, ids, n, n_pos, depth, path)
    } else {
        train_greedy(ctx, ids, n, n_pos, depth, path)
    }
}

/// Random decision node (§3.3): attribute uniform over P (rejecting
/// attributes constant in D), threshold uniform in [a_min, a_max).
fn train_random(
    ctx: &TrainCtx<'_>,
    ids: Vec<InstanceId>,
    n: u32,
    n_pos: u32,
    depth: usize,
    path: u64,
) -> Node {
    let mut rng = node_rng(ctx.tree_seed, path);
    let p = ctx.data.n_features();
    // Rejection-sample an attribute that is non-constant at this node;
    // uniform over the non-constant attributes.
    let mut order: Vec<usize> = (0..p).collect();
    rng.shuffle(&mut order);
    let mut chosen: Option<(usize, f32, f32)> = None;
    for attr in order {
        // Read through the column slice directly (like `gather_pairs`)
        // instead of per-element `x(i, attr)` double-indexing.
        let col = ctx.data.col(attr);
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &i in &ids {
            let v = col[i as usize];
            if v < lo {
                lo = v;
            }
            if v > hi {
                hi = v;
            }
        }
        if lo < hi {
            chosen = Some((attr, lo, hi));
            break;
        }
    }
    let Some((attr, lo, hi)) = chosen else {
        // all attributes constant: cannot split (duplicate points)
        return make_leaf(ctx.data, ids);
    };
    let v = rng.range_f32(lo, hi);
    let (left_ids, right_ids) = partition(ctx.data, &ids, attr, v);
    debug_assert!(!left_ids.is_empty() && !right_ids.is_empty());
    let n_left = left_ids.len() as u32;
    let n_right = right_ids.len() as u32;
    let left = train(ctx, left_ids, depth + 1, child_path(path, depth, false));
    let right = train(ctx, right_ids, depth + 1, child_path(path, depth, true));
    Node::Random(RandomNode {
        n,
        n_pos,
        attr,
        v,
        n_left,
        n_right,
        left: Box::new(left),
        right: Box::new(right),
    })
}

/// Greedy decision node (Alg. 1 lines 15–27): sample p̃ *valid* attributes
/// (uniform over valid attributes, per §A.1), ≤k valid thresholds each,
/// cache statistics, pick the criterion-optimal pair.
fn train_greedy(
    ctx: &TrainCtx<'_>,
    ids: Vec<InstanceId>,
    n: u32,
    n_pos: u32,
    depth: usize,
    path: u64,
) -> Node {
    let mut rng = node_rng(ctx.tree_seed, path);
    let p = ctx.data.n_features();
    let p_tilde = ctx.params.max_features.resolve(p);

    // Draw attributes uniformly without replacement, keeping the first p̃
    // that have at least one valid threshold (rejection ⇒ uniform over the
    // valid attributes, matching the resampling semantics of §A.1).
    let mut order: Vec<usize> = (0..p).collect();
    rng.shuffle(&mut order);
    let mut attrs: Vec<AttrStats> = Vec::with_capacity(p_tilde);
    for attr in order {
        if attrs.len() == p_tilde {
            break;
        }
        let mut pairs = gather_pairs(ctx.data, &ids, attr);
        let candidates = enumerate_valid(&mut pairs);
        if candidates.is_empty() {
            continue; // invalid attribute at this node
        }
        let thresholds = sample_thresholds(candidates, ctx.params.k, &mut rng);
        attrs.push(AttrStats { attr, thresholds });
    }
    if attrs.is_empty() {
        // No valid split anywhere (e.g. identical points with mixed labels).
        return make_leaf(ctx.data, ids);
    }

    let (best_attr, best_thr) =
        select_best(n, n_pos, &attrs, ctx.params).expect("non-empty attrs");
    let split_attr = attrs[best_attr].attr;
    let split_v = attrs[best_attr].thresholds[best_thr].v;
    let (left_ids, right_ids) = partition(ctx.data, &ids, split_attr, split_v);
    debug_assert!(
        !left_ids.is_empty() && !right_ids.is_empty(),
        "valid threshold must split non-trivially"
    );
    let left = train(ctx, left_ids, depth + 1, child_path(path, depth, false));
    let right = train(ctx, right_ids, depth + 1, child_path(path, depth, true));
    Node::Greedy(GreedyNode {
        n,
        n_pos,
        attrs,
        best_attr,
        best_thr,
        left: Box::new(left),
        right: Box::new(right),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};
    use crate::forest::params::MaxFeatures;

    fn ctx_params(d_rmax: usize, k: usize) -> Params {
        Params {
            n_trees: 1,
            max_depth: 8,
            k,
            d_rmax,
            max_features: MaxFeatures::Sqrt,
            ..Default::default()
        }
    }

    fn toy_data(n: usize) -> Dataset {
        generate(
            &SynthSpec {
                n,
                informative: 3,
                redundant: 1,
                noise: 2,
                flip: 0.05,
                ..Default::default()
            },
            99,
        )
    }

    fn check_counts(node: &Node, data: &Dataset) {
        match node {
            Node::Leaf(l) => {
                assert_eq!(l.n as usize, l.ids.len());
                assert_eq!(l.n_pos, count_pos(data, &l.ids));
            }
            Node::Random(r) => {
                assert_eq!(r.n, r.left.n() + r.right.n());
                assert_eq!(r.n_pos, r.left.n_pos() + r.right.n_pos());
                assert_eq!(r.n_left, r.left.n());
                assert_eq!(r.n_right, r.right.n());
                assert!(r.n_left > 0 && r.n_right > 0);
                check_counts(&r.left, data);
                check_counts(&r.right, data);
            }
            Node::Greedy(g) => {
                assert_eq!(g.n, g.left.n() + g.right.n());
                assert_eq!(g.n_pos, g.left.n_pos() + g.right.n_pos());
                let t = &g.attrs[g.best_attr].thresholds[g.best_thr];
                assert_eq!(t.n_left, g.left.n());
                assert_eq!(t.n_left_pos, g.left.n_pos());
                for a in &g.attrs {
                    assert!(!a.thresholds.is_empty());
                    for t in &a.thresholds {
                        assert!(t.is_valid(), "thresholds valid at train time");
                        assert!(t.n_left <= g.n && t.n_left_pos <= g.n_pos);
                    }
                }
                check_counts(&g.left, data);
                check_counts(&g.right, data);
            }
        }
    }

    #[test]
    fn trains_consistent_greedy_tree() {
        let data = toy_data(300);
        let params = ctx_params(0, 5);
        let ctx = TrainCtx {
            data: &data,
            params: &params,
            tree_seed: 7,
        };
        let root = train(&ctx, data.live_ids(), 0, ROOT_PATH);
        assert_eq!(root.n() as usize, 300);
        check_counts(&root, &data);
        let s = root.shape();
        assert!(s.greedy_nodes > 0);
        assert_eq!(s.random_nodes, 0);
        assert!(s.max_depth <= 8);
    }

    #[test]
    fn random_layers_obey_drmax() {
        let data = toy_data(400);
        let params = ctx_params(3, 5);
        let ctx = TrainCtx {
            data: &data,
            params: &params,
            tree_seed: 11,
        };
        let root = train(&ctx, data.live_ids(), 0, ROOT_PATH);
        check_counts(&root, &data);
        // walk: depth < 3 ⇒ Random or Leaf; depth >= 3 ⇒ Greedy or Leaf
        fn walk(node: &Node, depth: usize) {
            match node {
                Node::Leaf(_) => {}
                Node::Random(r) => {
                    assert!(depth < 3, "random node below d_rmax at depth {depth}");
                    walk(&r.left, depth + 1);
                    walk(&r.right, depth + 1);
                }
                Node::Greedy(g) => {
                    assert!(depth >= 3, "greedy node above d_rmax at depth {depth}");
                    walk(&g.left, depth + 1);
                    walk(&g.right, depth + 1);
                }
            }
        }
        walk(&root, 0);
        assert!(root.shape().random_nodes > 0);
    }

    #[test]
    fn deterministic_given_seed() {
        let data = toy_data(200);
        let params = ctx_params(2, 5);
        let ctx = TrainCtx {
            data: &data,
            params: &params,
            tree_seed: 5,
        };
        let a = train(&ctx, data.live_ids(), 0, ROOT_PATH);
        let b = train(&ctx, data.live_ids(), 0, ROOT_PATH);
        assert!(crate::forest::tree::structural_eq(&a, &b));
        let ctx2 = TrainCtx {
            tree_seed: 6,
            ..ctx
        };
        let c = train(&ctx2, data.live_ids(), 0, ROOT_PATH);
        assert!(!crate::forest::tree::structural_eq(&a, &c));
    }

    #[test]
    fn pure_data_yields_leaf() {
        let data = Dataset::from_rows(&[vec![1.0], vec![2.0], vec![3.0]], vec![1, 1, 1]);
        let params = ctx_params(0, 5);
        let ctx = TrainCtx {
            data: &data,
            params: &params,
            tree_seed: 1,
        };
        let root = train(&ctx, data.live_ids(), 0, ROOT_PATH);
        assert!(matches!(root, Node::Leaf(_)));
        assert_eq!(root.predict(&[1.0]), 1.0);
    }

    #[test]
    fn identical_points_mixed_labels_yield_leaf() {
        let data = Dataset::from_rows(&[vec![1.0], vec![1.0], vec![1.0], vec![1.0]], vec![1, 0, 1, 0]);
        let params = ctx_params(2, 5); // even with random layers requested
        let ctx = TrainCtx {
            data: &data,
            params: &params,
            tree_seed: 1,
        };
        let root = train(&ctx, data.live_ids(), 0, ROOT_PATH);
        assert!(matches!(root, Node::Leaf(_)));
        assert_eq!(root.predict(&[1.0]), 0.5);
    }

    #[test]
    fn max_depth_respected() {
        let data = toy_data(2000);
        let params = Params {
            max_depth: 3,
            ..ctx_params(0, 10)
        };
        let ctx = TrainCtx {
            data: &data,
            params: &params,
            tree_seed: 2,
        };
        let root = train(&ctx, data.live_ids(), 0, ROOT_PATH);
        assert!(root.shape().max_depth <= 3);
    }

    #[test]
    fn training_accuracy_beats_chance() {
        let data = toy_data(1000);
        let params = ctx_params(0, 10);
        let ctx = TrainCtx {
            data: &data,
            params: &params,
            tree_seed: 3,
        };
        let root = train(&ctx, data.live_ids(), 0, ROOT_PATH);
        let mut correct = 0;
        for id in data.live_ids() {
            let p = root.predict(&data.row(id));
            if (p >= 0.5) as u8 == data.y(id) {
                correct += 1;
            }
        }
        let acc = correct as f64 / 1000.0;
        assert!(acc > 0.8, "training acc {acc}");
    }
}
