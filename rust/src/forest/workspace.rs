//! Sort-free training workspace (DESIGN.md §6).
//!
//! The seed training path (`train.rs`) re-gathers and re-sorts every sampled
//! attribute at **every** greedy node — an O(depth · p̃ · m log m) cascade of
//! redundant sorts plus a fresh `Vec` per gather. This module removes both:
//!
//! 1. **Presorted columns.** Each feature column is sorted *once* per
//!    (sub)tree: `cols[j]` holds the node's instance ids ordered by attribute
//!    `j` under `f32::total_cmp`. A node occupies the same index range
//!    `[lo, hi)` in all `p` orderings, and splitting a node stably partitions
//!    every ordering in place, so both children inherit value-sorted runs.
//!    Threshold enumeration then becomes a linear scan
//!    ([`crate::forest::stats::enumerate_valid_presorted`]) instead of a
//!    gather + `sort_unstable` per attribute per node.
//! 2. **Reusable scratch buffers.** The id orderings, the stable-partition
//!    scratch vector, and the goes-left byte mask are owned by a
//!    thread-local [`TrainWorkspace`] and recycled across nodes, trees and
//!    subtree retrains — no per-node `Vec` churn.
//!
//! **Exactness invariant** (enforced by `tests/workspace_exactness.rs`):
//! trees built here are `structural_eq` to the seed path's. This holds
//! because (a) node RNG streams are keyed by `(tree_seed, node_path)` and
//! both paths consume draws in the same order, (b) a stably-partitioned
//! subset of a `total_cmp`-sorted run is itself `total_cmp`-sorted, so the
//! per-attribute (value, label) group sequence — and therefore every
//! candidate-threshold list — is bit-identical to gather + sort, and (c) the
//! split predicate `x ≤ v` partitions the same instance sets. Leaf id
//! *order* differs (value order vs. arrival order), which `structural_eq`
//! deliberately ignores.

use std::cell::RefCell;

use crate::data::dataset::{Dataset, InstanceId};
use crate::forest::node::{GreedyNode, Node, RandomNode};
use crate::forest::params::Params;
use crate::forest::stats::{enumerate_valid_presorted, sample_thresholds, AttrStats};
use crate::forest::train::{
    child_path, count_pos, make_leaf, node_rng, select_best, train, TrainCtx, ROOT_PATH,
};

/// Below this many instances the plain gather+sort path always wins: the
/// workspace setup costs p column sorts, which only amortize over a deep
/// enough recursion. Both paths are bit-exact, so the gate (see
/// [`workspace_pays`]) is a pure heuristic — the deletion path's many tiny
/// subtree retrains take the plain route.
pub const WORKSPACE_CUTOFF: usize = 64;

/// Retained-buffer bound: after a build whose buffers exceed this many
/// elements, the thread-local workspace is dropped instead of cached, so
/// paper-scale fits (p·n can reach hundreds of MB) don't stay pinned in
/// thread-local storage for the thread's lifetime. ~16 MB of u32 ids.
const RETAIN_ELEMS: usize = 1 << 22;

/// Does presorting pay for this job? The workspace sorts ALL p columns once
/// (O(p·m log m)); the seed path sorts only the p̃ sampled columns, but at
/// every level (O(p̃·m log m) per level). The crossover is a recursion depth
/// of ~p/p̃, so wide datasets with `MaxFeatures::Sqrt` need a deeper (≈
/// larger) subtree before the workspace wins. Purely a perf heuristic —
/// both paths produce `structural_eq` trees.
fn workspace_pays(m: usize, p: usize, depth: usize, params: &Params) -> bool {
    if m < WORKSPACE_CUTOFF || p == 0 {
        return false;
    }
    let p_tilde = params.max_features.resolve(p);
    let remaining = params.max_depth.saturating_sub(depth).max(1);
    let depth_est = ((usize::BITS - m.leading_zeros()) as usize).min(remaining);
    depth_est >= p / p_tilde
}

/// Reusable per-thread training state: presorted per-attribute id orderings
/// plus the scratch buffers of the stable partition.
///
/// Buffer ownership (DESIGN.md §6): one workspace per OS thread, held in a
/// thread-local and borrowed for the duration of one (sub)tree build. The
/// recursion works entirely inside `[lo, hi)` index ranges of the shared
/// orderings, so no per-node allocation is needed; `mask` is indexed by
/// global instance id and only ever read after being written for the node at
/// hand, so it is never cleared.
#[derive(Debug, Default)]
pub struct TrainWorkspace {
    /// `cols[j][lo..hi]` = ids of the current node, sorted by attribute `j`
    /// (`total_cmp` order). All attributes hold the same id multiset per
    /// node range.
    cols: Vec<Vec<InstanceId>>,
    /// Stable-partition staging area (sized to the root segment).
    scratch: Vec<InstanceId>,
    /// Goes-left flags of the split being applied, indexed by instance id.
    mask: Vec<u8>,
}

impl TrainWorkspace {
    pub fn new() -> Self {
        TrainWorkspace::default()
    }

    /// Load `ids` and sort them by every attribute — the single O(p·m log m)
    /// sort this whole (sub)tree build will perform.
    fn prepare(&mut self, data: &Dataset, ids: &[InstanceId]) {
        let p = data.n_features();
        self.cols.resize_with(p, Vec::new);
        for (j, ordering) in self.cols.iter_mut().enumerate() {
            let col = data.col(j);
            ordering.clear();
            ordering.extend_from_slice(ids);
            ordering.sort_unstable_by(|&a, &b| col[a as usize].total_cmp(&col[b as usize]));
        }
        self.scratch.resize(ids.len(), 0);
        if self.mask.len() < data.n_total() {
            self.mask.resize(data.n_total(), 0);
        }
    }

    /// Stable-partition every attribute ordering of `[lo, hi)` by
    /// `col[id] ≤ v` (`col` = the split attribute's column). Left-going ids
    /// end up in `[lo, lo + n_left)`, right-going in the remainder, each
    /// side preserving its value-sorted order. Returns `n_left`.
    fn split_segment(&mut self, col: &[f32], lo: usize, hi: usize, split_attr: usize, v: f32) -> usize {
        let mut n_left = 0usize;
        for &i in &self.cols[split_attr][lo..hi] {
            let gl = (col[i as usize] <= v) as u8;
            self.mask[i as usize] = gl;
            n_left += gl as usize;
        }
        let m = hi - lo;
        for j in 0..self.cols.len() {
            let scratch = &mut self.scratch[..m];
            let seg = &mut self.cols[j][lo..hi];
            let (mut a, mut b) = (0usize, n_left);
            for &i in seg.iter() {
                if self.mask[i as usize] == 1 {
                    scratch[a] = i;
                    a += 1;
                } else {
                    scratch[b] = i;
                    b += 1;
                }
            }
            debug_assert!(a == n_left && b == m, "partition counts disagree");
            seg.copy_from_slice(scratch);
        }
        n_left
    }

    /// Current node's ids (any attribute ordering works — attribute 0 by
    /// convention; callers guarantee p ≥ 1).
    #[inline]
    fn ids(&self, lo: usize, hi: usize) -> &[InstanceId] {
        &self.cols[0][lo..hi]
    }
}

thread_local! {
    /// One workspace per thread: per-tree parallelism hands whole trees to
    /// worker threads, so builds never share a workspace.
    static WS: RefCell<TrainWorkspace> = RefCell::new(TrainWorkspace::new());
}

/// Train a full tree over the live instances — the workspace-backed
/// equivalent of `train(ctx, data.live_ids(), 0, ROOT_PATH)`.
pub fn train_tree(data: &Dataset, params: &Params, tree_seed: u64) -> Node {
    let ctx = TrainCtx {
        data,
        params,
        tree_seed,
    };
    train_subtree(&ctx, data.live_ids(), 0, ROOT_PATH)
}

/// Drop-in replacement for [`train`]: trains the (sub)tree rooted at `depth`
/// / `path` over `ids`, producing a `structural_eq`-identical tree. Small
/// jobs (and the degenerate p = 0 case) fall through to the plain path; big
/// ones sort each column once and recurse sort-free. Used by `DareTree::fit`
/// and by every subtree-retrain site on the deletion/addition path.
pub fn train_subtree(ctx: &TrainCtx<'_>, ids: Vec<InstanceId>, depth: usize, path: u64) -> Node {
    let m = ids.len();
    let p = ctx.data.n_features();
    if !workspace_pays(m, p, depth, ctx.params) {
        return train(ctx, ids, depth, path);
    }
    WS.with(|cell| match cell.try_borrow_mut() {
        Ok(mut ws) => {
            ws.prepare(ctx.data, &ids);
            drop(ids);
            let node = train_ws(ctx, &mut ws, 0, m, depth, path);
            // Cache small buffers for the next (sub)tree on this thread;
            // drop big ones so paper-scale builds don't pin O(p·n) memory
            // in thread-local storage (mask counts at 1/4 weight: u8 vs u32).
            let retained = m
                .saturating_mul(p + 1)
                .saturating_add(ctx.data.n_total() / 4);
            if retained > RETAIN_ELEMS {
                *ws = TrainWorkspace::default();
            }
            node
        }
        // Defensive: a re-entrant build on this thread (none exist today)
        // falls back to the allocation-per-node path rather than panicking.
        Err(_) => train(ctx, ids, depth, path),
    })
}

/// Core recursion: mirrors `train.rs::train` over a workspace segment.
fn train_ws(
    ctx: &TrainCtx<'_>,
    ws: &mut TrainWorkspace,
    lo: usize,
    hi: usize,
    depth: usize,
    path: u64,
) -> Node {
    let n = (hi - lo) as u32;
    let n_pos = count_pos(ctx.data, ws.ids(lo, hi));

    // stopping criteria: pure node, insufficient data, or max depth
    if n < ctx.params.min_samples_split as u32
        || n_pos == 0
        || n_pos == n
        || depth >= ctx.params.max_depth
    {
        return make_leaf(ctx.data, ws.ids(lo, hi).to_vec());
    }

    if depth < ctx.params.d_rmax {
        train_random_ws(ctx, ws, lo, hi, n, n_pos, depth, path)
    } else {
        train_greedy_ws(ctx, ws, lo, hi, n, n_pos, depth, path)
    }
}

/// Random decision node (§3.3) over a presorted segment. The min/max scan of
/// the seed path collapses to reading the ends of the value-sorted run
/// (skipping inward past NaNs, which the seed scan's `<`/`>` comparisons
/// ignore).
#[allow(clippy::too_many_arguments)]
fn train_random_ws(
    ctx: &TrainCtx<'_>,
    ws: &mut TrainWorkspace,
    lo: usize,
    hi: usize,
    n: u32,
    n_pos: u32,
    depth: usize,
    path: u64,
) -> Node {
    let mut rng = node_rng(ctx.tree_seed, path);
    let p = ctx.data.n_features();
    let mut order: Vec<usize> = (0..p).collect();
    rng.shuffle(&mut order);
    let mut chosen: Option<(usize, f32, f32)> = None;
    for attr in order {
        let col = ctx.data.col(attr);
        let seg = &ws.cols[attr][lo..hi];
        let mut a = 0usize;
        let mut b = seg.len();
        while a < b && col[seg[a] as usize].is_nan() {
            a += 1;
        }
        while b > a && col[seg[b - 1] as usize].is_nan() {
            b -= 1;
        }
        if a < b {
            let lo_v = col[seg[a] as usize];
            let hi_v = col[seg[b - 1] as usize];
            if lo_v < hi_v {
                chosen = Some((attr, lo_v, hi_v));
                break;
            }
        }
    }
    let Some((attr, lo_v, hi_v)) = chosen else {
        // all attributes constant: cannot split (duplicate points)
        return make_leaf(ctx.data, ws.ids(lo, hi).to_vec());
    };
    let v = rng.range_f32(lo_v, hi_v);
    let n_left = ws.split_segment(ctx.data.col(attr), lo, hi, attr, v);
    debug_assert!(n_left > 0 && n_left < hi - lo);
    let mid = lo + n_left;
    let left = train_ws(ctx, ws, lo, mid, depth + 1, child_path(path, depth, false));
    let right = train_ws(ctx, ws, mid, hi, depth + 1, child_path(path, depth, true));
    Node::Random(RandomNode {
        n,
        n_pos,
        attr,
        v,
        n_left: n_left as u32,
        n_right: (hi - mid) as u32,
        left: Box::new(left),
        right: Box::new(right),
    })
}

/// Greedy decision node (Alg. 1 lines 15–27) over a presorted segment:
/// candidate enumeration is a linear scan per sampled attribute.
#[allow(clippy::too_many_arguments)]
fn train_greedy_ws(
    ctx: &TrainCtx<'_>,
    ws: &mut TrainWorkspace,
    lo: usize,
    hi: usize,
    n: u32,
    n_pos: u32,
    depth: usize,
    path: u64,
) -> Node {
    let mut rng = node_rng(ctx.tree_seed, path);
    let p = ctx.data.n_features();
    let p_tilde = ctx.params.max_features.resolve(p);
    let labels = ctx.data.labels();

    let mut order: Vec<usize> = (0..p).collect();
    rng.shuffle(&mut order);
    let mut attrs: Vec<AttrStats> = Vec::with_capacity(p_tilde);
    for attr in order {
        if attrs.len() == p_tilde {
            break;
        }
        let candidates =
            enumerate_valid_presorted(ctx.data.col(attr), labels, &ws.cols[attr][lo..hi]);
        if candidates.is_empty() {
            continue; // invalid attribute at this node
        }
        let thresholds = sample_thresholds(candidates, ctx.params.k, &mut rng);
        attrs.push(AttrStats { attr, thresholds });
    }
    if attrs.is_empty() {
        // No valid split anywhere (e.g. identical points with mixed labels).
        return make_leaf(ctx.data, ws.ids(lo, hi).to_vec());
    }

    let (best_attr, best_thr) =
        select_best(n, n_pos, &attrs, ctx.params).expect("non-empty attrs");
    let split_attr = attrs[best_attr].attr;
    let split_v = attrs[best_attr].thresholds[best_thr].v;
    let n_left = ws.split_segment(ctx.data.col(split_attr), lo, hi, split_attr, split_v);
    debug_assert!(
        n_left > 0 && n_left < hi - lo,
        "valid threshold must split non-trivially"
    );
    let mid = lo + n_left;
    let left = train_ws(ctx, ws, lo, mid, depth + 1, child_path(path, depth, false));
    let right = train_ws(ctx, ws, mid, hi, depth + 1, child_path(path, depth, true));
    Node::Greedy(GreedyNode {
        n,
        n_pos,
        attrs,
        best_attr,
        best_thr,
        left: Box::new(left),
        right: Box::new(right),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};
    use crate::forest::params::MaxFeatures;
    use crate::forest::tree::structural_eq;

    fn toy_data(n: usize, seed: u64) -> Dataset {
        generate(
            &SynthSpec {
                n,
                informative: 3,
                redundant: 1,
                noise: 2,
                flip: 0.05,
                ..Default::default()
            },
            seed,
        )
    }

    fn params(d_rmax: usize) -> Params {
        Params {
            n_trees: 1,
            max_depth: 8,
            k: 5,
            d_rmax,
            max_features: MaxFeatures::Sqrt,
            ..Default::default()
        }
    }

    #[test]
    fn prepare_sorts_every_column() {
        let data = toy_data(200, 3);
        let mut ws = TrainWorkspace::new();
        ws.prepare(&data, &data.live_ids());
        for j in 0..data.n_features() {
            let col = data.col(j);
            assert_eq!(ws.cols[j].len(), 200);
            assert!(ws.cols[j]
                .windows(2)
                .all(|w| col[w[0] as usize] <= col[w[1] as usize]));
        }
    }

    #[test]
    fn split_segment_is_stable_and_complete() {
        let data = toy_data(150, 4);
        let mut ws = TrainWorkspace::new();
        ws.prepare(&data, &data.live_ids());
        let col0 = data.col(0).to_vec();
        // split on the median-ish value of attribute 0
        let v = col0[ws.cols[0][75] as usize];
        let n_left = ws.split_segment(&col0, 0, 150, 0, v);
        assert!(n_left > 0 && n_left < 150);
        for j in 0..data.n_features() {
            let col = data.col(j);
            let (l, r) = ws.cols[j].split_at(n_left);
            // membership respects the predicate
            assert!(l.iter().all(|&i| col0[i as usize] <= v));
            assert!(r.iter().all(|&i| col0[i as usize] > v));
            // each side stays value-sorted on its own attribute
            assert!(l.windows(2).all(|w| col[w[0] as usize] <= col[w[1] as usize]));
            assert!(r.windows(2).all(|w| col[w[0] as usize] <= col[w[1] as usize]));
        }
    }

    #[test]
    fn workspace_tree_matches_seed_tree() {
        // Above the cutoff so the presorted path actually runs.
        let data = toy_data(500, 5);
        for d_rmax in [0usize, 2] {
            let p = params(d_rmax);
            for tree_seed in [1u64, 2, 3] {
                let ctx = TrainCtx {
                    data: &data,
                    params: &p,
                    tree_seed,
                };
                let seed_tree = train(&ctx, data.live_ids(), 0, ROOT_PATH);
                let ws_tree = train_subtree(&ctx, data.live_ids(), 0, ROOT_PATH);
                assert!(
                    structural_eq(&seed_tree, &ws_tree),
                    "workspace tree diverged (d_rmax={d_rmax}, seed={tree_seed})"
                );
            }
        }
    }

    #[test]
    fn small_jobs_fall_back_to_plain_path() {
        let data = toy_data(WORKSPACE_CUTOFF - 1, 6);
        let p = params(0);
        let ctx = TrainCtx {
            data: &data,
            params: &p,
            tree_seed: 9,
        };
        let a = train_subtree(&ctx, data.live_ids(), 0, ROOT_PATH);
        let b = train(&ctx, data.live_ids(), 0, ROOT_PATH);
        assert!(structural_eq(&a, &b));
    }

    #[test]
    fn zero_feature_data_degrades_to_leaf() {
        let data = Dataset::from_columns(vec![], vec![0, 1, 0, 1]);
        let p = params(0);
        let ctx = TrainCtx {
            data: &data,
            params: &p,
            tree_seed: 1,
        };
        let root = train_subtree(&ctx, data.live_ids(), 0, ROOT_PATH);
        assert!(matches!(root, Node::Leaf(_)));
        assert_eq!(root.n(), 4);
    }

    #[test]
    fn train_tree_entry_point() {
        let data = toy_data(300, 7);
        let p = params(1);
        let a = train_tree(&data, &p, 42);
        let ctx = TrainCtx {
            data: &data,
            params: &p,
            tree_seed: 42,
        };
        let b = train(&ctx, data.live_ids(), 0, ROOT_PATH);
        assert!(structural_eq(&a, &b));
    }
}
