//! A single DaRE tree: the unit of training, deletion and prediction.
//!
//! Since the arena refactor (DESIGN.md §7) the tree's nodes live in an
//! [`ArenaTree`] — an SoA hot plane for descents plus an id-indexed cold
//! plane for the cached deletion statistics — instead of a `Box<Node>` web.
//! Trees are still *built* as boxed [`Node`]s by the (workspace) trainer and
//! grafted into the arena, which keeps the boxed path available as the
//! bit-exactness oracle.

use crate::data::dataset::{Dataset, InstanceId};
use crate::forest::arena::{ArenaTree, IdScratch};
use crate::forest::arena_update;
use crate::forest::delete::DeleteReport;
use crate::forest::lazy::{DirtySet, LazySink};
use crate::forest::node::{Node, NodeMemory, TreeShape};
use crate::forest::params::Params;
use crate::forest::forest::owned_live_ids;
use crate::forest::train::{TrainCtx, ROOT_PATH};
use crate::forest::workspace::{train_subtree, train_tree};

/// One DaRE tree plus its seed and update counter.
#[derive(Clone, Debug)]
pub struct DareTree {
    /// Arena node store (hot SoA plane + cold stats plane).
    pub arena: ArenaTree,
    pub tree_seed: u64,
    /// Number of structural updates applied (deletions + additions); feeds
    /// the per-update resampling RNG (Lemma A.1 streams).
    pub epoch: u64,
    /// Deferred subtree retrains (empty under `LazyPolicy::Eager`;
    /// DESIGN.md §9).
    pub dirty: DirtySet,
}

impl DareTree {
    /// Train on the live instances of `data` (paper Alg. 1), via the
    /// sort-free workspace (bit-exact with the plain path; DESIGN.md §6),
    /// then graft the result into a fresh BFS-compact arena.
    ///
    /// Under Occ(q) subsampling (`params.q < 1.0`; DESIGN.md §13) the tree
    /// trains on exactly its *owned* live ids — the stateless per-tree
    /// ownership predicate keyed by `tree_seed`. At q = 1.0 the owned set
    /// is the live set and this is byte-identical to the pre-Occ(q) path
    /// (same `train_tree` call, no ownership draws).
    pub fn fit(data: &Dataset, params: &Params, tree_seed: u64) -> Self {
        let root = if params.subsampled() {
            let ctx = TrainCtx {
                data,
                params,
                tree_seed,
            };
            train_subtree(&ctx, owned_live_ids(data, tree_seed, params.q), 0, ROOT_PATH)
        } else {
            train_tree(data, params, tree_seed)
        };
        DareTree {
            arena: ArenaTree::from_node(root),
            tree_seed,
            epoch: 0,
            dirty: DirtySet::default(),
        }
    }

    /// Wrap an already-built boxed tree (deserialization, oracles).
    pub fn from_root(root: Node, tree_seed: u64, epoch: u64) -> Self {
        DareTree {
            arena: ArenaTree::from_node(root),
            tree_seed,
            epoch,
            dirty: DirtySet::default(),
        }
    }

    /// Reconstruct the boxed view of the tree (oracle comparisons,
    /// serialization). O(nodes); not for hot paths.
    pub fn root_node(&self) -> Node {
        self.arena.to_node()
    }

    /// |D| at the root.
    #[inline]
    pub fn n(&self) -> u32 {
        self.arena.n_root()
    }

    /// Delete a (still-live) instance (paper Alg. 2), retraining eagerly.
    /// The tree must be fully flushed (`dirty` empty) — the forest-level
    /// policy routing guarantees this.
    pub fn delete(&mut self, data: &Dataset, params: &Params, id: InstanceId) -> DeleteReport {
        debug_assert!(self.dirty.is_empty(), "eager delete on a dirty tree");
        let ctx = TrainCtx {
            data,
            params,
            tree_seed: self.tree_seed,
        };
        let mut report = DeleteReport::default();
        arena_update::delete(&mut self.arena, &ctx, id, self.epoch, &mut report);
        self.epoch += 1;
        report
    }

    /// Lazy delete (DESIGN.md §9): the mark half. Statistics update exactly
    /// as [`DareTree::delete`] would (same epoch, same Lemma-A.1 RNG
    /// streams), but subtree retrains are deferred into `self.dirty`; the
    /// walk flushes any pending region it must pass through or gather, so
    /// the returned report is identical to the eager one.
    pub fn mark_delete(
        &mut self,
        data: &Dataset,
        params: &Params,
        id: InstanceId,
    ) -> DeleteReport {
        let ctx = TrainCtx {
            data,
            params,
            tree_seed: self.tree_seed,
        };
        let mut report = DeleteReport::default();
        let mut sink = LazySink {
            dirty: &mut self.dirty,
        };
        arena_update::delete_with(&mut self.arena, &ctx, id, self.epoch, &mut report, &mut sink);
        self.epoch += 1;
        report
    }

    /// Add an instance already pushed into `data` (§6), retraining eagerly.
    pub fn add(&mut self, data: &Dataset, params: &Params, id: InstanceId) -> DeleteReport {
        debug_assert!(self.dirty.is_empty(), "eager add on a dirty tree");
        let ctx = TrainCtx {
            data,
            params,
            tree_seed: self.tree_seed,
        };
        let mut report = DeleteReport::default();
        arena_update::add(&mut self.arena, &ctx, id, self.epoch, &mut report);
        self.epoch += 1;
        report
    }

    /// Lazy add: the mark half of [`DareTree::add`] (see
    /// [`DareTree::mark_delete`]).
    pub fn mark_add(&mut self, data: &Dataset, params: &Params, id: InstanceId) -> DeleteReport {
        let ctx = TrainCtx {
            data,
            params,
            tree_seed: self.tree_seed,
        };
        let mut report = DeleteReport::default();
        let mut sink = LazySink {
            dirty: &mut self.dirty,
        };
        arena_update::add_with(&mut self.arena, &ctx, id, self.epoch, &mut report, &mut sink);
        self.epoch += 1;
        report
    }

    /// Dry-run retrain cost of deleting `id` (adversary signal; no
    /// mutation). On a dirty tree the descended path may contain pending
    /// subtrees — use [`DareTree::delete_cost_flushed`] there.
    pub fn delete_cost(&self, data: &Dataset, params: &Params, id: InstanceId) -> u64 {
        let ctx = TrainCtx {
            data,
            params,
            tree_seed: self.tree_seed,
        };
        arena_update::delete_cost(&self.arena, &ctx, id)
    }

    /// As-if-flushed deletion cost: materialize the pending subtrees on
    /// `id`'s path, then run the dry-run — bit-identical to the eager
    /// tree's `delete_cost` at this moment.
    pub fn delete_cost_flushed(
        &mut self,
        data: &Dataset,
        params: &Params,
        id: InstanceId,
    ) -> u64 {
        let ctx = TrainCtx {
            data,
            params,
            tree_seed: self.tree_seed,
        };
        self.dirty.flush_for_instance(&mut self.arena, &ctx, id);
        arena_update::delete_cost(&self.arena, &ctx, id)
    }

    /// Flush the pending subtrees a descent of `row` passes through, so a
    /// following [`DareTree::predict`] serves the eager-exact value.
    pub fn flush_for_row(&mut self, data: &Dataset, params: &Params, row: &[f32]) {
        let ctx = TrainCtx {
            data,
            params,
            tree_seed: self.tree_seed,
        };
        self.dirty.flush_for_row(&mut self.arena, &ctx, row);
    }

    /// Execute up to `k` deferred retrains; returns how many ran.
    pub fn flush_budget(&mut self, data: &Dataset, params: &Params, k: usize) -> usize {
        let ctx = TrainCtx {
            data,
            params,
            tree_seed: self.tree_seed,
        };
        self.dirty.flush_budget(&mut self.arena, &ctx, k)
    }

    /// Execute every deferred retrain; afterwards the tree is bit-identical
    /// to its eager twin (structure, bytes, predictions).
    pub fn flush_all(&mut self, data: &Dataset, params: &Params) -> usize {
        let ctx = TrainCtx {
            data,
            params,
            tree_seed: self.tree_seed,
        };
        self.dirty.flush_all(&mut self.arena, &ctx)
    }

    /// Pending deferred retrains.
    #[inline]
    pub fn dirty_len(&self) -> usize {
        self.dirty.len()
    }

    /// Cumulative retrains deferred / executed (telemetry).
    #[inline]
    pub fn deferred_retrains(&self) -> u64 {
        self.dirty.deferred_total()
    }
    #[inline]
    pub fn flushed_retrains(&self) -> u64 {
        self.dirty.flushed_total()
    }

    /// Full consistency audit: the arena invariants plus the dirty set
    /// (every entry live, leaf-shaped, flushable).
    pub fn validate(&self) -> anyhow::Result<()> {
        self.arena.validate()?;
        self.dirty.validate(&self.arena)
    }

    /// Positive-class probability for one feature row (hot-plane descent).
    #[inline]
    pub fn predict(&self, row: &[f32]) -> f32 {
        self.arena.predict(row)
    }

    pub fn shape(&self) -> TreeShape {
        self.arena.shape()
    }

    pub fn memory(&self) -> NodeMemory {
        self.arena.memory()
    }

    /// Structural equality with another arena tree (same semantics as
    /// [`structural_eq`], computed directly on the arenas).
    pub fn structural_matches(&self, other: &DareTree) -> bool {
        self.arena.structural_matches(&other.arena)
    }

    /// Structural equality against a boxed oracle tree.
    pub fn matches_root(&self, root: &Node) -> bool {
        self.arena.matches_node(root)
    }
}

/// Structural equality of two boxed trees: same node kinds, splits, counts
/// and leaf contents (id order-insensitive). Used by the exactness tests.
/// Leaf id lists are compared through one reused pair of sorted scratch
/// buffers instead of two fresh clone+sort allocations per leaf, so grid
/// tests stop churning the allocator.
pub fn structural_eq(a: &Node, b: &Node) -> bool {
    let mut scratch = IdScratch::default();
    structural_eq_rec(a, b, &mut scratch)
}

fn structural_eq_rec(a: &Node, b: &Node, scratch: &mut IdScratch) -> bool {
    match (a, b) {
        (Node::Leaf(x), Node::Leaf(y)) => {
            x.n == y.n && x.n_pos == y.n_pos && scratch.ids_eq(&x.ids, &y.ids)
        }
        (Node::Random(x), Node::Random(y)) => {
            x.attr == y.attr
                && x.v == y.v
                && x.n == y.n
                && x.n_pos == y.n_pos
                && structural_eq_rec(&x.left, &y.left, scratch)
                && structural_eq_rec(&x.right, &y.right, scratch)
        }
        (Node::Greedy(x), Node::Greedy(y)) => {
            x.split_attr() == y.split_attr()
                && x.split_v() == y.split_v()
                && x.n == y.n
                && x.n_pos == y.n_pos
                && structural_eq_rec(&x.left, &y.left, scratch)
                && structural_eq_rec(&x.right, &y.right, scratch)
        }
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};
    use crate::forest::train::{train, ROOT_PATH};

    fn data(n: usize) -> Dataset {
        generate(
            &SynthSpec {
                n,
                informative: 3,
                redundant: 1,
                noise: 2,
                flip: 0.05,
                ..Default::default()
            },
            17,
        )
    }

    #[test]
    fn fit_predict_delete_cycle() {
        let mut d = data(300);
        let params = Params {
            max_depth: 8,
            k: 5,
            ..Default::default()
        };
        let mut tree = DareTree::fit(&d, &params, 1);
        assert_eq!(tree.n() as usize, 300);
        let p0 = tree.predict(&d.row(0));
        assert!((0.0..=1.0).contains(&p0));

        let report = tree.delete(&d, &params, 0);
        d.mark_removed(0);
        assert_eq!(tree.n() as usize, 299);
        assert_eq!(tree.epoch, 1);
        tree.arena.validate().unwrap();
        let _ = report.cost();
    }

    #[test]
    fn structural_eq_detects_difference() {
        let d = data(150);
        let params = Params {
            max_depth: 5,
            k: 5,
            ..Default::default()
        };
        let t1 = DareTree::fit(&d, &params, 1);
        let t2 = DareTree::fit(&d, &params, 1);
        let t3 = DareTree::fit(&d, &params, 2);
        assert!(t1.structural_matches(&t2));
        assert!(!t1.structural_matches(&t3));
        // boxed-view comparisons agree
        assert!(structural_eq(&t1.root_node(), &t2.root_node()));
        assert!(!structural_eq(&t1.root_node(), &t3.root_node()));
    }

    #[test]
    fn arena_tree_matches_boxed_builder() {
        // DareTree::fit must produce the same structure as the seed boxed
        // trainer — the tentpole bit-exactness invariant at tree level.
        let d = data(400);
        let params = Params {
            max_depth: 7,
            k: 5,
            d_rmax: 2,
            ..Default::default()
        };
        for seed in [1u64, 2, 3] {
            let tree = DareTree::fit(&d, &params, seed);
            let ctx = TrainCtx {
                data: &d,
                params: &params,
                tree_seed: seed,
            };
            let oracle = train(&ctx, d.live_ids(), 0, ROOT_PATH);
            assert!(tree.matches_root(&oracle), "arena != boxed (seed {seed})");
            // predictions agree bit-for-bit
            for id in d.live_ids().into_iter().take(60) {
                let row = d.row(id);
                assert_eq!(tree.predict(&row), oracle.predict(&row));
            }
        }
    }

    #[test]
    fn shape_and_memory_exposed() {
        let d = data(200);
        let params = Params {
            max_depth: 6,
            k: 5,
            d_rmax: 2,
            ..Default::default()
        };
        let tree = DareTree::fit(&d, &params, 3);
        let s = tree.shape();
        assert!(s.leaves > 0);
        assert!(s.random_nodes > 0);
        let m = tree.memory();
        assert!(m.total() > 0);
    }
}
