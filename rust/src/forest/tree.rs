//! A single DaRE tree: the unit of training, deletion and prediction.

use crate::data::dataset::{Dataset, InstanceId};
use crate::forest::delete::{add, delete, delete_cost, DeleteReport};
use crate::forest::node::{Node, NodeMemory, TreeShape};
use crate::forest::params::Params;
use crate::forest::train::{TrainCtx, ROOT_PATH};
use crate::forest::workspace::train_tree;

/// One DaRE tree plus its seed and update counter.
#[derive(Clone, Debug)]
pub struct DareTree {
    pub root: Node,
    pub tree_seed: u64,
    /// Number of structural updates applied (deletions + additions); feeds
    /// the per-update resampling RNG (Lemma A.1 streams).
    pub epoch: u64,
}

impl DareTree {
    /// Train on the live instances of `data` (paper Alg. 1), via the
    /// sort-free workspace (bit-exact with the plain path; DESIGN.md §6).
    pub fn fit(data: &Dataset, params: &Params, tree_seed: u64) -> Self {
        DareTree {
            root: train_tree(data, params, tree_seed),
            tree_seed,
            epoch: 0,
        }
    }

    /// Delete a (still-live) instance (paper Alg. 2).
    pub fn delete(&mut self, data: &Dataset, params: &Params, id: InstanceId) -> DeleteReport {
        let ctx = TrainCtx {
            data,
            params,
            tree_seed: self.tree_seed,
        };
        let mut report = DeleteReport::default();
        delete(&ctx, &mut self.root, id, 0, ROOT_PATH, self.epoch, &mut report);
        self.epoch += 1;
        report
    }

    /// Add an instance already pushed into `data` (§6).
    pub fn add(&mut self, data: &Dataset, params: &Params, id: InstanceId) -> DeleteReport {
        let ctx = TrainCtx {
            data,
            params,
            tree_seed: self.tree_seed,
        };
        let mut report = DeleteReport::default();
        add(&ctx, &mut self.root, id, 0, ROOT_PATH, self.epoch, &mut report);
        self.epoch += 1;
        report
    }

    /// Dry-run retrain cost of deleting `id` (adversary signal; no mutation).
    pub fn delete_cost(&self, data: &Dataset, params: &Params, id: InstanceId) -> u64 {
        let ctx = TrainCtx {
            data,
            params,
            tree_seed: self.tree_seed,
        };
        delete_cost(&ctx, &self.root, id, 0)
    }

    /// Positive-class probability for one feature row.
    #[inline]
    pub fn predict(&self, row: &[f32]) -> f32 {
        self.root.predict(row)
    }

    pub fn shape(&self) -> TreeShape {
        self.root.shape()
    }

    pub fn memory(&self) -> NodeMemory {
        self.root.memory()
    }
}

/// Structural equality of two trees: same node kinds, splits, counts and
/// leaf contents (id order-insensitive). Used by the exactness tests.
pub fn structural_eq(a: &Node, b: &Node) -> bool {
    match (a, b) {
        (Node::Leaf(x), Node::Leaf(y)) => {
            if x.n != y.n || x.n_pos != y.n_pos {
                return false;
            }
            let mut xi = x.ids.clone();
            let mut yi = y.ids.clone();
            xi.sort_unstable();
            yi.sort_unstable();
            xi == yi
        }
        (Node::Random(x), Node::Random(y)) => {
            x.attr == y.attr
                && x.v == y.v
                && x.n == y.n
                && x.n_pos == y.n_pos
                && structural_eq(&x.left, &y.left)
                && structural_eq(&x.right, &y.right)
        }
        (Node::Greedy(x), Node::Greedy(y)) => {
            x.split_attr() == y.split_attr()
                && x.split_v() == y.split_v()
                && x.n == y.n
                && x.n_pos == y.n_pos
                && structural_eq(&x.left, &y.left)
                && structural_eq(&x.right, &y.right)
        }
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};

    fn data(n: usize) -> Dataset {
        generate(
            &SynthSpec {
                n,
                informative: 3,
                redundant: 1,
                noise: 2,
                flip: 0.05,
                ..Default::default()
            },
            17,
        )
    }

    #[test]
    fn fit_predict_delete_cycle() {
        let mut d = data(300);
        let params = Params {
            max_depth: 8,
            k: 5,
            ..Default::default()
        };
        let mut tree = DareTree::fit(&d, &params, 1);
        assert_eq!(tree.root.n() as usize, 300);
        let p0 = tree.predict(&d.row(0));
        assert!((0.0..=1.0).contains(&p0));

        let report = tree.delete(&d, &params, 0);
        d.mark_removed(0);
        assert_eq!(tree.root.n() as usize, 299);
        assert_eq!(tree.epoch, 1);
        let _ = report.cost();
    }

    #[test]
    fn structural_eq_detects_difference() {
        let d = data(150);
        let params = Params {
            max_depth: 5,
            k: 5,
            ..Default::default()
        };
        let t1 = DareTree::fit(&d, &params, 1);
        let t2 = DareTree::fit(&d, &params, 1);
        let t3 = DareTree::fit(&d, &params, 2);
        assert!(structural_eq(&t1.root, &t2.root));
        assert!(!structural_eq(&t1.root, &t3.root));
    }

    #[test]
    fn shape_and_memory_exposed() {
        let d = data(200);
        let params = Params {
            max_depth: 6,
            k: 5,
            d_rmax: 2,
            ..Default::default()
        };
        let tree = DareTree::fit(&d, &params, 3);
        let s = tree.shape();
        assert!(s.leaves > 0);
        assert!(s.random_nodes > 0);
        let m = tree.memory();
        assert!(m.total() > 0);
    }
}
