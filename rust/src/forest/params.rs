//! DaRE forest hyperparameters (paper §3–4).

use crate::data::registry::PaperParams;

/// Split criterion (paper Eq. 2 / Eq. 3; Appendix C.1 evaluates both).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SplitCriterion {
    Gini,
    Entropy,
}

impl std::str::FromStr for SplitCriterion {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "gini" => Ok(SplitCriterion::Gini),
            "entropy" => Ok(SplitCriterion::Entropy),
            _ => Err(format!("unknown criterion '{s}' (gini|entropy)")),
        }
    }
}

/// How many attributes each decision node considers (p̃).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MaxFeatures {
    /// ⌊√p⌋ — the paper's choice.
    Sqrt,
    /// All p attributes (degenerates to a single deterministic tree family).
    All,
    /// Fixed count.
    Fixed(usize),
}

impl MaxFeatures {
    pub fn resolve(&self, p: usize) -> usize {
        match self {
            MaxFeatures::Sqrt => ((p as f64).sqrt().floor() as usize).max(1),
            MaxFeatures::All => p.max(1),
            MaxFeatures::Fixed(m) => (*m).clamp(1, p.max(1)),
        }
    }
}

/// Hyperparameters for a DaRE forest.
#[derive(Clone, Debug)]
pub struct Params {
    /// Number of trees (T).
    pub n_trees: usize,
    /// Maximum tree depth (d_max).
    pub max_depth: usize,
    /// Thresholds considered per attribute at greedy nodes (k).
    pub k: usize,
    /// Layers of random nodes at the top of each tree (d_rmax);
    /// 0 ⇒ G-DaRE, >0 ⇒ R-DaRE.
    pub d_rmax: usize,
    /// Split criterion for greedy nodes.
    pub criterion: SplitCriterion,
    /// Attributes sampled per decision node (p̃).
    pub max_features: MaxFeatures,
    /// Minimum instances required to attempt a split (2 in the paper:
    /// training stops on pure nodes or max depth).
    pub min_samples_split: usize,
    /// Worker threads for per-tree parallelism (1 ⇒ sequential, matching the
    /// paper's single-threaded timing protocol).
    pub n_threads: usize,
    /// Occ(q) subsample fraction (DynFrs, arXiv 2410.01588; DESIGN.md §13):
    /// each tree *owns* every instance independently with probability `q`,
    /// trains on exactly its owned ids, and skips mutations of instances it
    /// does not own. `1.0` (the default) is full ownership — every code
    /// path, RNG stream and serialized byte is identical to the pre-Occ(q)
    /// forest. Must be in (0, 1].
    pub q: f64,
}

impl Default for Params {
    fn default() -> Self {
        Params {
            n_trees: 100,
            max_depth: 10,
            k: 25,
            d_rmax: 0,
            criterion: SplitCriterion::Gini,
            max_features: MaxFeatures::Sqrt,
            min_samples_split: 2,
            n_threads: 1,
            q: 1.0,
        }
    }
}

impl Params {
    /// Instantiate from a paper Table-6/8 row with an explicit d_rmax.
    pub fn from_paper(pp: &PaperParams, d_rmax: usize) -> Self {
        Params {
            n_trees: pp.n_trees,
            max_depth: pp.max_depth,
            k: pp.k,
            d_rmax,
            ..Default::default()
        }
    }

    /// G-DaRE variant (d_rmax = 0).
    pub fn gdare(pp: &PaperParams) -> Self {
        Self::from_paper(pp, 0)
    }

    /// R-DaRE at one of the paper's four error tolerances
    /// (0 → 0.1%, 1 → 0.25%, 2 → 0.5%, 3 → 1.0%).
    pub fn rdare(pp: &PaperParams, tol_idx: usize) -> Self {
        Self::from_paper(pp, pp.drmax[tol_idx.min(3)])
    }

    pub fn with_threads(mut self, t: usize) -> Self {
        self.n_threads = t.max(1);
        self
    }

    pub fn with_criterion(mut self, c: SplitCriterion) -> Self {
        self.criterion = c;
        self
    }

    /// Occ(q) subsampling: own each instance with probability `q`.
    pub fn with_subsample(mut self, q: f64) -> Self {
        self.q = q;
        self
    }

    /// Whether per-tree ownership is a strict subset of the corpus (the
    /// ownership predicate short-circuits to `true` when this is false).
    #[inline]
    pub fn subsampled(&self) -> bool {
        self.q < 1.0
    }

    /// Sanity-check invariants; call before fitting.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.n_trees >= 1, "n_trees must be >= 1");
        anyhow::ensure!(self.max_depth >= 1, "max_depth must be >= 1");
        anyhow::ensure!(self.k >= 1, "k must be >= 1");
        anyhow::ensure!(
            self.d_rmax <= self.max_depth,
            "d_rmax ({}) cannot exceed max_depth ({})",
            self.d_rmax,
            self.max_depth
        );
        anyhow::ensure!(self.min_samples_split >= 2, "min_samples_split must be >= 2");
        anyhow::ensure!(
            self.q > 0.0 && self.q <= 1.0 && self.q.is_finite(),
            "subsample fraction q ({}) must be in (0, 1]",
            self.q
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn max_features_resolution() {
        assert_eq!(MaxFeatures::Sqrt.resolve(100), 10);
        assert_eq!(MaxFeatures::Sqrt.resolve(90), 9); // ⌊√90⌋
        assert_eq!(MaxFeatures::Sqrt.resolve(0), 1);
        assert_eq!(MaxFeatures::All.resolve(7), 7);
        assert_eq!(MaxFeatures::Fixed(3).resolve(2), 2);
        assert_eq!(MaxFeatures::Fixed(0).resolve(5), 1);
    }

    #[test]
    fn paper_param_construction() {
        let pp = crate::data::registry::find("bank_marketing").unwrap().gini;
        let g = Params::gdare(&pp);
        assert_eq!(g.d_rmax, 0);
        assert_eq!(g.n_trees, 100);
        let r = Params::rdare(&pp, 1); // tol=0.25% → d_rmax=9
        assert_eq!(r.d_rmax, 9);
    }

    #[test]
    fn validation() {
        assert!(Params::default().validate().is_ok());
        let bad = Params {
            d_rmax: 11,
            max_depth: 10,
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        let bad2 = Params {
            k: 0,
            ..Default::default()
        };
        assert!(bad2.validate().is_err());
        for q in [0.0, -0.1, 1.5, f64::NAN] {
            let bad_q = Params {
                q,
                ..Default::default()
            };
            assert!(bad_q.validate().is_err(), "q={q} must be rejected");
        }
        assert!(Params::default().with_subsample(0.3).validate().is_ok());
        assert!(!Params::default().subsampled());
        assert!(Params::default().with_subsample(0.3).subsampled());
    }

    #[test]
    fn criterion_parse() {
        assert_eq!("gini".parse::<SplitCriterion>().unwrap(), SplitCriterion::Gini);
        assert_eq!(
            "Entropy".parse::<SplitCriterion>().unwrap(),
            SplitCriterion::Entropy
        );
        assert!("x".parse::<SplitCriterion>().is_err());
    }
}
