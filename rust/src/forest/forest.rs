//! The DaRE forest: an ensemble of independently trained DaRE trees over a
//! shared (liveness-masked) dataset. No bootstrapping (§2.2): every tree sees
//! the same instances but samples its own attributes/thresholds.

use crate::data::dataset::{Dataset, InstanceId};
use crate::forest::delete::DeleteReport;
use crate::forest::lazy::LazyPolicy;
use crate::forest::node::NodeMemory;
use crate::forest::params::Params;
use crate::forest::tree::DareTree;
use crate::util::rng::mix_seed;
use crate::util::threadpool::{scope_map, scope_map_mut};

/// Row count at or above which [`DareForest::predict_proba_rows`] switches
/// from the per-row loop to level-synchronous blocks (and, with
/// `params.n_threads > 1`, fans blocks out over the threadpool). Below it
/// the per-row path is used unchanged — single-row latency is unaffected.
pub const PREDICT_BATCH_CUTOFF: usize = 32;

/// Upper bound on rows per block in the batched prediction path; one block
/// is one threadpool job and one cursor-array working set (~1 KB of
/// cursors). With multiple threads the block size shrinks (never below
/// [`PREDICT_BATCH_CUTOFF`]) so large batches split across the pool — see
/// [`DareForest::predict_block_rows`].
pub const PREDICT_BLOCK: usize = 256;

/// Ensemble of DaRE trees plus the training database they index into.
#[derive(Clone, Debug)]
pub struct DareForest {
    params: Params,
    seed: u64,
    trees: Vec<DareTree>,
    data: Dataset,
    /// When deferred retrains run (DESIGN.md §9). Runtime serving policy,
    /// not a model hyperparameter: never serialized, `Eager` by default.
    lazy: LazyPolicy,
}

/// Aggregate report for one forest-level deletion (all trees).
#[derive(Clone, Debug, Default)]
pub struct ForestDeleteReport {
    pub per_tree: Vec<DeleteReport>,
}

impl ForestDeleteReport {
    /// Total instances across retrained nodes, summed over trees — the
    /// paper's worst-of-1000 cost measure.
    pub fn cost(&self) -> u64 {
        self.per_tree.iter().map(|r| r.cost()).sum()
    }
    pub fn retrain_events(&self) -> usize {
        self.per_tree.iter().map(|r| r.retrain_events.len()).sum()
    }
    /// Histogram of retrained instances by node depth (Fig. 2 right).
    pub fn cost_by_depth(&self, max_depth: usize) -> Vec<u64> {
        let mut h = vec![0u64; max_depth + 1];
        for r in &self.per_tree {
            for e in &r.retrain_events {
                h[e.depth.min(max_depth)] += e.n as u64;
            }
        }
        h
    }
}

/// Seed of tree `t` in a forest seeded with `forest_seed`. Public so the
/// exactness harnesses (the boxed-oracle leg of `tests/op_fuzz.rs`) derive
/// the identical per-tree streams instead of copying the constant.
pub fn tree_seed(forest_seed: u64, t: usize) -> u64 {
    mix_seed(&[forest_seed, t as u64, 0x7EEE])
}

/// Salt of the per-tree *ownership* stream (Occ(q) subsampling, DESIGN.md
/// §13). Distinct from every split/resample stream salt, so ownership draws
/// never perturb the training or Lemma-A.1 RNG sequences.
const OWNERSHIP_SALT: u64 = 0x0CC5;

/// Does the tree seeded `tree_seed` own instance `id` at subsample fraction
/// `q` (paper-external: DynFrs Occ(q))? One draw from a dedicated
/// counter-based stream keyed `(tree_seed, id, OWNERSHIP_SALT)` — a pure
/// function of the tree seed and the instance id, so ownership needs no
/// stored state: save/load, WAL replay and log-shipped followers all
/// recompute the identical sets (DESIGN.md §13). `q >= 1.0` short-circuits
/// without hashing — full ownership, the pre-Occ(q) behavior, bit for bit.
#[inline]
pub fn owns(tree_seed: u64, id: InstanceId, q: f64) -> bool {
    if q >= 1.0 {
        return true;
    }
    // Saturating f64→u64 cast: deterministic on every platform, and the
    // comparison is strict-less-than so q→0⁺ owns (almost) nothing.
    let threshold = (q * (u64::MAX as f64)) as u64;
    mix_seed(&[tree_seed, id as u64, OWNERSHIP_SALT]) < threshold
}

/// The live instances owned by the tree seeded `tree_seed` — ascending id
/// order, exactly the id set `DareTree::fit` trains on at fraction `q`.
pub fn owned_live_ids(data: &Dataset, tree_seed: u64, q: f64) -> Vec<InstanceId> {
    let mut ids = data.live_ids();
    if q < 1.0 {
        ids.retain(|&id| owns(tree_seed, id, q));
    }
    ids
}

/// Contiguous, near-even partition of `0..n_trees` into at most `n_shards`
/// non-empty ranges (sizes differ by ≤ 1). Shard `s` owning a contiguous,
/// ascending tree range is what lets the sharded coordinator reduce
/// per-shard prediction partials in exact global tree order (DESIGN.md §8).
pub fn shard_ranges(n_trees: usize, n_shards: usize) -> Vec<std::ops::Range<usize>> {
    let s = n_shards.max(1).min(n_trees.max(1));
    let base = n_trees / s;
    let extra = n_trees % s;
    let mut out = Vec::with_capacity(s);
    let mut start = 0usize;
    for i in 0..s {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Validate and dedupe a deletion batch against `data`'s liveness mask:
/// returns the accepted ids (first occurrence of each live, in-range id, in
/// request order) and the skipped count. Shared by
/// [`DareForest::delete_batch`] and the sharded coordinator store so the
/// two paths can never diverge on accepted/skipped sets.
pub fn accept_deletions(data: &Dataset, ids: &[InstanceId]) -> (Vec<InstanceId>, usize) {
    let mut seen = std::collections::BTreeSet::new();
    let mut accepted: Vec<InstanceId> = Vec::with_capacity(ids.len());
    let mut skipped = 0usize;
    for &id in ids {
        if !seen.insert(id) || (id as usize) >= data.n_total() || !data.is_alive(id) {
            skipped += 1;
        } else {
            accepted.push(id);
        }
    }
    (accepted, skipped)
}

impl DareForest {
    /// Train a forest on (a copy of) `data`'s live instances.
    pub fn fit(data: Dataset, params: &Params, seed: u64) -> Self {
        params.validate().expect("invalid params");
        let tree_seeds: Vec<u64> = (0..params.n_trees)
            .map(|t| tree_seed(seed, t))
            .collect();
        let trees = scope_map(&tree_seeds, params.n_threads, |_, &ts| {
            DareTree::fit(&data, params, ts)
        });
        DareForest {
            params: params.clone(),
            seed,
            trees,
            data,
            lazy: LazyPolicy::Eager,
        }
    }

    /// Reassemble a forest from snapshot parts (see `forest::serialize`).
    pub fn from_parts(
        params: Params,
        seed: u64,
        trees: Vec<DareTree>,
        data: Dataset,
    ) -> anyhow::Result<Self> {
        params.validate()?;
        anyhow::ensure!(!trees.is_empty(), "snapshot has no trees");
        if params.subsampled() {
            // Occ(q): every tree must hold exactly the live instances the
            // ownership predicate assigns it — the id sets are re-derivable
            // from (tree_seed, q), so a snapshot whose leaves disagree is
            // corrupt (or was written under a different q) and is rejected
            // up front rather than diverging on the first mutation.
            let live = data.live_ids();
            let mut ids = Vec::with_capacity(live.len());
            for (i, t) in trees.iter().enumerate() {
                let expect: Vec<InstanceId> = live
                    .iter()
                    .copied()
                    .filter(|&id| owns(t.tree_seed, id, params.q))
                    .collect();
                anyhow::ensure!(
                    t.n() as usize == expect.len(),
                    "tree {i}: size {} != owned live instances {} (q={})",
                    t.n(),
                    expect.len(),
                    params.q
                );
                ids.clear();
                t.arena.collect_ids(t.arena.root(), None, &mut ids);
                ids.sort_unstable();
                anyhow::ensure!(
                    ids == expect,
                    "tree {i}: leaf id set disagrees with the Occ(q={}) \
                     ownership predicate",
                    params.q
                );
            }
        } else {
            for t in &trees {
                anyhow::ensure!(
                    t.n() as usize == data.n_alive(),
                    "tree size {} != live instances {}",
                    t.n(),
                    data.n_alive()
                );
            }
        }
        Ok(DareForest {
            params,
            seed,
            trees,
            data,
            lazy: LazyPolicy::Eager,
        })
    }

    /// Deconstruct into `(params, seed, trees, data)` — the sharded
    /// coordinator takes ownership of the tree vector and re-homes each
    /// contiguous range with its shard (`coordinator::shards`).
    pub fn into_parts(self) -> (Params, u64, Vec<DareTree>, Dataset) {
        (self.params, self.seed, self.trees, self.data)
    }

    pub fn params(&self) -> &Params {
        &self.params
    }
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Current deferral policy (DESIGN.md §9).
    pub fn lazy_policy(&self) -> LazyPolicy {
        self.lazy
    }

    /// Switch the deferral policy. Leaving a lazy policy flushes first so
    /// the eager paths never see a dirty tree.
    pub fn set_lazy_policy(&mut self, policy: LazyPolicy) {
        if !policy.is_lazy() && self.dirty_subtrees() > 0 {
            self.flush_all();
        }
        self.lazy = policy;
    }
    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }
    pub fn trees(&self) -> &[DareTree] {
        &self.trees
    }
    pub fn data(&self) -> &Dataset {
        &self.data
    }
    pub fn n_alive(&self) -> usize {
        self.data.n_alive()
    }

    /// Ids that can currently be deleted.
    pub fn live_ids(&self) -> Vec<InstanceId> {
        self.data.live_ids()
    }

    /// Apply one tree-level mutation under the current policy: eager
    /// retrain, mark-only, or mark + bounded drain. Shared by every
    /// forest-level mutation so the policies cannot drift.
    ///
    /// Occ(q) gate: a tree that does not own `id` is skipped *entirely* —
    /// no statistics walk, no mark, no budgeted drain, no epoch bump — so
    /// its state (and Lemma-A.1 stream position) is exactly that of a
    /// single tree which never saw the op. The returned default report
    /// keeps `per_tree` at forest arity.
    fn apply_delete(
        lazy: LazyPolicy,
        t: &mut DareTree,
        data: &Dataset,
        params: &Params,
        id: InstanceId,
    ) -> DeleteReport {
        if !owns(t.tree_seed, id, params.q) {
            return DeleteReport::default();
        }
        match lazy {
            LazyPolicy::Eager => t.delete(data, params, id),
            LazyPolicy::OnRead => t.mark_delete(data, params, id),
            LazyPolicy::Budgeted(k) => {
                let r = t.mark_delete(data, params, id);
                t.flush_budget(data, params, k);
                r
            }
        }
    }

    fn apply_add(
        lazy: LazyPolicy,
        t: &mut DareTree,
        data: &Dataset,
        params: &Params,
        id: InstanceId,
    ) {
        // Occ(q): the new instance joins each tree with probability q —
        // the same stateless predicate the fit and delete paths consult.
        // Under a lazy policy an *owned* add lands in the tree's DirtySet
        // exactly like a deferred delete (mark_add); unowned trees skip
        // the op wholesale.
        if !owns(t.tree_seed, id, params.q) {
            return;
        }
        match lazy {
            LazyPolicy::Eager => {
                t.add(data, params, id);
            }
            LazyPolicy::OnRead => {
                t.mark_add(data, params, id);
            }
            LazyPolicy::Budgeted(k) => {
                t.mark_add(data, params, id);
                t.flush_budget(data, params, k);
            }
        }
    }

    /// Exactly unlearn one training instance (paper Alg. 2 across all trees,
    /// then remove it from the database). Under a lazy policy the subtree
    /// retrains are deferred (DESIGN.md §9); the reported costs are
    /// identical either way.
    pub fn delete(&mut self, id: InstanceId) -> anyhow::Result<ForestDeleteReport> {
        anyhow::ensure!(
            (id as usize) < self.data.n_total() && self.data.is_alive(id),
            "instance {id} is not a live training instance"
        );
        let data = &self.data;
        let params = &self.params;
        let lazy = self.lazy;
        let per_tree = scope_map_mut(&mut self.trees, params.n_threads, |_, t| {
            Self::apply_delete(lazy, t, data, params, id)
        });
        self.data.mark_removed(id);
        Ok(ForestDeleteReport { per_tree })
    }

    /// Sequential (no-clone) deletion used on the single-threaded hot path.
    pub fn delete_seq(&mut self, id: InstanceId) -> anyhow::Result<ForestDeleteReport> {
        anyhow::ensure!(
            (id as usize) < self.data.n_total() && self.data.is_alive(id),
            "instance {id} is not a live training instance"
        );
        let mut per_tree = Vec::with_capacity(self.trees.len());
        for t in self.trees.iter_mut() {
            per_tree.push(Self::apply_delete(self.lazy, t, &self.data, &self.params, id));
        }
        self.data.mark_removed(id);
        Ok(ForestDeleteReport { per_tree })
    }

    /// Batch deletion (§A.7): applies a set of deletions tree-by-tree, with
    /// the independently-retrained trees fanned out over the threadpool.
    /// Duplicate or dead ids are skipped and reported.
    ///
    /// Equivalent to a sequential id-by-id [`DareForest::delete_seq`] loop:
    /// tree updates never read the liveness mask (only row values, which are
    /// immutable), and each tree applies the same deletion sequence with the
    /// same per-tree epoch order, so the Lemma-A.1 RNG streams — and hence
    /// the resulting trees — are identical. The mask is updated once at the
    /// end. Returns one merged [`DeleteReport`] per tree.
    pub fn delete_batch(&mut self, ids: &[InstanceId]) -> (ForestDeleteReport, usize) {
        // Validate and dedupe up front; liveness cannot change until the
        // mark-removed pass below, so the filter sees a consistent mask.
        let (accepted, skipped) = accept_deletions(&self.data, ids);
        let data = &self.data;
        let params = &self.params;
        let lazy = self.lazy;
        let per_tree = scope_map_mut(&mut self.trees, params.n_threads, |_, t| {
            let mut merged = DeleteReport::default();
            for &id in &accepted {
                merged.merge(&Self::apply_delete(lazy, t, data, params, id));
            }
            merged
        });
        for &id in &accepted {
            self.data.mark_removed(id);
        }
        (ForestDeleteReport { per_tree }, skipped)
    }

    /// Add a fresh training instance to the database and all trees (§6).
    pub fn add(&mut self, row: &[f32], label: u8) -> InstanceId {
        let id = self.data.push_row(row, label);
        let data = &self.data;
        let params = &self.params;
        let lazy = self.lazy;
        scope_map_mut(&mut self.trees, params.n_threads, |_, t| {
            Self::apply_add(lazy, t, data, params, id);
        });
        id
    }

    /// Dry-run total retrain cost of deleting `id` across all trees — the
    /// worst-of-1000 adversary's ranking signal. Assumes fully flushed
    /// trees; under a lazy policy use [`DareForest::delete_cost_flushed`].
    pub fn delete_cost(&self, id: InstanceId) -> u64 {
        self.trees
            .iter()
            .filter(|t| owns(t.tree_seed, id, self.params.q))
            .map(|t| t.delete_cost(&self.data, &self.params, id))
            .sum()
    }

    /// As-if-flushed deletion cost: flush the pending subtrees on `id`'s
    /// path in every tree, then cost the dry run — bit-identical to the
    /// eager forest's [`DareForest::delete_cost`] at this moment.
    pub fn delete_cost_flushed(&mut self, id: InstanceId) -> u64 {
        let data = &self.data;
        let params = &self.params;
        let costs = scope_map_mut(&mut self.trees, params.n_threads, |_, t| {
            // Non-owning trees cost 0 by definition (deleting an instance
            // a tree never saw is a no-op), so nothing needs flushing.
            if !owns(t.tree_seed, id, params.q) {
                return 0;
            }
            t.delete_cost_flushed(data, params, id)
        });
        costs.into_iter().sum()
    }

    /// Serve a single-row prediction under a lazy policy: flush the pending
    /// subtrees on the row's descent path in every tree, then predict —
    /// bit-identical to the eager forest's value at this moment.
    pub fn predict_proba_flushed(&mut self, row: &[f32]) -> f32 {
        let data = &self.data;
        let params = &self.params;
        let sum: f32 = scope_map_mut(&mut self.trees, params.n_threads, |_, t| {
            t.flush_for_row(data, params, row);
            t.predict(row)
        })
        .into_iter()
        .sum();
        sum / self.trees.len() as f32
    }

    /// Batch prediction under a lazy policy: flush every row's path in
    /// every tree, then take the normal batched read path. Values are
    /// bit-identical to the eager forest's [`DareForest::predict_proba_rows`].
    pub fn predict_proba_rows_flushed(&mut self, rows: &[Vec<f32>]) -> Vec<f32> {
        if self.dirty_subtrees() > 0 {
            let data = &self.data;
            let params = &self.params;
            scope_map_mut(&mut self.trees, params.n_threads, |_, t| {
                for row in rows {
                    t.flush_for_row(data, params, row);
                }
            });
        }
        self.predict_proba_rows(rows)
    }

    /// Execute every deferred retrain in every tree. Afterwards the forest
    /// is bit-identical (structure, serialized bytes, predictions) to one
    /// that ran the same op sequence eagerly (DESIGN.md §9). Returns the
    /// number of retrains executed.
    pub fn flush_all(&mut self) -> usize {
        let data = &self.data;
        let params = &self.params;
        scope_map_mut(&mut self.trees, params.n_threads, |_, t| {
            t.flush_all(data, params)
        })
        .into_iter()
        .sum()
    }

    /// Execute up to `k` deferred retrains per tree (the compactor's unit
    /// of work); returns the total executed.
    pub fn compact(&mut self, k: usize) -> usize {
        let data = &self.data;
        let params = &self.params;
        scope_map_mut(&mut self.trees, params.n_threads, |_, t| {
            t.flush_budget(data, params, k)
        })
        .into_iter()
        .sum()
    }

    /// Currently pending deferred retrains across all trees.
    pub fn dirty_subtrees(&self) -> usize {
        self.trees.iter().map(|t| t.dirty_len()).sum()
    }

    /// Cumulative retrains deferred across all trees (telemetry).
    pub fn deferred_retrains(&self) -> u64 {
        self.trees.iter().map(|t| t.deferred_retrains()).sum()
    }

    /// Cumulative deferred retrains executed across all trees (telemetry).
    pub fn flushed_retrains(&self) -> u64 {
        self.trees.iter().map(|t| t.flushed_retrains()).sum()
    }

    /// Positive-class probability for one feature row (mean over trees).
    ///
    /// Contract under a lazy policy: `&self` cannot flush, so on a forest
    /// with pending deferred retrains this descends into stale pending
    /// leaves — use [`DareForest::predict_proba_flushed`] to serve
    /// eager-exact values (the sharded coordinator does this routing
    /// automatically; only direct library users must pick the right
    /// entry point).
    pub fn predict_proba(&self, row: &[f32]) -> f32 {
        let s: f32 = self.trees.iter().map(|t| t.predict(row)).sum();
        s / self.trees.len() as f32
    }

    /// Batch prediction over row-major features. Same lazy-policy contract
    /// as [`DareForest::predict_proba`]: on a dirty forest, use
    /// [`DareForest::predict_proba_rows_flushed`].
    ///
    /// Small batches take the plain per-row path. At
    /// [`PREDICT_BATCH_CUTOFF`] rows and above, the batch is cut into
    /// [`PREDICT_BLOCK`]-row blocks; each block walks every tree with the
    /// level-synchronous arena descent (the tree's upper hot-plane levels
    /// stay cached across the block), and blocks fan out over the
    /// threadpool when `params.n_threads > 1`. Per-row accumulation order
    /// is identical to `predict_proba`, so results are bit-equal on every
    /// path.
    pub fn predict_proba_rows(&self, rows: &[Vec<f32>]) -> Vec<f32> {
        if rows.len() < PREDICT_BATCH_CUTOFF {
            return rows.iter().map(|r| self.predict_proba(r)).collect();
        }
        self.predict_chunked(rows, |block| self.predict_block(block))
    }

    /// Block size for an `n`-row batch: capped at [`PREDICT_BLOCK`], and
    /// with multiple threads shrunk toward `n / n_threads` (but never below
    /// [`PREDICT_BATCH_CUTOFF`], so tiny blocks don't drown the win in
    /// dispatch overhead) — without this a 256-row batch would be a single
    /// block and never fan out. Small multi-thread batches may therefore
    /// still yield fewer blocks than threads. Blocking never changes
    /// results: per-row sums are independent.
    fn predict_block_rows(&self, n: usize) -> usize {
        let threads = self.params.n_threads.max(1);
        if threads == 1 {
            return PREDICT_BLOCK;
        }
        let per_thread = (n + threads - 1) / threads;
        per_thread.clamp(PREDICT_BATCH_CUTOFF, PREDICT_BLOCK)
    }

    /// Shared batched fan-out: cut `items` into [`Self::predict_block_rows`]
    /// chunks, run `per_chunk` on each over the threadpool, and concatenate
    /// in order. Both batch entry points route here so they can never
    /// diverge on blocking policy.
    fn predict_chunked<T, F>(&self, items: &[T], per_chunk: F) -> Vec<f32>
    where
        T: Sync,
        F: Fn(&[T]) -> Vec<f32> + Sync,
    {
        let chunks: Vec<&[T]> = items.chunks(self.predict_block_rows(items.len())).collect();
        let per_block = scope_map(&chunks, self.params.n_threads, |_, chunk| per_chunk(chunk));
        let mut out = Vec::with_capacity(items.len());
        for b in per_block {
            out.extend(b);
        }
        out
    }

    /// One batched block: route all rows through each tree together, then
    /// normalize by the tree count (same division as `predict_proba`).
    fn predict_block(&self, block: &[Vec<f32>]) -> Vec<f32> {
        let mut sums = vec![0.0f32; block.len()];
        let mut cursors: Vec<u32> = Vec::with_capacity(block.len());
        for t in &self.trees {
            t.arena.predict_block_sum(block, &mut cursors, &mut sums);
        }
        let nt = self.trees.len() as f32;
        for s in sums.iter_mut() {
            *s /= nt;
        }
        sums
    }

    /// Predict every live instance of an external dataset. Takes the
    /// batched path block-by-block: each threadpool job materializes only
    /// its own block of rows, so peak extra memory is O(block · p) instead
    /// of O(n_alive · p).
    pub fn predict_proba_dataset(&self, data: &Dataset) -> Vec<f32> {
        let ids = data.live_ids();
        if ids.len() < PREDICT_BATCH_CUTOFF {
            return ids.iter().map(|&i| self.predict_proba(&data.row(i))).collect();
        }
        self.predict_chunked(&ids, |chunk| {
            let rows: Vec<Vec<f32>> = chunk.iter().map(|&i| data.row(i)).collect();
            self.predict_block(&rows)
        })
    }

    /// Memory breakdown across all trees (paper Table 3).
    pub fn memory(&self) -> NodeMemory {
        let mut m = NodeMemory::default();
        for t in &self.trees {
            m.add(&t.memory());
        }
        m
    }

    /// Bytes of the training database (Table 3 "Data" column).
    pub fn data_bytes(&self) -> usize {
        self.data.memory_bytes()
    }

    /// Mean decision nodes per tree (paper §4.4 discussion).
    pub fn mean_decision_nodes(&self) -> f64 {
        let total: usize = self.trees.iter().map(|t| t.shape().decision_nodes()).sum();
        total as f64 / self.trees.len() as f64
    }

    /// Per-tree owned-live-instance counts (Occ(q) telemetry; all equal to
    /// `n_alive` at q = 1.0). One pass over the live set per tree.
    pub fn ownership_counts(&self) -> Vec<usize> {
        if !self.params.subsampled() {
            return vec![self.data.n_alive(); self.trees.len()];
        }
        let live = self.data.live_ids();
        self.trees
            .iter()
            .map(|t| live.iter().filter(|&&id| owns(t.tree_seed, id, self.params.q)).count())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};
    use crate::metrics::accuracy;

    fn data(n: usize, seed: u64) -> Dataset {
        generate(
            &SynthSpec {
                n,
                informative: 4,
                redundant: 2,
                noise: 4,
                flip: 0.05,
                ..Default::default()
            },
            seed,
        )
    }

    fn small_params(n_trees: usize) -> Params {
        Params {
            n_trees,
            max_depth: 6,
            k: 5,
            ..Default::default()
        }
    }

    #[test]
    fn fit_and_predict_better_than_chance() {
        let all = data(900, 1);
        let (train, test) = crate::data::split::train_test(&all, 0.67, 0);
        let f = DareForest::fit(train, &small_params(10), 7);
        let probs = f.predict_proba_dataset(&test);
        let (_, ys, _) = test.to_row_major();
        let acc = accuracy(&probs, &ys);
        assert!(acc > 0.75, "test acc {acc}");
    }

    #[test]
    fn delete_keeps_forest_consistent() {
        let train = data(300, 3);
        let mut f = DareForest::fit(train, &small_params(5), 9);
        let ids = f.live_ids();
        for &id in ids.iter().take(50) {
            let r = f.delete(id).unwrap();
            assert_eq!(r.per_tree.len(), 5);
        }
        assert_eq!(f.n_alive(), 250);
        for t in f.trees() {
            assert_eq!(t.n() as usize, 250);
            t.arena.validate().unwrap();
        }
        // double-delete errors
        assert!(f.delete(ids[0]).is_err());
        // out-of-range errors
        assert!(f.delete(10_000_000).is_err());
    }

    #[test]
    fn delete_seq_matches_parallel_delete() {
        let train = data(200, 4);
        let mut f1 = DareForest::fit(train.clone(), &small_params(4), 11);
        let mut f2 = DareForest::fit(train, &small_params(4), 11);
        for id in [3u32, 77, 150, 42] {
            f1.delete(id).unwrap();
            f2.delete_seq(id).unwrap();
        }
        for (a, b) in f1.trees().iter().zip(f2.trees()) {
            assert!(a.structural_matches(b));
        }
    }

    #[test]
    fn parallel_batch_matches_sequential_deletes() {
        let train = data(240, 10);
        let par = Params {
            n_threads: 4,
            ..small_params(4)
        };
        let mut f1 = DareForest::fit(train.clone(), &par, 19);
        let mut f2 = DareForest::fit(train, &small_params(4), 19);
        let ids = [5u32, 9, 100, 100, 57, 33, 999_999];
        let (report, skipped) = f1.delete_batch(&ids);
        assert_eq!(skipped, 2, "one duplicate + one out-of-range");
        assert_eq!(report.per_tree.len(), 4, "one merged report per tree");
        for id in [5u32, 9, 100, 57, 33] {
            f2.delete_seq(id).unwrap();
        }
        assert_eq!(f1.n_alive(), f2.n_alive());
        for (a, b) in f1.trees().iter().zip(f2.trees()) {
            assert!(a.structural_matches(b));
        }
    }

    #[test]
    fn batch_delete_skips_duplicates_and_dead() {
        let train = data(200, 5);
        let mut f = DareForest::fit(train, &small_params(3), 13);
        let (_, skipped) = f.delete_batch(&[1, 2, 2, 3, 999_999]);
        assert_eq!(skipped, 2);
        assert_eq!(f.n_alive(), 197);
    }

    #[test]
    fn add_grows_forest() {
        let train = data(150, 6);
        let p = train.n_features();
        let mut f = DareForest::fit(train, &small_params(4), 15);
        let id = f.add(&vec![0.0; p], 1);
        assert_eq!(f.n_alive(), 151);
        for t in f.trees() {
            assert_eq!(t.n(), 151);
        }
        // the added instance can be deleted again
        f.delete(id).unwrap();
        assert_eq!(f.n_alive(), 150);
    }

    #[test]
    fn parallel_fit_matches_sequential_fit() {
        let train = data(250, 7);
        let par = Params {
            n_threads: 4,
            ..small_params(6)
        };
        let seq = small_params(6);
        let f1 = DareForest::fit(train.clone(), &par, 21);
        let f2 = DareForest::fit(train, &seq, 21);
        for (a, b) in f1.trees().iter().zip(f2.trees()) {
            assert!(a.structural_matches(b));
        }
    }

    #[test]
    fn batched_prediction_is_bit_exact_with_per_row() {
        let all = data(600, 12);
        let (train, test) = crate::data::split::train_test(&all, 0.5, 1);
        // sequential batched path
        let f_seq = DareForest::fit(train.clone(), &small_params(8), 31);
        // parallel batched path (same trees: fit parallelism is structural-
        // equality tested above; predict threading must not change values)
        let par = Params {
            n_threads: 4,
            ..small_params(8)
        };
        let f_par = DareForest::fit(train, &par, 31);
        let rows: Vec<Vec<f32>> = test.live_ids().iter().map(|&i| test.row(i)).collect();
        assert!(rows.len() >= PREDICT_BATCH_CUTOFF);
        let per_row: Vec<f32> = rows.iter().map(|r| f_seq.predict_proba(r)).collect();
        let batched = f_seq.predict_proba_rows(&rows);
        let parallel = f_par.predict_proba_rows(&rows);
        assert_eq!(per_row, batched, "batched path must be bit-exact");
        assert_eq!(per_row, parallel, "parallel path must be bit-exact");
        // dataset-level entry point takes the same path
        assert_eq!(f_seq.predict_proba_dataset(&test), per_row);
        // small batches take the per-row route and agree trivially
        let small = &rows[..PREDICT_BATCH_CUTOFF - 1];
        assert_eq!(
            f_seq.predict_proba_rows(small),
            &per_row[..PREDICT_BATCH_CUTOFF - 1]
        );
    }

    #[test]
    fn batched_prediction_handles_ragged_tail_blocks() {
        // A batch that is not a multiple of PREDICT_BLOCK exercises the
        // chunked fan-out's tail handling.
        let train = data(400, 13);
        let f = DareForest::fit(train, &small_params(5), 17);
        let n = PREDICT_BLOCK + 37;
        let rows: Vec<Vec<f32>> = (0..n as u32)
            .map(|i| f.data().row(i % f.data().n_total() as u32))
            .collect();
        let got = f.predict_proba_rows(&rows);
        assert_eq!(got.len(), n);
        for (r, g) in rows.iter().zip(&got) {
            assert_eq!(*g, f.predict_proba(r));
        }
    }

    #[test]
    fn shard_ranges_partition_trees_contiguously() {
        for (n_trees, n_shards) in [(10usize, 4usize), (4, 4), (3, 8), (16, 1), (1, 1), (7, 3)] {
            let ranges = shard_ranges(n_trees, n_shards);
            assert!(ranges.len() <= n_shards && !ranges.is_empty());
            assert!(ranges.iter().all(|r| !r.is_empty()), "no empty shards");
            // contiguous ascending cover of 0..n_trees
            let mut next = 0usize;
            for r in &ranges {
                assert_eq!(r.start, next);
                next = r.end;
            }
            assert_eq!(next, n_trees);
            // near-even: sizes differ by at most one
            let lens: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
            let (lo, hi) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
            assert!(hi - lo <= 1, "uneven shards: {lens:?}");
        }
    }

    #[test]
    fn into_parts_roundtrips_through_from_parts() {
        let train = data(150, 21);
        let f = DareForest::fit(train, &small_params(3), 5);
        let probe = f.data().row(3);
        let before = f.predict_proba(&probe);
        let (params, seed, trees, d) = f.into_parts();
        let back = DareForest::from_parts(params, seed, trees, d).unwrap();
        assert_eq!(back.predict_proba(&probe), before);
        assert_eq!(back.seed(), 5);
    }

    #[test]
    fn memory_breakdown_scales_with_trees() {
        let train = data(300, 8);
        let f1 = DareForest::fit(train.clone(), &small_params(2), 1);
        let f2 = DareForest::fit(train, &small_params(8), 1);
        assert!(f2.memory().total() > f1.memory().total());
        assert!(f1.data_bytes() > 0);
        assert!(f1.mean_decision_nodes() > 0.0);
    }

    #[test]
    fn ownership_predicate_is_pure_and_calibrated() {
        // Pure: same (seed, id, q) → same answer; q=1.0 owns everything
        // without consuming a draw (short-circuit).
        for id in 0..200u32 {
            assert!(owns(42, id, 1.0));
            assert_eq!(owns(42, id, 0.3), owns(42, id, 0.3));
        }
        // Monotone in q: an id owned at q must be owned at every q' > q
        // (same hash, larger threshold).
        for id in 0..500u32 {
            if owns(7, id, 0.2) {
                assert!(owns(7, id, 0.6), "ownership must be monotone in q");
            }
        }
        // Calibrated: the owned fraction of a large id range is ~q.
        for q in [0.1f64, 0.3, 0.7] {
            let owned = (0..20_000u32).filter(|&id| owns(99, id, q)).count();
            let frac = owned as f64 / 20_000.0;
            assert!(
                (frac - q).abs() < 0.02,
                "owned fraction {frac} far from q={q}"
            );
        }
    }

    #[test]
    fn subsampled_trees_own_disjoint_work() {
        let train = data(300, 44);
        let params = Params {
            q: 0.4,
            ..small_params(6)
        };
        let mut f = DareForest::fit(train, &params, 23);
        // Every tree's size equals its owned-live count.
        let counts = f.ownership_counts();
        for (t, tree) in f.trees().iter().enumerate() {
            assert_eq!(tree.n() as usize, counts[t]);
        }
        // Deleting an instance bumps epochs only on owning trees.
        let id = f.live_ids()[0];
        let owners: Vec<bool> =
            f.trees().iter().map(|t| owns(t.tree_seed, id, 0.4)).collect();
        let before: Vec<u64> = f.trees().iter().map(|t| t.epoch).collect();
        let r = f.delete_seq(id).unwrap();
        assert_eq!(r.per_tree.len(), 6);
        for (t, tree) in f.trees().iter().enumerate() {
            if owners[t] {
                assert_eq!(tree.epoch, before[t] + 1, "owner {t} must retrain");
            } else {
                assert_eq!(tree.epoch, before[t], "non-owner {t} must not move");
                assert_eq!(r.per_tree[t].retrain_events.len(), 0);
                assert_eq!(r.per_tree[t].thresholds_resampled, 0);
            }
        }
        // Adds join each owning tree only.
        let p = f.data().n_features();
        let new_id = f.add(&vec![0.1; p], 1);
        for tree in f.trees() {
            let expect = owned_live_ids(f.data(), tree.tree_seed, 0.4).len();
            assert_eq!(tree.n() as usize, expect);
            tree.validate().unwrap();
            let _ = new_id;
        }
        // Unowned-everywhere cost is 0 even though the id is live.
        if let Some(&orphan) = f
            .live_ids()
            .iter()
            .find(|&&i| f.trees().iter().all(|t| !owns(t.tree_seed, i, 0.4)))
        {
            assert_eq!(f.delete_cost(orphan), 0);
        }
    }

    #[test]
    fn q1_fit_is_identical_to_default_fit() {
        let train = data(200, 55);
        let f_default = DareForest::fit(train.clone(), &small_params(4), 9);
        let f_q1 = DareForest::fit(
            train,
            &Params {
                q: 1.0,
                ..small_params(4)
            },
            9,
        );
        for (a, b) in f_default.trees().iter().zip(f_q1.trees()) {
            assert!(a.structural_matches(b), "q=1.0 must not change any stream");
        }
    }

    #[test]
    fn deletion_probability_stays_calibrated() {
        // After deleting many random instances, predictions should still be
        // sane probabilities and accuracy should not collapse.
        let all = data(700, 9);
        let (train, test) = crate::data::split::train_test(&all, 0.71, 0);
        let mut f = DareForest::fit(train, &small_params(10), 3);
        let mut rng = crate::util::rng::Rng::new(5);
        for _ in 0..200 {
            let live = f.live_ids();
            let id = live[rng.index(live.len())];
            f.delete_seq(id).unwrap();
        }
        let probs = f.predict_proba_dataset(&test);
        assert!(probs.iter().all(|p| (0.0..=1.0).contains(p)));
        let (_, ys, _) = test.to_row_major();
        let acc = accuracy(&probs, &ys);
        assert!(acc > 0.7, "post-deletion acc {acc}");
    }
}
