//! # DaRE RF — Machine Unlearning for Random Forests
//!
//! Production reimplementation of *Machine Unlearning for Random Forests*
//! (Brophy & Lowd, ICML 2021) as a three-layer Rust + JAX + Pallas system:
//!
//! - **L3 (this crate)**: the DaRE forest engine — training, exact deletion
//!   (Alg. 1–3), random/greedy nodes, cached node statistics — plus the
//!   unlearning service (coordinator), baselines, dataset corpus, evaluation
//!   harness and the experiment reproductions.
//! - **L2/L1 (python/, build-time only)**: JAX batched-inference graph and
//!   the Pallas split-criterion kernel, AOT-lowered to HLO text in
//!   `artifacts/` and executed from Rust through PJRT (`runtime`).
//!
//! Quickstart:
//! ```no_run
//! use dare::data::{find, split::train_test};
//! use dare::forest::{DareForest, Params};
//!
//! let info = find("surgical").unwrap();
//! let data = info.generate(10, 0);           // 1/10th-scale corpus entry
//! let (train, test) = train_test(&data, 0.8, 0);
//! let params = Params::from_paper(&info.gini, 0); // G-DaRE (d_rmax = 0)
//! let mut forest = DareForest::fit(train, &params, 42);
//! let deleted = forest.delete(3).unwrap();    // exact unlearning of id 3
//! let probs = forest.predict_proba_dataset(&test);
//! # let _ = (deleted, probs);
//! ```

pub mod baselines;
pub mod bench;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod exp;
pub mod forest;
pub mod metrics;
pub mod runtime;
pub mod util;
