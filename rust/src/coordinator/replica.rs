//! Log-shipping replication (DESIGN.md §12): read-serving followers that
//! bootstrap from a leader snapshot (`pull_snapshot`) and tail its
//! write-ahead log (`pull_log`) over the wire.
//!
//! **Exactness.** DaRE removal is exact and replay is deterministic
//! (retrains are path-seeded pure functions of the op sequence —
//! DESIGN.md §6/§9/§11), so a follower that has applied the leader's log
//! through epoch E is *bit-identical* to the leader at epoch E: same
//! forest structure, same serialized JSON, same predictions. The op-fuzz
//! differential harness enforces this directly.
//!
//! **The epoch-chain dedup rule.** The WAL's epochs increase by exactly 1
//! per record, so a follower needs no other bookkeeping: a shipped record
//! with `epoch <= applied` is a duplicate (leader resend, reconnect
//! overlap) and is skipped; `epoch == applied + 1` extends the chain;
//! anything further ahead is a gap and is refused. Applies run under one
//! lock in log order — the same log-order-equals-apply-order discipline
//! as recovery — and each accepted record is journaled to the follower's
//! *own* WAL before it is applied, so a follower restart recovers locally
//! without re-pulling history.
//!
//! **Graceful degradation.** A follower that cannot reach its leader
//! keeps serving the read plane; once its lag exceeds a configured bound
//! (or the leader has been unreachable too long to even measure lag),
//! read responses are annotated `"stale":true` rather than refused.
//! [`promote`] drains catch-up and flips the model into a writable
//! leader — the failover path.

use crate::coordinator::api::{ApiError, Op};
use crate::coordinator::protocol::{Client, ClientConfig};
use crate::coordinator::registry::Model;
use crate::coordinator::service::UnlearningService;
use crate::coordinator::wal::LogRecord;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Weak};
use std::time::{Duration, Instant};

/// How a follower tails its leader.
#[derive(Clone, Debug)]
pub struct ReplicationConfig {
    /// Leader address (`host:port`).
    pub leader: String,
    /// Sleep between catch-up rounds once caught up (or after an error).
    pub poll_interval: Duration,
    /// Max records per `pull_log` round.
    pub max_records: usize,
    /// Annotate reads `"stale":true` once the applied epoch trails the
    /// last observed leader epoch by more than this.
    pub stale_after_epochs: u64,
    /// Also annotate stale once the leader has been unreachable this long
    /// — lag cannot be observed across a partition.
    pub stale_after_unreachable: Duration,
    /// Transport policy for catch-up connections: the same one
    /// timeout/retry/backoff implementation every typed client uses.
    pub client: ClientConfig,
    /// Spawn a background tailer thread per model. Tests turn this off
    /// and drive [`ReplicaState::sync_once`] deterministically.
    pub spawn_tailers: bool,
}

impl Default for ReplicationConfig {
    fn default() -> Self {
        ReplicationConfig {
            leader: String::new(),
            poll_interval: Duration::from_millis(100),
            max_records: 512,
            stale_after_epochs: 64,
            stale_after_unreachable: Duration::from_secs(5),
            client: ClientConfig::default(),
            spawn_tailers: true,
        }
    }
}

/// Outcome of offering one shipped record to a follower.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Applied {
    /// The record extended the epoch chain: journaled and applied.
    Ok,
    /// `epoch <= applied`: already have it — skipped without touching
    /// any state (the epoch-chain dedup rule).
    Duplicate,
}

/// Per-model replication state, attached to a follower's [`Model`].
pub struct ReplicaState {
    cfg: ReplicationConfig,
    /// Current leader address; updatable so failover can re-point
    /// surviving followers at a promoted peer.
    leader: Mutex<String>,
    /// Epoch of the last record applied locally (mirrors the follower's
    /// own WAL epoch when it has one).
    applied_epoch: AtomicU64,
    /// Last leader epoch observed via `pull_log`.
    leader_epoch: AtomicU64,
    reachable: AtomicBool,
    /// When the leader became unreachable (`None` while reachable).
    unreachable_since: Mutex<Option<Instant>>,
    /// A promoted follower is a writable leader; tailers exit.
    promoted: AtomicBool,
    stopped: AtomicBool,
    /// Serializes catch-up rounds (background tailer vs promote's drain
    /// vs test-driven syncs): log order equals apply order, exactly as
    /// in recovery.
    sync: Mutex<()>,
}

impl ReplicaState {
    /// State for a follower whose local journal stands at `applied_epoch`.
    pub fn new(cfg: ReplicationConfig, applied_epoch: u64) -> Arc<ReplicaState> {
        Arc::new(ReplicaState {
            leader: Mutex::new(cfg.leader.clone()),
            applied_epoch: AtomicU64::new(applied_epoch),
            leader_epoch: AtomicU64::new(applied_epoch),
            reachable: AtomicBool::new(true),
            unreachable_since: Mutex::new(None),
            promoted: AtomicBool::new(false),
            stopped: AtomicBool::new(false),
            sync: Mutex::new(()),
            cfg,
        })
    }

    /// Whether the model still rejects mutations.
    pub fn is_follower(&self) -> bool {
        !self.promoted.load(Ordering::SeqCst)
    }

    /// `"follower"` until promoted, then `"leader"` (the `stats` field).
    pub fn role(&self) -> &'static str {
        if self.is_follower() {
            "follower"
        } else {
            "leader"
        }
    }

    pub fn leader(&self) -> String {
        self.leader.lock().unwrap().clone()
    }

    /// Re-point the follower at a new leader address (failover).
    pub fn set_leader(&self, addr: &str) {
        *self.leader.lock().unwrap() = addr.to_string();
    }

    pub fn applied_epoch(&self) -> u64 {
        self.applied_epoch.load(Ordering::SeqCst)
    }

    pub fn leader_reachable(&self) -> bool {
        self.reachable.load(Ordering::SeqCst)
    }

    /// Epochs the follower trails the last observed leader epoch by.
    pub fn lag_epochs(&self) -> u64 {
        self.leader_epoch.load(Ordering::SeqCst).saturating_sub(self.applied_epoch())
    }

    /// Record a leader epoch observed out-of-band (never moves backward;
    /// `sync_once` calls this itself).
    pub fn note_leader_epoch(&self, epoch: u64) {
        let cur = self.leader_epoch.load(Ordering::SeqCst);
        self.leader_epoch.store(epoch.max(cur), Ordering::SeqCst);
    }

    /// Whether reads should be annotated stale: observed lag beyond the
    /// bound, or the leader unreachable for longer than the grace window
    /// (during a partition the lag itself cannot be observed).
    pub fn is_stale(&self) -> bool {
        if !self.is_follower() {
            return false;
        }
        if self.lag_epochs() > self.cfg.stale_after_epochs {
            return true;
        }
        if !self.leader_reachable() {
            if let Some(since) = *self.unreachable_since.lock().unwrap() {
                return since.elapsed() > self.cfg.stale_after_unreachable;
            }
        }
        false
    }

    fn mark_reachable(&self, up: bool) {
        self.reachable.store(up, Ordering::SeqCst);
        let mut since = self.unreachable_since.lock().unwrap();
        if up {
            *since = None;
        } else if since.is_none() {
            *since = Some(Instant::now());
        }
    }

    /// Offer one shipped record under the epoch-chain rule (see module
    /// docs): duplicates are skipped, gaps refused, and the successor
    /// record is journaled to the follower's own WAL *before* it is
    /// applied — the same ack-after-durability contract the leader
    /// honors. Callers serialize rounds via [`ReplicaState::sync_once`];
    /// records must be offered in log order.
    pub fn apply_shipped(&self, model: &Model, rec: &LogRecord) -> anyhow::Result<Applied> {
        let local = self.applied_epoch();
        if rec.epoch <= local {
            return Ok(Applied::Duplicate);
        }
        anyhow::ensure!(
            rec.epoch == local + 1,
            "epoch gap in shipped log: have {local}, got {} (resync needed)",
            rec.epoch
        );
        anyhow::ensure!(
            rec.request.model == model.name(),
            "shipped record for model '{}' offered to '{}'",
            rec.request.model,
            model.name()
        );
        let sharded = model.sharded();
        match &rec.request.op {
            Op::Delete { ids } => {
                let ids = ids.clone();
                self.journal(model, rec, move || {
                    sharded.delete_batch(&ids);
                })?;
            }
            Op::Add { row, label } => {
                anyhow::ensure!(
                    row.len() == sharded.n_features(),
                    "shipped add has arity {} but the model expects {}",
                    row.len(),
                    sharded.n_features()
                );
                let (row, label) = (row.clone(), *label);
                self.journal(model, rec, move || {
                    let _ = sharded.add(&row, label);
                })?;
            }
            other => anyhow::bail!("non-mutating op in shipped log: {other:?}"),
        }
        self.applied_epoch.store(rec.epoch, Ordering::SeqCst);
        model.telemetry().incr("replicated_ops", 1);
        Ok(Applied::Ok)
    }

    /// Journal + apply one accepted record. The follower's WAL assigns
    /// `its epoch + 1` to the append; the chain check in `apply_shipped`
    /// keeps that equal to the leader's record epoch, and the wire codec
    /// is deterministic — so leader and follower logs hold byte-identical
    /// records. Without a WAL (in-memory follower) the record is applied
    /// directly.
    fn journal(&self, model: &Model, rec: &LogRecord, apply: impl FnOnce()) -> anyhow::Result<()> {
        match model.wal() {
            None => {
                apply();
                Ok(())
            }
            Some(wal) => {
                anyhow::ensure!(
                    wal.epoch() + 1 == rec.epoch,
                    "follower wal at epoch {} cannot journal shipped record {}",
                    wal.epoch(),
                    rec.epoch
                );
                let sharded = Arc::clone(model.sharded());
                wal.logged(rec.request.op.clone(), apply, move || sharded.snapshot())?;
                Ok(())
            }
        }
    }

    /// One catch-up round: pull a window past the applied epoch from the
    /// current leader and apply it in order. Returns how many records
    /// were applied (0 = caught up). Any failure — transport, an epoch
    /// gap, or the leader having truncated past us (`snapshot_needed`,
    /// which requires an operator re-bootstrap: wipe the follower's
    /// journal dir and restart) — marks the leader unreachable for
    /// staleness accounting; the follower keeps serving either way.
    pub fn sync_once(&self, model: &Model) -> anyhow::Result<usize> {
        let _round = self.sync.lock().unwrap();
        let leader = self.leader();
        let outcome = (|| -> anyhow::Result<usize> {
            let mut client = Client::connect_with(leader.as_str(), self.cfg.client.clone())?;
            let batch = client
                .pull_log(model.name(), self.applied_epoch(), self.cfg.max_records)
                .map_err(|e| anyhow::anyhow!("pull_log from {leader}: {e}"))?;
            self.note_leader_epoch(batch.leader_epoch);
            anyhow::ensure!(
                !batch.snapshot_needed,
                "leader truncated its log past epoch {} (base {}): wipe the \
                 follower journal for '{}' and re-bootstrap",
                self.applied_epoch(),
                batch.base_epoch,
                model.name()
            );
            let mut applied = 0;
            for rec in &batch.records {
                if self.apply_shipped(model, rec)? == Applied::Ok {
                    applied += 1;
                }
            }
            Ok(applied)
        })();
        self.mark_reachable(outcome.is_ok());
        outcome
    }
}

/// Spawn the background catch-up loop for one follower model. Holds only
/// a `Weak` handle, so dropping the model (or its registry) stops the
/// thread within one round — the same lifecycle discipline as the
/// service compactor.
pub fn spawn_tailer(model: Weak<Model>) {
    let _ = std::thread::Builder::new().name("dare-replica".to_string()).spawn(move || loop {
        let Some(m) = model.upgrade() else { return };
        let Some(rep) = m.replica() else { return };
        if rep.stopped.load(Ordering::SeqCst) || !rep.is_follower() {
            return;
        }
        let poll = rep.cfg.poll_interval;
        match rep.sync_once(&m) {
            // applied something: more may be waiting, pull again now
            Ok(n) if n > 0 => {}
            // caught up or unreachable: back off (drop the strong handle
            // first so the model can be freed while we sleep)
            _ => {
                drop(rep);
                drop(m);
                std::thread::sleep(poll);
            }
        }
    });
}

/// Bootstrap `svc` as a read-serving follower of `cfg.leader`: list the
/// leader's models and, for each durable one, either resume the local
/// journal (a follower restart recovers locally, no snapshot transfer)
/// or pull a snapshot and install it at the snapshot's epoch. Returns
/// the model names now following. Leader models without durability have
/// no epoch chain to ship and are skipped with a warning.
pub fn bootstrap_follower(
    svc: &Arc<UnlearningService>,
    cfg: &ReplicationConfig,
) -> anyhow::Result<Vec<String>> {
    let mut client = Client::connect_with(cfg.leader.as_str(), cfg.client.clone())
        .map_err(|e| anyhow::anyhow!("cannot reach leader {}: {e}", cfg.leader))?;
    let summaries = client.list().map_err(|e| anyhow::anyhow!("list on {}: {e}", cfg.leader))?;
    let mut following = Vec::new();
    for s in &summaries {
        match follow_model(svc, cfg, &mut client, &s.name) {
            Ok(()) => following.push(s.name.clone()),
            Err(e) => eprintln!("replica: not following '{}': {e}", s.name),
        }
    }
    Ok(following)
}

fn follow_model(
    svc: &Arc<UnlearningService>,
    cfg: &ReplicationConfig,
    client: &mut Client,
    name: &str,
) -> anyhow::Result<()> {
    let (model, applied) = match svc.registry().get(name) {
        // Already recovered from the follower's own journal at startup:
        // resume tailing from the local epoch.
        Ok(m) => {
            anyhow::ensure!(m.replica().is_none(), "already following '{name}'");
            let wal = m
                .wal()
                .ok_or_else(|| anyhow::anyhow!("local model '{name}' has no journal to resume from"))?;
            let epoch = wal.epoch();
            (m, epoch)
        }
        Err(_) => {
            let (epoch, snapshot) = client
                .pull_snapshot(name)
                .map_err(|e| anyhow::anyhow!("pull_snapshot: {e}"))?;
            let m = svc
                .install_snapshot(name, &snapshot, epoch)
                .map_err(|e| anyhow::anyhow!("install: {e}"))?;
            (m, epoch)
        }
    };
    let rep = ReplicaState::new(cfg.clone(), applied);
    model.attach_replica(rep);
    if cfg.spawn_tailers {
        spawn_tailer(Arc::downgrade(&model));
    }
    Ok(())
}

/// Drain catch-up and flip a follower model into a writable leader (the
/// `promote` op). Pull rounds repeat until one applies nothing new; if
/// the leader cannot be reached at all, promotion proceeds with what has
/// already been replicated — that *is* the failover case. Returns the
/// epoch the model promoted at; its own WAL continues the same chain, so
/// subsequent mutations journal and replay cleanly.
pub fn promote(model: &Model) -> Result<u64, ApiError> {
    let Some(rep) = model.replica() else {
        return Err(ApiError::BadRequest("promote: model is not a follower".to_string()));
    };
    if !rep.is_follower() {
        return Err(ApiError::BadRequest("promote: model is already a leader".to_string()));
    }
    loop {
        match rep.sync_once(model) {
            Ok(0) => break,    // one full round with nothing new: drained
            Ok(_) => continue, // still catching up
            Err(_) => break,   // leader gone — promote with what we have
        }
    }
    rep.promoted.store(true, Ordering::SeqCst);
    rep.stopped.store(true, Ordering::SeqCst);
    Ok(rep.applied_epoch())
}
