//! The multi-tenant model registry (DESIGN.md §10).
//!
//! [`Model`] bundles everything one served model owns: its sharded forest
//! store (DESIGN.md §8), deletion batcher, per-model telemetry, and the
//! PJRT predictor snapshot state. [`ModelRegistry`] is the concurrent
//! name → model map the service dispatches into.
//!
//! **Locking story.** The registry's `RwLock` guards only the name→`Arc`
//! mapping and is never held across model work: data-plane dispatch clones
//! the `Arc` out under the read lock and releases it before touching any
//! per-model lock, so a slow retrain in one tenant can never block
//! `create` / `drop` / `list` or another tenant's traffic — and lifecycle
//! ops only ever contend on the map itself. `drop` removes the entry;
//! in-flight requests on already-resolved handles finish safely and the
//! model's batcher thread stops when the last `Arc` drops.

use crate::coordinator::api::{ApiError, Certificate, ModelSummary, Op};
use crate::coordinator::batcher::{DeleteOutcome, DeletionBatcher};
use crate::coordinator::replica::ReplicaState;
use crate::coordinator::service::ServiceConfig;
use crate::coordinator::shards::ShardedForest;
use crate::coordinator::telemetry::Telemetry;
use crate::coordinator::wal::Wal;
use crate::data::dataset::InstanceId;
use crate::forest::forest::DareForest;
use crate::forest::lazy::LazyPolicy;
use crate::runtime::{Engine, Manifest, PjrtPredictor};
use crate::util::json::Value;
use crate::util::threadpool::default_threads;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, RwLock};

/// One served model: sharded store + batcher + telemetry + PJRT state.
pub struct Model {
    name: String,
    sharded: Arc<ShardedForest>,
    batcher: DeletionBatcher,
    telemetry: Arc<Telemetry>,
    /// RwLock, not Mutex: predicts over a current snapshot share the read
    /// lock (the backend executable serializes internally), only refreshes
    /// take the write lock.
    pjrt: RwLock<Option<PjrtPredictor>>,
    manifest: Option<Manifest>,
    /// Per-shard epochs the PJRT tensor snapshot was last refreshed at —
    /// only ever published after an epoch-validated (consistent) refresh;
    /// compared against [`ShardedForest::shard_epochs`] so only mutated
    /// shards are re-tensorized.
    pjrt_epochs: Mutex<Vec<u64>>,
    /// Write-ahead log (DESIGN.md §11); `None` = in-memory-only model.
    /// Adds journal through it here; deletes journal inside the batcher
    /// worker (the same `Arc`), so every mutating op is logged before it
    /// is applied or acked.
    wal: Option<Arc<Wal>>,
    /// Replication state (DESIGN.md §12); `Some` makes this model a
    /// read-only follower until promoted. Attached after construction by
    /// `replica::bootstrap_follower`.
    replica: Mutex<Option<Arc<ReplicaState>>>,
}

impl Model {
    /// Build a served model from a trained forest under the service's
    /// config (shard count, deferral policy, batching window).
    pub fn new(name: &str, forest: DareForest, cfg: &ServiceConfig) -> Arc<Model> {
        Self::new_with_wal(name, forest, cfg, None)
    }

    /// Like [`Model::new`], with an optional write-ahead log: every
    /// mutating op on the model is journaled before it is applied.
    pub fn new_with_wal(
        name: &str,
        forest: DareForest,
        cfg: &ServiceConfig,
        wal: Option<Arc<Wal>>,
    ) -> Arc<Model> {
        // Build the PJRT predictor against the intact forest, then hand the
        // trees over to the sharded store.
        let (pjrt, manifest) = if cfg.use_pjrt {
            match crate::runtime::manifest::locate_artifacts()
                .ok_or_else(|| anyhow::anyhow!("artifacts not built"))
                .and_then(|dir| Manifest::load(&dir))
            {
                Ok(m) => {
                    let p = Engine::global()
                        .and_then(|e| PjrtPredictor::new(e, &m, &forest))
                        .ok();
                    (p, Some(m))
                }
                Err(_) => (None, None),
            }
        } else {
            (None, None)
        };
        let n_shards = if cfg.n_shards == 0 {
            default_threads()
        } else {
            cfg.n_shards
        };
        let sharded = Arc::new(ShardedForest::new_with_policy(forest, n_shards, cfg.lazy));
        let batcher = DeletionBatcher::start_with_wal(
            Arc::clone(&sharded),
            cfg.batch_window,
            cfg.max_batch,
            wal.clone(),
        );
        let pjrt_epochs = sharded.shard_epochs();
        Arc::new(Model {
            name: name.to_string(),
            sharded,
            batcher,
            telemetry: Arc::new(Telemetry::new()),
            pjrt: RwLock::new(pjrt),
            manifest,
            pjrt_epochs: Mutex::new(pjrt_epochs),
            wal,
            replica: Mutex::new(None),
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// The sharded forest store backing this model.
    pub fn sharded(&self) -> &Arc<ShardedForest> {
        &self.sharded
    }

    pub fn telemetry(&self) -> &Telemetry {
        &self.telemetry
    }

    pub fn telemetry_arc(&self) -> Arc<Telemetry> {
        Arc::clone(&self.telemetry)
    }

    /// The model's write-ahead log, when durability is enabled.
    pub fn wal(&self) -> Option<&Arc<Wal>> {
        self.wal.as_ref()
    }

    /// Attach replication state: the model becomes a read-only follower
    /// until promoted (DESIGN.md §12).
    pub fn attach_replica(&self, rep: Arc<ReplicaState>) {
        *self.replica.lock().unwrap() = Some(rep);
    }

    /// The model's replication state, when it is (or was) a follower.
    pub fn replica(&self) -> Option<Arc<ReplicaState>> {
        self.replica.lock().unwrap().clone()
    }

    /// Whether the model currently rejects mutations (unpromoted follower).
    pub fn is_follower(&self) -> bool {
        self.replica().map(|r| r.is_follower()).unwrap_or(false)
    }

    /// The leader this follower redirects mutations to, if any.
    pub fn leader_addr(&self) -> Option<String> {
        self.replica().filter(|r| r.is_follower()).map(|r| r.leader())
    }

    /// Whether the PJRT predictor is active for this model.
    pub fn pjrt_active(&self) -> bool {
        self.pjrt.read().unwrap().is_some()
    }

    /// The model's deferral policy (DESIGN.md §9).
    pub fn lazy_policy(&self) -> LazyPolicy {
        self.sharded.lazy_policy()
    }

    /// Feature arity of the served model.
    pub fn n_features(&self) -> usize {
        self.sharded.n_features()
    }

    /// Clone a consistent [`DareForest`] view of the current model+data.
    pub fn snapshot_forest(&self) -> DareForest {
        self.sharded.snapshot()
    }

    // -- data-plane operations (typed; the service encodes the results) --

    /// Batch prediction: positive-class probability per row. PJRT when the
    /// tensor snapshot is current and consistent, native otherwise; the
    /// returned tag says which engine served.
    pub fn predict(&self, rows: &[Vec<f32>]) -> Result<(Vec<f32>, &'static str), ApiError> {
        // Arity is validated here because the arena descent indexes
        // row[attr] unchecked — a short row from the wire must be a
        // request error, not a panic in the handler thread.
        let want = self.sharded.n_features();
        for r in rows {
            if r.len() != want {
                return Err(ApiError::ArityMismatch {
                    got: r.len(),
                    want,
                });
            }
        }
        self.telemetry.incr("predict_rows", rows.len() as u64);

        // Under a lazy policy the tensorized snapshot may contain pending
        // (stale) subtrees that these rows never descend into — the epochs
        // can't tell us which. PJRT serves only a fully-flushed model; with
        // a backlog, this request takes the native path, which flushes
        // exactly the subtrees it reads. The compactor drains the backlog
        // and PJRT re-engages via the normal epoch diff.
        let pjrt_eligible =
            !self.sharded.lazy_policy().is_lazy() || self.sharded.pending_retrains() == 0;

        if pjrt_eligible {
            // Fast path: PJRT predicts over a current snapshot share the
            // read lock — concurrent predicts don't serialize here.
            {
                let pjrt = self.pjrt.read().unwrap();
                if let Some(pred) = pjrt.as_ref() {
                    if self.pjrt_snapshot_current() {
                        if let Ok(probs) = pred.predict(rows) {
                            return Ok((probs, "pjrt"));
                        }
                    }
                }
            }
            // Slow path (model mutated since the last snapshot): take the
            // write lock, refresh only the dirty shards, and serve if the
            // refresh was epoch-consistent. The read guard is dropped in
            // its own block before the write acquisition — same-thread
            // read→write on one RwLock would deadlock.
            let pjrt_present = { self.pjrt.read().unwrap().is_some() };
            if pjrt_present {
                let mut pjrt_guard = self.pjrt.write().unwrap();
                if self.refresh_pjrt(&mut pjrt_guard) {
                    if let Some(pred) = pjrt_guard.as_ref() {
                        if let Ok(probs) = pred.predict(rows) {
                            return Ok((probs, "pjrt"));
                        }
                    }
                }
            }
        }

        // Native path: per-shard partials, no write lock anywhere.
        Ok((self.sharded.predict_proba_rows(rows), "native"))
    }

    /// Route a deletion request through the model's batcher.
    pub fn delete(&self, ids: Vec<InstanceId>) -> Result<DeleteOutcome, ApiError> {
        match self.batcher.delete(ids) {
            Ok(out) => {
                // A no-op batch (all ids dead/duplicate) mutates nothing
                // and moves no shard epoch — count only effective
                // mutations so 'mutations' stays reconcilable with the
                // epochs.
                if out.deleted > 0 {
                    self.telemetry.incr("mutations", 1);
                }
                self.telemetry.incr("deleted_ids", out.deleted as u64);
                self.telemetry.incr("deferred_retrains", out.deferred as u64);
                Ok(out)
            }
            // The batcher only errors when its worker stopped — i.e. the
            // model is being torn down.
            Err(_) => Err(ApiError::ShuttingDown),
        }
    }

    /// Add a fresh training instance (§6); returns its id. With a WAL the
    /// op is journaled (+fsync'd) before it is applied — validation
    /// happens first, so only ops that will deterministically succeed on
    /// replay reach the log.
    pub fn add(&self, row: &[f32], label: u8) -> Result<InstanceId, ApiError> {
        let want = self.sharded.n_features();
        if row.len() != want {
            return Err(ApiError::ArityMismatch {
                got: row.len(),
                want,
            });
        }
        let applied = match &self.wal {
            None => self.sharded.add(row, label),
            Some(wal) => {
                match wal.logged(
                    Op::Add {
                        row: row.to_vec(),
                        label,
                    },
                    || self.sharded.add(row, label),
                    || self.sharded.snapshot(),
                ) {
                    Ok(r) => r,
                    Err(e) => {
                        return Err(ApiError::BadRequest(format!("durability failure: {e}")))
                    }
                }
            }
        };
        match applied {
            Ok(id) => {
                self.telemetry.incr("mutations", 1);
                Ok(id)
            }
            Err(e) => Err(ApiError::BadRequest(format!("{e}"))),
        }
    }

    /// Dry-run total retrain cost of deleting `id`.
    pub fn delete_cost(&self, id: InstanceId) -> Result<u64, ApiError> {
        self.sharded.delete_cost(id).map_err(|_| ApiError::UnknownId(id))
    }

    /// Issue a signed deletion certificate for a removed instance
    /// (DESIGN.md §11). Requires durability: without a log there is no
    /// epoch to anchor the claim to. The id must reference a known, dead
    /// instance — dead ids are never resurrected (adds mint fresh ids),
    /// so the certified statement holds for every later epoch too.
    pub fn certify(&self, id: InstanceId) -> Result<Certificate, ApiError> {
        let Some(wal) = &self.wal else {
            return Err(ApiError::BadRequest(
                "certify requires durability (start the service with a WAL dir)".to_string(),
            ));
        };
        let alive = self.sharded.with_data(|d| {
            if (id as usize) < d.n_total() {
                Some(d.is_alive(id))
            } else {
                None
            }
        });
        match alive {
            None => return Err(ApiError::UnknownId(id)),
            Some(true) => {
                return Err(ApiError::BadRequest(format!(
                    "instance {id} is still live — certify only deleted instances"
                )))
            }
            Some(false) => {}
        }
        self.telemetry.incr("certificates", 1);
        Ok(wal.certify(id, || self.sharded.snapshot()))
    }

    /// The complete `stats` payload (includes `"ok":true`).
    pub fn stats(&self) -> Value {
        let mem = self.sharded.memory();
        let epochs = self.sharded.shard_epochs();
        let mut shards = Vec::with_capacity(epochs.len());
        for (s, &epoch) in epochs.iter().enumerate() {
            let trees = self.sharded.with_shard_trees(s, |_, ts| ts.len());
            let mut o = Value::obj();
            o.set("trees", trees).set("epoch", epoch);
            shards.push(o);
        }
        let (deferred, flushed) = self.sharded.retrain_counters();
        let mut resp = Value::obj();
        resp.set("ok", true)
            .set("model", self.name.as_str())
            .set("telemetry", self.telemetry.snapshot())
            .set("n_alive", self.sharded.n_alive())
            .set("n_features", self.sharded.n_features())
            .set("n_trees", self.sharded.n_trees())
            .set("n_shards", self.sharded.n_shards())
            .set("shards", Value::Arr(shards))
            .set("pjrt_active", self.pjrt_active())
            .set("lazy_policy", self.sharded.lazy_policy().to_string())
            .set("dirty_subtrees", self.sharded.pending_retrains())
            .set("deferred_retrains", deferred)
            .set("flushed_retrains", flushed)
            .set("model_bytes", mem.total())
            .set("data_bytes", self.sharded.data_bytes());
        // Occ(q) ownership telemetry (DESIGN.md §13): the subsample
        // fraction, (tree, instance) mutation pairs skipped because the
        // tree never owned the instance, and the per-tree owned counts
        // (all equal to n_alive at q=1.0).
        resp.set("subsample_q", self.sharded.subsample_q())
            .set("unowned_skips", self.sharded.unowned_skips())
            .set(
                "owned_per_tree",
                Value::Arr(
                    self.sharded
                        .ownership_counts()
                        .into_iter()
                        .map(Value::from)
                        .collect(),
                ),
            );
        resp.set("durable", self.wal.is_some());
        if let Some(wal) = &self.wal {
            // u64 epochs stay exact as JSON numbers far past any real op
            // count; the snapshot schema's string encoding is for seeds.
            resp.set("wal_epoch", wal.epoch());
        }
        match self.replica() {
            None => {
                resp.set("role", "leader");
            }
            Some(rep) => {
                resp.set("role", rep.role());
                if rep.is_follower() {
                    resp.set("replication_lag_epochs", rep.lag_epochs())
                        .set("leader_reachable", rep.leader_reachable())
                        .set("leader", rep.leader().as_str());
                }
            }
        }
        resp
    }

    /// Snapshot the model+data to disk (flushes deferred retrains first —
    /// see [`ShardedForest::snapshot`]).
    pub fn save(&self, path: &str) -> Result<(), ApiError> {
        let snapshot = self.sharded.snapshot();
        crate::forest::serialize::save(&snapshot, std::path::Path::new(path))
            .map_err(|e| ApiError::BadRequest(format!("{e}")))
    }

    /// Execute every deferred retrain; returns how many ran.
    pub fn flush(&self) -> u64 {
        self.sharded.flush_all()
    }

    /// Drain up to `budget` deferred retrains per tree.
    pub fn compact(&self, budget: usize) -> u64 {
        self.sharded.compact(budget)
    }

    /// [`Model::compact`] with tick accounting: every drain — the wire
    /// `compact` op, a scheduler compaction bid replaying through it, or
    /// the legacy background sweep — counts a `compact_ticks`, its
    /// retrains, and the time it spent, so compaction is observable in
    /// `stats` no matter which path triggered it.
    pub fn drain_compact(&self, budget: usize) -> u64 {
        let t0 = std::time::Instant::now();
        let flushed = self.compact(budget);
        self.telemetry.incr("compact_ticks", 1);
        self.telemetry
            .incr("compact_spent_us", t0.elapsed().as_micros() as u64);
        if flushed > 0 {
            self.telemetry.incr("compacted_retrains", flushed);
        }
        flushed
    }

    /// The `list` summary line for this model.
    pub fn summary(&self) -> ModelSummary {
        ModelSummary {
            name: self.name.clone(),
            n_trees: self.sharded.n_trees(),
            n_alive: self.sharded.n_alive(),
            n_shards: self.sharded.n_shards(),
            lazy_policy: self.sharded.lazy_policy().to_string(),
            dirty_subtrees: self.sharded.pending_retrains(),
            pjrt_active: self.pjrt_active(),
        }
    }

    /// Whether the PJRT tensor snapshot matches the current (stable) shard
    /// epochs. `pjrt_epochs` is only published after an epoch-validated
    /// refresh, so equality implies both current and consistent.
    fn pjrt_snapshot_current(&self) -> bool {
        *self.pjrt_epochs.lock().unwrap() == self.sharded.shard_epochs()
    }

    /// Refresh the PJRT tensor snapshot for shards whose epoch moved since
    /// the last refresh, epoch-validated like the native read path: the
    /// epoch vector must be even and unchanged across the whole refresh,
    /// else the per-shard reads could mix pre-/post-mutation trees into a
    /// forest state that never existed. Returns true when the snapshot is
    /// current and consistent (safe to serve); false means serve native
    /// this request (`pjrt_epochs` stays unpublished, so every shard the
    /// torn attempt touched is still marked dirty and re-tensorized next
    /// round). Disables the predictor permanently when a refresh errors —
    /// the forest outgrew the artifact.
    fn refresh_pjrt(&self, pjrt_guard: &mut Option<PjrtPredictor>) -> bool {
        if pjrt_guard.is_none() || self.manifest.is_none() {
            return false;
        }
        let mut last = self.pjrt_epochs.lock().unwrap();
        for _ in 0..2 {
            let epochs = self.sharded.shard_epochs();
            if epochs.iter().any(|e| e % 2 == 1) {
                // A mutation is in flight (§8 seqlock): this request takes
                // the native path, which waits it out consistently.
                return false;
            }
            // Lazy policy: a concurrent mutation may have *marked* pending
            // subtrees since the caller's eligibility check — tensorizing
            // those collapsed regions would serve non-eager bits. Pending
            // counters publish under the shard write locks before the
            // epochs go even, so re-checking here inside the epoch-
            // validated window closes the race: a mark that lands after
            // this check moves the epochs and fails the validation below.
            if self.sharded.lazy_policy().is_lazy() && self.sharded.pending_retrains() > 0 {
                return false;
            }
            if epochs == *last {
                return true;
            }
            let dirty: Vec<usize> =
                (0..epochs.len()).filter(|&s| epochs[s] != last[s]).collect();
            let refreshed = (|| -> anyhow::Result<()> {
                let pred = pjrt_guard.as_mut().unwrap();
                for &s in &dirty {
                    self.sharded
                        .with_shard_trees(s, |first, trees| pred.refresh_trees(first, trees))?;
                }
                pred.rebuild_literals()
            })();
            if refreshed.is_err() {
                *pjrt_guard = None;
                return false;
            }
            // Validate: if a mutation interleaved, the snapshot may be torn
            // — do not publish; retry once, then fall back to native.
            if self.sharded.shard_epochs() == epochs {
                *last = epochs;
                return true;
            }
        }
        false
    }
}

/// The concurrent name → model map. See the module docs for the locking
/// contract.
#[derive(Default)]
pub struct ModelRegistry {
    models: RwLock<BTreeMap<String, Arc<Model>>>,
}

impl ModelRegistry {
    pub fn new() -> Self {
        ModelRegistry {
            models: RwLock::new(BTreeMap::new()),
        }
    }

    /// Resolve a name to its model handle.
    pub fn get(&self, name: &str) -> Result<Arc<Model>, ApiError> {
        self.models
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| ApiError::UnknownModel(name.to_string()))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.models.read().unwrap().contains_key(name)
    }

    /// Register a model under its name; rejects duplicates.
    pub fn insert(&self, model: Arc<Model>) -> Result<(), ApiError> {
        let mut m = self.models.write().unwrap();
        if m.contains_key(model.name()) {
            return Err(ApiError::BadRequest(format!(
                "model '{}' already exists",
                model.name()
            )));
        }
        m.insert(model.name().to_string(), model);
        Ok(())
    }

    /// Unregister and return the model.
    pub fn remove(&self, name: &str) -> Result<Arc<Model>, ApiError> {
        self.models
            .write()
            .unwrap()
            .remove(name)
            .ok_or_else(|| ApiError::UnknownModel(name.to_string()))
    }

    /// All registered models in name order (the map lock is released
    /// before the returned handles are used).
    pub fn models(&self) -> Vec<Arc<Model>> {
        self.models.read().unwrap().values().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.models.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.models.read().unwrap().is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};
    use crate::forest::params::Params;

    fn forest(seed: u64) -> DareForest {
        let d = generate(
            &SynthSpec {
                n: 160,
                informative: 3,
                redundant: 0,
                noise: 2,
                flip: 0.05,
                ..Default::default()
            },
            seed,
        );
        DareForest::fit(
            d,
            &Params {
                n_trees: 3,
                max_depth: 5,
                k: 5,
                ..Default::default()
            },
            seed ^ 0x17,
        )
    }

    fn cfg() -> ServiceConfig {
        ServiceConfig {
            use_pjrt: false,
            n_shards: 2,
            batch_window: std::time::Duration::from_millis(1),
            ..Default::default()
        }
    }

    #[test]
    fn registry_resolves_inserts_and_drops() {
        let reg = ModelRegistry::new();
        assert!(reg.is_empty());
        assert!(matches!(reg.get("a"), Err(ApiError::UnknownModel(n)) if n == "a"));
        reg.insert(Model::new("a", forest(1), &cfg())).unwrap();
        reg.insert(Model::new("b", forest(2), &cfg())).unwrap();
        assert_eq!(reg.len(), 2);
        assert!(reg.contains("a"));
        // duplicate names rejected with a typed error
        let dup = Model::new("a", forest(3), &cfg());
        assert!(matches!(reg.insert(dup), Err(ApiError::BadRequest(_))));
        // listing is name-ordered
        let names: Vec<String> =
            reg.models().iter().map(|m| m.name().to_string()).collect();
        assert_eq!(names, vec!["a".to_string(), "b".to_string()]);
        let dropped = reg.remove("a").unwrap();
        assert_eq!(dropped.name(), "a");
        assert!(!reg.contains("a"));
        assert!(matches!(reg.remove("a"), Err(ApiError::UnknownModel(_))));
    }

    #[test]
    fn models_are_isolated_stores() {
        let reg = ModelRegistry::new();
        reg.insert(Model::new("a", forest(5), &cfg())).unwrap();
        reg.insert(Model::new("b", forest(5), &cfg())).unwrap();
        let a = reg.get("a").unwrap();
        let b = reg.get("b").unwrap();
        let probe = a.sharded().with_data(|d| d.row(0));
        let before = b.predict(&[probe.clone()]).unwrap();
        // a mutation in 'a' must not move 'b' at all
        let out = a.delete(vec![0, 1, 2]).unwrap();
        assert_eq!(out.deleted, 3);
        assert_eq!(b.predict(&[probe]).unwrap(), before);
        assert_eq!(b.sharded().n_alive(), 160);
        assert_eq!(a.sharded().n_alive(), 157);
        // per-model telemetry: only 'a' recorded the mutation
        assert_eq!(a.telemetry().counter("mutations"), 1);
        assert_eq!(b.telemetry().counter("mutations"), 0);
    }

    #[test]
    fn drain_compact_ticks_are_observable() {
        let m = Model::new("m", forest(7), &cfg());
        let flushed = m.drain_compact(4);
        // a fresh model has no backlog: the tick still counts, retrains 0
        assert_eq!(flushed, 0);
        assert_eq!(m.telemetry().counter("compact_ticks"), 1);
        assert_eq!(m.telemetry().counter("compacted_retrains"), 0);
        m.drain_compact(4);
        assert_eq!(m.telemetry().counter("compact_ticks"), 2);
    }

    #[test]
    fn typed_errors_from_model_ops() {
        let m = Model::new("m", forest(9), &cfg());
        let p = m.n_features();
        assert!(matches!(
            m.predict(&[vec![0.0; p + 1]]),
            Err(ApiError::ArityMismatch { want, .. }) if want == p
        ));
        assert!(matches!(
            m.add(&[0.0], 1),
            Err(ApiError::ArityMismatch { got: 1, .. })
        ));
        assert_eq!(m.delete_cost(999_999), Err(ApiError::UnknownId(999_999)));
        assert!(m.delete_cost(5).is_ok());
    }
}
