//! Service telemetry: per-operation counters and streaming latency stats
//! (Welford for exact moments plus a fixed-bucket log-spaced histogram for
//! tail quantiles — no per-request samples retained).

use crate::util::histogram::Histogram;
use crate::util::json::Value;
use crate::util::stats::Welford;
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

#[derive(Default)]
struct OpStats {
    count: u64,
    errors: u64,
    latency: Welford,
    /// Same samples as `latency`, bucketed — the stats surface the scenario
    /// harness exports p50/p95/p99 from (`util::histogram`).
    hist: Histogram,
}

/// Thread-safe telemetry registry.
#[derive(Default)]
pub struct Telemetry {
    ops: Mutex<BTreeMap<String, OpStats>>,
    /// Named monotonic counters (mutations applied, rows predicted, …) —
    /// the stress harness cross-checks these against the ops it issued.
    counters: Mutex<BTreeMap<String, u64>>,
    started: Option<Instant>,
}

impl Telemetry {
    pub fn new() -> Self {
        Telemetry {
            ops: Mutex::new(BTreeMap::new()),
            counters: Mutex::new(BTreeMap::new()),
            started: Some(Instant::now()),
        }
    }

    /// Add `delta` to the named counter.
    pub fn incr(&self, name: &str, delta: u64) {
        let mut c = self.counters.lock().unwrap();
        *c.entry(name.to_string()).or_insert(0) += delta;
    }

    /// Current value of a named counter (0 when never incremented).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    /// Count of operations recorded under `op` (0 when never seen).
    pub fn op_count(&self, op: &str) -> u64 {
        self.ops.lock().unwrap().get(op).map(|s| s.count).unwrap_or(0)
    }

    /// Errors recorded under `op` (0 when never seen).
    pub fn op_errors(&self, op: &str) -> u64 {
        self.ops.lock().unwrap().get(op).map(|s| s.errors).unwrap_or(0)
    }

    /// Record one operation with its latency; `ok` false counts an error.
    pub fn record(&self, op: &str, seconds: f64, ok: bool) {
        let mut ops = self.ops.lock().unwrap();
        let s = ops.entry(op.to_string()).or_default();
        s.count += 1;
        if !ok {
            s.errors += 1;
        }
        s.latency.push(seconds);
        s.hist.record(seconds);
    }

    /// Bucketed latency distribution recorded under `op` (None when the op
    /// was never seen). Cloned out so callers can merge across tenants
    /// without holding the lock.
    pub fn op_histogram(&self, op: &str) -> Option<Histogram> {
        self.ops.lock().unwrap().get(op).map(|s| s.hist.clone())
    }

    /// Exact latency moments recorded under `op` (None when the op was
    /// never seen). Cloned out like [`Telemetry::op_histogram`]; the
    /// scheduler seeds its per-(tenant, op-class) cost estimators from this
    /// so a freshly attached scheduler starts with everything the service
    /// already learned about the tenant's costs.
    pub fn op_latency(&self, op: &str) -> Option<Welford> {
        self.ops.lock().unwrap().get(op).map(|s| s.latency.clone())
    }

    /// Time a closure and record it under `op`.
    pub fn timed<R>(&self, op: &str, f: impl FnOnce() -> (R, bool)) -> R {
        let t0 = Instant::now();
        let (r, ok) = f();
        self.record(op, t0.elapsed().as_secs_f64(), ok);
        r
    }

    /// JSON snapshot for the `stats` op.
    pub fn snapshot(&self) -> Value {
        let ops = self.ops.lock().unwrap();
        let mut out = Value::obj();
        if let Some(t0) = self.started {
            out.set("uptime_seconds", t0.elapsed().as_secs_f64());
        }
        let mut per_op = Value::obj();
        for (name, s) in ops.iter() {
            let mut o = Value::obj();
            o.set("count", s.count)
                .set("errors", s.errors)
                .set("latency_mean_s", s.latency.mean())
                .set("latency_std_s", s.latency.std())
                .set("latency_min_s", s.latency.min())
                .set("latency_max_s", s.latency.max())
                .set("latency_p50_s", s.hist.p50())
                .set("latency_p95_s", s.hist.p95())
                .set("latency_p99_s", s.hist.p99());
            per_op.set(name, o);
        }
        out.set("ops", per_op);
        let counters = self.counters.lock().unwrap();
        let mut cs = Value::obj();
        for (name, v) in counters.iter() {
            cs.set(name, *v);
        }
        out.set("counters", cs);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let t = Telemetry::new();
        t.record("delete", 0.010, true);
        t.record("delete", 0.020, true);
        t.record("predict", 0.001, false);
        let snap = t.snapshot();
        let del = snap.get("ops").unwrap().get("delete").unwrap();
        assert_eq!(del.get("count").unwrap().as_u64(), Some(2));
        assert_eq!(del.get("errors").unwrap().as_u64(), Some(0));
        let mean = del.get("latency_mean_s").unwrap().as_f64().unwrap();
        assert!((mean - 0.015).abs() < 1e-9);
        let pred = snap.get("ops").unwrap().get("predict").unwrap();
        assert_eq!(pred.get("errors").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn timed_wrapper() {
        let t = Telemetry::new();
        let v = t.timed("op", || (42, true));
        assert_eq!(v, 42);
        assert_eq!(
            t.snapshot()
                .get("ops")
                .unwrap()
                .get("op")
                .unwrap()
                .get("count")
                .unwrap()
                .as_u64(),
            Some(1)
        );
    }

    #[test]
    fn histogram_tracks_every_recorded_sample() {
        let t = Telemetry::new();
        for i in 0..50 {
            t.record("predict", 1e-4 * (1 + i % 7) as f64, true);
        }
        // Coherence: the histogram sees exactly the ops the Welford saw.
        let h = t.op_histogram("predict").unwrap();
        assert_eq!(h.count(), t.op_count("predict"));
        assert!(t.op_histogram("delete").is_none());
        let snap = t.snapshot();
        let p = snap.get("ops").unwrap().get("predict").unwrap();
        let p50 = p.get("latency_p50_s").unwrap().as_f64().unwrap();
        let p99 = p.get("latency_p99_s").unwrap().as_f64().unwrap();
        let max = p.get("latency_max_s").unwrap().as_f64().unwrap();
        assert!(p50 > 0.0 && p50 <= p99 && p99 <= max + 1e-12);
    }

    #[test]
    fn op_latency_exports_exact_moments() {
        let t = Telemetry::new();
        assert!(t.op_latency("predict").is_none());
        t.record("predict", 0.010, true);
        t.record("predict", 0.030, true);
        let w = t.op_latency("predict").unwrap();
        assert_eq!(w.n, 2);
        assert!((w.mean() - 0.020).abs() < 1e-12);
        assert_eq!(w.min(), 0.010);
        assert_eq!(w.max(), 0.030);
    }

    #[test]
    fn op_count_and_errors_accessors() {
        let t = Telemetry::new();
        assert_eq!(t.op_count("delete"), 0);
        t.record("delete", 0.01, true);
        t.record("delete", 0.01, false);
        assert_eq!(t.op_count("delete"), 2);
        assert_eq!(t.op_errors("delete"), 1);
        assert_eq!(t.op_errors("predict"), 0);
    }

    #[test]
    fn counters_accumulate_and_snapshot() {
        let t = Telemetry::new();
        assert_eq!(t.counter("mutations"), 0);
        t.incr("mutations", 2);
        t.incr("mutations", 3);
        t.incr("predict_rows", 7);
        assert_eq!(t.counter("mutations"), 5);
        let snap = t.snapshot();
        let cs = snap.get("counters").unwrap();
        assert_eq!(cs.get("mutations").unwrap().as_u64(), Some(5));
        assert_eq!(cs.get("predict_rows").unwrap().as_u64(), Some(7));
    }

    #[test]
    fn concurrent_recording() {
        let t = std::sync::Arc::new(Telemetry::new());
        let mut handles = Vec::new();
        for _ in 0..8 {
            let t = std::sync::Arc::clone(&t);
            handles.push(std::thread::spawn(move || {
                for _ in 0..100 {
                    t.record("x", 0.001, true);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let snap = t.snapshot();
        assert_eq!(
            snap.get("ops").unwrap().get("x").unwrap().get("count").unwrap().as_u64(),
            Some(800)
        );
    }
}
