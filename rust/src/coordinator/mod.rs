//! L3 coordinator: the unlearning service around the DaRE forest — the
//! sharded forest store (per-shard locks + mutation epochs, DESIGN.md §8),
//! request router, deletion batcher (dynamic batching of GDPR deletion
//! requests), per-operation telemetry, and a JSON-lines TCP protocol.

pub mod batcher;
pub mod protocol;
pub mod service;
pub mod shards;
pub mod telemetry;

pub use batcher::{DeleteOutcome, DeletionBatcher};
pub use protocol::{serve, Client};
pub use service::{ServiceConfig, UnlearningService};
pub use shards::ShardedForest;
pub use telemetry::Telemetry;
