//! L3 coordinator: the unlearning service around the DaRE forest — the
//! typed, versioned wire API (`api`, DESIGN.md §10) over a multi-tenant
//! model registry (`registry`), where each served model owns its sharded
//! forest store (per-shard locks + mutation epochs, DESIGN.md §8), a
//! deletion batcher (dynamic batching of GDPR deletion requests), and
//! per-model telemetry; plus a JSON-lines TCP protocol with a typed
//! client.

pub mod api;
pub mod batcher;
pub mod protocol;
pub mod registry;
pub mod service;
pub mod shards;
pub mod telemetry;

pub use api::{
    ApiError, CreateSpec, ModelSummary, Op, Request, Response, DEFAULT_MODEL, WIRE_VERSION,
};
pub use batcher::{DeleteOutcome, DeletionBatcher};
pub use protocol::{serve, Client, Prediction};
pub use registry::{Model, ModelRegistry};
pub use service::{ServiceConfig, UnlearningService};
pub use shards::ShardedForest;
pub use telemetry::Telemetry;
