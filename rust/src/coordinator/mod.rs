//! L3 coordinator: the unlearning service around the DaRE forest — the
//! typed, versioned wire API (`api`, DESIGN.md §10) over a multi-tenant
//! model registry (`registry`), where each served model owns its sharded
//! forest store (per-shard locks + mutation epochs, DESIGN.md §8), a
//! deletion batcher (dynamic batching of GDPR deletion requests), and
//! per-model telemetry; plus a JSON-lines TCP protocol with a typed
//! client, an event-sourced durability layer (`wal`, DESIGN.md §11):
//! write-ahead op log, crash recovery by replay, and signed deletion
//! certificates; and log-shipping replication (`replica`, DESIGN.md §12):
//! WAL-tailing read-only followers with epoch-consistent catch-up,
//! staleness annotation, and failover by promotion; and a deadline-aware
//! cross-tenant scheduler (`scheduler`, DESIGN.md §15): ticket queues,
//! learned per-(tenant, op-class, batch-bucket) cost estimators, and
//! time-budgeted serving with EDF + deficit-round-robin packing.

pub mod api;
pub mod batcher;
pub mod protocol;
pub mod registry;
pub mod replica;
pub mod scheduler;
pub mod service;
pub mod shards;
pub mod telemetry;
pub mod wal;

pub use api::{
    ApiError, Certificate, CreateSpec, ModelSummary, Op, Request, Response, DEFAULT_MODEL,
    WIRE_VERSION,
};
pub use batcher::{DeleteOutcome, DeletionBatcher};
pub use protocol::{serve, Client, ClientConfig, Prediction};
pub use registry::{Model, ModelRegistry};
pub use replica::{bootstrap_follower, Applied, ReplicaState, ReplicationConfig};
pub use scheduler::{
    Clock, ManualClock, OpClass, RunReport, Scheduler, SchedulerConfig, Submitted,
};
pub use service::{ServiceConfig, UnlearningService};
pub use shards::ShardedForest;
pub use telemetry::Telemetry;
pub use wal::{FsyncPolicy, LogRecord, PullBatch, Wal};
