//! Wire protocol: JSON-lines over TCP.
//!
//! Each request is one JSON object on one line; the service answers with one
//! JSON object on one line. `serve` runs the accept loop with a worker pool;
//! `Client` is the matching blocking client used by examples and tests.

use crate::coordinator::service::{err_response, UnlearningService};
use crate::util::json::{parse, Value};
use crate::util::threadpool::ThreadPool;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::Arc;
use std::time::Duration;

/// Serve the JSON-lines protocol until a `shutdown` request arrives.
/// Returns the bound local address via the callback before blocking.
pub fn serve(
    svc: Arc<UnlearningService>,
    addr: &str,
    workers: usize,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> anyhow::Result<()> {
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    on_bound(listener.local_addr()?);
    let pool = ThreadPool::new(workers.max(1));
    loop {
        if svc.is_shutdown() {
            break;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                let svc = Arc::clone(&svc);
                pool.execute(move || {
                    let _ = handle_connection(&svc, stream);
                });
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(e.into()),
        }
    }
    pool.join();
    Ok(())
}

fn handle_connection(svc: &UnlearningService, stream: TcpStream) -> anyhow::Result<()> {
    stream.set_nodelay(true)?;
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = match parse(&line) {
            Ok(req) => svc.handle(&req),
            Err(e) => err_response(&format!("bad request: {e}")),
        };
        writer.write_all(resp.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
        if svc.is_shutdown() {
            break;
        }
    }
    Ok(())
}

/// Blocking JSON-lines client.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    pub fn connect<A: ToSocketAddrs>(addr: A) -> anyhow::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
        })
    }

    /// Send one request and read one response.
    pub fn call(&mut self, req: &Value) -> anyhow::Result<Value> {
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        anyhow::ensure!(!line.is_empty(), "server closed connection");
        parse(&line).map_err(|e| anyhow::anyhow!("{e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::{ServiceConfig, UnlearningService};
    use crate::data::synth::{generate, SynthSpec};
    use crate::forest::forest::DareForest;
    use crate::forest::params::Params;

    fn spawn_server() -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
        let d = generate(
            &SynthSpec {
                n: 150,
                informative: 3,
                redundant: 0,
                noise: 1,
                flip: 0.05,
                ..Default::default()
            },
            2,
        );
        let f = DareForest::fit(
            d,
            &Params {
                n_trees: 3,
                max_depth: 5,
                k: 5,
                ..Default::default()
            },
            1,
        );
        let svc = UnlearningService::new(
            f,
            ServiceConfig {
                use_pjrt: false,
                ..Default::default()
            },
        );
        let (tx, rx) = std::sync::mpsc::channel();
        let handle = std::thread::spawn(move || {
            serve(svc, "127.0.0.1:0", 2, move |addr| {
                tx.send(addr).unwrap();
            })
            .unwrap();
        });
        (rx.recv().unwrap(), handle)
    }

    #[test]
    fn tcp_roundtrip_and_shutdown() {
        let (addr, handle) = spawn_server();
        let mut c = Client::connect(addr).unwrap();

        let r = c.call(&parse(r#"{"op":"stats"}"#).unwrap()).unwrap();
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(r.get("n_alive").unwrap().as_u64(), Some(150));
        // sharded store surfaces its shape over the wire
        let n_shards = r.get("n_shards").unwrap().as_u64().unwrap();
        assert!(n_shards >= 1);
        assert_eq!(r.get("shards").unwrap().as_arr().unwrap().len() as u64, n_shards);

        let r = c.call(&parse(r#"{"op":"delete","ids":[1,2]}"#).unwrap()).unwrap();
        assert_eq!(r.get("deleted").unwrap().as_u64(), Some(2));

        // malformed request gets an error response, connection stays up
        let r = c.call(&parse(r#"{"op":"bogus"}"#).unwrap()).unwrap();
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(false));

        let r = c.call(&parse(r#"{"op":"shutdown"}"#).unwrap()).unwrap();
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
        handle.join().unwrap();
    }

    #[test]
    fn concurrent_clients() {
        let (addr, handle) = spawn_server();
        let mut handles = Vec::new();
        for i in 0..4u32 {
            handles.push(std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                let req = parse(&format!(r#"{{"op":"delete","ids":[{}]}}"#, 10 + i)).unwrap();
                let r = c.call(&req).unwrap();
                assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let mut c = Client::connect(addr).unwrap();
        let r = c.call(&parse(r#"{"op":"stats"}"#).unwrap()).unwrap();
        assert_eq!(r.get("n_alive").unwrap().as_u64(), Some(146));
        c.call(&parse(r#"{"op":"shutdown"}"#).unwrap()).unwrap();
        handle.join().unwrap();
    }
}
