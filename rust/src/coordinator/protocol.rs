//! Wire protocol: JSON-lines over TCP.
//!
//! Each request is one JSON object on one line; the service answers with
//! one JSON object on one line (the v1 schema, DESIGN.md §10). `serve`
//! runs the accept loop with a worker pool; [`Client`] is the matching
//! blocking client. The typed methods (`predict`, `delete`, `create`, …)
//! speak v1 and return `Result<_, ApiError>` — transport failures surface
//! as [`ApiError::Transport`] (carrying the attempt count), server-side
//! failures as the decoded wire variant. `call` remains the raw escape
//! hatch (and still speaks v0 when given un-namespaced objects).
//!
//! The client is governed by a [`ClientConfig`]: connect/read/write
//! timeouts, plus bounded retry with exponential backoff and jitter.
//! Retries apply **only to idempotent ops** (`predict`, `stats`, `list`,
//! `delete_cost`, `verify_cert`, and the replication pulls) — retrying a
//! `delete`/`add` whose first ack was lost could double-apply it. Any IO
//! error tears the connection down; the next attempt reconnects. This is
//! the one retry implementation in the repo — the replica catch-up loop
//! (DESIGN.md §12) drives it rather than rolling its own.

use crate::coordinator::api::{
    self, ApiError, Certificate, CreateSpec, ModelSummary, Op, Request, Response, WIRE_VERSION,
};
use crate::coordinator::batcher::DeleteOutcome;
use crate::coordinator::service::UnlearningService;
use crate::coordinator::wal::{LogRecord, PullBatch};
use crate::data::dataset::InstanceId;
use crate::util::json::{parse, Value};
use crate::util::rng::Rng;
use crate::util::threadpool::ThreadPool;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::Arc;
use std::time::{Duration, SystemTime, UNIX_EPOCH};

/// Serve the JSON-lines protocol until a `shutdown` request arrives.
/// Returns the bound local address via the callback before blocking.
pub fn serve(
    svc: Arc<UnlearningService>,
    addr: &str,
    workers: usize,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> anyhow::Result<()> {
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    on_bound(listener.local_addr()?);
    let pool = ThreadPool::new(workers.max(1));
    loop {
        if svc.is_shutdown() {
            break;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                let svc = Arc::clone(&svc);
                pool.execute(move || {
                    let _ = handle_connection(&svc, stream);
                });
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(e.into()),
        }
    }
    pool.join();
    Ok(())
}

fn handle_connection(svc: &UnlearningService, stream: TcpStream) -> anyhow::Result<()> {
    stream.set_nodelay(true)?;
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = match parse(&line) {
            // With a scheduler attached (DESIGN.md §15) scheduled ops wait
            // for their budget slot (or bounce `overloaded`); without one
            // — and for every bypass op — this is the direct path.
            Ok(req) => match svc.scheduler() {
                Some(sched) => sched.handle(&req),
                None => svc.handle(&req),
            },
            Err(e) => api::encode_response(&Response::Err(ApiError::BadRequest(format!(
                "bad request: {e}"
            )))),
        };
        writer.write_all(resp.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
        if svc.is_shutdown() {
            break;
        }
    }
    Ok(())
}

/// A successful `predict` response: probabilities plus the engine that
/// served them (`"pjrt"` or `"native"`).
#[derive(Clone, Debug, PartialEq)]
pub struct Prediction {
    pub probs: Vec<f32>,
    pub engine: String,
}

/// Client-side transport policy: per-attempt timeouts plus bounded retry
/// with exponential backoff + jitter (idempotent ops only — see the
/// module docs).
#[derive(Clone, Debug)]
pub struct ClientConfig {
    /// Per-address TCP connect timeout. Zero disables the bound.
    pub connect_timeout: Duration,
    /// Read/write timeout on an established connection. Zero disables.
    pub io_timeout: Duration,
    /// Extra attempts after the first failure (idempotent ops only).
    pub retries: u32,
    /// First retry delay; doubled per retry, with ±50% jitter so a fleet
    /// of clients doesn't hammer a recovering server in lockstep.
    pub backoff: Duration,
}

impl Default for ClientConfig {
    fn default() -> Self {
        ClientConfig {
            connect_timeout: Duration::from_secs(2),
            io_timeout: Duration::from_secs(10),
            retries: 2,
            backoff: Duration::from_millis(50),
        }
    }
}

struct Conn {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

/// Blocking JSON-lines client with typed v1 methods. Reconnects lazily
/// after transport errors; see [`ClientConfig`] for the retry policy.
pub struct Client {
    addrs: Vec<SocketAddr>,
    cfg: ClientConfig,
    conn: Option<Conn>,
    /// Jitter source for retry backoff — seeded from the clock; retry
    /// timing is the one place determinism is *not* wanted.
    rng: Rng,
}

impl Client {
    /// Connect with the default [`ClientConfig`].
    pub fn connect<A: ToSocketAddrs>(addr: A) -> anyhow::Result<Client> {
        Self::connect_with(addr, ClientConfig::default())
    }

    /// Connect with an explicit transport policy. The address is resolved
    /// once up front; reconnects reuse the resolved list.
    pub fn connect_with<A: ToSocketAddrs>(addr: A, cfg: ClientConfig) -> anyhow::Result<Client> {
        let addrs: Vec<SocketAddr> = addr.to_socket_addrs()?.collect();
        anyhow::ensure!(!addrs.is_empty(), "address resolved to no endpoints");
        let seed = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0x5eed);
        let mut client = Client {
            addrs,
            cfg,
            conn: None,
            rng: Rng::new(seed ^ (u64::from(std::process::id()) << 32)),
        };
        client.ensure_conn()?;
        Ok(client)
    }

    fn ensure_conn(&mut self) -> std::io::Result<()> {
        if self.conn.is_some() {
            return Ok(());
        }
        let mut last: Option<std::io::Error> = None;
        for addr in self.addrs.clone() {
            match self.open(addr) {
                Ok(conn) => {
                    self.conn = Some(conn);
                    return Ok(());
                }
                Err(e) => last = Some(e),
            }
        }
        Err(last.unwrap_or_else(|| {
            std::io::Error::new(std::io::ErrorKind::AddrNotAvailable, "no endpoint to connect to")
        }))
    }

    fn open(&self, addr: SocketAddr) -> std::io::Result<Conn> {
        let stream = if self.cfg.connect_timeout.is_zero() {
            TcpStream::connect(addr)?
        } else {
            TcpStream::connect_timeout(&addr, self.cfg.connect_timeout)?
        };
        stream.set_nodelay(true)?;
        let io = if self.cfg.io_timeout.is_zero() {
            None
        } else {
            Some(self.cfg.io_timeout)
        };
        stream.set_read_timeout(io)?;
        stream.set_write_timeout(io)?;
        Ok(Conn {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
        })
    }

    /// Send one raw request object and read one response (any version).
    pub fn call(&mut self, req: &Value) -> anyhow::Result<Value> {
        self.call_once(req).map_err(|e| anyhow::anyhow!("{e}"))
    }

    /// One write+read exchange. Any failure tears down the connection so
    /// the next attempt starts from a clean reconnect.
    fn call_once(&mut self, req: &Value) -> std::io::Result<Value> {
        let out = self.exchange_io(req);
        if out.is_err() {
            self.conn = None;
        }
        out
    }

    fn exchange_io(&mut self, req: &Value) -> std::io::Result<Value> {
        self.ensure_conn()?;
        let conn = self.conn.as_mut().expect("ensure_conn established it");
        conn.writer.write_all(req.to_string().as_bytes())?;
        conn.writer.write_all(b"\n")?;
        conn.writer.flush()?;
        let mut line = String::new();
        conn.reader.read_line(&mut line)?;
        if line.is_empty() {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "server closed connection",
            ));
        }
        parse(&line).map_err(|e| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, format!("unparseable response: {e}"))
        })
    }

    /// Send one typed v1 request; decode failure outcomes into
    /// [`ApiError`]. Single attempt: mutations must not be replayed.
    fn request(&mut self, model: &str, op: Op) -> Result<Value, ApiError> {
        self.send(model, op, 1)
    }

    /// Like [`Client::request`] with the configured retry budget — only
    /// for idempotent ops, where re-asking after a lost ack is safe.
    fn request_retrying(&mut self, model: &str, op: Op) -> Result<Value, ApiError> {
        let attempts = 1 + self.cfg.retries;
        self.send(model, op, attempts)
    }

    fn send(&mut self, model: &str, op: Op, max_attempts: u32) -> Result<Value, ApiError> {
        let wire = api::encode_request(&Request {
            v: WIRE_VERSION,
            model: model.to_string(),
            op,
        });
        let mut delay = self.cfg.backoff;
        let mut attempt = 0u32;
        loop {
            attempt += 1;
            match self.call_once(&wire) {
                Ok(resp) => {
                    return if resp.get("ok").and_then(Value::as_bool) == Some(true) {
                        Ok(resp)
                    } else {
                        Err(api::error_from_wire(&resp))
                    };
                }
                Err(e) => {
                    if attempt >= max_attempts.max(1) {
                        return Err(ApiError::Transport {
                            msg: format!("{e}"),
                            attempts: attempt,
                        });
                    }
                    // exponential backoff with ±50% jitter
                    std::thread::sleep(delay.mul_f64(0.5 + self.rng.f64()));
                    delay = delay.saturating_mul(2);
                }
            }
        }
    }

    fn proto_err(msg: impl Into<String>) -> ApiError {
        ApiError::Transport {
            msg: msg.into(),
            attempts: 1,
        }
    }

    fn field_u64(resp: &Value, key: &str) -> Result<u64, ApiError> {
        resp.get(key)
            .and_then(Value::as_u64)
            .ok_or_else(|| Self::proto_err(format!("response missing '{key}'")))
    }

    /// Positive-class probabilities for `rows` from `model`.
    pub fn predict(&mut self, model: &str, rows: &[Vec<f32>]) -> Result<Prediction, ApiError> {
        let resp = self.request_retrying(
            model,
            Op::Predict {
                rows: rows.to_vec(),
            },
        )?;
        let probs = resp
            .get("probs")
            .and_then(Value::as_arr)
            .ok_or_else(|| Self::proto_err("response missing 'probs'"))?
            .iter()
            .map(|p| p.as_f64().unwrap_or(0.0) as f32)
            .collect();
        Ok(Prediction {
            probs,
            engine: resp.get("engine").and_then(Value::as_str).unwrap_or("?").to_string(),
        })
    }

    /// Unlearn `ids` from `model` (grouped with concurrent requests by the
    /// server's deletion batcher).
    pub fn delete(&mut self, model: &str, ids: &[InstanceId]) -> Result<DeleteOutcome, ApiError> {
        let resp = self.request(model, Op::Delete { ids: ids.to_vec() })?;
        let deleted = Self::field_u64(&resp, "deleted")? as usize;
        let skipped = Self::field_u64(&resp, "skipped")? as usize;
        Ok(DeleteOutcome {
            requested: deleted + skipped,
            deleted,
            skipped,
            retrain_cost: Self::field_u64(&resp, "retrain_cost")?,
            deferred: Self::field_u64(&resp, "deferred")? as usize,
            batch_size: Self::field_u64(&resp, "batch_size")? as usize,
        })
    }

    /// Add one training instance to `model`; returns its id.
    pub fn add(&mut self, model: &str, row: &[f32], label: u8) -> Result<InstanceId, ApiError> {
        let resp = self.request(
            model,
            Op::Add {
                row: row.to_vec(),
                label,
            },
        )?;
        Ok(Self::field_u64(&resp, "id")? as InstanceId)
    }

    /// Dry-run retrain cost of deleting `id` from `model`.
    pub fn delete_cost(&mut self, model: &str, id: InstanceId) -> Result<u64, ApiError> {
        let resp = self.request_retrying(model, Op::DeleteCost { id })?;
        Self::field_u64(&resp, "cost")
    }

    /// The model's full stats payload (telemetry, shards, backlog, bytes).
    pub fn stats(&mut self, model: &str) -> Result<Value, ApiError> {
        self.request_retrying(model, Op::Stats)
    }

    /// Execute every deferred retrain of `model`; returns how many ran.
    pub fn flush(&mut self, model: &str) -> Result<u64, ApiError> {
        let resp = self.request(model, Op::Flush)?;
        Self::field_u64(&resp, "flushed")
    }

    /// Drain up to `budget` deferred retrains per tree of `model`.
    pub fn compact(&mut self, model: &str, budget: usize) -> Result<u64, ApiError> {
        let resp = self.request(model, Op::Compact { budget })?;
        Self::field_u64(&resp, "flushed")
    }

    /// Snapshot `model` (with its training database) to a server-side path.
    pub fn save(&mut self, model: &str, path: &str) -> Result<(), ApiError> {
        self.request(
            model,
            Op::Save {
                path: path.to_string(),
            },
        )
        .map(|_| ())
    }

    /// Install a server-side snapshot as a new model named `model`.
    pub fn load(&mut self, model: &str, path: &str) -> Result<(), ApiError> {
        self.request(
            model,
            Op::Load {
                path: path.to_string(),
            },
        )
        .map(|_| ())
    }

    /// Train and register a new model named `model` from a corpus dataset.
    pub fn create(&mut self, model: &str, spec: CreateSpec) -> Result<(), ApiError> {
        self.request(model, Op::Create(spec)).map(|_| ())
    }

    /// Unregister `model`.
    pub fn drop_model(&mut self, model: &str) -> Result<(), ApiError> {
        self.request(model, Op::DropModel).map(|_| ())
    }

    /// Request a signed deletion certificate for an already-deleted
    /// instance of `model` (requires the server to run with a WAL dir).
    pub fn certify(&mut self, model: &str, id: InstanceId) -> Result<Certificate, ApiError> {
        let resp = self.request(model, Op::Certify { id })?;
        let cert = resp
            .get("cert")
            .ok_or_else(|| Self::proto_err("response missing 'cert'"))?;
        Certificate::from_wire(cert)
            .map_err(|e| Self::proto_err(format!("malformed cert in response: {e}")))
    }

    /// Check a deletion certificate against the server's signing key.
    /// Model-independent: works even after the certified model is dropped.
    pub fn verify_cert(&mut self, cert: &Certificate) -> Result<bool, ApiError> {
        let resp = self.request_retrying(
            api::DEFAULT_MODEL,
            Op::VerifyCert { cert: cert.clone() },
        )?;
        resp.get("valid")
            .and_then(Value::as_bool)
            .ok_or_else(|| Self::proto_err("response missing 'valid'"))
    }

    /// Summaries of every registered model.
    pub fn list(&mut self) -> Result<Vec<ModelSummary>, ApiError> {
        let resp = self.request_retrying(api::DEFAULT_MODEL, Op::List)?;
        Ok(resp
            .get("models")
            .and_then(Value::as_arr)
            .unwrap_or(&[])
            .iter()
            .map(ModelSummary::from_wire)
            .collect())
    }

    /// Replication bootstrap (DESIGN.md §12): `model`'s canonical
    /// snapshot JSON and the WAL epoch it captures.
    pub fn pull_snapshot(&mut self, model: &str) -> Result<(u64, String), ApiError> {
        let resp = self.request_retrying(model, Op::PullSnapshot)?;
        let epoch = Self::field_u64(&resp, "wal_epoch")?;
        let snapshot = resp
            .get("snapshot")
            .and_then(Value::as_str)
            .ok_or_else(|| Self::proto_err("response missing 'snapshot'"))?
            .to_string();
        Ok((epoch, snapshot))
    }

    /// Replication catch-up: up to `max_records` log records of `model`
    /// with `epoch > after_epoch`, plus where the leader's log stands.
    pub fn pull_log(
        &mut self,
        model: &str,
        after_epoch: u64,
        max_records: usize,
    ) -> Result<PullBatch, ApiError> {
        let resp = self.request_retrying(
            model,
            Op::PullLog {
                after_epoch,
                max_records,
            },
        )?;
        let mut records = Vec::new();
        for rec in resp.get("records").and_then(Value::as_arr).unwrap_or(&[]) {
            let epoch = rec
                .get("epoch")
                .and_then(Value::as_u64)
                .ok_or_else(|| Self::proto_err("log record missing 'epoch'"))?;
            let request = rec
                .get("request")
                .ok_or_else(|| Self::proto_err("log record missing 'request'"))
                .and_then(api::decode)?;
            records.push(LogRecord { epoch, request });
        }
        Ok(PullBatch {
            records,
            leader_epoch: Self::field_u64(&resp, "leader_epoch")?,
            base_epoch: Self::field_u64(&resp, "base_epoch")?,
            snapshot_needed: resp
                .get("snapshot_needed")
                .and_then(Value::as_bool)
                .unwrap_or(false),
        })
    }

    /// Drain catch-up and flip a follower `model` into a writable leader;
    /// returns the epoch it promoted at. Never retried: promotion is a
    /// topology change, not an idempotent read.
    pub fn promote(&mut self, model: &str) -> Result<u64, ApiError> {
        let resp = self.request(model, Op::Promote)?;
        Self::field_u64(&resp, "epoch")
    }

    /// Stop the server's accept loop.
    pub fn shutdown(&mut self) -> Result<(), ApiError> {
        self.request(api::DEFAULT_MODEL, Op::Shutdown).map(|_| ())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::{ServiceConfig, UnlearningService};
    use crate::data::synth::{generate, SynthSpec};
    use crate::forest::forest::DareForest;
    use crate::forest::params::Params;

    fn spawn_server() -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
        let d = generate(
            &SynthSpec {
                n: 150,
                informative: 3,
                redundant: 0,
                noise: 1,
                flip: 0.05,
                ..Default::default()
            },
            2,
        );
        let f = DareForest::fit(
            d,
            &Params {
                n_trees: 3,
                max_depth: 5,
                k: 5,
                ..Default::default()
            },
            1,
        );
        let svc = UnlearningService::new(
            f,
            ServiceConfig {
                use_pjrt: false,
                ..Default::default()
            },
        );
        let (tx, rx) = std::sync::mpsc::channel();
        let handle = std::thread::spawn(move || {
            serve(svc, "127.0.0.1:0", 2, move |addr| {
                tx.send(addr).unwrap();
            })
            .unwrap();
        });
        (rx.recv().unwrap(), handle)
    }

    #[test]
    fn tcp_roundtrip_and_shutdown() {
        let (addr, handle) = spawn_server();
        let mut c = Client::connect(addr).unwrap();

        // typed stats
        let r = c.stats("default").unwrap();
        assert_eq!(r.get("n_alive").unwrap().as_u64(), Some(150));
        // sharded store surfaces its shape over the wire
        let n_shards = r.get("n_shards").unwrap().as_u64().unwrap();
        assert!(n_shards >= 1);
        assert_eq!(r.get("shards").unwrap().as_arr().unwrap().len() as u64, n_shards);

        // typed delete
        let out = c.delete("default", &[1, 2]).unwrap();
        assert_eq!(out.deleted, 2);
        assert_eq!(out.skipped, 0);

        // a raw v0 request still works over the same connection
        let r = c.call(&parse(r#"{"op":"stats"}"#).unwrap()).unwrap();
        assert_eq!(r.get("n_alive").unwrap().as_u64(), Some(148));

        // typed errors cross the wire intact
        match c.call(&parse(r#"{"op":"bogus"}"#).unwrap()) {
            Ok(r) => {
                assert_eq!(r.get("ok").unwrap().as_bool(), Some(false));
                assert_eq!(
                    r.get("error").unwrap().get("code").unwrap().as_str(),
                    Some("bad_request")
                );
            }
            Err(e) => panic!("raw call should surface the error object: {e}"),
        }
        match c.delete_cost("default", 999_999) {
            Err(ApiError::UnknownId(id)) => assert_eq!(id, 999_999),
            other => panic!("expected UnknownId, got {other:?}"),
        }
        match c.stats("ghost") {
            Err(ApiError::UnknownModel(m)) => assert_eq!(m, "ghost"),
            other => panic!("expected UnknownModel, got {other:?}"),
        }

        c.shutdown().unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn certify_and_verify_over_tcp() {
        let root = std::env::temp_dir().join(format!("dare-proto-wal-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let d = generate(
            &SynthSpec {
                n: 120,
                informative: 3,
                redundant: 0,
                noise: 1,
                flip: 0.05,
                ..Default::default()
            },
            4,
        );
        let f = DareForest::fit(
            d,
            &Params {
                n_trees: 3,
                max_depth: 5,
                k: 5,
                ..Default::default()
            },
            6,
        );
        let svc = UnlearningService::new(
            f,
            ServiceConfig {
                use_pjrt: false,
                wal_dir: Some(root.clone()),
                cert_key: Some("tcp-test-key".to_string()),
                ..Default::default()
            },
        );
        let (tx, rx) = std::sync::mpsc::channel();
        let handle = std::thread::spawn(move || {
            serve(svc, "127.0.0.1:0", 2, move |addr| {
                tx.send(addr).unwrap();
            })
            .unwrap();
        });
        let addr = rx.recv().unwrap();
        let mut c = Client::connect(addr).unwrap();

        // live instance: typed bad_request before deletion...
        match c.certify("default", 7) {
            Err(ApiError::BadRequest(_)) => {}
            other => panic!("expected BadRequest for a live instance, got {other:?}"),
        }
        // ...then a verifiable certificate after
        c.delete("default", &[7]).unwrap();
        let cert = c.certify("default", 7).unwrap();
        assert_eq!(cert.instance_id, 7);
        assert_eq!(cert.model, "default");
        assert!(c.verify_cert(&cert).unwrap());
        let mut forged = cert.clone();
        forged.epoch += 1;
        assert!(!c.verify_cert(&forged).unwrap());

        c.shutdown().unwrap();
        handle.join().unwrap();
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn idempotent_ops_retry_and_surface_attempt_counts() {
        // a one-shot fake server: accepts a single connection, reads the
        // request, then closes without answering
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let fake = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut buf = [0u8; 512];
            use std::io::Read;
            let _ = s.read(&mut buf);
            // dropping both tears the endpoint down: retries get refused
        });
        let mut c = Client::connect_with(
            addr,
            ClientConfig {
                connect_timeout: Duration::from_millis(500),
                io_timeout: Duration::from_millis(500),
                retries: 2,
                backoff: Duration::from_millis(1),
            },
        )
        .unwrap();
        // idempotent op: all 1 + retries attempts are consumed
        match c.stats("default") {
            Err(ApiError::Transport { attempts, .. }) => assert_eq!(attempts, 3),
            other => panic!("expected Transport after retries, got {other:?}"),
        }
        fake.join().unwrap();
        // mutation: fails on the first transport error, no silent replay
        match c.delete("default", &[1]) {
            Err(ApiError::Transport { attempts, .. }) => assert_eq!(attempts, 1),
            other => panic!("expected single-attempt Transport, got {other:?}"),
        }
    }

    #[test]
    fn concurrent_clients() {
        let (addr, handle) = spawn_server();
        let mut handles = Vec::new();
        for i in 0..4u32 {
            handles.push(std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                let out = c.delete("default", &[10 + i]).unwrap();
                assert_eq!(out.deleted, 1);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let mut c = Client::connect(addr).unwrap();
        let r = c.stats("default").unwrap();
        assert_eq!(r.get("n_alive").unwrap().as_u64(), Some(146));
        c.shutdown().unwrap();
        handle.join().unwrap();
    }
}
