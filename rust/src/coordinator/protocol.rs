//! Wire protocol: JSON-lines over TCP.
//!
//! Each request is one JSON object on one line; the service answers with
//! one JSON object on one line (the v1 schema, DESIGN.md §10). `serve`
//! runs the accept loop with a worker pool; [`Client`] is the matching
//! blocking client. The typed methods (`predict`, `delete`, `create`, …)
//! speak v1 and return `Result<_, ApiError>` — transport failures surface
//! as [`ApiError::Transport`], server-side failures as the decoded wire
//! variant. `call` remains the raw escape hatch (and still speaks v0 when
//! given un-namespaced objects).

use crate::coordinator::api::{
    self, ApiError, Certificate, CreateSpec, ModelSummary, Op, Request, Response, WIRE_VERSION,
};
use crate::coordinator::batcher::DeleteOutcome;
use crate::coordinator::service::UnlearningService;
use crate::data::dataset::InstanceId;
use crate::util::json::{parse, Value};
use crate::util::threadpool::ThreadPool;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::Arc;
use std::time::Duration;

/// Serve the JSON-lines protocol until a `shutdown` request arrives.
/// Returns the bound local address via the callback before blocking.
pub fn serve(
    svc: Arc<UnlearningService>,
    addr: &str,
    workers: usize,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> anyhow::Result<()> {
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    on_bound(listener.local_addr()?);
    let pool = ThreadPool::new(workers.max(1));
    loop {
        if svc.is_shutdown() {
            break;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                let svc = Arc::clone(&svc);
                pool.execute(move || {
                    let _ = handle_connection(&svc, stream);
                });
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(e.into()),
        }
    }
    pool.join();
    Ok(())
}

fn handle_connection(svc: &UnlearningService, stream: TcpStream) -> anyhow::Result<()> {
    stream.set_nodelay(true)?;
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = match parse(&line) {
            Ok(req) => svc.handle(&req),
            Err(e) => api::encode_response(&Response::Err(ApiError::BadRequest(format!(
                "bad request: {e}"
            )))),
        };
        writer.write_all(resp.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
        if svc.is_shutdown() {
            break;
        }
    }
    Ok(())
}

/// A successful `predict` response: probabilities plus the engine that
/// served them (`"pjrt"` or `"native"`).
#[derive(Clone, Debug, PartialEq)]
pub struct Prediction {
    pub probs: Vec<f32>,
    pub engine: String,
}

/// Blocking JSON-lines client with typed v1 methods.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    pub fn connect<A: ToSocketAddrs>(addr: A) -> anyhow::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
        })
    }

    /// Send one raw request object and read one response (any version).
    pub fn call(&mut self, req: &Value) -> anyhow::Result<Value> {
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        anyhow::ensure!(!line.is_empty(), "server closed connection");
        parse(&line).map_err(|e| anyhow::anyhow!("{e}"))
    }

    /// Send one typed v1 request; decode failure outcomes into [`ApiError`].
    fn request(&mut self, model: &str, op: Op) -> Result<Value, ApiError> {
        let wire = api::encode_request(&Request {
            v: WIRE_VERSION,
            model: model.to_string(),
            op,
        });
        let resp = self.call(&wire).map_err(|e| ApiError::Transport(format!("{e}")))?;
        if resp.get("ok").and_then(Value::as_bool) == Some(true) {
            Ok(resp)
        } else {
            Err(api::error_from_wire(&resp))
        }
    }

    fn field_u64(resp: &Value, key: &str) -> Result<u64, ApiError> {
        resp.get(key)
            .and_then(Value::as_u64)
            .ok_or_else(|| ApiError::Transport(format!("response missing '{key}'")))
    }

    /// Positive-class probabilities for `rows` from `model`.
    pub fn predict(&mut self, model: &str, rows: &[Vec<f32>]) -> Result<Prediction, ApiError> {
        let resp = self.request(
            model,
            Op::Predict {
                rows: rows.to_vec(),
            },
        )?;
        let probs = resp
            .get("probs")
            .and_then(Value::as_arr)
            .ok_or_else(|| ApiError::Transport("response missing 'probs'".to_string()))?
            .iter()
            .map(|p| p.as_f64().unwrap_or(0.0) as f32)
            .collect();
        Ok(Prediction {
            probs,
            engine: resp.get("engine").and_then(Value::as_str).unwrap_or("?").to_string(),
        })
    }

    /// Unlearn `ids` from `model` (grouped with concurrent requests by the
    /// server's deletion batcher).
    pub fn delete(&mut self, model: &str, ids: &[InstanceId]) -> Result<DeleteOutcome, ApiError> {
        let resp = self.request(model, Op::Delete { ids: ids.to_vec() })?;
        let deleted = Self::field_u64(&resp, "deleted")? as usize;
        let skipped = Self::field_u64(&resp, "skipped")? as usize;
        Ok(DeleteOutcome {
            requested: deleted + skipped,
            deleted,
            skipped,
            retrain_cost: Self::field_u64(&resp, "retrain_cost")?,
            deferred: Self::field_u64(&resp, "deferred")? as usize,
            batch_size: Self::field_u64(&resp, "batch_size")? as usize,
        })
    }

    /// Add one training instance to `model`; returns its id.
    pub fn add(&mut self, model: &str, row: &[f32], label: u8) -> Result<InstanceId, ApiError> {
        let resp = self.request(
            model,
            Op::Add {
                row: row.to_vec(),
                label,
            },
        )?;
        Ok(Self::field_u64(&resp, "id")? as InstanceId)
    }

    /// Dry-run retrain cost of deleting `id` from `model`.
    pub fn delete_cost(&mut self, model: &str, id: InstanceId) -> Result<u64, ApiError> {
        let resp = self.request(model, Op::DeleteCost { id })?;
        Self::field_u64(&resp, "cost")
    }

    /// The model's full stats payload (telemetry, shards, backlog, bytes).
    pub fn stats(&mut self, model: &str) -> Result<Value, ApiError> {
        self.request(model, Op::Stats)
    }

    /// Execute every deferred retrain of `model`; returns how many ran.
    pub fn flush(&mut self, model: &str) -> Result<u64, ApiError> {
        let resp = self.request(model, Op::Flush)?;
        Self::field_u64(&resp, "flushed")
    }

    /// Drain up to `budget` deferred retrains per tree of `model`.
    pub fn compact(&mut self, model: &str, budget: usize) -> Result<u64, ApiError> {
        let resp = self.request(model, Op::Compact { budget })?;
        Self::field_u64(&resp, "flushed")
    }

    /// Snapshot `model` (with its training database) to a server-side path.
    pub fn save(&mut self, model: &str, path: &str) -> Result<(), ApiError> {
        self.request(
            model,
            Op::Save {
                path: path.to_string(),
            },
        )
        .map(|_| ())
    }

    /// Install a server-side snapshot as a new model named `model`.
    pub fn load(&mut self, model: &str, path: &str) -> Result<(), ApiError> {
        self.request(
            model,
            Op::Load {
                path: path.to_string(),
            },
        )
        .map(|_| ())
    }

    /// Train and register a new model named `model` from a corpus dataset.
    pub fn create(&mut self, model: &str, spec: CreateSpec) -> Result<(), ApiError> {
        self.request(model, Op::Create(spec)).map(|_| ())
    }

    /// Unregister `model`.
    pub fn drop_model(&mut self, model: &str) -> Result<(), ApiError> {
        self.request(model, Op::DropModel).map(|_| ())
    }

    /// Request a signed deletion certificate for an already-deleted
    /// instance of `model` (requires the server to run with a WAL dir).
    pub fn certify(&mut self, model: &str, id: InstanceId) -> Result<Certificate, ApiError> {
        let resp = self.request(model, Op::Certify { id })?;
        let cert = resp
            .get("cert")
            .ok_or_else(|| ApiError::Transport("response missing 'cert'".to_string()))?;
        Certificate::from_wire(cert)
            .map_err(|e| ApiError::Transport(format!("malformed cert in response: {e}")))
    }

    /// Check a deletion certificate against the server's signing key.
    /// Model-independent: works even after the certified model is dropped.
    pub fn verify_cert(&mut self, cert: &Certificate) -> Result<bool, ApiError> {
        let resp = self.request(
            api::DEFAULT_MODEL,
            Op::VerifyCert { cert: cert.clone() },
        )?;
        resp.get("valid")
            .and_then(Value::as_bool)
            .ok_or_else(|| ApiError::Transport("response missing 'valid'".to_string()))
    }

    /// Summaries of every registered model.
    pub fn list(&mut self) -> Result<Vec<ModelSummary>, ApiError> {
        let resp = self.request(api::DEFAULT_MODEL, Op::List)?;
        Ok(resp
            .get("models")
            .and_then(Value::as_arr)
            .unwrap_or(&[])
            .iter()
            .map(ModelSummary::from_wire)
            .collect())
    }

    /// Stop the server's accept loop.
    pub fn shutdown(&mut self) -> Result<(), ApiError> {
        self.request(api::DEFAULT_MODEL, Op::Shutdown).map(|_| ())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::{ServiceConfig, UnlearningService};
    use crate::data::synth::{generate, SynthSpec};
    use crate::forest::forest::DareForest;
    use crate::forest::params::Params;

    fn spawn_server() -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
        let d = generate(
            &SynthSpec {
                n: 150,
                informative: 3,
                redundant: 0,
                noise: 1,
                flip: 0.05,
                ..Default::default()
            },
            2,
        );
        let f = DareForest::fit(
            d,
            &Params {
                n_trees: 3,
                max_depth: 5,
                k: 5,
                ..Default::default()
            },
            1,
        );
        let svc = UnlearningService::new(
            f,
            ServiceConfig {
                use_pjrt: false,
                ..Default::default()
            },
        );
        let (tx, rx) = std::sync::mpsc::channel();
        let handle = std::thread::spawn(move || {
            serve(svc, "127.0.0.1:0", 2, move |addr| {
                tx.send(addr).unwrap();
            })
            .unwrap();
        });
        (rx.recv().unwrap(), handle)
    }

    #[test]
    fn tcp_roundtrip_and_shutdown() {
        let (addr, handle) = spawn_server();
        let mut c = Client::connect(addr).unwrap();

        // typed stats
        let r = c.stats("default").unwrap();
        assert_eq!(r.get("n_alive").unwrap().as_u64(), Some(150));
        // sharded store surfaces its shape over the wire
        let n_shards = r.get("n_shards").unwrap().as_u64().unwrap();
        assert!(n_shards >= 1);
        assert_eq!(r.get("shards").unwrap().as_arr().unwrap().len() as u64, n_shards);

        // typed delete
        let out = c.delete("default", &[1, 2]).unwrap();
        assert_eq!(out.deleted, 2);
        assert_eq!(out.skipped, 0);

        // a raw v0 request still works over the same connection
        let r = c.call(&parse(r#"{"op":"stats"}"#).unwrap()).unwrap();
        assert_eq!(r.get("n_alive").unwrap().as_u64(), Some(148));

        // typed errors cross the wire intact
        match c.call(&parse(r#"{"op":"bogus"}"#).unwrap()) {
            Ok(r) => {
                assert_eq!(r.get("ok").unwrap().as_bool(), Some(false));
                assert_eq!(
                    r.get("error").unwrap().get("code").unwrap().as_str(),
                    Some("bad_request")
                );
            }
            Err(e) => panic!("raw call should surface the error object: {e}"),
        }
        match c.delete_cost("default", 999_999) {
            Err(ApiError::UnknownId(id)) => assert_eq!(id, 999_999),
            other => panic!("expected UnknownId, got {other:?}"),
        }
        match c.stats("ghost") {
            Err(ApiError::UnknownModel(m)) => assert_eq!(m, "ghost"),
            other => panic!("expected UnknownModel, got {other:?}"),
        }

        c.shutdown().unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn certify_and_verify_over_tcp() {
        let root = std::env::temp_dir().join(format!("dare-proto-wal-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let d = generate(
            &SynthSpec {
                n: 120,
                informative: 3,
                redundant: 0,
                noise: 1,
                flip: 0.05,
                ..Default::default()
            },
            4,
        );
        let f = DareForest::fit(
            d,
            &Params {
                n_trees: 3,
                max_depth: 5,
                k: 5,
                ..Default::default()
            },
            6,
        );
        let svc = UnlearningService::new(
            f,
            ServiceConfig {
                use_pjrt: false,
                wal_dir: Some(root.clone()),
                cert_key: Some("tcp-test-key".to_string()),
                ..Default::default()
            },
        );
        let (tx, rx) = std::sync::mpsc::channel();
        let handle = std::thread::spawn(move || {
            serve(svc, "127.0.0.1:0", 2, move |addr| {
                tx.send(addr).unwrap();
            })
            .unwrap();
        });
        let addr = rx.recv().unwrap();
        let mut c = Client::connect(addr).unwrap();

        // live instance: typed bad_request before deletion...
        match c.certify("default", 7) {
            Err(ApiError::BadRequest(_)) => {}
            other => panic!("expected BadRequest for a live instance, got {other:?}"),
        }
        // ...then a verifiable certificate after
        c.delete("default", &[7]).unwrap();
        let cert = c.certify("default", 7).unwrap();
        assert_eq!(cert.instance_id, 7);
        assert_eq!(cert.model, "default");
        assert!(c.verify_cert(&cert).unwrap());
        let mut forged = cert.clone();
        forged.epoch += 1;
        assert!(!c.verify_cert(&forged).unwrap());

        c.shutdown().unwrap();
        handle.join().unwrap();
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn concurrent_clients() {
        let (addr, handle) = spawn_server();
        let mut handles = Vec::new();
        for i in 0..4u32 {
            handles.push(std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                let out = c.delete("default", &[10 + i]).unwrap();
                assert_eq!(out.deleted, 1);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let mut c = Client::connect(addr).unwrap();
        let r = c.stats("default").unwrap();
        assert_eq!(r.get("n_alive").unwrap().as_u64(), Some(146));
        c.shutdown().unwrap();
        handle.join().unwrap();
    }
}
