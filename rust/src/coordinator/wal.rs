//! Event-sourced durability for served models (DESIGN.md §11): a per-model
//! write-ahead op log, periodic snapshots with log truncation, crash
//! recovery by replay, and signed deletion certificates.
//!
//! **Layout.** Each durable model owns one directory under the service's
//! WAL root:
//!
//! ```text
//! <wal_root>/<dir_name(model)>/
//!     name.txt        exact model name (the directory name is sanitized)
//!     snapshot.json   forest snapshot + "wal_epoch" (the epoch it captures)
//!     wal.log         header + framed op records past that epoch
//! ```
//!
//! **Log format.** The log opens with a 16-byte header — the magic
//! `DAREWAL1` then the base epoch as u64 LE — followed by records:
//!
//! ```text
//! [u32 LE payload_len][u32 LE crc32(payload)][payload]
//! payload = [u64 LE epoch][v1 wire-codec request JSON]
//! ```
//!
//! Records reuse the PR-5 wire codec ([`api::encode_request`]) verbatim, so
//! the log is greppable JSON and replay is the same decode path the server
//! already property-tests. Epochs are assigned under the WAL mutex and
//! increase by exactly 1 per record; within one log file they form the
//! contiguous range `base+1 ..= base+n`.
//!
//! **Durability contract.** Every mutating op goes through [`Wal::logged`],
//! which holds the WAL mutex across *append (+fsync per policy) → apply*.
//! The client ack happens after `logged` returns, so an acked op is always
//! on disk before it is visible — and log order equals apply order, which
//! is what makes replay byte-exact (retrains are path-seeded pure functions
//! of the op sequence; see DESIGN.md §6/§9). Flush/compact are *not*
//! logged: they change no logical state, and flush-order invariance means
//! replaying eagerly reproduces the bits of any live policy after a drain.
//!
//! **Recovery** ([`Wal::recover`]) loads the snapshot, then replays the
//! longest valid prefix of the log: reading stops at the first record with
//! a short frame, an insane length, a CRC mismatch, or a non-consecutive
//! epoch; the file is truncated to that prefix so a torn tail can never
//! corrupt later appends. Records with `epoch <= snapshot.wal_epoch` are
//! skipped — that filter is what makes the snapshot-then-truncate dance
//! crash-safe at every intermediate point.

use crate::coordinator::api::{self, Certificate, Op, Request, WIRE_VERSION};
use crate::data::dataset::InstanceId;
use crate::forest::forest::DareForest;
use crate::forest::serialize::{forest_from_json, forest_to_json};
use crate::util::fsio::{atomic_write, fsync_dir};
use crate::util::hash::{crc32, ct_eq, hmac_sha256, sha256, to_hex};
use crate::util::json::{parse, Value};
use std::fs::{File, OpenOptions};
use std::io::{self, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::{Duration, Instant};

const MAGIC: &[u8; 8] = b"DAREWAL1";
const HEADER_LEN: u64 = 16;
/// Upper bound on one record's payload; anything larger is treated as
/// corruption (the largest real op is a bulk delete, far below this).
const MAX_RECORD: u32 = 256 * 1024 * 1024;

pub const SNAPSHOT_FILE: &str = "snapshot.json";
pub const LOG_FILE: &str = "wal.log";
pub const NAME_FILE: &str = "name.txt";

/// The development-default certificate key, used when neither the config
/// nor `DARE_HMAC_KEY` provides one. It is public by construction —
/// certificates signed with it prove nothing; production deployments must
/// set a real key.
pub const DEV_CERT_KEY: &str = "dare-dev-insecure-cert-key";

/// Resolve the certificate HMAC key: explicit config, then the
/// `DARE_HMAC_KEY` environment variable, then the (insecure) dev default.
pub fn resolve_key(explicit: Option<&str>) -> Vec<u8> {
    match explicit {
        Some(k) => k.as_bytes().to_vec(),
        None => std::env::var("DARE_HMAC_KEY")
            .map(String::into_bytes)
            .unwrap_or_else(|_| DEV_CERT_KEY.as_bytes().to_vec()),
    }
}

/// When appended records are fsync'd.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// fsync before every ack (full durability; the default).
    EveryOp,
    /// fsync every Nth record — up to N-1 acked ops can be lost to a
    /// *power* failure (never to a process crash: the OS still has the
    /// writes).
    EveryN(u32),
    /// fsync when this much time has passed since the last sync.
    Interval(Duration),
}

impl FsyncPolicy {
    /// Parse `"every_op" | "every:<n>" | "interval_ms:<ms>"`.
    pub fn parse(s: &str) -> Option<FsyncPolicy> {
        let s = s.trim().to_ascii_lowercase();
        match s.as_str() {
            "every_op" | "everyop" | "always" => Some(FsyncPolicy::EveryOp),
            _ => {
                if let Some(n) = s.strip_prefix("every:") {
                    n.parse::<u32>().ok().filter(|n| *n > 0).map(FsyncPolicy::EveryN)
                } else if let Some(ms) = s.strip_prefix("interval_ms:") {
                    ms.parse::<u64>().ok().map(|ms| FsyncPolicy::Interval(Duration::from_millis(ms)))
                } else {
                    None
                }
            }
        }
    }
}

impl std::fmt::Display for FsyncPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FsyncPolicy::EveryOp => write!(f, "every_op"),
            FsyncPolicy::EveryN(n) => write!(f, "every:{n}"),
            FsyncPolicy::Interval(d) => write!(f, "interval_ms:{}", d.as_millis()),
        }
    }
}

/// Map a model name to its directory name: names are user-supplied
/// (1..=128 arbitrary bytes), so the printable-safe characters survive and
/// everything else becomes `_`, with a crc32 suffix disambiguating names
/// that sanitize identically. The exact name round-trips via `name.txt`.
pub fn dir_name(model: &str) -> String {
    let safe: String = model
        .chars()
        .take(40)
        .map(|c| if c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-') { c } else { '_' })
        .collect();
    format!("{safe}-{:08x}", crc32(model.as_bytes()))
}

fn header_bytes(base_epoch: u64) -> Vec<u8> {
    let mut h = Vec::with_capacity(HEADER_LEN as usize);
    h.extend_from_slice(MAGIC);
    h.extend_from_slice(&base_epoch.to_le_bytes());
    h
}

fn record_bytes(epoch: u64, json: &[u8]) -> Vec<u8> {
    let mut payload = Vec::with_capacity(8 + json.len());
    payload.extend_from_slice(&epoch.to_le_bytes());
    payload.extend_from_slice(json);
    let mut rec = Vec::with_capacity(8 + payload.len());
    rec.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    rec.extend_from_slice(&crc32(&payload).to_le_bytes());
    rec.extend_from_slice(&payload);
    rec
}

/// One decoded log record.
#[derive(Clone, Debug)]
pub struct LogRecord {
    pub epoch: u64,
    pub request: Request,
}

/// A window of log records cut for replication (`pull_log`, DESIGN.md §12).
#[derive(Clone, Debug)]
pub struct PullBatch {
    /// Records with `epoch > after_epoch`, in log (= apply) order.
    pub records: Vec<LogRecord>,
    /// Epoch of the leader's last durably-logged op when the window was
    /// cut; always ≥ the last record's epoch, so `leader_epoch - applied`
    /// is a sound lag measure on the follower.
    pub leader_epoch: u64,
    /// Base epoch of the on-disk log. Records at or below it live only in
    /// the snapshot.
    pub base_epoch: u64,
    /// True when `after_epoch < base_epoch`: the requested records were
    /// truncated into a snapshot, so tailing cannot continue — the
    /// follower must re-bootstrap from `pull_snapshot`.
    pub snapshot_needed: bool,
}

/// Parse the longest valid prefix of raw log bytes. Returns the records
/// and the byte length of that prefix (header included). Never errors:
/// any malformed tail — short frame, oversized length, CRC mismatch,
/// unparseable JSON, undecodable request, non-consecutive epoch — simply
/// ends the prefix. A bad header yields an empty log (prefix 0).
pub fn read_valid_prefix(bytes: &[u8]) -> (Vec<LogRecord>, u64, u64) {
    if bytes.len() < HEADER_LEN as usize || &bytes[..8] != MAGIC {
        return (Vec::new(), 0, 0);
    }
    let base_epoch = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
    let mut records = Vec::new();
    let mut off = HEADER_LEN as usize;
    let mut epoch = base_epoch;
    loop {
        if bytes.len() - off < 8 {
            break;
        }
        let len = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
        let crc = u32::from_le_bytes(bytes[off + 4..off + 8].try_into().unwrap());
        if len < 9 || len > MAX_RECORD || bytes.len() - off - 8 < len as usize {
            break;
        }
        let payload = &bytes[off + 8..off + 8 + len as usize];
        if crc32(payload) != crc {
            break;
        }
        let rec_epoch = u64::from_le_bytes(payload[..8].try_into().unwrap());
        if rec_epoch != epoch + 1 {
            break;
        }
        let Ok(json) = std::str::from_utf8(&payload[8..]) else {
            break;
        };
        let Ok(value) = parse(json) else {
            break;
        };
        let Ok(request) = api::decode(&value) else {
            break;
        };
        records.push(LogRecord {
            epoch: rec_epoch,
            request,
        });
        epoch = rec_epoch;
        off += 8 + len as usize;
    }
    (records, off as u64, base_epoch)
}

/// Apply one logged op to a forest during replay. Only `add` and `delete`
/// ever reach the log; both are deterministic given the op sequence
/// (dead-id deletes skip identically). Anything else in a decodable record
/// means the log was produced by something other than `Wal::logged`.
fn apply_record(forest: &mut DareForest, req: &Request) -> anyhow::Result<()> {
    match &req.op {
        Op::Delete { ids } => {
            forest.delete_batch(ids);
            Ok(())
        }
        Op::Add { row, label } => {
            anyhow::ensure!(
                row.len() == forest.data().n_features(),
                "logged add has arity {} but the model expects {}",
                row.len(),
                forest.data().n_features()
            );
            forest.add(row, *label);
            Ok(())
        }
        other => anyhow::bail!("unexpected op in wal: {other:?}"),
    }
}

/// Canonical byte string a certificate's HMAC covers.
fn cert_message(c: &Certificate) -> Vec<u8> {
    format!(
        "{}\0{}\0{}\0{}",
        c.model, c.instance_id, c.epoch, c.snapshot_hash
    )
    .into_bytes()
}

/// Sign `cert` (fills `hmac`) with the server key.
pub fn sign_certificate(key: &[u8], cert: &mut Certificate) {
    cert.hmac = to_hex(&hmac_sha256(key, &cert_message(cert)));
}

/// Check a certificate's signature (constant-time compare).
pub fn verify_certificate(key: &[u8], cert: &Certificate) -> bool {
    let expect = to_hex(&hmac_sha256(key, &cert_message(cert)));
    ct_eq(expect.as_bytes(), cert.hmac.as_bytes())
}

struct WalState {
    file: File,
    /// Epoch of the last durably-logged record.
    epoch: u64,
    since_sync: u64,
    last_sync: Instant,
    since_snapshot: u64,
    /// `(epoch, hex sha256 of the canonical forest snapshot at that
    /// epoch)` — certify requests at an unchanged epoch reuse it.
    cert_cache: Option<(u64, String)>,
    /// Set after an append/fsync error: the on-disk tail is unknown, so
    /// further appends could land after garbage and be silently dropped by
    /// the next recovery. All mutations are refused until restart.
    failed: bool,
}

/// One model's write-ahead log. All mutating ops funnel through
/// [`Wal::logged`]; the interior mutex makes log order equal apply order.
pub struct Wal {
    dir: PathBuf,
    model: String,
    fsync: FsyncPolicy,
    /// Snapshot + truncate after this many logged ops (0 = never).
    snapshot_every: u64,
    key: Vec<u8>,
    state: Mutex<WalState>,
}

/// What [`Wal::recover`] found on disk.
pub struct Recovered {
    pub name: String,
    pub forest: DareForest,
    pub wal: Wal,
    /// Log records replayed on top of the snapshot.
    pub replayed: u64,
}

impl Wal {
    /// Create a fresh durable model directory: exact name, epoch-0
    /// snapshot of `forest`, empty log. The forest must be fully flushed
    /// (fresh `fit`/`load` results are).
    pub fn create(
        root: &Path,
        model: &str,
        forest: &DareForest,
        fsync: FsyncPolicy,
        snapshot_every: u64,
        key: Vec<u8>,
    ) -> anyhow::Result<Wal> {
        Self::create_at(root, model, forest, 0, fsync, snapshot_every, key)
    }

    /// Like [`Wal::create`] but with the log based at `base_epoch`: a
    /// follower bootstrapping from a leader snapshot cut at epoch E
    /// journals onward from E, not from zero, so its local log holds the
    /// same `(epoch, record)` chain as the leader's (DESIGN.md §12).
    pub fn create_at(
        root: &Path,
        model: &str,
        forest: &DareForest,
        base_epoch: u64,
        fsync: FsyncPolicy,
        snapshot_every: u64,
        key: Vec<u8>,
    ) -> anyhow::Result<Wal> {
        let dir = root.join(dir_name(model));
        std::fs::create_dir_all(&dir)?;
        atomic_write(&dir.join(NAME_FILE), model.as_bytes())?;
        let json = forest_to_json(forest);
        let hash = to_hex(&sha256(json.as_bytes()));
        write_snapshot_file(&dir, &json, base_epoch)?;
        atomic_write(&dir.join(LOG_FILE), &header_bytes(base_epoch))?;
        fsync_dir(root)?;
        let file = OpenOptions::new().append(true).open(dir.join(LOG_FILE))?;
        Ok(Wal {
            dir,
            model: model.to_string(),
            fsync,
            snapshot_every,
            key,
            state: Mutex::new(WalState {
                file,
                epoch: base_epoch,
                since_sync: 0,
                last_sync: Instant::now(),
                since_snapshot: 0,
                cert_cache: Some((base_epoch, hash)),
                failed: false,
            }),
        })
    }

    /// Recover a model directory written by a previous process: load the
    /// snapshot, replay the valid log prefix past its epoch, truncate any
    /// torn tail, and reopen the log for append. Errors (unreadable or
    /// invalid snapshot) are structured; corruption in the *log* is never
    /// an error — the valid-prefix rule absorbs it.
    pub fn recover(
        root: &Path,
        dir: &str,
        fsync: FsyncPolicy,
        snapshot_every: u64,
        key: Vec<u8>,
    ) -> anyhow::Result<Recovered> {
        let dir = root.join(dir);
        let name = std::fs::read_to_string(dir.join(NAME_FILE))
            .map_err(|e| anyhow::anyhow!("unreadable {NAME_FILE}: {e}"))?;
        let snap_str = std::fs::read_to_string(dir.join(SNAPSHOT_FILE))
            .map_err(|e| anyhow::anyhow!("unreadable {SNAPSHOT_FILE}: {e}"))?;
        let snap_epoch = snapshot_epoch(&snap_str)?;
        let mut forest = forest_from_json(&snap_str)
            .map_err(|e| anyhow::anyhow!("invalid {SNAPSHOT_FILE}: {e}"))?;

        let mut log_bytes = Vec::new();
        match File::open(dir.join(LOG_FILE)) {
            Ok(mut f) => {
                f.read_to_end(&mut log_bytes)?;
            }
            // A missing log (crash between snapshot and log reset in an
            // older layout, or manual cleanup) is an empty log.
            Err(_) => {}
        }
        let (records, valid_len, _base) = read_valid_prefix(&log_bytes);
        let mut replayed = 0u64;
        let mut epoch = snap_epoch;
        for rec in &records {
            if rec.epoch <= snap_epoch {
                continue;
            }
            apply_record(&mut forest, &rec.request)?;
            epoch = rec.epoch;
            replayed += 1;
        }

        // Drop the torn tail (or recreate a missing/headerless log), then
        // reopen for append.
        if valid_len == 0 {
            atomic_write(&dir.join(LOG_FILE), &header_bytes(epoch))?;
        } else if (log_bytes.len() as u64) > valid_len {
            let f = OpenOptions::new().write(true).open(dir.join(LOG_FILE))?;
            f.set_len(valid_len)?;
            f.sync_all()?;
        }
        let file = OpenOptions::new().append(true).open(dir.join(LOG_FILE))?;

        let json = forest_to_json(&forest);
        let hash = to_hex(&sha256(json.as_bytes()));
        Ok(Recovered {
            name,
            forest,
            replayed,
            wal: Wal {
                dir,
                model: String::new(), // set by the caller via set_model
                fsync,
                snapshot_every,
                key,
                state: Mutex::new(WalState {
                    file,
                    epoch,
                    since_sync: 0,
                    last_sync: Instant::now(),
                    since_snapshot: replayed,
                    cert_cache: Some((epoch, hash)),
                    failed: false,
                }),
            },
        })
    }

    /// Set the model name records are stamped with (recovery constructs
    /// the `Wal` before the name is adopted by the registry).
    pub fn set_model(&mut self, name: &str) {
        self.model = name.to_string();
    }

    /// List model directories under a WAL root (anything containing a
    /// snapshot file; temp droppings and stray files are ignored).
    pub fn scan(root: &Path) -> Vec<String> {
        let Ok(rd) = std::fs::read_dir(root) else {
            return Vec::new();
        };
        let mut dirs: Vec<String> = rd
            .filter_map(|e| e.ok())
            .filter(|e| e.path().join(SNAPSHOT_FILE).is_file())
            .filter_map(|e| e.file_name().into_string().ok())
            .collect();
        dirs.sort();
        dirs
    }

    /// Epoch of the last durably-logged op.
    pub fn epoch(&self) -> u64 {
        self.state.lock().unwrap().epoch
    }

    /// The leader side of `pull_log` (DESIGN.md §12): records with
    /// `epoch > after_epoch`, capped at `max` (min 1). Reads the log file
    /// *without* the state mutex — appends never block on replication. A
    /// concurrently-appended torn tail is absorbed by the valid-prefix
    /// rule (the follower just asks again), and a concurrent
    /// snapshot+truncate swaps the file atomically, which the next call
    /// reports as `snapshot_needed` if it outran the follower. The
    /// leader's epoch is read *after* the file, so it bounds every
    /// returned record.
    pub fn read_records_after(&self, after_epoch: u64, max: usize) -> PullBatch {
        let mut bytes = Vec::new();
        if let Ok(mut f) = File::open(self.dir.join(LOG_FILE)) {
            let _ = f.read_to_end(&mut bytes);
        }
        let (records, _valid_len, base_epoch) = read_valid_prefix(&bytes);
        let leader_epoch = self.epoch();
        if after_epoch < base_epoch {
            return PullBatch {
                records: Vec::new(),
                leader_epoch,
                base_epoch,
                snapshot_needed: true,
            };
        }
        PullBatch {
            records: records
                .into_iter()
                .filter(|r| r.epoch > after_epoch)
                .take(max.max(1))
                .collect(),
            leader_epoch,
            base_epoch,
            snapshot_needed: false,
        }
    }

    /// The leader side of `pull_snapshot`: serialize `snap()` under the
    /// WAL mutex, so the returned `(epoch, json)` pair is cut at a single
    /// point in the op order — no mutation can land between reading the
    /// epoch and hashing the state. The hash also primes the certify
    /// cache for this epoch. The JSON carries no `wal_epoch` key; a
    /// bootstrapping follower splices its own via [`Wal::create_at`].
    pub fn snapshot_with_epoch(&self, snap: impl FnOnce() -> DareForest) -> (u64, String) {
        let mut st = self.state.lock().unwrap();
        let epoch = st.epoch;
        let json = forest_to_json(&snap());
        if !matches!(&st.cert_cache, Some((e, _)) if *e == epoch) {
            st.cert_cache = Some((epoch, to_hex(&sha256(json.as_bytes()))));
        }
        (epoch, json)
    }

    /// Remove a model's durability directory (the `drop` op: resurrecting
    /// a dropped tenant on restart would be the opposite of unlearning).
    pub fn remove_dir(root: &Path, model: &str) {
        let _ = std::fs::remove_dir_all(root.join(dir_name(model)));
        let _ = fsync_dir(root);
    }

    /// The durability gate every mutating op passes through: append the
    /// record (+fsync per policy), then run `apply`, all under the WAL
    /// mutex — so the log's record order is exactly the store's apply
    /// order, which replay then reproduces. After `snapshot_every` logged
    /// ops, `snap` is invoked (still under the mutex: the logical state
    /// cannot move) to write a fresh snapshot and truncate the log.
    ///
    /// An `Err` means nothing was applied and the op must not be acked;
    /// the WAL also latches into a failed state (see `WalState::failed`).
    pub fn logged<R>(
        &self,
        op: Op,
        apply: impl FnOnce() -> R,
        snap: impl FnOnce() -> DareForest,
    ) -> io::Result<R> {
        let mut st = self.state.lock().unwrap();
        if st.failed {
            return Err(io::Error::new(
                io::ErrorKind::Other,
                "wal is in a failed state; restart to recover",
            ));
        }
        let epoch = st.epoch + 1;
        let req = Request {
            v: WIRE_VERSION,
            model: self.model.clone(),
            op,
        };
        let json = api::encode_request(&req).to_string();
        let append = (|| -> io::Result<()> {
            st.file.write_all(&record_bytes(epoch, json.as_bytes()))?;
            st.since_sync += 1;
            let due = match self.fsync {
                FsyncPolicy::EveryOp => true,
                FsyncPolicy::EveryN(n) => st.since_sync >= n as u64,
                FsyncPolicy::Interval(d) => st.last_sync.elapsed() >= d,
            };
            if due {
                st.file.sync_data()?;
                st.since_sync = 0;
                st.last_sync = Instant::now();
            }
            Ok(())
        })();
        if let Err(e) = append {
            st.failed = true;
            return Err(e);
        }
        st.epoch = epoch;
        let out = apply();
        st.since_snapshot += 1;
        if self.snapshot_every > 0 && st.since_snapshot >= self.snapshot_every {
            // Snapshot failure is not fatal: the log still holds every op,
            // so recovery just replays a longer suffix.
            if let Err(e) = self.write_snapshot_locked(&mut st, &snap()) {
                eprintln!("wal[{}]: snapshot failed (log kept): {e}", self.model);
            }
        }
        Ok(out)
    }

    /// Snapshot the current state and truncate the log, outside the
    /// normal `snapshot_every` cadence (used by tests and shutdown paths).
    pub fn checkpoint(&self, forest: &DareForest) -> anyhow::Result<()> {
        let mut st = self.state.lock().unwrap();
        self.write_snapshot_locked(&mut st, forest)
    }

    fn write_snapshot_locked(&self, st: &mut WalState, forest: &DareForest) -> anyhow::Result<()> {
        let json = forest_to_json(forest);
        let hash = to_hex(&sha256(json.as_bytes()));
        write_snapshot_file(&self.dir, &json, st.epoch)?;
        // The snapshot is durable; any crash from here on replays zero or
        // more pre-snapshot records, all filtered by the epoch rule.
        atomic_write(&self.dir.join(LOG_FILE), &header_bytes(st.epoch))?;
        st.file = OpenOptions::new().append(true).open(self.dir.join(LOG_FILE))?;
        st.since_snapshot = 0;
        st.since_sync = 0;
        st.cert_cache = Some((st.epoch, hash));
        Ok(())
    }

    /// Issue a signed deletion certificate for `id` at the current epoch.
    /// The caller has verified `id` is a dead instance; dead ids are never
    /// resurrected (adds always mint fresh ids), so the statement stays
    /// true for every later epoch too. `snap` supplies the flushed state
    /// for the snapshot hash; it runs under the WAL mutex (no mutation can
    /// interleave) and is cached per epoch.
    pub fn certify(&self, id: InstanceId, snap: impl FnOnce() -> DareForest) -> Certificate {
        let mut st = self.state.lock().unwrap();
        let epoch = st.epoch;
        let hash = match &st.cert_cache {
            Some((e, h)) if *e == epoch => h.clone(),
            _ => {
                let h = to_hex(&sha256(forest_to_json(&snap()).as_bytes()));
                st.cert_cache = Some((epoch, h.clone()));
                h
            }
        };
        let mut cert = Certificate {
            model: self.model.clone(),
            instance_id: id,
            epoch,
            snapshot_hash: hash,
            hmac: String::new(),
        };
        sign_certificate(&self.key, &mut cert);
        cert
    }

    /// Verify a certificate against this WAL's key.
    pub fn verify(&self, cert: &Certificate) -> bool {
        verify_certificate(&self.key, cert)
    }
}

/// Read `wal_epoch` out of a snapshot file's JSON (stored as a string,
/// like every u64 in the snapshot schema; absent means 0).
fn snapshot_epoch(snap_str: &str) -> anyhow::Result<u64> {
    let v = parse(snap_str).map_err(|e| anyhow::anyhow!("invalid {SNAPSHOT_FILE}: {e}"))?;
    match v.get("wal_epoch") {
        None => Ok(0),
        Some(Value::Str(s)) => s
            .parse::<u64>()
            .map_err(|e| anyhow::anyhow!("bad wal_epoch: {e}")),
        Some(Value::Num(n)) => Ok(*n as u64),
        Some(_) => anyhow::bail!("bad wal_epoch type"),
    }
}

/// Write `snapshot.json` = the forest snapshot plus its WAL epoch,
/// atomically. The epoch is spliced as an extra top-level key;
/// `forest_from_json` ignores unknown keys, so the file remains a valid
/// `load`able snapshot.
fn write_snapshot_file(dir: &Path, forest_json: &str, epoch: u64) -> anyhow::Result<()> {
    let mut v = parse(forest_json).map_err(|e| anyhow::anyhow!("{e}"))?;
    v.set("wal_epoch", epoch.to_string());
    atomic_write(&dir.join(SNAPSHOT_FILE), v.to_string().as_bytes())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};
    use crate::forest::params::Params;

    fn forest(seed: u64) -> DareForest {
        let d = generate(
            &SynthSpec {
                n: 80,
                informative: 3,
                redundant: 0,
                noise: 1,
                flip: 0.05,
                ..Default::default()
            },
            seed,
        );
        DareForest::fit(
            d,
            &Params {
                n_trees: 2,
                max_depth: 4,
                k: 4,
                ..Default::default()
            },
            seed ^ 0x2a,
        )
    }

    fn temp_root(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("dare-wal-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn fsync_policy_parse_roundtrip() {
        for p in [
            FsyncPolicy::EveryOp,
            FsyncPolicy::EveryN(16),
            FsyncPolicy::Interval(Duration::from_millis(250)),
        ] {
            assert_eq!(FsyncPolicy::parse(&p.to_string()), Some(p));
        }
        assert_eq!(FsyncPolicy::parse("always"), Some(FsyncPolicy::EveryOp));
        assert_eq!(FsyncPolicy::parse("every:0"), None);
        assert_eq!(FsyncPolicy::parse("nope"), None);
    }

    #[test]
    fn dir_name_is_safe_and_distinct() {
        let a = dir_name("eu/prod model");
        assert!(a.chars().all(|c| c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-')));
        // names that sanitize identically stay distinct via the crc suffix
        assert_ne!(dir_name("a/b"), dir_name("a_b"));
        assert_eq!(dir_name("m"), dir_name("m"));
    }

    #[test]
    fn framing_roundtrip_and_valid_prefix() {
        let req = Request {
            v: 1,
            model: "m".to_string(),
            op: Op::Delete { ids: vec![1, 2, 3] },
        };
        let json = api::encode_request(&req).to_string();
        let mut bytes = header_bytes(5);
        bytes.extend_from_slice(&record_bytes(6, json.as_bytes()));
        bytes.extend_from_slice(&record_bytes(7, json.as_bytes()));
        let full_len = bytes.len() as u64;
        let (recs, len, base) = read_valid_prefix(&bytes);
        assert_eq!((recs.len(), len, base), (2, full_len, 5));
        assert_eq!(recs[0].epoch, 6);
        assert_eq!(recs[1].request, req);

        // torn tail: every truncation keeps a valid prefix
        let one_rec_len = HEADER_LEN + 8 + 8 + json.len() as u64;
        for cut in 0..bytes.len() {
            let (recs, len, _) = read_valid_prefix(&bytes[..cut]);
            let expect = if (cut as u64) >= one_rec_len * 2 - HEADER_LEN {
                2
            } else if (cut as u64) >= one_rec_len {
                1
            } else {
                0
            };
            assert_eq!(recs.len(), expect, "cut at {cut}");
            assert!(len <= cut as u64);
        }

        // epoch gap ends the prefix
        let mut gap = header_bytes(5);
        gap.extend_from_slice(&record_bytes(6, json.as_bytes()));
        gap.extend_from_slice(&record_bytes(8, json.as_bytes()));
        let (recs, _, _) = read_valid_prefix(&gap);
        assert_eq!(recs.len(), 1);

        // corrupt crc ends the prefix
        let mut bad = bytes.clone();
        let flip = bad.len() - 3;
        bad[flip] ^= 0xff;
        let (recs, _, _) = read_valid_prefix(&bad);
        assert_eq!(recs.len(), 1);

        // bad header: empty log
        let (recs, len, _) = read_valid_prefix(b"NOTAWAL!garbage");
        assert_eq!((recs.len(), len), (0, 0));
    }

    #[test]
    fn create_log_recover_roundtrip() {
        let root = temp_root("roundtrip");
        let f = forest(3);
        let p = f.data().n_features();
        let wal = Wal::create(&root, "m", &f, FsyncPolicy::EveryOp, 0, b"k".to_vec()).unwrap();

        // live side: apply + log the same ops
        let mut live = f.clone();
        wal.logged(
            Op::Delete { ids: vec![0, 3, 5] },
            || live.delete_batch(&[0, 3, 5]),
            || unreachable!("snapshot_every=0"),
        )
        .unwrap();
        wal.logged(
            Op::Add { row: vec![0.5; p], label: 1 },
            || live.add(&vec![0.5; p], 1),
            || unreachable!(),
        )
        .unwrap();
        wal.logged(
            Op::Delete { ids: vec![3, 7] }, // 3 already dead: skip must replay identically
            || live.delete_batch(&[3, 7]),
            || unreachable!(),
        )
        .unwrap();
        assert_eq!(wal.epoch(), 3);
        drop(wal);

        let rec = Wal::recover(&root, &dir_name("m"), FsyncPolicy::EveryOp, 0, b"k".to_vec()).unwrap();
        assert_eq!(rec.name, "m");
        assert_eq!(rec.replayed, 3);
        assert_eq!(rec.wal.epoch(), 3);
        assert_eq!(forest_to_json(&rec.forest), forest_to_json(&live));
    }

    #[test]
    fn snapshot_truncates_log_and_recovery_uses_epoch_filter() {
        let root = temp_root("snap");
        let f = forest(9);
        // snapshot every 2 ops
        let wal = Wal::create(&root, "m", &f, FsyncPolicy::EveryOp, 2, b"k".to_vec()).unwrap();
        let live = std::cell::RefCell::new(f.clone());
        for (i, ids) in [vec![0u32], vec![1], vec![2]].into_iter().enumerate() {
            wal.logged(
                Op::Delete { ids: ids.clone() },
                || live.borrow_mut().delete_batch(&ids),
                || live.borrow().clone(),
            )
            .unwrap();
            let _ = i;
        }
        // after 3 ops with snapshot_every=2: snapshot at epoch 2, log holds
        // only the epoch-3 record
        let dir = root.join(dir_name("m"));
        let log = std::fs::read(dir.join(LOG_FILE)).unwrap();
        let (recs, _, base) = read_valid_prefix(&log);
        assert_eq!(base, 2);
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].epoch, 3);
        assert_eq!(snapshot_epoch(&std::fs::read_to_string(dir.join(SNAPSHOT_FILE)).unwrap()).unwrap(), 2);
        drop(wal);

        let rec = Wal::recover(&root, &dir_name("m"), FsyncPolicy::EveryOp, 2, b"k".to_vec()).unwrap();
        assert_eq!(rec.replayed, 1);
        assert_eq!(forest_to_json(&rec.forest), forest_to_json(&live.borrow()));
    }

    #[test]
    fn certificates_sign_and_verify() {
        let root = temp_root("cert");
        let f = forest(5);
        let mut wal =
            Wal::create(&root, "m", &f, FsyncPolicy::EveryOp, 0, b"secret".to_vec()).unwrap();
        wal.set_model("m");
        let mut live = f.clone();
        wal.logged(Op::Delete { ids: vec![4] }, || live.delete_batch(&[4]), || unreachable!())
            .unwrap();
        let cert = wal.certify(4, || live.clone());
        assert_eq!(cert.epoch, 1);
        assert_eq!(cert.model, "m");
        assert_eq!(cert.snapshot_hash.len(), 64);
        assert!(wal.verify(&cert));
        assert!(verify_certificate(b"secret", &cert));
        // any tampering breaks the signature
        for tamper in [
            Certificate { instance_id: 5, ..cert.clone() },
            Certificate { epoch: 2, ..cert.clone() },
            Certificate { model: "m2".to_string(), ..cert.clone() },
            Certificate { snapshot_hash: format!("0{}", &cert.snapshot_hash[1..]), ..cert.clone() },
        ] {
            assert!(!verify_certificate(b"secret", &tamper), "{tamper:?}");
        }
        assert!(!verify_certificate(b"wrong-key", &cert));
        // the cached hash matches a fresh hash of the live state
        assert_eq!(
            cert.snapshot_hash,
            to_hex(&sha256(forest_to_json(&live).as_bytes()))
        );
    }

    #[test]
    fn pull_windows_filter_by_epoch_and_follow_truncation() {
        let root = temp_root("pull");
        let f = forest(11);
        let wal = Wal::create(&root, "m", &f, FsyncPolicy::EveryOp, 0, b"k".to_vec()).unwrap();
        let live = std::cell::RefCell::new(f.clone());
        for ids in [vec![0u32], vec![1], vec![2], vec![3]] {
            wal.logged(
                Op::Delete { ids: ids.clone() },
                || live.borrow_mut().delete_batch(&ids),
                || live.borrow().clone(),
            )
            .unwrap();
        }

        // the full window, then epoch filtering + the max cap
        let w = wal.read_records_after(0, 100);
        assert_eq!((w.leader_epoch, w.base_epoch, w.snapshot_needed), (4, 0, false));
        assert_eq!(w.records.len(), 4);
        assert_eq!(w.records[0].epoch, 1);
        let w = wal.read_records_after(2, 1);
        assert_eq!(w.records.len(), 1);
        assert_eq!(w.records[0].epoch, 3);
        // caught up: empty window, no snapshot demand
        assert!(wal.read_records_after(4, 8).records.is_empty());

        // snapshot + truncate: pre-base epochs now need a re-bootstrap
        wal.checkpoint(&live.borrow()).unwrap();
        let w = wal.read_records_after(1, 8);
        assert!(w.snapshot_needed);
        assert_eq!(w.base_epoch, 4);
        let w = wal.read_records_after(4, 8);
        assert!(!w.snapshot_needed);
        assert!(w.records.is_empty());

        // snapshot_with_epoch cuts at the current epoch, canonical bytes
        let (epoch, json) = wal.snapshot_with_epoch(|| live.borrow().clone());
        assert_eq!(epoch, 4);
        assert_eq!(json, forest_to_json(&live.borrow()));

        // a follower journal based at that epoch recovers to the same state
        let froot = temp_root("pull-follower");
        let fwal = Wal::create_at(
            &froot,
            "m",
            &forest_from_json(&json).unwrap(),
            epoch,
            FsyncPolicy::EveryOp,
            0,
            b"k".to_vec(),
        )
        .unwrap();
        assert_eq!(fwal.epoch(), 4);
        drop(fwal);
        let rec = Wal::recover(&froot, &dir_name("m"), FsyncPolicy::EveryOp, 0, b"k".to_vec()).unwrap();
        assert_eq!(rec.wal.epoch(), 4);
        assert_eq!(rec.replayed, 0);
        assert_eq!(forest_to_json(&rec.forest), json);
    }

    #[test]
    fn scan_ignores_stray_files() {
        let root = temp_root("scan");
        let f = forest(1);
        Wal::create(&root, "a", &f, FsyncPolicy::EveryOp, 0, b"k".to_vec()).unwrap();
        std::fs::write(root.join("stray.txt"), b"junk").unwrap();
        std::fs::create_dir_all(root.join("empty-dir")).unwrap();
        assert_eq!(Wal::scan(&root), vec![dir_name("a")]);
    }
}
