//! Deletion batcher: the coordinator's dynamic-batching stage. Each
//! registry model owns its own batcher (DESIGN.md §10), so one tenant's
//! deletion stream never queues behind another's.
//!
//! Deletions must serialize within a model (every DaRE tree contains every
//! instance, so a mutation touches all shards), but retraining a node at
//! most once per *batch* (paper §A.7) makes grouped deletions cheaper than
//! one-at-a-time processing. The batcher collects deletion requests that
//! arrive within a short window (or up to a max batch size) and applies
//! them back-to-back on the model's single mutation thread. Since the
//! sharded store (DESIGN.md §8) each application fans out across shard
//! locks internally — readers on other shards keep running while a batch
//! is applied. The worker stops when the batcher drops, i.e. when the last
//! handle to its model goes away (`drop` op or service teardown); a
//! request caught in that window surfaces as `ApiError::ShuttingDown`.

use crate::coordinator::api::Op;
use crate::coordinator::shards::ShardedForest;
use crate::coordinator::wal::Wal;
use crate::data::dataset::InstanceId;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Outcome of one deletion request.
#[derive(Clone, Debug)]
pub struct DeleteOutcome {
    pub requested: usize,
    pub deleted: usize,
    pub skipped: usize,
    /// Total retrain cost this request's deletions reported. Under a lazy
    /// policy (DESIGN.md §9) the costs are identical to the eager path's
    /// but the retrains themselves may still be pending — see `deferred`.
    pub retrain_cost: u64,
    /// Subtree retrains this request deferred instead of executing inline
    /// (0 under `LazyPolicy::Eager`; under `Budgeted` some may already have
    /// been drained again by the per-batch budget before the reply).
    pub deferred: usize,
    /// Requests that shared this batch (including this one).
    pub batch_size: usize,
}

struct Job {
    ids: Vec<InstanceId>,
    reply: Sender<DeleteOutcome>,
}

/// Handle for submitting deletion requests.
pub struct DeletionBatcher {
    tx: Sender<Job>,
    worker: Option<JoinHandle<()>>,
}

impl DeletionBatcher {
    /// Spawn the mutation thread. `window` bounds how long the first request
    /// in a batch waits for company; `max_batch` bounds batch size.
    pub fn start(
        forest: Arc<ShardedForest>,
        window: Duration,
        max_batch: usize,
    ) -> DeletionBatcher {
        Self::start_with_wal(forest, window, max_batch, None)
    }

    /// Like [`DeletionBatcher::start`], journaling every applied deletion
    /// job to the model's write-ahead log first (DESIGN.md §11).
    pub fn start_with_wal(
        forest: Arc<ShardedForest>,
        window: Duration,
        max_batch: usize,
        wal: Option<Arc<Wal>>,
    ) -> DeletionBatcher {
        let (tx, rx) = channel::<Job>();
        let worker = std::thread::Builder::new()
            .name("dare-batcher".into())
            .spawn(move || run_worker(forest, rx, window, max_batch, wal))
            .expect("spawn batcher");
        DeletionBatcher {
            tx,
            worker: Some(worker),
        }
    }

    /// Submit ids for deletion; blocks until the batch containing them has
    /// been applied and returns this request's outcome.
    pub fn delete(&self, ids: Vec<InstanceId>) -> anyhow::Result<DeleteOutcome> {
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(Job {
                ids,
                reply: reply_tx,
            })
            .map_err(|_| anyhow::anyhow!("batcher stopped"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("batcher dropped reply"))
    }
}

impl Drop for DeletionBatcher {
    fn drop(&mut self) {
        // Closing the channel stops the worker after it drains.
        let (tx, _) = channel();
        let _ = std::mem::replace(&mut self.tx, tx);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn run_worker(
    forest: Arc<ShardedForest>,
    rx: Receiver<Job>,
    window: Duration,
    max_batch: usize,
    wal: Option<Arc<Wal>>,
) {
    loop {
        // block for the first job
        let first = match rx.recv() {
            Ok(j) => j,
            Err(_) => return,
        };
        let mut jobs = vec![first];
        let mut total: usize = jobs[0].ids.len();
        let deadline = Instant::now() + window;
        // gather more jobs within the window / batch cap
        while total < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(j) => {
                    total += j.ids.len();
                    jobs.push(j);
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }

        // Apply the whole batch back-to-back. Request order within a batch
        // is arrival order, so the per-tree operation sequence — and hence
        // every RNG stream — is identical to handling the requests one by
        // one (DESIGN.md §6/§8).
        let batch_size = jobs.len();
        for job in jobs {
            let requested = job.ids.len();
            // The deferral count is measured per tree inside the mutation
            // (delete_batch_counted), so concurrent adds or compactor
            // ticks can never skew it — and under Eager it is 0 with no
            // extra counter sweep.
            //
            // With a WAL, each job is journaled (+fsync'd) immediately
            // before its application, under the WAL mutex — log order is
            // apply order, and the ack below never precedes durability. A
            // job whose append fails is *not* applied; dropping its reply
            // sender surfaces as a service-level error to that client.
            let applied = match &wal {
                None => Some(forest.delete_batch_counted(&job.ids)),
                Some(w) => match w.logged(
                    Op::Delete {
                        ids: job.ids.clone(),
                    },
                    || forest.delete_batch_counted(&job.ids),
                    || forest.snapshot(),
                ) {
                    Ok(r) => Some(r),
                    Err(e) => {
                        eprintln!("dare-batcher: wal append failed; refusing delete: {e}");
                        None
                    }
                },
            };
            let Some((report, skipped, deferred)) = applied else {
                drop(job.reply);
                continue;
            };
            let outcome = DeleteOutcome {
                requested,
                deleted: requested - skipped,
                skipped,
                retrain_cost: report.cost(),
                deferred: deferred as usize,
                batch_size,
            };
            let _ = job.reply.send(outcome);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};
    use crate::forest::forest::DareForest;
    use crate::forest::params::Params;

    fn forest(n: usize) -> Arc<ShardedForest> {
        let d = generate(
            &SynthSpec {
                n,
                informative: 3,
                redundant: 0,
                noise: 2,
                flip: 0.05,
                ..Default::default()
            },
            5,
        );
        Arc::new(ShardedForest::new(
            DareForest::fit(
                d,
                &Params {
                    n_trees: 3,
                    max_depth: 5,
                    k: 5,
                    ..Default::default()
                },
                9,
            ),
            2,
        ))
    }

    #[test]
    fn single_request_applies() {
        let f = forest(150);
        let b = DeletionBatcher::start(Arc::clone(&f), Duration::from_millis(5), 64);
        let out = b.delete(vec![0, 1, 2]).unwrap();
        assert_eq!(out.deleted, 3);
        assert_eq!(out.skipped, 0);
        assert_eq!(f.n_alive(), 147);
    }

    #[test]
    fn dead_ids_skipped() {
        let f = forest(100);
        let b = DeletionBatcher::start(Arc::clone(&f), Duration::from_millis(1), 64);
        b.delete(vec![5]).unwrap();
        let out = b.delete(vec![5, 6]).unwrap();
        assert_eq!(out.deleted, 1);
        assert_eq!(out.skipped, 1);
    }

    #[test]
    fn concurrent_requests_batch_together() {
        let f = forest(300);
        let b = Arc::new(DeletionBatcher::start(
            Arc::clone(&f),
            Duration::from_millis(50),
            1024,
        ));
        let mut handles = Vec::new();
        for i in 0..8u32 {
            let b = Arc::clone(&b);
            handles.push(std::thread::spawn(move || {
                b.delete(vec![i * 10, i * 10 + 1]).unwrap()
            }));
        }
        let outcomes: Vec<DeleteOutcome> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(outcomes.iter().map(|o| o.deleted).sum::<usize>(), 16);
        assert_eq!(f.n_alive(), 284);
        f.validate().unwrap();
        // at least some requests should have shared a batch
        assert!(
            outcomes.iter().any(|o| o.batch_size > 1),
            "window should group concurrent requests"
        );
    }

    #[test]
    fn drop_stops_worker() {
        let f = forest(50);
        let b = DeletionBatcher::start(f, Duration::from_millis(1), 8);
        drop(b); // must not hang
    }
}
