//! Sharded forest ownership (DESIGN.md §8): the coordinator's store.
//!
//! The tree vector of a [`DareForest`] is partitioned into `S` contiguous
//! shards. Each shard owns its tree subset behind its **own** `RwLock` and
//! carries a mutation-epoch counter, so
//!
//! - reads (predict, delete_cost, stats) take per-shard *read* locks and
//!   proceed concurrently with each other and with mutations of *other*
//!   shards — no global forest lock exists anymore;
//! - mutations fan out across shards and run concurrently with each other
//!   *within* one logical operation (each shard worker holds only its own
//!   write lock);
//! - snapshot consumers (the PJRT predictor refresh) compare per-shard
//!   epochs and re-tensorize only shards that actually mutated.
//!
//! **Bit-exactness with the unsharded path.** Nothing about the model
//! changes: tree seeds stay keyed by *global* tree index
//! ([`crate::forest::forest::tree_seed`]), per-tree update epochs live in
//! the trees themselves, and every mutation applies the same per-tree
//! operation sequence in the same order as `DareForest::delete_batch` /
//! `add` (tree updates never read the liveness mask, see DESIGN.md §6), so
//! all Lemma-A.1 RNG streams are identical. Prediction gathers per-shard,
//! per-tree leaf-value partials and reduces them in global tree order —
//! the exact f32 accumulation sequence of `DareForest::predict_proba` — so
//! probabilities are bit-identical, not merely close. `tests/op_fuzz.rs`
//! enforces all of this against the boxed oracle and the arena path.
//!
//! **Locking protocol.** Writers (delete/add) serialize on a store-level
//! mutation mutex (they would contend on every shard anyway — each DaRE
//! tree contains every instance) and bracket every mutation with a
//! seqlock-style epoch protocol: each *touched* shard's epoch is bumped to
//! *odd* before the first tree is touched and back to *even* after the
//! dataset is updated, so one mutation advances every touched epoch by 2.
//! At q=1.0 every shard is touched by every mutation; under Occ(q)
//! subsampling (DESIGN.md §13) only shards containing a tree that owns one
//! of the mutated instances move — untouched shards' trees provably cannot
//! change, so leaving their epochs still keeps optimistic readers and PJRT
//! snapshot diffing correct *and* cache-friendly. Readers that
//! must observe one consistent forest state (`predict_proba_rows`,
//! `delete_cost`) read the epoch vector before and after, retry when it
//! moved or was odd, and after a few failed attempts fall back to taking
//! the mutation mutex. Deadlock is impossible: at most one thread (the
//! mutation-mutex holder) ever acquires write locks, it never requests
//! another lock while holding the dataset write lock, and readers hold at
//! most one shard lock at a time.

use crate::data::dataset::{Dataset, InstanceId};
use crate::forest::delete::DeleteReport;
use crate::forest::forest::{
    accept_deletions, owns, shard_ranges, DareForest, ForestDeleteReport, PREDICT_BATCH_CUTOFF,
    PREDICT_BLOCK,
};
use crate::forest::lazy::LazyPolicy;
use crate::forest::node::NodeMemory;
use crate::forest::params::Params;
use crate::forest::tree::DareTree;
use crate::util::threadpool::scope_map;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, RwLock};

/// Attempts at an optimistic (epoch-validated) read before falling back to
/// the mutation mutex.
const READ_RETRIES: usize = 4;

/// One shard: a contiguous range of the forest's trees behind its own lock.
struct Shard {
    /// Trees with global indices `start..start + len`.
    trees: RwLock<Vec<DareTree>>,
    /// Global index of the first tree in this shard.
    start: usize,
    /// Tree count (fixed at construction) — readable without the lock, so
    /// mutation routing can size skipped-shard reports lock-free.
    len: usize,
    /// Seqlock epoch: odd while a mutation is in flight, +2 per mutation
    /// that changed this shard's trees (flushes bump only the shards they
    /// actually retrained, and Occ(q) mutations bump only shards with an
    /// owning tree, so PJRT re-tensorization stays dirty-shard-only).
    epoch: AtomicU64,
    /// Deferred retrains currently pending in this shard's trees — the
    /// fast-path signal read paths use to decide whether flushing is
    /// needed. Updated under the shard write lock after every mutation or
    /// flush.
    pending: AtomicU64,
}

impl Shard {
    /// Recompute `pending` from the trees; call with the write lock held.
    fn refresh_pending(&self, trees: &[DareTree]) {
        let p: u64 = trees.iter().map(|t| t.dirty_len() as u64).sum();
        self.pending.store(p, Ordering::SeqCst);
    }
}

/// The coordinator's sharded forest store. See the module docs.
pub struct ShardedForest {
    params: Params,
    seed: u64,
    n_trees: usize,
    data: RwLock<Dataset>,
    shards: Vec<Shard>,
    /// When deferred retrains run (DESIGN.md §9). Under a lazy policy the
    /// read paths route through the mutation mutex so they can flush the
    /// subtrees they descend into before serving.
    lazy: LazyPolicy,
    /// Serializes mutations (see module docs: every mutation touches every
    /// shard, so writer concurrency buys nothing and interleaved writer
    /// fan-outs could deadlock on the dataset lock).
    mutation: Mutex<()>,
    /// Per-tree seeds in global order — the Occ(q) ownership predicate's
    /// key (DESIGN.md §13), cached at construction so mutation routing can
    /// compute touched-shard masks without taking any shard lock.
    seeds: Vec<u64>,
    /// Cumulative (tree, instance) mutation pairs skipped because the tree
    /// does not own the instance (stats telemetry; always 0 at q=1.0).
    skipped_unowned: AtomicU64,
}

impl ShardedForest {
    /// Partition `forest` into at most `n_shards` shards (capped at the
    /// tree count so no shard is empty), retraining eagerly.
    pub fn new(forest: DareForest, n_shards: usize) -> Self {
        Self::new_with_policy(forest, n_shards, LazyPolicy::Eager)
    }

    /// [`ShardedForest::new`] with an explicit deferral policy.
    pub fn new_with_policy(forest: DareForest, n_shards: usize, lazy: LazyPolicy) -> Self {
        let (params, seed, mut trees, data) = forest.into_parts();
        // Adopting dirty trees under an Eager policy would strand their
        // pending retrains forever (no read path flushes under Eager):
        // drain them now, exactly like `DareForest::set_lazy_policy` does
        // on the lazy→eager transition. No-op on a clean forest.
        if !lazy.is_lazy() {
            for t in trees.iter_mut() {
                t.flush_all(&data, &params);
            }
        }
        let n_trees = trees.len();
        let seeds: Vec<u64> = trees.iter().map(|t| t.tree_seed).collect();
        let ranges = shard_ranges(n_trees, n_shards);
        let mut shards = Vec::with_capacity(ranges.len());
        // split_off from the back so each shard keeps its contiguous range
        for r in ranges.iter().rev() {
            let tail = trees.split_off(r.start);
            let pending: u64 = tail.iter().map(|t| t.dirty_len() as u64).sum();
            shards.push(Shard {
                len: tail.len(),
                trees: RwLock::new(tail),
                start: r.start,
                epoch: AtomicU64::new(0),
                pending: AtomicU64::new(pending),
            });
        }
        shards.reverse();
        ShardedForest {
            params,
            seed,
            n_trees,
            data: RwLock::new(data),
            shards,
            lazy,
            mutation: Mutex::new(()),
            seeds,
            skipped_unowned: AtomicU64::new(0),
        }
    }

    /// The store's deferral policy.
    pub fn lazy_policy(&self) -> LazyPolicy {
        self.lazy
    }

    /// Deferred retrains currently pending across all shards (fast:
    /// per-shard atomics, no locks).
    pub fn pending_retrains(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.pending.load(Ordering::SeqCst))
            .sum()
    }

    /// Cumulative (deferred, executed) retrain counters across all trees
    /// (telemetry; takes shard read locks).
    pub fn retrain_counters(&self) -> (u64, u64) {
        let mut deferred = 0u64;
        let mut flushed = 0u64;
        self.for_each_tree(|_, t| {
            deferred += t.deferred_retrains();
            flushed += t.flushed_retrains();
        });
        (deferred, flushed)
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }
    pub fn n_trees(&self) -> usize {
        self.n_trees
    }
    pub fn params(&self) -> &Params {
        &self.params
    }
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Occ(q) subsample fraction (1.0 = full ownership, the default).
    pub fn subsample_q(&self) -> f64 {
        self.params.q
    }

    /// Cumulative (tree, instance) mutation pairs skipped by non-ownership
    /// (stats telemetry; fast — a single atomic, no locks).
    pub fn unowned_skips(&self) -> u64 {
        self.skipped_unowned.load(Ordering::SeqCst)
    }

    /// Per-tree owned live-instance counts in global tree order (every
    /// entry equals `n_alive` at q=1.0). Computed from the cached seed
    /// vector and the liveness mask — no shard locks.
    pub fn ownership_counts(&self) -> Vec<u64> {
        let live = self.live_ids();
        if !self.params.subsampled() {
            return vec![live.len() as u64; self.n_trees];
        }
        self.seeds
            .iter()
            .map(|&ts| {
                live.iter()
                    .filter(|&&id| owns(ts, id, self.params.q))
                    .count() as u64
            })
            .collect()
    }

    /// Per-shard mutation epochs (index = shard id). Even = stable, odd =
    /// a mutation is in flight; one mutation advances every *touched*
    /// shard's epoch by 2 (all shards at q=1.0, owning shards only under
    /// Occ(q)). Snapshot consumers diff this against their last-seen
    /// vector to find dirty shards.
    pub fn shard_epochs(&self) -> Vec<u64> {
        self.shards.iter().map(|s| s.epoch.load(Ordering::SeqCst)).collect()
    }

    /// Shard routing for a mutation over `ids`: shard `s` is touched iff
    /// any of its trees owns any of the ids (Occ(q), DESIGN.md §13) — at
    /// q=1.0 this is the all-true mask with zero hashing, so the fan-out is
    /// byte-identical to the pre-Occ(q) store. Also returns the number of
    /// (tree, id) pairs the mutation will skip by non-ownership.
    fn touched_shards(&self, ids: &[InstanceId]) -> (Vec<bool>, u64) {
        if !self.params.subsampled() {
            return (vec![true; self.shards.len()], 0);
        }
        let q = self.params.q;
        let mut mask = vec![false; self.shards.len()];
        let mut skipped = 0u64;
        for (si, s) in self.shards.iter().enumerate() {
            for gt in s.start..s.start + s.len {
                for &id in ids {
                    if owns(self.seeds[gt], id, q) {
                        mask[si] = true;
                    } else {
                        skipped += 1;
                    }
                }
            }
        }
        (mask, skipped)
    }

    /// Seqlock write-side: flip the touched shards' epochs odd (mutation in
    /// flight). Caller must hold the mutation mutex. Untouched shards'
    /// epochs never move: their trees provably cannot change (every
    /// per-tree op gates on the same ownership predicate that built the
    /// mask), so PJRT snapshot consumers keep them cached.
    fn begin_mutation_masked(&self, touched: &[bool]) {
        for (s, &t) in self.shards.iter().zip(touched) {
            if t {
                s.epoch.fetch_add(1, Ordering::SeqCst);
            }
        }
    }

    /// Seqlock write-side: flip the touched shards' epochs back to even.
    fn end_mutation_masked(&self, touched: &[bool]) {
        for (s, &t) in self.shards.iter().zip(touched) {
            if t {
                s.epoch.fetch_add(1, Ordering::SeqCst);
            }
        }
    }

    /// Seqlock read-side: run `f` and return its result only if the epoch
    /// vector was even and unchanged across the run (i.e. `f` observed ONE
    /// forest state, not a mix of pre-/post-mutation shards). After
    /// [`READ_RETRIES`] failed attempts, serialize behind the mutation
    /// mutex instead of spinning.
    fn read_consistent<R>(&self, f: impl Fn() -> R) -> R {
        for _ in 0..READ_RETRIES {
            let before = self.shard_epochs();
            if before.iter().any(|e| e % 2 == 1) {
                std::thread::yield_now();
                continue;
            }
            let r = f();
            if self.shard_epochs() == before {
                return r;
            }
        }
        let _m = self.mutation.lock().unwrap();
        f()
    }

    /// Lazy-policy steady-state read: run `f` only if the epoch vector was
    /// even and unchanged across the run AND the deferred backlog was
    /// empty *inside* the validated window. The in-window pending check is
    /// sound because pending counters publish under the shard write locks
    /// before a mutation's epochs go even — a concurrent mark either shows
    /// up in the check or moves the epochs and fails the validation.
    /// Returns `None` when the caller must take the flushing (mutex) path.
    fn read_if_clean<R>(&self, f: impl Fn() -> R) -> Option<R> {
        for _ in 0..READ_RETRIES {
            let before = self.shard_epochs();
            if before.iter().any(|e| e % 2 == 1) {
                std::thread::yield_now();
                continue;
            }
            if self.pending_retrains() != 0 {
                return None;
            }
            let r = f();
            if self.shard_epochs() == before {
                return Some(r);
            }
        }
        None
    }

    /// Run `f` against the training database under the read lock.
    pub fn with_data<R>(&self, f: impl FnOnce(&Dataset) -> R) -> R {
        f(&self.data.read().unwrap())
    }

    pub fn n_alive(&self) -> usize {
        self.with_data(|d| d.n_alive())
    }

    pub fn n_features(&self) -> usize {
        self.with_data(|d| d.n_features())
    }

    pub fn live_ids(&self) -> Vec<InstanceId> {
        self.with_data(|d| d.live_ids())
    }

    /// Bytes of the training database (Table 3 "Data" column).
    pub fn data_bytes(&self) -> usize {
        self.with_data(|d| d.memory_bytes())
    }

    /// Run `f` over one shard's trees under its read lock. `f` receives the
    /// global index of the shard's first tree and the tree slice.
    pub fn with_shard_trees<R>(&self, shard: usize, f: impl FnOnce(usize, &[DareTree]) -> R) -> R {
        let s = &self.shards[shard];
        let trees = s.trees.read().unwrap();
        f(s.start, &trees)
    }

    /// Visit every tree in global index order (read locks, shard by shard).
    pub fn for_each_tree(&self, mut f: impl FnMut(usize, &DareTree)) {
        for s in &self.shards {
            let trees = s.trees.read().unwrap();
            for (k, t) in trees.iter().enumerate() {
                f(s.start + k, t);
            }
        }
    }

    /// Batch deletion, bit-exact with [`DareForest::delete_batch`]: same
    /// dedup/validation, same per-tree operation order and epochs, same
    /// merged per-tree reports (gathered back into global tree order) —
    /// only the locking and fan-out routing differ.
    pub fn delete_batch(&self, ids: &[InstanceId]) -> (ForestDeleteReport, usize) {
        let (report, skipped, _) = self.delete_batch_counted(ids);
        (report, skipped)
    }

    /// [`ShardedForest::delete_batch`] plus the number of subtree retrains
    /// THIS batch deferred (always 0 under `LazyPolicy::Eager`). Counted
    /// per tree inside the mutation, so concurrent adds / compactor ticks
    /// can never skew it — the batcher reports it per request.
    pub fn delete_batch_counted(&self, ids: &[InstanceId]) -> (ForestDeleteReport, usize, u64) {
        let _m = self.mutation.lock().unwrap();
        // Phase 1: validate and dedupe against the liveness mask (the
        // helper shared with `DareForest::delete_batch`, so the two paths
        // cannot diverge on accepted/skipped sets). No writer can
        // interleave (mutation mutex), so the mask is stable until the
        // mark-removed pass below.
        let (accepted, skipped) = {
            let d = self.data.read().unwrap();
            accept_deletions(&d, ids)
        };

        // An all-skipped batch mutates nothing — no marks, no budgeted
        // drain, no epoch movement (tree state may only change inside a
        // seqlock bracket or an epoch-bumping flush; DESIGN.md §9).
        if accepted.is_empty() {
            let per_tree = vec![DeleteReport::default(); self.n_trees];
            return (ForestDeleteReport { per_tree }, skipped, 0);
        }

        // Phase 2: fan the accepted sequence out to the shards that own any
        // of it; each worker holds only its shard's write lock (plus a
        // shared read lock on the immutable-row dataset). The seqlock
        // bracket makes the in-flight state visible to optimistic readers.
        let (touched, unowned) = self.touched_shards(&accepted);
        self.skipped_unowned.fetch_add(unowned, Ordering::SeqCst);
        self.begin_mutation_masked(&touched);
        let per_shard: Vec<(Vec<DeleteReport>, u64)> =
            scope_map(&self.shards, self.shards.len(), |si, shard| {
                // Occ(q): shards with no owning tree are skipped wholesale —
                // no lock, no epoch movement, default (empty) reports.
                if !touched[si] {
                    return (vec![DeleteReport::default(); shard.len], 0);
                }
                let mut trees = shard.trees.write().unwrap();
                let d = self.data.read().unwrap();
                let mut deferred = 0u64;
                let reports = trees
                    .iter_mut()
                    .map(|t| {
                        let before = t.deferred_retrains();
                        let mut merged = DeleteReport::default();
                        for &id in &accepted {
                            // A tree that never owned `id` skips the whole
                            // op — no statistics walk, no mark, and no
                            // budgeted drain (the unsharded `apply_delete`
                            // gates in the same place, so the two budget
                            // schedules cannot drift).
                            if !owns(t.tree_seed, id, self.params.q) {
                                continue;
                            }
                            merged.merge(&match self.lazy {
                                LazyPolicy::Eager => t.delete(&d, &self.params, id),
                                _ => t.mark_delete(&d, &self.params, id),
                            });
                            // Budgeted: drain up to k per *deletion* —
                            // the same schedule as the unsharded
                            // `DareForest::apply_delete`, so the two
                            // implementations of the policy cannot drift.
                            if let LazyPolicy::Budgeted(k) = self.lazy {
                                t.flush_budget(&d, &self.params, k);
                            }
                        }
                        deferred += t.deferred_retrains() - before;
                        merged
                    })
                    .collect();
                shard.refresh_pending(&trees);
                (reports, deferred)
            });

        // Phase 3: retire the instances and publish the new shard epochs.
        // Instances leave the corpus even when no tree owned them (liveness
        // is global); a zero-owner batch therefore moves no epochs — safe,
        // because non-owning trees contribute no state or cost for the ids.
        {
            let mut d = self.data.write().unwrap();
            for &id in &accepted {
                d.mark_removed(id);
            }
        }
        self.end_mutation_masked(&touched);
        let deferred: u64 = per_shard.iter().map(|(_, d)| d).sum();
        let per_tree: Vec<DeleteReport> = per_shard.into_iter().flat_map(|(r, _)| r).collect();
        (ForestDeleteReport { per_tree }, skipped, deferred)
    }

    /// Add a fresh training instance (§6), bit-exact with
    /// [`DareForest::add`]. Returns an error (instead of the unsharded
    /// path's assert) when the row arity is wrong.
    pub fn add(&self, row: &[f32], label: u8) -> anyhow::Result<InstanceId> {
        let _m = self.mutation.lock().unwrap();
        // Validate before the seqlock bracket so a rejected request leaves
        // the epochs untouched (n_features/label are immutable properties).
        {
            let d = self.data.read().unwrap();
            anyhow::ensure!(
                row.len() == d.n_features(),
                "row has {} features, model expects {}",
                row.len(),
                d.n_features()
            );
        }
        anyhow::ensure!(label <= 1, "label must be 0 or 1");
        // Prospective id: `push_row` assigns sequential ids, so the new
        // row's id is known before the bracket opens (the mutation mutex
        // keeps n_total stable here) — needed to route the fan-out to
        // owning shards only under Occ(q).
        let id = { self.data.read().unwrap().n_total() as InstanceId };
        let (touched, unowned) = self.touched_shards(std::slice::from_ref(&id));
        self.skipped_unowned.fetch_add(unowned, Ordering::SeqCst);
        // The dataset row must exist before the trees index it, so the
        // bracket opens before push_row — optimistic readers retry across
        // the whole window.
        self.begin_mutation_masked(&touched);
        let pushed = self.data.write().unwrap().push_row(row, label);
        debug_assert_eq!(pushed, id, "push_row ids must be sequential");
        scope_map(&self.shards, self.shards.len(), |si, shard| {
            if !touched[si] {
                return;
            }
            let mut trees = shard.trees.write().unwrap();
            let d = self.data.read().unwrap();
            for t in trees.iter_mut() {
                // Occ(q): the instance joins each tree with probability q
                // (same gate, including the budgeted-drain skip, as the
                // unsharded `apply_add`).
                if !owns(t.tree_seed, id, self.params.q) {
                    continue;
                }
                match self.lazy {
                    LazyPolicy::Eager => {
                        t.add(&d, &self.params, id);
                    }
                    _ => {
                        t.mark_add(&d, &self.params, id);
                    }
                }
                if let LazyPolicy::Budgeted(k) = self.lazy {
                    t.flush_budget(&d, &self.params, k);
                }
            }
            shard.refresh_pending(&trees);
        });
        self.end_mutation_masked(&touched);
        Ok(id)
    }

    /// Dry-run total retrain cost of deleting `id` across all trees.
    /// Read locks only in the common case; the epoch-validated retry
    /// guarantees the liveness check and every shard's costing observed
    /// the same forest state (a concurrent deletion of `id` yields the
    /// "not live" error, never a cost mixing pre-/post-delete shards).
    ///
    /// Under a lazy policy the cost is computed **as-if-flushed**: the
    /// query serializes on the mutation mutex, flushes the pending
    /// subtrees on `id`'s path, and costs the materialized trees — the
    /// value is bit-identical to the eager store's at this moment.
    pub fn delete_cost(&self, id: InstanceId) -> anyhow::Result<u64> {
        if self.lazy.is_lazy() {
            // Steady state (backlog drained): the lock-free §8 read path
            // (see [`ShardedForest::read_if_clean`]).
            if let Some(r) = self.read_if_clean(|| self.cost_eager(id)) {
                return r;
            }
            let _m = self.mutation.lock().unwrap();
            {
                let d = self.data.read().unwrap();
                anyhow::ensure!(
                    (id as usize) < d.n_total() && d.is_alive(id),
                    "instance {id} is not a live training instance"
                );
            }
            let per_shard = scope_map(&self.shards, self.shards.len(), |_, shard| {
                let mut trees = shard.trees.write().unwrap();
                let d = self.data.read().unwrap();
                let flushed_before: u64 = trees.iter().map(|t| t.flushed_retrains()).sum();
                let cost: u64 = trees
                    .iter_mut()
                    .map(|t| {
                        // Occ(q): a non-owning tree is costless for `id`
                        // and must not flush — its backlog is unrelated.
                        if owns(t.tree_seed, id, self.params.q) {
                            t.delete_cost_flushed(&d, &self.params, id)
                        } else {
                            0
                        }
                    })
                    .sum();
                let flushed_after: u64 = trees.iter().map(|t| t.flushed_retrains()).sum();
                if flushed_after != flushed_before {
                    shard.refresh_pending(&trees);
                    shard.epoch.fetch_add(2, Ordering::SeqCst);
                }
                cost
            });
            return Ok(per_shard.into_iter().sum());
        }
        self.read_consistent(|| self.cost_eager(id))
    }

    /// One read-locked costing pass over fully-flushed trees (the §8 read
    /// body); callers are responsible for consistency validation.
    fn cost_eager(&self, id: InstanceId) -> anyhow::Result<u64> {
        {
            let d = self.data.read().unwrap();
            anyhow::ensure!(
                (id as usize) < d.n_total() && d.is_alive(id),
                "instance {id} is not a live training instance"
            );
        }
        let per_shard = scope_map(&self.shards, self.shards.len(), |_, shard| {
            let trees = shard.trees.read().unwrap();
            let d = self.data.read().unwrap();
            trees
                .iter()
                .filter(|t| owns(t.tree_seed, id, self.params.q))
                .map(|t| t.delete_cost(&d, &self.params, id))
                .sum::<u64>()
        });
        Ok(per_shard.into_iter().sum())
    }

    /// Positive-class probability for one row (bit-exact with
    /// [`DareForest::predict_proba`]).
    pub fn predict_proba(&self, row: &[f32]) -> f32 {
        self.predict_proba_rows(std::slice::from_ref(&row.to_vec()))[0]
    }

    /// Batch prediction without any write lock: every shard computes its
    /// trees' per-row leaf values (level-synchronous
    /// [`crate::forest::arena::ArenaTree::predict_block_sum`] blocks at or
    /// above [`PREDICT_BATCH_CUTOFF`] rows, scalar descents below), and the
    /// partials are reduced in global tree order — the identical f32
    /// accumulation sequence as [`DareForest::predict_proba_rows`], hence
    /// bit-identical probabilities. The epoch-validated retry guarantees
    /// all shards were read at one forest state (never a pre-/post-delete
    /// mix).
    ///
    /// Parallelism note: the fan-out is one worker per shard (tree-level),
    /// not per row block — size `n_shards` to the cores you want the read
    /// path to use (the default, threadpool width, does this; only forests
    /// with fewer trees than cores are narrower).
    pub fn predict_proba_rows(&self, rows: &[Vec<f32>]) -> Vec<f32> {
        let n_rows = rows.len();
        if n_rows == 0 {
            return Vec::new();
        }
        if self.lazy.is_lazy() {
            // Steady state (compactor drained the backlog): the lock-free
            // §8 read path (see [`ShardedForest::read_if_clean`]).
            if let Some(partials) = self.read_if_clean(|| self.gather_partials(rows)) {
                return self.reduce_partials(&partials, n_rows);
            }
            // Flush-on-read (DESIGN.md §9): serialize on the mutation
            // mutex, materialize the pending subtrees every row descends
            // into (bumping only the epochs of shards that actually
            // flushed), then gather — the mutex excludes writers for the
            // whole request, so no retry is needed.
            let _m = self.mutation.lock().unwrap();
            self.flush_rows_locked(rows);
            let partials = self.gather_partials(rows);
            return self.reduce_partials(&partials, n_rows);
        }
        let partials: Vec<Vec<f32>> = self.read_consistent(|| self.gather_partials(rows));
        self.reduce_partials(&partials, n_rows)
    }

    /// Per shard: a (trees_in_shard × n_rows) flat plane of leaf values.
    /// `predict_block_sum` accumulates into zeroed slices, which yields
    /// plain leaf values — the same reuse the forest's block path gets.
    fn gather_partials(&self, rows: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let n_rows = rows.len();
        scope_map(&self.shards, self.shards.len(), |_, shard| {
            let trees = shard.trees.read().unwrap();
            let mut vals = vec![0.0f32; trees.len() * n_rows];
            let mut cursors: Vec<u32> = Vec::new();
            for (k, t) in trees.iter().enumerate() {
                let out = &mut vals[k * n_rows..(k + 1) * n_rows];
                if n_rows < PREDICT_BATCH_CUTOFF {
                    for (o, row) in out.iter_mut().zip(rows) {
                        *o = t.predict(row);
                    }
                } else {
                    for (b, chunk) in rows.chunks(PREDICT_BLOCK).enumerate() {
                        let lo = b * PREDICT_BLOCK;
                        t.arena.predict_block_sum(
                            chunk,
                            &mut cursors,
                            &mut out[lo..lo + chunk.len()],
                        );
                    }
                }
            }
            vals
        })
    }

    /// Reduce in global tree order: shards hold contiguous ascending
    /// ranges, so folding shard-by-shard, tree-by-tree replays the
    /// unsharded per-row sum exactly.
    fn reduce_partials(&self, partials: &[Vec<f32>], n_rows: usize) -> Vec<f32> {
        let mut sums = vec![0.0f32; n_rows];
        for vals in partials {
            for tree_vals in vals.chunks(n_rows) {
                for (s, v) in sums.iter_mut().zip(tree_vals) {
                    *s += *v;
                }
            }
        }
        let nt = self.n_trees as f32;
        for s in sums.iter_mut() {
            *s /= nt;
        }
        sums
    }

    /// Flush every pending subtree the given rows descend into, shard by
    /// shard over the threadpool. Caller must hold the mutation mutex.
    /// Shards that executed at least one retrain publish a new epoch (+2),
    /// so the PJRT snapshot re-tensorizes exactly the flushed shards.
    fn flush_rows_locked(&self, rows: &[Vec<f32>]) {
        if self.pending_retrains() == 0 {
            return;
        }
        scope_map(&self.shards, self.shards.len(), |_, shard| {
            if shard.pending.load(Ordering::SeqCst) == 0 {
                return;
            }
            let mut trees = shard.trees.write().unwrap();
            let d = self.data.read().unwrap();
            let mut flushed = 0u64;
            for t in trees.iter_mut() {
                let before = t.flushed_retrains();
                for row in rows {
                    t.flush_for_row(&d, &self.params, row);
                }
                flushed += t.flushed_retrains() - before;
            }
            if flushed > 0 {
                shard.refresh_pending(&trees);
                shard.epoch.fetch_add(2, Ordering::SeqCst);
            }
        });
    }

    /// Drain up to `k` deferred retrains per tree through the coordinator
    /// threadpool (the background compactor's unit of work; also the
    /// explicit `compact` escape hatch). Returns the number of retrains
    /// executed. Flush order cannot change any result — retrains are
    /// path-seeded (DESIGN.md §9) — so compaction timing is free to be
    /// nondeterministic.
    pub fn compact(&self, k: usize) -> u64 {
        let _m = self.mutation.lock().unwrap();
        self.compact_locked(k)
    }

    /// Execute every deferred retrain; afterwards the store serves the
    /// same bits with or without the lazy pipeline. Returns the number of
    /// retrains executed.
    pub fn flush_all(&self) -> u64 {
        self.compact(usize::MAX)
    }

    fn compact_locked(&self, k: usize) -> u64 {
        if self.pending_retrains() == 0 {
            return 0;
        }
        let flushed = scope_map(&self.shards, self.shards.len(), |_, shard| {
            if shard.pending.load(Ordering::SeqCst) == 0 {
                return 0u64;
            }
            let mut trees = shard.trees.write().unwrap();
            let d = self.data.read().unwrap();
            let mut fl = 0u64;
            for t in trees.iter_mut() {
                fl += t.flush_budget(&d, &self.params, k) as u64;
            }
            if fl > 0 {
                shard.refresh_pending(&trees);
                shard.epoch.fetch_add(2, Ordering::SeqCst);
            }
            fl
        });
        flushed.into_iter().sum()
    }

    /// Memory breakdown across all trees (paper Table 3).
    pub fn memory(&self) -> NodeMemory {
        let mut m = NodeMemory::default();
        self.for_each_tree(|_, t| m.add(&t.memory()));
        m
    }

    /// Clone a consistent [`DareForest`] view (serialization, oracles).
    /// Takes the mutation mutex so trees and dataset cannot diverge
    /// mid-snapshot. Under a lazy policy all deferred retrains are flushed
    /// first: a snapshot is an external read of the *whole* model, so
    /// as-if-flushed exactness demands the fixpoint — the returned forest
    /// (and its serialized bytes) is identical to the eager store's.
    pub fn snapshot(&self) -> DareForest {
        let _m = self.mutation.lock().unwrap();
        self.compact_locked(usize::MAX);
        let mut trees = Vec::with_capacity(self.n_trees);
        for s in &self.shards {
            trees.extend(s.trees.read().unwrap().iter().cloned());
        }
        let data = self.data.read().unwrap().clone();
        DareForest::from_parts(self.params.clone(), self.seed, trees, data)
            .expect("sharded store is internally consistent")
    }

    /// Deep structural audit for the stress/fuzz harnesses: every shard's
    /// arenas validate (including the per-tree dirty sets — every entry a
    /// live, flushable, leaf-shaped id), every tree covers exactly the
    /// live instance set (nothing lost, nothing duplicated — pending-leaf
    /// payloads are kept current by the flush-before-touch contract), and
    /// tree sizes agree with the database. Quiesces writers via the
    /// mutation mutex.
    pub fn validate(&self) -> anyhow::Result<()> {
        let _m = self.mutation.lock().unwrap();
        let d = self.data.read().unwrap();
        let live = d.live_ids(); // ascending
        let mut ids = Vec::with_capacity(live.len());
        for s in &self.shards {
            let trees = s.trees.read().unwrap();
            let mut pending = 0u64;
            for (k, t) in trees.iter().enumerate() {
                let gt = s.start + k;
                t.validate()?;
                pending += t.dirty_len() as u64;
                // Occ(q): each tree covers exactly the owned fraction of
                // the live set (the whole set at q=1.0 — `owns`
                // short-circuits without hashing).
                let owned: Vec<InstanceId>;
                let expect: &[InstanceId] = if self.params.subsampled() {
                    owned = live
                        .iter()
                        .copied()
                        .filter(|&i| owns(t.tree_seed, i, self.params.q))
                        .collect();
                    &owned
                } else {
                    &live
                };
                anyhow::ensure!(
                    t.n() as usize == expect.len(),
                    "tree {gt}: size {} != owned live instances {}",
                    t.n(),
                    expect.len()
                );
                ids.clear();
                t.arena.collect_ids(t.arena.root(), None, &mut ids);
                ids.sort_unstable();
                anyhow::ensure!(
                    ids == expect,
                    "tree {gt}: instance set diverged from its owned live \
                     set (lost or duplicated ids across shards)"
                );
            }
            anyhow::ensure!(
                pending == s.pending.load(Ordering::SeqCst),
                "shard {}: pending counter {} disagrees with its trees ({pending})",
                s.start,
                s.pending.load(Ordering::SeqCst)
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};

    fn forest(n: usize, n_trees: usize, seed: u64) -> DareForest {
        let d = generate(
            &SynthSpec {
                n,
                informative: 3,
                redundant: 1,
                noise: 2,
                flip: 0.05,
                ..Default::default()
            },
            seed,
        );
        DareForest::fit(
            d,
            &Params {
                n_trees,
                max_depth: 6,
                k: 5,
                d_rmax: 1,
                ..Default::default()
            },
            seed ^ 0x5A5A,
        )
    }

    #[test]
    fn sharded_delete_batch_is_bit_exact_with_unsharded() {
        let mut plain = forest(240, 5, 3);
        let sharded = ShardedForest::new(forest(240, 5, 3), 3);
        assert_eq!(sharded.n_shards(), 3);
        assert_eq!(sharded.n_trees(), 5);

        let ids = [4u32, 9, 9, 77, 200, 999_999];
        let (rs, skipped_s) = sharded.delete_batch(&ids);
        let (rp, skipped_p) = plain.delete_batch(&ids);
        assert_eq!(skipped_s, skipped_p);
        assert_eq!(rs.per_tree.len(), rp.per_tree.len());
        for (a, b) in rs.per_tree.iter().zip(&rp.per_tree) {
            assert_eq!(a.retrain_events, b.retrain_events);
            assert_eq!(a.thresholds_resampled, b.thresholds_resampled);
            assert_eq!(a.attrs_resampled, b.attrs_resampled);
        }
        assert_eq!(sharded.n_alive(), plain.n_alive());
        sharded.for_each_tree(|gt, t| {
            assert!(
                t.structural_matches(&plain.trees()[gt]),
                "tree {gt} diverged from the unsharded path"
            );
        });
        sharded.validate().unwrap();
        // one mutation = +2 on every shard (odd while in flight, §8 seqlock)
        assert!(sharded.shard_epochs().iter().all(|&e| e == 2));
        // an all-skipped batch must not bump epochs
        let (_, skipped) = sharded.delete_batch(&[999_999]);
        assert_eq!(skipped, 1);
        assert!(sharded.shard_epochs().iter().all(|&e| e == 2));
    }

    #[test]
    fn sharded_add_and_delete_cost_match_unsharded() {
        let mut plain = forest(200, 4, 7);
        let sharded = ShardedForest::new(forest(200, 4, 7), 4);
        let p = plain.data().n_features();
        let row = vec![0.3f32; p];
        let id_s = sharded.add(&row, 1).unwrap();
        let id_p = plain.add(&row, 1);
        assert_eq!(id_s, id_p);
        sharded.for_each_tree(|gt, t| {
            assert!(t.structural_matches(&plain.trees()[gt]));
        });
        for id in [0u32, 7, 55, id_s] {
            assert_eq!(sharded.delete_cost(id).unwrap(), plain.delete_cost(id));
        }
        assert!(sharded.delete_cost(999_999).is_err());
        // arity / label validation — rejected requests leave epochs stable
        let before = sharded.shard_epochs();
        assert!(sharded.add(&vec![0.0; p + 1], 0).is_err());
        assert!(sharded.add(&row, 2).is_err());
        assert_eq!(sharded.shard_epochs(), before);
    }

    #[test]
    fn sharded_predictions_are_bit_exact() {
        let plain = forest(300, 6, 11);
        let sharded = ShardedForest::new(forest(300, 6, 11), 4);
        // both the scalar (<cutoff) and the blocked (≥cutoff) path
        let small: Vec<Vec<f32>> = (0..PREDICT_BATCH_CUTOFF as u32 - 1)
            .map(|i| plain.data().row(i))
            .collect();
        let big: Vec<Vec<f32>> = (0..290u32).map(|i| plain.data().row(i)).collect();
        assert_eq!(sharded.predict_proba_rows(&small), plain.predict_proba_rows(&small));
        assert_eq!(sharded.predict_proba_rows(&big), plain.predict_proba_rows(&big));
        assert_eq!(sharded.predict_proba(&big[0]), plain.predict_proba(&big[0]));
        assert!(sharded.predict_proba_rows(&[]).is_empty());
    }

    #[test]
    fn snapshot_reassembles_the_forest() {
        let plain = forest(180, 5, 13);
        let sharded = ShardedForest::new(forest(180, 5, 13), 2);
        sharded.delete_batch(&[1, 2, 3]).0.cost();
        let snap = sharded.snapshot();
        assert_eq!(snap.n_trees(), 5);
        assert_eq!(snap.n_alive(), 177);
        assert_eq!(snap.seed(), plain.seed());
        // snapshot trees are in global order and structurally live
        for t in snap.trees() {
            t.arena.validate().unwrap();
        }
        let rows: Vec<Vec<f32>> = (4..40u32).map(|i| snap.data().row(i)).collect();
        assert_eq!(snap.predict_proba_rows(&rows), sharded.predict_proba_rows(&rows));
    }

    #[test]
    fn more_shards_than_trees_caps_cleanly() {
        let sharded = ShardedForest::new(forest(120, 2, 17), 8);
        assert_eq!(sharded.n_shards(), 2);
        sharded.delete_batch(&[0, 1]);
        sharded.validate().unwrap();
        assert!(sharded.memory().total() > 0);
    }

    #[test]
    fn lazy_store_serves_eager_bits_and_flushes_on_read() {
        use crate::forest::lazy::LazyPolicy;
        let mut eager = forest(260, 5, 23);
        let lazy = ShardedForest::new_with_policy(forest(260, 5, 23), 3, LazyPolicy::OnRead);
        assert_eq!(lazy.lazy_policy(), LazyPolicy::OnRead);

        // Deletions mark; reports stay identical to the eager path.
        let ids = [1u32, 8, 40, 90, 130];
        let (rl, skipped_l) = lazy.delete_batch(&ids);
        let (re, skipped_e) = eager.delete_batch(&ids);
        assert_eq!(skipped_l, skipped_e);
        for (a, b) in rl.per_tree.iter().zip(&re.per_tree) {
            assert_eq!(a.retrain_events, b.retrain_events);
            assert_eq!(a.thresholds_resampled, b.thresholds_resampled);
        }
        lazy.validate().unwrap();

        // Served predictions and costs are bit-identical at query time.
        let rows: Vec<Vec<f32>> = (0..60u32).map(|i| eager.data().row(i)).collect();
        assert_eq!(lazy.predict_proba_rows(&rows), eager.predict_proba_rows(&rows));
        for id in [3u32, 50, 77] {
            assert_eq!(lazy.delete_cost(id).unwrap(), eager.delete_cost(id));
        }

        // Drain the rest; the snapshot equals the eager forest structurally.
        lazy.flush_all();
        assert_eq!(lazy.pending_retrains(), 0);
        lazy.for_each_tree(|gt, t| {
            assert!(
                t.structural_matches(&eager.trees()[gt]),
                "tree {gt} diverged after flush"
            );
        });
        lazy.validate().unwrap();
        let (deferred, flushed) = lazy.retrain_counters();
        assert_eq!(deferred, flushed, "drained store must have no backlog");
    }

    #[test]
    fn lazy_flush_bumps_only_flushed_shard_epochs() {
        use crate::forest::lazy::LazyPolicy;
        use std::sync::atomic::Ordering;
        let lazy = ShardedForest::new_with_policy(forest(240, 4, 29), 4, LazyPolicy::OnRead);
        // one mutation: every epoch moves by exactly 2 (seqlock bracket)
        lazy.delete_batch(&(0u32..12).collect::<Vec<_>>());
        assert!(lazy.shard_epochs().iter().all(|&e| e == 2));
        let before = lazy.shard_epochs();
        let pending_before: Vec<u64> = lazy
            .shards
            .iter()
            .map(|s| s.pending.load(Ordering::SeqCst))
            .collect();
        // a full drain bumps exactly the shards that had a backlog
        lazy.flush_all();
        let after = lazy.shard_epochs();
        for s in 0..lazy.n_shards() {
            if pending_before[s] > 0 {
                assert_eq!(after[s], before[s] + 2, "flushed shard {s} must republish");
            } else {
                assert_eq!(after[s], before[s], "clean shard {s} must not move");
            }
        }
        // nothing pending → compact is a no-op and moves no epoch
        assert_eq!(lazy.compact(8), 0);
        assert_eq!(lazy.shard_epochs(), after);
        lazy.validate().unwrap();
    }

    #[test]
    fn budgeted_store_bounds_the_backlog() {
        use crate::forest::lazy::LazyPolicy;
        let lazy = ShardedForest::new_with_policy(forest(220, 4, 31), 2, LazyPolicy::Budgeted(1));
        let mut eager = forest(220, 4, 31);
        for chunk in (0u32..40).collect::<Vec<_>>().chunks(4) {
            lazy.delete_batch(chunk);
            eager.delete_batch(chunk);
        }
        lazy.validate().unwrap();
        // the per-batch budget keeps draining; a final snapshot (which
        // flushes) must match the eager trees exactly
        let snap = lazy.snapshot();
        assert_eq!(lazy.pending_retrains(), 0, "snapshot must flush the backlog");
        for (a, b) in snap.trees().iter().zip(eager.trees()) {
            assert!(a.structural_matches(b));
        }
    }

    fn subsampled_forest(n: usize, n_trees: usize, seed: u64, q: f64) -> DareForest {
        let d = generate(
            &SynthSpec {
                n,
                informative: 3,
                redundant: 1,
                noise: 2,
                flip: 0.05,
                ..Default::default()
            },
            seed,
        );
        DareForest::fit(
            d,
            &Params {
                n_trees,
                max_depth: 6,
                k: 5,
                d_rmax: 1,
                ..Default::default()
            }
            .with_subsample(q),
            seed ^ 0x5A5A,
        )
    }

    #[test]
    fn subsampled_store_is_bit_exact_and_routes_to_owning_shards_only() {
        let q = 0.35;
        let mut plain = subsampled_forest(240, 6, 41, q);
        let sharded = ShardedForest::new(subsampled_forest(240, 6, 41, q), 3);
        assert_eq!(sharded.subsample_q(), q);
        let counts: Vec<u64> = plain.ownership_counts().iter().map(|&c| c as u64).collect();
        assert_eq!(sharded.ownership_counts(), counts);
        sharded.validate().unwrap();

        // Mixed batch (owned in places, dead/oob): reports, skips, trees
        // and costs must match the unsharded subsampled path bit-for-bit.
        let ids = [4u32, 9, 77, 200, 999_999];
        let (rs, skipped_s) = sharded.delete_batch(&ids);
        let (rp, skipped_p) = plain.delete_batch(&ids);
        assert_eq!(skipped_s, skipped_p);
        assert_eq!(rs.per_tree.len(), rp.per_tree.len());
        for (a, b) in rs.per_tree.iter().zip(&rp.per_tree) {
            assert_eq!(a.retrain_events, b.retrain_events);
            assert_eq!(a.thresholds_resampled, b.thresholds_resampled);
        }
        sharded.for_each_tree(|gt, t| {
            assert!(t.structural_matches(&plain.trees()[gt]), "tree {gt} diverged");
        });
        sharded.validate().unwrap();
        assert!(
            sharded.unowned_skips() > 0,
            "a q=0.35 batch over 6 trees must skip some (tree, id) pairs"
        );
        for id in [0u32, 7, 55, 120] {
            assert_eq!(sharded.delete_cost(id).unwrap(), plain.delete_cost(id));
        }
        let rows: Vec<Vec<f32>> = (0..50u32).map(|i| plain.data().row(i)).collect();
        assert_eq!(sharded.predict_proba_rows(&rows), plain.predict_proba_rows(&rows));

        // Epoch routing: find a live id with mixed shard ownership and
        // check that deleting it republishes exactly the owning shards.
        // Ownership is a pure function of (tree_seed, id), so the expected
        // routing is computable out-of-band.
        let owner_mask = |id: InstanceId| -> Vec<bool> {
            (0..sharded.n_shards())
                .map(|si| {
                    sharded.with_shard_trees(si, |_, trees| {
                        trees.iter().any(|t| owns(t.tree_seed, id, q))
                    })
                })
                .collect()
        };
        let target = (100u32..200)
            .find(|&id| {
                plain.data().is_alive(id) && {
                    let m = owner_mask(id);
                    m.iter().any(|&x| x) && m.iter().any(|&x| !x)
                }
            })
            .expect("some live id must have mixed shard routing at q=0.35");
        let expect_touch = owner_mask(target);
        let before = sharded.shard_epochs();
        sharded.delete_batch(&[target]);
        plain.delete_batch(&[target]);
        let after = sharded.shard_epochs();
        for si in 0..sharded.n_shards() {
            if expect_touch[si] {
                assert_eq!(after[si], before[si] + 2, "owning shard {si} must republish");
            } else {
                assert_eq!(after[si], before[si], "non-owning shard {si} must not move");
            }
        }
        sharded.for_each_tree(|gt, t| {
            assert!(t.structural_matches(&plain.trees()[gt]));
        });

        // Adds route the same way: epochs move only on shards owning the
        // prospective id, and the trees match the unsharded path.
        let p = plain.data().n_features();
        let row = vec![0.3f32; p];
        let before = sharded.shard_epochs();
        let id_s = sharded.add(&row, 1).unwrap();
        let id_p = plain.add(&row, 1);
        assert_eq!(id_s, id_p);
        let expect_touch = owner_mask(id_s);
        let after = sharded.shard_epochs();
        for si in 0..sharded.n_shards() {
            let want = before[si] + if expect_touch[si] { 2 } else { 0 };
            assert_eq!(after[si], want, "add routed shard {si} wrong");
        }
        sharded.for_each_tree(|gt, t| {
            assert!(t.structural_matches(&plain.trees()[gt]));
        });
        sharded.validate().unwrap();

        // Snapshot (→ from_parts) revalidates ownership and round-trips.
        let snap = sharded.snapshot();
        assert_eq!(snap.params().q, q);
        for (a, b) in snap.trees().iter().zip(plain.trees()) {
            assert!(a.structural_matches(b));
        }
    }

    #[test]
    fn lazy_subsampled_store_drains_to_eager_bits() {
        use crate::forest::lazy::LazyPolicy;
        let q = 0.3;
        let mut eager = subsampled_forest(220, 5, 43, q);
        let lazy =
            ShardedForest::new_with_policy(subsampled_forest(220, 5, 43, q), 2, LazyPolicy::OnRead);
        let (rl, skipped_l) = lazy.delete_batch(&[1, 8, 40, 90]);
        let (re, skipped_e) = eager.delete_batch(&[1, 8, 40, 90]);
        assert_eq!(skipped_l, skipped_e);
        for (a, b) in rl.per_tree.iter().zip(&re.per_tree) {
            assert_eq!(a.retrain_events, b.retrain_events);
        }
        let p = eager.data().n_features();
        let id_l = lazy.add(&vec![0.4; p], 1).unwrap();
        let id_e = eager.add(&vec![0.4; p], 1);
        assert_eq!(id_l, id_e);
        for id in [3u32, 50, 77] {
            assert_eq!(lazy.delete_cost(id).unwrap(), eager.delete_cost(id));
        }
        lazy.flush_all();
        lazy.for_each_tree(|gt, t| {
            assert!(
                t.structural_matches(&eager.trees()[gt]),
                "tree {gt} diverged after flush"
            );
        });
        lazy.validate().unwrap();
    }

    #[test]
    fn concurrent_readers_during_mutation() {
        use std::sync::Arc;
        let sharded = Arc::new(ShardedForest::new(forest(260, 4, 19), 4));
        let probe: Vec<Vec<f32>> = (0..40u32).map(|i| sharded.with_data(|d| d.row(i))).collect();
        let mut handles = Vec::new();
        for _ in 0..3 {
            let s = Arc::clone(&sharded);
            let rows = probe.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..30 {
                    let probs = s.predict_proba_rows(&rows);
                    assert!(probs.iter().all(|p| (0.0..=1.0).contains(p)));
                }
            }));
        }
        for chunk in (0u32..60).collect::<Vec<_>>().chunks(5) {
            sharded.delete_batch(chunk);
        }
        for h in handles {
            h.join().unwrap();
        }
        sharded.validate().unwrap();
        assert_eq!(sharded.n_alive(), 200);
    }
}
