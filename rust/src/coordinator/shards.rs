//! Sharded forest ownership (DESIGN.md §8): the coordinator's store.
//!
//! The tree vector of a [`DareForest`] is partitioned into `S` contiguous
//! shards. Each shard owns its tree subset behind its **own** `RwLock` and
//! carries a mutation-epoch counter, so
//!
//! - reads (predict, delete_cost, stats) take per-shard *read* locks and
//!   proceed concurrently with each other and with mutations of *other*
//!   shards — no global forest lock exists anymore;
//! - mutations fan out across shards and run concurrently with each other
//!   *within* one logical operation (each shard worker holds only its own
//!   write lock);
//! - snapshot consumers (the PJRT predictor refresh) compare per-shard
//!   epochs and re-tensorize only shards that actually mutated.
//!
//! **Bit-exactness with the unsharded path.** Nothing about the model
//! changes: tree seeds stay keyed by *global* tree index
//! ([`crate::forest::forest::tree_seed`]), per-tree update epochs live in
//! the trees themselves, and every mutation applies the same per-tree
//! operation sequence in the same order as `DareForest::delete_batch` /
//! `add` (tree updates never read the liveness mask, see DESIGN.md §6), so
//! all Lemma-A.1 RNG streams are identical. Prediction gathers per-shard,
//! per-tree leaf-value partials and reduces them in global tree order —
//! the exact f32 accumulation sequence of `DareForest::predict_proba` — so
//! probabilities are bit-identical, not merely close. `tests/op_fuzz.rs`
//! enforces all of this against the boxed oracle and the arena path.
//!
//! **Locking protocol.** Writers (delete/add) serialize on a store-level
//! mutation mutex (they would contend on every shard anyway — each DaRE
//! tree contains every instance) and bracket every mutation with a
//! seqlock-style epoch protocol: each shard's epoch is bumped to *odd*
//! before the first tree is touched and back to *even* after the dataset
//! is updated, so one mutation advances every epoch by 2. Readers that
//! must observe one consistent forest state (`predict_proba_rows`,
//! `delete_cost`) read the epoch vector before and after, retry when it
//! moved or was odd, and after a few failed attempts fall back to taking
//! the mutation mutex. Deadlock is impossible: at most one thread (the
//! mutation-mutex holder) ever acquires write locks, it never requests
//! another lock while holding the dataset write lock, and readers hold at
//! most one shard lock at a time.

use crate::data::dataset::{Dataset, InstanceId};
use crate::forest::delete::DeleteReport;
use crate::forest::forest::{
    accept_deletions, shard_ranges, DareForest, ForestDeleteReport, PREDICT_BATCH_CUTOFF,
    PREDICT_BLOCK,
};
use crate::forest::node::NodeMemory;
use crate::forest::params::Params;
use crate::forest::tree::DareTree;
use crate::util::threadpool::scope_map;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, RwLock};

/// Attempts at an optimistic (epoch-validated) read before falling back to
/// the mutation mutex.
const READ_RETRIES: usize = 4;

/// One shard: a contiguous range of the forest's trees behind its own lock.
struct Shard {
    /// Trees with global indices `start..start + trees.len()`.
    trees: RwLock<Vec<DareTree>>,
    /// Global index of the first tree in this shard.
    start: usize,
    /// Seqlock epoch: odd while a mutation is in flight, +2 per mutation
    /// that changed this shard's trees.
    epoch: AtomicU64,
}

/// The coordinator's sharded forest store. See the module docs.
pub struct ShardedForest {
    params: Params,
    seed: u64,
    n_trees: usize,
    data: RwLock<Dataset>,
    shards: Vec<Shard>,
    /// Serializes mutations (see module docs: every mutation touches every
    /// shard, so writer concurrency buys nothing and interleaved writer
    /// fan-outs could deadlock on the dataset lock).
    mutation: Mutex<()>,
}

impl ShardedForest {
    /// Partition `forest` into at most `n_shards` shards (capped at the
    /// tree count so no shard is empty).
    pub fn new(forest: DareForest, n_shards: usize) -> Self {
        let (params, seed, mut trees, data) = forest.into_parts();
        let n_trees = trees.len();
        let ranges = shard_ranges(n_trees, n_shards);
        let mut shards = Vec::with_capacity(ranges.len());
        // split_off from the back so each shard keeps its contiguous range
        for r in ranges.iter().rev() {
            let tail = trees.split_off(r.start);
            shards.push(Shard {
                trees: RwLock::new(tail),
                start: r.start,
                epoch: AtomicU64::new(0),
            });
        }
        shards.reverse();
        ShardedForest {
            params,
            seed,
            n_trees,
            data: RwLock::new(data),
            shards,
            mutation: Mutex::new(()),
        }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }
    pub fn n_trees(&self) -> usize {
        self.n_trees
    }
    pub fn params(&self) -> &Params {
        &self.params
    }
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Per-shard mutation epochs (index = shard id). Even = stable, odd =
    /// a mutation is in flight; one mutation advances every epoch by 2.
    /// Snapshot consumers diff this against their last-seen vector to find
    /// dirty shards.
    pub fn shard_epochs(&self) -> Vec<u64> {
        self.shards.iter().map(|s| s.epoch.load(Ordering::SeqCst)).collect()
    }

    /// Seqlock write-side: flip every epoch odd (mutation in flight).
    /// Caller must hold the mutation mutex.
    fn begin_mutation(&self) {
        for s in &self.shards {
            s.epoch.fetch_add(1, Ordering::SeqCst);
        }
    }

    /// Seqlock write-side: flip every epoch back to even (stable).
    fn end_mutation(&self) {
        for s in &self.shards {
            s.epoch.fetch_add(1, Ordering::SeqCst);
        }
    }

    /// Seqlock read-side: run `f` and return its result only if the epoch
    /// vector was even and unchanged across the run (i.e. `f` observed ONE
    /// forest state, not a mix of pre-/post-mutation shards). After
    /// [`READ_RETRIES`] failed attempts, serialize behind the mutation
    /// mutex instead of spinning.
    fn read_consistent<R>(&self, f: impl Fn() -> R) -> R {
        for _ in 0..READ_RETRIES {
            let before = self.shard_epochs();
            if before.iter().any(|e| e % 2 == 1) {
                std::thread::yield_now();
                continue;
            }
            let r = f();
            if self.shard_epochs() == before {
                return r;
            }
        }
        let _m = self.mutation.lock().unwrap();
        f()
    }

    /// Run `f` against the training database under the read lock.
    pub fn with_data<R>(&self, f: impl FnOnce(&Dataset) -> R) -> R {
        f(&self.data.read().unwrap())
    }

    pub fn n_alive(&self) -> usize {
        self.with_data(|d| d.n_alive())
    }

    pub fn n_features(&self) -> usize {
        self.with_data(|d| d.n_features())
    }

    pub fn live_ids(&self) -> Vec<InstanceId> {
        self.with_data(|d| d.live_ids())
    }

    /// Bytes of the training database (Table 3 "Data" column).
    pub fn data_bytes(&self) -> usize {
        self.with_data(|d| d.memory_bytes())
    }

    /// Run `f` over one shard's trees under its read lock. `f` receives the
    /// global index of the shard's first tree and the tree slice.
    pub fn with_shard_trees<R>(&self, shard: usize, f: impl FnOnce(usize, &[DareTree]) -> R) -> R {
        let s = &self.shards[shard];
        let trees = s.trees.read().unwrap();
        f(s.start, &trees)
    }

    /// Visit every tree in global index order (read locks, shard by shard).
    pub fn for_each_tree(&self, mut f: impl FnMut(usize, &DareTree)) {
        for s in &self.shards {
            let trees = s.trees.read().unwrap();
            for (k, t) in trees.iter().enumerate() {
                f(s.start + k, t);
            }
        }
    }

    /// Batch deletion, bit-exact with [`DareForest::delete_batch`]: same
    /// dedup/validation, same per-tree operation order and epochs, same
    /// merged per-tree reports (gathered back into global tree order) —
    /// only the locking and fan-out routing differ.
    pub fn delete_batch(&self, ids: &[InstanceId]) -> (ForestDeleteReport, usize) {
        let _m = self.mutation.lock().unwrap();
        // Phase 1: validate and dedupe against the liveness mask (the
        // helper shared with `DareForest::delete_batch`, so the two paths
        // cannot diverge on accepted/skipped sets). No writer can
        // interleave (mutation mutex), so the mask is stable until the
        // mark-removed pass below.
        let (accepted, skipped) = {
            let d = self.data.read().unwrap();
            accept_deletions(&d, ids)
        };

        // Phase 2: fan the whole accepted sequence out to every shard; each
        // worker holds only its shard's write lock (plus a shared read lock
        // on the immutable-row dataset). The seqlock bracket makes the
        // in-flight state visible to optimistic readers. An all-skipped
        // batch mutates nothing and must not move epochs.
        if !accepted.is_empty() {
            self.begin_mutation();
        }
        let per_shard: Vec<Vec<DeleteReport>> =
            scope_map(&self.shards, self.shards.len(), |_, shard| {
                let mut trees = shard.trees.write().unwrap();
                let d = self.data.read().unwrap();
                trees
                    .iter_mut()
                    .map(|t| {
                        let mut merged = DeleteReport::default();
                        for &id in &accepted {
                            merged.merge(&t.delete(&d, &self.params, id));
                        }
                        merged
                    })
                    .collect()
            });

        // Phase 3: retire the instances and publish the new shard epochs.
        if !accepted.is_empty() {
            let mut d = self.data.write().unwrap();
            for &id in &accepted {
                d.mark_removed(id);
            }
            drop(d);
            self.end_mutation();
        }
        let per_tree: Vec<DeleteReport> = per_shard.into_iter().flatten().collect();
        (ForestDeleteReport { per_tree }, skipped)
    }

    /// Add a fresh training instance (§6), bit-exact with
    /// [`DareForest::add`]. Returns an error (instead of the unsharded
    /// path's assert) when the row arity is wrong.
    pub fn add(&self, row: &[f32], label: u8) -> anyhow::Result<InstanceId> {
        let _m = self.mutation.lock().unwrap();
        // Validate before the seqlock bracket so a rejected request leaves
        // the epochs untouched (n_features/label are immutable properties).
        {
            let d = self.data.read().unwrap();
            anyhow::ensure!(
                row.len() == d.n_features(),
                "row has {} features, model expects {}",
                row.len(),
                d.n_features()
            );
        }
        anyhow::ensure!(label <= 1, "label must be 0 or 1");
        // The dataset row must exist before the trees index it, so the
        // bracket opens before push_row — optimistic readers retry across
        // the whole window.
        self.begin_mutation();
        let id = self.data.write().unwrap().push_row(row, label);
        scope_map(&self.shards, self.shards.len(), |_, shard| {
            let mut trees = shard.trees.write().unwrap();
            let d = self.data.read().unwrap();
            for t in trees.iter_mut() {
                t.add(&d, &self.params, id);
            }
        });
        self.end_mutation();
        Ok(id)
    }

    /// Dry-run total retrain cost of deleting `id` across all trees.
    /// Read locks only in the common case; the epoch-validated retry
    /// guarantees the liveness check and every shard's costing observed
    /// the same forest state (a concurrent deletion of `id` yields the
    /// "not live" error, never a cost mixing pre-/post-delete shards).
    pub fn delete_cost(&self, id: InstanceId) -> anyhow::Result<u64> {
        self.read_consistent(|| {
            {
                let d = self.data.read().unwrap();
                anyhow::ensure!(
                    (id as usize) < d.n_total() && d.is_alive(id),
                    "instance {id} is not a live training instance"
                );
            }
            let per_shard = scope_map(&self.shards, self.shards.len(), |_, shard| {
                let trees = shard.trees.read().unwrap();
                let d = self.data.read().unwrap();
                trees
                    .iter()
                    .map(|t| t.delete_cost(&d, &self.params, id))
                    .sum::<u64>()
            });
            Ok(per_shard.into_iter().sum())
        })
    }

    /// Positive-class probability for one row (bit-exact with
    /// [`DareForest::predict_proba`]).
    pub fn predict_proba(&self, row: &[f32]) -> f32 {
        self.predict_proba_rows(std::slice::from_ref(&row.to_vec()))[0]
    }

    /// Batch prediction without any write lock: every shard computes its
    /// trees' per-row leaf values (level-synchronous
    /// [`crate::forest::arena::ArenaTree::predict_block_sum`] blocks at or
    /// above [`PREDICT_BATCH_CUTOFF`] rows, scalar descents below), and the
    /// partials are reduced in global tree order — the identical f32
    /// accumulation sequence as [`DareForest::predict_proba_rows`], hence
    /// bit-identical probabilities. The epoch-validated retry guarantees
    /// all shards were read at one forest state (never a pre-/post-delete
    /// mix).
    ///
    /// Parallelism note: the fan-out is one worker per shard (tree-level),
    /// not per row block — size `n_shards` to the cores you want the read
    /// path to use (the default, threadpool width, does this; only forests
    /// with fewer trees than cores are narrower).
    pub fn predict_proba_rows(&self, rows: &[Vec<f32>]) -> Vec<f32> {
        let n_rows = rows.len();
        if n_rows == 0 {
            return Vec::new();
        }
        let partials: Vec<Vec<f32>> = self.read_consistent(|| {
            // Per shard: a (trees_in_shard × n_rows) flat plane of leaf
            // values. `predict_block_sum` accumulates into zeroed slices,
            // which yields plain leaf values — the same reuse the forest's
            // block path gets.
            scope_map(&self.shards, self.shards.len(), |_, shard| {
                let trees = shard.trees.read().unwrap();
                let mut vals = vec![0.0f32; trees.len() * n_rows];
                let mut cursors: Vec<u32> = Vec::new();
                for (k, t) in trees.iter().enumerate() {
                    let out = &mut vals[k * n_rows..(k + 1) * n_rows];
                    if n_rows < PREDICT_BATCH_CUTOFF {
                        for (o, row) in out.iter_mut().zip(rows) {
                            *o = t.predict(row);
                        }
                    } else {
                        for (b, chunk) in rows.chunks(PREDICT_BLOCK).enumerate() {
                            let lo = b * PREDICT_BLOCK;
                            t.arena.predict_block_sum(
                                chunk,
                                &mut cursors,
                                &mut out[lo..lo + chunk.len()],
                            );
                        }
                    }
                }
                vals
            })
        });
        // Reduce in global tree order: shards hold contiguous ascending
        // ranges, so folding shard-by-shard, tree-by-tree replays the
        // unsharded per-row sum exactly.
        let mut sums = vec![0.0f32; n_rows];
        for vals in &partials {
            for tree_vals in vals.chunks(n_rows) {
                for (s, v) in sums.iter_mut().zip(tree_vals) {
                    *s += *v;
                }
            }
        }
        let nt = self.n_trees as f32;
        for s in sums.iter_mut() {
            *s /= nt;
        }
        sums
    }

    /// Memory breakdown across all trees (paper Table 3).
    pub fn memory(&self) -> NodeMemory {
        let mut m = NodeMemory::default();
        self.for_each_tree(|_, t| m.add(&t.memory()));
        m
    }

    /// Clone a consistent [`DareForest`] view (serialization, oracles).
    /// Takes the mutation mutex so trees and dataset cannot diverge
    /// mid-snapshot.
    pub fn snapshot(&self) -> DareForest {
        let _m = self.mutation.lock().unwrap();
        let mut trees = Vec::with_capacity(self.n_trees);
        for s in &self.shards {
            trees.extend(s.trees.read().unwrap().iter().cloned());
        }
        let data = self.data.read().unwrap().clone();
        DareForest::from_parts(self.params.clone(), self.seed, trees, data)
            .expect("sharded store is internally consistent")
    }

    /// Deep structural audit for the stress/fuzz harnesses: every shard's
    /// arenas validate, every tree covers exactly the live instance set
    /// (nothing lost, nothing duplicated), and tree sizes agree with the
    /// database. Quiesces writers via the mutation mutex.
    pub fn validate(&self) -> anyhow::Result<()> {
        let _m = self.mutation.lock().unwrap();
        let d = self.data.read().unwrap();
        let expect = d.live_ids(); // ascending
        let mut ids = Vec::with_capacity(expect.len());
        for s in &self.shards {
            let trees = s.trees.read().unwrap();
            for (k, t) in trees.iter().enumerate() {
                let gt = s.start + k;
                t.arena.validate()?;
                anyhow::ensure!(
                    t.n() as usize == d.n_alive(),
                    "tree {gt}: size {} != live instances {}",
                    t.n(),
                    d.n_alive()
                );
                ids.clear();
                t.arena.collect_ids(t.arena.root(), None, &mut ids);
                ids.sort_unstable();
                anyhow::ensure!(
                    ids == expect,
                    "tree {gt}: instance set diverged from the live set \
                     (lost or duplicated ids across shards)"
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};

    fn forest(n: usize, n_trees: usize, seed: u64) -> DareForest {
        let d = generate(
            &SynthSpec {
                n,
                informative: 3,
                redundant: 1,
                noise: 2,
                flip: 0.05,
                ..Default::default()
            },
            seed,
        );
        DareForest::fit(
            d,
            &Params {
                n_trees,
                max_depth: 6,
                k: 5,
                d_rmax: 1,
                ..Default::default()
            },
            seed ^ 0x5A5A,
        )
    }

    #[test]
    fn sharded_delete_batch_is_bit_exact_with_unsharded() {
        let mut plain = forest(240, 5, 3);
        let sharded = ShardedForest::new(forest(240, 5, 3), 3);
        assert_eq!(sharded.n_shards(), 3);
        assert_eq!(sharded.n_trees(), 5);

        let ids = [4u32, 9, 9, 77, 200, 999_999];
        let (rs, skipped_s) = sharded.delete_batch(&ids);
        let (rp, skipped_p) = plain.delete_batch(&ids);
        assert_eq!(skipped_s, skipped_p);
        assert_eq!(rs.per_tree.len(), rp.per_tree.len());
        for (a, b) in rs.per_tree.iter().zip(&rp.per_tree) {
            assert_eq!(a.retrain_events, b.retrain_events);
            assert_eq!(a.thresholds_resampled, b.thresholds_resampled);
            assert_eq!(a.attrs_resampled, b.attrs_resampled);
        }
        assert_eq!(sharded.n_alive(), plain.n_alive());
        sharded.for_each_tree(|gt, t| {
            assert!(
                t.structural_matches(&plain.trees()[gt]),
                "tree {gt} diverged from the unsharded path"
            );
        });
        sharded.validate().unwrap();
        // one mutation = +2 on every shard (odd while in flight, §8 seqlock)
        assert!(sharded.shard_epochs().iter().all(|&e| e == 2));
        // an all-skipped batch must not bump epochs
        let (_, skipped) = sharded.delete_batch(&[999_999]);
        assert_eq!(skipped, 1);
        assert!(sharded.shard_epochs().iter().all(|&e| e == 2));
    }

    #[test]
    fn sharded_add_and_delete_cost_match_unsharded() {
        let mut plain = forest(200, 4, 7);
        let sharded = ShardedForest::new(forest(200, 4, 7), 4);
        let p = plain.data().n_features();
        let row = vec![0.3f32; p];
        let id_s = sharded.add(&row, 1).unwrap();
        let id_p = plain.add(&row, 1);
        assert_eq!(id_s, id_p);
        sharded.for_each_tree(|gt, t| {
            assert!(t.structural_matches(&plain.trees()[gt]));
        });
        for id in [0u32, 7, 55, id_s] {
            assert_eq!(sharded.delete_cost(id).unwrap(), plain.delete_cost(id));
        }
        assert!(sharded.delete_cost(999_999).is_err());
        // arity / label validation — rejected requests leave epochs stable
        let before = sharded.shard_epochs();
        assert!(sharded.add(&vec![0.0; p + 1], 0).is_err());
        assert!(sharded.add(&row, 2).is_err());
        assert_eq!(sharded.shard_epochs(), before);
    }

    #[test]
    fn sharded_predictions_are_bit_exact() {
        let plain = forest(300, 6, 11);
        let sharded = ShardedForest::new(forest(300, 6, 11), 4);
        // both the scalar (<cutoff) and the blocked (≥cutoff) path
        let small: Vec<Vec<f32>> = (0..PREDICT_BATCH_CUTOFF as u32 - 1)
            .map(|i| plain.data().row(i))
            .collect();
        let big: Vec<Vec<f32>> = (0..290u32).map(|i| plain.data().row(i)).collect();
        assert_eq!(sharded.predict_proba_rows(&small), plain.predict_proba_rows(&small));
        assert_eq!(sharded.predict_proba_rows(&big), plain.predict_proba_rows(&big));
        assert_eq!(sharded.predict_proba(&big[0]), plain.predict_proba(&big[0]));
        assert!(sharded.predict_proba_rows(&[]).is_empty());
    }

    #[test]
    fn snapshot_reassembles_the_forest() {
        let plain = forest(180, 5, 13);
        let sharded = ShardedForest::new(forest(180, 5, 13), 2);
        sharded.delete_batch(&[1, 2, 3]).0.cost();
        let snap = sharded.snapshot();
        assert_eq!(snap.n_trees(), 5);
        assert_eq!(snap.n_alive(), 177);
        assert_eq!(snap.seed(), plain.seed());
        // snapshot trees are in global order and structurally live
        for t in snap.trees() {
            t.arena.validate().unwrap();
        }
        let rows: Vec<Vec<f32>> = (4..40u32).map(|i| snap.data().row(i)).collect();
        assert_eq!(snap.predict_proba_rows(&rows), sharded.predict_proba_rows(&rows));
    }

    #[test]
    fn more_shards_than_trees_caps_cleanly() {
        let sharded = ShardedForest::new(forest(120, 2, 17), 8);
        assert_eq!(sharded.n_shards(), 2);
        sharded.delete_batch(&[0, 1]);
        sharded.validate().unwrap();
        assert!(sharded.memory().total() > 0);
    }

    #[test]
    fn concurrent_readers_during_mutation() {
        use std::sync::Arc;
        let sharded = Arc::new(ShardedForest::new(forest(260, 4, 19), 4));
        let probe: Vec<Vec<f32>> = (0..40u32).map(|i| sharded.with_data(|d| d.row(i))).collect();
        let mut handles = Vec::new();
        for _ in 0..3 {
            let s = Arc::clone(&sharded);
            let rows = probe.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..30 {
                    let probs = s.predict_proba_rows(&rows);
                    assert!(probs.iter().all(|p| (0.0..=1.0).contains(p)));
                }
            }));
        }
        for chunk in (0u32..60).collect::<Vec<_>>().chunks(5) {
            sharded.delete_batch(chunk);
        }
        for h in handles {
            h.join().unwrap();
        }
        sharded.validate().unwrap();
        assert_eq!(sharded.n_alive(), 200);
    }
}
