//! The typed, versioned wire API (DESIGN.md §10).
//!
//! Every request on the wire is one JSON object; this module is the codec
//! between that object and the typed [`Request`] / [`Response`] enums the
//! service dispatches on, so decode → dispatch → encode are three
//! separately testable layers (the string-matching that used to live
//! inline in `service::handle` is gone).
//!
//! **Versioning.** A request carries `"v"` (wire version) and `"model"`
//! (registry name). Both are optional: a request with no `"v"` key is a
//! *v0* request — the pre-registry wire format — and routes to the
//! [`DEFAULT_MODEL`]. A v0 request and its v1 equivalent addressed to
//! `"default"` produce byte-identical response payloads (enforced by
//! `tests/api_compat.rs`). Versions above [`WIRE_VERSION`] are rejected
//! with a `bad_request` error, which doubles as the negotiation signal: a
//! client probes with its preferred version and falls back on rejection.
//!
//! **Errors.** Failures are a closed taxonomy ([`ApiError`]); each variant
//! carries a stable machine-readable `code` on the wire:
//! `{"ok":false,"error":{"code":...,"msg":...},"error_msg":...}`. The
//! `"error"` key now holds the structured object (previously it held a
//! free-form string); the top-level `"error_msg"` string carries that old
//! message verbatim, so a v0 caller that displayed the string needs only
//! a key rename — v0 callers that merely test `"error"`'s presence or
//! `"ok"` keep working unchanged. Integer payloads (seeds, budgets) are
//! JSON numbers and therefore exact only up to 2^53.

use crate::coordinator::batcher::DeleteOutcome;
use crate::data::dataset::InstanceId;
use crate::util::json::Value;
use std::fmt;

/// Highest wire version this build speaks.
pub const WIRE_VERSION: u64 = 1;

/// The model un-namespaced (v0) requests route to.
pub const DEFAULT_MODEL: &str = "default";

// ---------------------------------------------------------------------------
// Error taxonomy
// ---------------------------------------------------------------------------

/// Every way a request can fail, with a stable wire `code` per variant.
#[derive(Clone, Debug, PartialEq)]
pub enum ApiError {
    /// Malformed or unsupported request (bad JSON shape, unknown op,
    /// unsupported wire version, unknown dataset, duplicate model name).
    BadRequest(String),
    /// The addressed model is not in the registry.
    UnknownModel(String),
    /// A row's feature count does not match the model's arity.
    ArityMismatch { got: usize, want: usize },
    /// The instance id is not a live training instance.
    UnknownId(InstanceId),
    /// The service is draining after a `shutdown` request.
    ShuttingDown,
    /// The addressed model is a read-serving follower (DESIGN.md §12):
    /// mutations must go to `leader` instead.
    ReadOnly { leader: String },
    /// Admission control (DESIGN.md §15): the tenant's scheduler queue is
    /// at its depth bound. `retry_after_ms` is the predicted drain time of
    /// the queue — a structured backoff hint, not a promise.
    Overloaded { retry_after_ms: u64 },
    /// Client-side only: the transport failed (IO, unparseable response)
    /// after `attempts` tries. Never emitted by the server.
    Transport { msg: String, attempts: u32 },
}

impl ApiError {
    /// The stable machine-readable code serialized on the wire.
    pub fn code(&self) -> &'static str {
        match self {
            ApiError::BadRequest(_) => "bad_request",
            ApiError::UnknownModel(_) => "unknown_model",
            ApiError::ArityMismatch { .. } => "arity_mismatch",
            ApiError::UnknownId(_) => "unknown_id",
            ApiError::ShuttingDown => "shutting_down",
            ApiError::ReadOnly { .. } => "read_only",
            ApiError::Overloaded { .. } => "overloaded",
            ApiError::Transport { .. } => "transport",
        }
    }
}

impl fmt::Display for ApiError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ApiError::BadRequest(m) => write!(f, "{m}"),
            ApiError::Transport { msg, .. } => write!(f, "{msg}"),
            ApiError::ReadOnly { leader } => {
                write!(f, "model is a read-only follower; send mutations to {leader}")
            }
            ApiError::UnknownModel(m) => write!(f, "unknown model '{m}'"),
            ApiError::ArityMismatch { got, want } => {
                write!(f, "row has {got} features, model expects {want}")
            }
            ApiError::UnknownId(id) => {
                write!(f, "instance {id} is not a live training instance")
            }
            ApiError::ShuttingDown => write!(f, "service is shutting down"),
            ApiError::Overloaded { retry_after_ms } => {
                write!(f, "tenant queue is full; retry after {retry_after_ms} ms")
            }
        }
    }
}

impl std::error::Error for ApiError {}

// ---------------------------------------------------------------------------
// Requests
// ---------------------------------------------------------------------------

/// A decoded request: wire version, target model, operation.
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    /// Wire version the caller spoke (0 = legacy un-namespaced).
    pub v: u64,
    /// Registry name the operation addresses ([`DEFAULT_MODEL`] when the
    /// wire object had no `"model"` key).
    pub model: String,
    pub op: Op,
}

/// The operation set: per-model data-plane ops plus registry lifecycle.
#[derive(Clone, Debug, PartialEq)]
pub enum Op {
    // -- data plane (addressed to `Request::model`) --
    Predict { rows: Vec<Vec<f32>> },
    Delete { ids: Vec<InstanceId> },
    Add { row: Vec<f32>, label: u8 },
    DeleteCost { id: InstanceId },
    Stats,
    /// Execute every deferred retrain of the model (DESIGN.md §9).
    Flush,
    /// Drain up to `budget` deferred retrains per tree.
    Compact { budget: usize },
    Save { path: String },
    /// Issue a signed deletion certificate for a removed instance
    /// (requires the model to have durability enabled — DESIGN.md §11).
    Certify { id: InstanceId },
    /// Check a certificate's HMAC signature against this server's key.
    VerifyCert { cert: Certificate },
    // -- replication (DESIGN.md §12) --
    /// The model's canonical snapshot plus the WAL epoch it captures
    /// (follower bootstrap; requires durability on the leader).
    PullSnapshot,
    /// Up to `max_records` write-ahead log records with
    /// `epoch > after_epoch` (follower catch-up).
    PullLog { after_epoch: u64, max_records: usize },
    /// Drain catch-up and flip a follower model into a writable leader.
    Promote,
    // -- lifecycle (registry) --
    /// Train a new model named `Request::model` from a corpus dataset ref.
    Create(CreateSpec),
    /// Install a snapshot from disk as `Request::model`.
    Load { path: String },
    /// Remove `Request::model` from the registry.
    DropModel,
    /// Summaries of every registered model.
    List,
    Shutdown,
}

/// Parameters for `create`: a corpus dataset reference plus optional
/// hyperparameter overrides (paper-tuned defaults otherwise).
#[derive(Clone, Debug, PartialEq)]
pub struct CreateSpec {
    pub dataset: String,
    /// Generate the dataset at 1/`scale_div` of the paper's size.
    pub scale_div: usize,
    /// Dataset + training seed (JSON number: exact up to 2^53).
    pub seed: u64,
    pub n_trees: Option<usize>,
    pub max_depth: Option<usize>,
    pub k: Option<usize>,
    pub d_rmax: Option<usize>,
    /// Occ(q) subsample fraction in (0, 1] (DESIGN.md §13); omitted ⇒ full
    /// ownership (q = 1.0).
    pub q: Option<f64>,
}

impl Default for CreateSpec {
    fn default() -> Self {
        CreateSpec {
            dataset: String::new(),
            scale_div: 500,
            seed: 1,
            n_trees: None,
            max_depth: None,
            k: None,
            d_rmax: None,
            q: None,
        }
    }
}

/// A signed deletion certificate: an auditable, operator-verifiable record
/// that `instance_id` was removed from `model` at write-ahead-log epoch
/// `epoch`, when the model's durable snapshot state hashed to
/// `snapshot_hash`. `hmac` is HMAC-SHA256 over the canonical byte string
/// `model \0 instance_id \0 epoch \0 snapshot_hash` under the server's
/// certificate key (`coordinator::wal::sign_certificate`).
#[derive(Clone, Debug, PartialEq)]
pub struct Certificate {
    pub model: String,
    pub instance_id: InstanceId,
    /// WAL epoch of the delete record that removed the instance (exact on
    /// the wire up to 2^53 — epochs count mutating ops, far below that).
    pub epoch: u64,
    /// Hex SHA-256 of the model's serialized snapshot at certification time.
    pub snapshot_hash: String,
    /// Hex HMAC-SHA256 signature.
    pub hmac: String,
}

impl Certificate {
    pub fn to_wire(&self) -> Value {
        let mut o = Value::obj();
        o.set("model", self.model.as_str())
            .set("instance_id", self.instance_id)
            .set("epoch", self.epoch)
            .set("snapshot_hash", self.snapshot_hash.as_str())
            .set("hmac", self.hmac.as_str());
        o
    }

    pub fn from_wire(v: &Value) -> Result<Certificate, ApiError> {
        Ok(Certificate {
            model: req_str(v, "model", "cert needs 'model'")?,
            instance_id: v
                .get("instance_id")
                .and_then(|x| as_uint(x, u32::MAX as f64))
                .ok_or_else(|| bad("cert needs 'instance_id'"))? as InstanceId,
            epoch: req_uint(v, "epoch", "cert needs 'epoch'")?,
            snapshot_hash: req_str(v, "snapshot_hash", "cert needs 'snapshot_hash'")?,
            hmac: req_str(v, "hmac", "cert needs 'hmac'")?,
        })
    }
}

fn bad(msg: &str) -> ApiError {
    ApiError::BadRequest(msg.to_string())
}

/// A JSON number that is a non-negative integer within `max`, else `None`.
fn as_uint(v: &Value, max: f64) -> Option<u64> {
    v.as_f64()
        .filter(|n| *n >= 0.0 && n.fract() == 0.0 && *n <= max)
        .map(|n| n as u64)
}

fn req_uint(req: &Value, key: &str, missing: &str) -> Result<u64, ApiError> {
    req.get(key)
        .and_then(|v| as_uint(v, 9e15))
        .ok_or_else(|| bad(missing))
}

fn opt_uint(req: &Value, key: &str) -> Result<Option<u64>, ApiError> {
    match req.get(key) {
        None => Ok(None),
        Some(v) => as_uint(v, 9e15)
            .map(Some)
            .ok_or_else(|| bad(&format!("'{key}' must be a non-negative integer"))),
    }
}

fn req_str(req: &Value, key: &str, missing: &str) -> Result<String, ApiError> {
    req.get(key)
        .and_then(Value::as_str)
        .map(str::to_string)
        .ok_or_else(|| bad(missing))
}

fn num_rows(req: &Value, key: &str, missing: &str) -> Result<Vec<Vec<f32>>, ApiError> {
    let rows_json = req.get(key).and_then(Value::as_arr).ok_or_else(|| bad(missing))?;
    let mut rows = Vec::with_capacity(rows_json.len());
    for r in rows_json {
        let cells = r.as_arr().ok_or_else(|| bad("rows must be arrays of numbers"))?;
        rows.push(num_row(cells)?);
    }
    Ok(rows)
}

fn num_row(cells: &[Value]) -> Result<Vec<f32>, ApiError> {
    cells
        .iter()
        .map(|c| c.as_f64().map(|x| x as f32).ok_or_else(|| bad("row cells must be numbers")))
        .collect()
}

/// Scheduling metadata (DESIGN.md §15): an optional top-level
/// `"deadline_ms"` key — milliseconds from arrival by which the caller
/// wants the op served. Deliberately NOT a [`Request`] field: a deadline
/// describes *this delivery*, not the operation, so it must never be
/// journaled into the WAL or shipped to replicas (a replayed op's deadline
/// is meaningless). The scheduler peels it off the raw wire object before
/// `decode`, which ignores unknown keys as always.
pub fn deadline_ms(req: &Value) -> Result<Option<u64>, ApiError> {
    opt_uint(req, "deadline_ms")
}

/// Decode one wire object into a typed [`Request`].
pub fn decode(req: &Value) -> Result<Request, ApiError> {
    if !matches!(req, Value::Obj(_)) {
        return Err(bad("request must be a JSON object"));
    }
    let v = match req.get("v") {
        None => 0,
        Some(x) => as_uint(x, 9e15).ok_or_else(|| bad("'v' must be a non-negative integer"))?,
    };
    if v > WIRE_VERSION {
        return Err(bad(&format!(
            "unsupported wire version {v} (this server speaks 0..={WIRE_VERSION})"
        )));
    }
    let model = match req.get("model") {
        None => DEFAULT_MODEL.to_string(),
        Some(m) => m.as_str().ok_or_else(|| bad("'model' must be a string"))?.to_string(),
    };
    let op_name = req
        .get("op")
        .and_then(Value::as_str)
        .ok_or_else(|| bad("request needs 'op'"))?;
    let op = match op_name {
        "predict" => Op::Predict {
            rows: num_rows(req, "rows", "predict needs 'rows': [[f32,...],...]")?,
        },
        "delete" => {
            let ids_json = req
                .get("ids")
                .and_then(Value::as_arr)
                .ok_or_else(|| bad("delete needs 'ids': [u32,...]"))?;
            let ids = ids_json
                .iter()
                .map(|x| {
                    as_uint(x, u32::MAX as f64)
                        .map(|n| n as InstanceId)
                        .ok_or_else(|| bad("ids must be non-negative integers"))
                })
                .collect::<Result<Vec<_>, _>>()?;
            Op::Delete { ids }
        }
        "add" => {
            let row_json = req
                .get("row")
                .and_then(Value::as_arr)
                .ok_or_else(|| bad("add needs 'row': [f32,...]"))?;
            let row = num_row(row_json)?;
            let label = req
                .get("label")
                .and_then(|x| as_uint(x, 9e15))
                .ok_or_else(|| bad("add needs 'label': 0|1"))?;
            if label > 1 {
                return Err(bad("label must be 0 or 1"));
            }
            Op::Add {
                row,
                label: label as u8,
            }
        }
        "delete_cost" => Op::DeleteCost {
            id: req
                .get("id")
                .and_then(|x| as_uint(x, u32::MAX as f64))
                .ok_or_else(|| bad("delete_cost needs 'id'"))? as InstanceId,
        },
        "stats" => Op::Stats,
        "flush" => Op::Flush,
        "compact" => Op::Compact {
            budget: opt_uint(req, "budget")?.unwrap_or(1) as usize,
        },
        "save" => Op::Save {
            path: req_str(req, "path", "save needs 'path'")?,
        },
        "certify" => Op::Certify {
            id: req
                .get("id")
                .and_then(|x| as_uint(x, u32::MAX as f64))
                .ok_or_else(|| bad("certify needs 'id'"))? as InstanceId,
        },
        "verify_cert" => Op::VerifyCert {
            cert: Certificate::from_wire(
                req.get("cert")
                    .filter(|c| matches!(c, Value::Obj(_)))
                    .ok_or_else(|| bad("verify_cert needs 'cert': {...}"))?,
            )?,
        },
        "load" => Op::Load {
            path: req_str(req, "path", "load needs 'path'")?,
        },
        "create" => Op::Create(CreateSpec {
            dataset: req_str(req, "dataset", "create needs 'dataset'")?,
            scale_div: opt_uint(req, "scale")?.unwrap_or(500) as usize,
            seed: match req.get("seed") {
                None => 1,
                Some(_) => req_uint(req, "seed", "'seed' must be a non-negative integer")?,
            },
            n_trees: opt_uint(req, "trees")?.map(|n| n as usize),
            max_depth: opt_uint(req, "depth")?.map(|n| n as usize),
            k: opt_uint(req, "k")?.map(|n| n as usize),
            d_rmax: opt_uint(req, "drmax")?.map(|n| n as usize),
            q: match req.get("q") {
                None => None,
                Some(v) => Some(
                    v.as_f64()
                        .filter(|q| *q > 0.0 && *q <= 1.0)
                        .ok_or_else(|| bad("'q' must be a number in (0, 1]"))?,
                ),
            },
        }),
        "pull_snapshot" => Op::PullSnapshot,
        "pull_log" => Op::PullLog {
            after_epoch: req_uint(req, "after_epoch", "pull_log needs 'after_epoch'")?,
            max_records: opt_uint(req, "max_records")?.unwrap_or(256) as usize,
        },
        "promote" => Op::Promote,
        "drop" => Op::DropModel,
        "list" => Op::List,
        "shutdown" => Op::Shutdown,
        other => return Err(bad(&format!("unknown op '{other}'"))),
    };
    Ok(Request { v, model, op })
}

/// Encode a typed [`Request`] as its wire object. v0 requests stay
/// un-namespaced (no `"v"`; `"model"` only when non-default), so the
/// typed client can also speak the legacy format. `decode ∘ encode = id`
/// (property-tested below).
pub fn encode_request(r: &Request) -> Value {
    let mut o = Value::obj();
    if r.v >= 1 {
        o.set("v", r.v).set("model", r.model.as_str());
    } else if r.model != DEFAULT_MODEL {
        o.set("model", r.model.as_str());
    }
    match &r.op {
        Op::Predict { rows } => {
            o.set("op", "predict").set(
                "rows",
                Value::Arr(
                    rows.iter()
                        .map(|row| {
                            Value::Arr(row.iter().map(|&x| Value::Num(x as f64)).collect())
                        })
                        .collect(),
                ),
            );
        }
        Op::Delete { ids } => {
            o.set("op", "delete").set("ids", ids.clone());
        }
        Op::Add { row, label } => {
            o.set("op", "add")
                .set("row", Value::Arr(row.iter().map(|&x| Value::Num(x as f64)).collect()))
                .set("label", *label as u64);
        }
        Op::DeleteCost { id } => {
            o.set("op", "delete_cost").set("id", *id);
        }
        Op::Stats => {
            o.set("op", "stats");
        }
        Op::Flush => {
            o.set("op", "flush");
        }
        Op::Compact { budget } => {
            o.set("op", "compact").set("budget", *budget);
        }
        Op::Save { path } => {
            o.set("op", "save").set("path", path.as_str());
        }
        Op::Certify { id } => {
            o.set("op", "certify").set("id", *id);
        }
        Op::VerifyCert { cert } => {
            o.set("op", "verify_cert").set("cert", cert.to_wire());
        }
        Op::Load { path } => {
            o.set("op", "load").set("path", path.as_str());
        }
        Op::Create(spec) => {
            o.set("op", "create")
                .set("dataset", spec.dataset.as_str())
                .set("scale", spec.scale_div)
                .set("seed", spec.seed);
            if let Some(t) = spec.n_trees {
                o.set("trees", t);
            }
            if let Some(d) = spec.max_depth {
                o.set("depth", d);
            }
            if let Some(k) = spec.k {
                o.set("k", k);
            }
            if let Some(r) = spec.d_rmax {
                o.set("drmax", r);
            }
            if let Some(q) = spec.q {
                o.set("q", q);
            }
        }
        Op::PullSnapshot => {
            o.set("op", "pull_snapshot");
        }
        Op::PullLog {
            after_epoch,
            max_records,
        } => {
            o.set("op", "pull_log")
                .set("after_epoch", *after_epoch)
                .set("max_records", *max_records);
        }
        Op::Promote => {
            o.set("op", "promote");
        }
        Op::DropModel => {
            o.set("op", "drop");
        }
        Op::List => {
            o.set("op", "list");
        }
        Op::Shutdown => {
            o.set("op", "shutdown");
        }
    }
    o
}

// ---------------------------------------------------------------------------
// Responses
// ---------------------------------------------------------------------------

/// One registered model's summary (the `list` op / `Client::list`).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelSummary {
    pub name: String,
    pub n_trees: usize,
    pub n_alive: usize,
    pub n_shards: usize,
    pub lazy_policy: String,
    pub dirty_subtrees: u64,
    pub pjrt_active: bool,
}

impl ModelSummary {
    pub fn to_wire(&self) -> Value {
        let mut o = Value::obj();
        o.set("name", self.name.as_str())
            .set("n_trees", self.n_trees)
            .set("n_alive", self.n_alive)
            .set("n_shards", self.n_shards)
            .set("lazy_policy", self.lazy_policy.as_str())
            .set("dirty_subtrees", self.dirty_subtrees)
            .set("pjrt_active", self.pjrt_active);
        o
    }

    pub fn from_wire(v: &Value) -> ModelSummary {
        ModelSummary {
            name: v.get("name").and_then(Value::as_str).unwrap_or("?").to_string(),
            n_trees: v.get("n_trees").and_then(Value::as_usize).unwrap_or(0),
            n_alive: v.get("n_alive").and_then(Value::as_usize).unwrap_or(0),
            n_shards: v.get("n_shards").and_then(Value::as_usize).unwrap_or(0),
            lazy_policy: v
                .get("lazy_policy")
                .and_then(Value::as_str)
                .unwrap_or("?")
                .to_string(),
            dirty_subtrees: v.get("dirty_subtrees").and_then(Value::as_u64).unwrap_or(0),
            pjrt_active: v.get("pjrt_active").and_then(Value::as_bool).unwrap_or(false),
        }
    }
}

/// A typed response, encoded by [`encode_response`].
#[derive(Clone, Debug)]
pub enum Response {
    /// Bare success (`save`, `shutdown`).
    Ok,
    Predict { probs: Vec<f32>, engine: &'static str },
    Delete(DeleteOutcome),
    Add { id: InstanceId },
    DeleteCost { cost: u64 },
    /// The complete `stats` payload (already includes `"ok":true` — built
    /// by `registry::Model::stats`, passed through verbatim).
    Stats(Value),
    /// `flush` / `compact`: retrains executed by this request.
    Flushed { flushed: u64 },
    /// `certify`: the signed deletion certificate.
    Certified(Certificate),
    /// `verify_cert`: signature check result.
    CertCheck { valid: bool },
    /// `create` / `load`: the model is registered and serving.
    ModelReady { model: String, n_trees: usize, n_alive: usize },
    Dropped { model: String },
    List { models: Vec<ModelSummary> },
    /// `pull_snapshot`: the canonical forest JSON (as a string payload)
    /// and the WAL epoch it captures (DESIGN.md §12).
    Snapshot { wal_epoch: u64, snapshot: String },
    /// `pull_log`: shipped `(epoch, request)` records past the asked-for
    /// epoch, plus where the leader's log stands. `snapshot_needed` means
    /// the window was truncated into a snapshot — re-bootstrap.
    LogWindow {
        records: Vec<(u64, Request)>,
        leader_epoch: u64,
        base_epoch: u64,
        snapshot_needed: bool,
    },
    /// `promote`: the model is now a writable leader at this epoch.
    Promoted { model: String, epoch: u64 },
    /// A follower read served beyond the staleness bound: the inner
    /// response, annotated `"stale":true` on the wire (DESIGN.md §12).
    Stale(Box<Response>),
    Err(ApiError),
}

/// The error payload: structured object plus the v0 string alias.
pub fn err_value(e: &ApiError) -> Value {
    let msg = e.to_string();
    let mut eo = Value::obj();
    eo.set("code", e.code()).set("msg", msg.as_str());
    match e {
        ApiError::UnknownModel(m) => {
            eo.set("model", m.as_str());
        }
        ApiError::ArityMismatch { got, want } => {
            eo.set("got", *got).set("want", *want);
        }
        ApiError::UnknownId(id) => {
            eo.set("id", *id);
        }
        ApiError::ReadOnly { leader } => {
            eo.set("leader", leader.as_str());
        }
        ApiError::Transport { attempts, .. } => {
            eo.set("attempts", *attempts as u64);
        }
        ApiError::Overloaded { retry_after_ms } => {
            eo.set("retry_after_ms", *retry_after_ms);
        }
        _ => {}
    }
    let mut o = Value::obj();
    o.set("ok", false).set("error", eo).set("error_msg", msg);
    o
}

/// Parse the typed error back out of a failed (`"ok":false`) response.
/// Falls back to `BadRequest` when the error object carries an unknown
/// code, and tolerates pre-v1 servers that sent a bare string.
pub fn error_from_wire(resp: &Value) -> ApiError {
    let Some(e) = resp.get("error") else {
        return ApiError::Transport {
            msg: "server returned ok=false without an error".to_string(),
            attempts: 1,
        };
    };
    if let Some(msg) = e.as_str() {
        return ApiError::BadRequest(msg.to_string());
    }
    let msg = e.get("msg").and_then(Value::as_str).unwrap_or("").to_string();
    match e.get("code").and_then(Value::as_str).unwrap_or("") {
        "unknown_model" => ApiError::UnknownModel(
            e.get("model").and_then(Value::as_str).unwrap_or("?").to_string(),
        ),
        "arity_mismatch" => ApiError::ArityMismatch {
            got: e.get("got").and_then(Value::as_usize).unwrap_or(0),
            want: e.get("want").and_then(Value::as_usize).unwrap_or(0),
        },
        "unknown_id" => {
            ApiError::UnknownId(e.get("id").and_then(Value::as_u64).unwrap_or(0) as InstanceId)
        }
        "shutting_down" => ApiError::ShuttingDown,
        "read_only" => ApiError::ReadOnly {
            leader: e.get("leader").and_then(Value::as_str).unwrap_or("").to_string(),
        },
        "overloaded" => ApiError::Overloaded {
            retry_after_ms: e.get("retry_after_ms").and_then(Value::as_u64).unwrap_or(0),
        },
        "transport" => ApiError::Transport {
            msg,
            attempts: e.get("attempts").and_then(Value::as_u64).unwrap_or(1) as u32,
        },
        _ => ApiError::BadRequest(msg),
    }
}

/// Encode a typed [`Response`] as its wire object. Field names and number
/// encodings are byte-for-byte the pre-registry (v0) payloads for every
/// data-plane op — `tests/api_compat.rs` pins this.
pub fn encode_response(r: &Response) -> Value {
    if let Response::Err(e) = r {
        return err_value(e);
    }
    if let Response::Stats(v) = r {
        return v.clone();
    }
    if let Response::Stale(inner) = r {
        let mut v = encode_response(inner);
        v.set("stale", true);
        return v;
    }
    let mut o = Value::obj();
    o.set("ok", true);
    match r {
        Response::Ok => {}
        Response::Predict { probs, engine } => {
            o.set("probs", probs.iter().map(|p| *p as f64).collect::<Vec<f64>>())
                .set("engine", *engine);
        }
        Response::Delete(out) => {
            o.set("deleted", out.deleted)
                .set("skipped", out.skipped)
                .set("retrain_cost", out.retrain_cost)
                .set("deferred", out.deferred)
                .set("batch_size", out.batch_size);
        }
        Response::Add { id } => {
            o.set("id", *id);
        }
        Response::DeleteCost { cost } => {
            o.set("cost", *cost);
        }
        Response::Flushed { flushed } => {
            o.set("flushed", *flushed);
        }
        Response::Certified(cert) => {
            o.set("cert", cert.to_wire());
        }
        Response::CertCheck { valid } => {
            o.set("valid", *valid);
        }
        Response::ModelReady {
            model,
            n_trees,
            n_alive,
        } => {
            o.set("model", model.as_str()).set("n_trees", *n_trees).set("n_alive", *n_alive);
        }
        Response::Dropped { model } => {
            o.set("model", model.as_str());
        }
        Response::List { models } => {
            o.set("models", Value::Arr(models.iter().map(ModelSummary::to_wire).collect()));
        }
        Response::Snapshot { wal_epoch, snapshot } => {
            o.set("wal_epoch", *wal_epoch).set("snapshot", snapshot.as_str());
        }
        Response::LogWindow {
            records,
            leader_epoch,
            base_epoch,
            snapshot_needed,
        } => {
            o.set(
                "records",
                Value::Arr(
                    records
                        .iter()
                        .map(|(epoch, request)| {
                            let mut rec = Value::obj();
                            rec.set("epoch", *epoch).set("request", encode_request(request));
                            rec
                        })
                        .collect(),
                ),
            )
            .set("leader_epoch", *leader_epoch)
            .set("base_epoch", *base_epoch)
            .set("snapshot_needed", *snapshot_needed);
        }
        Response::Promoted { model, epoch } => {
            o.set("model", model.as_str()).set("epoch", *epoch);
        }
        Response::Stats(_) | Response::Err(_) | Response::Stale(_) => {
            unreachable!("handled above")
        }
    }
    o
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;
    use crate::util::prop::{check, Config};
    use crate::util::rng::Rng;

    fn gen_name(rng: &mut Rng) -> String {
        // include JSON-hostile characters so the codec's escaping is in
        // the property, not just happy-path ASCII
        let pool: Vec<char> = "abcXYZ0189_-./ é\"\\\n\t".chars().collect();
        (0..1 + rng.index(12)).map(|_| pool[rng.index(pool.len())]).collect()
    }

    fn gen_row(rng: &mut Rng) -> Vec<f32> {
        (0..1 + rng.index(5)).map(|_| rng.range_f32(-8.0, 8.0)).collect()
    }

    fn opt_usize(rng: &mut Rng, max: usize) -> Option<usize> {
        if rng.bernoulli(0.5) {
            Some(rng.index(max))
        } else {
            None
        }
    }

    fn gen_request(rng: &mut Rng) -> Request {
        let v = rng.index(2) as u64;
        let model = if v == 0 && rng.bernoulli(0.5) {
            DEFAULT_MODEL.to_string()
        } else {
            gen_name(rng)
        };
        let op = match rng.index(18) {
            0 => Op::Predict {
                rows: (0..rng.index(4)).map(|_| gen_row(rng)).collect(),
            },
            1 => Op::Delete {
                ids: (0..rng.index(6)).map(|_| rng.index(10_000) as u32).collect(),
            },
            2 => Op::Add {
                row: gen_row(rng),
                label: rng.index(2) as u8,
            },
            3 => Op::DeleteCost {
                id: rng.index(10_000) as u32,
            },
            4 => Op::Stats,
            5 => Op::Flush,
            6 => Op::Compact {
                budget: rng.index(64),
            },
            7 => Op::Save {
                path: gen_name(rng),
            },
            8 => Op::Load {
                path: gen_name(rng),
            },
            9 => Op::Create(CreateSpec {
                dataset: gen_name(rng),
                scale_div: 1 + rng.index(1000),
                seed: rng.next_u64() % (1u64 << 53),
                n_trees: opt_usize(rng, 200),
                max_depth: opt_usize(rng, 30),
                k: opt_usize(rng, 100),
                d_rmax: opt_usize(rng, 6),
                // exactly-representable fractions so the JSON roundtrip is
                // bit-exact (the codec carries f64 through shortest-repr)
                q: if rng.bernoulli(0.5) {
                    Some([0.25, 0.5, 0.75, 1.0][rng.index(4)])
                } else {
                    None
                },
            }),
            10 => Op::DropModel,
            11 => Op::List,
            12 => Op::Certify {
                id: rng.index(10_000) as u32,
            },
            13 => Op::VerifyCert {
                cert: Certificate {
                    model: gen_name(rng),
                    instance_id: rng.index(10_000) as u32,
                    epoch: rng.next_u64() % (1u64 << 53),
                    snapshot_hash: gen_name(rng),
                    hmac: gen_name(rng),
                },
            },
            14 => Op::PullSnapshot,
            15 => Op::PullLog {
                after_epoch: rng.next_u64() % (1u64 << 53),
                max_records: 1 + rng.index(1024),
            },
            16 => Op::Promote,
            _ => Op::Shutdown,
        };
        Request { v, model, op }
    }

    #[test]
    fn codec_roundtrip_property() {
        // encode ∘ (serialize → parse) ∘ decode = id over generated
        // requests — the wire bytes themselves are in the loop.
        check(
            "api codec roundtrip",
            Config {
                cases: 300,
                ..Default::default()
            },
            |rng| {
                let req = gen_request(rng);
                let wire = encode_request(&req).to_string();
                let back = decode(&parse(&wire).unwrap())
                    .unwrap_or_else(|e| panic!("decode failed on {wire}: {e}"));
                assert_eq!(req, back, "roundtrip diverged through {wire}");
            },
        );
    }

    #[test]
    fn v0_requests_stay_unnamespaced() {
        let r = Request {
            v: 0,
            model: DEFAULT_MODEL.to_string(),
            op: Op::Stats,
        };
        assert_eq!(encode_request(&r).to_string(), r#"{"op":"stats"}"#);
        // and decode restores the implicit routing
        assert_eq!(decode(&parse(r#"{"op":"stats"}"#).unwrap()).unwrap(), r);
    }

    #[test]
    fn decode_rejects_malformed_inputs_with_bad_request() {
        for (src, expect) in [
            (r#"[1,2]"#, "request must be a JSON object"),
            (r#"{"v":"one","op":"stats"}"#, "'v' must be a non-negative integer"),
            (r#"{"v":1.5,"op":"stats"}"#, "'v' must be a non-negative integer"),
            (r#"{"v":99,"op":"stats"}"#, "unsupported wire version"),
            (r#"{"model":7,"op":"stats"}"#, "'model' must be a string"),
            (r#"{}"#, "request needs 'op'"),
            (r#"{"op":"frobnicate"}"#, "unknown op"),
            (r#"{"op":"predict"}"#, "predict needs 'rows'"),
            (r#"{"op":"predict","rows":[7]}"#, "rows must be arrays of numbers"),
            (r#"{"op":"predict","rows":[["x"]]}"#, "row cells must be numbers"),
            (r#"{"op":"delete"}"#, "delete needs 'ids'"),
            (r#"{"op":"delete","ids":[-1]}"#, "ids must be non-negative integers"),
            (r#"{"op":"delete","ids":[1.5]}"#, "ids must be non-negative integers"),
            (r#"{"op":"add","row":[1.0]}"#, "add needs 'label'"),
            (r#"{"op":"add","row":[1.0],"label":5}"#, "label must be 0 or 1"),
            (r#"{"op":"add","label":1}"#, "add needs 'row'"),
            (r#"{"op":"delete_cost"}"#, "delete_cost needs 'id'"),
            (r#"{"op":"save"}"#, "save needs 'path'"),
            (r#"{"op":"load"}"#, "load needs 'path'"),
            (r#"{"op":"create"}"#, "create needs 'dataset'"),
            (r#"{"op":"create","dataset":"surgical","q":0}"#, "'q' must be a number in (0, 1]"),
            (r#"{"op":"create","dataset":"surgical","q":1.5}"#, "'q' must be a number in (0, 1]"),
            (r#"{"op":"create","dataset":"surgical","q":"x"}"#, "'q' must be a number in (0, 1]"),
            (r#"{"op":"compact","budget":-2}"#, "'budget' must be a non-negative integer"),
            (r#"{"op":"pull_log"}"#, "pull_log needs 'after_epoch'"),
            (r#"{"op":"pull_log","after_epoch":-1}"#, "pull_log needs 'after_epoch'"),
            (
                r#"{"op":"pull_log","after_epoch":3,"max_records":-2}"#,
                "'max_records' must be a non-negative integer",
            ),
            (r#"{"op":"certify"}"#, "certify needs 'id'"),
            (r#"{"op":"certify","id":-3}"#, "certify needs 'id'"),
            (r#"{"op":"verify_cert"}"#, "verify_cert needs 'cert'"),
            (r#"{"op":"verify_cert","cert":"sig"}"#, "verify_cert needs 'cert'"),
            (r#"{"op":"verify_cert","cert":{"model":"m"}}"#, "cert needs 'instance_id'"),
            (
                r#"{"op":"verify_cert","cert":{"model":"m","instance_id":1,"epoch":2,"hmac":"ab"}}"#,
                "cert needs 'snapshot_hash'",
            ),
        ] {
            match decode(&parse(src).unwrap()) {
                Err(ApiError::BadRequest(msg)) => {
                    assert!(msg.contains(expect), "{src}: got '{msg}', want '{expect}'")
                }
                other => panic!("{src}: expected BadRequest, got {other:?}"),
            }
        }
    }

    #[test]
    fn deadline_ms_is_metadata_not_part_of_the_request() {
        let with = parse(r#"{"v":1,"model":"m","op":"stats","deadline_ms":250}"#).unwrap();
        let without = parse(r#"{"v":1,"model":"m","op":"stats"}"#).unwrap();
        assert_eq!(deadline_ms(&with).unwrap(), Some(250));
        assert_eq!(deadline_ms(&without).unwrap(), None);
        // decode is blind to the key: same typed request either way, so
        // nothing downstream (WAL, replication) can ever see a deadline.
        assert_eq!(decode(&with).unwrap(), decode(&without).unwrap());
        assert!(deadline_ms(&parse(r#"{"op":"stats","deadline_ms":-5}"#).unwrap()).is_err());
        assert!(deadline_ms(&parse(r#"{"op":"stats","deadline_ms":"soon"}"#).unwrap()).is_err());
    }

    #[test]
    fn error_wire_roundtrip_every_variant() {
        for e in [
            ApiError::BadRequest("nope".to_string()),
            ApiError::UnknownModel("ghost".to_string()),
            ApiError::ArityMismatch { got: 1, want: 5 },
            ApiError::UnknownId(42),
            ApiError::ShuttingDown,
            ApiError::ReadOnly {
                leader: "10.0.0.1:7878".to_string(),
            },
            ApiError::Overloaded { retry_after_ms: 120 },
            ApiError::Transport {
                msg: "pipe broke".to_string(),
                attempts: 3,
            },
        ] {
            let v = err_value(&e);
            assert_eq!(v.get("ok").and_then(Value::as_bool), Some(false));
            let eo = v.get("error").unwrap();
            assert_eq!(eo.get("code").and_then(Value::as_str), Some(e.code()));
            // the v0 alias mirrors the structured message exactly
            assert_eq!(
                v.get("error_msg").and_then(Value::as_str),
                Some(e.to_string().as_str())
            );
            // and the bytes parse back into the same typed variant
            let back = error_from_wire(&parse(&v.to_string()).unwrap());
            assert_eq!(back, e);
        }
    }

    #[test]
    fn data_plane_response_payloads_keep_v0_field_names() {
        let r = encode_response(&Response::Predict {
            probs: vec![0.5],
            engine: "native",
        });
        assert_eq!(r.to_string(), r#"{"engine":"native","ok":true,"probs":[0.5]}"#);
        let r = encode_response(&Response::Delete(DeleteOutcome {
            requested: 3,
            deleted: 2,
            skipped: 1,
            retrain_cost: 40,
            deferred: 0,
            batch_size: 1,
        }));
        assert_eq!(
            r.to_string(),
            r#"{"batch_size":1,"deferred":0,"deleted":2,"ok":true,"retrain_cost":40,"skipped":1}"#
        );
        assert_eq!(
            encode_response(&Response::Add { id: 7 }).to_string(),
            r#"{"id":7,"ok":true}"#
        );
        assert_eq!(
            encode_response(&Response::DeleteCost { cost: 11 }).to_string(),
            r#"{"cost":11,"ok":true}"#
        );
        assert_eq!(encode_response(&Response::Ok).to_string(), r#"{"ok":true}"#);
    }

    #[test]
    fn certificate_wire_roundtrip_and_response_shape() {
        let cert = Certificate {
            model: "eu-prod".to_string(),
            instance_id: 42,
            epoch: 17,
            snapshot_hash: "ab12".to_string(),
            hmac: "cd34".to_string(),
        };
        let back = Certificate::from_wire(&parse(&cert.to_wire().to_string()).unwrap()).unwrap();
        assert_eq!(back, cert);
        assert_eq!(
            encode_response(&Response::Certified(cert)).to_string(),
            concat!(
                r#"{"cert":{"epoch":17,"hmac":"cd34","instance_id":42,"#,
                r#""model":"eu-prod","snapshot_hash":"ab12"},"ok":true}"#
            )
        );
        assert_eq!(
            encode_response(&Response::CertCheck { valid: true }).to_string(),
            r#"{"ok":true,"valid":true}"#
        );
    }

    #[test]
    fn replication_response_shapes() {
        assert_eq!(
            encode_response(&Response::Snapshot {
                wal_epoch: 4,
                snapshot: r#"{"t":1}"#.to_string(),
            })
            .to_string(),
            r#"{"ok":true,"snapshot":"{\"t\":1}","wal_epoch":4}"#
        );
        let window = Response::LogWindow {
            records: vec![(
                5,
                Request {
                    v: 1,
                    model: "m".to_string(),
                    op: Op::Delete { ids: vec![7] },
                },
            )],
            leader_epoch: 9,
            base_epoch: 2,
            snapshot_needed: false,
        };
        assert_eq!(
            encode_response(&window).to_string(),
            concat!(
                r#"{"base_epoch":2,"leader_epoch":9,"ok":true,"records":"#,
                r#"[{"epoch":5,"request":{"ids":[7],"model":"m","op":"delete","v":1}}],"#,
                r#""snapshot_needed":false}"#
            )
        );
        assert_eq!(
            encode_response(&Response::Promoted {
                model: "m".to_string(),
                epoch: 9,
            })
            .to_string(),
            r#"{"epoch":9,"model":"m","ok":true}"#
        );
        // staleness annotation wraps the inner payload without renaming it
        let stale = Response::Stale(Box::new(Response::Predict {
            probs: vec![0.5],
            engine: "native",
        }));
        assert_eq!(
            encode_response(&stale).to_string(),
            r#"{"engine":"native","ok":true,"probs":[0.5],"stale":true}"#
        );
    }

    #[test]
    fn model_summary_wire_roundtrip() {
        let s = ModelSummary {
            name: "eu-prod".to_string(),
            n_trees: 10,
            n_alive: 900,
            n_shards: 4,
            lazy_policy: "on_read".to_string(),
            dirty_subtrees: 3,
            pjrt_active: false,
        };
        assert_eq!(ModelSummary::from_wire(&parse(&s.to_wire().to_string()).unwrap()), s);
    }
}
