//! Deadline-aware cross-tenant scheduler (DESIGN.md §15).
//!
//! A serving runtime between the wire and the per-model data plane: every
//! scheduled op becomes a *ticket* (tenant, op class, batch-size bucket,
//! optional deadline, arrival time) in a per-tenant FIFO queue, and a
//! time-budgeted [`Scheduler::run_for`] packs queued work into a latency
//! budget using learned per-(tenant, class, bucket) Welford cost
//! estimators — deadline-first (EDF) among tenants holding deadlined
//! tickets, deficit round-robin (DRR) by tenant weight among the rest.
//! Work that does not fit stays queued; the budget is never knowingly
//! blown (`run_for(d)` overruns `d` by at most one ticket's *predicted*
//! cost — the one progress-guaranteeing dispatch per cycle).
//!
//! **Exactness is untouched.** The scheduler reorders *when* work runs
//! across tenants, never *what* one tenant's op stream contains: within a
//! tenant, scheduled ops (predict / delete / add / delete_cost / flush /
//! compact) execute in exact submission order, through the same
//! `UnlearningService::handle` path as unscheduled traffic. So every
//! §8/§9/§13 differential oracle applies verbatim to scheduled execution
//! (proven by the op_fuzz scheduler leg). Background compaction tickets
//! are the one out-of-FIFO insertion, and those are order-free by
//! flush-order invariance (§9).
//!
//! **Admission control.** Each tenant's foreground queue is depth-bounded;
//! past the bound, submission is refused with the structured
//! `ApiError::Overloaded { retry_after_ms }` where the hint is the
//! predicted drain time of the queue.
//!
//! **Testability.** Time and execution are injected: the real deployment
//! uses a monotonic clock and `svc.handle`, the unit suite a manual clock
//! plus a synthetic executor whose cost *is* the prediction — which turns
//! the budget-overrun bound, EDF order, and DRR ratios into exact,
//! wall-clock-free assertions.

use crate::coordinator::api::{
    self, decode, encode_request, err_value, ApiError, Op, Request, WIRE_VERSION,
};
use crate::coordinator::service::UnlearningService;
use crate::coordinator::telemetry::Telemetry;
use crate::util::histogram::Histogram;
use crate::util::json::Value;
use crate::util::stats::Welford;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// Op classes and batch-size buckets
// ---------------------------------------------------------------------------

/// The scheduled op classes. Reads (`predict`, `delete_cost`) are Predict;
/// `delete`/`add` are Mutate; `flush`/`compact` are their own classes
/// because their cost scales with the dirty set, not the request payload.
/// Everything else on the wire (stats, lifecycle, replication, certify,
/// save, shutdown) bypasses the queue and executes immediately — none of
/// those mutate a tenant's op stream, so FIFO is unaffected.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum OpClass {
    Predict,
    Mutate,
    Flush,
    Compact,
}

impl OpClass {
    pub fn name(self) -> &'static str {
        match self {
            OpClass::Predict => "predict",
            OpClass::Mutate => "mutate",
            OpClass::Flush => "flush",
            OpClass::Compact => "compact",
        }
    }
}

/// log2 batch-size bucket: requests with 1 row and 1000 rows should not
/// share a cost estimate, but per-exact-size estimators would never
/// converge.
fn bucket_of(n: usize) -> usize {
    let mut b = 0usize;
    let mut x = n.max(1);
    while x > 1 {
        x >>= 1;
        b += 1;
    }
    b
}

/// Class + bucket for a scheduled op; `None` for bypass (immediate) ops.
fn class_of(op: &Op) -> Option<(OpClass, usize)> {
    match op {
        Op::Predict { rows } => Some((OpClass::Predict, bucket_of(rows.len()))),
        Op::DeleteCost { .. } => Some((OpClass::Predict, 0)),
        Op::Delete { ids } => Some((OpClass::Mutate, bucket_of(ids.len()))),
        Op::Add { .. } => Some((OpClass::Mutate, 0)),
        Op::Flush => Some((OpClass::Flush, 0)),
        Op::Compact { .. } => Some((OpClass::Compact, 0)),
        _ => None,
    }
}

// ---------------------------------------------------------------------------
// Injected clock
// ---------------------------------------------------------------------------

/// A hand-advanced clock for deterministic scheduling tests.
#[derive(Clone, Default)]
pub struct ManualClock(Arc<AtomicU64>);

impl ManualClock {
    pub fn now(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::SeqCst))
    }
    pub fn advance(&self, seconds: f64) {
        let _ = self.0.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |bits| {
            Some((f64::from_bits(bits) + seconds).to_bits())
        });
    }
}

/// Scheduler time source: monotonic in production, manual in tests.
#[derive(Clone)]
pub enum Clock {
    Real(Instant),
    Manual(ManualClock),
}

impl Clock {
    pub fn real() -> Clock {
        Clock::Real(Instant::now())
    }
    pub fn manual() -> (Clock, ManualClock) {
        let m = ManualClock::default();
        (Clock::Manual(m.clone()), m)
    }
    fn now(&self) -> f64 {
        match self {
            Clock::Real(t0) => t0.elapsed().as_secs_f64(),
            Clock::Manual(m) => m.now(),
        }
    }
}

// ---------------------------------------------------------------------------
// Configuration
// ---------------------------------------------------------------------------

/// Scheduler tuning. Weights and quantum drive DRR; safety/min_samples/
/// default_cost drive the cost predictor.
#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// Default per-cycle budget for the background runner thread.
    pub budget: Duration,
    /// Per-tenant foreground queue depth bound; 0 = unbounded.
    pub queue_depth: usize,
    /// Per-tenant DRR weights (`--fairness a=2,b=1`); absent tenants get 1.
    pub weights: BTreeMap<String, f64>,
    /// Seconds of deficit credited per weight unit per replenish round.
    pub quantum: f64,
    /// Predicted cost = mean + `safety`·std (one-sided headroom).
    pub safety: f64,
    /// Bucket estimators are trusted once they hold this many samples;
    /// below that the per-(tenant, class) aggregate answers.
    pub min_samples: u64,
    /// Prior for a never-observed (tenant, class): 100 µs.
    pub default_cost: f64,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            budget: Duration::from_millis(10),
            queue_depth: 1024,
            weights: BTreeMap::new(),
            quantum: 0.002,
            safety: 1.0,
            min_samples: 8,
            default_cost: 100e-6,
        }
    }
}

impl SchedulerConfig {
    /// Parse a `--fairness` spec: `tenant=weight,tenant=weight,...`.
    pub fn parse_weights(spec: &str) -> Result<BTreeMap<String, f64>, String> {
        let mut out = BTreeMap::new();
        for part in spec.split(',').filter(|p| !p.is_empty()) {
            let (name, w) = part
                .split_once('=')
                .ok_or_else(|| format!("fairness entry '{part}' is not tenant=weight"))?;
            let w: f64 = w
                .parse()
                .map_err(|_| format!("fairness weight '{w}' is not a number"))?;
            if !(w > 0.0) || !w.is_finite() {
                return Err(format!("fairness weight for '{name}' must be finite and > 0"));
            }
            out.insert(name.to_string(), w);
        }
        Ok(out)
    }

    fn weight_for(&self, tenant: &str) -> f64 {
        self.weights.get(tenant).copied().unwrap_or(1.0).max(1e-6)
    }
}

// ---------------------------------------------------------------------------
// Learned timing model
// ---------------------------------------------------------------------------

/// Two-level Welford cost model: fine per-(tenant, class, bucket)
/// estimators backed by a per-(tenant, class) aggregate that also absorbs
/// seed moments from PR 9's telemetry Welfords and latency histograms.
struct TimingModel {
    safety: f64,
    min_samples: u64,
    default_cost: f64,
    buckets: BTreeMap<(String, OpClass, usize), Welford>,
    agg: BTreeMap<(String, OpClass), Welford>,
}

impl TimingModel {
    fn new(cfg: &SchedulerConfig) -> TimingModel {
        TimingModel {
            safety: cfg.safety,
            min_samples: cfg.min_samples.max(1),
            default_cost: cfg.default_cost,
            buckets: BTreeMap::new(),
            agg: BTreeMap::new(),
        }
    }

    fn observe(&mut self, tenant: &str, class: OpClass, bucket: usize, cost: f64) {
        self.buckets
            .entry((tenant.to_string(), class, bucket))
            .or_insert_with(Welford::new)
            .push(cost);
        self.agg
            .entry((tenant.to_string(), class))
            .or_insert_with(Welford::new)
            .push(cost);
    }

    /// Merge external moments into the aggregate (seeding, not samples).
    fn seed(&mut self, tenant: &str, class: OpClass, w: &Welford) {
        self.agg
            .entry((tenant.to_string(), class))
            .or_insert_with(Welford::new)
            .merge(w);
    }

    fn predict(&self, tenant: &str, class: OpClass, bucket: usize) -> f64 {
        if let Some(w) = self.buckets.get(&(tenant.to_string(), class, bucket)) {
            if w.n >= self.min_samples {
                return (w.mean() + self.safety * w.std()).max(1e-9);
            }
        }
        if let Some(w) = self.agg.get(&(tenant.to_string(), class)) {
            if w.n > 0 {
                return (w.mean() + self.safety * w.std()).max(1e-9);
            }
        }
        self.default_cost
    }
}

// ---------------------------------------------------------------------------
// Tickets and queues
// ---------------------------------------------------------------------------

struct Ticket {
    /// Global submission counter — the FIFO tiebreak.
    seq: u64,
    class: OpClass,
    bucket: usize,
    /// The raw wire object, executed verbatim through the injected
    /// executor (production: `svc.handle`) — same path as direct traffic.
    wire: Value,
    /// Absolute scheduler-clock deadline (seconds), if the caller set one.
    deadline: Option<f64>,
    /// Scheduler-clock submission time — queue-wait accounting.
    arrival: f64,
    reply: Option<Sender<Value>>,
    background: bool,
}

#[derive(Default)]
struct TenantQ {
    fg: VecDeque<Ticket>,
    bg: VecDeque<Ticket>,
    weight: f64,
    deficit: f64,
    executed: u64,
    executed_bg: u64,
    /// Total queue wait (arrival → dispatch) across executed tickets.
    waited_s: f64,
    compact_ticks: u64,
    compact_spent_s: f64,
    overloaded: u64,
}

struct Inner {
    queues: BTreeMap<String, TenantQ>,
    seq: u64,
    cursor: usize,
}

/// `(tenant, background?, predicted cost)` — what `choose` hands `run_for`.
struct Choice {
    tenant: String,
    background: bool,
    predicted: f64,
}

/// Outcome of [`Scheduler::submit`].
pub enum Submitted {
    /// The op was enqueued; the receiver yields its response once executed.
    Queued(Receiver<Value>),
    /// A bypass (control-plane) op, executed inline.
    Immediate(Value),
}

/// Per-cycle accounting from [`Scheduler::run_for`]. `spent_s` is measured
/// on the scheduler's own clock, so `spent_s ≤ budget_s + last_cost_s`
/// holds by construction and `spent_s ≤ budget_s + last_predicted_s`
/// whenever predictions are exact (the virtual-clock unit suite).
#[derive(Clone, Debug, Default)]
pub struct RunReport {
    pub budget_s: f64,
    pub spent_s: f64,
    pub executed: u64,
    pub executed_bg: u64,
    /// Measured cost of the last executed ticket.
    pub last_cost_s: f64,
    /// Predicted cost of the last executed ticket.
    pub last_predicted_s: f64,
    /// True when work remained but would have blown the budget.
    pub deferred: bool,
    /// Tickets still queued when the cycle ended.
    pub remaining: usize,
}

// ---------------------------------------------------------------------------
// The scheduler
// ---------------------------------------------------------------------------

type Exec = Box<dyn Fn(&Value) -> Value + Send + Sync>;

pub struct Scheduler {
    cfg: SchedulerConfig,
    clock: Clock,
    exec: Exec,
    timing: Mutex<TimingModel>,
    inner: Mutex<Inner>,
    /// Serializes `run_for` cycles (runner thread vs. ad-hoc callers).
    run_lock: Mutex<()>,
    park: Mutex<()>,
    parked: Condvar,
    stop: AtomicBool,
}

impl Scheduler {
    /// Build a scheduler over an injected clock + executor. Production code
    /// uses [`Scheduler::attach`]; tests inject a manual clock and a
    /// synthetic executor.
    pub fn new(cfg: SchedulerConfig, clock: Clock, exec: Exec) -> Scheduler {
        let timing = TimingModel::new(&cfg);
        Scheduler {
            cfg,
            clock,
            exec,
            timing: Mutex::new(timing),
            inner: Mutex::new(Inner {
                queues: BTreeMap::new(),
                seq: 0,
                cursor: 0,
            }),
            run_lock: Mutex::new(()),
            park: Mutex::new(()),
            parked: Condvar::new(),
            stop: AtomicBool::new(false),
        }
    }

    /// Wire a scheduler onto a live service: execution routes through
    /// `svc.handle` (the exact unscheduled path), cost estimators are
    /// seeded from every registered model's telemetry Welfords, and the
    /// service learns the scheduler so `serve` and the compactor route
    /// through it.
    pub fn attach(svc: &Arc<UnlearningService>, cfg: SchedulerConfig) -> Arc<Scheduler> {
        let exec_svc = Arc::clone(svc);
        let sched = Arc::new(Scheduler::new(
            cfg,
            Clock::real(),
            Box::new(move |v| exec_svc.handle(v)),
        ));
        for model in svc.registry().models() {
            sched.seed_from_telemetry(model.name(), model.telemetry());
        }
        svc.attach_scheduler(Arc::downgrade(&sched));
        sched
    }

    /// Spawn the serving loop: drains queued work in `cfg.budget` cycles,
    /// parking when idle. Exits when the scheduler is dropped or stopped.
    pub fn spawn_runner(sched: &Arc<Scheduler>) {
        let weak = Arc::downgrade(sched);
        let _ = std::thread::Builder::new()
            .name("dare-scheduler".into())
            .spawn(move || loop {
                let Some(s) = weak.upgrade() else { return };
                if s.stop.load(Ordering::SeqCst) {
                    return;
                }
                if s.queued_total() == 0 {
                    let guard = s.park.lock().unwrap();
                    let _ = s
                        .parked
                        .wait_timeout(guard, Duration::from_millis(10))
                        .unwrap();
                    continue;
                }
                let budget = s.cfg.budget;
                s.run_for(budget);
            });
    }

    /// Ask the runner (and any parked waiters) to wind down.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        self.parked.notify_all();
    }

    // -- seeding ---------------------------------------------------------

    /// Fold a model's exact telemetry Welfords into the aggregate cost
    /// estimators (op name → class map mirrors `class_of`).
    pub fn seed_from_telemetry(&self, tenant: &str, t: &Telemetry) {
        const MAP: &[(&str, OpClass)] = &[
            ("predict", OpClass::Predict),
            ("delete_cost", OpClass::Predict),
            ("delete", OpClass::Mutate),
            ("add", OpClass::Mutate),
            ("flush", OpClass::Flush),
            ("compact", OpClass::Compact),
        ];
        let mut timing = self.timing.lock().unwrap();
        for (op, class) in MAP {
            if let Some(w) = t.op_latency(op) {
                timing.seed(tenant, *class, &w);
            }
        }
    }

    /// Seed from a latency histogram (the cross-process artifact): exact
    /// count/mean, bucket-midpoint variance (`Histogram::approx_moments`).
    pub fn seed_from_histogram(&self, tenant: &str, class: OpClass, h: &Histogram) {
        let (n, mean, var) = h.approx_moments();
        let w = Welford::from_moments(n, mean, var, h.min(), h.max());
        self.timing.lock().unwrap().seed(tenant, class, &w);
    }

    /// Current predicted cost (seconds) — test/observability hook.
    pub fn predicted_cost(&self, tenant: &str, class: OpClass, bucket: usize) -> f64 {
        self.timing.lock().unwrap().predict(tenant, class, bucket)
    }

    // -- submission ------------------------------------------------------

    /// Decode + classify + enqueue one wire request. Scheduled ops return
    /// a receiver for their eventual response; bypass ops execute inline.
    /// Refuses with `Overloaded` past the tenant's queue-depth bound.
    pub fn submit(&self, req: &Value) -> Result<Submitted, ApiError> {
        let parsed = decode(req)?;
        let deadline = api::deadline_ms(req)?;
        let Some((class, bucket)) = class_of(&parsed.op) else {
            return Ok(Submitted::Immediate((self.exec)(req)));
        };
        let now = self.clock.now();
        let rx = {
            let timing = self.timing.lock().unwrap();
            let mut inner = self.inner.lock().unwrap();
            inner.seq += 1;
            let seq = inner.seq;
            let weight = self.cfg.weight_for(&parsed.model);
            let q = inner.queues.entry(parsed.model.clone()).or_insert_with(|| TenantQ {
                weight,
                ..Default::default()
            });
            if self.cfg.queue_depth > 0 && q.fg.len() >= self.cfg.queue_depth {
                q.overloaded += 1;
                let drain: f64 = q
                    .fg
                    .iter()
                    .map(|t| timing.predict(&parsed.model, t.class, t.bucket))
                    .sum();
                return Err(ApiError::Overloaded {
                    retry_after_ms: (drain * 1000.0).ceil().max(1.0) as u64,
                });
            }
            let (tx, rx) = channel();
            q.fg.push_back(Ticket {
                seq,
                class,
                bucket,
                wire: req.clone(),
                deadline: deadline.map(|ms| now + ms as f64 / 1000.0),
                arrival: now,
                reply: Some(tx),
                background: false,
            });
            rx
        };
        self.parked.notify_all();
        Ok(Submitted::Queued(rx))
    }

    /// Blocking wire entry point — what `protocol::serve` calls when a
    /// scheduler is attached. Scheduled ops wait for their turn in the
    /// budget; everything else is served immediately.
    pub fn handle(&self, req: &Value) -> Value {
        match self.submit(req) {
            Err(e) => err_value(&e),
            Ok(Submitted::Immediate(v)) => v,
            Ok(Submitted::Queued(rx)) => rx
                .recv()
                .unwrap_or_else(|_| err_value(&ApiError::ShuttingDown)),
        }
    }

    /// Enqueue a background compaction bid for `model` (from the
    /// compactor thread). Background tickets run only in slack — when no
    /// foreground ticket is queued anywhere — and at most one bid per
    /// tenant is outstanding. Returns false if a bid is already queued.
    pub fn bid_compact(&self, model: &str, budget: usize) -> bool {
        let wire = encode_request(&Request {
            v: WIRE_VERSION,
            model: model.to_string(),
            op: Op::Compact { budget },
        });
        {
            let mut inner = self.inner.lock().unwrap();
            inner.seq += 1;
            let seq = inner.seq;
            let weight = self.cfg.weight_for(model);
            let q = inner.queues.entry(model.to_string()).or_insert_with(|| TenantQ {
                weight,
                ..Default::default()
            });
            if !q.bg.is_empty() {
                return false;
            }
            q.bg.push_back(Ticket {
                seq,
                class: OpClass::Compact,
                bucket: 0,
                wire,
                deadline: None,
                arrival: self.clock.now(),
                reply: None,
                background: true,
            });
        }
        self.parked.notify_all();
        true
    }

    // -- the budget-packing loop ----------------------------------------

    /// Execute queued tickets for up to `budget`, EDF-then-DRR, leaving
    /// the remainder queued. The first ticket of a cycle always runs
    /// (progress guarantee); afterwards a ticket is dispatched only if
    /// `spent + predicted ≤ budget` — hence the one-predicted-cost
    /// overrun bound.
    pub fn run_for(&self, budget: Duration) -> RunReport {
        let _cycle = self.run_lock.lock().unwrap();
        let budget_s = budget.as_secs_f64();
        let t0 = self.clock.now();
        let mut report = RunReport {
            budget_s,
            ..Default::default()
        };
        loop {
            let popped = {
                let timing = self.timing.lock().unwrap();
                let mut inner = self.inner.lock().unwrap();
                let Some(choice) = choose(&mut inner, &timing, &self.cfg) else {
                    break;
                };
                let spent = self.clock.now() - t0;
                if report.executed > 0 && spent + choice.predicted > budget_s {
                    report.deferred = true;
                    break; // nothing popped: the ticket stays at its head
                }
                let q = inner.queues.get_mut(&choice.tenant).unwrap();
                let ticket = if choice.background {
                    q.bg.pop_front()
                } else {
                    q.fg.pop_front()
                }
                .expect("choose returned a tenant with an empty queue");
                if !choice.background {
                    q.deficit -= choice.predicted;
                    if q.fg.is_empty() {
                        q.deficit = 0.0;
                    }
                }
                (ticket, choice)
            };
            let (ticket, choice) = popped;
            let t_start = self.clock.now();
            let resp = (self.exec)(&ticket.wire);
            let dt = self.clock.now() - t_start;
            self.timing
                .lock()
                .unwrap()
                .observe(&choice.tenant, ticket.class, ticket.bucket, dt);
            {
                let mut inner = self.inner.lock().unwrap();
                if let Some(q) = inner.queues.get_mut(&choice.tenant) {
                    q.executed += 1;
                    q.waited_s += (t_start - ticket.arrival).max(0.0);
                    if ticket.background {
                        q.executed_bg += 1;
                        if ticket.class == OpClass::Compact {
                            q.compact_ticks += 1;
                            q.compact_spent_s += dt;
                        }
                    }
                }
            }
            if let Some(tx) = ticket.reply {
                let _ = tx.send(resp);
            }
            report.executed += 1;
            if ticket.background {
                report.executed_bg += 1;
            }
            report.last_cost_s = dt;
            report.last_predicted_s = choice.predicted;
        }
        report.spent_s = self.clock.now() - t0;
        report.remaining = self.queued_total();
        report
    }

    // -- observability ---------------------------------------------------

    /// Total queued tickets (foreground + background) across tenants.
    pub fn queued_total(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        inner.queues.values().map(|q| q.fg.len() + q.bg.len()).sum()
    }

    /// Queued foreground tickets for one tenant.
    pub fn queued(&self, tenant: &str) -> usize {
        let inner = self.inner.lock().unwrap();
        inner.queues.get(tenant).map(|q| q.fg.len()).unwrap_or(0)
    }

    /// True if a background bid is outstanding for `tenant`.
    pub fn pending_bid(&self, tenant: &str) -> bool {
        let inner = self.inner.lock().unwrap();
        inner.queues.get(tenant).map(|q| !q.bg.is_empty()).unwrap_or(false)
    }

    /// The per-tenant `"sched"` object attached to `stats` payloads:
    /// queue depths, DRR state, executed/compaction accounting.
    pub fn tenant_stats(&self, tenant: &str) -> Value {
        let inner = self.inner.lock().unwrap();
        let mut o = Value::obj();
        match inner.queues.get(tenant) {
            None => {
                o.set("queued", 0u64).set("queued_bg", 0u64);
            }
            Some(q) => {
                o.set("queued", q.fg.len())
                    .set("queued_bg", q.bg.len())
                    .set("weight", q.weight)
                    .set("deficit_s", q.deficit)
                    .set("executed", q.executed)
                    .set("executed_bg", q.executed_bg)
                    .set("waited_s", q.waited_s)
                    .set("compact_ticks", q.compact_ticks)
                    .set("compact_spent_s", q.compact_spent_s)
                    .set("overloaded", q.overloaded);
            }
        }
        o
    }
}

/// Pick the next tenant to serve. EDF first: among tenants whose
/// foreground queue holds any deadlined ticket, the earliest effective
/// deadline (min over the queue — the deadline pulls the whole tenant
/// queue forward, in-tenant priority inheritance) wins and its HEAD runs
/// (per-tenant FIFO is inviolable). Otherwise DRR: a tenant is eligible
/// when its deficit covers its head's predicted cost; when none is,
/// every contending tenant is replenished `weight·quantum` and the scan
/// repeats — each round strictly grows every deficit, so the loop
/// terminates and no weighted tenant starves. Background tickets run
/// only when no foreground work exists anywhere.
fn choose(inner: &mut Inner, timing: &TimingModel, cfg: &SchedulerConfig) -> Option<Choice> {
    // EDF pass.
    let mut best: Option<(f64, u64, String)> = None;
    for (name, q) in inner.queues.iter() {
        if q.fg.is_empty() {
            continue;
        }
        let dl = q
            .fg
            .iter()
            .filter_map(|t| t.deadline)
            .fold(f64::INFINITY, f64::min);
        if dl.is_finite() {
            let head_seq = q.fg.front().unwrap().seq;
            let better = match &best {
                None => true,
                Some((bd, bs, _)) => dl < *bd || (dl == *bd && head_seq < *bs),
            };
            if better {
                best = Some((dl, head_seq, name.clone()));
            }
        }
    }
    if let Some((_, _, tenant)) = best {
        let q = &inner.queues[&tenant];
        let head = q.fg.front().unwrap();
        let predicted = timing.predict(&tenant, head.class, head.bucket);
        return Some(Choice {
            tenant,
            background: false,
            predicted,
        });
    }

    // DRR pass over tenants with foreground work.
    let names: Vec<String> = inner
        .queues
        .iter()
        .filter(|(_, q)| !q.fg.is_empty())
        .map(|(n, _)| n.clone())
        .collect();
    if !names.is_empty() {
        let preds: Vec<f64> = names
            .iter()
            .map(|n| {
                let head = inner.queues[n].fg.front().unwrap();
                timing.predict(n, head.class, head.bucket)
            })
            .collect();
        let n = names.len();
        for _round in 0..100_000 {
            for i in 0..n {
                let idx = (inner.cursor + i) % n;
                if inner.queues[&names[idx]].deficit >= preds[idx] {
                    inner.cursor = (idx + 1) % n;
                    return Some(Choice {
                        tenant: names[idx].clone(),
                        background: false,
                        predicted: preds[idx],
                    });
                }
            }
            for name in &names {
                let q = inner.queues.get_mut(name).unwrap();
                q.deficit += q.weight * cfg.quantum.max(1e-9);
            }
        }
        // Degenerate floats only: serve the deepest deficit rather than spin.
        let idx = (0..n)
            .max_by(|&a, &b| {
                inner.queues[&names[a]]
                    .deficit
                    .partial_cmp(&inner.queues[&names[b]].deficit)
                    .unwrap_or(std::cmp::Ordering::Equal)
            })
            .unwrap();
        inner.cursor = (idx + 1) % n;
        return Some(Choice {
            tenant: names[idx].clone(),
            background: false,
            predicted: preds[idx],
        });
    }

    // Slack: the oldest background bid across tenants.
    let mut best_bg: Option<(u64, String)> = None;
    for (name, q) in inner.queues.iter() {
        if let Some(t) = q.bg.front() {
            if best_bg.as_ref().map_or(true, |(s, _)| t.seq < *s) {
                best_bg = Some((t.seq, name.clone()));
            }
        }
    }
    let (_, tenant) = best_bg?;
    let head = inner.queues[&tenant].bg.front().unwrap();
    let predicted = timing.predict(&tenant, head.class, head.bucket);
    Some(Choice {
        tenant,
        background: true,
        predicted,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{mix_seed, Rng};

    /// Synthetic harness: manual clock + an executor whose cost is a pure
    /// function of the tenant, so predictions converge to the exact cost
    /// (constant ⇒ zero variance) and every scheduling assertion is
    /// deterministic and wall-clock-free.
    fn mk(
        cfg: SchedulerConfig,
        costs: &[(&str, f64)],
    ) -> (Scheduler, ManualClock, Arc<Mutex<Vec<String>>>) {
        let (clock, manual) = Clock::manual();
        let costs: BTreeMap<String, f64> =
            costs.iter().map(|(n, c)| (n.to_string(), *c)).collect();
        let log = Arc::new(Mutex::new(Vec::new()));
        let log2 = Arc::clone(&log);
        let m2 = manual.clone();
        let exec: Exec = Box::new(move |req: &Value| {
            let model = req
                .get("model")
                .and_then(Value::as_str)
                .unwrap_or("default")
                .to_string();
            m2.advance(costs.get(&model).copied().unwrap_or(0.001));
            log2.lock().unwrap().push(req.to_string());
            let mut o = Value::obj();
            o.set("ok", true);
            o
        });
        (Scheduler::new(cfg, clock, exec), manual, log)
    }

    fn predict_req(model: &str, rows: usize) -> Value {
        encode_request(&Request {
            v: WIRE_VERSION,
            model: model.to_string(),
            op: Op::Predict {
                rows: vec![vec![0.5]; rows.max(1)],
            },
        })
    }

    fn delete_req(model: &str, id: u32) -> Value {
        encode_request(&Request {
            v: WIRE_VERSION,
            model: model.to_string(),
            op: Op::Delete { ids: vec![id] },
        })
    }

    fn with_deadline(mut v: Value, ms: u64) -> Value {
        v.set("deadline_ms", ms);
        v
    }

    fn enqueue(s: &Scheduler, req: &Value) -> Receiver<Value> {
        match s.submit(req).expect("submit refused") {
            Submitted::Queued(rx) => rx,
            Submitted::Immediate(_) => panic!("expected a queued ticket"),
        }
    }

    #[test]
    fn welford_cost_model_converges_on_synthetic_costs() {
        let cfg = SchedulerConfig {
            safety: 1.0,
            min_samples: 8,
            ..Default::default()
        };
        let mut tm = TimingModel::new(&cfg);
        // Alternating 1ms/3ms: predicted → mean + std of the sample.
        let xs: Vec<f64> = (0..100).map(|i| if i % 2 == 0 { 0.001 } else { 0.003 }).collect();
        for &x in &xs {
            tm.observe("t", OpClass::Predict, 0, x);
        }
        let want = crate::util::stats::mean(&xs) + crate::util::stats::std_dev(&xs);
        let got = tm.predict("t", OpClass::Predict, 0);
        assert!((got - want).abs() < 1e-12, "predict {got} != mean+std {want}");
        // Bucket specificity: a different bucket trained on different costs
        // answers with ITS moments, not the aggregate's.
        for _ in 0..8 {
            tm.observe("t", OpClass::Predict, 5, 0.010);
        }
        assert!((tm.predict("t", OpClass::Predict, 5) - 0.010).abs() < 1e-9);
        // An untrained bucket falls back to the aggregate, never the default.
        let agg = tm.predict("t", OpClass::Predict, 3);
        assert!(agg > cfg.default_cost, "bucket 3 should fall back to aggregate");
        // Unknown tenant: the prior.
        assert_eq!(tm.predict("ghost", OpClass::Mutate, 0), cfg.default_cost);
    }

    #[test]
    fn edf_serves_earliest_deadline_and_inherits_within_tenant() {
        let (s, _clk, log) = mk(
            SchedulerConfig {
                min_samples: u64::MAX, // predictions pinned at default_cost
                default_cost: 0.001,
                ..Default::default()
            },
            &[("a", 0.001), ("b", 0.001), ("c", 0.001)],
        );
        // Submission order: a,a,b,b(+10ms deadline),c(+5ms deadline).
        let rxs = vec![
            enqueue(&s, &predict_req("a", 1)),
            enqueue(&s, &predict_req("a", 1)),
            enqueue(&s, &predict_req("b", 1)),
            enqueue(&s, &with_deadline(predict_req("b", 1), 10)),
            enqueue(&s, &with_deadline(predict_req("c", 1), 5)),
        ];
        let r = s.run_for(Duration::from_secs(1));
        assert_eq!(r.executed, 5);
        assert_eq!(r.remaining, 0);
        for rx in rxs {
            assert!(rx.recv().unwrap().get("ok").is_some());
        }
        let order: Vec<String> = log
            .lock()
            .unwrap()
            .iter()
            .map(|w| {
                crate::util::json::parse(w)
                    .unwrap()
                    .get("model")
                    .unwrap()
                    .as_str()
                    .unwrap()
                    .to_string()
            })
            .collect();
        // c's 5ms deadline wins; then b — whose deadlined SECOND ticket
        // pulls its non-deadlined head forward (in-tenant inheritance,
        // FIFO preserved); a's backlog runs last under DRR.
        assert_eq!(order, vec!["c", "b", "b", "a", "a"]);
    }

    #[test]
    fn drr_shares_the_budget_by_tenant_weight() {
        let mut weights = BTreeMap::new();
        weights.insert("x".to_string(), 2.0);
        weights.insert("y".to_string(), 1.0);
        let (s, _clk, log) = mk(
            SchedulerConfig {
                weights,
                quantum: 0.0005,
                min_samples: u64::MAX,
                default_cost: 0.001, // == the synthetic cost: exact packing
                ..Default::default()
            },
            &[("x", 0.001), ("y", 0.001)],
        );
        for i in 0..60u32 {
            enqueue(&s, &delete_req("x", i));
            enqueue(&s, &delete_req("y", i));
        }
        let r = s.run_for(Duration::from_millis(30));
        assert!(r.executed >= 29 && r.executed <= 31, "packed {} into 30ms", r.executed);
        let xs = log
            .lock()
            .unwrap()
            .iter()
            .filter(|w| w.contains(r#""model":"x""#))
            .count() as f64;
        let ys = log.lock().unwrap().len() as f64 - xs;
        assert!(ys > 0.0, "weight-1 tenant must not starve");
        let ratio = xs / ys;
        assert!(
            (1.5..=2.5).contains(&ratio),
            "weight 2:1 should serve ~2:1, got {xs}:{ys}"
        );
    }

    #[test]
    fn budget_overrun_is_bounded_by_one_predicted_ticket() {
        let (s, _clk, _log) = mk(
            SchedulerConfig {
                min_samples: 4,
                safety: 1.0,
                ..Default::default()
            },
            &[("p", 0.002), ("q", 0.0005)],
        );
        // Warm-up: constant per-tenant costs → zero variance → the learned
        // prediction equals the actual cost exactly.
        for _ in 0..8 {
            enqueue(&s, &predict_req("p", 1));
            enqueue(&s, &predict_req("q", 1));
        }
        s.run_for(Duration::from_secs(10));
        assert_eq!(s.queued_total(), 0);

        for _ in 0..40 {
            enqueue(&s, &predict_req("p", 1));
            enqueue(&s, &predict_req("q", 1));
        }
        let mut executed = 0u64;
        let mut deferred_cycles = 0;
        for _cycle in 0..500 {
            let r = s.run_for(Duration::from_millis(5));
            if r.executed > 0 {
                // THE acceptance bound: a cycle overruns its budget by at
                // most the last ticket's predicted cost.
                assert!(
                    r.spent_s <= r.budget_s + r.last_predicted_s + 1e-12,
                    "spent {} > budget {} + predicted {}",
                    r.spent_s,
                    r.budget_s,
                    r.last_predicted_s
                );
            }
            if r.deferred {
                assert!(r.remaining > 0, "deferred cycle must leave work queued");
                deferred_cycles += 1;
            }
            executed += r.executed;
            if r.remaining == 0 {
                break;
            }
        }
        assert_eq!(executed, 80, "every ticket is eventually served");
        assert!(deferred_cycles > 0, "5ms cycles over 80 tickets must defer");
        assert_eq!(s.queued_total(), 0);
    }

    #[test]
    fn per_tenant_fifo_is_preserved_under_cross_tenant_reordering() {
        let (s, _clk, log) = mk(
            SchedulerConfig::default(),
            &[("a", 0.001), ("b", 0.0003), ("c", 0.002)],
        );
        let mut rng = Rng::new(mix_seed(&[11, 0x5CED]));
        let tenants = ["a", "b", "c"];
        let mut submitted: BTreeMap<&str, Vec<String>> = BTreeMap::new();
        for i in 0..90u32 {
            let t = tenants[rng.index(3)];
            let mut req = match rng.index(3) {
                0 => predict_req(t, 1 + rng.index(8)),
                1 => delete_req(t, i),
                _ => encode_request(&Request {
                    v: WIRE_VERSION,
                    model: t.to_string(),
                    op: Op::Flush,
                }),
            };
            if rng.bernoulli(0.3) {
                req.set("deadline_ms", 1 + rng.index(50) as u64);
            }
            submitted.entry(t).or_default().push(req.to_string());
            enqueue(&s, &req);
        }
        while s.queued_total() > 0 {
            s.run_for(Duration::from_millis(3));
        }
        let done = log.lock().unwrap();
        for t in tenants {
            let key = format!(r#""model":"{t}""#);
            let got: Vec<&String> = done.iter().filter(|w| w.contains(&key)).collect();
            let want = submitted.get(t).map(|v| v.as_slice()).unwrap_or(&[]);
            assert_eq!(got.len(), want.len(), "tenant {t} lost tickets");
            for (g, w) in got.iter().zip(want) {
                assert_eq!(
                    g.as_str(),
                    w.as_str(),
                    "tenant {t}: execution order broke submission FIFO"
                );
            }
        }
    }

    #[test]
    fn admission_control_refuses_past_queue_depth_with_retry_hint() {
        let (s, _clk, _log) = mk(
            SchedulerConfig {
                queue_depth: 2,
                ..Default::default()
            },
            &[("a", 0.001)],
        );
        let _r1 = enqueue(&s, &predict_req("a", 1));
        let _r2 = enqueue(&s, &predict_req("a", 1));
        match s.submit(&predict_req("a", 1)) {
            Err(ApiError::Overloaded { retry_after_ms }) => {
                assert!(retry_after_ms >= 1, "hint must be a positive backoff");
            }
            other => panic!("expected Overloaded, got {:?}", other.is_ok()),
        }
        // The refusal is visible in the tenant's stats and on the wire.
        let st = s.tenant_stats("a");
        assert_eq!(st.get("overloaded").unwrap().as_u64(), Some(1));
        assert_eq!(st.get("queued").unwrap().as_u64(), Some(2));
        let wire = s.handle(&predict_req("a", 1));
        let e = api::error_from_wire(&wire);
        assert!(matches!(e, ApiError::Overloaded { .. }));
        // Draining reopens admission.
        s.run_for(Duration::from_secs(1));
        assert!(s.submit(&predict_req("a", 1)).is_ok());
    }

    #[test]
    fn background_bids_run_only_in_slack_and_dedupe() {
        let (s, _clk, log) = mk(SchedulerConfig::default(), &[("a", 0.001)]);
        for i in 0..5u32 {
            enqueue(&s, &delete_req("a", i));
        }
        assert!(s.bid_compact("a", 4));
        assert!(!s.bid_compact("a", 4), "one outstanding bid per tenant");
        assert!(s.pending_bid("a"));
        let r = s.run_for(Duration::from_secs(1));
        assert_eq!(r.executed, 6);
        assert_eq!(r.executed_bg, 1);
        assert!(!s.pending_bid("a"));
        let done = log.lock().unwrap();
        assert!(
            done.last().unwrap().contains(r#""op":"compact""#),
            "the compact bid must run after ALL foreground work"
        );
        let st = s.tenant_stats("a");
        assert_eq!(st.get("compact_ticks").unwrap().as_u64(), Some(1));
        assert!(st.get("compact_spent_s").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn seeding_sets_the_prior_before_any_observation() {
        let (s, _clk, _log) = mk(SchedulerConfig::default(), &[]);
        let d = SchedulerConfig::default().default_cost;
        assert_eq!(s.predicted_cost("m", OpClass::Predict, 0), d);
        // Histogram seed (cross-process artifact).
        let mut h = Histogram::new();
        for _ in 0..20 {
            h.record(0.005);
        }
        s.seed_from_histogram("m", OpClass::Predict, &h);
        let p = s.predicted_cost("m", OpClass::Predict, 0);
        assert!((0.004..0.02).contains(&p), "seeded predict {p} should be ~5ms");
        // Telemetry seed (exact in-process Welford).
        let t = Telemetry::new();
        t.record("delete", 0.008, true);
        t.record("delete", 0.008, true);
        s.seed_from_telemetry("m", &t);
        let p = s.predicted_cost("m", OpClass::Mutate, 0);
        assert!((p - 0.008).abs() < 1e-9, "telemetry seed should be exact, got {p}");
    }

    #[test]
    fn fairness_spec_parses_and_rejects_garbage() {
        let w = SchedulerConfig::parse_weights("a=2,b=0.5").unwrap();
        assert_eq!(w.get("a"), Some(&2.0));
        assert_eq!(w.get("b"), Some(&0.5));
        assert!(SchedulerConfig::parse_weights("").unwrap().is_empty());
        assert!(SchedulerConfig::parse_weights("a").is_err());
        assert!(SchedulerConfig::parse_weights("a=zero").is_err());
        assert!(SchedulerConfig::parse_weights("a=-1").is_err());
        assert!(SchedulerConfig::parse_weights("a=0").is_err());
    }

    #[test]
    fn bypass_ops_execute_immediately_without_queueing() {
        let (s, _clk, log) = mk(SchedulerConfig::default(), &[("a", 0.001)]);
        enqueue(&s, &predict_req("a", 1)); // queued, NOT yet executed
        let resp = s.handle(&encode_request(&Request {
            v: WIRE_VERSION,
            model: "a".to_string(),
            op: Op::List,
        }));
        assert!(resp.get("ok").is_some());
        assert_eq!(log.lock().unwrap().len(), 1, "only the bypass op ran");
        assert_eq!(s.queued_total(), 1, "the predict is still queued");
    }
}
