//! The unlearning service: a request router over a DaRE forest.
//!
//! Requests (JSON objects) are dispatched to:
//! - `predict` — read path: batched inference under a read lock, via the
//!   PJRT predictor when the forest fits the compiled artifact (refreshing
//!   the tensorized snapshot lazily after mutations), else native traversal;
//! - `delete` — write path: routed through the [`DeletionBatcher`] so
//!   concurrent GDPR requests share a write lock / retrain batches;
//! - `add` — write path (continual learning §6);
//! - `delete_cost` — the dry-run adversary signal;
//! - `stats` — telemetry + model shape snapshot;
//! - `save` — snapshot the model+data to disk;
//! - `shutdown` — stop a `serve()` loop.
//!
//! Wire format: one JSON object per line over TCP (see `protocol`).

use crate::coordinator::batcher::DeletionBatcher;
use crate::coordinator::telemetry::Telemetry;
use crate::forest::forest::DareForest;
use crate::runtime::{Engine, Manifest, PjrtPredictor};
use crate::util::json::Value;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Batching window for deletion requests.
    pub batch_window: Duration,
    /// Max ids per deletion batch.
    pub max_batch: usize,
    /// Try to use the PJRT predictor (falls back to native when the forest
    /// exceeds the artifact shape or artifacts are missing).
    pub use_pjrt: bool,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            batch_window: Duration::from_millis(10),
            max_batch: 4096,
            use_pjrt: true,
        }
    }
}

/// The unlearning service.
pub struct UnlearningService {
    forest: Arc<RwLock<DareForest>>,
    batcher: DeletionBatcher,
    telemetry: Telemetry,
    pjrt: Mutex<Option<PjrtPredictor>>,
    manifest: Option<Manifest>,
    /// Bumped on every mutation; predictor refreshes when stale.
    version: AtomicU64,
    pjrt_version: AtomicU64,
    shutdown: AtomicBool,
}

impl UnlearningService {
    pub fn new(forest: DareForest, cfg: ServiceConfig) -> Arc<Self> {
        let forest = Arc::new(RwLock::new(forest));
        let batcher = DeletionBatcher::start(Arc::clone(&forest), cfg.batch_window, cfg.max_batch);
        let (pjrt, manifest) = if cfg.use_pjrt {
            match crate::runtime::manifest::locate_artifacts()
                .ok_or_else(|| anyhow::anyhow!("artifacts not built"))
                .and_then(|dir| Manifest::load(&dir))
            {
                Ok(m) => {
                    let p = Engine::global()
                        .and_then(|e| PjrtPredictor::new(e, &m, &forest.read().unwrap()))
                        .ok();
                    (p, Some(m))
                }
                Err(_) => (None, None),
            }
        } else {
            (None, None)
        };
        Arc::new(UnlearningService {
            forest,
            batcher,
            telemetry: Telemetry::new(),
            pjrt: Mutex::new(pjrt),
            manifest,
            version: AtomicU64::new(0),
            pjrt_version: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
        })
    }

    /// Whether the PJRT predictor is active.
    pub fn pjrt_active(&self) -> bool {
        self.pjrt.lock().unwrap().is_some()
    }

    pub fn forest(&self) -> &Arc<RwLock<DareForest>> {
        &self.forest
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Handle one request object, returning the response object.
    pub fn handle(&self, req: &Value) -> Value {
        let op = req.get("op").and_then(Value::as_str).unwrap_or("");
        match op {
            "predict" => self.telemetry.timed("predict", || {
                let r = self.op_predict(req);
                let ok = r.get("ok").and_then(Value::as_bool) == Some(true);
                (r, ok)
            }),
            "delete" => self.telemetry.timed("delete", || {
                let r = self.op_delete(req);
                let ok = r.get("ok").and_then(Value::as_bool) == Some(true);
                (r, ok)
            }),
            "add" => self.telemetry.timed("add", || {
                let r = self.op_add(req);
                let ok = r.get("ok").and_then(Value::as_bool) == Some(true);
                (r, ok)
            }),
            "delete_cost" => self.telemetry.timed("delete_cost", || {
                let r = self.op_delete_cost(req);
                let ok = r.get("ok").and_then(Value::as_bool) == Some(true);
                (r, ok)
            }),
            "stats" => self.op_stats(),
            "save" => self.op_save(req),
            "shutdown" => {
                self.shutdown.store(true, Ordering::SeqCst);
                ok_response()
            }
            _ => err_response(&format!("unknown op '{op}'")),
        }
    }

    fn op_predict(&self, req: &Value) -> Value {
        let Some(rows_json) = req.get("rows").and_then(Value::as_arr) else {
            return err_response("predict needs 'rows': [[f32,...],...]");
        };
        let mut rows: Vec<Vec<f32>> = Vec::with_capacity(rows_json.len());
        for r in rows_json {
            let Some(cells) = r.as_arr() else {
                return err_response("rows must be arrays of numbers");
            };
            rows.push(cells.iter().map(|c| c.as_f64().unwrap_or(0.0) as f32).collect());
        }

        // Fast path: PJRT batch predictor (refresh if the model mutated).
        let version = self.version.load(Ordering::SeqCst);
        let mut pjrt_guard = self.pjrt.lock().unwrap();
        if let (Some(pred), Some(m)) = (pjrt_guard.as_mut(), self.manifest.as_ref()) {
            let forest = self.forest.read().unwrap();
            if self.pjrt_version.swap(version, Ordering::SeqCst) != version {
                if pred.refresh(m, &forest).is_err() {
                    *pjrt_guard = None; // forest outgrew the artifact: fall back
                }
            }
            if let Some(pred) = pjrt_guard.as_ref() {
                if let Ok(probs) = pred.predict(&rows) {
                    let mut resp = ok_response();
                    resp.set("probs", probs.iter().map(|p| *p as f64).collect::<Vec<f64>>());
                    resp.set("engine", "pjrt");
                    return resp;
                }
            }
        }
        drop(pjrt_guard);

        // Native path.
        let forest = self.forest.read().unwrap();
        let probs = forest.predict_proba_rows(&rows);
        let mut resp = ok_response();
        resp.set("probs", probs.iter().map(|p| *p as f64).collect::<Vec<f64>>());
        resp.set("engine", "native");
        resp
    }

    fn op_delete(&self, req: &Value) -> Value {
        let Some(ids_json) = req.get("ids").and_then(Value::as_arr) else {
            return err_response("delete needs 'ids': [u32,...]");
        };
        let ids: Vec<u32> = ids_json.iter().filter_map(|v| v.as_u64()).map(|v| v as u32).collect();
        if ids.len() != ids_json.len() {
            return err_response("ids must be non-negative integers");
        }
        match self.batcher.delete(ids) {
            Ok(out) => {
                self.version.fetch_add(1, Ordering::SeqCst);
                let mut resp = ok_response();
                resp.set("deleted", out.deleted)
                    .set("skipped", out.skipped)
                    .set("retrain_cost", out.retrain_cost)
                    .set("batch_size", out.batch_size);
                resp
            }
            Err(e) => err_response(&format!("{e}")),
        }
    }

    fn op_add(&self, req: &Value) -> Value {
        let Some(row_json) = req.get("row").and_then(Value::as_arr) else {
            return err_response("add needs 'row': [f32,...]");
        };
        let Some(label) = req.get("label").and_then(Value::as_u64) else {
            return err_response("add needs 'label': 0|1");
        };
        if label > 1 {
            return err_response("label must be 0 or 1");
        }
        let row: Vec<f32> = row_json.iter().map(|v| v.as_f64().unwrap_or(0.0) as f32).collect();
        let mut forest = self.forest.write().unwrap();
        if row.len() != forest.data().n_features() {
            return err_response(&format!(
                "row has {} features, model expects {}",
                row.len(),
                forest.data().n_features()
            ));
        }
        let id = forest.add(&row, label as u8);
        drop(forest);
        self.version.fetch_add(1, Ordering::SeqCst);
        let mut resp = ok_response();
        resp.set("id", id);
        resp
    }

    fn op_delete_cost(&self, req: &Value) -> Value {
        let Some(id) = req.get("id").and_then(Value::as_u64) else {
            return err_response("delete_cost needs 'id'");
        };
        let forest = self.forest.read().unwrap();
        let id = id as u32;
        if (id as usize) >= forest.data().n_total() || !forest.data().is_alive(id) {
            return err_response("not a live instance");
        }
        let cost = forest.delete_cost(id);
        let mut resp = ok_response();
        resp.set("cost", cost);
        resp
    }

    fn op_stats(&self) -> Value {
        let forest = self.forest.read().unwrap();
        let mem = forest.memory();
        let mut resp = ok_response();
        resp.set("telemetry", self.telemetry.snapshot())
            .set("n_alive", forest.n_alive())
            .set("n_trees", forest.n_trees())
            .set("pjrt_active", self.pjrt_active())
            .set("model_bytes", mem.total())
            .set("data_bytes", forest.data_bytes());
        resp
    }

    fn op_save(&self, req: &Value) -> Value {
        let Some(path) = req.get("path").and_then(Value::as_str) else {
            return err_response("save needs 'path'");
        };
        let forest = self.forest.read().unwrap();
        match crate::forest::serialize::save(&forest, std::path::Path::new(path)) {
            Ok(()) => ok_response(),
            Err(e) => err_response(&format!("{e}")),
        }
    }
}

pub fn ok_response() -> Value {
    let mut v = Value::obj();
    v.set("ok", true);
    v
}

pub fn err_response(msg: &str) -> Value {
    let mut v = Value::obj();
    v.set("ok", false).set("error", msg);
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};
    use crate::forest::params::Params;
    use crate::util::json::parse;

    fn service() -> Arc<UnlearningService> {
        let d = generate(
            &SynthSpec {
                n: 200,
                informative: 3,
                redundant: 0,
                noise: 2,
                flip: 0.05,
                ..Default::default()
            },
            7,
        );
        let f = DareForest::fit(
            d,
            &Params {
                n_trees: 4,
                max_depth: 5,
                k: 5,
                ..Default::default()
            },
            3,
        );
        UnlearningService::new(
            f,
            ServiceConfig {
                batch_window: Duration::from_millis(1),
                use_pjrt: false, // unit tests: native path (pjrt covered separately)
                ..Default::default()
            },
        )
    }

    fn req(s: &str) -> Value {
        parse(s).unwrap()
    }

    #[test]
    fn predict_roundtrip() {
        let svc = service();
        let p = svc.forest().read().unwrap().data().n_features();
        let row: Vec<String> = vec!["0.1".into(); p];
        let r = svc.handle(&req(&format!(r#"{{"op":"predict","rows":[[{}]]}}"#, row.join(","))));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
        let probs = r.get("probs").unwrap().as_arr().unwrap();
        assert_eq!(probs.len(), 1);
        let pr = probs[0].as_f64().unwrap();
        assert!((0.0..=1.0).contains(&pr));
        assert_eq!(r.get("engine").unwrap().as_str(), Some("native"));
    }

    #[test]
    fn delete_then_stats() {
        let svc = service();
        let r = svc.handle(&req(r#"{"op":"delete","ids":[0,1,2]}"#));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(r.get("deleted").unwrap().as_u64(), Some(3));
        let s = svc.handle(&req(r#"{"op":"stats"}"#));
        assert_eq!(s.get("n_alive").unwrap().as_u64(), Some(197));
        let tele = s.get("telemetry").unwrap().get("ops").unwrap();
        assert!(tele.get("delete").is_some());
    }

    #[test]
    fn add_then_delete_roundtrip() {
        let svc = service();
        let p = svc.forest().read().unwrap().data().n_features();
        let row: Vec<String> = vec!["0.5".into(); p];
        let r = svc.handle(&req(&format!(
            r#"{{"op":"add","row":[{}],"label":1}}"#,
            row.join(",")
        )));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
        let id = r.get("id").unwrap().as_u64().unwrap();
        let r = svc.handle(&req(&format!(r#"{{"op":"delete","ids":[{id}]}}"#)));
        assert_eq!(r.get("deleted").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn delete_cost_query() {
        let svc = service();
        let r = svc.handle(&req(r#"{"op":"delete_cost","id":5}"#));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
        assert!(r.get("cost").unwrap().as_u64().is_some());
        let bad = svc.handle(&req(r#"{"op":"delete_cost","id":999999}"#));
        assert_eq!(bad.get("ok").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn error_paths() {
        let svc = service();
        for bad in [
            r#"{"op":"nope"}"#,
            r#"{"op":"predict"}"#,
            r#"{"op":"delete"}"#,
            r#"{"op":"add","row":[1.0],"label":5}"#,
            r#"{"op":"add","row":[1.0],"label":1}"#, // wrong arity
        ] {
            let r = svc.handle(&req(bad));
            assert_eq!(r.get("ok").unwrap().as_bool(), Some(false), "{bad}");
            assert!(r.get("error").is_some());
        }
    }

    #[test]
    fn shutdown_flag() {
        let svc = service();
        assert!(!svc.is_shutdown());
        svc.handle(&req(r#"{"op":"shutdown"}"#));
        assert!(svc.is_shutdown());
    }

    #[test]
    fn predictions_change_after_unlearning_an_instance_class() {
        // Deleting all positives of a region should pull predictions down —
        // the service-level view of exact unlearning.
        let svc = service();
        let (probe, pos_ids): (Vec<f32>, Vec<u32>) = {
            let f = svc.forest().read().unwrap();
            let d = f.data();
            let pos: Vec<u32> = d.live_ids().into_iter().filter(|&i| d.y(i) == 1).collect();
            (d.row(pos[0]), pos)
        };
        let before = {
            let f = svc.forest().read().unwrap();
            f.predict_proba(&probe)
        };
        // delete 80% of positives
        let del: Vec<String> = pos_ids
            .iter()
            .take(pos_ids.len() * 4 / 5)
            .map(|i| i.to_string())
            .collect();
        svc.handle(&req(&format!(r#"{{"op":"delete","ids":[{}]}}"#, del.join(","))));
        let after = {
            let f = svc.forest().read().unwrap();
            f.predict_proba(&probe)
        };
        assert!(
            after < before + 1e-6,
            "removing positives should not raise positive probability ({before} -> {after})"
        );
    }
}
