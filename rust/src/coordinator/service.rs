//! The unlearning service: the typed, versioned wire API (DESIGN.md §10)
//! over a multi-tenant [`ModelRegistry`].
//!
//! A request travels through three separately testable layers:
//!
//! 1. **decode** — [`api::decode`] turns the wire JSON into a typed
//!    [`Request`] (version check, model routing, payload validation);
//! 2. **dispatch** — [`UnlearningService::dispatch`] resolves the model in
//!    the registry and runs the typed operation;
//! 3. **encode** — [`api::encode_response`] serializes the typed
//!    [`Response`] (data-plane payloads are byte-identical to the
//!    pre-registry v0 wire format).
//!
//! Data-plane ops (`predict` / `delete` / `add` / `delete_cost` / `stats`
//! / `flush` / `compact` / `save`) address one model; lifecycle ops
//! (`create` / `load` / `drop` / `list`) manage the registry itself.
//! Un-namespaced v0 requests route to the `"default"` model, which
//! [`UnlearningService::new`] installs — so the single-model surface keeps
//! working unchanged. Wire format: one JSON object per line over TCP (see
//! `protocol`).

use crate::coordinator::api::{self, ApiError, CreateSpec, Op, Request, Response, DEFAULT_MODEL};
use crate::coordinator::registry::{Model, ModelRegistry};
use crate::coordinator::scheduler::Scheduler;
use crate::coordinator::shards::ShardedForest;
use crate::coordinator::telemetry::Telemetry;
use crate::coordinator::wal::{self, FsyncPolicy, Wal};
use crate::forest::forest::DareForest;
use crate::forest::lazy::LazyPolicy;
use crate::forest::params::Params;
use crate::util::json::Value;
use crate::util::threadpool::default_threads;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, Weak};
use std::time::Duration;

/// Service configuration; also the template every `create`/`load`ed model
/// inherits (shard count, deferral policy, batching window).
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Batching window for deletion requests.
    pub batch_window: Duration,
    /// Max ids per deletion batch.
    pub max_batch: usize,
    /// Try to use the PJRT predictor (falls back to native when the forest
    /// exceeds the artifact shape or artifacts are missing).
    pub use_pjrt: bool,
    /// Forest shard count; 0 means the threadpool width (DESIGN.md §8).
    pub n_shards: usize,
    /// When deferred retrains run (DESIGN.md §9). The default honors the
    /// `DARE_LAZY_POLICY` environment variable (`eager` | `on_read` |
    /// `budgeted:<k>`), falling back to eager — this is how the CI matrix
    /// leg serves the whole tier-1 suite under `on_read`.
    pub lazy: LazyPolicy,
    /// How often the background compactor wakes to drain deferred retrains
    /// (a no-op sweep when no model has a backlog).
    pub compact_interval: Duration,
    /// Deferred retrains the compactor executes per tree per tick.
    pub compact_budget: usize,
    /// Durability root (DESIGN.md §11): when set, every model owns a
    /// write-ahead-log directory under it, mutating ops are journaled
    /// before they are acked, and startup recovers every model found on
    /// disk. `None` (the default) keeps the historical in-memory-only
    /// behavior.
    pub wal_dir: Option<PathBuf>,
    /// When appended WAL records are fsync'd.
    pub wal_fsync: FsyncPolicy,
    /// Snapshot + truncate each model's log after this many logged ops
    /// (0 = never snapshot; the log grows until restart).
    pub wal_snapshot_every: u64,
    /// Certificate HMAC key; `None` falls back to `DARE_HMAC_KEY`, then
    /// the insecure dev default (see [`wal::resolve_key`]).
    pub cert_key: Option<String>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            batch_window: Duration::from_millis(10),
            max_batch: 4096,
            use_pjrt: true,
            n_shards: 0,
            lazy: LazyPolicy::from_env(),
            compact_interval: Duration::from_millis(25),
            compact_budget: 8,
            wal_dir: None,
            wal_fsync: FsyncPolicy::EveryOp,
            wal_snapshot_every: 256,
            cert_key: None,
        }
    }
}

/// The unlearning service: a [`ModelRegistry`] behind the typed wire API.
pub struct UnlearningService {
    registry: ModelRegistry,
    cfg: ServiceConfig,
    /// Resolved certificate HMAC key (config → `DARE_HMAC_KEY` → dev
    /// default); shared by every model's WAL and by `verify_cert`.
    cert_key: Vec<u8>,
    shutdown: AtomicBool,
    /// Attached [`Scheduler`] (DESIGN.md §15), when `serve --budget-ms`
    /// routed traffic through one. Weak: the scheduler owns an `Arc` to
    /// the service (its executor), so a strong back-edge would leak both.
    scheduler: Mutex<Weak<Scheduler>>,
}

impl UnlearningService {
    /// Single-model service: installs `forest` as the `"default"` model
    /// (the target of un-namespaced v0 requests).
    pub fn new(forest: DareForest, cfg: ServiceConfig) -> Arc<Self> {
        Self::with_models(vec![(DEFAULT_MODEL.to_string(), forest)], cfg)
    }

    /// Multi-tenant service: install each named forest. Names must be
    /// unique; v0 requests only reach a model literally named `"default"`.
    ///
    /// With `cfg.wal_dir` set, startup first *recovers* every model found
    /// under the durability root (snapshot + valid log prefix — see
    /// DESIGN.md §11); disk state wins over a passed-in forest of the same
    /// name, because the durable state may carry acked mutations the
    /// caller's freshly-trained forest does not. Remaining passed-in
    /// models get fresh WAL directories. A model directory that fails to
    /// recover is left untouched on disk and *not* served (its name stays
    /// free for an operator to investigate), never silently reset.
    pub fn with_models(models: Vec<(String, DareForest)>, cfg: ServiceConfig) -> Arc<Self> {
        let registry = ModelRegistry::new();
        let cert_key = wal::resolve_key(cfg.cert_key.as_deref());
        let mut recovered: Vec<String> = Vec::new();
        if let Some(root) = &cfg.wal_dir {
            std::fs::create_dir_all(root).expect("create wal root");
            for dir in Wal::scan(root) {
                match Wal::recover(
                    root,
                    &dir,
                    cfg.wal_fsync,
                    cfg.wal_snapshot_every,
                    cert_key.clone(),
                ) {
                    Ok(mut rec) => {
                        rec.wal.set_model(&rec.name);
                        let model = Model::new_with_wal(
                            &rec.name,
                            rec.forest,
                            &cfg,
                            Some(Arc::new(rec.wal)),
                        );
                        recovered.push(rec.name.clone());
                        registry
                            .insert(model)
                            .expect("duplicate recovered model name");
                    }
                    Err(e) => {
                        eprintln!("wal: cannot recover '{dir}' (not serving it): {e}");
                    }
                }
            }
        }
        for (name, forest) in models {
            if recovered.iter().any(|r| r == &name) {
                continue; // durable state wins
            }
            let wal = cfg.wal_dir.as_ref().map(|root| {
                Arc::new(
                    Wal::create(
                        root,
                        &name,
                        &forest,
                        cfg.wal_fsync,
                        cfg.wal_snapshot_every,
                        cert_key.clone(),
                    )
                    .expect("initialize wal"),
                )
            });
            registry
                .insert(Model::new_with_wal(&name, forest, &cfg, wal))
                .expect("duplicate model name at startup");
        }
        let svc = Arc::new(UnlearningService {
            registry,
            cfg: cfg.clone(),
            cert_key,
            shutdown: AtomicBool::new(false),
            scheduler: Mutex::new(Weak::new()),
        });
        spawn_compactor(Arc::downgrade(&svc), cfg.compact_interval, cfg.compact_budget);
        svc
    }

    /// The model registry (name → served model).
    pub fn registry(&self) -> &ModelRegistry {
        &self.registry
    }

    /// The `"default"` model's handle. Panics when it was dropped — the
    /// single-model accessors below exist for that model only.
    pub fn default_model(&self) -> Arc<Model> {
        self.registry
            .get(DEFAULT_MODEL)
            .expect("service has no 'default' model")
    }

    /// Whether the PJRT predictor is active (default model).
    pub fn pjrt_active(&self) -> bool {
        self.default_model().pjrt_active()
    }

    /// The default model's deferral policy (DESIGN.md §9).
    pub fn lazy_policy(&self) -> LazyPolicy {
        self.default_model().lazy_policy()
    }

    /// The sharded forest store backing the default model.
    pub fn sharded(&self) -> Arc<ShardedForest> {
        Arc::clone(self.default_model().sharded())
    }

    /// Clone a consistent [`DareForest`] view of the default model.
    pub fn snapshot_forest(&self) -> DareForest {
        self.default_model().snapshot_forest()
    }

    /// Feature arity of the default model.
    pub fn n_features(&self) -> usize {
        self.default_model().n_features()
    }

    /// The default model's telemetry registry.
    pub fn telemetry(&self) -> Arc<Telemetry> {
        self.default_model().telemetry_arc()
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Register an attached scheduler (called by [`Scheduler::attach`]).
    /// The wire loop and the background compactor route through it from
    /// this point on.
    pub fn attach_scheduler(&self, sched: Weak<Scheduler>) {
        *self.scheduler.lock().unwrap() = sched;
    }

    /// The attached scheduler, if one is alive.
    pub fn scheduler(&self) -> Option<Arc<Scheduler>> {
        self.scheduler.lock().unwrap().upgrade()
    }

    /// Handle one wire object: decode → dispatch → encode.
    pub fn handle(&self, req: &Value) -> Value {
        let resp = match api::decode(req) {
            Ok(r) => self.dispatch(r),
            Err(e) => Response::Err(e),
        };
        api::encode_response(&resp)
    }

    /// Run one typed request against the registry.
    pub fn dispatch(&self, req: Request) -> Response {
        if self.is_shutdown() && !matches!(req.op, Op::Shutdown) {
            return Response::Err(ApiError::ShuttingDown);
        }
        match req.op {
            Op::Shutdown => {
                self.shutdown.store(true, Ordering::SeqCst);
                Response::Ok
            }
            Op::List => Response::List {
                models: self.registry.models().iter().map(|m| m.summary()).collect(),
            },
            Op::Create(spec) => self.op_create(&req.model, &spec),
            Op::Load { path } => self.op_load(&req.model, &path),
            Op::DropModel => match self.registry.remove(&req.model) {
                Ok(m) => {
                    // Durability follows the registry: a dropped tenant
                    // must not resurrect on restart (that would un-honor
                    // every deletion it ever acked).
                    if let Some(root) = &self.cfg.wal_dir {
                        wal::Wal::remove_dir(root, m.name());
                    }
                    Response::Dropped {
                        model: m.name().to_string(),
                    }
                }
                Err(e) => Response::Err(e),
            },
            // Signature checks are model-independent (a cert for a since-
            // dropped model must still verify): handle before resolution.
            Op::VerifyCert { cert } => Response::CertCheck {
                valid: wal::verify_certificate(&self.cert_key, &cert),
            },
            // Data-plane: resolve the model (the registry lock is released
            // inside `get`, before any per-model lock is touched).
            op => match self.registry.get(&req.model) {
                Ok(model) => match dispatch_model(&model, op) {
                    // Stats carry the tenant's scheduling view (queue
                    // depth, DRR state, compaction accounting) when a
                    // scheduler is attached.
                    Response::Stats(mut v) => {
                        if let Some(sched) = self.scheduler() {
                            v.set("sched", sched.tenant_stats(model.name()));
                        }
                        Response::Stats(v)
                    }
                    r => r,
                },
                Err(e) => Response::Err(e),
            },
        }
    }

    /// `create`: train a fresh model from a corpus dataset reference with
    /// the paper-tuned hyperparameters (plus any explicit overrides) and
    /// register it under `name`.
    fn op_create(&self, name: &str, spec: &CreateSpec) -> Response {
        if let Err(e) = validate_name(name) {
            return Response::Err(e);
        }
        // Reject duplicates before the (expensive) training run; the
        // insert below re-checks under the write lock, so a racing create
        // still resolves to exactly one winner.
        if self.registry.contains(name) {
            return Response::Err(ApiError::BadRequest(format!(
                "model '{name}' already exists"
            )));
        }
        let Some(info) = crate::data::registry::find(&spec.dataset) else {
            return Response::Err(ApiError::BadRequest(format!(
                "unknown dataset '{}'",
                spec.dataset
            )));
        };
        let mut params = Params::from_paper(&info.gini, spec.d_rmax.unwrap_or(0));
        if let Some(t) = spec.n_trees {
            params.n_trees = t;
        }
        if let Some(d) = spec.max_depth {
            params.max_depth = d;
        }
        if let Some(k) = spec.k {
            params.k = k;
        }
        if let Some(q) = spec.q {
            // Occ(q) subsampling (DESIGN.md §13); the decoder already
            // bounds q to (0, 1], validate() re-checks below.
            params.q = q;
        }
        params.n_threads = default_threads();
        // Wire-supplied hyperparameters must come back as a typed error,
        // never reach the `validate().expect()` panic inside `fit` (and a
        // rejected request shouldn't pay for dataset generation).
        if let Err(e) = params.validate() {
            return Response::Err(ApiError::BadRequest(format!("{e}")));
        }
        let data = info.generate(spec.scale_div, spec.seed);
        let forest = DareForest::fit(data, &params, spec.seed);
        self.install(name, forest)
    }

    /// `load`: install a serialized snapshot as a new registry model.
    fn op_load(&self, name: &str, path: &str) -> Response {
        if let Err(e) = validate_name(name) {
            return Response::Err(e);
        }
        if self.registry.contains(name) {
            return Response::Err(ApiError::BadRequest(format!(
                "model '{name}' already exists"
            )));
        }
        match crate::forest::serialize::load(std::path::Path::new(path)) {
            Ok(forest) => self.install(name, forest),
            Err(e) => Response::Err(ApiError::BadRequest(format!("{e}"))),
        }
    }

    fn install(&self, name: &str, forest: DareForest) -> Response {
        let wal = match &self.cfg.wal_dir {
            Some(root) => match Wal::create(
                root,
                name,
                &forest,
                self.cfg.wal_fsync,
                self.cfg.wal_snapshot_every,
                self.cert_key.clone(),
            ) {
                Ok(w) => Some(Arc::new(w)),
                Err(e) => {
                    return Response::Err(ApiError::BadRequest(format!(
                        "cannot initialize durability for '{name}': {e}"
                    )))
                }
            },
            None => None,
        };
        let model = Model::new_with_wal(name, forest, &self.cfg, wal);
        let n_trees = model.sharded().n_trees();
        let n_alive = model.sharded().n_alive();
        match self.registry.insert(model) {
            Ok(()) => Response::ModelReady {
                model: name.to_string(),
                n_trees,
                n_alive,
            },
            Err(e) => Response::Err(e),
        }
    }

    /// Install a follower-bootstrapped model (DESIGN.md §12): `snapshot`
    /// is the leader's canonical forest JSON cut at WAL epoch `epoch`.
    /// With durability enabled, the local journal is created *at that
    /// epoch* ([`Wal::create_at`]), so a follower restart recovers
    /// locally and resumes tailing without re-pulling history. Returns
    /// the model handle so the caller can attach replication state.
    pub fn install_snapshot(
        &self,
        name: &str,
        snapshot: &str,
        epoch: u64,
    ) -> Result<Arc<Model>, ApiError> {
        validate_name(name)?;
        if self.registry.contains(name) {
            return Err(ApiError::BadRequest(format!("model '{name}' already exists")));
        }
        let forest = crate::forest::serialize::forest_from_json(snapshot)
            .map_err(|e| ApiError::BadRequest(format!("invalid snapshot from leader: {e}")))?;
        let wal = match &self.cfg.wal_dir {
            Some(root) => match Wal::create_at(
                root,
                name,
                &forest,
                epoch,
                self.cfg.wal_fsync,
                self.cfg.wal_snapshot_every,
                self.cert_key.clone(),
            ) {
                Ok(w) => Some(Arc::new(w)),
                Err(e) => {
                    return Err(ApiError::BadRequest(format!(
                        "cannot initialize durability for '{name}': {e}"
                    )))
                }
            },
            None => None,
        };
        let model = Model::new_with_wal(name, forest, &self.cfg, wal);
        self.registry.insert(Arc::clone(&model))?;
        Ok(model)
    }
}

fn validate_name(name: &str) -> Result<(), ApiError> {
    if name.is_empty() || name.len() > 128 {
        return Err(ApiError::BadRequest(
            "model name must be 1..=128 bytes".to_string(),
        ));
    }
    Ok(())
}

/// Run one data-plane op against a resolved model, recording latency and
/// outcome in the model's telemetry for the four high-traffic ops.
///
/// Followers (DESIGN.md §12) serve the read plane only: mutations bounce
/// with [`ApiError::ReadOnly`] naming the leader, and read responses are
/// wrapped in [`Response::Stale`] once the replica has fallen behind its
/// staleness bound — annotated, never refused (graceful degradation).
fn dispatch_model(model: &Model, op: Op) -> Response {
    if let Op::Delete { .. } | Op::Add { .. } | Op::Certify { .. } = op {
        if model.is_follower() {
            return Response::Err(ApiError::ReadOnly {
                leader: model.leader_addr().unwrap_or_default(),
            });
        }
    }
    let annotate_stale = matches!(op, Op::Predict { .. } | Op::DeleteCost { .. })
        && model.replica().map_or(false, |r| r.is_follower() && r.is_stale());
    let resp = dispatch_model_inner(model, op);
    if annotate_stale && !matches!(resp, Response::Err(_)) {
        return Response::Stale(Box::new(resp));
    }
    resp
}

fn dispatch_model_inner(model: &Model, op: Op) -> Response {
    match op {
        Op::Predict { rows } => model.telemetry().timed("predict", || {
            match model.predict(&rows) {
                Ok((probs, engine)) => (Response::Predict { probs, engine }, true),
                Err(e) => (Response::Err(e), false),
            }
        }),
        Op::Delete { ids } => model.telemetry().timed("delete", || {
            match model.delete(ids) {
                Ok(out) => (Response::Delete(out), true),
                Err(e) => (Response::Err(e), false),
            }
        }),
        Op::Add { row, label } => model.telemetry().timed("add", || {
            match model.add(&row, label) {
                Ok(id) => (Response::Add { id }, true),
                Err(e) => (Response::Err(e), false),
            }
        }),
        Op::DeleteCost { id } => model.telemetry().timed("delete_cost", || {
            match model.delete_cost(id) {
                Ok(cost) => (Response::DeleteCost { cost }, true),
                Err(e) => (Response::Err(e), false),
            }
        }),
        Op::Stats => Response::Stats(model.stats()),
        Op::Flush => Response::Flushed {
            flushed: model.flush(),
        },
        Op::Compact { budget } => Response::Flushed {
            flushed: model.drain_compact(budget),
        },
        Op::Save { path } => match model.save(&path) {
            Ok(()) => Response::Ok,
            Err(e) => Response::Err(e),
        },
        Op::Certify { id } => match model.certify(id) {
            Ok(cert) => Response::Certified(cert),
            Err(e) => Response::Err(e),
        },
        // -- replication, leader side (DESIGN.md §12) --
        Op::PullSnapshot => match model.wal() {
            Some(wal) => {
                let (wal_epoch, snapshot) =
                    wal.snapshot_with_epoch(|| model.snapshot_forest());
                Response::Snapshot { wal_epoch, snapshot }
            }
            None => Response::Err(ApiError::BadRequest(
                "replication requires durability (start the leader with a WAL dir)".to_string(),
            )),
        },
        Op::PullLog {
            after_epoch,
            max_records,
        } => match model.wal() {
            Some(wal) => {
                let batch = wal.read_records_after(after_epoch, max_records);
                Response::LogWindow {
                    records: batch
                        .records
                        .into_iter()
                        .map(|r| (r.epoch, r.request))
                        .collect(),
                    leader_epoch: batch.leader_epoch,
                    base_epoch: batch.base_epoch,
                    snapshot_needed: batch.snapshot_needed,
                }
            }
            None => Response::Err(ApiError::BadRequest(
                "replication requires durability (start the leader with a WAL dir)".to_string(),
            )),
        },
        Op::Promote => match crate::coordinator::replica::promote(model) {
            Ok(epoch) => Response::Promoted {
                model: model.name().to_string(),
                epoch,
            },
            Err(e) => Response::Err(e),
        },
        Op::Shutdown
        | Op::List
        | Op::Create(_)
        | Op::Load { .. }
        | Op::DropModel
        | Op::VerifyCert { .. } => {
            unreachable!("control-plane op routed to a model")
        }
    }
}

/// The background compactor (DESIGN.md §9): a detached thread that sweeps
/// every registered model and drains deferred retrains during idle ticks,
/// so the flush cost is paid off the request path. Holds only a `Weak`
/// handle — dropping the last service `Arc` (or the shutdown op) stops it
/// within one tick. Timing is nondeterministic and harmlessly so: retrains
/// are path-seeded, so *when* a flush runs cannot change what it builds.
///
/// With a scheduler attached (DESIGN.md §15) the compactor no longer
/// compacts blindly: it *bids* a background compaction ticket per backlog
/// model and the scheduler runs the bid in slack budget — after all
/// foreground work, never against it. Without one it drains directly
/// (via [`Model::drain_compact`], so ticks are observable either way).
fn spawn_compactor(svc: Weak<UnlearningService>, interval: Duration, budget: usize) {
    let _ = std::thread::Builder::new()
        .name("dare-compactor".into())
        .spawn(move || loop {
            std::thread::sleep(interval);
            let Some(svc) = svc.upgrade() else {
                return;
            };
            if svc.is_shutdown() {
                return;
            }
            let sched = svc.scheduler();
            for model in svc.registry.models() {
                if model.lazy_policy().is_lazy() && model.sharded().pending_retrains() > 0 {
                    match &sched {
                        Some(s) => {
                            // At most one outstanding bid per tenant; the
                            // ticket replays through `svc.handle`, i.e. the
                            // same compact path as a wire request.
                            s.bid_compact(model.name(), budget);
                        }
                        None => {
                            model.drain_compact(budget);
                        }
                    }
                }
            }
        });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};
    use crate::forest::params::Params;
    use crate::util::json::parse;

    fn service_with_shards(n_shards: usize) -> Arc<UnlearningService> {
        let d = generate(
            &SynthSpec {
                n: 200,
                informative: 3,
                redundant: 0,
                noise: 2,
                flip: 0.05,
                ..Default::default()
            },
            7,
        );
        let f = DareForest::fit(
            d,
            &Params {
                n_trees: 4,
                max_depth: 5,
                k: 5,
                ..Default::default()
            },
            3,
        );
        UnlearningService::new(
            f,
            ServiceConfig {
                batch_window: Duration::from_millis(1),
                use_pjrt: false, // unit tests: native path (pjrt covered separately)
                n_shards,
                ..Default::default()
            },
        )
    }

    fn service() -> Arc<UnlearningService> {
        service_with_shards(2)
    }

    fn req(s: &str) -> Value {
        parse(s).unwrap()
    }

    #[test]
    fn predict_roundtrip() {
        let svc = service();
        let p = svc.n_features();
        let row: Vec<String> = vec!["0.1".into(); p];
        let r = svc.handle(&req(&format!(r#"{{"op":"predict","rows":[[{}]]}}"#, row.join(","))));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
        let probs = r.get("probs").unwrap().as_arr().unwrap();
        assert_eq!(probs.len(), 1);
        let pr = probs[0].as_f64().unwrap();
        assert!((0.0..=1.0).contains(&pr));
        assert_eq!(r.get("engine").unwrap().as_str(), Some("native"));
    }

    #[test]
    fn delete_then_stats() {
        let svc = service();
        let r = svc.handle(&req(r#"{"op":"delete","ids":[0,1,2]}"#));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(r.get("deleted").unwrap().as_u64(), Some(3));
        let s = svc.handle(&req(r#"{"op":"stats"}"#));
        assert_eq!(s.get("n_alive").unwrap().as_u64(), Some(197));
        assert_eq!(s.get("n_shards").unwrap().as_u64(), Some(2));
        assert_eq!(s.get("model").unwrap().as_str(), Some(DEFAULT_MODEL));
        let tele = s.get("telemetry").unwrap().get("ops").unwrap();
        assert!(tele.get("delete").is_some());
        // the mutation advanced every shard's epoch by exactly 2 (seqlock);
        // under the DARE_LAZY_POLICY=on_read matrix leg the background
        // compactor may legitimately add further +2 bumps, so assert the
        // invariant (even, moved) rather than the eager-exact value
        let lazy = svc.lazy_policy().is_lazy();
        let shards = s.get("shards").unwrap().as_arr().unwrap();
        assert_eq!(shards.len(), 2);
        for sh in shards {
            let epoch = sh.get("epoch").unwrap().as_u64().unwrap();
            if lazy {
                assert!(epoch >= 2 && epoch % 2 == 0, "bad epoch {epoch}");
            } else {
                assert_eq!(epoch, 2);
            }
            assert_eq!(sh.get("trees").unwrap().as_u64(), Some(2));
        }
        assert_eq!(
            s.get("telemetry").unwrap().get("counters").unwrap().get("mutations").unwrap().as_u64(),
            Some(1)
        );
    }

    #[test]
    fn add_then_delete_roundtrip() {
        let svc = service();
        let p = svc.n_features();
        let row: Vec<String> = vec!["0.5".into(); p];
        let r = svc.handle(&req(&format!(
            r#"{{"op":"add","row":[{}],"label":1}}"#,
            row.join(",")
        )));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
        let id = r.get("id").unwrap().as_u64().unwrap();
        let r = svc.handle(&req(&format!(r#"{{"op":"delete","ids":[{id}]}}"#)));
        assert_eq!(r.get("deleted").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn delete_cost_query() {
        let svc = service();
        let r = svc.handle(&req(r#"{"op":"delete_cost","id":5}"#));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
        assert!(r.get("cost").unwrap().as_u64().is_some());
        let bad = svc.handle(&req(r#"{"op":"delete_cost","id":999999}"#));
        assert_eq!(bad.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(
            bad.get("error").unwrap().get("code").unwrap().as_str(),
            Some("unknown_id")
        );
    }

    #[test]
    fn error_paths() {
        let svc = service();
        for bad in [
            r#"{"op":"nope"}"#,
            r#"{"op":"predict"}"#,
            r#"{"op":"delete"}"#,
            r#"{"op":"add","row":[1.0],"label":5}"#,
            r#"{"op":"add","row":[1.0],"label":1}"#,  // wrong arity
            r#"{"op":"predict","rows":[[1.0]]}"#,     // wrong arity: error, not a panic
            r#"{"op":"predict","rows":[[]]}"#,        // empty row
            r#"{"v":1,"model":"ghost","op":"stats"}"#, // unknown model
        ] {
            let r = svc.handle(&req(bad));
            assert_eq!(r.get("ok").unwrap().as_bool(), Some(false), "{bad}");
            // structured error object + the v0 string alias
            let eo = r.get("error").unwrap();
            assert!(eo.get("code").unwrap().as_str().is_some(), "{bad}");
            assert_eq!(
                r.get("error_msg").unwrap().as_str(),
                eo.get("msg").unwrap().as_str(),
                "{bad}"
            );
        }
    }

    #[test]
    fn lifecycle_ops_manage_the_registry() {
        let svc = service();
        // list: the default model is registered
        let r = svc.handle(&req(r#"{"v":1,"op":"list"}"#));
        let models = r.get("models").unwrap().as_arr().unwrap();
        assert_eq!(models.len(), 1);
        assert_eq!(models[0].get("name").unwrap().as_str(), Some(DEFAULT_MODEL));

        // save the default model, load it back under a new name
        let path = std::env::temp_dir().join("dare_service_lifecycle.json");
        let r = svc.handle(&req(&format!(
            r#"{{"op":"save","path":"{}"}}"#,
            path.display()
        )));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
        let r = svc.handle(&req(&format!(
            r#"{{"v":1,"model":"replica","op":"load","path":"{}"}}"#,
            path.display()
        )));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
        assert_eq!(r.get("model").unwrap().as_str(), Some("replica"));
        assert_eq!(svc.registry().len(), 2);

        // the replica serves byte-identical predictions
        let p = svc.n_features();
        let row = vec!["0.4"; p].join(",");
        let a = svc.handle(&req(&format!(r#"{{"op":"predict","rows":[[{row}]]}}"#)));
        let b = svc.handle(&req(&format!(
            r#"{{"v":1,"model":"replica","op":"predict","rows":[[{row}]]}}"#
        )));
        assert_eq!(a.to_string(), b.to_string());

        // deleting in the replica leaves the default model untouched
        let r = svc.handle(&req(r#"{"v":1,"model":"replica","op":"delete","ids":[0,1]}"#));
        assert_eq!(r.get("deleted").unwrap().as_u64(), Some(2));
        assert_eq!(svc.sharded().n_alive(), 200);
        let b2 = svc.handle(&req(&format!(
            r#"{{"v":1,"model":"{DEFAULT_MODEL}","op":"predict","rows":[[{row}]]}}"#
        )));
        assert_eq!(a.to_string(), b2.to_string());

        // duplicate load is a typed bad_request
        let r = svc.handle(&req(&format!(
            r#"{{"v":1,"model":"replica","op":"load","path":"{}"}}"#,
            path.display()
        )));
        assert_eq!(
            r.get("error").unwrap().get("code").unwrap().as_str(),
            Some("bad_request")
        );

        // drop; addressing the dropped model is unknown_model
        let r = svc.handle(&req(r#"{"v":1,"model":"replica","op":"drop"}"#));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
        let r = svc.handle(&req(r#"{"v":1,"model":"replica","op":"stats"}"#));
        assert_eq!(
            r.get("error").unwrap().get("code").unwrap().as_str(),
            Some("unknown_model")
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn lazy_service_defers_and_serves_exact_bits() {
        use crate::forest::lazy::LazyPolicy;
        // Two services over the same model: one eager, one on_read with a
        // compactor too slow to interfere — every response must be
        // bit-identical, and the lazy one must actually defer.
        let mk = |lazy: LazyPolicy| {
            let d = generate(
                &SynthSpec {
                    n: 220,
                    informative: 3,
                    redundant: 0,
                    noise: 2,
                    flip: 0.05,
                    ..Default::default()
                },
                11,
            );
            let f = DareForest::fit(
                d,
                &Params {
                    n_trees: 4,
                    max_depth: 6,
                    k: 5,
                    ..Default::default()
                },
                13,
            );
            UnlearningService::new(
                f,
                ServiceConfig {
                    batch_window: Duration::from_millis(1),
                    use_pjrt: false,
                    n_shards: 2,
                    lazy,
                    compact_interval: Duration::from_secs(3600),
                    ..Default::default()
                },
            )
        };
        let eager = mk(LazyPolicy::Eager);
        let lazy = mk(LazyPolicy::OnRead);
        assert_eq!(lazy.lazy_policy(), LazyPolicy::OnRead);

        let del = r#"{"op":"delete","ids":[1,2,3,5,8,13,21,34,55,89,100,110,120,130,140,144]}"#;
        let re = eager.handle(&req(del));
        let rl = lazy.handle(&req(del));
        assert_eq!(re.get("deleted").unwrap().as_u64(), rl.get("deleted").unwrap().as_u64());
        assert_eq!(
            re.get("retrain_cost").unwrap().as_u64(),
            rl.get("retrain_cost").unwrap().as_u64(),
            "mark-phase reported cost must equal the eager cost"
        );
        assert_eq!(re.get("deferred").unwrap().as_u64(), Some(0));
        let deferred = rl.get("deferred").unwrap().as_u64().unwrap();
        assert!(deferred > 0, "16 deletions should defer at least one retrain");

        // stats surfaces the backlog + cumulative counters
        let s = lazy.handle(&req(r#"{"op":"stats"}"#));
        assert_eq!(s.get("lazy_policy").unwrap().as_str(), Some("on_read"));
        assert!(s.get("dirty_subtrees").unwrap().as_u64().unwrap() > 0);
        assert!(s.get("deferred_retrains").unwrap().as_u64().unwrap() >= deferred);

        // flush-on-read: served predictions are bit-identical to eager
        let p = lazy.n_features();
        let row = vec!["0.2"; p].join(",");
        let pr = format!(r#"{{"op":"predict","rows":[[{row}]]}}"#);
        assert_eq!(
            lazy.handle(&req(&pr)).to_string(),
            eager.handle(&req(&pr)).to_string()
        );
        // delete_cost is as-if-flushed
        let dc = r#"{"op":"delete_cost","id":40}"#;
        assert_eq!(
            lazy.handle(&req(dc)).to_string(),
            eager.handle(&req(dc)).to_string()
        );

        // an explicit wire-level drain equalizes the stores completely
        let fl = lazy.handle(&req(r#"{"op":"flush"}"#));
        assert_eq!(fl.get("ok").unwrap().as_bool(), Some(true));
        let s = lazy.handle(&req(r#"{"op":"stats"}"#));
        assert_eq!(s.get("dirty_subtrees").unwrap().as_u64(), Some(0));
        let eager_snap = eager.snapshot_forest();
        lazy.sharded().for_each_tree(|gt, t| {
            assert!(
                t.structural_matches(&eager_snap.trees()[gt]),
                "tree {gt} diverged after the drain"
            );
        });
        lazy.sharded().validate().unwrap();
    }

    #[test]
    fn durable_service_recovers_and_certifies() {
        let root = std::env::temp_dir().join(format!("dare-svc-wal-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let wal_cfg = || ServiceConfig {
            batch_window: Duration::from_millis(1),
            use_pjrt: false,
            n_shards: 2,
            wal_dir: Some(root.clone()),
            wal_snapshot_every: 4,
            cert_key: Some("test-key".to_string()),
            ..Default::default()
        };
        let d = generate(
            &SynthSpec {
                n: 180,
                informative: 3,
                redundant: 0,
                noise: 2,
                flip: 0.05,
                ..Default::default()
            },
            21,
        );
        let f = DareForest::fit(
            d,
            &Params {
                n_trees: 3,
                max_depth: 5,
                k: 5,
                ..Default::default()
            },
            23,
        );

        // Session 1: mutate, certify a deletion, remember the state.
        let svc = UnlearningService::new(f.clone(), wal_cfg());
        let p = svc.n_features();
        let row = vec!["0.3"; p].join(",");
        svc.handle(&req(r#"{"op":"delete","ids":[0,5,9]}"#));
        svc.handle(&req(&format!(r#"{{"op":"add","row":[{row}],"label":1}}"#)));
        svc.handle(&req(r#"{"op":"delete","ids":[12,14]}"#));

        // certify before deletion → typed bad_request; after → a cert
        let r = svc.handle(&req(r#"{"op":"certify","id":30}"#));
        assert_eq!(
            r.get("error").unwrap().get("code").unwrap().as_str(),
            Some("bad_request")
        );
        let r = svc.handle(&req(r#"{"op":"certify","id":999999}"#));
        assert_eq!(
            r.get("error").unwrap().get("code").unwrap().as_str(),
            Some("unknown_id")
        );
        let r = svc.handle(&req(r#"{"op":"certify","id":5}"#));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
        let cert = r.get("cert").unwrap().clone();
        assert_eq!(cert.get("instance_id").unwrap().as_u64(), Some(5));
        assert_eq!(cert.get("epoch").unwrap().as_u64(), Some(3));

        let state_before = crate::forest::serialize::forest_to_json(&svc.snapshot_forest());
        let pr = format!(r#"{{"op":"predict","rows":[[{row}]]}}"#);
        let pred_before = svc.handle(&req(&pr)).to_string();
        drop(svc); // "crash" (any un-fsync'd tail is already durable: EveryOp)

        // Session 2: no forests passed in — everything comes off disk.
        let svc2 = UnlearningService::with_models(Vec::new(), wal_cfg());
        assert_eq!(svc2.registry().len(), 1);
        let state_after = crate::forest::serialize::forest_to_json(&svc2.snapshot_forest());
        assert_eq!(state_before, state_after, "recovered state must be byte-identical");
        assert_eq!(svc2.handle(&req(&pr)).to_string(), pred_before);
        // stats report durability + the recovered epoch
        let s = svc2.handle(&req(r#"{"op":"stats"}"#));
        assert_eq!(s.get("durable").unwrap().as_bool(), Some(true));
        assert_eq!(s.get("wal_epoch").unwrap().as_u64(), Some(3));
        // the pre-crash deletion is still absent and its cert verifies
        let r = svc2.handle(&req(r#"{"op":"delete_cost","id":5}"#));
        assert_eq!(
            r.get("error").unwrap().get("code").unwrap().as_str(),
            Some("unknown_id"),
            "deleted instance resurrected after recovery"
        );
        let vr = svc2.handle(&req(&format!(
            r#"{{"op":"verify_cert","cert":{cert}}}"#,
            cert = cert.to_string()
        )));
        assert_eq!(vr.get("valid").unwrap().as_bool(), Some(true));
        // a tampered cert does not verify
        let mut bad = cert.clone();
        bad.set("instance_id", 6u64);
        let vr = svc2.handle(&req(&format!(r#"{{"op":"verify_cert","cert":{bad}}}"#, bad = bad.to_string())));
        assert_eq!(vr.get("valid").unwrap().as_bool(), Some(false));

        // a passed-in forest for a recovered name is ignored (disk wins)
        drop(svc2);
        let svc3 = UnlearningService::new(f, wal_cfg());
        assert_eq!(
            crate::forest::serialize::forest_to_json(&svc3.snapshot_forest()),
            state_before,
            "durable state must win over the passed-in forest"
        );
        // drop removes the durability dir; restart serves nothing
        svc3.handle(&req(&format!(r#"{{"v":1,"model":"{DEFAULT_MODEL}","op":"drop"}}"#)));
        drop(svc3);
        let svc4 = UnlearningService::with_models(Vec::new(), wal_cfg());
        assert_eq!(svc4.registry().len(), 0, "dropped model resurrected");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn shutdown_flag_and_shutting_down_errors() {
        let svc = service();
        assert!(!svc.is_shutdown());
        svc.handle(&req(r#"{"op":"shutdown"}"#));
        assert!(svc.is_shutdown());
        // every further op is refused with the typed code
        let r = svc.handle(&req(r#"{"op":"stats"}"#));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(
            r.get("error").unwrap().get("code").unwrap().as_str(),
            Some("shutting_down")
        );
        // shutdown itself stays idempotent
        let r = svc.handle(&req(r#"{"op":"shutdown"}"#));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn shard_count_does_not_change_results() {
        // The same request stream against 1-, 2- and 4-shard services must
        // produce bit-identical responses — sharding is pure routing.
        let svcs: Vec<_> = [1usize, 2, 4].iter().map(|&s| service_with_shards(s)).collect();
        let p = svcs[0].n_features();
        let row = vec!["0.3"; p].join(",");
        let reqs = [
            format!(r#"{{"op":"delete","ids":[3,4,5]}}"#),
            format!(r#"{{"op":"add","row":[{row}],"label":0}}"#),
            format!(r#"{{"op":"predict","rows":[[{row}]]}}"#),
            format!(r#"{{"op":"delete_cost","id":9}}"#),
        ];
        for rq in &reqs {
            let rs: Vec<Value> = svcs.iter().map(|s| s.handle(&req(rq))).collect();
            for r in &rs[1..] {
                assert_eq!(r.to_string(), rs[0].to_string(), "request {rq} diverged");
            }
        }
        for s in &svcs {
            s.sharded().validate().unwrap();
        }
    }

    #[test]
    fn predictions_change_after_unlearning_an_instance_class() {
        // Deleting all positives of a region should pull predictions down —
        // the service-level view of exact unlearning.
        let svc = service();
        let (probe, pos_ids): (Vec<f32>, Vec<u32>) = svc.sharded().with_data(|d| {
            let pos: Vec<u32> = d.live_ids().into_iter().filter(|&i| d.y(i) == 1).collect();
            (d.row(pos[0]), pos)
        });
        let before = svc.sharded().predict_proba(&probe);
        // delete 80% of positives
        let del: Vec<String> = pos_ids
            .iter()
            .take(pos_ids.len() * 4 / 5)
            .map(|i| i.to_string())
            .collect();
        svc.handle(&req(&format!(r#"{{"op":"delete","ids":[{}]}}"#, del.join(","))));
        let after = svc.sharded().predict_proba(&probe);
        assert!(
            after < before + 1e-6,
            "removing positives should not raise positive probability ({before} -> {after})"
        );
    }

    #[test]
    fn follower_models_reject_mutations_and_annotate_stale_reads() {
        use crate::coordinator::replica::{ReplicaState, ReplicationConfig};
        let svc = service();
        let model = svc.registry().get(DEFAULT_MODEL).unwrap();
        // Nothing listens on port 1, so every leader contact fails fast —
        // this pins the graceful-degradation path, not a live tail.
        let rep = ReplicaState::new(
            ReplicationConfig {
                leader: "127.0.0.1:1".to_string(),
                stale_after_epochs: 0,
                ..Default::default()
            },
            0,
        );
        model.attach_replica(Arc::clone(&rep));

        // Mutations bounce with the read_only wire code naming the leader.
        for rq in [
            r#"{"op":"delete","ids":[1]}"#.to_string(),
            {
                let row = vec!["0.2"; svc.n_features()].join(",");
                format!(r#"{{"op":"add","row":[{row}],"label":1}}"#)
            },
            r#"{"op":"certify","id":3}"#.to_string(),
        ] {
            let r = svc.handle(&req(&rq));
            assert_eq!(r.get("ok").unwrap().as_bool(), Some(false), "{rq}");
            let err = r.get("error").unwrap();
            assert_eq!(err.get("code").unwrap().as_str(), Some("read_only"), "{rq}");
            assert_eq!(err.get("leader").unwrap().as_str(), Some("127.0.0.1:1"));
        }

        // Stats grow the replication gauges.
        let s = svc.handle(&req(r#"{"op":"stats"}"#));
        assert_eq!(s.get("role").unwrap().as_str(), Some("follower"));
        assert_eq!(s.get("replication_lag_epochs").unwrap().as_u64(), Some(0));
        assert_eq!(s.get("leader").unwrap().as_str(), Some("127.0.0.1:1"));
        assert!(s.get("leader_reachable").is_some());

        // In-sync follower: reads serve unannotated.
        let row = vec!["0.2"; svc.n_features()].join(",");
        let predict = format!(r#"{{"op":"predict","rows":[[{row}]]}}"#);
        let r = svc.handle(&req(&predict));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
        assert!(r.get("stale").is_none());

        // Behind the (zero) staleness bound: still served, but annotated.
        rep.note_leader_epoch(5);
        let r = svc.handle(&req(&predict));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(r.get("stale").unwrap().as_bool(), Some(true));
        let r = svc.handle(&req(r#"{"op":"delete_cost","id":5}"#));
        assert_eq!(r.get("stale").unwrap().as_bool(), Some(true));

        // Promote: the drain hits the unreachable leader, fails over, and
        // flips the model writable.
        let r = svc.handle(&req(r#"{"op":"promote"}"#));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(r.get("model").unwrap().as_str(), Some(DEFAULT_MODEL));
        let s = svc.handle(&req(r#"{"op":"stats"}"#));
        assert_eq!(s.get("role").unwrap().as_str(), Some("leader"));
        assert!(s.get("replication_lag_epochs").is_none());
        let r = svc.handle(&req(r#"{"op":"delete","ids":[1]}"#));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));

        // Promoting a model that is already a leader is a bad request.
        let r = svc.handle(&req(r#"{"op":"promote"}"#));
        assert_eq!(
            r.get("error").unwrap().get("code").unwrap().as_str(),
            Some("bad_request")
        );
    }

    #[test]
    fn pull_ops_require_durability() {
        let svc = service(); // no wal_dir
        for rq in [
            r#"{"op":"pull_snapshot"}"#,
            r#"{"op":"pull_log","after_epoch":0}"#,
        ] {
            let r = svc.handle(&req(rq));
            assert_eq!(r.get("ok").unwrap().as_bool(), Some(false), "{rq}");
            assert_eq!(
                r.get("error").unwrap().get("code").unwrap().as_str(),
                Some("bad_request"),
                "{rq}"
            );
        }
    }
}
