//! The unlearning service: a request router over a **sharded** DaRE forest
//! (DESIGN.md §8).
//!
//! Requests (JSON objects) are dispatched to:
//! - `predict` — read path: per-shard partial sums reduced in global tree
//!   order (never takes a write lock), via the PJRT predictor when the
//!   forest fits the compiled artifact — the predictor's tensor snapshot is
//!   refreshed lazily, re-tensorizing only shards whose epoch moved;
//! - `delete` — write path: routed through the [`DeletionBatcher`] so
//!   concurrent GDPR requests share the mutation thread / retrain batches;
//! - `add` — write path (continual learning §6);
//! - `delete_cost` — the dry-run adversary signal (read path);
//! - `stats` — telemetry + model shape + per-shard epochs;
//! - `save` — snapshot the model+data to disk;
//! - `shutdown` — stop a `serve()` loop.
//!
//! Wire format: one JSON object per line over TCP (see `protocol`).

use crate::coordinator::batcher::DeletionBatcher;
use crate::coordinator::shards::ShardedForest;
use crate::coordinator::telemetry::Telemetry;
use crate::forest::forest::DareForest;
use crate::forest::lazy::LazyPolicy;
use crate::runtime::{Engine, Manifest, PjrtPredictor};
use crate::util::json::Value;
use crate::util::threadpool::default_threads;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, RwLock, Weak};
use std::time::Duration;

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Batching window for deletion requests.
    pub batch_window: Duration,
    /// Max ids per deletion batch.
    pub max_batch: usize,
    /// Try to use the PJRT predictor (falls back to native when the forest
    /// exceeds the artifact shape or artifacts are missing).
    pub use_pjrt: bool,
    /// Forest shard count; 0 means the threadpool width (DESIGN.md §8).
    pub n_shards: usize,
    /// When deferred retrains run (DESIGN.md §9). The default honors the
    /// `DARE_LAZY_POLICY` environment variable (`eager` | `on_read` |
    /// `budgeted:<k>`), falling back to eager — this is how the CI matrix
    /// leg serves the whole tier-1 suite under `on_read`.
    pub lazy: LazyPolicy,
    /// How often the background compactor wakes to drain deferred retrains
    /// (ignored under `LazyPolicy::Eager`).
    pub compact_interval: Duration,
    /// Deferred retrains the compactor executes per tree per tick.
    pub compact_budget: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            batch_window: Duration::from_millis(10),
            max_batch: 4096,
            use_pjrt: true,
            n_shards: 0,
            lazy: LazyPolicy::from_env(),
            compact_interval: Duration::from_millis(25),
            compact_budget: 8,
        }
    }
}

/// The unlearning service.
pub struct UnlearningService {
    sharded: Arc<ShardedForest>,
    batcher: DeletionBatcher,
    telemetry: Telemetry,
    /// RwLock, not Mutex: predicts over a current snapshot share the read
    /// lock (the backend executable serializes internally), only refreshes
    /// take the write lock.
    pjrt: RwLock<Option<PjrtPredictor>>,
    manifest: Option<Manifest>,
    /// Per-shard epochs the PJRT tensor snapshot was last refreshed at —
    /// only ever published after an epoch-validated (consistent) refresh;
    /// compared against [`ShardedForest::shard_epochs`] so only mutated
    /// shards are re-tensorized.
    pjrt_epochs: Mutex<Vec<u64>>,
    shutdown: AtomicBool,
}

impl UnlearningService {
    pub fn new(forest: DareForest, cfg: ServiceConfig) -> Arc<Self> {
        // Build the PJRT predictor against the intact forest, then hand the
        // trees over to the sharded store.
        let (pjrt, manifest) = if cfg.use_pjrt {
            match crate::runtime::manifest::locate_artifacts()
                .ok_or_else(|| anyhow::anyhow!("artifacts not built"))
                .and_then(|dir| Manifest::load(&dir))
            {
                Ok(m) => {
                    let p = Engine::global()
                        .and_then(|e| PjrtPredictor::new(e, &m, &forest))
                        .ok();
                    (p, Some(m))
                }
                Err(_) => (None, None),
            }
        } else {
            (None, None)
        };
        let n_shards = if cfg.n_shards == 0 {
            default_threads()
        } else {
            cfg.n_shards
        };
        let sharded = Arc::new(ShardedForest::new_with_policy(forest, n_shards, cfg.lazy));
        let batcher = DeletionBatcher::start(Arc::clone(&sharded), cfg.batch_window, cfg.max_batch);
        let pjrt_epochs = sharded.shard_epochs();
        let svc = Arc::new(UnlearningService {
            sharded,
            batcher,
            telemetry: Telemetry::new(),
            pjrt: RwLock::new(pjrt),
            manifest,
            pjrt_epochs: Mutex::new(pjrt_epochs),
            shutdown: AtomicBool::new(false),
        });
        if cfg.lazy.is_lazy() {
            spawn_compactor(Arc::downgrade(&svc), cfg.compact_interval, cfg.compact_budget);
        }
        svc
    }

    /// Whether the PJRT predictor is active.
    pub fn pjrt_active(&self) -> bool {
        self.pjrt.read().unwrap().is_some()
    }

    /// The service's deferral policy (DESIGN.md §9).
    pub fn lazy_policy(&self) -> LazyPolicy {
        self.sharded.lazy_policy()
    }

    /// The sharded forest store backing this service.
    pub fn sharded(&self) -> &Arc<ShardedForest> {
        &self.sharded
    }

    /// Clone a consistent [`DareForest`] view of the current model+data.
    pub fn snapshot_forest(&self) -> DareForest {
        self.sharded.snapshot()
    }

    /// Feature arity of the served model.
    pub fn n_features(&self) -> usize {
        self.sharded.n_features()
    }

    pub fn telemetry(&self) -> &Telemetry {
        &self.telemetry
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Handle one request object, returning the response object.
    pub fn handle(&self, req: &Value) -> Value {
        let op = req.get("op").and_then(Value::as_str).unwrap_or("");
        match op {
            "predict" => self.telemetry.timed("predict", || {
                let r = self.op_predict(req);
                let ok = r.get("ok").and_then(Value::as_bool) == Some(true);
                (r, ok)
            }),
            "delete" => self.telemetry.timed("delete", || {
                let r = self.op_delete(req);
                let ok = r.get("ok").and_then(Value::as_bool) == Some(true);
                (r, ok)
            }),
            "add" => self.telemetry.timed("add", || {
                let r = self.op_add(req);
                let ok = r.get("ok").and_then(Value::as_bool) == Some(true);
                (r, ok)
            }),
            "delete_cost" => self.telemetry.timed("delete_cost", || {
                let r = self.op_delete_cost(req);
                let ok = r.get("ok").and_then(Value::as_bool) == Some(true);
                (r, ok)
            }),
            "stats" => self.op_stats(),
            "save" => self.op_save(req),
            "shutdown" => {
                self.shutdown.store(true, Ordering::SeqCst);
                ok_response()
            }
            _ => err_response(&format!("unknown op '{op}'")),
        }
    }

    /// Whether the PJRT tensor snapshot matches the current (stable) shard
    /// epochs. `pjrt_epochs` is only published after an epoch-validated
    /// refresh, so equality implies both current and consistent.
    fn pjrt_snapshot_current(&self) -> bool {
        *self.pjrt_epochs.lock().unwrap() == self.sharded.shard_epochs()
    }

    /// Refresh the PJRT tensor snapshot for shards whose epoch moved since
    /// the last refresh, epoch-validated like the native read path: the
    /// epoch vector must be even and unchanged across the whole refresh,
    /// else the per-shard reads could mix pre-/post-mutation trees into a
    /// forest state that never existed. Returns true when the snapshot is
    /// current and consistent (safe to serve); false means serve native
    /// this request (`pjrt_epochs` stays unpublished, so every shard the
    /// torn attempt touched is still marked dirty and re-tensorized next
    /// round). Disables the predictor permanently when a refresh errors —
    /// the forest outgrew the artifact.
    fn refresh_pjrt(&self, pjrt_guard: &mut Option<PjrtPredictor>) -> bool {
        if pjrt_guard.is_none() || self.manifest.is_none() {
            return false;
        }
        let mut last = self.pjrt_epochs.lock().unwrap();
        for _ in 0..2 {
            let epochs = self.sharded.shard_epochs();
            if epochs.iter().any(|e| e % 2 == 1) {
                // A mutation is in flight (§8 seqlock): this request takes
                // the native path, which waits it out consistently.
                return false;
            }
            // Lazy policy: a concurrent mutation may have *marked* pending
            // subtrees since the caller's eligibility check — tensorizing
            // those collapsed regions would serve non-eager bits. Pending
            // counters publish under the shard write locks before the
            // epochs go even, so re-checking here inside the epoch-
            // validated window closes the race: a mark that lands after
            // this check moves the epochs and fails the validation below.
            if self.sharded.lazy_policy().is_lazy() && self.sharded.pending_retrains() > 0 {
                return false;
            }
            if epochs == *last {
                return true;
            }
            let dirty: Vec<usize> =
                (0..epochs.len()).filter(|&s| epochs[s] != last[s]).collect();
            let refreshed = (|| -> anyhow::Result<()> {
                let pred = pjrt_guard.as_mut().unwrap();
                for &s in &dirty {
                    self.sharded
                        .with_shard_trees(s, |first, trees| pred.refresh_trees(first, trees))?;
                }
                pred.rebuild_literals()
            })();
            if refreshed.is_err() {
                *pjrt_guard = None;
                return false;
            }
            // Validate: if a mutation interleaved, the snapshot may be torn
            // — do not publish; retry once, then fall back to native.
            if self.sharded.shard_epochs() == epochs {
                *last = epochs;
                return true;
            }
        }
        false
    }

    fn op_predict(&self, req: &Value) -> Value {
        let Some(rows_json) = req.get("rows").and_then(Value::as_arr) else {
            return err_response("predict needs 'rows': [[f32,...],...]");
        };
        let p = self.sharded.n_features();
        let mut rows: Vec<Vec<f32>> = Vec::with_capacity(rows_json.len());
        for r in rows_json {
            let Some(cells) = r.as_arr() else {
                return err_response("rows must be arrays of numbers");
            };
            // Arity is validated here because the arena descent indexes
            // row[attr] unchecked — a short row from the wire must be a
            // request error, not a panic in the handler thread.
            if cells.len() != p {
                return err_response(&format!(
                    "row has {} features, model expects {p}",
                    cells.len()
                ));
            }
            rows.push(cells.iter().map(|c| c.as_f64().unwrap_or(0.0) as f32).collect());
        }
        self.telemetry.incr("predict_rows", rows.len() as u64);

        // Under a lazy policy the tensorized snapshot may contain pending
        // (stale) subtrees that these rows never descend into — the epochs
        // can't tell us which. PJRT serves only a fully-flushed model; with
        // a backlog, this request takes the native path, which flushes
        // exactly the subtrees it reads. The compactor drains the backlog
        // and PJRT re-engages via the normal epoch diff.
        let pjrt_eligible =
            !self.sharded.lazy_policy().is_lazy() || self.sharded.pending_retrains() == 0;

        // Fast path: PJRT predicts over a current snapshot share the read
        // lock — concurrent predicts don't serialize on the service layer.
        if pjrt_eligible {
            {
                let pjrt = self.pjrt.read().unwrap();
                if let Some(pred) = pjrt.as_ref() {
                    if self.pjrt_snapshot_current() {
                        if let Ok(probs) = pred.predict(&rows) {
                            return pjrt_response(&probs);
                        }
                    }
                }
            }
            // Slow path (model mutated since the last snapshot): take the
            // write lock, refresh only the dirty shards, and serve if the
            // refresh was epoch-consistent. The read guard is dropped in
            // its own block before the write acquisition — same-thread
            // read→write on one RwLock would deadlock.
            let pjrt_present = { self.pjrt.read().unwrap().is_some() };
            if pjrt_present {
                let mut pjrt_guard = self.pjrt.write().unwrap();
                if self.refresh_pjrt(&mut pjrt_guard) {
                    if let Some(pred) = pjrt_guard.as_ref() {
                        if let Ok(probs) = pred.predict(&rows) {
                            return pjrt_response(&probs);
                        }
                    }
                }
            }
        }

        // Native path: per-shard partials, no write lock anywhere.
        let probs = self.sharded.predict_proba_rows(&rows);
        let mut resp = ok_response();
        resp.set("probs", probs.iter().map(|p| *p as f64).collect::<Vec<f64>>());
        resp.set("engine", "native");
        resp
    }

    fn op_delete(&self, req: &Value) -> Value {
        let Some(ids_json) = req.get("ids").and_then(Value::as_arr) else {
            return err_response("delete needs 'ids': [u32,...]");
        };
        let ids: Vec<u32> = ids_json.iter().filter_map(|v| v.as_u64()).map(|v| v as u32).collect();
        if ids.len() != ids_json.len() {
            return err_response("ids must be non-negative integers");
        }
        match self.batcher.delete(ids) {
            Ok(out) => {
                // A no-op batch (all ids dead/duplicate) mutates nothing and
                // moves no shard epoch — count only effective mutations so
                // 'mutations' stays reconcilable with the epochs.
                if out.deleted > 0 {
                    self.telemetry.incr("mutations", 1);
                }
                self.telemetry.incr("deleted_ids", out.deleted as u64);
                self.telemetry.incr("deferred_retrains", out.deferred as u64);
                let mut resp = ok_response();
                resp.set("deleted", out.deleted)
                    .set("skipped", out.skipped)
                    .set("retrain_cost", out.retrain_cost)
                    .set("deferred", out.deferred)
                    .set("batch_size", out.batch_size);
                resp
            }
            Err(e) => err_response(&format!("{e}")),
        }
    }

    fn op_add(&self, req: &Value) -> Value {
        let Some(row_json) = req.get("row").and_then(Value::as_arr) else {
            return err_response("add needs 'row': [f32,...]");
        };
        let Some(label) = req.get("label").and_then(Value::as_u64) else {
            return err_response("add needs 'label': 0|1");
        };
        if label > 1 {
            return err_response("label must be 0 or 1");
        }
        let row: Vec<f32> = row_json.iter().map(|v| v.as_f64().unwrap_or(0.0) as f32).collect();
        match self.sharded.add(&row, label as u8) {
            Ok(id) => {
                self.telemetry.incr("mutations", 1);
                let mut resp = ok_response();
                resp.set("id", id);
                resp
            }
            Err(e) => err_response(&format!("{e}")),
        }
    }

    fn op_delete_cost(&self, req: &Value) -> Value {
        let Some(id) = req.get("id").and_then(Value::as_u64) else {
            return err_response("delete_cost needs 'id'");
        };
        match self.sharded.delete_cost(id as u32) {
            Ok(cost) => {
                let mut resp = ok_response();
                resp.set("cost", cost);
                resp
            }
            Err(_) => err_response("not a live instance"),
        }
    }

    fn op_stats(&self) -> Value {
        let mem = self.sharded.memory();
        let epochs = self.sharded.shard_epochs();
        let mut shards = Vec::with_capacity(epochs.len());
        for (s, &epoch) in epochs.iter().enumerate() {
            let trees = self.sharded.with_shard_trees(s, |_, ts| ts.len());
            let mut o = Value::obj();
            o.set("trees", trees).set("epoch", epoch);
            shards.push(o);
        }
        let (deferred, flushed) = self.sharded.retrain_counters();
        let mut resp = ok_response();
        resp.set("telemetry", self.telemetry.snapshot())
            .set("n_alive", self.sharded.n_alive())
            .set("n_trees", self.sharded.n_trees())
            .set("n_shards", self.sharded.n_shards())
            .set("shards", Value::Arr(shards))
            .set("pjrt_active", self.pjrt_active())
            .set("lazy_policy", self.sharded.lazy_policy().to_string())
            .set("dirty_subtrees", self.sharded.pending_retrains())
            .set("deferred_retrains", deferred)
            .set("flushed_retrains", flushed)
            .set("model_bytes", mem.total())
            .set("data_bytes", self.sharded.data_bytes());
        resp
    }

    fn op_save(&self, req: &Value) -> Value {
        let Some(path) = req.get("path").and_then(Value::as_str) else {
            return err_response("save needs 'path'");
        };
        let snapshot = self.sharded.snapshot();
        match crate::forest::serialize::save(&snapshot, std::path::Path::new(path)) {
            Ok(()) => ok_response(),
            Err(e) => err_response(&format!("{e}")),
        }
    }
}

/// The background compactor (DESIGN.md §9): a detached thread that drains
/// deferred retrains during idle ticks so the flush cost is paid off the
/// request path. Holds only a `Weak` handle — dropping the last service
/// `Arc` (or the shutdown op) stops it within one tick. Timing is
/// nondeterministic and harmlessly so: retrains are path-seeded, so *when*
/// a flush runs cannot change what it builds.
fn spawn_compactor(svc: Weak<UnlearningService>, interval: Duration, budget: usize) {
    let _ = std::thread::Builder::new()
        .name("dare-compactor".into())
        .spawn(move || loop {
            std::thread::sleep(interval);
            let Some(svc) = svc.upgrade() else {
                return;
            };
            if svc.is_shutdown() {
                return;
            }
            if svc.sharded.pending_retrains() > 0 {
                let flushed = svc.sharded.compact(budget);
                if flushed > 0 {
                    svc.telemetry.incr("compacted_retrains", flushed);
                }
            }
        });
}

fn pjrt_response(probs: &[f32]) -> Value {
    let mut resp = ok_response();
    resp.set("probs", probs.iter().map(|p| *p as f64).collect::<Vec<f64>>());
    resp.set("engine", "pjrt");
    resp
}

pub fn ok_response() -> Value {
    let mut v = Value::obj();
    v.set("ok", true);
    v
}

pub fn err_response(msg: &str) -> Value {
    let mut v = Value::obj();
    v.set("ok", false).set("error", msg);
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};
    use crate::forest::params::Params;
    use crate::util::json::parse;

    fn service_with_shards(n_shards: usize) -> Arc<UnlearningService> {
        let d = generate(
            &SynthSpec {
                n: 200,
                informative: 3,
                redundant: 0,
                noise: 2,
                flip: 0.05,
                ..Default::default()
            },
            7,
        );
        let f = DareForest::fit(
            d,
            &Params {
                n_trees: 4,
                max_depth: 5,
                k: 5,
                ..Default::default()
            },
            3,
        );
        UnlearningService::new(
            f,
            ServiceConfig {
                batch_window: Duration::from_millis(1),
                use_pjrt: false, // unit tests: native path (pjrt covered separately)
                n_shards,
                ..Default::default()
            },
        )
    }

    fn service() -> Arc<UnlearningService> {
        service_with_shards(2)
    }

    fn req(s: &str) -> Value {
        parse(s).unwrap()
    }

    #[test]
    fn predict_roundtrip() {
        let svc = service();
        let p = svc.n_features();
        let row: Vec<String> = vec!["0.1".into(); p];
        let r = svc.handle(&req(&format!(r#"{{"op":"predict","rows":[[{}]]}}"#, row.join(","))));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
        let probs = r.get("probs").unwrap().as_arr().unwrap();
        assert_eq!(probs.len(), 1);
        let pr = probs[0].as_f64().unwrap();
        assert!((0.0..=1.0).contains(&pr));
        assert_eq!(r.get("engine").unwrap().as_str(), Some("native"));
    }

    #[test]
    fn delete_then_stats() {
        let svc = service();
        let r = svc.handle(&req(r#"{"op":"delete","ids":[0,1,2]}"#));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(r.get("deleted").unwrap().as_u64(), Some(3));
        let s = svc.handle(&req(r#"{"op":"stats"}"#));
        assert_eq!(s.get("n_alive").unwrap().as_u64(), Some(197));
        assert_eq!(s.get("n_shards").unwrap().as_u64(), Some(2));
        let tele = s.get("telemetry").unwrap().get("ops").unwrap();
        assert!(tele.get("delete").is_some());
        // the mutation advanced every shard's epoch by exactly 2 (seqlock);
        // under the DARE_LAZY_POLICY=on_read matrix leg the background
        // compactor may legitimately add further +2 bumps, so assert the
        // invariant (even, moved) rather than the eager-exact value
        let lazy = svc.lazy_policy().is_lazy();
        let shards = s.get("shards").unwrap().as_arr().unwrap();
        assert_eq!(shards.len(), 2);
        for sh in shards {
            let epoch = sh.get("epoch").unwrap().as_u64().unwrap();
            if lazy {
                assert!(epoch >= 2 && epoch % 2 == 0, "bad epoch {epoch}");
            } else {
                assert_eq!(epoch, 2);
            }
            assert_eq!(sh.get("trees").unwrap().as_u64(), Some(2));
        }
        assert_eq!(
            s.get("telemetry").unwrap().get("counters").unwrap().get("mutations").unwrap().as_u64(),
            Some(1)
        );
    }

    #[test]
    fn add_then_delete_roundtrip() {
        let svc = service();
        let p = svc.n_features();
        let row: Vec<String> = vec!["0.5".into(); p];
        let r = svc.handle(&req(&format!(
            r#"{{"op":"add","row":[{}],"label":1}}"#,
            row.join(",")
        )));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
        let id = r.get("id").unwrap().as_u64().unwrap();
        let r = svc.handle(&req(&format!(r#"{{"op":"delete","ids":[{id}]}}"#)));
        assert_eq!(r.get("deleted").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn delete_cost_query() {
        let svc = service();
        let r = svc.handle(&req(r#"{"op":"delete_cost","id":5}"#));
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
        assert!(r.get("cost").unwrap().as_u64().is_some());
        let bad = svc.handle(&req(r#"{"op":"delete_cost","id":999999}"#));
        assert_eq!(bad.get("ok").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn error_paths() {
        let svc = service();
        for bad in [
            r#"{"op":"nope"}"#,
            r#"{"op":"predict"}"#,
            r#"{"op":"delete"}"#,
            r#"{"op":"add","row":[1.0],"label":5}"#,
            r#"{"op":"add","row":[1.0],"label":1}"#,  // wrong arity
            r#"{"op":"predict","rows":[[1.0]]}"#,     // wrong arity: error, not a panic
            r#"{"op":"predict","rows":[[]]}"#,        // empty row
        ] {
            let r = svc.handle(&req(bad));
            assert_eq!(r.get("ok").unwrap().as_bool(), Some(false), "{bad}");
            assert!(r.get("error").is_some());
        }
    }

    #[test]
    fn lazy_service_defers_and_serves_exact_bits() {
        use crate::forest::lazy::LazyPolicy;
        // Two services over the same model: one eager, one on_read with a
        // compactor too slow to interfere — every response must be
        // bit-identical, and the lazy one must actually defer.
        let mk = |lazy: LazyPolicy| {
            let d = generate(
                &SynthSpec {
                    n: 220,
                    informative: 3,
                    redundant: 0,
                    noise: 2,
                    flip: 0.05,
                    ..Default::default()
                },
                11,
            );
            let f = DareForest::fit(
                d,
                &Params {
                    n_trees: 4,
                    max_depth: 6,
                    k: 5,
                    ..Default::default()
                },
                13,
            );
            UnlearningService::new(
                f,
                ServiceConfig {
                    batch_window: Duration::from_millis(1),
                    use_pjrt: false,
                    n_shards: 2,
                    lazy,
                    compact_interval: Duration::from_secs(3600),
                    ..Default::default()
                },
            )
        };
        let eager = mk(LazyPolicy::Eager);
        let lazy = mk(LazyPolicy::OnRead);
        assert_eq!(lazy.lazy_policy(), LazyPolicy::OnRead);

        let del = r#"{"op":"delete","ids":[1,2,3,5,8,13,21,34,55,89,100,110,120,130,140,144]}"#;
        let re = eager.handle(&req(del));
        let rl = lazy.handle(&req(del));
        assert_eq!(re.get("deleted").unwrap().as_u64(), rl.get("deleted").unwrap().as_u64());
        assert_eq!(
            re.get("retrain_cost").unwrap().as_u64(),
            rl.get("retrain_cost").unwrap().as_u64(),
            "mark-phase reported cost must equal the eager cost"
        );
        assert_eq!(re.get("deferred").unwrap().as_u64(), Some(0));
        let deferred = rl.get("deferred").unwrap().as_u64().unwrap();
        assert!(deferred > 0, "16 deletions should defer at least one retrain");

        // stats surfaces the backlog + cumulative counters
        let s = lazy.handle(&req(r#"{"op":"stats"}"#));
        assert_eq!(s.get("lazy_policy").unwrap().as_str(), Some("on_read"));
        assert!(s.get("dirty_subtrees").unwrap().as_u64().unwrap() > 0);
        assert!(s.get("deferred_retrains").unwrap().as_u64().unwrap() >= deferred);

        // flush-on-read: served predictions are bit-identical to eager
        let p = lazy.n_features();
        let row = vec!["0.2"; p].join(",");
        let pr = format!(r#"{{"op":"predict","rows":[[{row}]]}}"#);
        assert_eq!(
            lazy.handle(&req(&pr)).to_string(),
            eager.handle(&req(&pr)).to_string()
        );
        // delete_cost is as-if-flushed
        let dc = r#"{"op":"delete_cost","id":40}"#;
        assert_eq!(
            lazy.handle(&req(dc)).to_string(),
            eager.handle(&req(dc)).to_string()
        );

        // an explicit full drain equalizes the stores completely
        lazy.sharded().flush_all();
        let s = lazy.handle(&req(r#"{"op":"stats"}"#));
        assert_eq!(s.get("dirty_subtrees").unwrap().as_u64(), Some(0));
        let eager_snap = eager.snapshot_forest();
        lazy.sharded().for_each_tree(|gt, t| {
            assert!(
                t.structural_matches(&eager_snap.trees()[gt]),
                "tree {gt} diverged after the drain"
            );
        });
        lazy.sharded().validate().unwrap();
    }

    #[test]
    fn shutdown_flag() {
        let svc = service();
        assert!(!svc.is_shutdown());
        svc.handle(&req(r#"{"op":"shutdown"}"#));
        assert!(svc.is_shutdown());
    }

    #[test]
    fn shard_count_does_not_change_results() {
        // The same request stream against 1-, 2- and 4-shard services must
        // produce bit-identical responses — sharding is pure routing.
        let svcs: Vec<_> = [1usize, 2, 4].iter().map(|&s| service_with_shards(s)).collect();
        let p = svcs[0].n_features();
        let row = vec!["0.3"; p].join(",");
        let reqs = [
            format!(r#"{{"op":"delete","ids":[3,4,5]}}"#),
            format!(r#"{{"op":"add","row":[{row}],"label":0}}"#),
            format!(r#"{{"op":"predict","rows":[[{row}]]}}"#),
            format!(r#"{{"op":"delete_cost","id":9}}"#),
        ];
        for rq in &reqs {
            let rs: Vec<Value> = svcs.iter().map(|s| s.handle(&req(rq))).collect();
            for r in &rs[1..] {
                assert_eq!(r.to_string(), rs[0].to_string(), "request {rq} diverged");
            }
        }
        for s in &svcs {
            s.sharded().validate().unwrap();
        }
    }

    #[test]
    fn predictions_change_after_unlearning_an_instance_class() {
        // Deleting all positives of a region should pull predictions down —
        // the service-level view of exact unlearning.
        let svc = service();
        let (probe, pos_ids): (Vec<f32>, Vec<u32>) = svc.sharded().with_data(|d| {
            let pos: Vec<u32> = d.live_ids().into_iter().filter(|&i| d.y(i) == 1).collect();
            (d.row(pos[0]), pos)
        });
        let before = svc.sharded().predict_proba(&probe);
        // delete 80% of positives
        let del: Vec<String> = pos_ids
            .iter()
            .take(pos_ids.len() * 4 / 5)
            .map(|i| i.to_string())
            .collect();
        svc.handle(&req(&format!(r#"{{"op":"delete","ids":[{}]}}"#, del.join(","))));
        let after = svc.sharded().predict_proba(&probe);
        assert!(
            after < before + 1e-6,
            "removing positives should not raise positive probability ({before} -> {after})"
        );
    }
}
