//! Baseline tree-ensemble learners for the paper's Table 5 comparison and
//! the naive-retraining comparator:
//!
//! - [`BaselineKind::Standard`] — a standard greedy random forest à la
//!   scikit-learn (exhaustive valid thresholds per sampled attribute),
//!   with or without bootstrapping;
//! - [`BaselineKind::ExtraTrees`] — Extra Trees (Geurts et al., 2006): one
//!   uniformly-drawn threshold per sampled attribute, best kept;
//! - [`BaselineKind::RandomTrees`] — extremely randomized trees: a single
//!   uniformly-drawn attribute + threshold, no scoring at all.
//!
//! Baselines use a *lean* node representation (split + children only) so the
//! Table-3 memory comparison against DaRE's stat-laden nodes is honest.

pub mod simple;

pub use simple::{BaselineForest, BaselineKind, BaselineParams};
