//! Lean baseline forests (see module docs in `baselines`).

use crate::data::dataset::{Dataset, InstanceId};
use crate::forest::criterion::split_score;
use crate::forest::params::{MaxFeatures, SplitCriterion};
use crate::forest::stats::enumerate_valid;
use crate::util::rng::{mix_seed, Rng};
use crate::util::threadpool::scope_map;

/// Which baseline family to train.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BaselineKind {
    /// Greedy RF over all valid thresholds of p̃ sampled attributes
    /// (scikit-learn-style).
    Standard,
    /// Extra Trees: one random threshold per sampled attribute, scored.
    ExtraTrees,
    /// Extremely randomized: one random attribute, one random threshold.
    RandomTrees,
}

impl std::str::FromStr for BaselineKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "standard" | "rf" | "sklearn" => Ok(BaselineKind::Standard),
            "extra" | "extra_trees" | "extratrees" => Ok(BaselineKind::ExtraTrees),
            "random" | "random_trees" | "randomtrees" => Ok(BaselineKind::RandomTrees),
            _ => Err(format!("unknown baseline '{s}'")),
        }
    }
}

/// Baseline hyperparameters (subset of DaRE's [`crate::forest::Params`]).
#[derive(Clone, Debug)]
pub struct BaselineParams {
    pub kind: BaselineKind,
    pub n_trees: usize,
    pub max_depth: usize,
    pub max_features: MaxFeatures,
    pub criterion: SplitCriterion,
    pub bootstrap: bool,
    pub min_samples_split: usize,
    pub n_threads: usize,
}

impl Default for BaselineParams {
    fn default() -> Self {
        BaselineParams {
            kind: BaselineKind::Standard,
            n_trees: 100,
            max_depth: 10,
            max_features: MaxFeatures::Sqrt,
            criterion: SplitCriterion::Gini,
            bootstrap: false,
            min_samples_split: 2,
            n_threads: 1,
        }
    }
}

/// Lean tree node: split info or leaf value only (what a deployed
/// scikit-learn forest stores — the Table-3 "SKLearn RF" column).
#[derive(Clone, Debug)]
pub enum SimpleNode {
    Leaf {
        value: f32,
    },
    Split {
        attr: usize,
        v: f32,
        left: Box<SimpleNode>,
        right: Box<SimpleNode>,
    },
}

impl SimpleNode {
    pub fn predict(&self, row: &[f32]) -> f32 {
        let mut node = self;
        loop {
            match node {
                SimpleNode::Leaf { value } => return *value,
                SimpleNode::Split { attr, v, left, right } => {
                    node = if row[*attr] <= *v { left } else { right };
                }
            }
        }
    }

    pub fn memory_bytes(&self) -> usize {
        use std::mem::size_of;
        match self {
            SimpleNode::Leaf { .. } => size_of::<f32>(),
            SimpleNode::Split { left, right, .. } => {
                size_of::<usize>()
                    + size_of::<f32>()
                    + 2 * size_of::<usize>()
                    + left.memory_bytes()
                    + right.memory_bytes()
            }
        }
    }

    pub fn node_count(&self) -> usize {
        match self {
            SimpleNode::Leaf { .. } => 1,
            SimpleNode::Split { left, right, .. } => 1 + left.node_count() + right.node_count(),
        }
    }
}

/// An ensemble of lean trees.
#[derive(Clone, Debug)]
pub struct BaselineForest {
    pub params: BaselineParams,
    trees: Vec<SimpleNode>,
}

impl BaselineForest {
    pub fn fit(data: &Dataset, params: &BaselineParams, seed: u64) -> Self {
        let seeds: Vec<u64> = (0..params.n_trees)
            .map(|t| mix_seed(&[seed, t as u64, 0xBA5E]))
            .collect();
        let trees = scope_map(&seeds, params.n_threads, |_, &ts| {
            let mut rng = Rng::new(ts);
            let ids = if params.bootstrap {
                let live = data.live_ids();
                (0..live.len())
                    .map(|_| live[rng.index(live.len())])
                    .collect()
            } else {
                data.live_ids()
            };
            train(data, params, ids, 0, &mut rng)
        });
        BaselineForest {
            params: params.clone(),
            trees,
        }
    }

    pub fn predict_proba(&self, row: &[f32]) -> f32 {
        let s: f32 = self.trees.iter().map(|t| t.predict(row)).sum();
        s / self.trees.len() as f32
    }

    pub fn predict_proba_dataset(&self, data: &Dataset) -> Vec<f32> {
        data.live_ids()
            .iter()
            .map(|&i| self.predict_proba(&data.row(i)))
            .collect()
    }

    /// Total model bytes (structure only — lean representation).
    pub fn memory_bytes(&self) -> usize {
        self.trees.iter().map(|t| t.memory_bytes()).sum()
    }

    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }
}

fn leaf(data: &Dataset, ids: &[InstanceId]) -> SimpleNode {
    let n = ids.len() as f32;
    if n == 0.0 {
        return SimpleNode::Leaf { value: 0.5 };
    }
    let pos: u32 = ids.iter().map(|&i| data.y(i) as u32).sum();
    SimpleNode::Leaf {
        value: pos as f32 / n,
    }
}

fn train(
    data: &Dataset,
    params: &BaselineParams,
    ids: Vec<InstanceId>,
    depth: usize,
    rng: &mut Rng,
) -> SimpleNode {
    let n = ids.len() as u32;
    let n_pos: u32 = ids.iter().map(|&i| data.y(i) as u32).sum();
    if n < params.min_samples_split as u32
        || n_pos == 0
        || n_pos == n
        || depth >= params.max_depth
    {
        return leaf(data, &ids);
    }
    let p = data.n_features();
    let p_tilde = params.max_features.resolve(p);

    let chosen: Option<(usize, f32)> = match params.kind {
        BaselineKind::Standard => {
            // exhaustive valid thresholds over p̃ sampled attributes
            let mut order: Vec<usize> = (0..p).collect();
            rng.shuffle(&mut order);
            let mut tried = 0usize;
            let mut best: Option<(usize, f32, f64)> = None;
            for attr in order {
                if tried == p_tilde {
                    break;
                }
                let mut pairs: Vec<(f32, u8)> =
                    ids.iter().map(|&i| (data.x(i, attr), data.y(i))).collect();
                let cands = enumerate_valid(&mut pairs);
                if cands.is_empty() {
                    continue;
                }
                tried += 1;
                for t in cands {
                    let s = split_score(params.criterion, n, n_pos, t.n_left, t.n_left_pos);
                    match best {
                        Some((_, _, bs)) if s >= bs => {}
                        _ => best = Some((attr, t.v, s)),
                    }
                }
            }
            best.map(|(a, v, _)| (a, v))
        }
        BaselineKind::ExtraTrees => {
            // one uniform threshold per sampled attribute, best kept
            let mut order: Vec<usize> = (0..p).collect();
            rng.shuffle(&mut order);
            let mut tried = 0usize;
            let mut best: Option<(usize, f32, f64)> = None;
            for attr in order {
                if tried == p_tilde {
                    break;
                }
                let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
                for &i in &ids {
                    let x = data.x(i, attr);
                    lo = lo.min(x);
                    hi = hi.max(x);
                }
                if !(lo < hi) {
                    continue;
                }
                tried += 1;
                let v = rng.range_f32(lo, hi);
                let mut n_l = 0u32;
                let mut n_lp = 0u32;
                for &i in &ids {
                    if data.x(i, attr) <= v {
                        n_l += 1;
                        n_lp += data.y(i) as u32;
                    }
                }
                if n_l == 0 || n_l == n {
                    continue;
                }
                let s = split_score(params.criterion, n, n_pos, n_l, n_lp);
                match best {
                    Some((_, _, bs)) if s >= bs => {}
                    _ => best = Some((attr, v, s)),
                }
            }
            best.map(|(a, v, _)| (a, v))
        }
        BaselineKind::RandomTrees => {
            // a single random attribute + threshold, unscored
            let mut order: Vec<usize> = (0..p).collect();
            rng.shuffle(&mut order);
            let mut pick = None;
            for attr in order {
                let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
                for &i in &ids {
                    let x = data.x(i, attr);
                    lo = lo.min(x);
                    hi = hi.max(x);
                }
                if lo < hi {
                    pick = Some((attr, rng.range_f32(lo, hi)));
                    break;
                }
            }
            pick
        }
    };

    let Some((attr, v)) = chosen else {
        return leaf(data, &ids);
    };
    let mut left_ids = Vec::new();
    let mut right_ids = Vec::new();
    for &i in &ids {
        if data.x(i, attr) <= v {
            left_ids.push(i);
        } else {
            right_ids.push(i);
        }
    }
    if left_ids.is_empty() || right_ids.is_empty() {
        return leaf(data, &ids);
    }
    let left = train(data, params, left_ids, depth + 1, rng);
    let right = train(data, params, right_ids, depth + 1, rng);
    SimpleNode::Split {
        attr,
        v,
        left: Box::new(left),
        right: Box::new(right),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::split::train_test;
    use crate::data::synth::{generate, SynthSpec};
    use crate::metrics::accuracy;

    fn dataset() -> (Dataset, Dataset) {
        let all = generate(
            &SynthSpec {
                n: 900,
                informative: 4,
                redundant: 2,
                noise: 4,
                flip: 0.05,
                ..Default::default()
            },
            31,
        );
        train_test(&all, 0.67, 0)
    }

    fn acc_of(kind: BaselineKind, bootstrap: bool) -> f64 {
        let (train_d, test_d) = dataset();
        let params = BaselineParams {
            kind,
            n_trees: 20,
            max_depth: 8,
            bootstrap,
            ..Default::default()
        };
        let f = BaselineForest::fit(&train_d, &params, 5);
        let probs = f.predict_proba_dataset(&test_d);
        let (_, ys, _) = test_d.to_row_major();
        accuracy(&probs, &ys)
    }

    #[test]
    fn standard_rf_learns() {
        let acc = acc_of(BaselineKind::Standard, false);
        assert!(acc > 0.75, "standard RF acc {acc}");
    }

    #[test]
    fn bootstrap_comparable_to_plain() {
        let plain = acc_of(BaselineKind::Standard, false);
        let boot = acc_of(BaselineKind::Standard, true);
        assert!((plain - boot).abs() < 0.08, "plain {plain} vs boot {boot}");
    }

    #[test]
    fn family_ordering_matches_paper() {
        // Table 5: RandomTrees ≤ ExtraTrees ≤ Standard (within tolerance)
        let rt = acc_of(BaselineKind::RandomTrees, false);
        let et = acc_of(BaselineKind::ExtraTrees, false);
        let st = acc_of(BaselineKind::Standard, false);
        assert!(rt > 0.5, "random trees beat chance: {rt}");
        assert!(st >= et - 0.05, "standard {st} vs extra {et}");
        assert!(et >= rt - 0.05, "extra {et} vs random {rt}");
    }

    #[test]
    fn memory_is_lean() {
        let (train_d, _) = dataset();
        let params = BaselineParams {
            n_trees: 5,
            max_depth: 6,
            ..Default::default()
        };
        let f = BaselineForest::fit(&train_d, &params, 1);
        assert!(f.memory_bytes() > 0);
        assert_eq!(f.n_trees(), 5);
        // per-node cost is tiny: < 40 bytes per node
        let nodes: usize = 5 * 2usize.pow(7); // generous upper bound
        assert!(f.memory_bytes() < nodes * 40 * 4);
    }

    #[test]
    fn parse_kinds() {
        assert_eq!("rf".parse::<BaselineKind>().unwrap(), BaselineKind::Standard);
        assert_eq!(
            "extra_trees".parse::<BaselineKind>().unwrap(),
            BaselineKind::ExtraTrees
        );
        assert!("zzz".parse::<BaselineKind>().is_err());
    }

    #[test]
    fn degenerate_data_yields_leaf() {
        let d = Dataset::from_rows(&[vec![1.0], vec![1.0]], vec![0, 1]);
        for kind in [
            BaselineKind::Standard,
            BaselineKind::ExtraTrees,
            BaselineKind::RandomTrees,
        ] {
            let f = BaselineForest::fit(
                &d,
                &BaselineParams {
                    kind,
                    n_trees: 2,
                    ..Default::default()
                },
                3,
            );
            assert_eq!(f.predict_proba(&[1.0]), 0.5);
        }
    }
}
