//! Deterministic pseudo-random number generation.
//!
//! The image is offline (no `rand` crate), and DaRE's exactness guarantees
//! require *reproducible* randomness: every node in a DaRE tree draws from a
//! stream derived from `(tree_seed, node_path)` so that retraining a subtree
//! from scratch replays the same choices (see DESIGN.md §5).
//!
//! We implement SplitMix64 (for seeding / hashing) and Xoshiro256** (the
//! workhorse generator), both public-domain algorithms by Blackman & Vigna.

/// SplitMix64 step: used to expand a single `u64` seed into a full
/// Xoshiro256** state, and as a cheap avalanche hash for path-derived seeds.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Mix an arbitrary sequence of `u64` words into a single seed word.
/// Used to derive per-node seeds from `(tree_seed, node_path_hash)`.
#[inline]
pub fn mix_seed(words: &[u64]) -> u64 {
    let mut s: u64 = 0x243F_6A88_85A3_08D3; // pi fraction, arbitrary constant
    for &w in words {
        s ^= w;
        s = splitmix64(&mut s);
    }
    s
}

/// Xoshiro256** — fast, high-quality, 256-bit state PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive a child generator from this one plus a stream discriminator.
    /// Streams with different tags are independent for practical purposes.
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(mix_seed(&[self.next_u64(), tag]))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` using Lemire's nearly-divisionless method.
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0, "next_below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn index(&mut self, bound: usize) -> usize {
        self.next_below(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f32 in `[lo, hi)`. Returns `lo` when the range is degenerate.
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        if !(hi > lo) {
            return lo;
        }
        let v = lo + (hi - lo) * self.f32();
        // Guard against rounding up to `hi` exactly.
        if v >= hi {
            lo
        } else {
            v
        }
    }

    /// Standard normal via Box–Muller (polar form avoided for determinism).
    pub fn normal(&mut self) -> f64 {
        // u in (0,1] to avoid ln(0)
        let u = 1.0 - self.f64();
        let v = self.f64();
        (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos()
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        let n = xs.len();
        if n < 2 {
            return;
        }
        for i in (1..n).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `m` distinct indices from `[0, n)` uniformly at random,
    /// in random order. When `m >= n`, returns a permutation of `0..n`.
    pub fn sample_indices(&mut self, n: usize, m: usize) -> Vec<usize> {
        let m = m.min(n);
        if m == 0 {
            return Vec::new();
        }
        // Partial Fisher-Yates over an index array; O(n) alloc but simple and
        // exact. For n large and m tiny, use rejection via a small set.
        if m * 8 < n {
            let mut chosen = Vec::with_capacity(m);
            'outer: while chosen.len() < m {
                let c = self.index(n);
                for &p in &chosen {
                    if p == c {
                        continue 'outer;
                    }
                }
                chosen.push(c);
            }
            chosen
        } else {
            let mut idx: Vec<usize> = (0..n).collect();
            for i in 0..m {
                let j = i + self.index(n - i);
                idx.swap(i, j);
            }
            idx.truncate(m);
            idx
        }
    }

    /// Reservoir-sample `m` items from an iterator of unknown length.
    pub fn reservoir<T, I: Iterator<Item = T>>(&mut self, iter: I, m: usize) -> Vec<T> {
        let mut out: Vec<T> = Vec::with_capacity(m);
        if m == 0 {
            return out;
        }
        for (i, item) in iter.enumerate() {
            if i < m {
                out.push(item);
            } else {
                let j = self.index(i + 1);
                if j < m {
                    out[j] = item;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn next_below_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn range_f32_degenerate() {
        let mut r = Rng::new(9);
        assert_eq!(r.range_f32(2.0, 2.0), 2.0);
        for _ in 0..100 {
            let v = r.range_f32(-1.5, 2.5);
            assert!((-1.5..2.5).contains(&v));
        }
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(11);
        for (n, m) in [(10, 3), (100, 99), (5, 5), (1000, 4), (4, 9)] {
            let s = r.sample_indices(n, m);
            assert_eq!(s.len(), m.min(n));
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), s.len(), "indices must be distinct");
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(17);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn reservoir_sizes() {
        let mut r = Rng::new(19);
        assert_eq!(r.reservoir(0..100u32, 10).len(), 10);
        assert_eq!(r.reservoir(0..5u32, 10).len(), 5);
        assert!(r.reservoir(0..100u32, 0).is_empty());
    }

    #[test]
    fn mix_seed_order_sensitive() {
        assert_ne!(mix_seed(&[1, 2]), mix_seed(&[2, 1]));
        assert_eq!(mix_seed(&[1, 2, 3]), mix_seed(&[1, 2, 3]));
    }
}
