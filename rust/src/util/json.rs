//! Minimal JSON substrate (no serde in the offline image).
//!
//! Provides a `Value` tree, a strict parser, and a compact writer. Used by
//! the coordinator's wire protocol (JSON-lines over TCP), the experiment
//! harness (results/*.json), and forest serialization.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are stored as f64 (sufficient for this codebase:
/// counts fit exactly up to 2^53).
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn obj() -> Value {
        Value::Obj(BTreeMap::new())
    }
    pub fn set(&mut self, key: &str, v: impl Into<Value>) -> &mut Self {
        if let Value::Obj(m) = self {
            m.insert(key.to_string(), v.into());
        } else {
            panic!("set() on non-object");
        }
        self
    }
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn get_mut(&mut self, key: &str) -> Option<&mut Value> {
        match self {
            Value::Obj(m) => m.get_mut(key),
            _ => None,
        }
    }
    /// Remove a key from an object; `None` on non-objects / missing keys.
    pub fn remove(&mut self, key: &str) -> Option<Value> {
        match self {
            Value::Obj(m) => m.remove(key),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Serialize compactly (no whitespace).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => write_num(*n, out),
            Value::Str(s) => write_str(s, out),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Value::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push(']');
            }
            Value::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    write_str(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push('}');
            }
            _ => self.write(out),
        }
    }
}

fn write_num(n: f64, out: &mut String) {
    if !n.is_finite() {
        // JSON has no NaN/Inf; encode as null per common practice.
        out.push_str("null");
    } else if n == n.trunc() && n.abs() < 9e15 {
        fmt::Write::write_fmt(out, format_args!("{}", n as i64)).unwrap();
    } else {
        fmt::Write::write_fmt(out, format_args!("{}", n)).unwrap();
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32)).unwrap()
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Value {
    fn from(b: bool) -> Value {
        Value::Bool(b)
    }
}
impl From<f64> for Value {
    fn from(n: f64) -> Value {
        Value::Num(n)
    }
}
impl From<f32> for Value {
    fn from(n: f32) -> Value {
        Value::Num(n as f64)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Value {
        Value::Num(n as f64)
    }
}
impl From<u64> for Value {
    fn from(n: u64) -> Value {
        Value::Num(n as f64)
    }
}
impl From<u32> for Value {
    fn from(n: u32) -> Value {
        Value::Num(n as f64)
    }
}
impl From<i64> for Value {
    fn from(n: i64) -> Value {
        Value::Num(n as f64)
    }
}
impl From<i32> for Value {
    fn from(n: i32) -> Value {
        Value::Num(n as f64)
    }
}
impl From<&str> for Value {
    fn from(s: &str) -> Value {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Value {
        Value::Str(s)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Value {
        Value::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for ParseError {}

/// Parse a complete JSON document; trailing whitespace allowed, trailing
/// garbage rejected.
pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser {
        b: input.as_bytes(),
        i: 0,
    };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.i,
            msg: msg.to_string(),
        }
    }
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }
    fn lit(&mut self, s: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Value::Null),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.eat(b'[')?;
        self.ws();
        let mut out = Vec::new();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                    self.ws();
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.eat(b'{')?;
        self.ws();
        let mut out = BTreeMap::new();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(key, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                    self.ws();
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // No surrogate-pair support needed for our wire
                            // format; map lone surrogates to replacement char.
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1", "3.5", "1e3"] {
            let v = parse(s).unwrap();
            let v2 = parse(&v.to_string()).unwrap();
            assert_eq!(v, v2);
        }
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a":[1,2,{"b":"x\ny","c":null}],"d":true,"e":-0.25}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&v.to_string()).unwrap(), v);
        assert_eq!(v.get("d").unwrap().as_bool(), Some(true));
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x\ny")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("nulll").is_err());
    }

    #[test]
    fn integers_written_without_fraction() {
        assert_eq!(Value::Num(3.0).to_string(), "3");
        assert_eq!(Value::Num(3.25).to_string(), "3.25");
        assert_eq!(Value::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn object_builder() {
        let mut o = Value::obj();
        o.set("x", 1u64).set("y", "hi").set("z", vec![1u64, 2]);
        let s = o.to_string();
        let back = parse(&s).unwrap();
        assert_eq!(back.get("y").unwrap().as_str(), Some("hi"));
        assert_eq!(back.get("z").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn pretty_parses_back() {
        let src = r#"{"a":[1,2],"b":{"c":1}}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&v.to_pretty()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            parse(r#""Aé""#).unwrap().as_str(),
            Some("Aé")
        );
    }
}
