//! Tiny CLI argument parser (no clap in the offline image).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional arguments.
//! Typed getters parse on demand and report readable errors.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

/// Keys that take a value; everything else starting with `--` is a flag.
pub fn parse<I: IntoIterator<Item = String>>(args: I, value_keys: &[&str]) -> Args {
    let mut out = Args::default();
    let mut it = args.into_iter().peekable();
    while let Some(a) = it.next() {
        if let Some(body) = a.strip_prefix("--") {
            if let Some((k, v)) = body.split_once('=') {
                out.options.insert(k.to_string(), v.to_string());
            } else if value_keys.contains(&body) {
                match it.next() {
                    Some(v) => {
                        out.options.insert(body.to_string(), v);
                    }
                    None => {
                        out.flags.push(body.to_string());
                    }
                }
            } else {
                out.flags.push(body.to_string());
            }
        } else {
            out.positional.push(a);
        }
    }
    out
}

impl Args {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }
    pub fn usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|s| {
                s.parse::<usize>()
                    .unwrap_or_else(|_| panic!("--{name} expects an integer, got '{s}'"))
            })
            .unwrap_or(default)
    }
    pub fn u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|s| {
                s.parse::<u64>()
                    .unwrap_or_else(|_| panic!("--{name} expects an integer, got '{s}'"))
            })
            .unwrap_or(default)
    }
    pub fn f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|s| {
                s.parse::<f64>()
                    .unwrap_or_else(|_| panic!("--{name} expects a number, got '{s}'"))
            })
            .unwrap_or(default)
    }
    /// Comma-separated list of usize, e.g. `--ks 1,5,10`.
    pub fn usize_list(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            None => default.to_vec(),
            Some(s) => s
                .split(',')
                .filter(|t| !t.is_empty())
                .map(|t| {
                    t.trim()
                        .parse::<usize>()
                        .unwrap_or_else(|_| panic!("--{name}: bad integer '{t}'"))
                })
                .collect(),
        }
    }
    /// Comma-separated list of f64.
    pub fn f64_list(&self, name: &str, default: &[f64]) -> Vec<f64> {
        match self.get(name) {
            None => default.to_vec(),
            Some(s) => s
                .split(',')
                .filter(|t| !t.is_empty())
                .map(|t| {
                    t.trim()
                        .parse::<f64>()
                        .unwrap_or_else(|_| panic!("--{name}: bad number '{t}'"))
                })
                .collect(),
        }
    }
    /// Comma-separated list of strings.
    pub fn str_list(&self, name: &str) -> Option<Vec<String>> {
        self.get(name)
            .map(|s| s.split(',').map(|t| t.trim().to_string()).collect())
    }
    /// A duration given in whole milliseconds, e.g. `--poll-ms 250`.
    pub fn duration_ms(&self, name: &str, default: std::time::Duration) -> std::time::Duration {
        self.get(name)
            .map(|s| {
                std::time::Duration::from_millis(s.parse::<u64>().unwrap_or_else(|_| {
                    panic!("--{name} expects milliseconds as an integer, got '{s}'")
                }))
            })
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = parse(
            args(&["train", "--trees", "100", "--depth=20", "--verbose", "surgical"]),
            &["trees", "depth"],
        );
        assert_eq!(a.positional, vec!["train", "surgical"]);
        assert_eq!(a.usize("trees", 0), 100);
        assert_eq!(a.usize("depth", 0), 20);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(args(&[]), &[]);
        assert_eq!(a.usize("k", 25), 25);
        assert_eq!(a.f64("tol", 0.5), 0.5);
        assert_eq!(a.get_or("name", "x"), "x");
    }

    #[test]
    fn lists() {
        let a = parse(args(&["--ks=1,5,10", "--tols", "0.1,0.25"]), &["tols"]);
        assert_eq!(a.usize_list("ks", &[]), vec![1, 5, 10]);
        assert_eq!(a.f64_list("tols", &[]), vec![0.1, 0.25]);
        assert_eq!(a.usize_list("missing", &[7]), vec![7]);
    }

    #[test]
    #[should_panic(expected = "expects an integer")]
    fn bad_integer_panics() {
        let a = parse(args(&["--trees", "abc"]), &["trees"]);
        a.usize("trees", 0);
    }

    #[test]
    fn durations_in_milliseconds() {
        use std::time::Duration;
        let a = parse(args(&["--poll-ms", "250"]), &["poll-ms"]);
        assert_eq!(a.duration_ms("poll-ms", Duration::from_secs(9)), Duration::from_millis(250));
        assert_eq!(a.duration_ms("io-ms", Duration::from_secs(9)), Duration::from_secs(9));
    }

    #[test]
    #[should_panic(expected = "expects milliseconds")]
    fn bad_duration_panics() {
        let a = parse(args(&["--poll-ms", "fast"]), &["poll-ms"]);
        a.duration_ms("poll-ms", std::time::Duration::ZERO);
    }
}
