//! Plain-text table rendering for the experiment harness — the `reproduce`
//! subcommands print the same rows the paper's tables report.

/// A simple column-aligned text table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncols = self
            .header
            .len()
            .max(self.rows.iter().map(|r| r.len()).max().unwrap_or(0));
        let mut widths = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.chars().count());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&self.title);
            out.push('\n');
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, w) in widths.iter().enumerate() {
                let empty = String::new();
                let c = cells.get(i).unwrap_or(&empty);
                line.push_str(c);
                for _ in c.chars().count()..*w {
                    line.push(' ');
                }
                if i + 1 < widths.len() {
                    line.push_str("  ");
                }
            }
            while line.ends_with(' ') {
                line.pop();
            }
            line
        };
        if !self.header.is_empty() {
            out.push_str(&fmt_row(&self.header, &widths));
            out.push('\n');
            let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
            out.push_str(&"-".repeat(total));
            out.push('\n');
        }
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Format a float with `d` decimals.
pub fn f(v: f64, d: usize) -> String {
    format!("{:.*}", d, v)
}

/// Format "mean (stderr)" in the paper's Table-3 style.
pub fn mean_se(mean: f64, se: f64, d: usize) -> String {
    format!("{:.*} ({:.*})", d, mean, d, se)
}

/// Format a speedup multiplier like the paper's "257x".
pub fn speedup(v: f64) -> String {
    if v >= 100.0 {
        format!("{:.0}x", v)
    } else if v >= 10.0 {
        format!("{:.1}x", v)
    } else {
        format!("{:.2}x", v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["a", "bbb"]);
        t.row(vec!["x".into(), "1".into()]);
        t.row(vec!["yyyy".into(), "22".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[0], "T");
        assert!(lines[1].starts_with("a"));
        assert!(lines[3].starts_with("x"));
        // columns aligned: 'bbb' column starts at same offset in all rows
        let col = lines[1].find("bbb").unwrap();
        assert_eq!(&lines[3][col..col + 1], "1");
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(f(1.23456, 3), "1.235");
        assert_eq!(mean_se(0.5, 0.01, 2), "0.50 (0.01)");
        assert_eq!(speedup(257.3), "257x");
        assert_eq!(speedup(52.6), "52.6x");
        assert_eq!(speedup(5.25), "5.25x");
    }

    #[test]
    fn ragged_rows_ok() {
        let mut t = Table::new("", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains('2'));
    }
}
