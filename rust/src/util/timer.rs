//! Wall-clock timing helpers shared by the eval + bench harnesses.

use std::time::{Duration, Instant};

/// Time a closure, returning (result, elapsed seconds).
pub fn time<R, F: FnOnce() -> R>(f: F) -> (R, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

/// A stopwatch that can accumulate across multiple start/stop intervals.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    acc: Duration,
    started: Option<Instant>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Stopwatch {
            acc: Duration::ZERO,
            started: None,
        }
    }
    pub fn start(&mut self) {
        if self.started.is_none() {
            self.started = Some(Instant::now());
        }
    }
    pub fn stop(&mut self) {
        if let Some(t0) = self.started.take() {
            self.acc += t0.elapsed();
        }
    }
    pub fn seconds(&self) -> f64 {
        let mut d = self.acc;
        if let Some(t0) = self.started {
            d += t0.elapsed();
        }
        d.as_secs_f64()
    }
    pub fn reset(&mut self) {
        self.acc = Duration::ZERO;
        self.started = None;
    }
}

/// Format seconds human-readably (µs/ms/s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else if s < 120.0 {
        format!("{:.2}s", s)
    } else {
        format!("{:.1}min", s / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_returns_result() {
        let (v, secs) = time(|| 40 + 2);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        sw.start();
        std::thread::sleep(Duration::from_millis(5));
        sw.stop();
        let a = sw.seconds();
        assert!(a >= 0.004, "a={a}");
        sw.start();
        std::thread::sleep(Duration::from_millis(5));
        sw.stop();
        assert!(sw.seconds() > a);
        sw.reset();
        assert_eq!(sw.seconds(), 0.0);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(5e-6).ends_with("µs"));
        assert!(fmt_secs(5e-3).ends_with("ms"));
        assert!(fmt_secs(5.0).ends_with('s'));
        assert!(fmt_secs(600.0).ends_with("min"));
    }
}
