//! Substrate utilities built from scratch for the offline environment:
//! RNG, JSON, CLI parsing, thread pool, statistics, latency histograms,
//! property testing, timing, and text-table rendering for the experiment
//! harness.

pub mod cli;
pub mod fsio;
pub mod hash;
pub mod histogram;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
pub mod threadpool;
pub mod timer;
