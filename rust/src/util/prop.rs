//! Property-based testing mini-framework (no proptest in the offline image).
//!
//! A property is a closure over a seeded [`crate::util::rng::Rng`]; the runner
//! executes it across many cases and, on failure, reports the failing seed so
//! the case can be replayed deterministically. Generators are free functions
//! over the Rng — composition happens in plain Rust.
//!
//! Shrinking: numeric sizes are retried at smaller magnitudes (halving) before
//! reporting, which in practice pinpoints minimal dataset sizes for forest
//! invariant failures.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub base_seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cases: 64,
            base_seed: 0xDA2E_2021,
        }
    }
}

/// Run `prop` across `cfg.cases` deterministic seeds. The property receives a
/// fresh Rng per case; it should panic (e.g. via assert!) on failure.
pub fn check<F: Fn(&mut Rng)>(name: &str, cfg: Config, prop: F) {
    for case in 0..cfg.cases {
        let seed = crate::util::rng::mix_seed(&[cfg.base_seed, case as u64]);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(e) = result {
            let msg = panic_message(&e);
            panic!(
                "property '{name}' failed at case {case}/{} (seed={seed:#x}): {msg}",
                cfg.cases
            );
        }
    }
}

/// Run a property parameterized by a "size" drawn from `[1, max_size]`.
/// On failure, tries to find a smaller failing size (simple halving shrink)
/// and reports the smallest found.
pub fn check_sized<F: Fn(&mut Rng, usize)>(name: &str, cfg: Config, max_size: usize, prop: F) {
    for case in 0..cfg.cases {
        let seed = crate::util::rng::mix_seed(&[cfg.base_seed, case as u64, 0x517E]);
        let mut rng = Rng::new(seed);
        let size = 1 + rng.index(max_size.max(1));
        let run = |sz: usize| {
            let mut r = Rng::new(seed);
            let _ = r.index(max_size.max(1)); // keep stream aligned
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut r, sz)))
        };
        if let Err(first) = run(size) {
            // Shrink: halve until it passes, keep the smallest failure.
            let mut lo_fail = size;
            let mut msg = panic_message(&first);
            let mut sz = size / 2;
            while sz >= 1 {
                match run(sz) {
                    Err(e) => {
                        lo_fail = sz;
                        msg = panic_message(&e);
                        sz /= 2;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property '{name}' failed at case {case} (seed={seed:#x}, size={lo_fail}, original size={size}): {msg}"
            );
        }
    }
}

fn panic_message(e: &Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        s.to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic>".to_string()
    }
}

// ---------------------------------------------------------------------------
// Common generators
// ---------------------------------------------------------------------------

/// Vector of f32 features in [-scale, scale], with a proportion of repeated
/// values (ties are the interesting edge case for threshold validity).
pub fn gen_feature_column(rng: &mut Rng, n: usize, tie_prob: f64, scale: f32) -> Vec<f32> {
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        if i > 0 && rng.bernoulli(tie_prob) {
            // duplicate a previous value to create ties
            let j = rng.index(i);
            out.push(out[j]);
        } else {
            out.push(rng.range_f32(-scale, scale));
        }
    }
    out
}

/// Binary labels with given positive rate; guarantees at least one of each
/// class when n >= 2 (so trees are non-trivial).
pub fn gen_labels(rng: &mut Rng, n: usize, pos_rate: f64) -> Vec<u8> {
    let mut y: Vec<u8> = (0..n).map(|_| rng.bernoulli(pos_rate) as u8).collect();
    if n >= 2 {
        if y.iter().all(|&v| v == 0) {
            let i = rng.index(n);
            y[i] = 1;
        }
        if y.iter().all(|&v| v == 1) {
            let i = rng.index(n);
            y[i] = 0;
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add commutes", Config::default(), |rng| {
            let a = rng.index(1000) as i64;
            let b = rng.index(1000) as i64;
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            check(
                "always fails",
                Config {
                    cases: 3,
                    base_seed: 1,
                },
                |_rng| {
                    panic!("intentional");
                },
            );
        });
        let msg = match r {
            Err(e) => panic_message(&e),
            Ok(()) => panic!("property should have failed"),
        };
        assert!(msg.contains("seed="), "message should include seed: {msg}");
        assert!(msg.contains("intentional"));
    }

    #[test]
    fn sized_shrinks_down() {
        let r = std::panic::catch_unwind(|| {
            check_sized(
                "fails for size>=2",
                Config {
                    cases: 5,
                    base_seed: 2,
                },
                100,
                |_rng, size| {
                    assert!(size < 2, "too big");
                },
            );
        });
        let msg = match r {
            Err(e) => panic_message(&e),
            Ok(()) => return, // all sampled sizes were 1 — acceptable
        };
        // shrinker should land on exactly size=2 or 3 (halving)
        assert!(msg.contains("size="), "{msg}");
    }

    #[test]
    fn generators_sane() {
        let mut rng = Rng::new(5);
        let col = gen_feature_column(&mut rng, 100, 0.5, 10.0);
        assert_eq!(col.len(), 100);
        assert!(col.iter().all(|v| (-10.0..10.0).contains(v)));
        // tie probability 0.5 should produce duplicates
        let mut sorted = col.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        sorted.dedup();
        assert!(sorted.len() < 100);

        let y = gen_labels(&mut rng, 50, 0.2);
        assert!(y.iter().any(|&v| v == 1));
        assert!(y.iter().any(|&v| v == 0));
    }
}
