//! Self-contained hashing primitives for the durability layer: CRC32
//! (IEEE, reflected — the `cksum`/zlib polynomial) for write-ahead-log
//! record framing, SHA-256 for snapshot content hashes, and HMAC-SHA256
//! for signing deletion certificates. The container image is offline, so
//! these are hand-rolled rather than pulled from crates; each carries its
//! standard known-answer vectors in the tests below.

/// CRC32 (IEEE 802.3, reflected, init 0xFFFFFFFF, final xor 0xFFFFFFFF).
/// `crc32(b"123456789") == 0xCBF4_3926`.
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc: u32 = 0xFFFF_FFFF;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

const SHA256_K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4, 0xab1c5ed5,
    0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174,
    0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967,
    0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85,
    0xa2bfe8a1, 0xa81a664b, 0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

/// SHA-256 digest of `data`.
pub fn sha256(data: &[u8]) -> [u8; 32] {
    let mut h: [u32; 8] = [
        0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c, 0x1f83d9ab,
        0x5be0cd19,
    ];
    // Pad: message || 0x80 || zeros || u64 BE bit length, to a 64-byte multiple.
    let mut msg = data.to_vec();
    let bit_len = (data.len() as u64).wrapping_mul(8);
    msg.push(0x80);
    while msg.len() % 64 != 56 {
        msg.push(0);
    }
    msg.extend_from_slice(&bit_len.to_be_bytes());

    let mut w = [0u32; 64];
    for block in msg.chunks_exact(64) {
        for (i, word) in w.iter_mut().take(16).enumerate() {
            *word = u32::from_be_bytes([
                block[4 * i],
                block[4 * i + 1],
                block[4 * i + 2],
                block[4 * i + 3],
            ]);
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut hh] = h;
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ ((!e) & g);
            let t1 = hh
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(SHA256_K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            hh = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        h[0] = h[0].wrapping_add(a);
        h[1] = h[1].wrapping_add(b);
        h[2] = h[2].wrapping_add(c);
        h[3] = h[3].wrapping_add(d);
        h[4] = h[4].wrapping_add(e);
        h[5] = h[5].wrapping_add(f);
        h[6] = h[6].wrapping_add(g);
        h[7] = h[7].wrapping_add(hh);
    }

    let mut out = [0u8; 32];
    for (i, word) in h.iter().enumerate() {
        out[4 * i..4 * i + 4].copy_from_slice(&word.to_be_bytes());
    }
    out
}

/// HMAC-SHA256 per RFC 2104: `H((k ^ opad) || H((k ^ ipad) || msg))`.
pub fn hmac_sha256(key: &[u8], msg: &[u8]) -> [u8; 32] {
    const BLOCK: usize = 64;
    let mut k = [0u8; BLOCK];
    if key.len() > BLOCK {
        k[..32].copy_from_slice(&sha256(key));
    } else {
        k[..key.len()].copy_from_slice(key);
    }
    let mut inner = Vec::with_capacity(BLOCK + msg.len());
    inner.extend(k.iter().map(|b| b ^ 0x36));
    inner.extend_from_slice(msg);
    let inner_hash = sha256(&inner);
    let mut outer = Vec::with_capacity(BLOCK + 32);
    outer.extend(k.iter().map(|b| b ^ 0x5c));
    outer.extend_from_slice(&inner_hash);
    sha256(&outer)
}

/// Lowercase hex encoding of a byte slice.
pub fn to_hex(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push(char::from_digit((b >> 4) as u32, 16).unwrap());
        s.push(char::from_digit((b & 0xf) as u32, 16).unwrap());
    }
    s
}

/// Constant-time byte equality — certificate HMAC checks must not leak a
/// match-prefix timing signal.
pub fn ct_eq(a: &[u8], b: &[u8]) -> bool {
    if a.len() != b.len() {
        return false;
    }
    let mut diff = 0u8;
    for (x, y) in a.iter().zip(b) {
        diff |= x ^ y;
    }
    diff == 0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_answers() {
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn sha256_known_answers() {
        assert_eq!(
            to_hex(&sha256(b"")),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            to_hex(&sha256(b"abc")),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        // Two-block message (56 bytes forces the length into a second block).
        assert_eq!(
            to_hex(&sha256(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq")),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
    }

    #[test]
    fn hmac_sha256_rfc4231_vectors() {
        // RFC 4231 test case 1.
        assert_eq!(
            to_hex(&hmac_sha256(&[0x0b; 20], b"Hi There")),
            "b0344c61d8db38535ca8afceaf0bf12b881dc200c9833da726e9376c2e32cff7"
        );
        // RFC 4231 test case 2 ("Jefe").
        assert_eq!(
            to_hex(&hmac_sha256(b"Jefe", b"what do ya want for nothing?")),
            "5bdcc146bf60754e6a042426089575c75a003f089d2739839dec58b964ec3843"
        );
        // RFC 4231 test case 6 (key longer than one block).
        assert_eq!(
            to_hex(&hmac_sha256(
                &[0xaa; 131],
                b"Test Using Larger Than Block-Size Key - Hash Key First"
            )),
            "60e431591ee0b67f0d8a26aacbf5b77f8e0bc6213728c5140546040f0ee37f54"
        );
    }

    #[test]
    fn hex_and_ct_eq() {
        assert_eq!(to_hex(&[0x00, 0xff, 0x1a]), "00ff1a");
        assert!(ct_eq(b"same", b"same"));
        assert!(!ct_eq(b"same", b"sama"));
        assert!(!ct_eq(b"short", b"longer"));
    }
}
