//! Crash-safe filesystem helpers shared by the forest snapshot writer and
//! the coordinator write-ahead log.
//!
//! The invariant all callers rely on: after `atomic_write(path, bytes)`
//! returns, either the old contents of `path` or the new `bytes` survive a
//! crash at any instant — never a prefix, never an empty file. That takes
//! three steps: write + fsync a temp file in the same directory, rename it
//! over the target (atomic within a filesystem), then fsync the parent
//! directory so the rename itself is durable.

use std::fs::{File, OpenOptions};
use std::io::{self, Write};
use std::path::Path;

/// fsync a directory so a rename/create/unlink inside it is durable.
/// On platforms where opening a directory for read fails (non-POSIX),
/// degrade to a no-op rather than an error.
pub fn fsync_dir(dir: &Path) -> io::Result<()> {
    match File::open(dir) {
        Ok(d) => d.sync_all(),
        Err(_) => Ok(()),
    }
}

/// Atomically replace `path` with `bytes` (temp file + fsync + rename +
/// parent-dir fsync). The temp file lives next to the target (same
/// filesystem, so the rename is atomic) and is named `.<file>.tmp`;
/// recovery scans ignore such names.
pub fn atomic_write(path: &Path, bytes: &[u8]) -> io::Result<()> {
    let dir = path.parent().filter(|p| !p.as_os_str().is_empty());
    let file_name = path
        .file_name()
        .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidInput, "atomic_write: no file name"))?;
    let tmp = path.with_file_name(format!(".{}.tmp", file_name.to_string_lossy()));
    {
        let mut f = OpenOptions::new()
            .write(true)
            .create(true)
            .truncate(true)
            .open(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    if let Err(e) = std::fs::rename(&tmp, path) {
        let _ = std::fs::remove_file(&tmp);
        return Err(e);
    }
    if let Some(dir) = dir {
        fsync_dir(dir)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("dare-fsio-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn atomic_write_creates_and_replaces() {
        let dir = temp_dir("replace");
        let path = dir.join("snap.json");
        atomic_write(&path, b"first").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"first");
        atomic_write(&path, b"second, longer contents").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"second, longer contents");
        // No temp droppings left behind.
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .filter(|n| n.contains(".tmp"))
            .collect();
        assert!(leftovers.is_empty(), "temp files left: {leftovers:?}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn atomic_write_rejects_bare_root() {
        let err = atomic_write(Path::new("/"), b"x");
        assert!(err.is_err());
    }
}
