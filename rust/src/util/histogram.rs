//! Fixed-bucket log-spaced latency histogram (no hdrhistogram in the
//! offline image).
//!
//! The bucket layout is a compile-time constant shared by every histogram:
//! [`BUCKETS_PER_DECADE`] log-spaced buckets per decade across
//! [`LO_SECONDS`, `HI_SECONDS`) (100 ns … 100 s), plus an underflow bucket
//! (index 0, everything `< LO_SECONDS` including zero and non-finite
//! garbage) and an overflow bucket (the last index, everything
//! `>= HI_SECONDS`). A fixed layout is what makes [`Histogram::merge`]
//! exact and associative: merging is element-wise counter addition, so
//! `(a ⊕ b) ⊕ c == a ⊕ (b ⊕ c)` bucket-for-bucket and scenario drivers can
//! aggregate per-tenant histograms into per-op rollups without losing
//! anything but the sub-bucket ordering they never had.
//!
//! Quantiles are bucket-resolution upper bounds (the conservative side for
//! latency reporting), clamped into the exactly-tracked `[min, max]` range —
//! so a single-sample histogram reports every quantile as exactly that
//! sample, and `quantile` is monotone in q by construction (cumulative scan
//! + monotone clamp). `count`, `sum`, `min`, `max` are tracked exactly.

use crate::util::json::Value;

/// Lower bound of the finest bucket: 100 ns.
pub const LO_SECONDS: f64 = 1e-7;
/// Upper bound of the coarsest non-overflow bucket: 100 s.
pub const HI_SECONDS: f64 = 1e2;
/// Log-spaced buckets per decade.
pub const BUCKETS_PER_DECADE: usize = 8;
/// Decades spanned by the regular buckets (1e-7 … 1e2).
pub const DECADES: usize = 9;
/// Total buckets: underflow + regular + overflow.
pub const N_BUCKETS: usize = DECADES * BUCKETS_PER_DECADE + 2;

/// Mergeable latency histogram over the fixed global bucket layout.
#[derive(Clone, Debug)]
pub struct Histogram {
    counts: [u64; N_BUCKETS],
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

/// Upper bound (seconds) of bucket `i` in the regular range; `bound(0)` is
/// `LO_SECONDS` (the underflow bucket's ceiling).
fn bound(i: usize) -> f64 {
    LO_SECONDS * 10f64.powf(i as f64 / BUCKETS_PER_DECADE as f64)
}

/// Bucket index for a sample. Non-positive and non-finite samples (a clock
/// that went backwards, a NaN from a division) land in the underflow bucket
/// rather than poisoning the layout.
fn index(x: f64) -> usize {
    if !(x >= LO_SECONDS) {
        return 0;
    }
    if x >= HI_SECONDS {
        return N_BUCKETS - 1;
    }
    // log10(x / LO) in units of buckets; the guards above keep the result
    // inside the regular range even at the exact boundaries.
    let b = ((x / LO_SECONDS).log10() * BUCKETS_PER_DECADE as f64).floor() as usize;
    (1 + b).min(N_BUCKETS - 2)
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            counts: [0; N_BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Record one sample (seconds).
    pub fn record(&mut self, seconds: f64) {
        self.counts[index(seconds)] += 1;
        self.count += 1;
        let s = if seconds.is_finite() { seconds } else { 0.0 };
        self.sum += s;
        self.min = self.min.min(s);
        self.max = self.max.max(s);
    }

    /// Total samples recorded (merges preserve this exactly).
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact sum of samples (seconds).
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Exact mean, 0.0 when empty.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Exact minimum sample, 0.0 when empty.
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Exact maximum sample, 0.0 when empty.
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Raw bucket counters (layout documented at module level).
    pub fn bucket_counts(&self) -> &[u64; N_BUCKETS] {
        &self.counts
    }

    /// Fold `other` into `self`: element-wise counter addition plus exact
    /// count/sum/min/max combination. Associative and commutative because
    /// every histogram shares the same fixed bucket layout.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        if other.count > 0 {
            self.min = self.min.min(other.min);
            self.max = self.max.max(other.max);
        }
    }

    /// Bucket-resolution quantile for `q ∈ [0, 1]` (seconds): the upper
    /// bound of the bucket containing the `ceil(q·count)`-th smallest
    /// sample, clamped into the exact `[min, max]` range. 0.0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        let mut bucket = N_BUCKETS - 1;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                bucket = i;
                break;
            }
        }
        // Upper bound of the bucket: underflow caps at LO, overflow (and
        // anything past the table) caps at the exact max.
        let ub = if bucket == 0 {
            LO_SECONDS
        } else if bucket >= N_BUCKETS - 1 {
            self.max
        } else {
            bound(bucket)
        };
        ub.clamp(self.min, self.max)
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    /// Approximate second moment: `(count, mean, variance)` where the mean
    /// is exact (from the tracked sum) and the variance is estimated from
    /// bucket geometric-mean midpoints (sample variance, n-1 denominator;
    /// underflow samples sit at `min`, overflow at `max`). This is the
    /// cross-process seeding path for the scheduler's Welford cost
    /// estimators: a histogram shipped in a stats payload carries no raw
    /// samples, so variance is bucket-resolution — good enough for a
    /// mean + safety·std cost predictor, and refined by live observations
    /// as soon as work flows.
    pub fn approx_moments(&self) -> (u64, f64, f64) {
        if self.count < 2 {
            return (self.count, self.mean(), 0.0);
        }
        let mean = self.mean();
        let mut m2 = 0.0;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            // Representative point: geometric mean of the bucket's bounds
            // (the natural center of a log-spaced bucket), clamped into the
            // exactly-tracked sample range.
            let raw = if i == 0 {
                self.min.min(LO_SECONDS)
            } else if i >= N_BUCKETS - 1 {
                self.max
            } else {
                let lo = if i == 1 { LO_SECONDS } else { bound(i - 1) };
                (lo * bound(i)).sqrt()
            };
            let mid = raw.clamp(self.min, self.max);
            let d = mid - mean;
            m2 += c as f64 * d * d;
        }
        (self.count, mean, m2 / (self.count - 1) as f64)
    }

    /// JSON summary — the per-op-type entry shape of `BENCH_scenarios.json`
    /// (pinned by `tests/scenarios.rs::bench_schema_is_pinned`).
    pub fn to_json(&self) -> Value {
        let mut o = Value::obj();
        o.set("count", self.count)
            .set("mean_s", self.mean())
            .set("min_s", self.min())
            .set("max_s", self.max())
            .set("p50_s", self.p50())
            .set("p95_s", self.p95())
            .set("p99_s", self.p99());
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Config};
    use crate::util::rng::Rng;

    fn sample(rng: &mut Rng) -> f64 {
        // Log-uniform across (and beyond) the bucket range, with a sliver
        // of pathological inputs.
        match rng.index(20) {
            0 => 0.0,
            1 => -rng.f64(),
            2 => 1e3 * (1.0 + rng.f64()),
            _ => 10f64.powf(rng.range_f32(-8.0, 2.5) as f64),
        }
    }

    fn hist_of(samples: &[f64]) -> Histogram {
        let mut h = Histogram::new();
        for &s in samples {
            h.record(s);
        }
        h
    }

    fn assert_same(a: &Histogram, b: &Histogram) {
        assert_eq!(a.bucket_counts(), b.bucket_counts());
        assert_eq!(a.count(), b.count());
        assert!((a.sum() - b.sum()).abs() < 1e-12 * (1.0 + a.sum().abs()));
        assert_eq!(a.min(), b.min());
        assert_eq!(a.max(), b.max());
    }

    #[test]
    fn merge_is_associative_and_count_preserving() {
        check("histogram merge associativity", Config::default(), |rng| {
            let mk = |rng: &mut Rng| {
                let n = rng.index(40);
                let xs: Vec<f64> = (0..n).map(|_| sample(rng)).collect();
                hist_of(&xs)
            };
            let (a, b, c) = (mk(rng), mk(rng), mk(rng));

            let mut left = a.clone();
            left.merge(&b);
            left.merge(&c);
            let mut bc = b.clone();
            bc.merge(&c);
            let mut right = a.clone();
            right.merge(&bc);
            assert_same(&left, &right);
            assert_eq!(left.count(), a.count() + b.count() + c.count());
            // Quantiles are a pure function of the merged state.
            for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
                assert_eq!(left.quantile(q), right.quantile(q));
            }
        });
    }

    #[test]
    fn merge_matches_direct_recording() {
        check("merge == record-all", Config::default(), |rng| {
            let n1 = rng.index(30);
            let n2 = rng.index(30);
            let xs: Vec<f64> = (0..n1 + n2).map(|_| sample(rng)).collect();
            let mut merged = hist_of(&xs[..n1]);
            merged.merge(&hist_of(&xs[n1..]));
            assert_same(&merged, &hist_of(&xs));
        });
    }

    #[test]
    fn quantiles_are_monotone_and_bracketed() {
        check("quantile monotonicity", Config::default(), |rng| {
            let n = 1 + rng.index(60);
            let xs: Vec<f64> = (0..n).map(|_| sample(rng)).collect();
            let h = hist_of(&xs);
            let mut prev = f64::NEG_INFINITY;
            for i in 0..=20 {
                let v = h.quantile(i as f64 / 20.0);
                assert!(v >= prev, "quantile must be monotone in q ({v} < {prev})");
                assert!(v >= h.min() && v <= h.max(), "quantile outside [min, max]");
                prev = v;
            }
        });
    }

    #[test]
    fn single_sample_is_exact_at_every_quantile() {
        for x in [3e-7, 1e-4, 0.25, 5.0] {
            let h = hist_of(&[x]);
            for q in [0.0, 0.01, 0.5, 0.99, 1.0] {
                assert_eq!(h.quantile(q), x, "single-sample clamp must be exact");
            }
            assert_eq!(h.max(), x);
            assert_eq!(h.mean(), x);
        }
    }

    #[test]
    fn bucket_boundary_edges() {
        // Exactly LO lands in the first regular bucket, strictly below it
        // underflows; HI and beyond overflow; garbage underflows.
        assert_eq!(index(LO_SECONDS), 1);
        assert!(index(LO_SECONDS * 0.999) == 0);
        assert_eq!(index(0.0), 0);
        assert_eq!(index(-1.0), 0);
        assert_eq!(index(f64::NAN), 0);
        assert_eq!(index(HI_SECONDS), N_BUCKETS - 1);
        assert_eq!(index(f64::INFINITY), N_BUCKETS - 1);
        // Monotone: bucket index never decreases as the sample grows.
        let mut prev = 0;
        let mut x = LO_SECONDS / 4.0;
        while x < HI_SECONDS * 4.0 {
            let i = index(x);
            assert!(i >= prev, "index must be monotone in the sample");
            prev = i;
            x *= 1.07;
        }
        // Every regular boundary maps inside the regular range.
        for i in 1..=DECADES * BUCKETS_PER_DECADE {
            let b = index(bound(i - 1));
            assert!(b >= 1 && b <= N_BUCKETS - 2, "bound {i} escaped: {b}");
        }
    }

    #[test]
    fn approx_moments_track_true_moments_at_bucket_resolution() {
        // Tight cluster: approx variance must be small relative to a spread
        // sample, and the mean is exact regardless of bucketing.
        let tight = hist_of(&[1.0e-3, 1.05e-3, 1.1e-3, 0.95e-3]);
        let (n, mean, var) = tight.approx_moments();
        assert_eq!(n, 4);
        assert!((mean - tight.mean()).abs() < 1e-15, "mean is exact");
        let spread = hist_of(&[1e-5, 1e-3, 1e-1, 10.0]);
        let (_, _, var_spread) = spread.approx_moments();
        assert!(
            var_spread > var,
            "spread sample must show more estimated variance ({var_spread} vs {var})"
        );
        // The estimate is bucket-resolution, not garbage: std within ~one
        // bucket width of the true std for an in-range sample.
        let xs = [2e-3, 4e-3, 8e-3, 1.6e-2, 3.2e-2];
        let h = hist_of(&xs);
        let (_, _, v) = h.approx_moments();
        let true_std = crate::util::stats::std_dev(&xs);
        assert!(v.sqrt() > 0.3 * true_std && v.sqrt() < 3.0 * true_std);
        // Degenerate cases report zero variance.
        assert_eq!(Histogram::new().approx_moments(), (0, 0.0, 0.0));
        assert_eq!(hist_of(&[0.5]).approx_moments(), (1, 0.5, 0.0));
    }

    #[test]
    fn empty_histogram_reports_zeros() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
        assert_eq!(h.quantile(0.5), 0.0);
        let j = h.to_json();
        assert_eq!(j.get("count").unwrap().as_u64(), Some(0));
    }

    #[test]
    fn json_summary_has_the_pinned_keys() {
        let h = hist_of(&[1e-4, 2e-4, 3e-4, 1e-2]);
        let j = h.to_json();
        for key in ["count", "mean_s", "min_s", "max_s", "p50_s", "p95_s", "p99_s"] {
            assert!(j.get(key).is_some(), "missing histogram key {key}");
        }
        assert_eq!(j.get("count").unwrap().as_u64(), Some(4));
        let p99 = j.get("p99_s").unwrap().as_f64().unwrap();
        let p50 = j.get("p50_s").unwrap().as_f64().unwrap();
        assert!(p99 >= p50);
    }
}
