//! Fixed-size worker thread pool (no tokio in the offline image).
//!
//! Two front-ends:
//! - [`ThreadPool::execute`] — fire-and-forget jobs with a [`ThreadPool::join`]
//!   barrier, used by the coordinator for request handling.
//! - [`scope_map`] — structured fork/join over a slice, used to parallelize
//!   per-tree work (training, deletion, dry-run costing) in the forest.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// Shared state tracking in-flight jobs so `join` can block until quiescent.
struct Inflight {
    count: Mutex<usize>,
    cv: Condvar,
}

pub struct ThreadPool {
    tx: mpsc::Sender<Msg>,
    rx_holder: Arc<Mutex<mpsc::Receiver<Msg>>>,
    workers: Vec<thread::JoinHandle<()>>,
    inflight: Arc<Inflight>,
    panics: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Create a pool with `n` workers (minimum 1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let inflight = Arc::new(Inflight {
            count: Mutex::new(0),
            cv: Condvar::new(),
        });
        let panics = Arc::new(AtomicUsize::new(0));
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let rx = Arc::clone(&rx);
            let inflight = Arc::clone(&inflight);
            let panics = Arc::clone(&panics);
            workers.push(
                thread::Builder::new()
                    .name(format!("dare-worker-{i}"))
                    .spawn(move || loop {
                        let msg = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match msg {
                            Ok(Msg::Run(job)) => {
                                if catch_unwind(AssertUnwindSafe(job)).is_err() {
                                    panics.fetch_add(1, Ordering::SeqCst);
                                }
                                let mut c = inflight.count.lock().unwrap();
                                *c -= 1;
                                if *c == 0 {
                                    inflight.cv.notify_all();
                                }
                            }
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool {
            tx,
            rx_holder: rx,
            workers,
            inflight,
            panics,
        }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        {
            let mut c = self.inflight.count.lock().unwrap();
            *c += 1;
        }
        self.tx.send(Msg::Run(Box::new(f))).expect("pool send");
    }

    /// Block until all submitted jobs have completed.
    pub fn join(&self) {
        let mut c = self.inflight.count.lock().unwrap();
        while *c != 0 {
            c = self.inflight.cv.wait(c).unwrap();
        }
    }

    /// Number of jobs that panicked since pool creation.
    pub fn panic_count(&self) -> usize {
        self.panics.load(Ordering::SeqCst)
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.join();
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Shutdown);
        }
        // Wake any worker blocked on recv via channel disconnect semantics is
        // handled by Shutdown messages; drain handles.
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        let _ = &self.rx_holder; // keep receiver alive until workers exit
    }
}

/// Structured fork/join: apply `f` to every element of `items` using up to
/// `threads` OS threads, preserving output order. Panics in `f` propagate.
///
/// This is the substrate for per-tree parallelism in the forest: trees are
/// independent, so training/deletion parallelizes embarrassingly.
pub fn scope_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let slots = Mutex::new(&mut out);
    // SAFETY: std::thread::scope guarantees all threads finish before refs die.
    thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i, &items[i]);
                let mut guard = slots.lock().unwrap();
                guard[i] = Some(r);
            });
        }
    });
    out.into_iter().map(|o| o.expect("scope_map slot")).collect()
}

/// Structured fork/join over a mutable slice: apply `f` to every element in
/// parallel, preserving output order. Each element is visited by exactly one
/// thread (disjoint &mut access via an atomic work index).
pub fn scope_map_mut<T, R, F>(items: &mut [T], threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, &mut T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        return items.iter_mut().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    struct Ptr<T>(*mut T);
    // SAFETY: each index is claimed exactly once via fetch_add, so threads
    // never alias an element; the scope outlives all accesses.
    unsafe impl<T> Sync for Ptr<T> {}
    impl<T> Ptr<T> {
        /// SAFETY: caller guarantees exclusive access to index `i`.
        unsafe fn get(&self, i: usize) -> &mut T {
            &mut *self.0.add(i)
        }
    }
    let base = Ptr(items.as_mut_ptr());
    let base = &base; // capture the wrapper, not the raw field (edition-2021 closures)
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let slots = Mutex::new(&mut out);
    thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item: &mut T = unsafe { base.get(i) };
                let r = f(i, item);
                let mut guard = slots.lock().unwrap();
                guard[i] = Some(r);
            });
        }
    });
    out.into_iter().map(|o| o.expect("scope_map_mut slot")).collect()
}

/// Parallel for over `0..n` with an index-only body.
pub fn scope_for<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Default parallelism: available cores (capped to 16 to avoid oversubscribing
/// the shared container).
pub fn default_threads() -> usize {
    thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_join_is_reusable() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for round in 0..3 {
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            pool.join();
            assert_eq!(counter.load(Ordering::SeqCst), (round + 1) * 10);
        }
    }

    #[test]
    fn pool_survives_panicking_job() {
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("boom"));
        pool.join();
        assert_eq!(pool.panic_count(), 1);
        let counter = Arc::new(AtomicU64::new(0));
        let c = Arc::clone(&counter);
        pool.execute(move || {
            c.fetch_add(1, Ordering::SeqCst);
        });
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn scope_map_preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = scope_map(&items, 8, |_, &x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn scope_map_single_thread_path() {
        let items = vec![1, 2, 3];
        assert_eq!(scope_map(&items, 1, |i, &x| i as i32 + x), vec![1, 3, 5]);
        let empty: Vec<i32> = vec![];
        assert!(scope_map(&empty, 4, |_, &x: &i32| x).is_empty());
    }

    #[test]
    fn scope_map_mut_updates_in_place() {
        let mut items: Vec<u64> = (0..500).collect();
        let out = scope_map_mut(&mut items, 8, |i, x| {
            *x += 1;
            i as u64
        });
        assert_eq!(items, (1..=500).collect::<Vec<_>>());
        assert_eq!(out, (0..500).collect::<Vec<_>>());
    }

    #[test]
    fn scope_for_covers_all_indices() {
        let hits: Vec<AtomicU64> = (0..100).map(|_| AtomicU64::new(0)).collect();
        scope_for(100, 8, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }
}
